//! Offline stub of the `xla` (xla-rs) API surface that `aie4ml`'s optional
//! `pjrt` feature compiles against.
//!
//! The build environment carries no XLA/PJRT toolchain, so this crate keeps
//! `cargo build --features pjrt` hermetic: it mirrors exactly the types and
//! signatures `aie4ml::runtime::pjrt` uses, and every entry point that would
//! touch a real PJRT client returns [`Error::Unavailable`] at runtime. To run
//! real HLO artifacts, point the `xla` path dependency in `rust/Cargo.toml`
//! at an xla-rs checkout with its PJRT runtime libraries.

use std::fmt;
use std::path::Path;

const STUB_MSG: &str = "xla/PJRT unavailable: this build links the in-repo stub \
     (rust/xla_stub); point the `xla` path dependency at a real xla-rs checkout \
     to execute HLO artifacts";

/// Error type mirroring xla-rs's crate error.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error::Unavailable(STUB_MSG))
}

/// Element types the stub literals accept (subset of xla-rs NativeType).
pub trait NativeType: Copy {}
impl NativeType for i8 {}
impl NativeType for i16 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for f32 {}
impl NativeType for f64 {}

/// A PJRT client. [`PjRtClient::cpu`] always fails in the stub, so the
/// remaining methods exist only to satisfy the type checker.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module (text form in the real crate).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// A host literal.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_refuses_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must not create clients");
        assert!(err.to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
    }

    #[test]
    fn literal_construction_is_pure() {
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert!(lit.reshape(&[3]).is_err());
    }
}
