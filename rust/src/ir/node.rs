//! IR nodes: operations with progressively-populated AIE attributes.
//!
//! Each node carries (a) frontend-level information (op kind, shapes,
//! weights, quantizers) and (b) AIE-specific attributes that the pass
//! pipeline resolves: tiling, cascade geometry, placement, packed buffers.
//! User-specified attributes arrive pre-populated from the config and are
//! honored by the passes (treated as hard constraints).

use super::quant::QuantSpec;
use crate::arch::{Dtype, MmulTiling};

pub type NodeId = usize;

/// Spatial padding mode of a Conv2D / pooling window walk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Padding {
    /// Output spatial dims = ceil(in / stride); missing taps read as zero
    /// (max/avg pooling ignores out-of-bounds taps instead).
    Same,
    /// No padding: output dims = (in - kernel) / stride + 1.
    Valid,
}

impl Padding {
    pub fn name(&self) -> &'static str {
        match self {
            Padding::Same => "same",
            Padding::Valid => "valid",
        }
    }
    pub fn parse(s: &str) -> Option<Padding> {
        match s {
            "same" => Some(Padding::Same),
            "valid" => Some(Padding::Valid),
            _ => None,
        }
    }
    fn out_dim(&self, input: usize, kernel: usize, stride: usize) -> usize {
        match self {
            Padding::Same => input.div_ceil(stride),
            Padding::Valid => (input.saturating_sub(kernel)) / stride + 1,
        }
    }
    /// Leading (top/left) pad for one spatial axis, TF/Keras 'same' split:
    /// total = max((out-1)*stride + kernel - in, 0), leading = total / 2.
    fn pad_lo(&self, input: usize, kernel: usize, stride: usize) -> usize {
        match self {
            Padding::Valid => 0,
            Padding::Same => {
                let out = self.out_dim(input, kernel, stride);
                ((out - 1) * stride + kernel).saturating_sub(input) / 2
            }
        }
    }
}

/// Shape/geometry of a Conv2D node: NHWC input `[batch, in_h, in_w, in_c]`,
/// HWIO-flattened weights `[out_c][kh*kw*in_c]` (patch order = row-major
/// over the window, channels innermost — exactly the order the implicit-GEMM
/// patch walk streams the input).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2DAttrs {
    pub in_h: usize,
    pub in_w: usize,
    pub in_c: usize,
    pub out_c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride_h: usize,
    pub stride_w: usize,
    pub padding: Padding,
    pub use_bias: bool,
    /// Populated by the Lowering pass when a following ReLU is fused.
    pub fused_relu: bool,
}

impl Conv2DAttrs {
    pub fn out_h(&self) -> usize {
        self.padding.out_dim(self.in_h, self.kh, self.stride_h)
    }
    pub fn out_w(&self) -> usize {
        self.padding.out_dim(self.in_w, self.kw, self.stride_w)
    }
    pub fn pad_top(&self) -> usize {
        self.padding.pad_lo(self.in_h, self.kh, self.stride_h)
    }
    pub fn pad_left(&self) -> usize {
        self.padding.pad_lo(self.in_w, self.kw, self.stride_w)
    }
    /// K of the lowered GEMM: one flattened patch.
    pub fn patch_len(&self) -> usize {
        self.kh * self.kw * self.in_c
    }
    /// Per-sample GEMM row count (output pixels) — the implicit-GEMM M
    /// multiplier on the batch dimension.
    pub fn gemm_m(&self) -> usize {
        self.out_h() * self.out_w()
    }
    /// Flattened input tensor width `in_h*in_w*in_c`.
    pub fn in_features(&self) -> usize {
        self.in_h * self.in_w * self.in_c
    }
    /// Flattened output tensor width `out_h*out_w*out_c`.
    pub fn out_features(&self) -> usize {
        self.gemm_m() * self.out_c
    }
    /// True MACs per sample: `OH·OW·KH·KW·C_in·C_out` — what the profiler
    /// and parallelism targets must count, not the padded GEMM shape.
    pub fn macs(&self) -> usize {
        self.gemm_m() * self.patch_len() * self.out_c
    }
}

/// Shape of a 2D pooling window walk over an NHWC tensor (channel count
/// preserved). Out-of-bounds taps under 'same' padding are *excluded*:
/// max pools over present elements, avg divides by the present count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Pool2DAttrs {
    pub in_h: usize,
    pub in_w: usize,
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride_h: usize,
    pub stride_w: usize,
    pub padding: Padding,
}

impl Pool2DAttrs {
    pub fn out_h(&self) -> usize {
        self.padding.out_dim(self.in_h, self.kh, self.stride_h)
    }
    pub fn out_w(&self) -> usize {
        self.padding.out_dim(self.in_w, self.kw, self.stride_w)
    }
    pub fn pad_top(&self) -> usize {
        self.padding.pad_lo(self.in_h, self.kh, self.stride_h)
    }
    pub fn pad_left(&self) -> usize {
        self.padding.pad_lo(self.in_w, self.kw, self.stride_w)
    }
    pub fn in_features(&self) -> usize {
        self.in_h * self.in_w * self.c
    }
    pub fn out_features(&self) -> usize {
        self.out_h() * self.out_w() * self.c
    }
}

/// Operation kind for a node.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// Network input placeholder: shape `[batch, features]`.
    Input { features: usize },
    /// Fully-connected layer (the paper's generalized linear layer).
    Dense {
        in_features: usize,
        out_features: usize,
        use_bias: bool,
        /// Populated by the Lowering pass when a following ReLU is fused.
        fused_relu: bool,
    },
    /// 2D convolution over an NHWC image, lowered onto the dense kernel via
    /// implicit GEMM: M = batch·OH·OW, K = KH·KW·C_in, N = C_out. The node
    /// *is* dense to the whole back half of the pipeline (tiling, cascade,
    /// packing, placement); the only conv-specific machinery is the
    /// patch-walk read plan ([`crate::sim::dma::ConvPatchTiler`]) that
    /// streams im2col rows straight out of the image buffer.
    Conv2D(Conv2DAttrs),
    /// Standalone activation (fused into Dense/Conv2D by Lowering).
    ReLU,
    /// Max pooling: a windowed max over the NHWC image, executed as a
    /// memory-tile stage (no compute tiles).
    MaxPool2D(Pool2DAttrs),
    /// Average pooling: windowed mean with round-half-toward-+inf (the SRS
    /// rounding flavor) and a saturating store.
    AvgPool2D(Pool2DAttrs),
    /// Per-sample 2D transpose: `[rows, cols]` row-major → `[cols, rows]`.
    /// The reshape/transpose step between an MLP-Mixer's token and channel
    /// mixing halves, executed as a memory-tile stage.
    Transpose { rows: usize, cols: usize },
    /// Residual fan-in: elementwise add of two or more activations of
    /// identical shape and quantization. The sum is taken in i32 (wrapping,
    /// like the hardware accumulator) and stored through an SRS with shift 0
    /// — a pure saturation, since all operands share one binary point.
    Add { features: usize },
    /// Feature-dimension concatenation of two or more activations (inputs
    /// ordered by edge insertion). `features` is the total output width.
    Concat { features: usize },
    /// Network output marker.
    Output,
}

impl OpKind {
    /// Does this node run on compute tiles through the generalized dense
    /// kernel? Conv2D qualifies: after lowering it is a GEMM with a
    /// patch-walk read plan.
    pub fn is_dense(&self) -> bool {
        matches!(self, OpKind::Dense { .. } | OpKind::Conv2D(_))
    }
    /// Is this a multi-input merge node (residual Add / Concat)?
    pub fn is_merge(&self) -> bool {
        matches!(self, OpKind::Add { .. } | OpKind::Concat { .. })
    }
    /// Does this node execute as a memory-tile stage (merge machinery):
    /// merges plus the single-input pooling/transpose ops?
    pub fn is_mem_stage(&self) -> bool {
        self.is_merge()
            || matches!(
                self,
                OpKind::MaxPool2D(_) | OpKind::AvgPool2D(_) | OpKind::Transpose { .. }
            )
    }
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Input { .. } => "input",
            OpKind::Dense { .. } => "dense",
            OpKind::Conv2D(_) => "conv2d",
            OpKind::ReLU => "relu",
            OpKind::MaxPool2D(_) => "maxpool2d",
            OpKind::AvgPool2D(_) => "avgpool2d",
            OpKind::Transpose { .. } => "transpose",
            OpKind::Add { .. } => "add",
            OpKind::Concat { .. } => "concat",
            OpKind::Output => "output",
        }
    }
}

/// Cascade geometry of one layer on the 2D array (paper §III-B):
/// `f_in = CAS_LEN · f_in_slice`, `f_out = CAS_NUM · f_out_slice`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CascadeGeometry {
    /// Tiles per cascade row (horizontal, reduction dimension).
    pub cas_len: usize,
    /// Number of cascade rows (vertical, output-feature dimension).
    pub cas_num: usize,
    /// Input features handled by each tile (after zero-padding).
    pub f_in_slice: usize,
    /// Output features produced by each cascade row.
    pub f_out_slice: usize,
}

impl CascadeGeometry {
    pub fn tiles(&self) -> usize {
        self.cas_len * self.cas_num
    }
    /// Padded global input dimension covered by the geometry.
    pub fn f_in_padded(&self) -> usize {
        self.cas_len * self.f_in_slice
    }
    /// Padded global output dimension covered by the geometry.
    pub fn f_out_padded(&self) -> usize {
        self.cas_num * self.f_out_slice
    }
}

/// Rectangle of tiles assigned to a layer by the Placement pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlacementRect {
    /// West-most column.
    pub col: usize,
    /// South-most row (row 0 is adjacent to the memory tiles).
    pub row: usize,
    /// Width = CAS_LEN, height = CAS_NUM.
    pub width: usize,
    pub height: usize,
}

impl PlacementRect {
    /// Column where the layer's input is injected (west edge — the cascade
    /// flows west→east, so inputs broadcast up from the memory tile below
    /// the west-most column).
    pub fn input_col(&self) -> usize {
        self.col
    }
    /// Column where outputs drain (east edge tiles hold the final SRS).
    pub fn output_col(&self) -> usize {
        self.col + self.width - 1
    }
    pub fn input_row(&self) -> usize {
        self.row
    }
    pub fn output_row(&self) -> usize {
        self.row
    }
    /// Top-most occupied row (the `r_top` term in Eq. 2).
    pub fn top_row(&self) -> usize {
        self.row + self.height - 1
    }
    /// Do two rectangles overlap?
    pub fn overlaps(&self, other: &PlacementRect) -> bool {
        self.col < other.col + other.width
            && other.col < self.col + self.width
            && self.row < other.row + other.height
            && other.row < self.row + self.height
    }
    /// Does the rectangle fit inside a cols×rows array?
    pub fn fits(&self, cols: usize, rows: usize) -> bool {
        self.col + self.width <= cols && self.row + self.height <= rows
    }
}

/// Quantization attributes of a Dense node, resolved by the Quantization
/// pass. All tensors are power-of-two scaled integers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DenseQuant {
    pub input: QuantSpec,
    pub weight: QuantSpec,
    /// Bias is stored at accumulator precision and scale.
    pub bias_dtype: Dtype,
    pub acc_dtype: Dtype,
    pub output: QuantSpec,
    /// SRS shift applied on store.
    pub shift: u32,
}

/// AIE attributes of a node, populated progressively by the pass pipeline.
/// `None` means "not yet resolved"; user overrides arrive pre-set.
#[derive(Debug, Clone, Default)]
pub struct AieAttrs {
    pub tiling: Option<MmulTiling>,
    pub cascade: Option<CascadeGeometry>,
    pub placement: Option<PlacementRect>,
    /// User pinned the placement (hard constraint for the B&B solver).
    pub placement_pinned: bool,
    pub quant: Option<DenseQuant>,
    /// Per-tile packed weight buffers, filled by the Packing pass. Indexed
    /// `[cas_row][cas_col]` flattened row-major; each buffer is the tile's
    /// weight slice laid out in ⟨K,N⟩ tile order, widened to i32 storage.
    pub packed_weights: Vec<Vec<i32>>,
    /// Per-cascade-row packed bias slices (accumulator precision).
    pub packed_bias: Vec<Vec<i64>>,
}

/// One IR node.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub op: OpKind,
    /// Raw (already-quantized) weights, row-major `[out_features][in_features]`,
    /// as exported by the frontend. Stored widened to i32.
    pub weights: Vec<i32>,
    /// Raw bias, length `out_features`, at accumulator scale.
    pub bias: Vec<i64>,
    pub attrs: AieAttrs,
}

impl Node {
    pub fn new(id: NodeId, name: impl Into<String>, op: OpKind) -> Node {
        Node {
            id,
            name: name.into(),
            op,
            weights: Vec::new(),
            bias: Vec::new(),
            attrs: AieAttrs::default(),
        }
    }

    /// GEMM dimensions (K, N) for dense-kernel nodes: `(in_features,
    /// out_features)` for Dense, `(KH·KW·C_in, C_out)` for Conv2D — the
    /// shape tiling, cascade geometry, packing and the kernels all see.
    pub fn dense_dims(&self) -> Option<(usize, usize)> {
        match self.op {
            OpKind::Dense { in_features, out_features, .. } => Some((in_features, out_features)),
            OpKind::Conv2D(c) => Some((c.patch_len(), c.out_c)),
            _ => None,
        }
    }

    /// Per-sample multiplier on the GEMM row dimension: a Conv2D computes
    /// `OH·OW` output rows per sample (implicit-GEMM M = batch · m_scale);
    /// everything else maps one sample to one row.
    pub fn m_scale(&self) -> usize {
        match self.op {
            OpKind::Conv2D(c) => c.gemm_m(),
            _ => 1,
        }
    }

    /// Conv geometry, when this node is a Conv2D.
    pub fn conv_attrs(&self) -> Option<&Conv2DAttrs> {
        match &self.op {
            OpKind::Conv2D(c) => Some(c),
            _ => None,
        }
    }

    pub fn use_bias(&self) -> bool {
        matches!(self.op, OpKind::Dense { use_bias: true, .. })
            || matches!(self.op, OpKind::Conv2D(Conv2DAttrs { use_bias: true, .. }))
    }

    pub fn fused_relu(&self) -> bool {
        matches!(self.op, OpKind::Dense { fused_relu: true, .. })
            || matches!(self.op, OpKind::Conv2D(Conv2DAttrs { fused_relu: true, .. }))
    }

    /// MACs for one sample through this node — a Conv2D counts its *true*
    /// MACs (`OH·OW·KH·KW·C_in·C_out`), not the padded GEMM shape.
    pub fn macs_per_sample(&self) -> usize {
        self.dense_dims().map(|(i, o)| i * o * self.m_scale()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rect_overlap() {
        let a = PlacementRect { col: 0, row: 0, width: 4, height: 4 };
        let b = PlacementRect { col: 3, row: 3, width: 2, height: 2 };
        let c = PlacementRect { col: 4, row: 0, width: 2, height: 2 };
        assert!(a.overlaps(&b));
        assert!(b.overlaps(&a));
        assert!(!a.overlaps(&c));
        assert!(!c.overlaps(&a));
    }

    #[test]
    fn rect_fits() {
        let a = PlacementRect { col: 36, row: 6, width: 2, height: 2 };
        assert!(a.fits(38, 8));
        assert!(!a.fits(37, 8));
        assert!(!a.fits(38, 7));
    }

    #[test]
    fn rect_io_coords() {
        let a = PlacementRect { col: 5, row: 2, width: 4, height: 3 };
        assert_eq!(a.input_col(), 5);
        assert_eq!(a.output_col(), 8);
        assert_eq!(a.top_row(), 4);
    }

    #[test]
    fn cascade_geometry_dims() {
        let g = CascadeGeometry { cas_len: 4, cas_num: 4, f_in_slice: 32, f_out_slice: 32 };
        assert_eq!(g.tiles(), 16);
        assert_eq!(g.f_in_padded(), 128);
        assert_eq!(g.f_out_padded(), 128);
    }

    #[test]
    fn conv_shape_derivation() {
        // 12x12x3, 3x3 kernel, stride 1, 'same': 12x12 out, pad 1.
        let c = Conv2DAttrs {
            in_h: 12,
            in_w: 12,
            in_c: 3,
            out_c: 8,
            kh: 3,
            kw: 3,
            stride_h: 1,
            stride_w: 1,
            padding: Padding::Same,
            use_bias: true,
            fused_relu: true,
        };
        assert_eq!((c.out_h(), c.out_w()), (12, 12));
        assert_eq!((c.pad_top(), c.pad_left()), (1, 1));
        assert_eq!(c.patch_len(), 27);
        assert_eq!(c.gemm_m(), 144);
        assert_eq!(c.macs(), 144 * 27 * 8);
        // 'valid', stride 2: floor((12-3)/2)+1 = 5.
        let v = Conv2DAttrs { padding: Padding::Valid, stride_h: 2, stride_w: 2, ..c };
        assert_eq!((v.out_h(), v.out_w()), (5, 5));
        assert_eq!((v.pad_top(), v.pad_left()), (0, 0));
        // The node views it as a (K, N) dense kernel with an M multiplier.
        let n = Node::new(0, "conv", OpKind::Conv2D(c));
        assert_eq!(n.dense_dims(), Some((27, 8)));
        assert_eq!(n.m_scale(), 144);
        assert_eq!(n.macs_per_sample(), c.macs());
        assert!(n.use_bias() && n.fused_relu());
        assert!(n.op.is_dense());
        assert!(!n.op.is_mem_stage());
    }

    #[test]
    fn pool_shape_derivation() {
        let p = Pool2DAttrs {
            in_h: 12,
            in_w: 12,
            c: 8,
            kh: 2,
            kw: 2,
            stride_h: 2,
            stride_w: 2,
            padding: Padding::Valid,
        };
        assert_eq!((p.out_h(), p.out_w()), (6, 6));
        assert_eq!(p.out_features(), 6 * 6 * 8);
        // 'same' on an odd dim: ceil(13/2) = 7, pad split leading = 0.
        let q = Pool2DAttrs { in_h: 13, padding: Padding::Same, ..p };
        assert_eq!(q.out_h(), 7);
        assert!(OpKind::MaxPool2D(p).is_mem_stage());
        assert!(!OpKind::MaxPool2D(p).is_merge());
        assert!(OpKind::Transpose { rows: 4, cols: 8 }.is_mem_stage());
    }

    #[test]
    fn node_macs() {
        let n = Node::new(
            0,
            "fc1",
            OpKind::Dense { in_features: 512, out_features: 512, use_bias: true, fused_relu: true },
        );
        assert_eq!(n.macs_per_sample(), 512 * 512);
        assert!(n.use_bias());
        assert!(n.fused_relu());
    }
}
