//! IR nodes: operations with progressively-populated AIE attributes.
//!
//! Each node carries (a) frontend-level information (op kind, shapes,
//! weights, quantizers) and (b) AIE-specific attributes that the pass
//! pipeline resolves: tiling, cascade geometry, placement, packed buffers.
//! User-specified attributes arrive pre-populated from the config and are
//! honored by the passes (treated as hard constraints).

use super::quant::QuantSpec;
use crate::arch::{Dtype, MmulTiling};

pub type NodeId = usize;

/// Operation kind for a node.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// Network input placeholder: shape `[batch, features]`.
    Input { features: usize },
    /// Fully-connected layer (the paper's generalized linear layer).
    Dense {
        in_features: usize,
        out_features: usize,
        use_bias: bool,
        /// Populated by the Lowering pass when a following ReLU is fused.
        fused_relu: bool,
    },
    /// Standalone activation (fused into Dense by Lowering when possible).
    ReLU,
    /// Residual fan-in: elementwise add of two or more activations of
    /// identical shape and quantization. The sum is taken in i32 (wrapping,
    /// like the hardware accumulator) and stored through an SRS with shift 0
    /// — a pure saturation, since all operands share one binary point.
    Add { features: usize },
    /// Feature-dimension concatenation of two or more activations (inputs
    /// ordered by edge insertion). `features` is the total output width.
    Concat { features: usize },
    /// Network output marker.
    Output,
}

impl OpKind {
    pub fn is_dense(&self) -> bool {
        matches!(self, OpKind::Dense { .. })
    }
    /// Is this a multi-input merge node (residual Add / Concat)?
    pub fn is_merge(&self) -> bool {
        matches!(self, OpKind::Add { .. } | OpKind::Concat { .. })
    }
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Input { .. } => "input",
            OpKind::Dense { .. } => "dense",
            OpKind::ReLU => "relu",
            OpKind::Add { .. } => "add",
            OpKind::Concat { .. } => "concat",
            OpKind::Output => "output",
        }
    }
}

/// Cascade geometry of one layer on the 2D array (paper §III-B):
/// `f_in = CAS_LEN · f_in_slice`, `f_out = CAS_NUM · f_out_slice`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CascadeGeometry {
    /// Tiles per cascade row (horizontal, reduction dimension).
    pub cas_len: usize,
    /// Number of cascade rows (vertical, output-feature dimension).
    pub cas_num: usize,
    /// Input features handled by each tile (after zero-padding).
    pub f_in_slice: usize,
    /// Output features produced by each cascade row.
    pub f_out_slice: usize,
}

impl CascadeGeometry {
    pub fn tiles(&self) -> usize {
        self.cas_len * self.cas_num
    }
    /// Padded global input dimension covered by the geometry.
    pub fn f_in_padded(&self) -> usize {
        self.cas_len * self.f_in_slice
    }
    /// Padded global output dimension covered by the geometry.
    pub fn f_out_padded(&self) -> usize {
        self.cas_num * self.f_out_slice
    }
}

/// Rectangle of tiles assigned to a layer by the Placement pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlacementRect {
    /// West-most column.
    pub col: usize,
    /// South-most row (row 0 is adjacent to the memory tiles).
    pub row: usize,
    /// Width = CAS_LEN, height = CAS_NUM.
    pub width: usize,
    pub height: usize,
}

impl PlacementRect {
    /// Column where the layer's input is injected (west edge — the cascade
    /// flows west→east, so inputs broadcast up from the memory tile below
    /// the west-most column).
    pub fn input_col(&self) -> usize {
        self.col
    }
    /// Column where outputs drain (east edge tiles hold the final SRS).
    pub fn output_col(&self) -> usize {
        self.col + self.width - 1
    }
    pub fn input_row(&self) -> usize {
        self.row
    }
    pub fn output_row(&self) -> usize {
        self.row
    }
    /// Top-most occupied row (the `r_top` term in Eq. 2).
    pub fn top_row(&self) -> usize {
        self.row + self.height - 1
    }
    /// Do two rectangles overlap?
    pub fn overlaps(&self, other: &PlacementRect) -> bool {
        self.col < other.col + other.width
            && other.col < self.col + self.width
            && self.row < other.row + other.height
            && other.row < self.row + self.height
    }
    /// Does the rectangle fit inside a cols×rows array?
    pub fn fits(&self, cols: usize, rows: usize) -> bool {
        self.col + self.width <= cols && self.row + self.height <= rows
    }
}

/// Quantization attributes of a Dense node, resolved by the Quantization
/// pass. All tensors are power-of-two scaled integers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DenseQuant {
    pub input: QuantSpec,
    pub weight: QuantSpec,
    /// Bias is stored at accumulator precision and scale.
    pub bias_dtype: Dtype,
    pub acc_dtype: Dtype,
    pub output: QuantSpec,
    /// SRS shift applied on store.
    pub shift: u32,
}

/// AIE attributes of a node, populated progressively by the pass pipeline.
/// `None` means "not yet resolved"; user overrides arrive pre-set.
#[derive(Debug, Clone, Default)]
pub struct AieAttrs {
    pub tiling: Option<MmulTiling>,
    pub cascade: Option<CascadeGeometry>,
    pub placement: Option<PlacementRect>,
    /// User pinned the placement (hard constraint for the B&B solver).
    pub placement_pinned: bool,
    pub quant: Option<DenseQuant>,
    /// Per-tile packed weight buffers, filled by the Packing pass. Indexed
    /// `[cas_row][cas_col]` flattened row-major; each buffer is the tile's
    /// weight slice laid out in ⟨K,N⟩ tile order, widened to i32 storage.
    pub packed_weights: Vec<Vec<i32>>,
    /// Per-cascade-row packed bias slices (accumulator precision).
    pub packed_bias: Vec<Vec<i64>>,
}

/// One IR node.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub op: OpKind,
    /// Raw (already-quantized) weights, row-major `[out_features][in_features]`,
    /// as exported by the frontend. Stored widened to i32.
    pub weights: Vec<i32>,
    /// Raw bias, length `out_features`, at accumulator scale.
    pub bias: Vec<i64>,
    pub attrs: AieAttrs,
}

impl Node {
    pub fn new(id: NodeId, name: impl Into<String>, op: OpKind) -> Node {
        Node {
            id,
            name: name.into(),
            op,
            weights: Vec::new(),
            bias: Vec::new(),
            attrs: AieAttrs::default(),
        }
    }

    /// (in_features, out_features) for Dense nodes.
    pub fn dense_dims(&self) -> Option<(usize, usize)> {
        match self.op {
            OpKind::Dense { in_features, out_features, .. } => Some((in_features, out_features)),
            _ => None,
        }
    }

    pub fn use_bias(&self) -> bool {
        matches!(self.op, OpKind::Dense { use_bias: true, .. })
    }

    pub fn fused_relu(&self) -> bool {
        matches!(self.op, OpKind::Dense { fused_relu: true, .. })
    }

    /// MACs for one sample through this node.
    pub fn macs_per_sample(&self) -> usize {
        self.dense_dims().map(|(i, o)| i * o).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rect_overlap() {
        let a = PlacementRect { col: 0, row: 0, width: 4, height: 4 };
        let b = PlacementRect { col: 3, row: 3, width: 2, height: 2 };
        let c = PlacementRect { col: 4, row: 0, width: 2, height: 2 };
        assert!(a.overlaps(&b));
        assert!(b.overlaps(&a));
        assert!(!a.overlaps(&c));
        assert!(!c.overlaps(&a));
    }

    #[test]
    fn rect_fits() {
        let a = PlacementRect { col: 36, row: 6, width: 2, height: 2 };
        assert!(a.fits(38, 8));
        assert!(!a.fits(37, 8));
        assert!(!a.fits(38, 7));
    }

    #[test]
    fn rect_io_coords() {
        let a = PlacementRect { col: 5, row: 2, width: 4, height: 3 };
        assert_eq!(a.input_col(), 5);
        assert_eq!(a.output_col(), 8);
        assert_eq!(a.top_row(), 4);
    }

    #[test]
    fn cascade_geometry_dims() {
        let g = CascadeGeometry { cas_len: 4, cas_num: 4, f_in_slice: 32, f_out_slice: 32 };
        assert_eq!(g.tiles(), 16);
        assert_eq!(g.f_in_padded(), 128);
        assert_eq!(g.f_out_padded(), 128);
    }

    #[test]
    fn node_macs() {
        let n = Node::new(
            0,
            "fc1",
            OpKind::Dense { in_features: 512, out_features: 512, use_bias: true, fused_relu: true },
        );
        assert_eq!(n.macs_per_sample(), 512 * 512);
        assert!(n.use_bias());
        assert!(n.fused_relu());
    }
}
