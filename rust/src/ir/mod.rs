//! The AIE4ML intermediate representation (paper §IV-A).
//!
//! During lowering, the frontend graph is transformed into this AIE-IR where
//! each node carries embedded metadata on layer topology, tensor dimensions,
//! quantization and connectivity; subsequent passes progressively populate
//! the AIE attributes (tiling, cascade geometry, packing, placement).

pub mod graph;
pub mod node;
pub mod quant;

pub use graph::{residual_block, sequential_mlp, Edge, Graph, GraphError};
pub use node::{
    AieAttrs, CascadeGeometry, Conv2DAttrs, DenseQuant, Node, NodeId, OpKind, Padding,
    PlacementRect, Pool2DAttrs,
};
pub use quant::{derive_shift, srs, srs_i32, QuantSpec};
