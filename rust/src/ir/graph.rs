//! The AIE-IR graph: a DAG of nodes connected by activation edges.
//!
//! Networks are true DAGs, not layer chains: a producer may fan out to
//! several consumers (one mem-tile buffer per edge, the producer
//! broadcasting to each consumer's read tiler), and fan-in is expressed
//! with explicit merge nodes — [`OpKind::Add`] for residual connections
//! (elementwise i32 add, saturating store) and [`OpKind::Concat`] for
//! feature concatenation. Merge inputs are ordered by edge insertion.
//! Network outputs are the graph's *sinks*: every node without a consumer
//! drains to the host through its own output buffer
//! ([`Graph::output_producers`], id order — frontend layer order). The
//! single-output accessors ([`Graph::output_node`] and friends) keep their
//! unique-sink contract for callers that mean "the" output, erroring with
//! [`GraphError::MultipleSinks`] on genuinely multi-output graphs.

use super::node::{Node, NodeId, OpKind};
use std::collections::HashMap;
use thiserror::Error;

#[derive(Debug, Error)]
pub enum GraphError {
    #[error("node {0} not found")]
    NodeNotFound(NodeId),
    #[error("graph has no input node")]
    NoInput,
    #[error("graph has no output node")]
    NoOutput,
    #[error("graph contains a cycle")]
    Cyclic,
    #[error("shape mismatch on edge {from}->{to}: producer {produced} features, consumer expects {expected}")]
    ShapeMismatch { from: NodeId, to: NodeId, produced: usize, expected: usize },
    #[error("graph has {0} sink nodes; exactly one network output is supported")]
    MultipleSinks(usize),
    #[error("node {node} ('{name}') has {found} inputs, which its operator does not support")]
    ArityMismatch { node: NodeId, name: String, found: usize },
}

/// A directed activation edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Edge {
    pub from: NodeId,
    pub to: NodeId,
}

/// The IR graph.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    pub edges: Vec<Edge>,
}

impl Graph {
    pub fn new() -> Graph {
        Graph::default()
    }

    pub fn add_node(&mut self, name: impl Into<String>, op: OpKind) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node::new(id, name, op));
        id
    }

    pub fn connect(&mut self, from: NodeId, to: NodeId) {
        self.edges.push(Edge { from, to });
    }

    pub fn node(&self, id: NodeId) -> Result<&Node, GraphError> {
        self.nodes.get(id).ok_or(GraphError::NodeNotFound(id))
    }

    pub fn node_mut(&mut self, id: NodeId) -> Result<&mut Node, GraphError> {
        self.nodes.get_mut(id).ok_or(GraphError::NodeNotFound(id))
    }

    pub fn predecessors(&self, id: NodeId) -> Vec<NodeId> {
        self.edges.iter().filter(|e| e.to == id).map(|e| e.from).collect()
    }

    pub fn successors(&self, id: NodeId) -> Vec<NodeId> {
        self.edges.iter().filter(|e| e.from == id).map(|e| e.to).collect()
    }

    /// Walk from `id` through non-dense nodes (merges, ReLU) to the nearest
    /// dense nodes in the given direction; Input/Output terminate a walk.
    /// The single skip-list for "which ops are transparent to dataflow" —
    /// placement's block-graph edges and emission's merge-buffer columns
    /// both rely on it.
    fn dense_neighbors(&self, id: NodeId, forward: bool) -> Vec<NodeId> {
        let step = |n: NodeId| if forward { self.successors(n) } else { self.predecessors(n) };
        let mut out = Vec::new();
        let mut seen: std::collections::HashSet<NodeId> = std::collections::HashSet::new();
        let mut stack = step(id);
        while let Some(n) = stack.pop() {
            if !seen.insert(n) {
                continue;
            }
            match self.nodes[n].op {
                OpKind::Dense { .. } | OpKind::Conv2D(_) => out.push(n),
                OpKind::Input { .. } | OpKind::Output => {}
                _ => stack.extend(step(n)),
            }
        }
        out.sort_unstable();
        out
    }

    /// Dense nodes whose outputs (transitively, through merge/ReLU nodes)
    /// feed `id`'s input, sorted by id.
    pub fn dense_ancestors(&self, id: NodeId) -> Vec<NodeId> {
        self.dense_neighbors(id, false)
    }

    /// Dense nodes that (transitively, through merge/ReLU nodes) consume
    /// `id`'s output, sorted by id.
    pub fn dense_descendants(&self, id: NodeId) -> Vec<NodeId> {
        self.dense_neighbors(id, true)
    }

    /// Dense nodes fed *directly* by the network input, in topological
    /// order — the layers whose input quantization defines the network
    /// input buffer (graph planning and emission must agree on this set).
    pub fn input_fed_dense(&self) -> Result<Vec<NodeId>, GraphError> {
        Ok(self
            .dense_order()?
            .into_iter()
            .filter(|&id| {
                self.predecessors(id)
                    .iter()
                    .any(|&p| matches!(self.nodes[p].op, OpKind::Input { .. }))
            })
            .collect())
    }

    /// Topological order of all node ids. Errors on cycles.
    pub fn topo_order(&self) -> Result<Vec<NodeId>, GraphError> {
        let mut indeg: HashMap<NodeId, usize> =
            self.nodes.iter().map(|n| (n.id, 0)).collect();
        for e in &self.edges {
            *indeg.get_mut(&e.to).ok_or(GraphError::NodeNotFound(e.to))? += 1;
        }
        let mut ready: Vec<NodeId> = self
            .nodes
            .iter()
            .filter(|n| indeg[&n.id] == 0)
            .map(|n| n.id)
            .collect();
        ready.sort_unstable();
        let mut order = Vec::with_capacity(self.nodes.len());
        while let Some(id) = ready.pop() {
            order.push(id);
            for s in self.successors(id) {
                let d = indeg.get_mut(&s).unwrap();
                *d -= 1;
                if *d == 0 {
                    ready.push(s);
                }
            }
            ready.sort_unstable();
            ready.reverse(); // pop smallest id first for determinism
        }
        if order.len() != self.nodes.len() {
            return Err(GraphError::Cyclic);
        }
        Ok(order)
    }

    /// Dense nodes in topological order — the layers the compiler maps.
    pub fn dense_order(&self) -> Result<Vec<NodeId>, GraphError> {
        Ok(self
            .topo_order()?
            .into_iter()
            .filter(|&id| self.nodes[id].op.is_dense())
            .collect())
    }

    /// Input feature count of the network.
    pub fn input_features(&self) -> Result<usize, GraphError> {
        self.nodes
            .iter()
            .find_map(|n| match n.op {
                OpKind::Input { features } => Some(features),
                _ => None,
            })
            .ok_or(GraphError::NoInput)
    }

    /// Feature count produced by a node's output, following ReLU nodes back
    /// to their producer. `None` for Output markers (they produce nothing).
    pub fn produced_features(&self, id: NodeId) -> Option<usize> {
        let mut id = id;
        for _ in 0..=self.nodes.len() {
            match self.nodes.get(id)?.op {
                OpKind::Input { features } => return Some(features),
                OpKind::Dense { out_features, .. } => return Some(out_features),
                OpKind::Conv2D(c) => return Some(c.out_features()),
                OpKind::MaxPool2D(p) | OpKind::AvgPool2D(p) => return Some(p.out_features()),
                OpKind::Transpose { rows, cols } => return Some(rows * cols),
                OpKind::Add { features } | OpKind::Concat { features } => return Some(features),
                OpKind::ReLU => id = *self.predecessors(id).first()?,
                OpKind::Output => return None,
            }
        }
        None // cycle of ReLU nodes
    }

    /// All sink nodes (no outgoing edges), in node-id order — which is the
    /// frontend's layer order for JSON-built graphs, so per-sink outputs
    /// line up with what the model author wrote.
    pub fn sink_nodes(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| self.successors(n.id).is_empty())
            .map(|n| n.id)
            .collect()
    }

    /// The nodes whose values are the network outputs: every sink, with
    /// `Output` markers skipped back to their single predecessor, in id
    /// order. This is the multi-output generalization of
    /// [`Graph::output_producer`]; single-sink graphs yield one entry.
    pub fn output_producers(&self) -> Result<Vec<NodeId>, GraphError> {
        let sinks = self.sink_nodes();
        if sinks.is_empty() {
            return Err(GraphError::NoOutput);
        }
        let mut out = Vec::with_capacity(sinks.len());
        for sink in sinks {
            if !matches!(self.nodes[sink].op, OpKind::Output) {
                out.push(sink);
                continue;
            }
            let preds = self.predecessors(sink);
            match preds.len() {
                1 => out.push(preds[0]),
                _ => return Err(GraphError::NoOutput),
            }
        }
        out.sort_unstable();
        Ok(out)
    }

    /// The unique sink node (no outgoing edges). Errors when the graph has
    /// no sink or more than one (callers that support multi-output graphs
    /// use [`Graph::output_producers`] instead).
    pub fn output_node(&self) -> Result<NodeId, GraphError> {
        let sinks = self.sink_nodes();
        match sinks.len() {
            0 => Err(GraphError::NoOutput),
            1 => Ok(sinks[0]),
            n => Err(GraphError::MultipleSinks(n)),
        }
    }

    /// The node whose value is the network output: the unique sink, skipping
    /// an `Output` marker back to its single predecessor.
    pub fn output_producer(&self) -> Result<NodeId, GraphError> {
        let sink = self.output_node()?;
        if !matches!(self.nodes[sink].op, OpKind::Output) {
            return Ok(sink);
        }
        let preds = self.predecessors(sink);
        match preds.len() {
            1 => Ok(preds[0]),
            0 => Err(GraphError::NoOutput),
            n => Err(GraphError::MultipleSinks(n)),
        }
    }

    /// Output feature count of the network, derived from the unique sink
    /// (not from "the last dense in topological order" — a DAG's final
    /// node may be a residual merge).
    pub fn output_features(&self) -> Result<usize, GraphError> {
        let id = self.output_producer()?;
        self.produced_features(id).ok_or(GraphError::NoOutput)
    }

    /// Validate per-node input arity and shape compatibility along every
    /// edge: dense layers take one input of `in_features`, Add merges take
    /// N ≥ 2 inputs of exactly `features` each, Concat merges take N ≥ 2
    /// inputs whose widths sum to `features`.
    pub fn validate_shapes(&self) -> Result<(), GraphError> {
        for n in &self.nodes {
            let preds = self.predecessors(n.id);
            let arity_ok = match n.op {
                OpKind::Input { .. } => preds.is_empty(),
                OpKind::Dense { .. }
                | OpKind::Conv2D(_)
                | OpKind::ReLU
                | OpKind::Output
                | OpKind::MaxPool2D(_)
                | OpKind::AvgPool2D(_)
                | OpKind::Transpose { .. } => preds.len() == 1,
                OpKind::Add { .. } | OpKind::Concat { .. } => preds.len() >= 2,
            };
            if !arity_ok {
                return Err(GraphError::ArityMismatch {
                    node: n.id,
                    name: n.name.clone(),
                    found: preds.len(),
                });
            }
            let expect_one = |expected: usize| -> Result<(), GraphError> {
                if let Some(produced) = self.produced_features(preds[0]) {
                    if produced != expected {
                        return Err(GraphError::ShapeMismatch {
                            from: preds[0],
                            to: n.id,
                            produced,
                            expected,
                        });
                    }
                }
                Ok(())
            };
            match n.op {
                OpKind::Dense { in_features, .. } => expect_one(in_features)?,
                OpKind::Conv2D(c) => expect_one(c.in_features())?,
                OpKind::MaxPool2D(p) | OpKind::AvgPool2D(p) => expect_one(p.in_features())?,
                OpKind::Transpose { rows, cols } => expect_one(rows * cols)?,
                OpKind::Add { features } => {
                    for &p in &preds {
                        if let Some(produced) = self.produced_features(p) {
                            if produced != features {
                                return Err(GraphError::ShapeMismatch {
                                    from: p,
                                    to: n.id,
                                    produced,
                                    expected: features,
                                });
                            }
                        }
                    }
                }
                OpKind::Concat { features } => {
                    let mut sum = 0usize;
                    let mut known = true;
                    for &p in &preds {
                        match self.produced_features(p) {
                            Some(f) => sum += f,
                            None => known = false,
                        }
                    }
                    if known && sum != features {
                        return Err(GraphError::ShapeMismatch {
                            from: preds[0],
                            to: n.id,
                            produced: sum,
                            expected: features,
                        });
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Total MACs for one sample through every dense layer.
    pub fn macs_per_sample(&self) -> usize {
        self.nodes.iter().map(|n| n.macs_per_sample()).sum()
    }

    /// Total ops (2 per MAC) for one sample.
    pub fn ops_per_sample(&self) -> usize {
        2 * self.macs_per_sample()
    }
}

/// Convenience constructor: a sequential MLP
/// `features[0] -> features[1] -> ... -> features[L]`, each layer with bias
/// and (optionally) ReLU on all but the last layer.
pub fn sequential_mlp(features: &[usize], relu_hidden: bool) -> Graph {
    assert!(features.len() >= 2, "need at least input+one layer");
    let mut g = Graph::new();
    let input = g.add_node("input", OpKind::Input { features: features[0] });
    let mut prev = input;
    for (i, w) in features.windows(2).enumerate() {
        let is_last = i == features.len() - 2;
        let id = g.add_node(
            format!("fc{}", i + 1),
            OpKind::Dense {
                in_features: w[0],
                out_features: w[1],
                use_bias: true,
                fused_relu: relu_hidden && !is_last,
            },
        );
        g.connect(prev, id);
        prev = id;
    }
    let out = g.add_node("output", OpKind::Output);
    g.connect(prev, out);
    g
}

/// Convenience constructor: a residual block
/// `input -> fc1(ReLU) -> fc2`, with `add(input, fc2)` as the network
/// output — the smallest graph exercising fan-out and fan-in.
pub fn residual_block(features: usize, hidden: usize) -> Graph {
    let mut g = Graph::new();
    let input = g.add_node("input", OpKind::Input { features });
    let fc1 = g.add_node(
        "fc1",
        OpKind::Dense { in_features: features, out_features: hidden, use_bias: true, fused_relu: true },
    );
    let fc2 = g.add_node(
        "fc2",
        OpKind::Dense { in_features: hidden, out_features: features, use_bias: true, fused_relu: false },
    );
    let res = g.add_node("res", OpKind::Add { features });
    let out = g.add_node("output", OpKind::Output);
    g.connect(input, fc1);
    g.connect(fc1, fc2);
    g.connect(input, res);
    g.connect(fc2, res);
    g.connect(res, out);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_topo() {
        let g = sequential_mlp(&[512, 512, 512], true);
        let topo = g.topo_order().unwrap();
        assert_eq!(topo.len(), 4); // input, fc1, fc2, output
        let dense = g.dense_order().unwrap();
        assert_eq!(dense.len(), 2);
        assert_eq!(g.input_features().unwrap(), 512);
        assert_eq!(g.output_features().unwrap(), 512);
        g.validate_shapes().unwrap();
    }

    #[test]
    fn macs_count() {
        let g = sequential_mlp(&[128, 128, 10], true);
        assert_eq!(g.macs_per_sample(), 128 * 128 + 128 * 10);
        assert_eq!(g.ops_per_sample(), 2 * (128 * 128 + 128 * 10));
    }

    #[test]
    fn shape_mismatch_detected() {
        let mut g = Graph::new();
        let i = g.add_node("in", OpKind::Input { features: 64 });
        let d = g.add_node(
            "fc",
            OpKind::Dense { in_features: 32, out_features: 8, use_bias: false, fused_relu: false },
        );
        g.connect(i, d);
        assert!(matches!(g.validate_shapes(), Err(GraphError::ShapeMismatch { .. })));
    }

    #[test]
    fn cycle_detected() {
        let mut g = Graph::new();
        let a = g.add_node("a", OpKind::ReLU);
        let b = g.add_node("b", OpKind::ReLU);
        g.connect(a, b);
        g.connect(b, a);
        assert!(matches!(g.topo_order(), Err(GraphError::Cyclic)));
    }

    #[test]
    fn relu_only_on_hidden() {
        let g = sequential_mlp(&[16, 32, 8], true);
        let dense = g.dense_order().unwrap();
        assert!(g.node(dense[0]).unwrap().fused_relu());
        assert!(!g.node(dense[1]).unwrap().fused_relu());
    }

    #[test]
    fn residual_block_validates_and_reports_shapes() {
        let g = residual_block(64, 128);
        g.validate_shapes().unwrap();
        assert_eq!(g.input_features().unwrap(), 64);
        // The network output is the Add merge's width, not the last dense's.
        assert_eq!(g.output_features().unwrap(), 64);
        let dense = g.dense_order().unwrap();
        assert_eq!(dense.len(), 2);
        // Fan-out: the input feeds both fc1 and the residual merge.
        assert_eq!(g.successors(0).len(), 2);
    }

    #[test]
    fn dense_neighbor_queries() {
        // residual_block ids: 0=input, 1=fc1, 2=fc2, 3=res(Add), 4=output.
        let g = residual_block(64, 128);
        assert_eq!(g.dense_ancestors(3), vec![2]); // through the merge, input stops
        assert!(g.dense_descendants(3).is_empty()); // Output terminates
        assert_eq!(g.dense_descendants(0), vec![1]); // fc1 directly; res is transparent
        assert_eq!(g.dense_ancestors(2), vec![1]);
        assert_eq!(g.input_fed_dense().unwrap(), vec![1]);
    }

    #[test]
    fn fanin_shape_mismatch_detected() {
        // fc produces 32 features but the Add merge expects 64 on both arms.
        let mut g = Graph::new();
        let i = g.add_node("in", OpKind::Input { features: 64 });
        let d = g.add_node(
            "fc",
            OpKind::Dense { in_features: 64, out_features: 32, use_bias: false, fused_relu: false },
        );
        let a = g.add_node("res", OpKind::Add { features: 64 });
        g.connect(i, d);
        g.connect(i, a);
        g.connect(d, a);
        assert!(matches!(
            g.validate_shapes(),
            Err(GraphError::ShapeMismatch { produced: 32, expected: 64, .. })
        ));
    }

    #[test]
    fn concat_width_sum_checked() {
        let mut g = Graph::new();
        let i = g.add_node("in", OpKind::Input { features: 16 });
        let a = g.add_node(
            "a",
            OpKind::Dense { in_features: 16, out_features: 8, use_bias: false, fused_relu: false },
        );
        let b = g.add_node(
            "b",
            OpKind::Dense { in_features: 16, out_features: 4, use_bias: false, fused_relu: false },
        );
        let c = g.add_node("cat", OpKind::Concat { features: 12 });
        g.connect(i, a);
        g.connect(i, b);
        g.connect(a, c);
        g.connect(b, c);
        g.validate_shapes().unwrap();
        assert_eq!(g.output_features().unwrap(), 12);
        // Wrong declared width trips the sum check.
        let mut bad = g.clone();
        bad.nodes[c].op = OpKind::Concat { features: 13 };
        assert!(matches!(bad.validate_shapes(), Err(GraphError::ShapeMismatch { .. })));
    }

    #[test]
    fn merge_arity_enforced() {
        let mut g = Graph::new();
        let i = g.add_node("in", OpKind::Input { features: 8 });
        let a = g.add_node("res", OpKind::Add { features: 8 });
        g.connect(i, a);
        assert!(matches!(g.validate_shapes(), Err(GraphError::ArityMismatch { found: 1, .. })));
    }

    #[test]
    fn cycle_through_merge_detected() {
        // fc -> add -> fc closes a loop; topo order must report Cyclic.
        let mut g = Graph::new();
        let i = g.add_node("in", OpKind::Input { features: 8 });
        let d = g.add_node(
            "fc",
            OpKind::Dense { in_features: 8, out_features: 8, use_bias: false, fused_relu: false },
        );
        let a = g.add_node("res", OpKind::Add { features: 8 });
        g.connect(i, a);
        g.connect(d, a);
        g.connect(a, d);
        assert!(matches!(g.topo_order(), Err(GraphError::Cyclic)));
        assert!(matches!(g.dense_order(), Err(GraphError::Cyclic)));
    }

    #[test]
    fn conv_pool_chain_shapes_validate() {
        use crate::ir::node::{Conv2DAttrs, Padding, Pool2DAttrs};
        // image 8x8x3 -> conv3x3 same (8 ch) -> maxpool 2x2/2 -> conv(valid)
        // -> flatten dense. Shapes flow as flattened NHWC widths.
        let conv1 = Conv2DAttrs {
            in_h: 8,
            in_w: 8,
            in_c: 3,
            out_c: 8,
            kh: 3,
            kw: 3,
            stride_h: 1,
            stride_w: 1,
            padding: Padding::Same,
            use_bias: true,
            fused_relu: false,
        };
        let pool = Pool2DAttrs {
            in_h: 8,
            in_w: 8,
            c: 8,
            kh: 2,
            kw: 2,
            stride_h: 2,
            stride_w: 2,
            padding: Padding::Valid,
        };
        let conv2 = Conv2DAttrs {
            in_h: 4,
            in_w: 4,
            in_c: 8,
            out_c: 4,
            kh: 3,
            kw: 3,
            stride_h: 1,
            stride_w: 1,
            padding: Padding::Valid,
            use_bias: false,
            fused_relu: false,
        };
        let mut g = Graph::new();
        let i = g.add_node("in", OpKind::Input { features: 8 * 8 * 3 });
        let c1 = g.add_node("c1", OpKind::Conv2D(conv1));
        let p = g.add_node("p", OpKind::MaxPool2D(pool));
        let c2 = g.add_node("c2", OpKind::Conv2D(conv2));
        let d = g.add_node(
            "fc",
            OpKind::Dense {
                in_features: 2 * 2 * 4,
                out_features: 10,
                use_bias: false,
                fused_relu: false,
            },
        );
        g.connect(i, c1);
        g.connect(c1, p);
        g.connect(p, c2);
        g.connect(c2, d);
        g.validate_shapes().unwrap();
        assert_eq!(g.produced_features(c1), Some(8 * 8 * 8));
        assert_eq!(g.produced_features(p), Some(4 * 4 * 8));
        assert_eq!(g.produced_features(c2), Some(2 * 2 * 4));
        // Pools are transparent to the dense walk (like merges): c1's
        // nearest dense descendant is c2, through the pool.
        assert_eq!(g.dense_descendants(c1), vec![c2]);
        assert_eq!(g.dense_order().unwrap(), vec![c1, c2, d]);
        // True conv MACs, not padded GEMM shapes.
        assert_eq!(
            g.macs_per_sample(),
            conv1.macs() + conv2.macs() + 2 * 2 * 4 * 10
        );
        // A channel mismatch trips the edge check.
        let mut bad = g.clone();
        bad.nodes[c2].op = OpKind::Conv2D(Conv2DAttrs { in_c: 4, ..conv2 });
        assert!(matches!(bad.validate_shapes(), Err(GraphError::ShapeMismatch { .. })));
    }

    #[test]
    fn multiple_sinks_enumerate_per_sink_producers() {
        // Two unconsumed dense layers: the single-output accessors keep
        // erroring (no unique network output), while the multi-output query
        // names both sinks in id (= layer) order.
        let mut g = Graph::new();
        let i = g.add_node("in", OpKind::Input { features: 8 });
        let a = g.add_node(
            "a",
            OpKind::Dense { in_features: 8, out_features: 4, use_bias: false, fused_relu: false },
        );
        let b = g.add_node(
            "b",
            OpKind::Dense { in_features: 8, out_features: 2, use_bias: false, fused_relu: false },
        );
        g.connect(i, a);
        g.connect(i, b);
        assert!(matches!(g.output_features(), Err(GraphError::MultipleSinks(2))));
        assert_eq!(g.output_producers().unwrap(), vec![a, b]);
        assert_eq!(g.sink_nodes(), vec![a, b]);
        // An Output marker is skipped back to its producer.
        let out = g.add_node("output", OpKind::Output);
        g.connect(b, out);
        assert_eq!(g.output_producers().unwrap(), vec![a, b]);
    }
}
