//! The AIE-IR graph: a DAG of nodes connected by activation edges.
//!
//! AIE4ML networks are (for the operator classes the paper evaluates —
//! MLPs and MLP-Mixer sub-blocks) layer *chains*; the graph structure still
//! models general fan-out so the memory-tile planner can broadcast one
//! producer to several consumers.

use super::node::{Node, NodeId, OpKind};
use std::collections::HashMap;
use thiserror::Error;

#[derive(Debug, Error)]
pub enum GraphError {
    #[error("node {0} not found")]
    NodeNotFound(NodeId),
    #[error("graph has no input node")]
    NoInput,
    #[error("graph has no output node")]
    NoOutput,
    #[error("graph contains a cycle")]
    Cyclic,
    #[error("shape mismatch on edge {from}->{to}: producer {produced} features, consumer expects {expected}")]
    ShapeMismatch { from: NodeId, to: NodeId, produced: usize, expected: usize },
}

/// A directed activation edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Edge {
    pub from: NodeId,
    pub to: NodeId,
}

/// The IR graph.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    pub edges: Vec<Edge>,
}

impl Graph {
    pub fn new() -> Graph {
        Graph::default()
    }

    pub fn add_node(&mut self, name: impl Into<String>, op: OpKind) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node::new(id, name, op));
        id
    }

    pub fn connect(&mut self, from: NodeId, to: NodeId) {
        self.edges.push(Edge { from, to });
    }

    pub fn node(&self, id: NodeId) -> Result<&Node, GraphError> {
        self.nodes.get(id).ok_or(GraphError::NodeNotFound(id))
    }

    pub fn node_mut(&mut self, id: NodeId) -> Result<&mut Node, GraphError> {
        self.nodes.get_mut(id).ok_or(GraphError::NodeNotFound(id))
    }

    pub fn predecessors(&self, id: NodeId) -> Vec<NodeId> {
        self.edges.iter().filter(|e| e.to == id).map(|e| e.from).collect()
    }

    pub fn successors(&self, id: NodeId) -> Vec<NodeId> {
        self.edges.iter().filter(|e| e.from == id).map(|e| e.to).collect()
    }

    /// Topological order of all node ids. Errors on cycles.
    pub fn topo_order(&self) -> Result<Vec<NodeId>, GraphError> {
        let mut indeg: HashMap<NodeId, usize> =
            self.nodes.iter().map(|n| (n.id, 0)).collect();
        for e in &self.edges {
            *indeg.get_mut(&e.to).ok_or(GraphError::NodeNotFound(e.to))? += 1;
        }
        let mut ready: Vec<NodeId> = self
            .nodes
            .iter()
            .filter(|n| indeg[&n.id] == 0)
            .map(|n| n.id)
            .collect();
        ready.sort_unstable();
        let mut order = Vec::with_capacity(self.nodes.len());
        while let Some(id) = ready.pop() {
            order.push(id);
            for s in self.successors(id) {
                let d = indeg.get_mut(&s).unwrap();
                *d -= 1;
                if *d == 0 {
                    ready.push(s);
                }
            }
            ready.sort_unstable();
            ready.reverse(); // pop smallest id first for determinism
        }
        if order.len() != self.nodes.len() {
            return Err(GraphError::Cyclic);
        }
        Ok(order)
    }

    /// Dense nodes in topological order — the layers the compiler maps.
    pub fn dense_order(&self) -> Result<Vec<NodeId>, GraphError> {
        Ok(self
            .topo_order()?
            .into_iter()
            .filter(|&id| self.nodes[id].op.is_dense())
            .collect())
    }

    /// Input feature count of the network.
    pub fn input_features(&self) -> Result<usize, GraphError> {
        self.nodes
            .iter()
            .find_map(|n| match n.op {
                OpKind::Input { features } => Some(features),
                _ => None,
            })
            .ok_or(GraphError::NoInput)
    }

    /// Output feature count (out_features of the last dense layer).
    pub fn output_features(&self) -> Result<usize, GraphError> {
        let dense = self.dense_order()?;
        let last = *dense.last().ok_or(GraphError::NoOutput)?;
        Ok(self.nodes[last].dense_dims().unwrap().1)
    }

    /// Validate shape compatibility along every dense→dense edge and from
    /// the input node into the first dense layer.
    pub fn validate_shapes(&self) -> Result<(), GraphError> {
        let feat_out = |n: &Node| -> Option<usize> {
            match n.op {
                OpKind::Input { features } => Some(features),
                OpKind::Dense { out_features, .. } => Some(out_features),
                _ => None,
            }
        };
        for e in &self.edges {
            let from = self.node(e.from)?;
            let to = self.node(e.to)?;
            if let (Some(produced), OpKind::Dense { in_features, .. }) = (feat_out(from), &to.op) {
                if produced != *in_features {
                    return Err(GraphError::ShapeMismatch {
                        from: e.from,
                        to: e.to,
                        produced,
                        expected: *in_features,
                    });
                }
            }
        }
        Ok(())
    }

    /// Total MACs for one sample through every dense layer.
    pub fn macs_per_sample(&self) -> usize {
        self.nodes.iter().map(|n| n.macs_per_sample()).sum()
    }

    /// Total ops (2 per MAC) for one sample.
    pub fn ops_per_sample(&self) -> usize {
        2 * self.macs_per_sample()
    }
}

/// Convenience constructor: a sequential MLP
/// `features[0] -> features[1] -> ... -> features[L]`, each layer with bias
/// and (optionally) ReLU on all but the last layer.
pub fn sequential_mlp(features: &[usize], relu_hidden: bool) -> Graph {
    assert!(features.len() >= 2, "need at least input+one layer");
    let mut g = Graph::new();
    let input = g.add_node("input", OpKind::Input { features: features[0] });
    let mut prev = input;
    for (i, w) in features.windows(2).enumerate() {
        let is_last = i == features.len() - 2;
        let id = g.add_node(
            format!("fc{}", i + 1),
            OpKind::Dense {
                in_features: w[0],
                out_features: w[1],
                use_bias: true,
                fused_relu: relu_hidden && !is_last,
            },
        );
        g.connect(prev, id);
        prev = id;
    }
    let out = g.add_node("output", OpKind::Output);
    g.connect(prev, out);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_topo() {
        let g = sequential_mlp(&[512, 512, 512], true);
        let topo = g.topo_order().unwrap();
        assert_eq!(topo.len(), 4); // input, fc1, fc2, output
        let dense = g.dense_order().unwrap();
        assert_eq!(dense.len(), 2);
        assert_eq!(g.input_features().unwrap(), 512);
        assert_eq!(g.output_features().unwrap(), 512);
        g.validate_shapes().unwrap();
    }

    #[test]
    fn macs_count() {
        let g = sequential_mlp(&[128, 128, 10], true);
        assert_eq!(g.macs_per_sample(), 128 * 128 + 128 * 10);
        assert_eq!(g.ops_per_sample(), 2 * (128 * 128 + 128 * 10));
    }

    #[test]
    fn shape_mismatch_detected() {
        let mut g = Graph::new();
        let i = g.add_node("in", OpKind::Input { features: 64 });
        let d = g.add_node(
            "fc",
            OpKind::Dense { in_features: 32, out_features: 8, use_bias: false, fused_relu: false },
        );
        g.connect(i, d);
        assert!(matches!(g.validate_shapes(), Err(GraphError::ShapeMismatch { .. })));
    }

    #[test]
    fn cycle_detected() {
        let mut g = Graph::new();
        let a = g.add_node("a", OpKind::ReLU);
        let b = g.add_node("b", OpKind::ReLU);
        g.connect(a, b);
        g.connect(b, a);
        assert!(matches!(g.topo_order(), Err(GraphError::Cyclic)));
    }

    #[test]
    fn relu_only_on_hidden() {
        let g = sequential_mlp(&[16, 32, 8], true);
        let dense = g.dense_order().unwrap();
        assert!(g.node(dense[0]).unwrap().fused_relu());
        assert!(!g.node(dense[1]).unwrap().fused_relu());
    }
}
