//! Quantization metadata and the shift-round-saturate (SRS) primitive.
//!
//! AIE4ML operates on power-of-two–scaled integer tensors (the regime used by
//! hls4ml/QKeras-style quantizers): a tensor holds integers `q` representing
//! real values `q · 2^-frac_bits`. A linear layer accumulates exactly in a
//! wide accumulator and requantizes on store with the hardware `VST.SRS`
//! instruction, which applies shift (scaling), rounding and saturation in one
//! step (paper §III-A). This module defines the *single* integer semantics
//! every implementation in the stack (Pallas kernel, jnp reference, Rust
//! functional simulator, PJRT-executed HLO) must match bit-exactly.

use crate::arch::Dtype;

/// Quantization spec of one tensor: storage dtype + binary-point position.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantSpec {
    pub dtype: Dtype,
    /// Number of fractional bits: real value = int · 2^-frac_bits.
    pub frac_bits: i32,
}

impl QuantSpec {
    pub const fn new(dtype: Dtype, frac_bits: i32) -> Self {
        QuantSpec { dtype, frac_bits }
    }

    /// Quantize a real value into this spec (round-half-up, saturating) —
    /// used only at the model boundary (optional float I/O), never on the
    /// integer inference path.
    pub fn quantize(&self, x: f64) -> i64 {
        let scaled = x * (2f64).powi(self.frac_bits);
        self.dtype.saturate(scaled.round_ties_even() as i64)
    }

    /// Dequantize back to a real value.
    pub fn dequantize(&self, q: i64) -> f64 {
        q as f64 * (2f64).powi(-self.frac_bits)
    }
}

/// Shift-round-saturate: `y = sat_dtype(round_half_up(acc / 2^shift))`.
///
/// `round_half_up(acc / 2^s) = (acc + 2^(s-1)) >> s` with an arithmetic
/// shift, for `s > 0`; `s == 0` is a pure saturate. The addition is wrapping
/// (the AIE accumulator is modular); saturation happens only at the store.
///
/// This is the exact semantics mirrored by `kernels/linear.py::srs` and
/// `kernels/ref.py::srs` on the Python side — change all of them together
/// or bit-exactness tests fail.
pub fn srs(acc: i64, shift: u32, out: Dtype) -> i64 {
    debug_assert!(shift < 63, "srs shift out of range: {shift}");
    let rounded = if shift == 0 {
        acc
    } else {
        acc.wrapping_add(1i64 << (shift - 1)) >> shift
    };
    out.saturate(rounded)
}

/// SRS over an `i32` accumulator (i8×i8 and i16×i8 paths): the rounding add
/// wraps in 32-bit before the shift, matching the hardware accumulator width
/// and `jnp.int32` arithmetic.
pub fn srs_i32(acc: i32, shift: u32, out: Dtype) -> i32 {
    debug_assert!(shift < 31, "srs32 shift out of range: {shift}");
    let rounded = if shift == 0 {
        acc
    } else {
        acc.wrapping_add(1i32 << (shift - 1)) >> shift
    };
    out.saturate(rounded as i64) as i32
}

/// Derive the output shift for a layer so the binary points line up:
/// `acc_frac = in_frac + w_frac`, and the store must produce `out_frac`,
/// so `shift = acc_frac - out_frac` (clamped at 0: we never up-shift on
/// store; the resolver widens `out_frac` instead).
pub fn derive_shift(in_frac: i32, w_frac: i32, out_frac: i32) -> u32 {
    (in_frac + w_frac - out_frac).max(0) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn srs_rounds_half_up() {
        // 3/2 = 1.5 -> 2 ; -3/2 = -1.5 -> -1 (round half toward +inf)
        assert_eq!(srs(3, 1, Dtype::I8), 2);
        assert_eq!(srs(-3, 1, Dtype::I8), -1);
        assert_eq!(srs(4, 2, Dtype::I8), 1);
        assert_eq!(srs(6, 2, Dtype::I8), 2); // 1.5 -> 2
        assert_eq!(srs(5, 2, Dtype::I8), 1); // 1.25 -> 1
        assert_eq!(srs(7, 2, Dtype::I8), 2); // 1.75 -> 2
    }

    #[test]
    fn srs_saturates() {
        assert_eq!(srs(1000, 1, Dtype::I8), 127);
        assert_eq!(srs(-1000, 1, Dtype::I8), -128);
        assert_eq!(srs(1 << 20, 4, Dtype::I16), 32767);
    }

    #[test]
    fn srs_zero_shift_is_saturate() {
        assert_eq!(srs(300, 0, Dtype::I8), 127);
        assert_eq!(srs(42, 0, Dtype::I8), 42);
    }

    #[test]
    fn srs_i32_matches_wide_when_no_wrap() {
        for acc in [-70000i64, -129, -1, 0, 1, 127, 70000] {
            for s in [0u32, 1, 3, 8] {
                assert_eq!(
                    srs(acc, s, Dtype::I8),
                    srs_i32(acc as i32, s, Dtype::I8) as i64,
                    "acc={acc} s={s}"
                );
            }
        }
    }

    #[test]
    fn srs_i32_wraps_on_rounding_overflow() {
        // i32::MAX + rounding bias wraps — the 64-bit version must not be
        // used on the 32-bit accumulator path, precisely because of this.
        let acc = i32::MAX;
        let w = srs_i32(acc, 1, Dtype::I16);
        // (MAX + 1) wraps to MIN; MIN >> 1 is very negative -> saturates low.
        assert_eq!(w, -32768);
        assert_eq!(srs(acc as i64, 1, Dtype::I16), 32767);
    }

    #[test]
    fn quantize_dequantize() {
        let q = QuantSpec::new(Dtype::I8, 6);
        assert_eq!(q.quantize(0.5), 32);
        assert_eq!(q.quantize(10.0), 127); // saturates
        assert!((q.dequantize(32) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn shift_derivation() {
        // in 6 frac bits, w 6 frac bits, out 6 frac bits -> shift 6.
        assert_eq!(derive_shift(6, 6, 6), 6);
        assert_eq!(derive_shift(0, 0, 0), 0);
        assert_eq!(derive_shift(2, 2, 8), 0); // clamped
    }
}
