//! Content-addressed firmware cache: memoized 7-pass compiles.
//!
//! Compile-in-the-loop partitioning ([`crate::partition::choose_cuts`]),
//! the deploy planner's (device group × batch × K) candidate sweep and any
//! autoscaler re-planning all evaluate *many* candidate compiles of the
//! same slices — compile throughput becomes a serving-path latency once
//! plans are recomputed under live traffic. Compiles are pure functions of
//! (model structure, [`CompileConfig`], device), so this module caches
//! them under a structural content hash:
//!
//! * the key covers every compile-relevant input — layer payloads
//!   (weights, bias), shapes, quantizers, DAG wiring (resolved through
//!   [`JsonModel::effective_inputs`], so chain-default and explicit wiring
//!   hash identically) and the canonical [`CompileConfig::to_json_string`]
//!   serialization (which includes the target device);
//! * the **model name is excluded**: a partition slice compiled while the
//!   cut DP scored candidates is byte-identical firmware to the same slice
//!   compiled as `model.p0` later, so a hit rehydrates the cached
//!   [`Model`] under the requested name;
//! * failures are cached too — an over-capacity K = 1 candidate rejected
//!   once is rejected from cache on every later sweep;
//! * cold compiles fan out across a bounded thread pool
//!   ([`FirmwareCache::compile_many`]) — compiles share no state, so the
//!   planner's candidate sweep and the cut DP's slice grid parallelize
//!   freely.
//!
//! `util::rng`'s FNV-1a seeds names; it is *not* the cache hasher. Keys
//! here are 128-bit structural digests over length-delimited field streams
//! (two independently-seeded FNV-64 lanes, one with positional rotation),
//! so accidental collisions between near-identical models — same shapes,
//! one weight changed — are not a practical concern.

use crate::frontend::{CompileConfig, JsonModel};
use crate::passes::{compile, Model};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// 128-bit structural digest of (model structure, config, device).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CacheKey {
    pub lo: u64,
    pub hi: u64,
}

impl std::fmt::Display for CacheKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}{:016x}", self.hi, self.lo)
    }
}

/// Two independent FNV-64 lanes over a length-delimited byte stream. The
/// second lane rotates its state per byte, so the lanes decorrelate and
/// the combined digest behaves as a 128-bit hash for non-adversarial use.
struct StructuralHasher {
    a: u64,
    b: u64,
}

const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl StructuralHasher {
    fn new() -> StructuralHasher {
        StructuralHasher { a: 0xcbf2_9ce4_8422_2325, b: 0x6c62_272e_07bb_0142 }
    }

    fn byte(&mut self, x: u8) {
        self.a = (self.a ^ x as u64).wrapping_mul(FNV_PRIME);
        self.b = (self.b.rotate_left(5) ^ x as u64).wrapping_mul(FNV_PRIME);
    }

    fn bytes(&mut self, xs: &[u8]) {
        for &x in xs {
            self.byte(x);
        }
    }

    fn u64(&mut self, x: u64) {
        self.bytes(&x.to_le_bytes());
    }

    /// Length-delimited string (length first, so "ab"+"c" != "a"+"bc").
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes());
    }

    fn finish(&self) -> CacheKey {
        CacheKey { lo: self.a, hi: self.b }
    }
}

/// The structural cache key of one compile request. Everything the 7-pass
/// pipeline reads goes in **except the model name** — see the module doc.
pub fn structural_key(json: &JsonModel, cfg: &CompileConfig) -> CacheKey {
    let mut h = StructuralHasher::new();
    // The canonical config serialization covers device, batch, placement
    // weights, tiles_per_layer, extra_outputs and per-layer overrides.
    h.str(&cfg.to_json_string());
    let inputs = json.effective_inputs();
    h.u64(json.layers.len() as u64);
    for (l, srcs) in json.layers.iter().zip(&inputs) {
        h.str(&l.name);
        h.str(&l.ty);
        h.u64(l.in_features as u64);
        h.u64(l.out_features as u64);
        h.byte(l.use_bias as u8);
        h.byte(l.relu as u8);
        for q in [&l.quant.input, &l.quant.weight, &l.quant.output] {
            h.str(&q.dtype);
            h.u64(q.frac_bits as u64);
        }
        h.u64(l.weights.len() as u64);
        for &w in &l.weights {
            h.bytes(&w.to_le_bytes());
        }
        h.u64(l.bias.len() as u64);
        for &b in &l.bias {
            h.bytes(&b.to_le_bytes());
        }
        h.u64(srcs.len() as u64);
        for s in srcs {
            h.str(s);
        }
    }
    h.finish()
}

/// Hit/miss counters of a cache (hits + misses = compile requests served).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
    pub entries: usize,
    /// Cached *failures* (infeasible candidates remembered so later
    /// sweeps reject them without re-running the pass pipeline).
    pub negative_entries: usize,
}

impl CacheStats {
    pub fn requests(&self) -> usize {
        self.hits + self.misses
    }

    /// Hit ratio in [0, 1]; 0 for an unused cache.
    pub fn hit_ratio(&self) -> f64 {
        if self.requests() == 0 {
            0.0
        } else {
            self.hits as f64 / self.requests() as f64
        }
    }
}

impl std::fmt::Display for CacheStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} hits / {} compiles ({:.0}% hit rate, {} cached, {} negative)",
            self.hits,
            self.requests(),
            100.0 * self.hit_ratio(),
            self.entries,
            self.negative_entries
        )
    }
}

/// Compiled outcome as stored: successes keep the whole [`Model`]
/// (placement report, firmware, memtile plans); failures keep the
/// flattened error text so later requests fail identically without
/// re-running the pass pipeline.
type CachedCompile = std::result::Result<Model, String>;

/// The content-addressed firmware cache. Cheap to construct, internally
/// synchronized — share one per planning session (`&FirmwareCache`
/// everywhere; wrap in `Arc` to share across threads you spawn yourself).
#[derive(Default)]
pub struct FirmwareCache {
    entries: Mutex<HashMap<CacheKey, CachedCompile>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl FirmwareCache {
    pub fn new() -> FirmwareCache {
        FirmwareCache::default()
    }

    pub fn stats(&self) -> CacheStats {
        let entries = self.entries.lock().unwrap();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: entries.len(),
            negative_entries: entries.values().filter(|e| e.is_err()).count(),
        }
    }

    /// Rehydrate a cached outcome under the requested identity: the model
    /// name is the one field outside the key, so a hit renames the clone
    /// (and its firmware) to what this caller asked for — firmware bytes
    /// are otherwise identical to a fresh compile.
    fn rehydrate(entry: &CachedCompile, json: &JsonModel, cfg: &CompileConfig) -> Result<Model> {
        match entry {
            Ok(m) => {
                let mut m = m.clone();
                m.name = json.name.clone();
                m.config = cfg.clone();
                if let Some(fw) = m.firmware.as_mut() {
                    fw.model_name = json.name.clone();
                }
                Ok(m)
            }
            Err(msg) => Err(anyhow::anyhow!("{msg}")),
        }
    }

    /// Compile `json` under `cfg`, serving from cache when the structural
    /// key is known. Exactly [`crate::passes::compile`] semantics
    /// otherwise (including failures, which are cached by content too).
    pub fn compile(&self, json: &JsonModel, cfg: CompileConfig) -> Result<Model> {
        let key = structural_key(json, &cfg);
        if let Some(entry) = self.entries.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            crate::obs::tracer()
                .instant("cache", "fw_cache_hit")
                .with_arg("key", key.to_string())
                .with_arg("negative", entry.is_err());
            return Self::rehydrate(entry, json, &cfg);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let _span = crate::obs::tracer()
            .span("cache", "fw_cache_miss_compile")
            .with_arg("key", key.to_string());
        let result = compile(json, cfg);
        let stored: CachedCompile = match &result {
            Ok(m) => Ok(m.clone()),
            Err(e) => Err(format!("{e:#}")),
        };
        self.entries.lock().unwrap().insert(key, stored);
        result
    }

    /// Compile a batch of requests, running the **cold** ones across a
    /// bounded thread pool (compiles are pure; results land in the cache
    /// exactly as sequential [`FirmwareCache::compile`] calls would).
    /// Returns one outcome per request, in order.
    pub fn compile_many(&self, jobs: &[(JsonModel, CompileConfig)]) -> Vec<Result<Model>> {
        let keys: Vec<CacheKey> = jobs.iter().map(|(j, c)| structural_key(j, c)).collect();
        // Unique keys not yet cached, each with one representative job.
        let mut cold: Vec<usize> = Vec::new();
        {
            let entries = self.entries.lock().unwrap();
            let mut seen: HashMap<CacheKey, ()> = HashMap::new();
            for (i, k) in keys.iter().enumerate() {
                if !entries.contains_key(k) && seen.insert(*k, ()).is_none() {
                    cold.push(i);
                }
            }
        }
        self.misses.fetch_add(cold.len(), Ordering::Relaxed);
        self.hits.fetch_add(jobs.len() - cold.len(), Ordering::Relaxed);
        let _span = crate::obs::tracer()
            .span("cache", "fw_cache_compile_many")
            .with_arg("jobs", jobs.len())
            .with_arg("cold", cold.len());
        if !cold.is_empty() {
            let workers = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .clamp(1, 8)
                .min(cold.len());
            let next = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                for _ in 0..workers {
                    scope.spawn(|| loop {
                        let slot = next.fetch_add(1, Ordering::Relaxed);
                        let Some(&i) = cold.get(slot) else { break };
                        let (json, cfg) = &jobs[i];
                        let result = compile(json, cfg.clone());
                        let stored: CachedCompile = match result {
                            Ok(m) => Ok(m),
                            Err(e) => Err(format!("{e:#}")),
                        };
                        self.entries.lock().unwrap().insert(keys[i], stored);
                    });
                }
            });
        }
        let entries = self.entries.lock().unwrap();
        jobs.iter()
            .zip(&keys)
            .map(|((json, cfg), key)| {
                let entry = entries.get(key).expect("every job compiled above");
                Self::rehydrate(entry, json, cfg)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dtype;
    use crate::harness::models::{mlp_spec, synth_model};

    fn cfg(batch: usize) -> CompileConfig {
        let mut c = CompileConfig::default();
        c.batch = batch;
        c.tiles_per_layer = Some(2);
        c
    }

    #[test]
    fn key_ignores_name_but_sees_everything_else() {
        let a = synth_model("cache_a", &mlp_spec(&[32, 16, 8], Dtype::I8), 6);
        let mut renamed = a.clone();
        renamed.name = "cache_b".into();
        let c = cfg(4);
        assert_eq!(structural_key(&a, &c), structural_key(&renamed, &c));

        // One weight flipped -> different key.
        let mut tweaked = a.clone();
        tweaked.layers[0].weights[0] = tweaked.layers[0].weights[0].wrapping_add(1);
        assert_ne!(structural_key(&a, &c), structural_key(&tweaked, &c));

        // Different batch, device or extra outputs -> different key.
        assert_ne!(structural_key(&a, &c), structural_key(&a, &cfg(8)));
        let mut dev = cfg(4);
        dev.device = "vek385".into();
        assert_ne!(structural_key(&a, &c), structural_key(&a, &dev));
        let mut extra = cfg(4);
        extra.extra_outputs = vec!["fc1".into()];
        assert_ne!(structural_key(&a, &c), structural_key(&a, &extra));
    }

    #[test]
    fn key_resolves_chain_default_wiring() {
        // A chain with empty `inputs` and the same chain wired explicitly
        // compile identically, so they must share a key.
        let implicit = synth_model("cache_wire", &mlp_spec(&[24, 16, 8], Dtype::I8), 6);
        let mut explicit = implicit.clone();
        explicit.layers[1].inputs = vec!["fc1".into()];
        assert_eq!(structural_key(&implicit, &cfg(4)), structural_key(&explicit, &cfg(4)));
    }

    #[test]
    fn hit_rehydrates_under_the_requested_name() {
        let a = synth_model("cache_hit_a", &mlp_spec(&[32, 16], Dtype::I8), 6);
        let mut b = a.clone();
        b.name = "cache_hit_b".into();
        let cache = FirmwareCache::new();
        let ma = cache.compile(&a, cfg(4)).unwrap();
        let mb = cache.compile(&b, cfg(4)).unwrap();
        let s = cache.stats();
        assert_eq!((s.misses, s.hits, s.entries), (1, 1, 1));
        assert_eq!(mb.name, "cache_hit_b");
        assert_eq!(mb.firmware.as_ref().unwrap().model_name, "cache_hit_b");
        // Identical apart from the identity fields.
        let ja = ma.firmware.unwrap().to_json().unwrap();
        let jb = mb.firmware.unwrap().to_json().unwrap();
        assert_eq!(ja.replace("cache_hit_a", "X"), jb.replace("cache_hit_b", "X"));
    }

    #[test]
    fn failures_are_cached() {
        let mut m = synth_model("cache_fail", &mlp_spec(&[32, 16], Dtype::I8), 6);
        m.layers.clear(); // empty model: validation fails in to_graph
        let cache = FirmwareCache::new();
        let e1 = cache.compile(&m, cfg(4)).unwrap_err().to_string();
        let e2 = cache.compile(&m, cfg(4)).unwrap_err().to_string();
        assert_eq!(e1, e2);
        let s = cache.stats();
        assert_eq!((s.misses, s.hits), (1, 1));
        assert_eq!(s.negative_entries, 1, "a cached failure is a negative entry");
    }

    #[test]
    fn compile_many_deduplicates_and_parallelizes() {
        let a = synth_model("cache_many_a", &mlp_spec(&[32, 16, 8], Dtype::I8), 6);
        let b = synth_model("cache_many_b", &mlp_spec(&[48, 24, 8], Dtype::I8), 6);
        let mut a_alias = a.clone();
        a_alias.name = "cache_many_alias".into();
        let cache = FirmwareCache::new();
        let jobs = vec![
            (a.clone(), cfg(4)),
            (b.clone(), cfg(4)),
            (a_alias.clone(), cfg(4)), // same content as `a`
        ];
        let out = cache.compile_many(&jobs);
        assert_eq!(out.len(), 3);
        for (i, r) in out.iter().enumerate() {
            assert!(r.is_ok(), "job {i} failed: {:?}", r.as_ref().err());
        }
        assert_eq!(out[2].as_ref().unwrap().name, "cache_many_alias");
        let s = cache.stats();
        assert_eq!((s.misses, s.hits, s.entries), (2, 1, 2));
        // A second sweep is all hits.
        let again = cache.compile_many(&jobs);
        assert!(again.iter().all(|r| r.is_ok()));
        let s = cache.stats();
        assert_eq!((s.misses, s.hits), (2, 4));
    }

    #[test]
    fn cached_compile_is_byte_identical_to_fresh() {
        // Determinism gate: same key -> byte-identical firmware.json, and
        // the cache round trip changes nothing against a fresh compile.
        let m = synth_model("cache_det", &mlp_spec(&[64, 32, 8], Dtype::I8), 6);
        let fresh = crate::passes::compile(&m, cfg(8)).unwrap();
        let cache = FirmwareCache::new();
        let cold = cache.compile(&m, cfg(8)).unwrap();
        let warm = cache.compile(&m, cfg(8)).unwrap();
        let j = |model: &Model| model.firmware.as_ref().unwrap().to_json().unwrap();
        assert_eq!(j(&fresh), j(&cold));
        assert_eq!(j(&cold), j(&warm));
        assert_eq!(cache.stats().hits, 1);
    }
}
