//! Figure 3 — automatic placement: B&B vs two greedy baselines on a 38×8
//! array (start (0,0), λ=1.0, µ=0.05).

use crate::passes::placement::{
    greedy_above, greedy_above_graph, greedy_right, greedy_right_graph, place_bnb,
    place_bnb_graph, BlockSpec, PlacementProblem, PlacementReport,
};
use anyhow::Result;
use std::fmt::Write as _;

/// The example graph set: a deep chain of mixed-aspect layer blocks of the
/// kind multi-layer MLP/Mixer models produce. Total width exceeds the
/// array, so naive strategies are forced into long wrap-around hops —
/// the regime Fig. 3 illustrates.
pub fn example_blocks() -> Vec<BlockSpec> {
    let shapes: &[(usize, usize)] =
        &[(10, 3), (12, 2), (8, 3), (14, 2), (10, 3), (6, 4), (12, 2), (9, 2)];
    shapes
        .iter()
        .enumerate()
        .map(|(i, &(w, h))| BlockSpec { name: format!("G{i}"), width: w, height: h, pinned: None })
        .collect()
}

/// The paper's Fig. 3 setup.
pub fn problem() -> PlacementProblem {
    PlacementProblem { cols: 38, rows: 8, lambda: 1.0, mu: 0.05, start: (0, 0), max_nodes: 150_000 }
}

/// A branching block graph (residual-MLP shape): a stem fans out into two
/// parallel branches that re-merge into a head, followed by a short tail —
/// the regime where the edge-weighted Eq. 2 objective differs from a
/// chain's. Returns (blocks, edges).
pub fn branching_blocks() -> (Vec<BlockSpec>, Vec<(usize, usize)>) {
    let shapes: &[(usize, usize)] = &[(8, 3), (10, 2), (6, 4), (8, 3), (12, 2), (6, 2)];
    let blocks = shapes
        .iter()
        .enumerate()
        .map(|(i, &(w, h))| BlockSpec { name: format!("G{i}"), width: w, height: h, pinned: None })
        .collect();
    // G0 -> {G1, G2} -> G3 (fan-in), then G3 -> G4 -> G5.
    let edges = vec![(0, 1), (0, 2), (1, 3), (2, 3), (3, 4), (4, 5)];
    (blocks, edges)
}

/// Run all three strategies on the branching scenario.
pub fn generate_branching() -> Result<(PlacementReport, PlacementReport, PlacementReport)> {
    let (blocks, edges) = branching_blocks();
    let p = problem();
    Ok((
        place_bnb_graph(&blocks, &edges, &p)?,
        greedy_right_graph(&blocks, &edges, &p)?,
        greedy_above_graph(&blocks, &edges, &p)?,
    ))
}

/// Render the branching comparison (costs + B&B search effort).
pub fn render_branching() -> Result<String> {
    let (bnb, gr, ga) = generate_branching()?;
    let p = problem();
    let mut s = String::new();
    let _ = writeln!(
        s,
        "FIG. 3b — edge-weighted placement (fan-out + fan-in) on 38x8, lambda=1.0, mu=0.05"
    );
    let _ = writeln!(
        s,
        "(a) branch-and-bound   J = {:.2}  ({} nodes, optimal={}, {:.1} ms)",
        bnb.cost, bnb.nodes_explored, bnb.optimal, bnb.elapsed_ms
    );
    let _ = write!(s, "{}", floorplan(&bnb, &p));
    let _ = writeln!(s, "(b) greedy-right       J = {:.2}", gr.cost);
    let _ = write!(s, "{}", floorplan(&gr, &p));
    let _ = writeln!(s, "(c) greedy-above       J = {:.2}", ga.cost);
    let _ = write!(s, "{}", floorplan(&ga, &p));
    Ok(s)
}

/// Run all three strategies.
pub fn generate() -> Result<(PlacementReport, PlacementReport, PlacementReport)> {
    let blocks = example_blocks();
    let p = problem();
    Ok((place_bnb(&blocks, &p)?, greedy_right(&blocks, &p)?, greedy_above(&blocks, &p)?))
}

fn floorplan(rep: &PlacementReport, p: &PlacementProblem) -> String {
    let mut grid = vec![vec!['.'; p.cols]; p.rows];
    for (i, r) in rep.rects.iter().enumerate() {
        let ch = char::from_digit(((i + 1) % 36) as u32, 36).unwrap_or('#');
        for row in r.row..r.row + r.height {
            for col in r.col..r.col + r.width {
                grid[row][col] = ch;
            }
        }
    }
    let mut s = String::new();
    for row in (0..p.rows).rev() {
        let _ = write!(s, "  |");
        for col in 0..p.cols {
            let _ = write!(s, "{}", grid[row][col]);
        }
        let _ = writeln!(s, "|");
    }
    s
}

/// Render the three placements with their Eq. 2 costs.
pub fn render() -> Result<String> {
    let (bnb, gr, ga) = generate()?;
    let p = problem();
    let mut s = String::new();
    let _ = writeln!(s, "FIG. 3 — placement on 38x8, start (0,0), lambda=1.0, mu=0.05");
    let _ = writeln!(
        s,
        "(a) branch-and-bound   J = {:.2}  ({} nodes, optimal={}, {:.1} ms)",
        bnb.cost, bnb.nodes_explored, bnb.optimal, bnb.elapsed_ms
    );
    let _ = write!(s, "{}", floorplan(&bnb, &p));
    let _ = writeln!(s, "(b) greedy-right       J = {:.2}", gr.cost);
    let _ = write!(s, "{}", floorplan(&gr, &p));
    let _ = writeln!(s, "(c) greedy-above       J = {:.2}", ga.cost);
    let _ = write!(s, "{}", floorplan(&ga, &p));
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bnb_wins_the_fig3_scenario() {
        let (bnb, gr, ga) = generate().unwrap();
        assert!(bnb.cost < gr.cost, "B&B {} vs greedy-right {}", bnb.cost, gr.cost);
        assert!(bnb.cost < ga.cost, "B&B {} vs greedy-above {}", bnb.cost, ga.cost);
    }

    #[test]
    fn bnb_runs_in_seconds() {
        // Paper: "typically requiring only a few seconds".
        let (bnb, _, _) = generate().unwrap();
        assert!(bnb.elapsed_ms < 10_000.0, "{} ms", bnb.elapsed_ms);
    }

    #[test]
    fn bnb_biases_to_lower_rows() {
        // Mean top-row of B&B should not exceed the greedy-above layout's.
        let (bnb, _, ga) = generate().unwrap();
        let mean_top = |r: &PlacementReport| {
            r.rects.iter().map(|x| x.top_row() as f64).sum::<f64>() / r.rects.len() as f64
        };
        assert!(mean_top(&bnb) <= mean_top(&ga) + 1e-9);
    }

    #[test]
    fn renders_all_three() {
        let s = render().unwrap();
        assert!(s.contains("(a) branch-and-bound"));
        assert!(s.contains("(b) greedy-right"));
        assert!(s.contains("(c) greedy-above"));
    }

    #[test]
    fn branching_bnb_beats_or_matches_greedy() {
        let (bnb, gr, ga) = generate_branching().unwrap();
        assert!(bnb.cost <= gr.cost + 1e-9, "B&B {} vs greedy-right {}", bnb.cost, gr.cost);
        assert!(bnb.cost <= ga.cost + 1e-9, "B&B {} vs greedy-above {}", bnb.cost, ga.cost);
        // The search cost stays visible (and bounded by the node budget).
        assert!(bnb.nodes_explored > 0);
        assert!(bnb.nodes_explored <= problem().max_nodes);
        let s = render_branching().unwrap();
        assert!(s.contains("edge-weighted"));
        assert!(s.contains("nodes"));
    }
}
