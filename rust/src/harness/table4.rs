//! Table IV — comparison with prior AIE-based frameworks.
//!
//! Baseline rows are published characteristics (`baselines::frameworks`);
//! the AIE4ML row is measured: a GEMM workload at full array utilization
//! through the compiler + engine (single linear layer, no bias/activation,
//! spanning 296 tiles — the paper's 160 TOPS / 82.2% configuration).

use crate::arch::Dtype;
use crate::baselines::frameworks::{aie4ml_row, prior_frameworks, FrameworkRow};
use crate::frontend::{CompileConfig, LayerConfig};
use crate::harness::models::{synth_model, LayerSpec};
use crate::passes::compile;
use crate::sim::engine::{analyze, EngineModel};
use anyhow::Result;
use std::fmt::Write as _;

/// Run the GEMM-at-full-array workload and return (TOPS, tiles used).
pub fn measure_gemm_full_array() -> Result<(f64, usize)> {
    // Full-width cascade: 37 columns x 8 rows = 296 tiles, int8,
    // 128-feature slices per tile (the Table II workload per tile),
    // no bias / no activation (pure GEMM).
    let spec = vec![LayerSpec {
        name: "gemm".into(),
        in_features: 37 * 128,
        out_features: 8 * 128,
        relu: false,
        dtype_act: Dtype::I8,
        dtype_wgt: Dtype::I8,
    }];
    let mut json = synth_model("gemm_full", &spec, 6);
    // Pure GEMM: drop the bias.
    json.layers[0].use_bias = false;
    json.layers[0].bias.clear();
    let mut cfg = CompileConfig::default();
    cfg.batch = 128;
    cfg.layers
        .insert("gemm".into(), LayerConfig { cascade: Some((37, 8)), ..Default::default() });
    let model = compile(&json, cfg)?;
    let fw = model.firmware.as_ref().unwrap();
    let report = analyze(fw, &EngineModel::default());
    Ok((report.throughput_tops, fw.tiles_used()))
}

/// All rows: AIE4ML (measured) first, then the literature baselines.
pub fn generate() -> Result<Vec<FrameworkRow>> {
    let (tops, tiles) = measure_gemm_full_array()?;
    let mut rows = vec![aie4ml_row(tops, tiles)];
    rows.extend(prior_frameworks());
    Ok(rows)
}

pub fn render() -> Result<String> {
    let rows = generate()?;
    let mut s = String::new();
    let _ = writeln!(s, "TABLE IV — comparison with prior AIE-based frameworks");
    let _ = writeln!(
        s,
        "{:<9} {:<10} {:>9} {:>8} {:>7} {:>7} {:>7} {:>7} {:>16}",
        "Framework", "AIE Gen", "Eff.(%)", "FusedBA", "WtsAIE", "ActAIE", "Multi", "Place", "Max AIEs"
    );
    for r in &rows {
        let (lo, hi) = r.efficiency_pct();
        let eff = if (lo - hi).abs() < 0.05 { format!("{lo:.1}") } else { format!("{lo:.0}-{hi:.0}") };
        let b = |v: bool| if v { "yes" } else { "no" };
        let multi = if r.multi_layer && r.multi_layer_via_pl {
            "via-PL"
        } else if r.multi_layer {
            "yes"
        } else {
            "no"
        };
        let _ = writeln!(
            s,
            "{:<9} {:<10} {:>9} {:>8} {:>7} {:>7} {:>7} {:>7} {:>10}/{} ({:.1}%)",
            r.name,
            format!("{}", r.generation),
            eff,
            b(r.fused_bias_act),
            b(r.weights_on_aie),
            b(r.activations_on_aie),
            multi,
            b(r.auto_placement),
            r.aies_used.0,
            r.aies_used.1,
            r.utilization_pct()
        );
    }
    let _ = writeln!(s, "paper AIE4ML row: 82.2% eff, 296/304 tiles (97.4%)");
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Device;

    #[test]
    fn gemm_uses_296_tiles() {
        let (_, tiles) = measure_gemm_full_array().unwrap();
        assert_eq!(tiles, 296);
        assert_eq!(Device::vek280().placeable_tiles(), 296);
    }

    #[test]
    fn gemm_efficiency_in_high_band() {
        // Paper: 160 TOPS = 82.2% of the 194.56 TOPS INT8 peak. Our cycle-
        // approximate model lands in the 80-100% band and the shape claim
        // (AIE4ML sustains a GAMA-class fraction of peak while doing
        // end-to-end data movement on-chip) holds. EXPERIMENTS.md discusses
        // the delta.
        let (tops, _) = measure_gemm_full_array().unwrap();
        let peak = Device::vek280().peak_int8_tops();
        let eff = tops / peak;
        assert!(eff > 0.75 && eff < 1.0, "GEMM eff {eff}");
    }

    #[test]
    fn aie4ml_is_the_only_fully_featured_row() {
        let rows = generate().unwrap();
        assert_eq!(rows[0].name, "AIE4ML");
        assert!(rows[0].fused_bias_act && rows[0].auto_placement);
        for r in &rows[1..] {
            assert!(!(r.weights_on_aie && r.activations_on_aie && r.fused_bias_act));
        }
    }
}
