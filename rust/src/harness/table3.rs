//! Table III — MLP-Mixer and standalone MLP blocks, fully on-chip:
//! MOPs, steady-state output interval per sample, sustained TOPS.

use crate::arch::Dtype;
use crate::frontend::CompileConfig;
use crate::harness::models::{mlp_spec, seven_layer_mlp, synth_model, table3_blocks};
use crate::passes::compile;
use crate::sim::engine::{analyze, EngineModel};
use anyhow::Result;
use std::fmt::Write as _;

/// One measured Table III row.
#[derive(Debug, Clone)]
pub struct Table3Row {
    pub operation: String,
    pub mops: f64,
    /// Steady-state interval between consecutive full inputs, µs. For the
    /// reshaped Mixer blocks one "sample" is the whole [rows, features]
    /// GEMM input (the paper's convention — MOPs/interval = TOPS).
    pub interval_us: f64,
    pub throughput_tops: f64,
    pub tiles: usize,
}

/// Paper-reported rows: (operation, MOPs, interval µs, TOPS).
pub fn paper() -> Vec<(&'static str, f64, f64, f64)> {
    vec![
        ("token_mlp_s16", 102.0, 1.2, 82.5),
        ("channel_mlp_s16", 822.0, 10.4, 77.3),
        ("token_mlp_l16", 411.0, 7.5, 55.0),
        ("mlp_2layer", 1074.0, 8.2, 129.7),
        ("mlp_7layer", 3.7, 0.03, 113.4),
    ]
}

/// Generate the measured table.
pub fn generate() -> Result<Vec<Table3Row>> {
    let mut rows = Vec::new();
    for block in table3_blocks() {
        let spec = mlp_spec(&block.dims, Dtype::I8);
        let json = synth_model(block.name, &spec, 6);
        let mut cfg = CompileConfig::default();
        cfg.batch = block.rows;
        let model = compile(&json, cfg)?;
        let fw = model.firmware.as_ref().unwrap();
        let report = analyze(fw, &EngineModel::default());
        let useful_ops = fw.ops_per_sample() as f64 * block.rows as f64;
        rows.push(Table3Row {
            operation: block.name.to_string(),
            mops: useful_ops / 1e6,
            interval_us: report.interval_us,
            throughput_tops: useful_ops / (report.interval_us * 1e-6) / 1e12,
            tiles: fw.tiles_used(),
        });
    }
    // 7-layer MLP: per-sample interval with a pipelined batch.
    let model = seven_layer_mlp(128)?;
    let fw = model.firmware.as_ref().unwrap();
    let report = analyze(fw, &EngineModel::default());
    rows.push(Table3Row {
        operation: "mlp_7layer".into(),
        mops: fw.ops_per_sample() as f64 / 1e6,
        interval_us: report.interval_per_sample_us,
        throughput_tops: report.throughput_tops,
        tiles: fw.tiles_used(),
    });
    Ok(rows)
}

pub fn render() -> Result<String> {
    let rows = generate()?;
    let paper = paper();
    let mut s = String::new();
    let _ = writeln!(s, "TABLE III — MLP-Mixer / MLP blocks, fully on-chip (measured | paper)");
    let _ = writeln!(
        s,
        "{:<18} {:>8} {:>22} {:>20} {:>6}",
        "Operation", "MOPs", "Interval/sample µs", "Throughput TOPS", "tiles"
    );
    for (r, p) in rows.iter().zip(&paper) {
        let _ = writeln!(
            s,
            "{:<18} {:>8.1} {:>12.2} | {:>5.2} {:>11.1} | {:>5.1} {:>6}",
            r.operation, r.mops, r.interval_us, p.2, r.throughput_tops, p.3, r.tiles
        );
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mops_match_paper() {
        let rows = generate().unwrap();
        for (r, p) in rows.iter().zip(paper()) {
            assert!(
                (r.mops - p.1).abs() / p.1 < 0.03,
                "{}: {} MOPs vs paper {}",
                r.operation,
                r.mops,
                p.1
            );
        }
    }

    #[test]
    fn throughputs_in_paper_band() {
        // Cycle-approximate: within 35% of each paper row, and the overall
        // ordering regime holds (tens-of-TOPS medium models, >90 TOPS MLPs).
        let rows = generate().unwrap();
        for (r, p) in rows.iter().zip(paper()) {
            let rel = (r.throughput_tops - p.3).abs() / p.3;
            assert!(
                rel < 0.35,
                "{}: {} TOPS vs paper {} (rel {:.2})",
                r.operation,
                r.throughput_tops,
                p.3,
                rel
            );
        }
    }

    #[test]
    fn everything_fits_on_chip() {
        for r in generate().unwrap() {
            assert!(r.tiles <= 296, "{}: {} tiles", r.operation, r.tiles);
        }
    }
}
