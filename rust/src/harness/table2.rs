//! Table II — single-kernel performance for different input precisions:
//! sustained GOPS + efficiency for the base and fused (+Bias+ReLU) kernels,
//! and micro-batch latency (B=8, 4×4 cascade).

use crate::arch::{default_tiling, tile_peak_gops, AieGeneration, Device, Dtype, PrecisionPair};
use crate::frontend::{CompileConfig, LayerConfig};
use crate::harness::models::{synth_model, LayerSpec};
use crate::ir::{DenseQuant, QuantSpec};
use crate::passes::{compile, resolve::batch_chunk};
use crate::sim::cycles::{batch_cycles, sustained_gops, CycleModel, KernelWorkload};
use crate::sim::engine::{analyze, EngineModel};
use anyhow::Result;
use std::fmt::Write as _;

/// One measured Table II row.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub datatype: String,
    pub workload: String,
    pub base_gops: f64,
    pub base_eff: f64,
    pub fused_gops: f64,
    pub fused_eff: f64,
    pub latency_us: f64,
}

/// Paper-reported values: (dtype, base GOPS, base eff, fused GOPS,
/// fused eff, latency µs).
pub fn paper() -> Vec<(&'static str, f64, f64, f64, f64, f64)> {
    vec![
        ("i8xi8", 613.0, 0.958, 520.0, 0.813, 0.5),
        ("i16xi8", 314.0, 0.981, 287.0, 0.897, 3.3),
        ("i16xi16", 138.0, 0.863, 114.0, 0.706, 2.5),
    ]
}

fn row_config() -> Vec<(PrecisionPair, usize)> {
    vec![
        (PrecisionPair::I8I8, 128),
        (PrecisionPair::I16I8, 128),
        (PrecisionPair::I16I16, 64),
    ]
}

fn single_tile_gops(pair: PrecisionPair, feat: usize, fused: bool, batch: usize) -> f64 {
    let device = Device::vek280();
    let tiling = default_tiling(pair).unwrap();
    let q = DenseQuant {
        input: QuantSpec::new(pair.act, 6),
        weight: QuantSpec::new(pair.wgt, 6),
        output: QuantSpec::new(pair.act, 6),
        bias_dtype: Dtype::I32,
        acc_dtype: pair.acc_dtype(),
        shift: 6,
    };
    let (chunk, _) = batch_chunk(&device, &tiling, &q, feat, feat, batch)
        .expect("single-kernel workload fits local memory");
    let w = KernelWorkload {
        batch: chunk,
        f_in_slice: feat,
        f_out_slice: feat,
        tiling,
        use_bias: fused,
        relu: fused,
        is_tail: true,
    };
    let cycles = batch_cycles(batch, chunk, &w, &CycleModel::default(), AieGeneration::AieMl, device.load_port_bytes);
    sustained_gops(batch * feat * feat, cycles, device.freq_ghz)
}

/// Micro-batch latency: base kernel, B=8, 4×4 cascade (paper setting).
fn micro_latency_us(pair: PrecisionPair, feat: usize) -> Result<f64> {
    let spec = vec![LayerSpec {
        name: "fc1".into(),
        in_features: feat,
        out_features: feat,
        relu: false,
        dtype_act: pair.act,
        dtype_wgt: pair.wgt,
    }];
    let json = synth_model(&format!("lat_{pair}"), &spec, 6);
    let mut cfg = CompileConfig::default();
    cfg.batch = 8;
    cfg.layers
        .insert("fc1".into(), LayerConfig { cascade: Some((4, 4)), ..Default::default() });
    let model = compile(&json, cfg)?;
    let report = analyze(model.firmware.as_ref().unwrap(), &EngineModel::default());
    Ok(report.latency_us)
}

/// Generate the measured Table II.
pub fn generate() -> Result<Vec<Table2Row>> {
    let mut rows = Vec::new();
    for (pair, feat) in row_config() {
        let peak = tile_peak_gops(AieGeneration::AieMl, pair, 1.25);
        let base = single_tile_gops(pair, feat, false, 128);
        let fused = single_tile_gops(pair, feat, true, 128);
        rows.push(Table2Row {
            datatype: pair.to_string(),
            workload: format!("{feat}x{feat}"),
            base_gops: base,
            base_eff: base / peak,
            fused_gops: fused,
            fused_eff: fused / peak,
            latency_us: micro_latency_us(pair, feat)?,
        });
    }
    Ok(rows)
}

/// Render measured-vs-paper.
pub fn render() -> Result<String> {
    let rows = generate()?;
    let paper = paper();
    let mut s = String::new();
    let _ = writeln!(s, "TABLE II — Single-kernel performance (measured | paper)");
    let _ = writeln!(
        s,
        "{:<9} {:<9} {:>20} {:>20} {:>16}",
        "Datatype", "Workload", "Base GOPS (eff)", "+Bias+ReLU (eff)", "Latency µs"
    );
    for (r, p) in rows.iter().zip(&paper) {
        let _ = writeln!(
            s,
            "{:<9} {:<9} {:>7.0} ({:>4.1}%)|{:>4.1}% {:>7.0} ({:>4.1}%)|{:>4.1}% {:>6.2}|{:>4.1}",
            r.datatype,
            r.workload,
            r.base_gops,
            100.0 * r.base_eff,
            100.0 * p.2,
            r.fused_gops,
            100.0 * r.fused_eff,
            100.0 * p.4,
            r.latency_us,
            p.5,
        );
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiencies_track_paper_within_tolerance() {
        let rows = generate().unwrap();
        let paper = paper();
        for (r, p) in rows.iter().zip(&paper) {
            assert!(
                (r.base_eff - p.2).abs() < 0.03,
                "{}: base eff {} vs paper {}",
                r.datatype,
                r.base_eff,
                p.2
            );
            assert!(
                (r.fused_eff - p.4).abs() < 0.05,
                "{}: fused eff {} vs paper {}",
                r.datatype,
                r.fused_eff,
                p.4
            );
        }
    }

    #[test]
    fn latencies_in_microsecond_regime() {
        for r in generate().unwrap() {
            assert!(r.latency_us > 0.05 && r.latency_us < 5.0, "{}: {} µs", r.datatype, r.latency_us);
        }
    }
}
