//! Figure 4 — scaling a single linear layer (bias+ReLU) from one tile to
//! the full array for each precision; input size grows proportionally with
//! the tile count, all data movement stays on-chip.

use crate::arch::{Device, PrecisionPair};
use crate::frontend::{CompileConfig, LayerConfig};
use crate::harness::models::{synth_model, LayerSpec};
use crate::passes::compile;
use crate::sim::engine::{analyze, EngineModel};
use anyhow::Result;
use std::fmt::Write as _;

/// One scaling point.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub tiles: usize,
    pub cas_len: usize,
    pub cas_num: usize,
    pub f_in: usize,
    pub f_out: usize,
    pub tops: f64,
    /// Throughput relative to `tiles × single-tile throughput`.
    pub scaling_eff: f64,
}

/// One precision's scaling series.
#[derive(Debug, Clone)]
pub struct ScaleSeries {
    pub datatype: String,
    pub points: Vec<ScalePoint>,
    /// Efficiency at the maximum-utilization point (the paper headline).
    pub peak_eff: f64,
}

/// Cascade sweep up to 296/304 tiles (37 placeable columns × 8 rows).
pub fn cascade_sweep() -> Vec<(usize, usize)> {
    vec![
        (1, 1),
        (2, 1),
        (2, 2),
        (4, 2),
        (4, 4),
        (8, 4),
        (8, 8),
        (16, 8),
        (24, 8),
        (32, 8),
        (37, 8),
    ]
}

/// Per-tile feature slice for each precision — the single-tile workloads of
/// Table II, so the 1-tile point *is* the Table II fused kernel.
fn slice_for(pair: PrecisionPair) -> usize {
    match pair {
        PrecisionPair::I16I16 => 64,
        _ => 128,
    }
}

fn point(pair: PrecisionPair, cas: (usize, usize), batch: usize) -> Result<ScalePoint> {
    let slice = slice_for(pair);
    let (f_in, f_out) = (cas.0 * slice, cas.1 * slice);
    let spec = vec![LayerSpec {
        name: "fc1".into(),
        in_features: f_in,
        out_features: f_out,
        relu: true,
        dtype_act: pair.act,
        dtype_wgt: pair.wgt,
    }];
    let json = synth_model(&format!("scale_{pair}_{}x{}", cas.0, cas.1), &spec, 6);
    let mut cfg = CompileConfig::default();
    cfg.batch = batch;
    cfg.layers
        .insert("fc1".into(), LayerConfig { cascade: Some(cas), ..Default::default() });
    let model = compile(&json, cfg)?;
    let fw = model.firmware.as_ref().unwrap();
    let report = analyze(fw, &EngineModel::default());
    Ok(ScalePoint {
        tiles: cas.0 * cas.1,
        cas_len: cas.0,
        cas_num: cas.1,
        f_in,
        f_out,
        tops: report.throughput_tops,
        scaling_eff: 0.0, // filled by the caller against the 1-tile point
    })
}

/// Generate one precision's series.
pub fn series(pair: PrecisionPair, batch: usize) -> Result<ScaleSeries> {
    let mut points: Vec<ScalePoint> = cascade_sweep()
        .into_iter()
        .map(|cas| point(pair, cas, batch))
        .collect::<Result<_>>()?;
    let single = points[0].tops;
    for p in &mut points {
        p.scaling_eff = p.tops / (single * p.tiles as f64);
    }
    let peak_eff = points.last().map(|p| p.scaling_eff).unwrap_or(0.0);
    Ok(ScaleSeries { datatype: pair.to_string(), points, peak_eff })
}

/// All three precisions (the paper's Fig. 4 panels).
pub fn generate(batch: usize) -> Result<Vec<ScaleSeries>> {
    [PrecisionPair::I8I8, PrecisionPair::I16I8, PrecisionPair::I16I16]
        .into_iter()
        .map(|p| series(p, batch))
        .collect()
}

/// Paper headline scaling efficiencies at max utilization.
pub fn paper_peak_eff() -> [(&'static str, f64); 3] {
    [("i8xi8", 0.973), ("i16xi8", 0.986), ("i16xi16", 0.971)]
}

pub fn render(batch: usize) -> Result<String> {
    let mut s = String::new();
    let _ = writeln!(s, "FIG. 4 — single-layer scaling across AIE tiles (batch {batch})");
    let max_tiles = Device::vek280().placeable_tiles();
    for series in generate(batch)? {
        let _ = writeln!(s, "[{}]", series.datatype);
        let _ = writeln!(
            s,
            "  {:>6} {:>9} {:>11} {:>9} {:>8}",
            "tiles", "cascade", "workload", "TOPS", "eff"
        );
        for p in &series.points {
            let _ = writeln!(
                s,
                "  {:>6} {:>9} {:>11} {:>9.2} {:>7.1}%{}",
                p.tiles,
                format!("{}x{}", p.cas_len, p.cas_num),
                format!("{}x{}", p.f_in, p.f_out),
                p.tops,
                100.0 * p.scaling_eff,
                if p.tiles == max_tiles { "  <- 296/304 tiles (97.4% util)" } else { "" }
            );
        }
    }
    let _ = writeln!(s, "paper peak scaling eff: 97.3% / 98.6% / 97.1%");
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn near_ideal_scaling_at_full_array() {
        // Paper: 97.3% / 98.6% / 97.1% at 296 tiles. Cycle-approximate
        // tolerance: within 3 points, and always < 100%.
        for (series, (name, paper)) in generate(128).unwrap().iter().zip(paper_peak_eff()) {
            assert_eq!(series.datatype, name);
            assert!(
                (series.peak_eff - paper).abs() < 0.03,
                "{name}: eff {} vs paper {paper}",
                series.peak_eff
            );
            assert!(series.peak_eff < 1.0);
        }
    }

    #[test]
    fn throughput_monotone_in_tiles() {
        for series in generate(128).unwrap() {
            for w in series.points.windows(2) {
                assert!(
                    w[1].tops > w[0].tops,
                    "{}: {} tiles {} TOPS !> {} tiles {} TOPS",
                    series.datatype,
                    w[1].tiles,
                    w[1].tops,
                    w[0].tiles,
                    w[0].tops
                );
            }
        }
    }

    #[test]
    fn max_point_uses_296_tiles() {
        let sweep = cascade_sweep();
        let (l, n) = *sweep.last().unwrap();
        assert_eq!(l * n, 296);
        assert_eq!(Device::vek280().placeable_tiles(), 296);
    }
}
