//! Deterministic in-repo model zoo: the artifact set the bit-exactness gate
//! runs against, generated on demand so a fresh checkout needs no Python,
//! no network and no PJRT toolchain.
//!
//! Mirrors `python/compile/exporter.py::MODEL_ZOO` in names, topology and
//! batch (the hermetic `mlp7` is width-reduced to keep `cargo test` fast;
//! `make artifacts` regenerates the paper-scale set plus HLO artifacts).
//! The `residual_mlp` DAG entry is mirrored by the Python exporter (which
//! emits per-layer `inputs` wiring); `wide_mlp_2x` is Rust-only — it only
//! exists to exercise the multi-array partitioner, so Python-written
//! manifests may omit it (tests that need it look it up leniently).
//! Weights come from the seeded PCG stream (`harness::models::synth_model`,
//! seeded by the FNV-1a name hash) — payload agreement between the firmware
//! and any oracle goes through the written JSON, never through parallel
//! generation, so the two zoos need not produce identical weights.
//!
//! `ensure_zoo` writes `models/<name>.json` plus a `manifest.json` whose
//! entries (`name`, `batch`, `model`, `hlo`) match what the Python exporter
//! and `aot.py` write, and is a no-op when a usable manifest already exists
//! (so Python-built artifact sets are never clobbered).

use crate::arch::Dtype;
use crate::frontend::JsonModel;
use crate::harness::models::{
    cnn_classifier_model, concat_mlp_model, residual_mlp_model, synth_model, wide_mlp_2x_model,
    LayerSpec,
};
use crate::util::json::{obj, Value};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// One zoo entry, paths resolved to the artifacts directory.
#[derive(Debug, Clone)]
pub struct ZooEntry {
    pub name: String,
    /// Batch size the model (and any AOT artifact) is specialized to.
    pub batch: usize,
    /// Exporter-format model JSON (always present after `ensure_zoo`).
    pub model: PathBuf,
    /// HLO-text artifact for the PJRT oracle (present only after
    /// `make artifacts`; the hermetic reference oracle never needs it).
    pub hlo: PathBuf,
    /// Whether the manifest declared the `hlo` path explicitly (true for
    /// Rust- and AOT-written manifests; false for the plain Python
    /// exporter, which omits the field).
    pub hlo_declared: bool,
}

fn layer_specs(dims: &[usize], act: Dtype, wgt: Dtype) -> Vec<LayerSpec> {
    dims.windows(2)
        .enumerate()
        .map(|(i, w)| LayerSpec {
            name: format!("fc{}", i + 1),
            in_features: w[0],
            out_features: w[1],
            relu: i + 2 < dims.len(),
            dtype_act: act,
            dtype_wgt: wgt,
        })
        .collect()
}

/// The hermetic zoo: (model, batch). Deterministic across runs and machines.
pub fn zoo_models() -> Vec<(JsonModel, usize)> {
    vec![
        // Quickstart demo: small MLP, fast everywhere.
        (synth_model("quickstart", &layer_specs(&[64, 32, 10], Dtype::I8, Dtype::I8), 6), 8),
        // 7-layer MLP (hermetic width; paper scale comes from `make artifacts`).
        (synth_model("mlp7", &layer_specs(&[256; 8], Dtype::I8, Dtype::I8), 6), 32),
        // Mixer-style token-mixing block (Table III row 1 geometry).
        (synth_model("token_mixer", &layer_specs(&[196, 256, 196], Dtype::I8, Dtype::I8), 6), 64),
        // Mixed precision: int16 activations x int8 weights.
        (synth_model("mlp_i16i8", &layer_specs(&[128, 128, 64], Dtype::I16, Dtype::I8), 6), 16),
        // Skip-connection MLP: fan-out + residual Add fan-in (DAG gate).
        (residual_mlp_model("residual_mlp", 128, 256, 32, 6), 16),
        // Concat-head MLP: uneven-width branches spliced by a Concat whose
        // producers land at feature offsets of the head's read-tile buffer
        // (the offset-tiler gate). Rust-only, like wide_mlp_2x.
        (concat_mlp_model("concat_mlp", 96, 64, 32, 16, 6), 16),
        // Over-capacity model: at its throughput config (128 tiles/layer,
        // `models::wide_mlp_2x_config`) it cannot place on one VEK280 and
        // must compile through the multi-array partitioner (K >= 2).
        (wide_mlp_2x_model("wide_mlp_2x"), 16),
        // Funnel chain: two wide 512x512 layers draining through a 512->32
        // bottleneck into a narrow tail. MAC balancing cuts after fc1 (the
        // only split that evens the MAC load) and pays a 512-wide link;
        // interval balancing finds the 32-wide crossing after fc3 instead —
        // the zoo's witness that compile-in-the-loop cut choice strictly
        // beats the MAC proxy. Rust-only, like wide_mlp_2x.
        (synth_model("funnel_mlp", &layer_specs(&[512, 512, 512, 32, 32], Dtype::I8, Dtype::I8), 6), 16),
        // CNN classifier: conv -> maxpool -> conv -> dense head, lowered
        // through implicit GEMM (the conv bit-exactness gate). Mirrored by
        // the Python exporter's CNN_ZOO entry.
        (cnn_classifier_model("cnn_classifier", 6), 4),
    ]
}

/// The artifacts directory used by tests, examples and the CLI:
/// `rust/artifacts` (next to this crate's manifest).
pub fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn resolve(dir: &Path, raw: &str) -> PathBuf {
    let p = PathBuf::from(raw);
    if p.is_absolute() {
        return p;
    }
    // Relative paths are anchored at the artifacts dir; the CWD-relative
    // form is accepted only when such a file actually exists (legacy
    // Python-written manifests), so diagnostics and existence checks never
    // depend on the process working directory otherwise.
    let joined = dir.join(&p);
    if !joined.exists() && p.exists() {
        return p;
    }
    joined
}

/// Parse `dir/manifest.json` if present. Tolerates manifests written by the
/// Python exporter (no `hlo` field) by defaulting to `dir/<name>.hlo.txt`.
/// Returns `None` when the manifest is absent or unreadable.
pub fn read_manifest(dir: &Path) -> Option<Vec<ZooEntry>> {
    let text = std::fs::read_to_string(dir.join("manifest.json")).ok()?;
    let v = Value::parse(&text).ok()?;
    let mut out = Vec::new();
    for e in v.as_array().ok()? {
        let name = e.field("name").ok()?.as_str().ok()?.to_string();
        let declared = e.get("hlo").and_then(|h| h.as_str().ok());
        let hlo_declared = declared.is_some();
        let hlo = match declared {
            Some(h) => resolve(dir, h),
            None => dir.join(format!("{name}.hlo.txt")),
        };
        out.push(ZooEntry {
            batch: e.field("batch").ok()?.as_usize().ok()?,
            model: resolve(dir, e.field("model").ok()?.as_str().ok()?),
            hlo,
            hlo_declared,
            name,
        });
    }
    Some(out)
}

/// Write the hermetic zoo (model JSONs + manifest) into `dir`.
pub fn write_zoo(dir: &Path) -> Result<Vec<ZooEntry>> {
    let models_dir = dir.join("models");
    std::fs::create_dir_all(&models_dir)
        .with_context(|| format!("creating {}", models_dir.display()))?;
    let mut entries = Vec::new();
    let mut manifest = Vec::new();
    for (model, batch) in zoo_models() {
        let path = models_dir.join(format!("{}.json", model.name));
        // Write-then-rename so a concurrent reader never sees a torn model.
        let tmp = models_dir.join(format!("{}.json.tmp.{}", model.name, std::process::id()));
        std::fs::write(&tmp, model.to_json_string())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing {}", path.display()))?;
        // A regenerated model invalidates any HLO artifact lowered from a
        // previous (possibly paper-scale) model of the same name.
        let _ = std::fs::remove_file(dir.join(format!("{}.hlo.txt", model.name)));
        manifest.push(obj([
            ("name", Value::from(model.name.as_str())),
            ("batch", Value::from(batch)),
            ("model", Value::from(format!("models/{}.json", model.name))),
            ("hlo", Value::from(format!("{}.hlo.txt", model.name))),
        ]));
        entries.push(ZooEntry {
            name: model.name.clone(),
            batch,
            model: path,
            hlo: dir.join(format!("{}.hlo.txt", model.name)),
            hlo_declared: true,
        });
    }
    // Write-then-rename so a concurrent reader never sees a torn manifest.
    let tmp = dir.join(format!("manifest.json.tmp.{}", std::process::id()));
    std::fs::write(&tmp, Value::Array(manifest).to_string_pretty())
        .with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, dir.join("manifest.json")).context("publishing manifest.json")?;
    Ok(entries)
}

/// Idempotent entry point: reuse an existing usable manifest (Rust- or
/// Python-written), else (re)generate the hermetic zoo.
///
/// A *stale* Rust-written hermetic manifest — explicit `hlo` paths, none
/// of them built, missing models the current zoo defines — is rebuilt so
/// newly added gates (e.g. `residual_mlp`) actually run. Python-exporter
/// manifests (no `hlo` fields) and AOT artifact sets (HLO files present)
/// are never clobbered.
pub fn ensure_zoo(dir: &Path) -> Result<Vec<ZooEntry>> {
    if let Some(entries) = read_manifest(dir) {
        let usable = !entries.is_empty() && entries.iter().all(|e| e.model.exists());
        if usable {
            let names: std::collections::HashSet<&str> =
                entries.iter().map(|e| e.name.as_str()).collect();
            let covers_zoo =
                zoo_models().iter().all(|(m, _)| names.contains(m.name.as_str()));
            let stale_hermetic = entries.iter().any(|e| e.hlo_declared)
                && !entries.iter().any(|e| e.hlo.exists());
            if covers_zoo || !stale_hermetic {
                return Ok(entries);
            }
        }
    }
    write_zoo(dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ScratchDir;

    #[test]
    fn zoo_is_deterministic() {
        let a = zoo_models();
        let b = zoo_models();
        assert_eq!(a.len(), 9);
        for ((ma, _), (mb, _)) in a.iter().zip(&b) {
            assert_eq!(ma.name, mb.name);
            assert_eq!(ma.layers[0].weights, mb.layers[0].weights);
        }
        // Mirrors the Python MODEL_ZOO names, plus the Rust-only DAG entries.
        let names: Vec<&str> = a.iter().map(|(m, _)| m.name.as_str()).collect();
        assert_eq!(
            names,
            [
                "quickstart",
                "mlp7",
                "token_mixer",
                "mlp_i16i8",
                "residual_mlp",
                "concat_mlp",
                "wide_mlp_2x",
                "funnel_mlp",
                "cnn_classifier"
            ]
        );
    }

    #[test]
    fn ensure_zoo_writes_and_reuses() {
        let dir = ScratchDir::new("zoo").unwrap();
        let first = ensure_zoo(dir.path()).unwrap();
        assert_eq!(first.len(), 9);
        for e in &first {
            assert!(e.model.exists(), "{} missing", e.model.display());
            // Written models parse back into valid exporter JSON.
            let m = JsonModel::from_file(&e.model).unwrap();
            m.validate().unwrap();
            assert_eq!(m.name, e.name);
        }
        // Second call reuses the manifest (same paths, no rewrite needed).
        let second = ensure_zoo(dir.path()).unwrap();
        assert_eq!(second.len(), 9);
        assert_eq!(second[0].model, first[0].model);
    }

    #[test]
    fn stale_rust_manifest_regenerated() {
        // A Rust-written hermetic manifest from before the DAG entry
        // (explicit hlo path, file not built, residual_mlp missing) must be
        // rebuilt — otherwise the residual bit-exactness gate silently skips.
        let dir = ScratchDir::new("zoo_stale").unwrap();
        ensure_zoo(dir.path()).unwrap(); // materializes models/
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"[{"name": "quickstart", "batch": 8,
                 "model": "models/quickstart.json", "hlo": "quickstart.hlo.txt"}]"#,
        )
        .unwrap();
        let entries = ensure_zoo(dir.path()).unwrap();
        assert_eq!(entries.len(), 9);
        assert!(entries.iter().any(|e| e.name == "residual_mlp"));
        assert!(entries.iter().any(|e| e.name == "concat_mlp"));
        assert!(entries.iter().any(|e| e.name == "wide_mlp_2x"));
        assert!(entries.iter().any(|e| e.name == "funnel_mlp"));
        assert!(entries.iter().any(|e| e.name == "cnn_classifier"));
        // With the HLO artifact actually present, the same truncated
        // manifest is an AOT set and must be preserved verbatim.
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"[{"name": "quickstart", "batch": 8,
                 "model": "models/quickstart.json", "hlo": "quickstart.hlo.txt"}]"#,
        )
        .unwrap();
        std::fs::write(dir.path().join("quickstart.hlo.txt"), "HloModule m").unwrap();
        let entries = ensure_zoo(dir.path()).unwrap();
        assert_eq!(entries.len(), 1);
    }

    #[test]
    fn residual_zoo_entry_is_a_dag() {
        let zoo = zoo_models();
        let (m, batch) = &zoo[4];
        assert_eq!(m.name, "residual_mlp");
        assert_eq!(*batch, 16);
        assert_eq!(m.layers[2].ty, "add");
        assert_eq!(m.layers[2].inputs, vec!["input", "fc2"]);
        // The DAG round-trips through the written JSON.
        let text = m.to_json_string();
        let back = JsonModel::from_str(&text).unwrap();
        back.to_graph().unwrap();
        assert_eq!(back.layers[2].inputs, vec!["input", "fc2"]);
    }

    #[test]
    fn concat_zoo_entry_merges_uneven_branches() {
        let zoo = zoo_models();
        let (m, batch) = &zoo[5];
        assert_eq!(m.name, "concat_mlp");
        assert_eq!(*batch, 16);
        assert_eq!(m.layers[2].ty, "concat");
        assert_eq!(m.layers[2].inputs, vec!["fc_a", "fc_b"]);
        // Uneven branches: the merged width is their sum.
        assert_ne!(m.layers[0].out_features, m.layers[1].out_features);
        assert_eq!(
            m.layers[0].out_features + m.layers[1].out_features,
            m.layers[2].out_features
        );
        // Round-trips through the written JSON as a DAG.
        let back = JsonModel::from_str(&m.to_json_string()).unwrap();
        back.to_graph().unwrap();
    }

    #[test]
    fn cnn_zoo_entry_round_trips_conv_blocks() {
        let zoo = zoo_models();
        let (m, batch) = &zoo[8];
        assert_eq!(m.name, "cnn_classifier");
        assert_eq!(*batch, 4);
        assert_eq!(m.layers[0].ty, "conv2d");
        assert_eq!(m.layers[1].ty, "maxpool2d");
        assert_eq!(m.layers[2].ty, "conv2d");
        assert_eq!(m.layers[3].ty, "dense");
        // Conv geometry survives the written JSON round trip.
        let back = JsonModel::from_str(&m.to_json_string()).unwrap();
        back.validate().unwrap();
        let c1 = back.layers[0].conv.as_ref().unwrap();
        assert_eq!((c1.in_h, c1.in_w, c1.in_c, c1.out_c), (12, 12, 3, 8));
        assert_eq!(c1.padding, "same");
        back.to_graph().unwrap();
    }

    #[test]
    fn python_style_manifest_accepted() {
        // The Python exporter writes entries without an `hlo` field.
        let dir = ScratchDir::new("zoo_py").unwrap();
        std::fs::create_dir_all(dir.path().join("models")).unwrap();
        let (model, _) = zoo_models().remove(0);
        std::fs::write(dir.path().join("models/quickstart.json"), model.to_json_string())
            .unwrap();
        std::fs::write(
            dir.path().join("manifest.json"),
            r#"[{"name": "quickstart", "batch": 8, "model": "models/quickstart.json"}]"#,
        )
        .unwrap();
        let entries = ensure_zoo(dir.path()).unwrap();
        assert_eq!(entries.len(), 1);
        assert!(entries[0].model.exists());
        assert_eq!(entries[0].hlo, dir.path().join("quickstart.hlo.txt"));
    }

    #[test]
    fn mixed_precision_entry_uses_i16_activations() {
        let zoo = zoo_models();
        let (m, batch) = &zoo[3];
        assert_eq!(m.name, "mlp_i16i8");
        assert_eq!(*batch, 16);
        assert_eq!(m.layers[0].quant.input.dtype, "i16");
        assert_eq!(m.layers[0].quant.weight.dtype, "i8");
    }
}
