//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation section. Each submodule exposes `generate()` (data),
//! `paper()` (the published values) and `render()` (formatted
//! measured-vs-paper output). Criterion benches and the `aie4ml bench`
//! CLI subcommand call into these.

pub mod fig3;
pub mod fig4;
pub mod models;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod traffic;
pub mod zoo;

use anyhow::Result;

/// Render every table/figure, in paper order.
pub fn render_all() -> Result<String> {
    let mut out = String::new();
    out.push_str(&table1::render());
    out.push('\n');
    out.push_str(&table2::render()?);
    out.push('\n');
    out.push_str(&fig3::render()?);
    out.push('\n');
    out.push_str(&fig4::render(128)?);
    out.push('\n');
    out.push_str(&table3::render()?);
    out.push('\n');
    out.push_str(&table4::render()?);
    out.push('\n');
    out.push_str(&table5::render()?);
    Ok(out)
}
