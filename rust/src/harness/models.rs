//! Synthetic model builders shared by benches, examples and tests.
//!
//! Weights are generated from a deterministic PCG stream seeded by the
//! FNV-1a hash of the model name, so the Rust-side builders and the Python
//! exporter (`python/compile/exporter.py`) can agree on seeds; bit-identical
//! payload sharing goes through the model JSON file.

use crate::arch::Dtype;
use crate::frontend::{CompileConfig, JsonLayer, JsonModel, LayerConfig};
use crate::passes::{compile, Model};
use crate::util::rng::{fnv1a, Pcg32};
use anyhow::Result;

/// Seed derived from a model name (stable across runs and languages).
pub fn name_seed(name: &str) -> u64 {
    fnv1a(name)
}

/// Specification of one synthetic dense layer.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub name: String,
    pub in_features: usize,
    pub out_features: usize,
    pub relu: bool,
    pub dtype_act: Dtype,
    pub dtype_wgt: Dtype,
}

/// Build a JsonModel with deterministic random weights.
pub fn synth_model(name: &str, layers: &[LayerSpec], frac_bits: i32) -> JsonModel {
    let mut rng = Pcg32::seed_from_u64(name_seed(name));
    let jlayers: Vec<JsonLayer> = layers
        .iter()
        .map(|l| {
            let (wlo, whi) = l.dtype_wgt.range();
            let weights: Vec<i32> = (0..l.in_features * l.out_features)
                .map(|_| rng.gen_i32_in(wlo, whi))
                .collect();
            let bias: Vec<i64> =
                (0..l.out_features).map(|_| rng.gen_range_i64(-512, 512)).collect();
            let mut layer = JsonLayer::dense(
                &l.name,
                l.in_features,
                l.out_features,
                true,
                l.relu,
                &l.dtype_act.to_string(),
                &l.dtype_wgt.to_string(),
                frac_bits,
                weights,
                bias,
            );
            layer.quant.weight.dtype = l.dtype_wgt.to_string();
            layer
        })
        .collect();
    let mut m = JsonModel::new(name, jlayers);
    m.device = Some("vek280".to_string());
    m
}

/// A uniform MLP: `dims[0] -> dims[1] -> ...`, ReLU on every layer
/// (paper §V-B: "every linear layer is immediately followed by a fused
/// ReLU activation, both within Mixer MLPs and standalone MLP layers").
pub fn mlp_spec(dims: &[usize], dtype: Dtype) -> Vec<LayerSpec> {
    dims.windows(2)
        .enumerate()
        .map(|(i, w)| LayerSpec {
            name: format!("fc{}", i + 1),
            in_features: w[0],
            out_features: w[1],
            relu: true,
            dtype_act: dtype,
            dtype_wgt: dtype,
        })
        .collect()
}

/// Compile a synthetic MLP with an explicit per-layer cascade geometry.
pub fn compile_mlp(
    name: &str,
    dims: &[usize],
    dtype: Dtype,
    batch: usize,
    cascade: Option<(usize, usize)>,
) -> Result<Model> {
    let spec = mlp_spec(dims, dtype);
    let json = synth_model(name, &spec, 6);
    let mut cfg = CompileConfig::default();
    cfg.batch = batch;
    if let Some(c) = cascade {
        for l in &spec {
            cfg.layers
                .insert(l.name.clone(), LayerConfig { cascade: Some(c), ..Default::default() });
        }
    }
    compile(&json, cfg)
}

/// A skip-connection MLP (the DAG analog of [`mlp_spec`]):
/// `input -> fc1(ReLU) -> fc2`, residual `add(input, fc2)`, then a dense
/// head reading the merged activation. Deterministic weights from the
/// name-seeded PCG stream, like [`synth_model`].
pub fn residual_mlp_model(
    name: &str,
    features: usize,
    hidden: usize,
    classes: usize,
    frac_bits: i32,
) -> JsonModel {
    let mut rng = Pcg32::seed_from_u64(name_seed(name));
    let mut dense = |lname: &str, fin: usize, fout: usize, relu: bool| -> JsonLayer {
        let weights: Vec<i32> = (0..fin * fout).map(|_| rng.gen_i32_in(-128, 127)).collect();
        let bias: Vec<i64> = (0..fout).map(|_| rng.gen_range_i64(-512, 512)).collect();
        JsonLayer::dense(lname, fin, fout, true, relu, "int8", "int8", frac_bits, weights, bias)
    };
    let layers = vec![
        dense("fc1", features, hidden, true),
        dense("fc2", hidden, features, false),
        JsonLayer::residual_add("res", features, "int8", frac_bits, &["input", "fc2"]),
        dense("head", features, classes, false).with_inputs(&["res"]),
    ];
    let mut m = JsonModel::new(name, layers);
    m.device = Some("vek280".to_string());
    m
}

/// A concat-merge MLP (`residual_mlp`'s `Concat` sibling): two parallel
/// branches of *different* widths read the input and are spliced by a
/// `Concat`, then a dense head consumes the merged activation — exactly
/// the topology whose merge the offset tilers compile without a staging
/// copy (each branch lands at a feature offset of the head's read-tile
/// buffer). Deterministic weights from the name-seeded PCG stream.
pub fn concat_mlp_model(
    name: &str,
    features: usize,
    branch_a: usize,
    branch_b: usize,
    classes: usize,
    frac_bits: i32,
) -> JsonModel {
    let mut rng = Pcg32::seed_from_u64(name_seed(name));
    let mut dense = |lname: &str, fin: usize, fout: usize, relu: bool| -> JsonLayer {
        let weights: Vec<i32> = (0..fin * fout).map(|_| rng.gen_i32_in(-128, 127)).collect();
        let bias: Vec<i64> = (0..fout).map(|_| rng.gen_range_i64(-512, 512)).collect();
        JsonLayer::dense(lname, fin, fout, true, relu, "int8", "int8", frac_bits, weights, bias)
    };
    let merged = branch_a + branch_b;
    let layers = vec![
        dense("fc_a", features, branch_a, true),
        dense("fc_b", features, branch_b, false).with_inputs(&["input"]),
        JsonLayer::concat("cat", merged, "int8", frac_bits, &["fc_a", "fc_b"]),
        dense("head", merged, classes, false).with_inputs(&["cat"]),
    ];
    let mut m = JsonModel::new(name, layers);
    m.device = Some("vek280".to_string());
    m
}

/// A diamond: `input -> stem`, which fans out into two parallel branches
/// `a` and `b` that re-merge through a residual add, then a dense head —
/// the smallest topology exercising fan-out *and* fan-in.
pub fn diamond_mlp_model(
    name: &str,
    features: usize,
    branch: usize,
    classes: usize,
    frac_bits: i32,
) -> JsonModel {
    let mut rng = Pcg32::seed_from_u64(name_seed(name));
    let mut dense = |lname: &str, fin: usize, fout: usize, relu: bool| -> JsonLayer {
        let weights: Vec<i32> = (0..fin * fout).map(|_| rng.gen_i32_in(-128, 127)).collect();
        let bias: Vec<i64> = (0..fout).map(|_| rng.gen_range_i64(-512, 512)).collect();
        JsonLayer::dense(lname, fin, fout, true, relu, "int8", "int8", frac_bits, weights, bias)
    };
    let layers = vec![
        dense("stem", features, branch, true),
        dense("a", branch, branch, true).with_inputs(&["stem"]),
        dense("b", branch, branch, false).with_inputs(&["stem"]),
        JsonLayer::residual_add("res", branch, "int8", frac_bits, &["a", "b"]),
        dense("head", branch, classes, false).with_inputs(&["res"]),
    ];
    let mut m = JsonModel::new(name, layers);
    m.device = Some("vek280".to_string());
    m
}

/// A small CNN classifier exercising the implicit-GEMM conv lowering
/// end-to-end: `12×12×3 image -> conv3×3→8 (same, ReLU) -> maxpool 2×2/2
/// -> conv3×3→16 (valid, ReLU) -> dense head -> 10 classes`. Both convs
/// ride the dense pipeline as GEMMs with patch-walk read plans; the pool
/// is a memory-tile stage. Deterministic weights from the name-seeded PCG
/// stream, like [`synth_model`].
pub fn cnn_classifier_model(name: &str, frac_bits: i32) -> JsonModel {
    use crate::frontend::JsonConv;
    fn conv_layer(
        rng: &mut Pcg32,
        lname: &str,
        c: JsonConv,
        relu: bool,
        frac_bits: i32,
    ) -> JsonLayer {
        let weights: Vec<i32> =
            (0..c.out_c * c.kh * c.kw * c.in_c).map(|_| rng.gen_i32_in(-128, 127)).collect();
        let bias: Vec<i64> = (0..c.out_c).map(|_| rng.gen_range_i64(-512, 512)).collect();
        JsonLayer::conv2d(lname, c, true, relu, "int8", "int8", frac_bits, weights, bias)
    }
    let mut rng = Pcg32::seed_from_u64(name_seed(name));
    let c1 = JsonConv {
        in_h: 12,
        in_w: 12,
        in_c: 3,
        out_c: 8,
        kh: 3,
        kw: 3,
        stride_h: 1,
        stride_w: 1,
        padding: "same".into(),
    };
    let pool = JsonConv {
        in_h: 12,
        in_w: 12,
        in_c: 8,
        out_c: 0,
        kh: 2,
        kw: 2,
        stride_h: 2,
        stride_w: 2,
        padding: "valid".into(),
    };
    let c2 = JsonConv {
        in_h: 6,
        in_w: 6,
        in_c: 8,
        out_c: 16,
        kh: 3,
        kw: 3,
        stride_h: 1,
        stride_w: 1,
        padding: "valid".into(),
    };
    let head_in = 4 * 4 * 16; // conv2's flattened 4×4×16 output
    let layers = vec![
        conv_layer(&mut rng, "c1", c1, true, frac_bits),
        JsonLayer::pool2d("pool1", "maxpool2d", pool, "int8", frac_bits),
        conv_layer(&mut rng, "c2", c2, true, frac_bits),
        JsonLayer::dense(
            "head",
            head_in,
            10,
            true,
            false,
            "int8",
            "int8",
            frac_bits,
            (0..head_in * 10).map(|_| rng.gen_i32_in(-128, 127)).collect(),
            (0..10).map(|_| rng.gen_range_i64(-512, 512)).collect(),
        ),
    ];
    let mut m = JsonModel::new(name, layers);
    m.device = Some("vek280".to_string());
    m
}

/// A complete MLP-Mixer block as a real IR DAG (paper §V-B, shrunk to
/// example scale): a patch-embedding conv turns an `8×8×1` image into
/// `T=16` tokens of `C=8` channels, then
///
/// * **token mixing** — `Transpose [T,C]→[C,T]`, a per-channel MLP over
///   tokens as two 1×1 convs (`in_h=C, in_c=T`), `Transpose` back,
///   residual `Add` with the embedding;
/// * **channel mixing** — a per-token MLP over channels as two 1×1 convs
///   (`in_h=T, in_c=C`), residual `Add`;
///
/// and a dense classifier head. Every op is a first-class IR node: the
/// convs lower through implicit GEMM, the transposes are memory-tile
/// stages, the adds are merges. Deterministic weights from the
/// name-seeded PCG stream, like [`synth_model`].
pub fn mlp_mixer_block_model(name: &str, frac_bits: i32) -> JsonModel {
    use crate::frontend::JsonConv;
    const T: usize = 16; // tokens (4×4 patches of the 8×8 image)
    const C: usize = 8; // embedding channels
    fn conv_layer(
        rng: &mut Pcg32,
        lname: &str,
        c: JsonConv,
        relu: bool,
        frac_bits: i32,
    ) -> JsonLayer {
        let weights: Vec<i32> =
            (0..c.out_c * c.kh * c.kw * c.in_c).map(|_| rng.gen_i32_in(-128, 127)).collect();
        let bias: Vec<i64> = (0..c.out_c).map(|_| rng.gen_range_i64(-512, 512)).collect();
        JsonLayer::conv2d(lname, c, true, relu, "int8", "int8", frac_bits, weights, bias)
    }
    // A 1×1 conv over an `[rows, 1, in_c]` image: the same dense layer
    // applied to every row — exactly a mixer MLP layer over the last axis.
    let mix = |rows: usize, in_c: usize, out_c: usize| JsonConv {
        in_h: rows,
        in_w: 1,
        in_c,
        out_c,
        kh: 1,
        kw: 1,
        stride_h: 1,
        stride_w: 1,
        padding: "valid".into(),
    };
    let mut rng = Pcg32::seed_from_u64(name_seed(name));
    let stem = JsonConv {
        in_h: 8,
        in_w: 8,
        in_c: 1,
        out_c: C,
        kh: 2,
        kw: 2,
        stride_h: 2,
        stride_w: 2,
        padding: "valid".into(),
    };
    let head_w: Vec<i32> = (0..T * C * 10).map(|_| rng.gen_i32_in(-128, 127)).collect();
    let head_b: Vec<i64> = (0..10).map(|_| rng.gen_range_i64(-512, 512)).collect();
    let layers = vec![
        // Patch embedding: 2×2/2 conv -> [4,4,C] = row-major [T, C].
        conv_layer(&mut rng, "embed", stem, false, frac_bits),
        // Token mixing on [C, T] rows.
        JsonLayer::transpose("tok_t", T, C, "int8", frac_bits).with_inputs(&["embed"]),
        conv_layer(&mut rng, "tok_fc1", mix(C, T, 2 * T), true, frac_bits),
        conv_layer(&mut rng, "tok_fc2", mix(C, 2 * T, T), false, frac_bits),
        JsonLayer::transpose("tok_back", C, T, "int8", frac_bits).with_inputs(&["tok_fc2"]),
        JsonLayer::residual_add("tok_res", T * C, "int8", frac_bits, &["embed", "tok_back"]),
        // Channel mixing on [T, C] rows.
        conv_layer(&mut rng, "ch_fc1", mix(T, C, 2 * C), true, frac_bits),
        conv_layer(&mut rng, "ch_fc2", mix(T, 2 * C, C), false, frac_bits),
        JsonLayer::residual_add("ch_res", T * C, "int8", frac_bits, &["tok_res", "ch_fc2"]),
        JsonLayer::dense(
            "head",
            T * C,
            10,
            true,
            false,
            "int8",
            "int8",
            frac_bits,
            head_w,
            head_b,
        ),
    ];
    let mut m = JsonModel::new(name, layers);
    m.device = Some("vek280".to_string());
    m
}

/// The over-capacity zoo model: a 4-layer 512-wide MLP (2× the hermetic
/// `mlp7` width) deployed at the throughput configuration
/// [`wide_mlp_2x_config`] — 128 tiles per layer, 512 compute tiles total,
/// far beyond one VEK280's 296 placeable tiles. A single-array compile
/// provably fails at placement, so the model must ship through the
/// multi-array partitioner (K ≥ 2 pipeline partitions).
pub fn wide_mlp_2x_model(name: &str) -> JsonModel {
    let dims = [512usize; 5];
    let specs: Vec<LayerSpec> = dims
        .windows(2)
        .enumerate()
        .map(|(i, w)| LayerSpec {
            name: format!("fc{}", i + 1),
            in_features: w[0],
            out_features: w[1],
            relu: i + 2 < dims.len(),
            dtype_act: Dtype::I8,
            dtype_wgt: Dtype::I8,
        })
        .collect();
    synth_model(name, &specs, 6)
}

/// The deployment configuration `wide_mlp_2x` ships with: every layer on a
/// 128-tile cascade for throughput. 4 layers × 128 = 512 tiles on a
/// 296-tile array — infeasible on one VEK280 by construction, which is
/// exactly what [`crate::partition::compile_partitioned`] exists for.
pub fn wide_mlp_2x_config() -> CompileConfig {
    let mut cfg = CompileConfig::default();
    cfg.batch = 16;
    cfg.tiles_per_layer = Some(128);
    cfg
}

/// The paper's cross-device workload: 7-layer 512×512 MLP, int8
/// (Table III row 5 / Table V).
pub fn seven_layer_mlp(batch: usize) -> Result<Model> {
    // 7 dense layers of hidden size 512; (4,8) cascades divide 512 exactly
    // (f_in_slice 128, f_out_slice 64) -> zero padding waste, 32 tiles/layer.
    compile_mlp("mlp7", &[512; 8], Dtype::I8, batch, Some((4, 8)))
}

/// MLP-Mixer sub-blocks of Table III. Each is two linear layers applied to
/// a reshaped tensor; `rows` is the GEMM row count after reshape.
pub struct MixerBlock {
    pub name: &'static str,
    pub rows: usize,
    pub dims: [usize; 3],
    pub mops: f64,
}

/// Table III workloads: token/channel-mixing blocks + standalone MLPs.
pub fn table3_blocks() -> Vec<MixerBlock> {
    vec![
        // input [B*C, T] = [512, 196], layer 196 -> 256 -> 196
        MixerBlock { name: "token_mlp_s16", rows: 512, dims: [196, 256, 196], mops: 102.0 },
        // input [B*T, C] = [196, 512], layer 512 -> 2048 -> 512
        MixerBlock { name: "channel_mlp_s16", rows: 196, dims: [512, 2048, 512], mops: 822.0 },
        // input [B*C, T] = [1024, 196], layer 196 -> 512 -> 196
        MixerBlock { name: "token_mlp_l16", rows: 1024, dims: [196, 512, 196], mops: 411.0 },
        // input [256, 1024], hidden 1024, 2 layers
        MixerBlock { name: "mlp_2layer", rows: 256, dims: [1024, 1024, 1024], mops: 1074.0 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_is_stable() {
        assert_eq!(name_seed("mlp7"), name_seed("mlp7"));
        assert_ne!(name_seed("mlp7"), name_seed("mlp8"));
    }

    #[test]
    fn synth_model_deterministic() {
        let a = synth_model("det", &mlp_spec(&[32, 16], Dtype::I8), 4);
        let b = synth_model("det", &mlp_spec(&[32, 16], Dtype::I8), 4);
        assert_eq!(a.layers[0].weights, b.layers[0].weights);
        assert_eq!(a.layers[0].bias, b.layers[0].bias);
    }

    #[test]
    fn weights_in_dtype_range() {
        let m = synth_model("rng", &mlp_spec(&[64, 64], Dtype::I8), 4);
        assert!(m.layers[0].weights.iter().all(|&w| (-128..=127).contains(&w)));
        m.validate().unwrap();
    }

    #[test]
    fn residual_and_diamond_models_compile_end_to_end() {
        let res = residual_mlp_model("models_res", 64, 96, 16, 6);
        res.validate().unwrap();
        let mut cfg = CompileConfig::default();
        cfg.batch = 8;
        let m = compile(&res, cfg).unwrap();
        let fw = m.firmware.as_ref().unwrap();
        fw.check_invariants().unwrap();
        assert_eq!(fw.merges.len(), 1);
        assert_eq!(fw.output_features(), 16);

        let dia = diamond_mlp_model("models_dia", 64, 64, 8, 6);
        dia.validate().unwrap();
        let mut cfg = CompileConfig::default();
        cfg.batch = 8;
        let m = compile(&dia, cfg).unwrap();
        let fw = m.firmware.as_ref().unwrap();
        fw.check_invariants().unwrap();
        assert_eq!(fw.layers.len(), 4);
        assert_eq!(fw.merges.len(), 1);
    }

    #[test]
    fn cnn_classifier_compiles_end_to_end() {
        let json = cnn_classifier_model("models_cnn", 6);
        json.validate().unwrap();
        let mut cfg = CompileConfig::default();
        cfg.batch = 4;
        let m = compile(&json, cfg).unwrap();
        let fw = m.firmware.as_ref().unwrap();
        fw.check_invariants().unwrap();
        // Two conv GEMM layers + the dense head; the pool is a merge stage.
        assert_eq!(fw.layers.len(), 3);
        assert_eq!(fw.merges.len(), 1);
        assert_eq!(fw.input_features(), 12 * 12 * 3);
        assert_eq!(fw.output_features(), 10);
        // Both convs carry patch-walk read plans (implicit GEMM, no im2col).
        let with_patch = fw.layers.iter().filter(|l| l.input_plan.patch.is_some()).count();
        assert_eq!(with_patch, 2);
    }

    #[test]
    fn mixer_block_model_compiles_end_to_end() {
        let json = mlp_mixer_block_model("models_mixer", 6);
        json.validate().unwrap();
        let mut cfg = CompileConfig::default();
        cfg.batch = 2;
        let m = compile(&json, cfg).unwrap();
        let fw = m.firmware.as_ref().unwrap();
        fw.check_invariants().unwrap();
        assert_eq!(fw.input_features(), 8 * 8);
        assert_eq!(fw.output_features(), 10);
        // 5 convs + the dense head run as GEMMs; the 2 transposes and 2
        // residual adds are memory-tile stages.
        assert_eq!(fw.layers.len(), 6);
        assert_eq!(fw.merges.len(), 4);
    }

    #[test]
    fn wide_mlp_2x_overflows_one_array_and_partitions() {
        use crate::partition::{compile_partitioned, PartitionOptions};
        let json = wide_mlp_2x_model("models_wide2x");
        json.validate().unwrap();
        let cfg = wide_mlp_2x_config();
        // Single-array compile must fail: 512 tiles on a 296-tile array.
        let err = compile(&json, cfg.clone()).unwrap_err().to_string();
        assert!(err.contains("tiles"), "unexpected failure: {err}");
        // The auto partitioner finds the smallest feasible pipeline depth.
        let pm = compile_partitioned(&json, cfg, &PartitionOptions::default()).unwrap();
        assert!(pm.firmware.k() >= 2, "expected >= 2 partitions, got {}", pm.firmware.k());
        for fw in &pm.firmware.partitions {
            assert!(fw.tiles_used() <= fw.device.placeable_tiles());
        }
        assert_eq!(pm.firmware.tiles_used(), 4 * 128);
    }

    #[test]
    fn seven_layer_compiles_and_fits() {
        let m = seven_layer_mlp(128).unwrap();
        let fw = m.firmware.as_ref().unwrap();
        assert_eq!(fw.layers.len(), 7);
        assert_eq!(fw.tiles_used(), 7 * 32);
        assert!(fw.tiles_used() <= fw.device.placeable_tiles());
        // Paper: 3.7 MOPs per sample for the 7-layer MLP.
        let mops = fw.ops_per_sample() as f64 / 1e6;
        assert!((mops - 3.67).abs() < 0.05, "mops {mops}");
    }

    #[test]
    fn table3_mops_match_paper() {
        for b in table3_blocks() {
            let macs: usize = b.dims.windows(2).map(|w| w[0] * w[1]).sum();
            let mops = (2 * macs * b.rows) as f64 / 1e6;
            assert!(
                (mops - b.mops).abs() / b.mops < 0.02,
                "{}: computed {mops} MOPs vs paper {}",
                b.name,
                b.mops
            );
        }
    }
}
