//! Table V — cross-architecture comparison: end-to-end INT8 throughput of
//! the 7-layer 512×512 MLP on AIE-ML (measured via our stack) vs FPGA /
//! GPU / ANE roofline baselines.

use crate::baselines::devices::{baseline_devices, paper_reported};
use crate::harness::models::seven_layer_mlp;
use crate::sim::engine::{analyze, EngineModel};
use anyhow::Result;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
pub struct Table5Row {
    pub device: String,
    pub generation: String,
    pub toolchain: String,
    pub throughput_tops: f64,
    pub measured: bool,
}

/// Generate the table: AIE4ML measured, baselines modeled.
pub fn generate() -> Result<Vec<Table5Row>> {
    let model = seven_layer_mlp(128)?;
    let fw = model.firmware.as_ref().unwrap();
    let report = analyze(fw, &EngineModel::default());
    let mut rows = vec![Table5Row {
        device: "Versal VEK280".into(),
        generation: "AIE-ML".into(),
        toolchain: "AIE4ML".into(),
        throughput_tops: report.throughput_tops,
        measured: true,
    }];
    for d in baseline_devices() {
        rows.push(Table5Row {
            device: d.device.into(),
            generation: d.generation.into(),
            toolchain: d.toolchain.into(),
            throughput_tops: d.throughput_tops(),
            measured: false,
        });
    }
    Ok(rows)
}

pub fn render() -> Result<String> {
    let rows = generate()?;
    let paper = paper_reported();
    let mut s = String::new();
    let _ = writeln!(s, "TABLE V — 7-layer MLP INT8 inference throughput (ours | paper)");
    let _ = writeln!(
        s,
        "{:<17} {:<12} {:<10} {:>12} {:>8}",
        "Device", "Generation", "Toolchain", "TOPS", "paper"
    );
    for r in &rows {
        let p = paper.iter().find(|(n, _)| *n == r.device).map(|(_, t)| *t).unwrap_or(0.0);
        let _ = writeln!(
            s,
            "{:<17} {:<12} {:<10} {:>9.1}{} {:>8.1}",
            r.device,
            r.generation,
            r.toolchain,
            r.throughput_tops,
            if r.measured { "*" } else { " " },
            p
        );
    }
    let _ = writeln!(s, "* measured on our simulator; baselines are documented roofline models");
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aie_wins_by_large_margins() {
        let rows = generate().unwrap();
        let aie = rows[0].throughput_tops;
        for r in &rows[1..] {
            let factor = aie / r.throughput_tops;
            assert!(factor > 5.0, "{}: only {:.1}x", r.device, factor);
        }
    }

    #[test]
    fn aie_throughput_in_paper_band() {
        // Paper: 113.4 TOPS. Cycle-approximate tolerance ±20%.
        let rows = generate().unwrap();
        let t = rows[0].throughput_tops;
        assert!((t - 113.4).abs() / 113.4 < 0.20, "AIE TOPS {t}");
    }

    #[test]
    fn crossover_factors_match_paper_shape() {
        // Paper factors: GPU 8.0x, FPGA 30.6x, ANE 10.8x. Ours should land
        // within 35% of each factor.
        let rows = generate().unwrap();
        let aie = rows[0].throughput_tops;
        let factor = |name: &str, paper: f64| {
            let r = rows.iter().find(|r| r.device == name).unwrap();
            let f = aie / r.throughput_tops;
            assert!((f - paper).abs() / paper < 0.35, "{name}: {f:.1}x vs paper {paper}x");
        };
        factor("Nvidia 3060 GPU", 113.4 / 14.1);
        factor("VU13P FPGA", 113.4 / 3.7);
        factor("Apple M4 ANE", 113.4 / 10.5);
    }
}
