//! Table I — single AIE-ML tile ceilings for selected tilings/datatypes.

use crate::arch::{table1_ceilings, AieGeneration, CeilingRow};
use std::fmt::Write as _;

pub use crate::arch::mmul::CeilingRow as Row;

/// Generate the Table I rows (analytical, from the architecture model).
pub fn generate() -> Vec<CeilingRow> {
    table1_ceilings(AieGeneration::AieMl, 1.25)
}

/// Paper-reported values for comparison: (tiling, dtype, MAC/cyc, GMAC/s, GOP/s).
pub fn paper() -> Vec<((usize, usize, usize), &'static str, u32, f64, f64)> {
    vec![
        ((4, 8, 8), "i8xi8", 256, 320.0, 640.0),
        ((4, 4, 8), "i16xi8", 128, 160.0, 320.0),
        ((4, 4, 4), "i16xi16", 64, 80.0, 160.0),
    ]
}

/// Render the table like the paper prints it.
pub fn render() -> String {
    let mut s = String::new();
    let _ = writeln!(s, "TABLE I — Single AIE-ML tile ceilings @ 1.25 GHz");
    let _ = writeln!(s, "{:<12} {:<10} {:>7} {:>9} {:>8} {:>8}", "<M,K,N>", "Datatype", "Native", "MAC/cyc", "GMAC/s", "GOP/s");
    for r in generate() {
        let _ = writeln!(
            s,
            "{:<12} {:<10} {:>7} {:>9} {:>8.0} {:>8.0}",
            format!("<{},{},{}>", r.tiling.0, r.tiling.1, r.tiling.2),
            r.datatype,
            if r.native { "Yes" } else { "No" },
            r.mac_per_cycle,
            r.gmac_s,
            r.gop_s
        );
    }
    s
}

#[cfg(test)]
mod tests {
    #[test]
    fn generated_rows_match_paper_exactly() {
        let gen = super::generate();
        let paper = super::paper();
        assert_eq!(gen.len(), paper.len());
        for (g, p) in gen.iter().zip(&paper) {
            assert_eq!(g.tiling, p.0);
            assert_eq!(g.mac_per_cycle, p.2);
            assert!((g.gmac_s - p.3).abs() < 1e-9);
            assert!((g.gop_s - p.4).abs() < 1e-9);
        }
    }

    #[test]
    fn renders() {
        let s = super::render();
        assert!(s.contains("<4,8,8>"));
        assert!(s.contains("640"));
    }
}
