//! Bit-exact functional execution of compiled firmware.
//!
//! This is the simulator's correctness half: it executes the *packed*
//! firmware exactly as the hardware kernels would — per-tile weight streams
//! are unpacked through the same ⟨K,N⟩ tiler the kernel uses, activations
//! travel through the mem-tile write/read tilers with DMA zero padding,
//! partial sums cascade west→east per row, the tail tile adds bias,
//! applies ReLU in the epilogue and stores through SRS.
//!
//! Execution walks the firmware **stage DAG** in topological order, keeping
//! every stage's activation alive for its consumers: fan-out re-reads a
//! producer's buffer, residual `Add` merges sum their inputs in wrapping
//! i32 and store through SRS(0) (pure saturation), `Concat` merges splice
//! features in input order. A chain is the degenerate DAG and executes
//! exactly as before.
//!
//! Accumulator semantics match the hardware (and `jnp` int arithmetic):
//! exact accumulation reduced modulo the accumulator width (i32 wraps for
//! the 8/16-bit paths, i64 for i16×i16), saturation only at the SRS store.
//! ReLU-before-SRS and clamp-after-SRS are bit-identical because SRS is
//! monotone with srs(0)=0; we apply `max(srs(acc), 0)`.

use crate::arch::Dtype;
use crate::codegen::firmware::{
    Firmware, FirmwareLayer, FirmwareOutput, MergeOp, MergeStage, StageRef, StageSource,
};
use crate::ir::{srs, srs_i32};
use crate::sim::dma::Tiler2d;
use anyhow::{ensure, Result};

/// A batch of activations: row-major `[batch, features]`, storage widened
/// to i32 (values always within the layer dtype's range).
#[derive(Debug, Clone, PartialEq)]
pub struct Activation {
    pub batch: usize,
    pub features: usize,
    pub data: Vec<i32>,
}

impl Activation {
    pub fn new(batch: usize, features: usize, data: Vec<i32>) -> Result<Activation> {
        ensure!(
            data.len() == batch * features,
            "activation data {} != {}x{}",
            data.len(),
            batch,
            features
        );
        Ok(Activation { batch, features, data })
    }

    pub fn zeros(batch: usize, features: usize) -> Activation {
        Activation { batch, features, data: vec![0; batch * features] }
    }

    pub fn row(&self, b: usize) -> &[i32] {
        &self.data[b * self.features..(b + 1) * self.features]
    }
}

/// Execute the whole firmware on an input batch and return the *primary*
/// network output (the first sink). The input must be within the network
/// input dtype range (checked). Multi-sink firmware callers use
/// [`execute_all`] to receive every output.
pub fn execute(fw: &Firmware, input: &Activation) -> Result<Activation> {
    let mut outs = run_stages(fw, input)?;
    let act = outs
        .get_mut(fw.output_stage)
        .and_then(Option::take)
        .ok_or_else(|| anyhow::anyhow!("output stage {} missing", fw.output_stage))?;
    drain_output(&fw.outputs[0], act)
}

/// Execute the whole firmware and return **every** network output, one per
/// sink, in [`Firmware::outputs`] order (frontend layer order). Single-sink
/// firmware yields one activation, identical to [`execute`].
pub fn execute_all(fw: &Firmware, input: &Activation) -> Result<Vec<Activation>> {
    let mut outs = run_stages(fw, input)?;
    let mut drained = Vec::with_capacity(fw.outputs.len());
    for o in &fw.outputs {
        let act = outs
            .get_mut(o.stage)
            .and_then(Option::take)
            .ok_or_else(|| anyhow::anyhow!("output stage {} ('{}') missing", o.stage, o.name))?;
        drained.push(drain_output(o, act)?);
    }
    Ok(drained)
}

/// Output drain through an output mem-tile plan (round-trip through the
/// write tiler models the final store order; values unchanged). A drain
/// re-targeted by the partitioner additionally executes its offset-tiler
/// landing — the scatter into (and read back out of) the downstream
/// consumer's {M, K} read image — so the direct-landing DMA program runs
/// under the bit-exactness gates too.
fn drain_output(out: &FirmwareOutput, act: Activation) -> Result<Activation> {
    let stream = out.plan.write_tiler.tile(&act.data);
    let mut data = out.plan.write_tiler.untile(&stream);
    if let Some(t) = &out.write_tiler {
        let mut image = vec![0i32; act.batch * t.stride];
        t.scatter(act.batch, act.features, &data, &mut image);
        data = t.gather(act.batch, act.features, &image);
    }
    Activation::new(act.batch, act.features, data)
}

/// Walk the stage DAG in topological order, returning every stage's
/// activation; a stage's inputs always reference earlier stages (or the
/// network input buffer).
fn run_stages(fw: &Firmware, input: &Activation) -> Result<Vec<Option<Activation>>> {
    ensure!(
        input.features == fw.input_features(),
        "input features {} != model {}",
        input.features,
        fw.input_features()
    );
    let (lo, hi) = fw.input_quant.dtype.range();
    ensure!(
        input.data.iter().all(|&x| (x as i64) >= lo && (x as i64) <= hi),
        "input values outside {} range",
        fw.input_quant.dtype
    );
    let mut outs: Vec<Option<Activation>> = vec![None; fw.stages.len()];
    for (i, stage) in fw.stages.iter().enumerate() {
        let mut ins: Vec<&Activation> = Vec::with_capacity(stage.inputs.len());
        for src in &stage.inputs {
            ins.push(match src {
                StageSource::Input => input,
                StageSource::Stage(j) => outs
                    .get(*j)
                    .and_then(|o| o.as_ref())
                    .ok_or_else(|| anyhow::anyhow!("stage {i} consumes unexecuted stage {j}"))?,
            });
        }
        let out = match stage.op {
            StageRef::Layer(li) => {
                let layer = &fw.layers[li];
                ensure!(ins.len() == 1, "layer '{}' expects exactly one input", layer.name);
                execute_layer(layer, ins[0])?
            }
            StageRef::Merge(mi) => execute_merge(&fw.merges[mi], &ins)?,
        };
        drop(ins);
        outs[i] = Some(out);
    }
    Ok(outs)
}

/// Execute one memory-tile stage (residual Add / Concat / pooling /
/// transpose) bit-exactly. Every input models its mem-tile landing
/// (write-tiler round trip), matching the DMA order the hardware buffer
/// sees.
pub fn execute_merge(m: &MergeStage, inputs: &[&Activation]) -> Result<Activation> {
    let (min_in, max_in) = m.op.arity_range();
    ensure!(
        inputs.len() == m.plan.write_tilers.len()
            && inputs.len() >= min_in
            && inputs.len() <= max_in,
        "merge '{}': {} inputs for {} write tilers",
        m.name,
        inputs.len(),
        m.plan.write_tilers.len()
    );
    let batch = inputs[0].batch;
    ensure!(
        inputs.iter().all(|a| a.batch == batch),
        "merge '{}': input batch sizes disagree",
        m.name
    );
    match m.op {
        MergeOp::Add => {
            for a in inputs {
                ensure!(
                    a.features == m.features,
                    "merge '{}': input features {} != {}",
                    m.name,
                    a.features,
                    m.features
                );
            }
            // Wrapping i32 accumulation (the hardware adder is modular),
            // then an SRS with shift 0 — a pure saturating store, since all
            // operands share one binary point.
            let mut data = vec![0i32; batch * m.features];
            for (a, wt) in inputs.iter().zip(&m.plan.write_tilers) {
                let linear = wt.untile(&wt.tile(&a.data));
                for (acc, v) in data.iter_mut().zip(&linear) {
                    *acc = acc.wrapping_add(*v);
                }
            }
            for v in &mut data {
                *v = srs_i32(*v, 0, m.quant.dtype);
            }
            Activation::new(batch, m.features, data)
        }
        MergeOp::Concat => {
            let total: usize = inputs.iter().map(|a| a.features).sum();
            ensure!(
                total == m.features,
                "merge '{}': concatenated widths {} != {}",
                m.name,
                total,
                m.features
            );
            let mut data = vec![0i32; batch * m.features];
            if m.plan.offset_tiled() {
                // Offset tilers: every branch scatters its feature band
                // straight into a consumer's read image in {M, K}
                // descriptor order — the merged activation never exists as
                // a separate row-major staging buffer. Each consumer's
                // group lands the identical logical image (scatter is a
                // permutation copy), so replaying the first group suffices
                // for bit-exactness.
                ensure!(
                    !m.plan.offset_tilers.is_empty()
                        && m.plan.offset_tilers.len() % inputs.len() == 0,
                    "merge '{}': {} offset tilers for {} inputs",
                    m.name,
                    m.plan.offset_tilers.len(),
                    inputs.len()
                );
                for (a, t) in inputs.iter().zip(&m.plan.offset_tilers[..inputs.len()]) {
                    t.scatter(batch, a.features, &a.data, &mut data);
                }
            } else {
                // Staged path: land each branch through its write tiler,
                // splice row-major.
                let mut off = 0usize;
                for (a, wt) in inputs.iter().zip(&m.plan.write_tilers) {
                    let linear = wt.untile(&wt.tile(&a.data));
                    for b in 0..batch {
                        data[b * m.features + off..b * m.features + off + a.features]
                            .copy_from_slice(&linear[b * a.features..(b + 1) * a.features]);
                    }
                    off += a.features;
                }
            }
            Activation::new(batch, m.features, data)
        }
        MergeOp::MaxPool2D(p) => pool2d(m, &p, true, inputs[0]),
        MergeOp::AvgPool2D(p) => pool2d(m, &p, false, inputs[0]),
        MergeOp::Transpose { rows, cols } => {
            ensure!(
                inputs[0].features == rows * cols && m.features == rows * cols,
                "transpose '{}': features {} != {}x{}",
                m.name,
                inputs[0].features,
                rows,
                cols
            );
            let wt = &m.plan.write_tilers[0];
            let linear = wt.untile(&wt.tile(&inputs[0].data));
            // Pure strided re-read: [rows, cols] row-major -> [cols, rows].
            let mut data = vec![0i32; batch * m.features];
            for b in 0..batch {
                let src = &linear[b * m.features..(b + 1) * m.features];
                let dst = &mut data[b * m.features..(b + 1) * m.features];
                for r in 0..rows {
                    for c in 0..cols {
                        dst[c * rows + r] = src[r * cols + c];
                    }
                }
            }
            Activation::new(batch, m.features, data)
        }
    }
}

/// Windowed pooling over an NHWC image, executed on the memory tile.
/// Out-of-bounds taps under 'same' padding are *excluded*: max pools over
/// the present elements only, avg divides by the present count with the
/// SRS rounding rule (round half toward +inf) and a saturating store.
fn pool2d(
    m: &MergeStage,
    p: &crate::ir::Pool2DAttrs,
    is_max: bool,
    input: &Activation,
) -> Result<Activation> {
    ensure!(
        input.features == p.in_features(),
        "pool '{}': input features {} != image {}",
        m.name,
        input.features,
        p.in_features()
    );
    ensure!(
        m.features == p.out_features(),
        "pool '{}': stage features {} != pooled image {}",
        m.name,
        m.features,
        p.out_features()
    );
    let batch = input.batch;
    let wt = &m.plan.write_tilers[0];
    let image = wt.untile(&wt.tile(&input.data));
    let (oh, ow) = (p.out_h(), p.out_w());
    let (pt, pl) = (p.pad_top() as isize, p.pad_left() as isize);
    let dtype = m.quant.dtype;
    let mut data = vec![0i32; batch * m.features];
    for b in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..p.c {
                    let mut mx = i32::MIN;
                    let mut sum: i64 = 0;
                    let mut count: i64 = 0;
                    for ky in 0..p.kh {
                        for kx in 0..p.kw {
                            let iy = (oy * p.stride_h + ky) as isize - pt;
                            let ix = (ox * p.stride_w + kx) as isize - pl;
                            if iy < 0
                                || ix < 0
                                || iy >= p.in_h as isize
                                || ix >= p.in_w as isize
                            {
                                continue;
                            }
                            let v = image
                                [((b * p.in_h + iy as usize) * p.in_w + ix as usize) * p.c + ch];
                            mx = mx.max(v);
                            sum += v as i64;
                            count += 1;
                        }
                    }
                    ensure!(count > 0, "pool '{}': window with no present taps", m.name);
                    let y = if is_max {
                        mx
                    } else {
                        // floor((sum + floor(count/2)) / count): nearest,
                        // exact halves toward +inf — the SRS rounding rule.
                        (sum + count / 2).div_euclid(count) as i32
                    };
                    data[b * m.features + (oy * ow + ox) * p.c + ch] = srs_i32(y, 0, dtype);
                }
            }
        }
    }
    Activation::new(batch, m.features, data)
}

/// Execute one layer bit-exactly.
pub fn execute_layer(layer: &FirmwareLayer, input: &Activation) -> Result<Activation> {
    let geo = layer.cascade;
    let t = layer.tiling;
    let q = layer.quant;

    // --- Mem-tile path: store in producer tile order, fetch, zero-pad ----
    // The write/read tiler round trip is exercised for DMA-model fidelity.
    // A Conv2D layer's buffer holds the NHWC *image*; the read DMA
    // synthesizes the im2col rows coordinate-by-coordinate on the way out
    // (implicit GEMM) — the patch matrix below is the transient DMA
    // stream the kernel consumes, never a buffer the plan accounts for.
    let plan = &layer.input_plan;
    let (batch, f_logical, linear) = if let Some(p) = &plan.patch {
        ensure!(
            input.features == p.image_features(),
            "conv layer '{}': image features {} != {}",
            layer.name,
            input.features,
            p.image_features()
        );
        let image = plan.write_tiler.untile(&plan.write_tiler.tile(&input.data));
        let stream = p.gather(input.batch, &image);
        let patches = p.read_tiler(input.batch).untile(&stream);
        (p.gemm_rows(input.batch), p.patch_len(), patches)
    } else {
        let stream = plan.write_tiler.tile(&input.data);
        (input.batch, input.features, plan.write_tiler.untile(&stream))
    };
    ensure!(
        f_logical == layer.in_features,
        "layer '{}': input features {} != {}",
        layer.name,
        f_logical,
        layer.in_features
    );
    let f_in_pad = geo.f_in_padded();
    let mut padded = vec![0i32; batch * f_in_pad];
    for b in 0..batch {
        padded[b * f_in_pad..b * f_in_pad + f_logical]
            .copy_from_slice(&linear[b * f_logical..(b + 1) * f_logical]);
    }

    // --- Per-cascade-row compute (rows are independent) ------------------
    let f_out = layer.out_features;
    let wide_acc = q.acc_dtype == Dtype::I64;
    // Cascade rows are independent — compute them on scoped threads (the
    // offline environment has no rayon; std::thread::scope serves the same
    // purpose for this embarrassingly parallel loop).
    let compute_row = |r: usize| -> Vec<i32> {
        {
            // Unpack each tile's weight stream through the kernel's tiler.
            let wt_tiler = Tiler2d::new(geo.f_in_slice, geo.f_out_slice, t.k, t.n);
            let slices: Vec<Vec<i32>> = (0..geo.cas_len)
                .map(|c| wt_tiler.untile(&layer.kernel(r, c).weights))
                .collect();
            let tail = layer.kernel(r, geo.cas_len - 1);
            let f_os = geo.f_out_slice;
            let mut out = vec![0i32; batch * f_os];
            // Row-of-accumulators loop order (i-k-j): each activation value
            // streams across the contiguous weight row, which vectorizes and
            // avoids the strided f_out_slice walk of the naive j-inner form.
            //
            // 32-bit path: accumulate with *wrapping i32* arithmetic — the
            // hardware accumulator is modular, and mod-2^32 arithmetic is a
            // ring homomorphism, so wrap-as-you-go equals exact-then-wrap.
            // i32 lanes also vectorize 2x denser than i64. The i16xi16 path
            // keeps exact i64 accumulation (its sums never overflow i64).
            if !wide_acc {
                let mut acc = vec![0i32; f_os];
                for b in 0..batch {
                    let a_row = &padded[b * f_in_pad..(b + 1) * f_in_pad];
                    acc.fill(0);
                    for (c, wt) in slices.iter().enumerate() {
                        let a = &a_row[c * geo.f_in_slice..(c + 1) * geo.f_in_slice];
                        for (i, &av) in a.iter().enumerate() {
                            if av == 0 {
                                continue; // zero padding rows/cols are common
                            }
                            let wrow = &wt[i * f_os..(i + 1) * f_os];
                            for (o, &wv) in wrow.iter().enumerate() {
                                acc[o] = acc[o].wrapping_add(av.wrapping_mul(wv));
                            }
                        }
                    }
                    let out_row = &mut out[b * f_os..(b + 1) * f_os];
                    for o in 0..f_os {
                        let mut a = acc[o];
                        if layer.use_bias {
                            a = a.wrapping_add(tail.bias[o] as i32);
                        }
                        // 32-bit store: the SRS rounding add wraps in the
                        // accumulator width, like the hardware and jnp.int32
                        // (see ir::srs_i32) — never the 64-bit srs here.
                        let mut y = srs_i32(a, q.shift, q.output.dtype);
                        if layer.relu {
                            y = y.max(0);
                        }
                        out_row[o] = y;
                    }
                }
            } else {
                let mut acc = vec![0i64; f_os];
                for b in 0..batch {
                    let a_row = &padded[b * f_in_pad..(b + 1) * f_in_pad];
                    acc.fill(0);
                    for (c, wt) in slices.iter().enumerate() {
                        let a = &a_row[c * geo.f_in_slice..(c + 1) * geo.f_in_slice];
                        for (i, &av) in a.iter().enumerate() {
                            if av == 0 {
                                continue;
                            }
                            let av = av as i64;
                            let wrow = &wt[i * f_os..(i + 1) * f_os];
                            for (o, &wv) in wrow.iter().enumerate() {
                                acc[o] += av * wv as i64;
                            }
                        }
                    }
                    let out_row = &mut out[b * f_os..(b + 1) * f_os];
                    for o in 0..f_os {
                        let mut a = acc[o];
                        if layer.use_bias {
                            a += tail.bias[o];
                        }
                        let mut y = srs(a, q.shift, q.output.dtype);
                        if layer.relu {
                            y = y.max(0);
                        }
                        out_row[o] = y as i32;
                    }
                }
            }
            out
        }
    };
    let parallel = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) > 1;
    let out_rows: Vec<Vec<i32>> = if parallel && geo.cas_num > 1 && batch * geo.f_out_slice >= 4096 {
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..geo.cas_num)
                .map(|r| scope.spawn(move || compute_row(r)))
                .collect();
            handles.into_iter().map(|h| h.join().expect("row thread")).collect()
        })
    } else {
        (0..geo.cas_num).map(compute_row).collect()
    };

    // --- Gather cascade-row outputs, drop feature padding -----------------
    let mut data = vec![0i32; batch * f_out];
    for (r, rows) in out_rows.iter().enumerate() {
        for b in 0..batch {
            for o in 0..geo.f_out_slice {
                let go = r * geo.f_out_slice + o;
                if go < f_out {
                    data[b * f_out + go] = rows[b * geo.f_out_slice + o];
                }
            }
        }
    }
    // Report the activation per *sample*: for a lowered conv the [rows, N]
    // GEMM output row-major IS the flattened NHWC output image, so the
    // `m_scale` GEMM rows of one sample fold back into its feature axis.
    Activation::new(batch / layer.m_scale.max(1), f_out * layer.m_scale, data)
}

/// Reference dense layer on *unpacked* logical tensors — a second,
/// independent implementation used to cross-check the packed path in tests.
pub fn reference_dense(
    input: &Activation,
    weights: &[i32], // [out][in] row-major
    bias: Option<&[i64]>,
    f_out: usize,
    shift: u32,
    out_dtype: Dtype,
    acc_dtype: Dtype,
    relu: bool,
) -> Activation {
    let f_in = input.features;
    let mut data = vec![0i32; input.batch * f_out];
    for b in 0..input.batch {
        for o in 0..f_out {
            let mut acc: i64 = 0;
            for i in 0..f_in {
                acc += input.data[b * f_in + i] as i64 * weights[o * f_in + i] as i64;
            }
            if let Some(bias) = bias {
                acc += bias[o];
            }
            // Match the store semantics exactly: 32-bit accumulators wrap
            // (including the SRS rounding add — srs_i32), the i16xi16 path
            // stays exact in i64.
            let mut y = if acc_dtype != Dtype::I64 {
                srs_i32(acc as i32, shift, out_dtype) as i64
            } else {
                srs(acc, shift, out_dtype)
            };
            if relu {
                y = y.max(0);
            }
            data[b * f_out + o] = y as i32;
        }
    }
    Activation { batch: input.batch, features: f_out, data }
}

/// Quantize a float batch at the model boundary (optional float I/O).
pub fn quantize_input(fw: &Firmware, x: &[f64], batch: usize) -> Result<Activation> {
    let q = fw.input_quant;
    let features = fw.input_features();
    ensure!(x.len() == batch * features, "float input length");
    let data = x.iter().map(|&v| q.quantize(v) as i32).collect();
    Activation::new(batch, features, data)
}

/// Dequantize the output batch back to floats.
pub fn dequantize_output(fw: &Firmware, y: &Activation) -> Vec<f64> {
    let q = fw.output_quant();
    y.data.iter().map(|&v| q.dequantize(v as i64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{CompileConfig, JsonLayer, JsonModel, LayerConfig};
    use crate::passes::compile;
    use crate::util::rng::Pcg32;

    fn rng() -> Pcg32 {
        Pcg32::seed_from_u64(0x41E4)
    }

    fn build_fw(
        dims: &[usize],
        dtype: &str,
        batch: usize,
        cascade: Option<(usize, usize)>,
        seed: u64,
    ) -> (Firmware, Vec<Vec<i32>>, Vec<Vec<i64>>) {
        let mut r = Pcg32::seed_from_u64(seed);
        let (lo, hi) = Dtype::parse(dtype).unwrap().range();
        let mut all_w = Vec::new();
        let mut all_b = Vec::new();
        let layers: Vec<JsonLayer> = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| {
                let weights: Vec<i32> =
                    (0..w[0] * w[1]).map(|_| r.gen_i32_in(lo, hi)).collect();
                let bias: Vec<i64> = (0..w[1]).map(|_| r.gen_range_i64(-1000, 1000)).collect();
                all_w.push(weights.clone());
                all_b.push(bias.clone());
                JsonLayer::dense(
                    &format!("fc{}", i + 1),
                    w[0],
                    w[1],
                    true,
                    i + 2 < dims.len(),
                    dtype,
                    dtype,
                    6,
                    weights,
                    bias,
                )
            })
            .collect();
        let jm = JsonModel::new("t", layers);
        let mut cfg = CompileConfig::default();
        cfg.batch = batch;
        cfg.tiles_per_layer = Some(8);
        if let Some(cas) = cascade {
            for i in 0..dims.len() - 1 {
                cfg.layers.insert(
                    format!("fc{}", i + 1),
                    LayerConfig { cascade: Some(cas), ..Default::default() },
                );
            }
        }
        let fw = compile(&jm, cfg).unwrap().firmware.unwrap();
        (fw, all_w, all_b)
    }

    fn random_input(batch: usize, features: usize, dtype: Dtype, r: &mut Pcg32) -> Activation {
        let (lo, hi) = dtype.range();
        let data = (0..batch * features).map(|_| r.gen_i32_in(lo, hi)).collect();
        Activation::new(batch, features, data).unwrap()
    }

    #[test]
    fn packed_path_matches_reference_i8() {
        let (fw, ws, bs) = build_fw(&[64, 96, 32], "int8", 8, Some((2, 2)), 7);
        let mut r = rng();
        let x = random_input(8, 64, Dtype::I8, &mut r);
        let y = execute(&fw, &x).unwrap();
        // Independent reference path over logical tensors.
        let mut a = x.clone();
        for (i, l) in fw.layers.iter().enumerate() {
            a = reference_dense(
                &a,
                &ws[i],
                Some(&bs[i]),
                l.out_features,
                l.quant.shift,
                l.quant.output.dtype,
                l.quant.acc_dtype,
                l.relu,
            );
        }
        assert_eq!(y.data, a.data);
    }

    #[test]
    fn packed_path_matches_reference_i16() {
        let (fw, ws, bs) = build_fw(&[48, 64, 16], "int16", 4, Some((2, 2)), 11);
        let mut r = rng();
        let x = random_input(4, 48, Dtype::I16, &mut r);
        let y = execute(&fw, &x).unwrap();
        let mut a = x.clone();
        for (i, l) in fw.layers.iter().enumerate() {
            a = reference_dense(
                &a,
                &ws[i],
                Some(&bs[i]),
                l.out_features,
                l.quant.shift,
                l.quant.output.dtype,
                l.quant.acc_dtype,
                l.relu,
            );
        }
        assert_eq!(y.data, a.data);
    }

    #[test]
    fn result_independent_of_cascade_geometry() {
        // The same layer computed on 1 tile vs 2x2 vs 4x2 cascades must be
        // bit-identical — parallelization must not change semantics.
        let mut r = rng();
        let x = random_input(8, 128, Dtype::I8, &mut r);
        let (fw1, _, _) = build_fw(&[128, 64], "int8", 8, Some((1, 1)), 3);
        let (fw2, _, _) = build_fw(&[128, 64], "int8", 8, Some((2, 2)), 3);
        let (fw3, _, _) = build_fw(&[128, 64], "int8", 8, Some((4, 2)), 3);
        let y1 = execute(&fw1, &x).unwrap();
        let y2 = execute(&fw2, &x).unwrap();
        let y3 = execute(&fw3, &x).unwrap();
        assert_eq!(y1.data, y2.data);
        assert_eq!(y1.data, y3.data);
    }

    #[test]
    fn ragged_shapes_execute() {
        // Non-divisible dims exercise mem-tile zero padding end to end.
        let (fw, ws, bs) = build_fw(&[100, 70, 10], "int8", 5, Some((2, 3)), 13);
        let mut r = rng();
        let x = random_input(5, 100, Dtype::I8, &mut r);
        let y = execute(&fw, &x).unwrap();
        let mut a = x.clone();
        for (i, l) in fw.layers.iter().enumerate() {
            a = reference_dense(
                &a,
                &ws[i],
                Some(&bs[i]),
                l.out_features,
                l.quant.shift,
                l.quant.output.dtype,
                l.quant.acc_dtype,
                l.relu,
            );
        }
        assert_eq!(y.data, a.data);
    }

    #[test]
    fn relu_clamps_negative() {
        // Identity-free check: all-negative weights + relu => zero outputs.
        let jm = JsonModel::new(
            "m",
            vec![JsonLayer::dense("fc1", 32, 32, false, true, "int8", "int8", 0, vec![-1; 32 * 32], vec![])],
        );
        let mut cfg = CompileConfig::default();
        cfg.batch = 4;
        cfg.tiles_per_layer = Some(1);
        let fw = compile(&jm, cfg).unwrap().firmware.unwrap();
        let x = Activation::new(4, 32, vec![1; 4 * 32]).unwrap();
        let y = execute(&fw, &x).unwrap();
        assert!(y.data.iter().all(|&v| v == 0));
    }

    #[test]
    fn srs_saturation_reached() {
        // Max-positive weights/inputs with shift 0 must pin at +127.
        let jm = JsonModel::new(
            "m",
            vec![JsonLayer::dense("fc1", 32, 32, false, false, "int8", "int8", 0, vec![127; 32 * 32], vec![])],
        );
        let mut cfg = CompileConfig::default();
        cfg.batch = 2;
        cfg.tiles_per_layer = Some(1);
        let fw = compile(&jm, cfg).unwrap().firmware.unwrap();
        let x = Activation::new(2, 32, vec![127; 2 * 32]).unwrap();
        let y = execute(&fw, &x).unwrap();
        assert!(y.data.iter().all(|&v| v == 127));
    }

    #[test]
    fn input_range_checked() {
        let (fw, _, _) = build_fw(&[32, 16], "int8", 2, Some((1, 1)), 1);
        let x = Activation::new(2, 32, vec![300; 64]).unwrap();
        assert!(execute(&fw, &x).is_err());
    }

    #[test]
    fn float_boundary_roundtrip() {
        let (fw, _, _) = build_fw(&[32, 16], "int8", 2, Some((1, 1)), 5);
        let x: Vec<f64> = (0..64).map(|i| (i as f64 - 32.0) / 64.0).collect();
        let qa = quantize_input(&fw, &x, 2).unwrap();
        let y = execute(&fw, &qa).unwrap();
        let yf = dequantize_output(&fw, &y);
        assert_eq!(yf.len(), 2 * 16);
        assert!(yf.iter().all(|v| v.is_finite()));
    }

    /// Independent saturating-add reference for merge checks.
    fn sat_add(a: &Activation, b: &Activation, dtype: Dtype) -> Activation {
        let data = a
            .data
            .iter()
            .zip(&b.data)
            .map(|(&x, &y)| crate::ir::srs_i32(x.wrapping_add(y), 0, dtype))
            .collect();
        Activation { batch: a.batch, features: a.features, data }
    }

    fn residual_fw(seed: u64, batch: usize) -> (Firmware, JsonModel) {
        let mut r = Pcg32::seed_from_u64(seed);
        let mut dense = |name: &str, fin: usize, fout: usize, relu: bool| {
            let weights: Vec<i32> = (0..fin * fout).map(|_| r.gen_i32_in(-128, 127)).collect();
            let bias: Vec<i64> = (0..fout).map(|_| r.gen_range_i64(-500, 500)).collect();
            JsonLayer::dense(name, fin, fout, true, relu, "int8", "int8", 6, weights, bias)
        };
        let jm = JsonModel::new(
            "res",
            vec![
                dense("fc1", 48, 64, true),
                dense("fc2", 64, 48, false),
                JsonLayer::residual_add("res", 48, "int8", 6, &["input", "fc2"]),
                dense("head", 48, 12, false).with_inputs(&["res"]),
            ],
        );
        let mut cfg = CompileConfig::default();
        cfg.batch = batch;
        cfg.tiles_per_layer = Some(4);
        let fw = compile(&jm, cfg).unwrap().firmware.unwrap();
        (fw, jm)
    }

    #[test]
    fn residual_packed_path_matches_reference() {
        let (fw, jm) = residual_fw(0xDA6, 6);
        fw.check_invariants().unwrap();
        let mut r = rng();
        let x = random_input(6, 48, Dtype::I8, &mut r);
        let got = execute(&fw, &x).unwrap();
        // Manual logical-tensor path: fc1 -> fc2, saturating skip add, head.
        let layer = |i: usize, a: &Activation| {
            let l = &jm.layers[i];
            reference_dense(
                a,
                &l.weights,
                Some(&l.bias),
                l.out_features,
                6, // frac 6 in, 6 wgt, 6 out -> shift 6
                Dtype::I8,
                Dtype::I32,
                l.relu,
            )
        };
        let h1 = layer(0, &x);
        let h2 = layer(1, &h1);
        let merged = sat_add(&x, &h2, Dtype::I8);
        let want = layer(3, &merged);
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn concat_packed_path_matches_reference() {
        let mut r = Pcg32::seed_from_u64(0xCA7);
        let mut dense = |name: &str, fin: usize, fout: usize, relu: bool| {
            let weights: Vec<i32> = (0..fin * fout).map(|_| r.gen_i32_in(-128, 127)).collect();
            let bias: Vec<i64> = (0..fout).map(|_| r.gen_range_i64(-500, 500)).collect();
            JsonLayer::dense(name, fin, fout, true, relu, "int8", "int8", 6, weights, bias)
        };
        let jm = JsonModel::new(
            "cat",
            vec![
                dense("a", 32, 24, true),
                dense("b", 32, 8, false).with_inputs(&["input"]),
                JsonLayer::concat("cat", 32, "int8", 6, &["a", "b"]),
                dense("head", 32, 5, false).with_inputs(&["cat"]),
            ],
        );
        let mut cfg = CompileConfig::default();
        cfg.batch = 4;
        cfg.tiles_per_layer = Some(2);
        let fw = compile(&jm, cfg).unwrap().firmware.unwrap();
        fw.check_invariants().unwrap();
        let mut rr = rng();
        let x = random_input(4, 32, Dtype::I8, &mut rr);
        let got = execute(&fw, &x).unwrap();
        let layer = |i: usize, a: &Activation| {
            let l = &jm.layers[i];
            reference_dense(a, &l.weights, Some(&l.bias), l.out_features, 6, Dtype::I8, Dtype::I32, l.relu)
        };
        let ha = layer(0, &x);
        let hb = layer(1, &x);
        let mut cat = vec![0i32; 4 * 32];
        for b in 0..4 {
            cat[b * 32..b * 32 + 24].copy_from_slice(ha.row(b));
            cat[b * 32 + 24..(b + 1) * 32].copy_from_slice(hb.row(b));
        }
        let merged = Activation::new(4, 32, cat).unwrap();
        let want = layer(3, &merged);
        assert_eq!(got.data, want.data);
    }

    #[test]
    fn multi_sink_execute_all_returns_every_output() {
        // Two heads off one trunk: execute_all yields both, in layer order,
        // and execute returns the primary (first) one.
        let mut r = Pcg32::seed_from_u64(0x51D);
        let mut dense = |name: &str, fin: usize, fout: usize, relu: bool| {
            let weights: Vec<i32> = (0..fin * fout).map(|_| r.gen_i32_in(-128, 127)).collect();
            let bias: Vec<i64> = (0..fout).map(|_| r.gen_range_i64(-500, 500)).collect();
            JsonLayer::dense(name, fin, fout, true, relu, "int8", "int8", 6, weights, bias)
        };
        let jm = JsonModel::new(
            "heads",
            vec![
                dense("trunk", 32, 48, true),
                dense("head_a", 48, 10, false).with_inputs(&["trunk"]),
                dense("head_b", 48, 4, false).with_inputs(&["trunk"]),
            ],
        );
        let mut cfg = CompileConfig::default();
        cfg.batch = 4;
        cfg.tiles_per_layer = Some(2);
        let fw = compile(&jm, cfg).unwrap().firmware.unwrap();
        fw.check_invariants().unwrap();
        let mut rr = rng();
        let x = random_input(4, 32, Dtype::I8, &mut rr);
        let all = execute_all(&fw, &x).unwrap();
        assert_eq!(all.len(), 2);
        assert_eq!((all[0].features, all[1].features), (10, 4));
        let primary = execute(&fw, &x).unwrap();
        assert_eq!(primary.data, all[0].data);
        // Each head agrees with the independent logical-tensor reference.
        let layer = |i: usize, a: &Activation| {
            let l = &jm.layers[i];
            reference_dense(a, &l.weights, Some(&l.bias), l.out_features, 6, Dtype::I8, Dtype::I32, l.relu)
        };
        let t = layer(0, &x);
        assert_eq!(all[0].data, layer(1, &t).data);
        assert_eq!(all[1].data, layer(2, &t).data);
    }

    #[test]
    fn residual_add_saturates_at_rails() {
        // Two rail-high activations summed must pin at +127, not wrap.
        let (fw, _) = residual_fw(0x5A7, 2);
        let mi = match fw.stages.iter().find_map(|s| match s.op {
            StageRef::Merge(mi) => Some(mi),
            _ => None,
        }) {
            Some(mi) => mi,
            None => panic!("residual firmware has no merge stage"),
        };
        let m = &fw.merges[mi];
        let hot = Activation::new(2, m.features, vec![120; 2 * m.features]).unwrap();
        let y = execute_merge(m, &[&hot, &hot]).unwrap();
        assert!(y.data.iter().all(|&v| v == 127), "{:?}", &y.data[..4]);
        let cold = Activation::new(2, m.features, vec![-120; 2 * m.features]).unwrap();
        let y = execute_merge(m, &[&cold, &cold]).unwrap();
        assert!(y.data.iter().all(|&v| v == -128));
    }
}
