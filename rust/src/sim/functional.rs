//! Bit-exact functional execution of compiled firmware.
//!
//! This is the simulator's correctness half: it executes the *packed*
//! firmware exactly as the hardware kernels would — per-tile weight streams
//! are unpacked through the same ⟨K,N⟩ tiler the kernel uses, activations
//! travel through the mem-tile write/read tilers with DMA zero padding,
//! partial sums cascade west→east per row, the tail tile adds bias,
//! applies ReLU in the epilogue and stores through SRS.
//!
//! Accumulator semantics match the hardware (and `jnp` int arithmetic):
//! exact accumulation reduced modulo the accumulator width (i32 wraps for
//! the 8/16-bit paths, i64 for i16×i16), saturation only at the SRS store.
//! ReLU-before-SRS and clamp-after-SRS are bit-identical because SRS is
//! monotone with srs(0)=0; we apply `max(srs(acc), 0)`.

use crate::arch::Dtype;
use crate::codegen::firmware::{Firmware, FirmwareLayer};
use crate::ir::{srs, srs_i32};
use crate::sim::dma::Tiler2d;
use anyhow::{ensure, Result};

/// A batch of activations: row-major `[batch, features]`, storage widened
/// to i32 (values always within the layer dtype's range).
#[derive(Debug, Clone, PartialEq)]
pub struct Activation {
    pub batch: usize,
    pub features: usize,
    pub data: Vec<i32>,
}

impl Activation {
    pub fn new(batch: usize, features: usize, data: Vec<i32>) -> Result<Activation> {
        ensure!(
            data.len() == batch * features,
            "activation data {} != {}x{}",
            data.len(),
            batch,
            features
        );
        Ok(Activation { batch, features, data })
    }

    pub fn zeros(batch: usize, features: usize) -> Activation {
        Activation { batch, features, data: vec![0; batch * features] }
    }

    pub fn row(&self, b: usize) -> &[i32] {
        &self.data[b * self.features..(b + 1) * self.features]
    }
}

/// Execute the whole firmware on an input batch. The input must be within
/// the first layer's input dtype range (checked).
pub fn execute(fw: &Firmware, input: &Activation) -> Result<Activation> {
    ensure!(
        input.features == fw.input_features(),
        "input features {} != model {}",
        input.features,
        fw.input_features()
    );
    let (lo, hi) = fw.layers[0].quant.input.dtype.range();
    ensure!(
        input.data.iter().all(|&x| (x as i64) >= lo && (x as i64) <= hi),
        "input values outside {} range",
        fw.layers[0].quant.input.dtype
    );
    let mut act = input.clone();
    for layer in &fw.layers {
        act = execute_layer(layer, &act)?;
    }
    // Output drain through the output mem-tile plan (round-trip through the
    // write tiler models the final store order; values unchanged).
    let plan = &fw.output_plan;
    let stream = plan.write_tiler.tile(&act.data);
    let data = plan.write_tiler.untile(&stream);
    Activation::new(act.batch, act.features, data)
}

/// Execute one layer bit-exactly.
pub fn execute_layer(layer: &FirmwareLayer, input: &Activation) -> Result<Activation> {
    ensure!(
        input.features == layer.in_features,
        "layer '{}': input features {} != {}",
        layer.name,
        input.features,
        layer.in_features
    );
    let geo = layer.cascade;
    let t = layer.tiling;
    let q = layer.quant;
    let batch = input.batch;

    // --- Mem-tile path: store in producer tile order, fetch, zero-pad ----
    // The write/read tiler round trip is exercised for DMA-model fidelity.
    let plan = &layer.input_plan;
    let stream = plan.write_tiler.tile(&input.data);
    let linear = plan.write_tiler.untile(&stream);
    let f_in_pad = geo.f_in_padded();
    let mut padded = vec![0i32; batch * f_in_pad];
    for b in 0..batch {
        padded[b * f_in_pad..b * f_in_pad + input.features]
            .copy_from_slice(&linear[b * input.features..(b + 1) * input.features]);
    }

    // --- Per-cascade-row compute (rows are independent) ------------------
    let f_out = layer.out_features;
    let wide_acc = q.acc_dtype == Dtype::I64;
    // Cascade rows are independent — compute them on scoped threads (the
    // offline environment has no rayon; std::thread::scope serves the same
    // purpose for this embarrassingly parallel loop).
    let compute_row = |r: usize| -> Vec<i32> {
        {
            // Unpack each tile's weight stream through the kernel's tiler.
            let wt_tiler = Tiler2d::new(geo.f_in_slice, geo.f_out_slice, t.k, t.n);
            let slices: Vec<Vec<i32>> = (0..geo.cas_len)
                .map(|c| wt_tiler.untile(&layer.kernel(r, c).weights))
                .collect();
            let tail = layer.kernel(r, geo.cas_len - 1);
            let f_os = geo.f_out_slice;
            let mut out = vec![0i32; batch * f_os];
            // Row-of-accumulators loop order (i-k-j): each activation value
            // streams across the contiguous weight row, which vectorizes and
            // avoids the strided f_out_slice walk of the naive j-inner form.
            //
            // 32-bit path: accumulate with *wrapping i32* arithmetic — the
            // hardware accumulator is modular, and mod-2^32 arithmetic is a
            // ring homomorphism, so wrap-as-you-go equals exact-then-wrap.
            // i32 lanes also vectorize 2x denser than i64. The i16xi16 path
            // keeps exact i64 accumulation (its sums never overflow i64).
            if !wide_acc {
                let mut acc = vec![0i32; f_os];
                for b in 0..batch {
                    let a_row = &padded[b * f_in_pad..(b + 1) * f_in_pad];
                    acc.fill(0);
                    for (c, wt) in slices.iter().enumerate() {
                        let a = &a_row[c * geo.f_in_slice..(c + 1) * geo.f_in_slice];
                        for (i, &av) in a.iter().enumerate() {
                            if av == 0 {
                                continue; // zero padding rows/cols are common
                            }
                            let wrow = &wt[i * f_os..(i + 1) * f_os];
                            for (o, &wv) in wrow.iter().enumerate() {
                                acc[o] = acc[o].wrapping_add(av.wrapping_mul(wv));
                            }
                        }
                    }
                    let out_row = &mut out[b * f_os..(b + 1) * f_os];
                    for o in 0..f_os {
                        let mut a = acc[o];
                        if layer.use_bias {
                            a = a.wrapping_add(tail.bias[o] as i32);
                        }
                        // 32-bit store: the SRS rounding add wraps in the
                        // accumulator width, like the hardware and jnp.int32
                        // (see ir::srs_i32) — never the 64-bit srs here.
                        let mut y = srs_i32(a, q.shift, q.output.dtype);
                        if layer.relu {
                            y = y.max(0);
                        }
                        out_row[o] = y;
                    }
                }
            } else {
                let mut acc = vec![0i64; f_os];
                for b in 0..batch {
                    let a_row = &padded[b * f_in_pad..(b + 1) * f_in_pad];
                    acc.fill(0);
                    for (c, wt) in slices.iter().enumerate() {
                        let a = &a_row[c * geo.f_in_slice..(c + 1) * geo.f_in_slice];
                        for (i, &av) in a.iter().enumerate() {
                            if av == 0 {
                                continue;
                            }
                            let av = av as i64;
                            let wrow = &wt[i * f_os..(i + 1) * f_os];
                            for (o, &wv) in wrow.iter().enumerate() {
                                acc[o] += av * wv as i64;
                            }
                        }
                    }
                    let out_row = &mut out[b * f_os..(b + 1) * f_os];
                    for o in 0..f_os {
                        let mut a = acc[o];
                        if layer.use_bias {
                            a += tail.bias[o];
                        }
                        let mut y = srs(a, q.shift, q.output.dtype);
                        if layer.relu {
                            y = y.max(0);
                        }
                        out_row[o] = y as i32;
                    }
                }
            }
            out
        }
    };
    let parallel = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) > 1;
    let out_rows: Vec<Vec<i32>> = if parallel && geo.cas_num > 1 && batch * geo.f_out_slice >= 4096 {
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..geo.cas_num)
                .map(|r| scope.spawn(move || compute_row(r)))
                .collect();
            handles.into_iter().map(|h| h.join().expect("row thread")).collect()
        })
    } else {
        (0..geo.cas_num).map(compute_row).collect()
    };

    // --- Gather cascade-row outputs, drop feature padding -----------------
    let mut data = vec![0i32; batch * f_out];
    for (r, rows) in out_rows.iter().enumerate() {
        for b in 0..batch {
            for o in 0..geo.f_out_slice {
                let go = r * geo.f_out_slice + o;
                if go < f_out {
                    data[b * f_out + go] = rows[b * geo.f_out_slice + o];
                }
            }
        }
    }
    Activation::new(batch, f_out, data)
}

/// Reference dense layer on *unpacked* logical tensors — a second,
/// independent implementation used to cross-check the packed path in tests.
pub fn reference_dense(
    input: &Activation,
    weights: &[i32], // [out][in] row-major
    bias: Option<&[i64]>,
    f_out: usize,
    shift: u32,
    out_dtype: Dtype,
    acc_dtype: Dtype,
    relu: bool,
) -> Activation {
    let f_in = input.features;
    let mut data = vec![0i32; input.batch * f_out];
    for b in 0..input.batch {
        for o in 0..f_out {
            let mut acc: i64 = 0;
            for i in 0..f_in {
                acc += input.data[b * f_in + i] as i64 * weights[o * f_in + i] as i64;
            }
            if let Some(bias) = bias {
                acc += bias[o];
            }
            // Match the store semantics exactly: 32-bit accumulators wrap
            // (including the SRS rounding add — srs_i32), the i16xi16 path
            // stays exact in i64.
            let mut y = if acc_dtype != Dtype::I64 {
                srs_i32(acc as i32, shift, out_dtype) as i64
            } else {
                srs(acc, shift, out_dtype)
            };
            if relu {
                y = y.max(0);
            }
            data[b * f_out + o] = y as i32;
        }
    }
    Activation { batch: input.batch, features: f_out, data }
}

/// Quantize a float batch at the model boundary (optional float I/O).
pub fn quantize_input(fw: &Firmware, x: &[f64], batch: usize) -> Result<Activation> {
    let q = fw.layers[0].quant.input;
    let features = fw.input_features();
    ensure!(x.len() == batch * features, "float input length");
    let data = x.iter().map(|&v| q.quantize(v) as i32).collect();
    Activation::new(batch, features, data)
}

/// Dequantize the output batch back to floats.
pub fn dequantize_output(fw: &Firmware, y: &Activation) -> Vec<f64> {
    let q = fw.layers.last().unwrap().quant.output;
    y.data.iter().map(|&v| q.dequantize(v as i64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{CompileConfig, JsonLayer, JsonModel, LayerConfig};
    use crate::passes::compile;
    use crate::util::rng::Pcg32;

    fn rng() -> Pcg32 {
        Pcg32::seed_from_u64(0x41E4)
    }

    fn build_fw(
        dims: &[usize],
        dtype: &str,
        batch: usize,
        cascade: Option<(usize, usize)>,
        seed: u64,
    ) -> (Firmware, Vec<Vec<i32>>, Vec<Vec<i64>>) {
        let mut r = Pcg32::seed_from_u64(seed);
        let (lo, hi) = Dtype::parse(dtype).unwrap().range();
        let mut all_w = Vec::new();
        let mut all_b = Vec::new();
        let layers: Vec<JsonLayer> = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| {
                let weights: Vec<i32> =
                    (0..w[0] * w[1]).map(|_| r.gen_i32_in(lo, hi)).collect();
                let bias: Vec<i64> = (0..w[1]).map(|_| r.gen_range_i64(-1000, 1000)).collect();
                all_w.push(weights.clone());
                all_b.push(bias.clone());
                JsonLayer::dense(
                    &format!("fc{}", i + 1),
                    w[0],
                    w[1],
                    true,
                    i + 2 < dims.len(),
                    dtype,
                    dtype,
                    6,
                    weights,
                    bias,
                )
            })
            .collect();
        let jm = JsonModel::new("t", layers);
        let mut cfg = CompileConfig::default();
        cfg.batch = batch;
        cfg.tiles_per_layer = Some(8);
        if let Some(cas) = cascade {
            for i in 0..dims.len() - 1 {
                cfg.layers.insert(
                    format!("fc{}", i + 1),
                    LayerConfig { cascade: Some(cas), ..Default::default() },
                );
            }
        }
        let fw = compile(&jm, cfg).unwrap().firmware.unwrap();
        (fw, all_w, all_b)
    }

    fn random_input(batch: usize, features: usize, dtype: Dtype, r: &mut Pcg32) -> Activation {
        let (lo, hi) = dtype.range();
        let data = (0..batch * features).map(|_| r.gen_i32_in(lo, hi)).collect();
        Activation::new(batch, features, data).unwrap()
    }

    #[test]
    fn packed_path_matches_reference_i8() {
        let (fw, ws, bs) = build_fw(&[64, 96, 32], "int8", 8, Some((2, 2)), 7);
        let mut r = rng();
        let x = random_input(8, 64, Dtype::I8, &mut r);
        let y = execute(&fw, &x).unwrap();
        // Independent reference path over logical tensors.
        let mut a = x.clone();
        for (i, l) in fw.layers.iter().enumerate() {
            a = reference_dense(
                &a,
                &ws[i],
                Some(&bs[i]),
                l.out_features,
                l.quant.shift,
                l.quant.output.dtype,
                l.quant.acc_dtype,
                l.relu,
            );
        }
        assert_eq!(y.data, a.data);
    }

    #[test]
    fn packed_path_matches_reference_i16() {
        let (fw, ws, bs) = build_fw(&[48, 64, 16], "int16", 4, Some((2, 2)), 11);
        let mut r = rng();
        let x = random_input(4, 48, Dtype::I16, &mut r);
        let y = execute(&fw, &x).unwrap();
        let mut a = x.clone();
        for (i, l) in fw.layers.iter().enumerate() {
            a = reference_dense(
                &a,
                &ws[i],
                Some(&bs[i]),
                l.out_features,
                l.quant.shift,
                l.quant.output.dtype,
                l.quant.acc_dtype,
                l.relu,
            );
        }
        assert_eq!(y.data, a.data);
    }

    #[test]
    fn result_independent_of_cascade_geometry() {
        // The same layer computed on 1 tile vs 2x2 vs 4x2 cascades must be
        // bit-identical — parallelization must not change semantics.
        let mut r = rng();
        let x = random_input(8, 128, Dtype::I8, &mut r);
        let (fw1, _, _) = build_fw(&[128, 64], "int8", 8, Some((1, 1)), 3);
        let (fw2, _, _) = build_fw(&[128, 64], "int8", 8, Some((2, 2)), 3);
        let (fw3, _, _) = build_fw(&[128, 64], "int8", 8, Some((4, 2)), 3);
        let y1 = execute(&fw1, &x).unwrap();
        let y2 = execute(&fw2, &x).unwrap();
        let y3 = execute(&fw3, &x).unwrap();
        assert_eq!(y1.data, y2.data);
        assert_eq!(y1.data, y3.data);
    }

    #[test]
    fn ragged_shapes_execute() {
        // Non-divisible dims exercise mem-tile zero padding end to end.
        let (fw, ws, bs) = build_fw(&[100, 70, 10], "int8", 5, Some((2, 3)), 13);
        let mut r = rng();
        let x = random_input(5, 100, Dtype::I8, &mut r);
        let y = execute(&fw, &x).unwrap();
        let mut a = x.clone();
        for (i, l) in fw.layers.iter().enumerate() {
            a = reference_dense(
                &a,
                &ws[i],
                Some(&bs[i]),
                l.out_features,
                l.quant.shift,
                l.quant.output.dtype,
                l.quant.acc_dtype,
                l.relu,
            );
        }
        assert_eq!(y.data, a.data);
    }

    #[test]
    fn relu_clamps_negative() {
        // Identity-free check: all-negative weights + relu => zero outputs.
        let jm = JsonModel::new(
            "m",
            vec![JsonLayer::dense("fc1", 32, 32, false, true, "int8", "int8", 0, vec![-1; 32 * 32], vec![])],
        );
        let mut cfg = CompileConfig::default();
        cfg.batch = 4;
        cfg.tiles_per_layer = Some(1);
        let fw = compile(&jm, cfg).unwrap().firmware.unwrap();
        let x = Activation::new(4, 32, vec![1; 4 * 32]).unwrap();
        let y = execute(&fw, &x).unwrap();
        assert!(y.data.iter().all(|&v| v == 0));
    }

    #[test]
    fn srs_saturation_reached() {
        // Max-positive weights/inputs with shift 0 must pin at +127.
        let jm = JsonModel::new(
            "m",
            vec![JsonLayer::dense("fc1", 32, 32, false, false, "int8", "int8", 0, vec![127; 32 * 32], vec![])],
        );
        let mut cfg = CompileConfig::default();
        cfg.batch = 2;
        cfg.tiles_per_layer = Some(1);
        let fw = compile(&jm, cfg).unwrap().firmware.unwrap();
        let x = Activation::new(2, 32, vec![127; 2 * 32]).unwrap();
        let y = execute(&fw, &x).unwrap();
        assert!(y.data.iter().all(|&v| v == 127));
    }

    #[test]
    fn input_range_checked() {
        let (fw, _, _) = build_fw(&[32, 16], "int8", 2, Some((1, 1)), 1);
        let x = Activation::new(2, 32, vec![300; 64]).unwrap();
        assert!(execute(&fw, &x).is_err());
    }

    #[test]
    fn float_boundary_roundtrip() {
        let (fw, _, _) = build_fw(&[32, 16], "int8", 2, Some((1, 1)), 5);
        let x: Vec<f64> = (0..64).map(|i| (i as f64 - 32.0) / 64.0).collect();
        let qa = quantize_input(&fw, &x, 2).unwrap();
        let y = execute(&fw, &qa).unwrap();
        let yf = dequantize_output(&fw, &y);
        assert_eq!(yf.len(), 2 * 16);
        assert!(yf.iter().all(|v| v.is_finite()));
    }
}
