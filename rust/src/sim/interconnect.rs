//! Stream-switch interconnect model: routing between layer outputs and the
//! next layer's memory tile.
//!
//! The AIE-ML array routes data through per-tile stream switches; a hop
//! costs one switch traversal, and links are shared, so long or overlapping
//! routes add latency and (under contention) serialize. The placement
//! objective (Eq. 2) exists precisely to shorten these routes — this module
//! makes the cost concrete so placement quality feeds the performance model
//! (and the `ablation_placement` bench can measure it).
//!
//! Routing is dimension-ordered (X then Y), the standard deadlock-free
//! scheme on mesh NoCs and a faithful stand-in for the AIE stream-switch
//! static routes the `aiecompiler` derives.

use crate::codegen::firmware::{Firmware, StageRef};
use crate::ir::PlacementRect;

/// One static route: from a producer tile through the array to a memory
/// tile column (memory tiles sit below row 0).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Route {
    /// Sequence of (col, row) tiles traversed, producer first.
    pub hops: Vec<(usize, usize)>,
}

impl Route {
    /// Dimension-ordered route from `(c0, r0)` down to the memory tile at
    /// column `mc` (X first along the producer's row, then Y down to row 0).
    pub fn dimension_ordered(c0: usize, r0: usize, mc: usize) -> Route {
        let mut hops = vec![(c0, r0)];
        let mut c = c0;
        while c != mc {
            c = if c < mc { c + 1 } else { c - 1 };
            hops.push((c, r0));
        }
        for r in (0..r0).rev() {
            hops.push((c, r));
        }
        Route { hops }
    }

    /// Switch traversals (route length minus the source).
    pub fn len(&self) -> usize {
        self.hops.len().saturating_sub(1)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Static routing of one compiled firmware: every cascade-tail tile routes
/// its output slice to each consumer's memory-tile column (a fan-out
/// producer gets one route per consumer); merge buffers forward along the
/// memory-tile row to their consumers; every memory tile broadcasts up its
/// column (vertical links).
#[derive(Debug, Clone)]
pub struct RoutingPlan {
    pub routes: Vec<Route>,
    /// Maximum number of routes crossing any single directed link.
    pub max_link_load: usize,
    /// Total switch traversals.
    pub total_hops: usize,
}

/// Build the routing plan from placements, walking the stage DAG: each
/// stage drains to the mem-tile column of every consumer stage (the output
/// plan's column when it is the network output).
pub fn route_firmware(fw: &Firmware) -> RoutingPlan {
    let mut routes = Vec::new();
    for (si, stage) in fw.stages.iter().enumerate() {
        let consumers = fw.stage_consumers(si);
        // Downstream consumers' buffer columns, plus this stage's own
        // output drain(s) — sink stages have only drains, and an interior
        // node promoted to a partition output drains *in addition to*
        // feeding its consumers.
        let mut targets: Vec<usize> = consumers
            .iter()
            .map(|&c| match fw.stages[c].op {
                StageRef::Layer(li) => fw.layers[li].input_plan.mem_col,
                StageRef::Merge(mi) => fw.merges[mi].plan.mem_col,
            })
            .collect();
        targets.extend(fw.outputs.iter().filter(|o| o.stage == si).map(|o| o.plan.mem_col));
        if targets.is_empty() {
            targets.push(fw.output_plan.mem_col);
        }
        match stage.op {
            StageRef::Layer(li) => {
                for k in &fw.layers[li].kernels {
                    if k.is_tail {
                        for &mc in &targets {
                            routes.push(Route::dimension_ordered(k.col, k.row, mc));
                        }
                    }
                }
            }
            StageRef::Merge(mi) => {
                // Mem-tile to mem-tile forwarding along the south row.
                let from = fw.merges[mi].plan.mem_col;
                for &mc in &targets {
                    routes.push(Route::dimension_ordered(from, 0, mc));
                }
            }
        }
    }
    let mut link_load = std::collections::HashMap::new();
    let mut total = 0usize;
    for r in &routes {
        total += r.len();
        for w in r.hops.windows(2) {
            *link_load.entry((w[0], w[1])).or_insert(0usize) += 1;
        }
    }
    RoutingPlan {
        routes,
        max_link_load: link_load.values().copied().max().unwrap_or(0),
        total_hops: total,
    }
}

/// Interconnect latency contribution of a placement (cycles): the longest
/// route, plus a serialization penalty on the most-contended link.
pub fn interconnect_latency_cycles(plan: &RoutingPlan, hop_cycles: usize) -> f64 {
    let longest = plan.routes.iter().map(Route::len).max().unwrap_or(0);
    (longest * hop_cycles) as f64 + plan.max_link_load.saturating_sub(1) as f64
}

/// Sum of Manhattan distances between consecutive layers' out/in columns —
/// the quantity Eq. 2 minimizes, measured on actual placements.
pub fn chain_wirelength(rects: &[PlacementRect]) -> usize {
    rects
        .windows(2)
        .map(|w| {
            w[0].output_col().abs_diff(w[1].input_col())
                + w[0].output_row().abs_diff(w[1].input_row())
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dtype;
    use crate::harness::models::compile_mlp;

    #[test]
    fn dimension_ordered_route_shape() {
        let r = Route::dimension_ordered(3, 2, 6);
        // 3 east hops + 2 south hops.
        assert_eq!(r.len(), 5);
        assert_eq!(r.hops.first(), Some(&(3, 2)));
        assert_eq!(r.hops.last(), Some(&(6, 0)));
        // X-first: row stays 2 until col reaches 6.
        assert!(r.hops.iter().take(4).all(|&(_, row)| row == 2));
    }

    #[test]
    fn route_to_own_column_is_pure_vertical() {
        let r = Route::dimension_ordered(5, 3, 5);
        assert_eq!(r.len(), 3);
        assert!(r.hops.iter().all(|&(c, _)| c == 5));
    }

    #[test]
    fn zero_length_route() {
        let r = Route::dimension_ordered(2, 0, 2);
        assert!(r.is_empty());
    }

    #[test]
    fn firmware_routing_covers_all_tails() {
        let m = compile_mlp("route", &[128, 128, 64], Dtype::I8, 8, Some((2, 4))).unwrap();
        let fw = m.firmware.as_ref().unwrap();
        let plan = route_firmware(fw);
        let tails: usize = fw
            .layers
            .iter()
            .map(|l| l.kernels.iter().filter(|k| k.is_tail).count())
            .sum();
        assert_eq!(plan.routes.len(), tails);
        assert!(plan.total_hops > 0);
        assert!(plan.max_link_load >= 1);
    }

    #[test]
    fn compact_placement_routes_shorter_than_scattered() {
        use crate::frontend::{CompileConfig, LayerConfig};
        use crate::harness::models::{mlp_spec, synth_model};
        let spec = mlp_spec(&[128, 128, 128], Dtype::I8);
        let json = synth_model("route_cmp", &spec, 6);
        // Compact: B&B placement.
        let mut cfg = CompileConfig::default();
        cfg.batch = 8;
        for l in &spec {
            cfg.layers
                .insert(l.name.clone(), LayerConfig { cascade: Some((2, 4)), ..Default::default() });
        }
        let compact = crate::passes::compile(&json, cfg.clone()).unwrap();
        // Scattered: pin the layers far apart.
        cfg.layers.get_mut("fc1").unwrap().place_at = Some((0, 0));
        cfg.layers.get_mut("fc2").unwrap().place_at = Some((30, 4));
        let scattered = crate::passes::compile(&json, cfg).unwrap();
        let hops_compact = route_firmware(compact.firmware.as_ref().unwrap()).total_hops;
        let hops_scattered = route_firmware(scattered.firmware.as_ref().unwrap()).total_hops;
        assert!(
            hops_compact < hops_scattered,
            "compact {hops_compact} !< scattered {hops_scattered}"
        );
    }

    #[test]
    fn wirelength_matches_manual() {
        use crate::ir::PlacementRect;
        let a = PlacementRect { col: 0, row: 0, width: 4, height: 2 };
        let b = PlacementRect { col: 6, row: 1, width: 2, height: 2 };
        // |out_col(a)=3 - in_col(b)=6| + |0 - 1| = 4
        assert_eq!(chain_wirelength(&[a, b]), 4);
    }

    #[test]
    fn dag_routing_covers_every_placed_edge() {
        use crate::frontend::CompileConfig;
        use crate::harness::models::residual_mlp_model;
        let json = residual_mlp_model("route_res", 64, 96, 16, 6);
        let mut cfg = CompileConfig::default();
        cfg.batch = 8;
        let m = crate::passes::compile(&json, cfg).unwrap();
        let fw = m.firmware.as_ref().unwrap();
        let plan = route_firmware(fw);
        // Every dense stage routes its tails once per consumer; the merge
        // buffer adds one forwarding route per consumer. fc2 feeds only the
        // merge, fc1 only fc2, head only the output drain — so route count
        // is all tails plus one merge route.
        let tails: usize = fw
            .layers
            .iter()
            .map(|l| l.kernels.iter().filter(|k| k.is_tail).count())
            .sum();
        assert_eq!(plan.routes.len(), tails + fw.merges.len());
    }
}
