//! Stream-switch interconnect model: routing between layer outputs and the
//! next layer's memory tile.
//!
//! The AIE-ML array routes data through per-tile stream switches; a hop
//! costs one switch traversal, and links are shared, so long or overlapping
//! routes add latency and (under contention) serialize. The placement
//! objective (Eq. 2) exists precisely to shorten these routes — this module
//! makes the cost concrete so placement quality feeds the performance model
//! (and the `ablation_placement` bench can measure it).
//!
//! Routing is dimension-ordered (X then Y), the standard deadlock-free
//! scheme on mesh NoCs and a faithful stand-in for the AIE stream-switch
//! static routes the `aiecompiler` derives.

use crate::codegen::firmware::{Firmware, StageRef};
use crate::ir::PlacementRect;
use anyhow::{ensure, Result};

/// One static route: from a producer tile through the array to a memory
/// tile column (memory tiles sit below row 0).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Route {
    /// Sequence of (col, row) tiles traversed, producer first.
    pub hops: Vec<(usize, usize)>,
}

impl Route {
    /// Dimension-ordered route from `(c0, r0)` down to the memory tile at
    /// column `mc` (X first along the producer's row, then Y down to row 0).
    pub fn dimension_ordered(c0: usize, r0: usize, mc: usize) -> Route {
        let mut hops = vec![(c0, r0)];
        let mut c = c0;
        while c != mc {
            c = if c < mc { c + 1 } else { c - 1 };
            hops.push((c, r0));
        }
        for r in (0..r0).rev() {
            hops.push((c, r));
        }
        Route { hops }
    }

    /// Switch traversals (route length minus the source).
    pub fn len(&self) -> usize {
        self.hops.len().saturating_sub(1)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Static routing of one compiled firmware: every cascade-tail tile routes
/// its output slice to each consumer's memory-tile column (a fan-out
/// producer gets one route per consumer); merge buffers forward along the
/// memory-tile row to their consumers; every memory tile broadcasts up its
/// column (vertical links).
#[derive(Debug, Clone)]
pub struct RoutingPlan {
    pub routes: Vec<Route>,
    /// Maximum number of routes crossing any single directed link.
    pub max_link_load: usize,
    /// Total switch traversals.
    pub total_hops: usize,
}

/// Build the routing plan from placements, walking the stage DAG: each
/// stage drains to the mem-tile column of every consumer stage, plus its
/// own output drain(s) — sink stages have only drains, and an interior
/// node promoted to a partition output drains *in addition to* feeding its
/// consumers. A stage with neither is a hard error: emission guarantees
/// every sink appears in [`Firmware::outputs`], and silently re-routing an
/// unmatched sink to `outputs[0]`'s column (the old fallback) sent
/// multi-sink drains to the wrong array column.
///
/// Merge stages route at buffer fidelity:
/// * a **staged** merge holds the merged row-major image and forwards it
///   along the memory-tile row into *every shard column* of each
///   consumer's input buffer — the staging copy, made explicit;
/// * an **offset-tiled** concat forwards nothing: its branches already
///   landed inside each dense consumer's read-tile buffer (whose columns
///   the producers target directly), so only its own drains route.
///
/// Granularity rule, so staged-vs-offset comparisons measure the data
/// path and not an accounting artifact: a producer's *store* costs one
/// route per (tail, destination buffer) — the landing DMA is a single
/// pass whether the buffer is the staged merge image or the consumer's
/// sharded read buffer (any intra-buffer spread rides the same pass).
/// Per-shard routes are charged only for **buffer-to-buffer copies** (the
/// staged re-tile), because that second pass re-reads the full image and
/// re-writes each shard — exactly the traffic offset tiling eliminates.
pub fn route_firmware(fw: &Firmware) -> Result<RoutingPlan> {
    let clamp = |c: usize| c.min(fw.device.mem_tiles.saturating_sub(1));
    let mut routes = Vec::new();
    for (si, stage) in fw.stages.iter().enumerate() {
        let consumers = fw.stage_consumers(si);
        let drains: Vec<usize> =
            fw.outputs.iter().filter(|o| o.stage == si).map(|o| o.plan.mem_col).collect();
        ensure!(
            !consumers.is_empty() || !drains.is_empty(),
            "stage '{}' has no consumers and no output drain — firmware outputs are incomplete",
            fw.stage_name(si)
        );
        match stage.op {
            StageRef::Layer(li) => {
                let mut targets: Vec<usize> = Vec::new();
                for &c in &consumers {
                    match fw.stages[c].op {
                        StageRef::Layer(lj) => targets.push(fw.layers[lj].input_plan.mem_col),
                        StageRef::Merge(mj) if fw.merges[mj].plan.offset_tiled() => {
                            // The branch lands straight in each dense
                            // consumer's read-tile buffer: one store per
                            // destination buffer.
                            for cc in fw.stage_consumers(c) {
                                if let StageRef::Layer(lk) = fw.stages[cc].op {
                                    targets.push(fw.layers[lk].input_plan.mem_col);
                                }
                            }
                        }
                        StageRef::Merge(mj) => targets.push(fw.merges[mj].plan.mem_col),
                    }
                }
                targets.extend(drains);
                for k in &fw.layers[li].kernels {
                    if k.is_tail {
                        for &mc in &targets {
                            routes.push(Route::dimension_ordered(k.col, k.row, mc));
                        }
                    }
                }
            }
            StageRef::Merge(mi) => {
                // Mem-tile to mem-tile forwarding along the south row.
                let m = &fw.merges[mi];
                let from = m.plan.mem_col;
                if !m.plan.offset_tiled() {
                    for &c in &consumers {
                        match fw.stages[c].op {
                            StageRef::Layer(lj) => {
                                let p = &fw.layers[lj].input_plan;
                                for s in 0..p.columns.max(1) {
                                    routes.push(Route::dimension_ordered(
                                        from,
                                        0,
                                        clamp(p.mem_col + s),
                                    ));
                                }
                            }
                            StageRef::Merge(mj) if fw.merges[mj].plan.offset_tiled() => {
                                // The downstream concat has no buffer: land
                                // in each of its dense consumers' read-tile
                                // buffers directly.
                                for cc in fw.stage_consumers(c) {
                                    if let StageRef::Layer(lk) = fw.stages[cc].op {
                                        routes.push(Route::dimension_ordered(
                                            from,
                                            0,
                                            clamp(fw.layers[lk].input_plan.mem_col),
                                        ));
                                    }
                                }
                            }
                            StageRef::Merge(mj) => routes.push(Route::dimension_ordered(
                                from,
                                0,
                                fw.merges[mj].plan.mem_col,
                            )),
                        }
                    }
                }
                for &mc in &drains {
                    routes.push(Route::dimension_ordered(from, 0, mc));
                }
            }
        }
    }
    let mut link_load = std::collections::HashMap::new();
    let mut total = 0usize;
    for r in &routes {
        total += r.len();
        for w in r.hops.windows(2) {
            *link_load.entry((w[0], w[1])).or_insert(0usize) += 1;
        }
    }
    Ok(RoutingPlan {
        routes,
        max_link_load: link_load.values().copied().max().unwrap_or(0),
        total_hops: total,
    })
}

/// Interconnect latency contribution of a placement (cycles): the longest
/// route plus a serialization penalty on the most-contended link, **both**
/// in units of `hop_cycles` — each extra route sharing the hottest link
/// stalls one switch traversal behind it. (The penalty used to be charged
/// in raw route count, so contention became negligible relative to
/// distance whenever a hop cost more than one cycle.)
pub fn interconnect_latency_cycles(plan: &RoutingPlan, hop_cycles: usize) -> f64 {
    let longest = plan.routes.iter().map(Route::len).max().unwrap_or(0);
    ((longest + plan.max_link_load.saturating_sub(1)) * hop_cycles) as f64
}

/// Sum of Manhattan distances between consecutive layers' out/in columns —
/// the quantity Eq. 2 minimizes, measured on actual placements.
pub fn chain_wirelength(rects: &[PlacementRect]) -> usize {
    rects
        .windows(2)
        .map(|w| {
            w[0].output_col().abs_diff(w[1].input_col())
                + w[0].output_row().abs_diff(w[1].input_row())
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dtype;
    use crate::harness::models::compile_mlp;

    #[test]
    fn dimension_ordered_route_shape() {
        let r = Route::dimension_ordered(3, 2, 6);
        // 3 east hops + 2 south hops.
        assert_eq!(r.len(), 5);
        assert_eq!(r.hops.first(), Some(&(3, 2)));
        assert_eq!(r.hops.last(), Some(&(6, 0)));
        // X-first: row stays 2 until col reaches 6.
        assert!(r.hops.iter().take(4).all(|&(_, row)| row == 2));
    }

    #[test]
    fn route_to_own_column_is_pure_vertical() {
        let r = Route::dimension_ordered(5, 3, 5);
        assert_eq!(r.len(), 3);
        assert!(r.hops.iter().all(|&(c, _)| c == 5));
    }

    #[test]
    fn zero_length_route() {
        let r = Route::dimension_ordered(2, 0, 2);
        assert!(r.is_empty());
    }

    #[test]
    fn firmware_routing_covers_all_tails() {
        let m = compile_mlp("route", &[128, 128, 64], Dtype::I8, 8, Some((2, 4))).unwrap();
        let fw = m.firmware.as_ref().unwrap();
        let plan = route_firmware(fw).unwrap();
        let tails: usize = fw
            .layers
            .iter()
            .map(|l| l.kernels.iter().filter(|k| k.is_tail).count())
            .sum();
        assert_eq!(plan.routes.len(), tails);
        assert!(plan.total_hops > 0);
        assert!(plan.max_link_load >= 1);
    }

    #[test]
    fn compact_placement_routes_shorter_than_scattered() {
        use crate::frontend::{CompileConfig, LayerConfig};
        use crate::harness::models::{mlp_spec, synth_model};
        let spec = mlp_spec(&[128, 128, 128], Dtype::I8);
        let json = synth_model("route_cmp", &spec, 6);
        // Compact: B&B placement.
        let mut cfg = CompileConfig::default();
        cfg.batch = 8;
        for l in &spec {
            cfg.layers
                .insert(l.name.clone(), LayerConfig { cascade: Some((2, 4)), ..Default::default() });
        }
        let compact = crate::passes::compile(&json, cfg.clone()).unwrap();
        // Scattered: pin the layers far apart.
        cfg.layers.get_mut("fc1").unwrap().place_at = Some((0, 0));
        cfg.layers.get_mut("fc2").unwrap().place_at = Some((30, 4));
        let scattered = crate::passes::compile(&json, cfg).unwrap();
        let hops_compact = route_firmware(compact.firmware.as_ref().unwrap()).unwrap().total_hops;
        let hops_scattered =
            route_firmware(scattered.firmware.as_ref().unwrap()).unwrap().total_hops;
        assert!(
            hops_compact < hops_scattered,
            "compact {hops_compact} !< scattered {hops_scattered}"
        );
    }

    #[test]
    fn wirelength_matches_manual() {
        use crate::ir::PlacementRect;
        let a = PlacementRect { col: 0, row: 0, width: 4, height: 2 };
        let b = PlacementRect { col: 6, row: 1, width: 2, height: 2 };
        // |out_col(a)=3 - in_col(b)=6| + |0 - 1| = 4
        assert_eq!(chain_wirelength(&[a, b]), 4);
    }

    #[test]
    fn dag_routing_covers_every_placed_edge() {
        use crate::frontend::CompileConfig;
        use crate::harness::models::residual_mlp_model;
        let json = residual_mlp_model("route_res", 64, 96, 16, 6);
        let mut cfg = CompileConfig::default();
        cfg.batch = 8;
        let m = crate::passes::compile(&json, cfg).unwrap();
        let fw = m.firmware.as_ref().unwrap();
        let plan = route_firmware(fw).unwrap();
        // Every dense stage routes its tails once per consumer; the staged
        // (Add) merge buffer forwards its row-major image into every shard
        // column of each consumer's input buffer. fc2 feeds only the merge,
        // fc1 only fc2, head only the output drain — so route count is all
        // tails plus the head's input-buffer shard count.
        let tails: usize = fw
            .layers
            .iter()
            .map(|l| l.kernels.iter().filter(|k| k.is_tail).count())
            .sum();
        let head = fw.layers.iter().find(|l| l.name == "head").unwrap();
        assert_eq!(plan.routes.len(), tails + head.input_plan.columns.max(1));
    }

    #[test]
    fn unmatched_sink_is_a_hard_error() {
        // A sink stage missing from `fw.outputs` used to fall back to the
        // legacy output_plan column — in multi-sink firmware that silently
        // routed a drain to outputs[0]'s array column. Now it refuses.
        let m = compile_mlp("route_err", &[64, 32], Dtype::I8, 4, Some((1, 2))).unwrap();
        let mut fw = m.firmware.clone().unwrap();
        assert!(route_firmware(&fw).is_ok());
        fw.outputs.clear();
        let err = route_firmware(&fw).unwrap_err().to_string();
        assert!(err.contains("no output drain"), "{err}");
    }

    #[test]
    fn contention_penalty_scales_with_hop_cost() {
        // Old formula: longest*hop + (load-1)*1 — contention vanished
        // relative to distance whenever a hop cost more than a cycle. New:
        // (longest + load - 1)*hop. Pin both on a hand-built plan.
        let plan = RoutingPlan {
            routes: vec![
                Route::dimension_ordered(0, 2, 3),
                Route::dimension_ordered(0, 2, 3),
                Route::dimension_ordered(0, 2, 3),
            ],
            max_link_load: 3,
            total_hops: 15,
        };
        // hop_cycles = 1: old and new agree (5 + 2).
        assert_eq!(interconnect_latency_cycles(&plan, 1), 7.0);
        // hop_cycles = 4: old was 5*4 + 2 = 22; new charges the two stalled
        // routes a full traversal each: (5 + 2) * 4 = 28.
        let old = (5 * 4 + 2) as f64;
        let new = interconnect_latency_cycles(&plan, 4);
        assert_eq!(new, 28.0);
        assert!(new > old, "contention must not shrink relative to hop cost");
    }
}
