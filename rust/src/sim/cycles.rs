//! Kernel-level cycle model (cycle-approximate, calibrated).
//!
//! We model one kernel invocation — C[chunk×f_out_slice] += A×W on one tile —
//! as: steady-state VMAC cycles from the VLIW model, plus per-accumulator-
//! block overheads (ACC_INIT in the prologue, SRS + VST epilogue feed),
//! plus fused-path extras (store/ReLU per block, BIAS_LOAD per output
//! column group), plus a fixed per-invocation cost (pipeline fill/drain,
//! lock acquire/release, pointer setup).
//!
//! The overhead constants below are **calibrated**: they are the unique
//! solution of the paper's measured single-tile efficiencies (Table II, six
//! equations) under this overhead structure — the same role the Vitis
//! cycle-accurate simulator plays for the authors. Scaling behaviour
//! (Fig. 4, Table III) then *emerges* from the model rather than being
//! fitted. See DESIGN.md §Cycle model and EXPERIMENTS.md for
//! paper-vs-measured numbers.

use crate::arch::{AieGeneration, Dtype, MmulTiling};
use crate::sim::vliw;

/// Calibration constants. One instance is shared across all benchmarks;
/// tests pin the derived Table II efficiencies.
#[derive(Debug, Clone, Copy)]
pub struct CycleModel {
    /// Fixed cycles per kernel invocation: lock handshakes on the
    /// double-buffered io_buffers, pointer setup, pipeline fill/drain.
    pub kernel_fixed: f64,
    /// Base epilogue per 2×2 accumulator block (ACC_INIT + SRS feed +
    /// overlapped store), 32-bit accumulators.
    pub block_base_acc32: f64,
    /// Same, 64-bit accumulators (two SRS passes per lane group).
    pub block_base_acc64: f64,
    /// Extra per block when the fused bias/ReLU epilogue is enabled
    /// (unoverlapped stores + ReLU clamp), 32-bit accumulators.
    pub fused_extra_acc32: f64,
    pub fused_extra_acc64: f64,
    /// BIAS_LOAD: fetch + replicate a bias tile, paid once per output
    /// column-pair per chunk (bias registers are reused down the batch).
    pub bias_col_acc32: f64,
    pub bias_col_acc64: f64,
    /// Cascade heads/mids: push accumulators to the cascade port instead of
    /// the SRS/store epilogue.
    pub head_block: f64,
    /// Multiplier on steady-state for non-native (emulated) tilings.
    pub non_native_penalty: f64,
}

impl Default for CycleModel {
    fn default() -> Self {
        // Solved from paper Table II (see module docs):
        //   i8xi8   128x128: base 95.8%, fused 81.3%
        //   i16xi8  128x128: base 98.1%, fused 89.7%
        //   i16xi16  64x64 : base 86.3%, fused 70.6%
        CycleModel {
            kernel_fixed: 26.0,
            block_base_acc32: 1.8,
            block_base_acc64: 9.3,
            fused_extra_acc32: 8.0,
            fused_extra_acc64: 12.0,
            bias_col_acc32: 16.4,
            bias_col_acc64: 18.0,
            head_block: 1.0,
            non_native_penalty: 1.8,
        }
    }
}

/// One kernel invocation's workload on a single tile.
#[derive(Debug, Clone, Copy)]
pub struct KernelWorkload {
    /// Batch rows processed in this invocation (one io_buffer chunk).
    pub batch: usize,
    pub f_in_slice: usize,
    pub f_out_slice: usize,
    pub tiling: MmulTiling,
    pub use_bias: bool,
    pub relu: bool,
    /// This tile performs the epilogue (cascade tail) — heads/mids forward
    /// raw accumulators over the cascade and skip SRS/store.
    pub is_tail: bool,
}

/// Cycle breakdown of one kernel invocation.
#[derive(Debug, Clone, Copy, Default)]
pub struct CycleBreakdown {
    pub steady: f64,
    pub block_overhead: f64,
    pub fixed: f64,
}

impl CycleBreakdown {
    pub fn total(&self) -> f64 {
        self.steady + self.block_overhead + self.fixed
    }
}

/// Number of 2×2 accumulator blocks in the output tile grid.
pub fn block_count(w: &KernelWorkload) -> usize {
    let m_tiles = w.batch.div_ceil(w.tiling.m);
    let n_tiles = w.f_out_slice.div_ceil(w.tiling.n);
    m_tiles.div_ceil(2) * n_tiles.div_ceil(2)
}

/// Output column-pair count (BIAS_LOAD granularity).
pub fn col_block_count(w: &KernelWorkload) -> usize {
    w.f_out_slice.div_ceil(w.tiling.n).div_ceil(2)
}

/// Cycles for one kernel invocation on one tile.
pub fn kernel_cycles(
    w: &KernelWorkload,
    model: &CycleModel,
    generation: AieGeneration,
    load_port_bytes: usize,
) -> CycleBreakdown {
    let m_tiles = w.batch.div_ceil(w.tiling.m);
    let k_tiles = w.f_in_slice.div_ceil(w.tiling.k);
    let n_tiles = w.f_out_slice.div_ceil(w.tiling.n);
    let tile_muls = m_tiles * k_tiles * n_tiles;

    let mut per_tile = vliw::blocked_cycles_per_tile(&w.tiling, generation, load_port_bytes);
    if !w.tiling.native {
        per_tile *= model.non_native_penalty;
    }
    let steady = tile_muls as f64 * per_tile;

    let wide = w.tiling.pair.acc_dtype() == Dtype::I64;
    let (base, fused_extra, bias_col) = if wide {
        (model.block_base_acc64, model.fused_extra_acc64, model.bias_col_acc64)
    } else {
        (model.block_base_acc32, model.fused_extra_acc32, model.bias_col_acc32)
    };
    let blocks = block_count(w) as f64;
    let block_overhead = if w.is_tail {
        let mut o = blocks * base;
        if w.use_bias || w.relu {
            o += blocks * fused_extra;
        }
        if w.use_bias {
            o += col_block_count(w) as f64 * bias_col;
        }
        o
    } else {
        blocks * model.head_block
    };

    CycleBreakdown { steady, block_overhead, fixed: model.kernel_fixed }
}

/// Cycles for a full batch on one tile: the batch is processed in io_buffer
/// chunks of `chunk` rows; each chunk is one kernel invocation.
pub fn batch_cycles(
    batch: usize,
    chunk: usize,
    w_template: &KernelWorkload,
    model: &CycleModel,
    generation: AieGeneration,
    load_port_bytes: usize,
) -> f64 {
    let chunks = batch.div_ceil(chunk.max(1));
    let mut total = 0.0;
    let mut remaining = batch;
    for _ in 0..chunks {
        let rows = remaining.min(chunk);
        remaining -= rows;
        let w = KernelWorkload { batch: rows, ..*w_template };
        total += kernel_cycles(&w, model, generation, load_port_bytes).total();
    }
    total
}

/// Sustained GOPS of one tile for a workload, at `freq_ghz`.
pub fn sustained_gops(macs: usize, cycles: f64, freq_ghz: f64) -> f64 {
    2.0 * macs as f64 * freq_ghz / cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{default_tiling, tile_peak_gops, PrecisionPair};

    fn table2_workload(pair: PrecisionPair, feat: usize, bias_relu: bool) -> KernelWorkload {
        KernelWorkload {
            batch: 128,
            f_in_slice: feat,
            f_out_slice: feat,
            tiling: default_tiling(pair).unwrap(),
            use_bias: bias_relu,
            relu: bias_relu,
            is_tail: true,
        }
    }

    fn efficiency(pair: PrecisionPair, feat: usize, bias_relu: bool) -> f64 {
        let w = table2_workload(pair, feat, bias_relu);
        // Full batch in io_buffer chunks of 32 rows (the calibration point).
        let macs = w.batch * feat * feat;
        let model = CycleModel::default();
        let cycles = batch_cycles(128, 32, &w, &model, AieGeneration::AieMl, 32);
        let gops = sustained_gops(macs, cycles, 1.25);
        gops / tile_peak_gops(AieGeneration::AieMl, pair, 1.25)
    }

    /// Paper Table II, base kernels: 95.8% / 98.1% / 86.3%.
    #[test]
    fn table2_base_efficiencies_in_band() {
        let e8 = efficiency(PrecisionPair::I8I8, 128, false);
        assert!((e8 - 0.958).abs() < 0.012, "i8xi8 base eff {e8}");
        let e168 = efficiency(PrecisionPair::I16I8, 128, false);
        assert!((e168 - 0.981).abs() < 0.012, "i16xi8 base eff {e168}");
        let e1616 = efficiency(PrecisionPair::I16I16, 64, false);
        assert!((e1616 - 0.863).abs() < 0.012, "i16xi16 base eff {e1616}");
    }

    /// Paper Table II, +Bias+ReLU: 81.3% / 89.7% / 70.6%.
    #[test]
    fn table2_fused_efficiencies_in_band() {
        let e8 = efficiency(PrecisionPair::I8I8, 128, true);
        assert!((e8 - 0.813).abs() < 0.015, "i8xi8 fused eff {e8}");
        let e168 = efficiency(PrecisionPair::I16I8, 128, true);
        assert!((e168 - 0.897).abs() < 0.015, "i16xi8 fused eff {e168}");
        let e1616 = efficiency(PrecisionPair::I16I16, 64, true);
        assert!((e1616 - 0.706).abs() < 0.015, "i16xi16 fused eff {e1616}");
    }

    #[test]
    fn fused_is_slower_than_base() {
        for (pair, feat) in [
            (PrecisionPair::I8I8, 128),
            (PrecisionPair::I16I8, 128),
            (PrecisionPair::I16I16, 64),
        ] {
            assert!(efficiency(pair, feat, true) < efficiency(pair, feat, false));
        }
    }

    #[test]
    fn cascade_heads_cheaper_than_tails() {
        let mut w = table2_workload(PrecisionPair::I8I8, 128, true);
        let model = CycleModel::default();
        let tail = kernel_cycles(&w, &model, AieGeneration::AieMl, 32).total();
        w.is_tail = false;
        let head = kernel_cycles(&w, &model, AieGeneration::AieMl, 32).total();
        assert!(head < tail);
    }

    #[test]
    fn non_native_penalized() {
        let mut w = table2_workload(PrecisionPair::I8I8, 128, false);
        let model = CycleModel::default();
        let native = kernel_cycles(&w, &model, AieGeneration::AieMl, 32).steady;
        w.tiling.native = false;
        let emulated = kernel_cycles(&w, &model, AieGeneration::AieMl, 32).steady;
        assert!(emulated > native * 1.5);
    }

    #[test]
    fn larger_batch_amortizes_overheads() {
        let model = CycleModel::default();
        let w1 = KernelWorkload { batch: 8, ..table2_workload(PrecisionPair::I8I8, 128, false) };
        let w2 = KernelWorkload { batch: 128, ..table2_workload(PrecisionPair::I8I8, 128, false) };
        let c1 = kernel_cycles(&w1, &model, AieGeneration::AieMl, 32);
        let c2 = kernel_cycles(&w2, &model, AieGeneration::AieMl, 32);
        let eff1 = c1.steady / c1.total();
        let eff2 = c2.steady / c2.total();
        assert!(eff2 > eff1);
    }

    #[test]
    fn bias_cost_scales_with_columns_not_rows() {
        // Doubling the batch (more row blocks) must not double the bias
        // overhead; doubling f_out_slice (more column groups) must.
        let model = CycleModel::default();
        let w = table2_workload(PrecisionPair::I8I8, 128, true);
        let base = kernel_cycles(&w, &model, AieGeneration::AieMl, 32);
        let w_rows = KernelWorkload { batch: 256, ..w };
        let w_cols = KernelWorkload { f_out_slice: 256, ..w };
        let rows = kernel_cycles(&w_rows, &model, AieGeneration::AieMl, 32);
        let cols = kernel_cycles(&w_cols, &model, AieGeneration::AieMl, 32);
        // Column-proportional part: isolate via col_block_count.
        assert_eq!(col_block_count(&w_rows), col_block_count(&w));
        assert_eq!(col_block_count(&w_cols), 2 * col_block_count(&w));
        assert!(rows.block_overhead < 2.0 * base.block_overhead);
        assert!(cols.block_overhead > 1.9 * base.block_overhead);
    }
}
