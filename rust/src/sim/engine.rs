//! Steady-state pipeline performance model over compiled firmware.
//!
//! Stages execute as a pipeline connected by double-buffered memory-tile
//! buffers: while stage *i* computes batch *t*, its consumers compute batch
//! *t−1* and the mem-tile DMAs move batch *t+1* (ping-pong overlap,
//! paper §III-C). The model runs over the firmware **stage DAG**: the
//! steady-state **output interval** is the slowest stage anywhere in the
//! DAG (every stage processes every batch), and **latency** is the longest
//! fill path from the network input to the output stage — a fan-in waits
//! for its slowest branch, and for a chain the longest path degenerates to
//! the sum of stage fills, exactly the old model.
//!
//! Per-dense-stage time is the max of (a) the cascade-tail kernel cycles
//! for the batch (tails do strictly more work than heads/mids), (b) input
//! DMA cycles through the memory-tile read channels, (c) output DMA cycles.
//! Merge stages are pure DMA work on the shared multi-input buffer.

use crate::arch::Device;
use crate::codegen::firmware::{Firmware, FirmwareLayer, MergeStage, StageRef, StageSource};
use crate::passes::resolve::batch_chunk;
use crate::sim::cycles::{batch_cycles, CycleModel, KernelWorkload};

/// Fixed infrastructure costs, calibrated alongside [`CycleModel`].
#[derive(Debug, Clone, Copy)]
pub struct EngineModel {
    pub kernel: CycleModel,
    /// Cycles to program + arm one mem-tile DMA transfer (descriptor fetch,
    /// lock handshake) — paid once per buffer per batch.
    pub dma_setup: usize,
    /// Cycles for one hop on the 512-bit cascade chain.
    pub cascade_hop: usize,
    /// One-time graph bring-up charged to latency (RTP weight commit,
    /// iteration start) — not to steady-state interval.
    pub graph_init: usize,
    /// Stream-switch latency for the vertical broadcast from the mem tile
    /// to a compute tile, per row climbed.
    pub broadcast_hop: usize,
    /// Ping-pong double buffering (paper §III): overlap compute with DMA.
    /// Disabled only by the `ablation_pingpong` study — stages then
    /// serialize (compute + dma_in + dma_out).
    pub ping_pong: bool,
    /// Stream-switch hop cost for inter-layer routes (placement-dependent
    /// latency via `sim::interconnect`).
    pub route_hop: usize,
}

impl Default for EngineModel {
    fn default() -> Self {
        EngineModel {
            kernel: CycleModel::default(),
            dma_setup: 120,
            cascade_hop: 2,
            graph_init: 220,
            broadcast_hop: 1,
            ping_pong: true,
            route_hop: 1,
        }
    }
}

/// Per-layer performance detail.
#[derive(Debug, Clone)]
pub struct LayerPerf {
    pub name: String,
    pub tiles: usize,
    /// Cascade-tail kernel cycles for one full batch.
    pub compute_cycles: f64,
    pub dma_in_cycles: f64,
    pub dma_out_cycles: f64,
    /// Modeled inbound DMA traffic for one batch, bytes. For a lowered conv
    /// this is the patch walk's *real* traffic — `rows × K` elements, the
    /// overlapping window taps re-read from the image — not the image size.
    pub dma_in_bytes: f64,
    /// Modeled outbound DMA traffic for one batch, bytes.
    pub dma_out_bytes: f64,
    /// max of the above — this layer's stage time.
    pub stage_cycles: f64,
    /// Fill contribution to end-to-end latency.
    pub fill_cycles: f64,
    pub bottleneck: Bottleneck,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bottleneck {
    Compute,
    DmaIn,
    DmaOut,
}

/// Whole-model performance report.
#[derive(Debug, Clone)]
pub struct PerfReport {
    pub model_name: String,
    pub batch: usize,
    pub tiles_used: usize,
    /// Steady-state cycles between consecutive full-batch outputs.
    pub interval_cycles: f64,
    /// End-to-end cycles for one batch through the empty pipeline.
    pub latency_cycles: f64,
    pub interval_us: f64,
    pub latency_us: f64,
    /// Steady-state per-sample output interval, µs (Table III metric).
    pub interval_per_sample_us: f64,
    /// Sustained throughput over the whole array, TOPS.
    pub throughput_tops: f64,
    pub layers: Vec<LayerPerf>,
}

impl PerfReport {
    pub fn bottleneck_layer(&self) -> Option<&LayerPerf> {
        self.layers
            .iter()
            .max_by(|a, b| a.stage_cycles.partial_cmp(&b.stage_cycles).unwrap())
    }
}

/// Analyze one layer.
fn layer_perf(
    layer: &FirmwareLayer,
    device: &Device,
    batch: usize,
    model: &EngineModel,
) -> LayerPerf {
    let geo = layer.cascade;
    let q = layer.quant;
    // A lowered conv processes `batch × m_scale` GEMM rows per batch; every
    // per-row figure below (kernel cycles, DMA streams) scales with the
    // row count, not the sample count.
    let rows = layer.gemm_rows(batch);
    let (chunk, _) = batch_chunk(device, &layer.tiling, &q, geo.f_in_slice, geo.f_out_slice, rows)
        .expect("emission validated local memory");

    // (a) Compute: the cascade tail is the slowest tile of each row.
    let tail = KernelWorkload {
        batch: chunk,
        f_in_slice: geo.f_in_slice,
        f_out_slice: geo.f_out_slice,
        tiling: layer.tiling,
        use_bias: layer.use_bias,
        relu: layer.relu,
        is_tail: true,
    };
    let mut compute = batch_cycles(rows, chunk, &tail, &model.kernel, device.generation, device.load_port_bytes);
    // Cascade fill: partial sums ripple CAS_LEN-1 hops once per chunk.
    let chunks = rows.div_ceil(chunk) as f64;
    compute += chunks * (geo.cas_len.saturating_sub(1) * model.cascade_hop) as f64;

    // (b) Input DMA: the activation buffer is sharded across the cascade
    // columns' memory tiles; each column's DMA streams its own slice and
    // broadcasts it up the column, so the per-column slice bounds the stage.
    // For a conv this is the patch walk's real traffic — overlapping
    // window taps are re-read from the image, so the stream is `rows × K`
    // elements even though the buffer only holds the image.
    let in_bytes = (rows * geo.f_in_slice * q.input.dtype.bytes()) as f64;
    let mut dma_in = in_bytes / device.mem_tile_port_bytes as f64 + model.dma_setup as f64;
    // Total inbound traffic across all cascade columns (reported bytes).
    let mut in_bytes_total = (rows * geo.cas_len * geo.f_in_slice * q.input.dtype.bytes()) as f64;
    let mut staging = 0.0;
    if layer.input_plan.patch.as_ref().is_some_and(|p| p.staged) {
        // Staged-im2col baseline (bench comparison only): the patch matrix
        // is materialized in the memory tile before the kernel stream
        // starts — one extra full pass of the gathered operand through the
        // port, plus another descriptor program. The pass is *serial*: the
        // operand stream reads the materialized matrix, so ping-pong
        // cannot hide the gather behind this layer's own compute.
        let staged_bytes = (rows * layer.in_features * q.input.dtype.bytes()) as f64;
        staging = staged_bytes / device.mem_tile_port_bytes as f64 + model.dma_setup as f64;
        dma_in += staging;
        in_bytes_total += staged_bytes;
    }

    // (c) Output DMA: tails of each cascade row store to the next buffer.
    let out_bytes = (rows * layer.out_features * q.output.dtype.bytes()) as f64;
    let out_channels = geo.cas_num.min(device.mem_tile_channels).max(1) as f64;
    let dma_out = out_bytes / (device.mem_tile_port_bytes as f64 * out_channels)
        + model.dma_setup as f64;

    let stage = if model.ping_pong {
        compute.max(dma_in - staging).max(dma_out) + staging
    } else {
        compute + dma_in + dma_out
    };
    let overlapped = compute.max(dma_in - staging).max(dma_out);
    let bottleneck = if staging > 0.0 && overlapped != dma_in - staging {
        // The serial gather pass is charged on top of whatever overlapped
        // term wins; any staged layer not already input-port-bound is
        // effectively paying an input-DMA tax.
        Bottleneck::DmaIn
    } else if overlapped == compute {
        Bottleneck::Compute
    } else if overlapped == dma_in - staging {
        Bottleneck::DmaIn
    } else {
        Bottleneck::DmaOut
    };

    // Fill: first chunk must traverse DMA + broadcast + compute + drain.
    let first_chunk = KernelWorkload { batch: chunk.min(rows), ..tail };
    let first_compute = batch_cycles(
        chunk.min(rows),
        chunk,
        &first_chunk,
        &model.kernel,
        device.generation,
        device.load_port_bytes,
    ) + (geo.cas_len.saturating_sub(1) * model.cascade_hop) as f64;
    let fill = dma_in / chunks.max(1.0)
        + (geo.cas_num.saturating_sub(1) * model.broadcast_hop) as f64
        + first_compute
        + model.dma_setup as f64;

    LayerPerf {
        name: layer.name.clone(),
        tiles: layer.tiles(),
        compute_cycles: compute,
        dma_in_cycles: dma_in,
        dma_out_cycles: dma_out,
        dma_in_bytes: in_bytes_total,
        dma_out_bytes: out_bytes,
        stage_cycles: stage,
        fill_cycles: fill,
        bottleneck,
    }
}

/// Analyze one merge stage: pure DMA work — every producer lands its slice
/// in the shared buffer and the merged activation streams out again. An
/// Add receives one *full-width* slice per producer (the arms overlap), so
/// inbound traffic scales with the fan-in arity; a Concat's arms partition
/// the width, so inbound equals the merged size.
///
/// An **offset-tiled** concat costs nothing here: its branches land inside
/// the consumer's input buffer during the producers' own output DMA
/// (charged at each producer's stage) and the consumer reads that buffer
/// through its own input DMA (charged at the consumer's stage) — there is
/// no staging buffer left to fill or re-stream, so the merge occupies no
/// pipeline slot and adds nothing to the fill path.
fn merge_perf(m: &MergeStage, device: &Device, batch: usize, model: &EngineModel) -> LayerPerf {
    use crate::codegen::firmware::MergeOp;
    if m.plan.offset_tiled() {
        return LayerPerf {
            name: m.name.clone(),
            tiles: 0,
            compute_cycles: 0.0,
            dma_in_cycles: 0.0,
            dma_out_cycles: 0.0,
            dma_in_bytes: 0.0,
            dma_out_bytes: 0.0,
            stage_cycles: 0.0,
            fill_cycles: 0.0,
            bottleneck: Bottleneck::DmaIn,
        };
    }
    let bytes = m.quant.dtype.bytes();
    let out_bytes = (batch * m.features * bytes) as f64;
    let in_bytes = match m.op {
        MergeOp::Add => out_bytes * m.plan.write_tilers.len() as f64,
        MergeOp::Concat => out_bytes,
        // Pooling lands the whole image, then the window walk re-reads
        // `OH·OW·KH·KW·C` taps to reduce them — both passes are real DMA
        // traffic on the memory tile.
        MergeOp::MaxPool2D(p) | MergeOp::AvgPool2D(p) => {
            let image = (batch * p.in_features() * bytes) as f64;
            let walk = (batch * p.out_h() * p.out_w() * p.kh * p.kw * p.c * bytes) as f64;
            image + walk
        }
        // Transpose lands the matrix and re-reads it once with a strided
        // descriptor — no staging copy beyond the landing buffer.
        MergeOp::Transpose { .. } => out_bytes * 2.0,
    };
    let dma_in = in_bytes / device.mem_tile_port_bytes as f64 + model.dma_setup as f64;
    let dma_out = out_bytes / device.mem_tile_port_bytes as f64 + model.dma_setup as f64;
    let stage = if model.ping_pong { dma_in.max(dma_out) } else { dma_in + dma_out };
    LayerPerf {
        name: m.name.clone(),
        tiles: 0,
        compute_cycles: 0.0,
        dma_in_cycles: dma_in,
        dma_out_cycles: dma_out,
        dma_in_bytes: in_bytes,
        dma_out_bytes: out_bytes,
        stage_cycles: stage,
        fill_cycles: dma_in,
        bottleneck: Bottleneck::DmaIn,
    }
}

/// Run the steady-state analysis over compiled firmware.
pub fn analyze(fw: &Firmware, model: &EngineModel) -> PerfReport {
    let device = &fw.device;
    let batch = fw.batch;
    // Per-stage performance in stage (topological) order — dense and merge
    // stages both occupy pipeline slots.
    let layers: Vec<LayerPerf> = fw
        .stages
        .iter()
        .map(|s| match s.op {
            StageRef::Layer(li) => layer_perf(&fw.layers[li], device, batch, model),
            StageRef::Merge(mi) => merge_perf(&fw.merges[mi], device, batch, model),
        })
        .collect();
    // Interval: the slowest stage anywhere in the DAG.
    let interval_cycles = layers.iter().map(|l| l.stage_cycles).fold(0.0, f64::max);
    // Placement-dependent interconnect latency: static routes from every
    // cascade tail to each consumer's memory tile.
    let routing = crate::sim::interconnect::route_firmware(fw)
        .expect("emitted firmware drains every sink (check_invariants)");
    let route_latency =
        crate::sim::interconnect::interconnect_latency_cycles(&routing, model.route_hop);
    // Latency: the longest fill path through the DAG (fan-in waits for its
    // slowest branch; a chain reduces to the plain sum of fills).
    let mut path = vec![0.0f64; fw.stages.len()];
    for (i, s) in fw.stages.iter().enumerate() {
        let upstream = s
            .inputs
            .iter()
            .map(|src| match src {
                StageSource::Input => 0.0,
                StageSource::Stage(j) => path[*j],
            })
            .fold(0.0, f64::max);
        path[i] = upstream + layers[i].fill_cycles;
    }
    // Single-output firmware keeps the exact historical expression (term
    // order preserved so results stay bit-identical); multi-sink firmware
    // takes the slowest (fill + drain) over its outputs — the host has the
    // full result only when the last drain lands.
    let latency_cycles = if fw.outputs.len() <= 1 {
        let fill_path = path.get(fw.output_stage).copied().unwrap_or(0.0);
        model.graph_init as f64
            + fill_path
            + route_latency
            + fw.output_plan.buffer_bytes as f64 / device.mem_tile_port_bytes as f64
            + model.dma_setup as f64
    } else {
        fw.outputs
            .iter()
            .map(|o| {
                model.graph_init as f64
                    + path.get(o.stage).copied().unwrap_or(0.0)
                    + route_latency
                    + o.plan.buffer_bytes as f64 / device.mem_tile_port_bytes as f64
                    + model.dma_setup as f64
            })
            .fold(0.0, f64::max)
    };
    let freq_hz = device.freq_ghz * 1e9;
    let interval_us = interval_cycles / freq_hz * 1e6;
    let latency_us = latency_cycles / freq_hz * 1e6;
    let ops = fw.ops_per_sample() as f64 * batch as f64;
    let throughput_tops = ops / (interval_cycles / freq_hz) / 1e12;
    PerfReport {
        model_name: fw.model_name.clone(),
        batch,
        tiles_used: fw.tiles_used(),
        interval_cycles,
        latency_cycles,
        interval_us,
        latency_us,
        interval_per_sample_us: interval_us / batch as f64,
        throughput_tops,
        layers,
    }
}

/// Throughput when the whole model graph is replicated across spare tiles
/// (paper §V-B: "when resources permit, the MLP block can be replicated
/// across the AI Engine array").
///
/// The replica count comes from the *placed* footprint
/// ([`Firmware::placement_footprint`]): each copy stamps the block's full
/// bounding box (idle tiles inside it included) and stacked copies share
/// their columns' memory tiles — not from the old
/// `placeable_tiles / tiles_used` approximation, which over-counted
/// whenever the placement left gaps or the memory tiles filled up before
/// the compute tiles did.
pub fn replicated_tops(fw: &Firmware, report: &PerfReport) -> (usize, f64) {
    let replicas = fw.placement_footprint().replicas_on(&fw.device);
    (replicas, report.throughput_tops * replicas as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{CompileConfig, JsonModel, LayerConfig};
    use crate::passes::compile;

    fn fw(dims: &[usize], batch: usize, cascade: Option<(usize, usize)>) -> Firmware {
        use crate::frontend::JsonLayer;
        let layers: Vec<JsonLayer> = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| {
                JsonLayer::dense(
                    &format!("fc{}", i + 1),
                    w[0],
                    w[1],
                    true,
                    true,
                    "int8",
                    "int8",
                    6,
                    vec![1; w[0] * w[1]],
                    vec![0i64; w[1]],
                )
            })
            .collect();
        let jm = JsonModel::new("perf", layers);
        let mut cfg = CompileConfig::default();
        cfg.batch = batch;
        if let Some(c) = cascade {
            for i in 0..dims.len() - 1 {
                cfg.layers.insert(
                    format!("fc{}", i + 1),
                    LayerConfig { cascade: Some(c), ..Default::default() },
                );
            }
        } else {
            cfg.tiles_per_layer = Some(16);
        }
        compile(&jm, cfg).unwrap().firmware.unwrap()
    }

    #[test]
    fn report_consistent() {
        let f = fw(&[512, 512, 512], 128, None);
        let r = analyze(&f, &EngineModel::default());
        assert!(r.interval_cycles > 0.0);
        assert!(r.latency_cycles > r.interval_cycles * 0.5);
        assert_eq!(r.layers.len(), 2);
        assert!(r.throughput_tops > 0.0);
        let max_stage = r.layers.iter().map(|l| l.stage_cycles).fold(0.0, f64::max);
        assert_eq!(r.interval_cycles, max_stage);
    }

    #[test]
    fn more_tiles_means_faster() {
        let small = fw(&[512, 512], 128, Some((4, 4)));
        let big = fw(&[512, 512], 128, Some((8, 8)));
        let rs = analyze(&small, &EngineModel::default());
        let rb = analyze(&big, &EngineModel::default());
        assert!(rb.interval_cycles < rs.interval_cycles);
        assert!(rb.throughput_tops > rs.throughput_tops);
    }

    #[test]
    fn compute_bound_at_large_slices() {
        let f = fw(&[512, 512], 128, Some((4, 4)));
        let r = analyze(&f, &EngineModel::default());
        assert_eq!(r.layers[0].bottleneck, Bottleneck::Compute);
    }

    #[test]
    fn micro_batch_latency_sub_two_microseconds() {
        // Paper Table II: i8 base kernel latency 0.5 µs at B=8, 4x4 cascade,
        // 128x128 workload. Cycle-approximate: assert the right regime.
        let f = fw(&[128, 128], 8, Some((4, 4)));
        let r = analyze(&f, &EngineModel::default());
        assert!(r.latency_us < 2.0, "latency {} µs", r.latency_us);
        assert!(r.latency_us > 0.1, "latency {} µs", r.latency_us);
    }

    #[test]
    fn replication_multiplies_throughput() {
        let f = fw(&[128, 128], 128, Some((2, 2)));
        let r = analyze(&f, &EngineModel::default());
        let (reps, tops) = replicated_tops(&f, &r);
        assert!(reps >= 2);
        assert!((tops / r.throughput_tops - reps as f64).abs() < 1e-9);
    }

    #[test]
    fn replication_counts_footprints_not_tiles() {
        // The old estimate divided placeable tiles by tiles_used; the new
        // one stamps the placed bounding box (with its mem-tile residency)
        // across the array. Pin both values and their divergence: a replica
        // costs the whole box, so the footprint count is strictly below the
        // tile-count estimate whenever the box spans don't divide the array
        // evenly or the memory tiles saturate first.
        let f = fw(&[128, 128], 128, Some((2, 2)));
        let r = analyze(&f, &EngineModel::default());
        let old_estimate = (f.device.placeable_tiles() / f.tiles_used().max(1)).max(1);
        let (new_estimate, _) = replicated_tops(&f, &r);
        let fp = f.placement_footprint();
        // The footprint covers both placed 2x2 layers and at least their
        // 8 compute tiles.
        assert!(fp.tiles() >= f.tiles_used(), "bbox {} < tiles {}", fp.tiles(), f.tiles_used());
        assert!(fp.mem_bytes_per_col > 0);
        // New count is exactly what the footprint says fits on the device…
        assert_eq!(new_estimate, fp.replicas_on(&f.device));
        // …and the naive tile-count estimate provably over-counted.
        assert_eq!(old_estimate, 37, "2 layers x 4 tiles on 296 placeable tiles");
        assert!(
            new_estimate < old_estimate,
            "footprint estimate {new_estimate} must diverge below tile estimate {old_estimate}"
        );
        assert!(new_estimate >= 2, "a 2-layer 2x2 block still replicates many times");
    }

    #[test]
    fn dag_interval_is_max_stage_and_latency_is_longest_path() {
        use crate::harness::models::residual_mlp_model;
        let json = residual_mlp_model("perf_res", 128, 256, 32, 6);
        let mut cfg = CompileConfig::default();
        cfg.batch = 32;
        let f = compile(&json, cfg).unwrap().firmware.unwrap();
        assert!(!f.merges.is_empty());
        let r = analyze(&f, &EngineModel::default());
        // One perf row per stage: 3 dense + 1 merge.
        assert_eq!(r.layers.len(), f.stages.len());
        let max_stage = r.layers.iter().map(|l| l.stage_cycles).fold(0.0, f64::max);
        assert_eq!(r.interval_cycles, max_stage);
        // The longest fill path runs input->fc1->fc2->res->head: it must be
        // at least the fill of that chain's slowest member and at most the
        // sum of all fills.
        let total: f64 = r.layers.iter().map(|l| l.fill_cycles).sum();
        assert!(r.latency_cycles > 0.0);
        let graph_overhead = EngineModel::default().graph_init as f64;
        assert!(r.latency_cycles >= graph_overhead);
        assert!(
            r.latency_cycles
                <= graph_overhead
                    + total
                    + 1e6 // routing + drain slack
        );
        // The merge stage reports as DMA work with no tiles.
        let merge_row = r.layers.iter().find(|l| l.name == "res").unwrap();
        assert_eq!(merge_row.tiles, 0);
        assert_eq!(merge_row.bottleneck, Bottleneck::DmaIn);
    }

    #[test]
    fn parallel_branches_fill_concurrently() {
        // A diamond's two branches fill in parallel: latency tracks the
        // slower branch, not the sum of both. Compare against a chain with
        // the same stages laid end to end.
        use crate::harness::models::diamond_mlp_model;
        let json = diamond_mlp_model("perf_diamond", 128, 128, 32, 6);
        let mut cfg = CompileConfig::default();
        cfg.batch = 16;
        let f = compile(&json, cfg).unwrap().firmware.unwrap();
        let r = analyze(&f, &EngineModel::default());
        let fills: std::collections::HashMap<&str, f64> =
            r.layers.iter().map(|l| (l.name.as_str(), l.fill_cycles)).collect();
        let chain_sum: f64 = r.layers.iter().map(|l| l.fill_cycles).sum();
        // Longest path excludes the faster of the two branches.
        let branch_min = fills["a"].min(fills["b"]);
        let overhead = r.latency_cycles
            - (chain_sum - branch_min)
            - EngineModel::default().graph_init as f64;
        // Remaining terms (routing + output drain + dma setup) are positive
        // and small relative to compute.
        assert!(overhead > 0.0, "latency must include routing/drain overhead");
        assert!(r.latency_cycles < EngineModel::default().graph_init as f64 + chain_sum + 1e6);
    }
}
