//! Memory-tile DMA "tiler" model (AM020 — Versal AI Engine-ML Memory Tile).
//!
//! AIE-ML memory tiles move data with DMA engines programmed by tiling
//! parameters: (i) the **buffer dimension** — the full logical extent of the
//! stored buffer, (ii) the **tiling dimension** — the inner block shape of
//! each transfer, and (iii) the **tile traversal** — stride and wrap per
//! dimension. The DMA injects **zeros** when accessing data outside the
//! defined buffer boundary (built-in zero padding), which AIE4ML exploits to
//! connect arbitrary layer shapes (paper §III-B, §III-C).
//!
//! Two layers of model live here:
//! * [`AddressGenerator`] — the raw stride/wrap nested-loop walker, exactly
//!   the hardware's D0/D1/D2 descriptors, over a linear buffer.
//! * [`Tiler2d`] — a coordinate-aware 2D tiler (row/col blocks over a
//!   row-major matrix) with out-of-bounds zero padding; this is what the
//!   packing pass and the memory-tile re-tiling plan use.


/// One traversal dimension: `wrap` iterations advancing `stride` elements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DimStep {
    pub stride: isize,
    pub wrap: usize,
}

/// Nested-loop address generator over a linear buffer: dims\[0\] is the
/// outermost loop, the last dim is innermost — mirroring the memory-tile
/// DMA buffer-descriptor fields.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AddressGenerator {
    pub base: isize,
    pub dims: Vec<DimStep>,
}

impl AddressGenerator {
    pub fn new(base: isize, dims: Vec<DimStep>) -> Self {
        AddressGenerator { base, dims }
    }

    /// Total number of addresses generated.
    pub fn len(&self) -> usize {
        self.dims.iter().map(|d| d.wrap).product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Generate the full address sequence.
    pub fn addresses(&self) -> Vec<isize> {
        let mut out = Vec::with_capacity(self.len());
        let mut idx = vec![0usize; self.dims.len()];
        if self.dims.iter().any(|d| d.wrap == 0) {
            return out;
        }
        loop {
            let addr = self.base
                + idx
                    .iter()
                    .zip(&self.dims)
                    .map(|(&i, d)| i as isize * d.stride)
                    .sum::<isize>();
            out.push(addr);
            // increment innermost-first
            let mut carry = true;
            for d in (0..self.dims.len()).rev() {
                if !carry {
                    break;
                }
                idx[d] += 1;
                if idx[d] == self.dims[d].wrap {
                    idx[d] = 0;
                } else {
                    carry = false;
                }
            }
            if carry {
                break;
            }
        }
        out
    }

    /// Gather elements from `buf` following the address sequence; addresses
    /// outside `[0, buf.len())` produce zeros (hardware zero padding).
    pub fn gather(&self, buf: &[i32]) -> Vec<i32> {
        self.addresses()
            .into_iter()
            .map(|a| {
                if a >= 0 && (a as usize) < buf.len() {
                    buf[a as usize]
                } else {
                    0
                }
            })
            .collect()
    }

    /// Scatter `data` into `buf` following the address sequence; OOB writes
    /// are dropped (the hardware masks them).
    pub fn scatter(&self, buf: &mut [i32], data: &[i32]) {
        for (a, &v) in self.addresses().into_iter().zip(data) {
            if a >= 0 && (a as usize) < buf.len() {
                buf[a as usize] = v;
            }
        }
    }
}

/// Coordinate-aware 2D tiler over a row-major `rows × cols` matrix:
/// emits `tile_rows × tile_cols` blocks in row-major block order, elements
/// row-major within each block. Reads outside the matrix produce zeros, so
/// the *padded* logical extent is `ceil(rows/tr)·tr × ceil(cols/tc)·tc`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tiler2d {
    pub rows: usize,
    pub cols: usize,
    pub tile_rows: usize,
    pub tile_cols: usize,
}

impl Tiler2d {
    pub fn new(rows: usize, cols: usize, tile_rows: usize, tile_cols: usize) -> Self {
        assert!(tile_rows > 0 && tile_cols > 0, "degenerate tile shape");
        Tiler2d { rows, cols, tile_rows, tile_cols }
    }

    /// Number of row blocks after padding.
    pub fn row_blocks(&self) -> usize {
        self.rows.div_ceil(self.tile_rows)
    }

    /// Number of column blocks after padding.
    pub fn col_blocks(&self) -> usize {
        self.cols.div_ceil(self.tile_cols)
    }

    /// Padded matrix extent.
    pub fn padded(&self) -> (usize, usize) {
        (self.row_blocks() * self.tile_rows, self.col_blocks() * self.tile_cols)
    }

    /// Length of the tiled stream.
    pub fn stream_len(&self) -> usize {
        let (pr, pc) = self.padded();
        pr * pc
    }

    /// Read `matrix` (row-major, rows×cols) into tile-major order with zero
    /// padding: the exact stream the memory tile feeds an `aie::mmul` kernel.
    pub fn tile(&self, matrix: &[i32]) -> Vec<i32> {
        debug_assert_eq!(matrix.len(), self.rows * self.cols);
        let mut out = Vec::with_capacity(self.stream_len());
        for br in 0..self.row_blocks() {
            for bc in 0..self.col_blocks() {
                let c0 = bc * self.tile_cols;
                for r in 0..self.tile_rows {
                    let rr = br * self.tile_rows + r;
                    if rr >= self.rows || c0 >= self.cols {
                        // Fully padded tile row.
                        out.resize(out.len() + self.tile_cols, 0);
                        continue;
                    }
                    // Interior: bulk row-segment copy; tail columns padded.
                    let valid = self.tile_cols.min(self.cols - c0);
                    let base = rr * self.cols + c0;
                    out.extend_from_slice(&matrix[base..base + valid]);
                    out.resize(out.len() + (self.tile_cols - valid), 0);
                }
            }
        }
        out
    }

    /// Inverse of [`tile`]: write a tile-major stream back into row-major
    /// form, dropping the zero padding.
    pub fn untile(&self, stream: &[i32]) -> Vec<i32> {
        debug_assert_eq!(stream.len(), self.stream_len());
        let mut out = vec![0i32; self.rows * self.cols];
        let mut it = stream.iter();
        for br in 0..self.row_blocks() {
            for bc in 0..self.col_blocks() {
                for r in 0..self.tile_rows {
                    for c in 0..self.tile_cols {
                        let v = *it.next().unwrap();
                        let rr = br * self.tile_rows + r;
                        let cc = bc * self.tile_cols + c;
                        if rr < self.rows && cc < self.cols {
                            out[rr * self.cols + cc] = v;
                        }
                    }
                }
            }
        }
        out
    }

    /// Lower this tiler to the raw stride/wrap descriptor (only valid when
    /// the matrix divides evenly — the hardware handles padding by boundary
    /// checks, which the coordinate form models directly).
    pub fn to_address_generator(&self) -> Option<AddressGenerator> {
        if self.rows % self.tile_rows != 0 || self.cols % self.tile_cols != 0 {
            return None;
        }
        Some(AddressGenerator::new(
            0,
            vec![
                DimStep { stride: (self.tile_rows * self.cols) as isize, wrap: self.row_blocks() },
                DimStep { stride: self.tile_cols as isize, wrap: self.col_blocks() },
                DimStep { stride: self.cols as isize, wrap: self.tile_rows },
                DimStep { stride: 1, wrap: self.tile_cols },
            ],
        ))
    }
}

/// A re-tiling between two layouts through a memory tile: producer writes in
/// `write` tile order, consumer reads in `read` tile order. Models the
/// independent write/read tilers of one memory-tile buffer (paper §III-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Retiler {
    pub write: Tiler2d,
    pub read: Tiler2d,
}

impl Retiler {
    /// Pass a producer-tiled stream through the buffer and out in consumer
    /// tile order. The logical matrix shape must agree.
    pub fn retile(&self, producer_stream: &[i32]) -> Vec<i32> {
        debug_assert_eq!(self.write.rows, self.read.rows);
        debug_assert_eq!(self.write.cols, self.read.cols);
        let linear = self.write.untile(producer_stream);
        self.read.tile(&linear)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn address_generator_contiguous() {
        let ag = AddressGenerator::new(0, vec![DimStep { stride: 1, wrap: 6 }]);
        assert_eq!(ag.addresses(), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(ag.len(), 6);
    }

    #[test]
    fn address_generator_strided_2d() {
        // 2 rows of 3, column-major read of a row-major 2x3 buffer.
        let ag = AddressGenerator::new(
            0,
            vec![DimStep { stride: 1, wrap: 3 }, DimStep { stride: 3, wrap: 2 }],
        );
        assert_eq!(ag.addresses(), vec![0, 3, 1, 4, 2, 5]);
    }

    #[test]
    fn address_generator_zero_pads_oob() {
        let ag = AddressGenerator::new(4, vec![DimStep { stride: 1, wrap: 4 }]);
        let buf = vec![1, 2, 3, 4, 5, 6];
        assert_eq!(ag.gather(&buf), vec![5, 6, 0, 0]);
    }

    #[test]
    fn tiler_roundtrip_exact() {
        let t = Tiler2d::new(4, 6, 2, 3);
        let m: Vec<i32> = (0..24).collect();
        let stream = t.tile(&m);
        assert_eq!(stream.len(), 24);
        assert_eq!(t.untile(&stream), m);
        // First tile is the top-left 2x3 block.
        assert_eq!(&stream[..6], &[0, 1, 2, 6, 7, 8]);
    }

    #[test]
    fn tiler_zero_pads() {
        // 3x5 matrix in 2x4 tiles -> padded to 4x8.
        let t = Tiler2d::new(3, 5, 2, 4);
        let m: Vec<i32> = (1..=15).collect();
        let stream = t.tile(&m);
        assert_eq!(stream.len(), 4 * 8);
        // Round-trip drops the padding.
        assert_eq!(t.untile(&stream), m);
        // Padding positions are zero: element (row 3, col 0) is OOB.
        let padded_rows = 4;
        let padded_cols = 8;
        assert_eq!(t.padded(), (padded_rows, padded_cols));
        // Tile (1,0) covers rows 2..4; its second row is all zeros.
        let tile10_start = (1 * t.col_blocks() + 0) * 8;
        assert_eq!(&stream[tile10_start + 4..tile10_start + 8], &[0, 0, 0, 0]);
    }

    #[test]
    fn tiler_matches_address_generator_when_divisible() {
        let t = Tiler2d::new(4, 8, 2, 4);
        let m: Vec<i32> = (0..32).collect();
        let ag = t.to_address_generator().unwrap();
        assert_eq!(ag.gather(&m), t.tile(&m));
    }

    #[test]
    fn address_generator_unavailable_when_padding_needed() {
        assert!(Tiler2d::new(3, 5, 2, 4).to_address_generator().is_none());
    }

    #[test]
    fn retile_between_layouts() {
        // Producer writes 2x2 tiles, consumer reads 1x4 tiles (layer_i
        // {M_i,N_i} -> layer_{i+1} {M_{i+1},K_{i+1}} re-tiling).
        let w = Tiler2d::new(4, 4, 2, 2);
        let r = Tiler2d::new(4, 4, 1, 4);
        let m: Vec<i32> = (0..16).collect();
        let produced = w.tile(&m);
        let retiled = Retiler { write: w, read: r }.retile(&produced);
        assert_eq!(retiled, r.tile(&m));
        // 1x4 tiles of a 4x4 row-major matrix are just its rows.
        assert_eq!(retiled, m);
    }

    #[test]
    fn scatter_gather_inverse() {
        let ag = AddressGenerator::new(
            0,
            vec![DimStep { stride: 4, wrap: 3 }, DimStep { stride: 1, wrap: 4 }],
        );
        let data: Vec<i32> = (100..112).collect();
        let mut buf = vec![0i32; 12];
        ag.scatter(&mut buf, &data);
        assert_eq!(ag.gather(&buf), data);
    }
}
