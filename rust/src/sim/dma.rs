//! Memory-tile DMA "tiler" model (AM020 — Versal AI Engine-ML Memory Tile).
//!
//! AIE-ML memory tiles move data with DMA engines programmed by tiling
//! parameters: (i) the **buffer dimension** — the full logical extent of the
//! stored buffer, (ii) the **tiling dimension** — the inner block shape of
//! each transfer, and (iii) the **tile traversal** — stride and wrap per
//! dimension. The DMA injects **zeros** when accessing data outside the
//! defined buffer boundary (built-in zero padding), which AIE4ML exploits to
//! connect arbitrary layer shapes (paper §III-B, §III-C).
//!
//! Two layers of model live here:
//! * [`AddressGenerator`] — the raw stride/wrap nested-loop walker, exactly
//!   the hardware's D0/D1/D2 descriptors, over a linear buffer.
//! * [`Tiler2d`] — a coordinate-aware 2D tiler (row/col blocks over a
//!   row-major matrix) with out-of-bounds zero padding; this is what the
//!   packing pass and the memory-tile re-tiling plan use.


/// One traversal dimension: `wrap` iterations advancing `stride` elements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DimStep {
    pub stride: isize,
    pub wrap: usize,
}

/// Nested-loop address generator over a linear buffer: dims\[0\] is the
/// outermost loop, the last dim is innermost — mirroring the memory-tile
/// DMA buffer-descriptor fields.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AddressGenerator {
    pub base: isize,
    pub dims: Vec<DimStep>,
}

impl AddressGenerator {
    pub fn new(base: isize, dims: Vec<DimStep>) -> Self {
        AddressGenerator { base, dims }
    }

    /// Total number of addresses generated.
    pub fn len(&self) -> usize {
        self.dims.iter().map(|d| d.wrap).product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Generate the full address sequence.
    pub fn addresses(&self) -> Vec<isize> {
        let mut out = Vec::with_capacity(self.len());
        let mut idx = vec![0usize; self.dims.len()];
        if self.dims.iter().any(|d| d.wrap == 0) {
            return out;
        }
        loop {
            let addr = self.base
                + idx
                    .iter()
                    .zip(&self.dims)
                    .map(|(&i, d)| i as isize * d.stride)
                    .sum::<isize>();
            out.push(addr);
            // increment innermost-first
            let mut carry = true;
            for d in (0..self.dims.len()).rev() {
                if !carry {
                    break;
                }
                idx[d] += 1;
                if idx[d] == self.dims[d].wrap {
                    idx[d] = 0;
                } else {
                    carry = false;
                }
            }
            if carry {
                break;
            }
        }
        out
    }

    /// Gather elements from `buf` following the address sequence; addresses
    /// outside `[0, buf.len())` produce zeros (hardware zero padding).
    pub fn gather(&self, buf: &[i32]) -> Vec<i32> {
        self.addresses()
            .into_iter()
            .map(|a| {
                if a >= 0 && (a as usize) < buf.len() {
                    buf[a as usize]
                } else {
                    0
                }
            })
            .collect()
    }

    /// Scatter `data` into `buf` following the address sequence; OOB writes
    /// are dropped (the hardware masks them).
    pub fn scatter(&self, buf: &mut [i32], data: &[i32]) {
        for (a, &v) in self.addresses().into_iter().zip(data) {
            if a >= 0 && (a as usize) < buf.len() {
                buf[a as usize] = v;
            }
        }
    }
}

/// Coordinate-aware 2D tiler over a row-major `rows × cols` matrix:
/// emits `tile_rows × tile_cols` blocks in row-major block order, elements
/// row-major within each block. Reads outside the matrix produce zeros, so
/// the *padded* logical extent is `ceil(rows/tr)·tr × ceil(cols/tc)·tc`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tiler2d {
    pub rows: usize,
    pub cols: usize,
    pub tile_rows: usize,
    pub tile_cols: usize,
}

impl Tiler2d {
    pub fn new(rows: usize, cols: usize, tile_rows: usize, tile_cols: usize) -> Self {
        assert!(tile_rows > 0 && tile_cols > 0, "degenerate tile shape");
        Tiler2d { rows, cols, tile_rows, tile_cols }
    }

    /// Number of row blocks after padding.
    pub fn row_blocks(&self) -> usize {
        self.rows.div_ceil(self.tile_rows)
    }

    /// Number of column blocks after padding.
    pub fn col_blocks(&self) -> usize {
        self.cols.div_ceil(self.tile_cols)
    }

    /// Padded matrix extent.
    pub fn padded(&self) -> (usize, usize) {
        (self.row_blocks() * self.tile_rows, self.col_blocks() * self.tile_cols)
    }

    /// Length of the tiled stream.
    pub fn stream_len(&self) -> usize {
        let (pr, pc) = self.padded();
        pr * pc
    }

    /// Read `matrix` (row-major, rows×cols) into tile-major order with zero
    /// padding: the exact stream the memory tile feeds an `aie::mmul` kernel.
    pub fn tile(&self, matrix: &[i32]) -> Vec<i32> {
        debug_assert_eq!(matrix.len(), self.rows * self.cols);
        let mut out = Vec::with_capacity(self.stream_len());
        for br in 0..self.row_blocks() {
            for bc in 0..self.col_blocks() {
                let c0 = bc * self.tile_cols;
                for r in 0..self.tile_rows {
                    let rr = br * self.tile_rows + r;
                    if rr >= self.rows || c0 >= self.cols {
                        // Fully padded tile row.
                        out.resize(out.len() + self.tile_cols, 0);
                        continue;
                    }
                    // Interior: bulk row-segment copy; tail columns padded.
                    let valid = self.tile_cols.min(self.cols - c0);
                    let base = rr * self.cols + c0;
                    out.extend_from_slice(&matrix[base..base + valid]);
                    out.resize(out.len() + (self.tile_cols - valid), 0);
                }
            }
        }
        out
    }

    /// Inverse of [`tile`]: write a tile-major stream back into row-major
    /// form, dropping the zero padding.
    pub fn untile(&self, stream: &[i32]) -> Vec<i32> {
        debug_assert_eq!(stream.len(), self.stream_len());
        let mut out = vec![0i32; self.rows * self.cols];
        let mut it = stream.iter();
        for br in 0..self.row_blocks() {
            for bc in 0..self.col_blocks() {
                for r in 0..self.tile_rows {
                    for c in 0..self.tile_cols {
                        let v = *it.next().unwrap();
                        let rr = br * self.tile_rows + r;
                        let cc = bc * self.tile_cols + c;
                        if rr < self.rows && cc < self.cols {
                            out[rr * self.cols + cc] = v;
                        }
                    }
                }
            }
        }
        out
    }

    /// Lower this tiler to the raw stride/wrap descriptor (only valid when
    /// the matrix divides evenly — the hardware handles padding by boundary
    /// checks, which the coordinate form models directly).
    pub fn to_address_generator(&self) -> Option<AddressGenerator> {
        if self.rows % self.tile_rows != 0 || self.cols % self.tile_cols != 0 {
            return None;
        }
        Some(AddressGenerator::new(
            0,
            vec![
                DimStep { stride: (self.tile_rows * self.cols) as isize, wrap: self.row_blocks() },
                DimStep { stride: self.tile_cols as isize, wrap: self.col_blocks() },
                DimStep { stride: self.cols as isize, wrap: self.tile_rows },
                DimStep { stride: 1, wrap: self.tile_cols },
            ],
        ))
    }
}

/// An **offset tiler**: lands one producer branch directly inside a
/// consumer's {M, K} read-tile buffer at a feature (column) offset,
/// instead of staging the merged activation row-major and re-tiling it.
///
/// This is the memory-tile tiling-parameter scheme of the paper applied to
/// fan-in: a `Concat` consumer's input buffer is one logical
/// `batch × stride` matrix read in `{tile_m, tile_k}` blocks; each branch
/// of the concat owns the column band `[offset, offset + branch_width)`
/// and its producer's DMA descriptor walks exactly the blocks of that band
/// — so the merged activation materializes in the consumer's read layout
/// without ever existing row-major. The same descriptor shape lets an
/// inter-partition link land an activation straight into the downstream
/// array's read tiles (`offset = 0`, `stride = features`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OffsetTiler {
    /// First column of the band this branch writes.
    pub offset: usize,
    /// Full row width of the consumer's buffer (the merged feature count).
    pub stride: usize,
    /// Consumer read-tile rows (its mmul M).
    pub tile_m: usize,
    /// Consumer read-tile columns (its mmul K).
    pub tile_k: usize,
}

impl OffsetTiler {
    pub fn new(offset: usize, stride: usize, tile_m: usize, tile_k: usize) -> Self {
        assert!(tile_m > 0 && tile_k > 0, "degenerate tile shape");
        OffsetTiler { offset, stride, tile_m, tile_k }
    }

    /// Scatter a row-major branch activation (`batch × features`) into the
    /// consumer's row-major image (`batch × stride`) at the feature offset,
    /// visiting elements in the consumer's `{tile_m, tile_k}` traversal
    /// restricted to the branch's column band — the exact descriptor order
    /// the memory-tile DMA executes. The visit order is a permutation of
    /// the band, so the landed image equals a plain columnwise copy; the
    /// walk is modeled for DMA-descriptor fidelity.
    pub fn scatter(&self, batch: usize, features: usize, branch: &[i32], dest: &mut [i32]) {
        debug_assert_eq!(branch.len(), batch * features);
        debug_assert_eq!(dest.len(), batch * self.stride);
        debug_assert!(self.offset + features <= self.stride, "band exceeds buffer row");
        if features == 0 || batch == 0 {
            return;
        }
        let col_lo = self.offset;
        let col_hi = self.offset + features;
        let first_block = col_lo / self.tile_k;
        let last_block = (col_hi - 1) / self.tile_k;
        for br in 0..batch.div_ceil(self.tile_m) {
            for bc in first_block..=last_block {
                for r in 0..self.tile_m {
                    let row = br * self.tile_m + r;
                    if row >= batch {
                        continue;
                    }
                    let c0 = (bc * self.tile_k).max(col_lo);
                    let c1 = ((bc + 1) * self.tile_k).min(col_hi);
                    if c0 >= c1 {
                        continue;
                    }
                    let src = row * features + (c0 - col_lo);
                    let dst = row * self.stride + c0;
                    dest[dst..dst + (c1 - c0)].copy_from_slice(&branch[src..src + (c1 - c0)]);
                }
            }
        }
    }

    /// Read the branch's band back out of the consumer image (row-major) —
    /// the inverse of [`scatter`](OffsetTiler::scatter) over the band.
    pub fn gather(&self, batch: usize, features: usize, image: &[i32]) -> Vec<i32> {
        debug_assert_eq!(image.len(), batch * self.stride);
        debug_assert!(self.offset + features <= self.stride);
        let mut out = vec![0i32; batch * features];
        for b in 0..batch {
            let src = b * self.stride + self.offset;
            out[b * features..(b + 1) * features].copy_from_slice(&image[src..src + features]);
        }
        out
    }
}

/// A **convolution patch tiler**: streams the implicit-GEMM (im2col) operand
/// of a `Conv2D` directly out of the stored NHWC image buffer.
///
/// The memory tile holds only the image (`batch × in_h·in_w·in_c` elements);
/// the read-side DMA descriptor walks the consumer's `{tile_m, tile_k}`
/// blocks of the *logical* `(batch·out_h·out_w) × (kh·kw·in_c)` patch matrix,
/// translating each (row, col) coordinate to an image address on the fly and
/// injecting zeros for 'same'-padding taps and K-padding columns (the
/// hardware's built-in out-of-bounds zero fill, exactly as [`Tiler2d`] models
/// it for plain matrices). The im2col matrix therefore never exists in
/// memory — this is the conv analogue of [`OffsetTiler`] killing the staged
/// concat copy.
///
/// `staged` is a pure modeling flag: when set, the cycle model charges the
/// buffer and DMA cost of a materialized im2col staging copy instead (the
/// baseline the `conv_lowering` bench compares against). Functional
/// behaviour is identical either way.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvPatchTiler {
    pub in_h: usize,
    pub in_w: usize,
    pub in_c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride_h: usize,
    pub stride_w: usize,
    pub pad_top: usize,
    pub pad_left: usize,
    pub out_h: usize,
    pub out_w: usize,
    /// Consumer read-tile rows (the lowered GEMM's mmul M).
    pub tile_m: usize,
    /// Consumer read-tile columns (the lowered GEMM's mmul K).
    pub tile_k: usize,
    /// Model a materialized im2col staging buffer (bench baseline only).
    pub staged: bool,
}

impl ConvPatchTiler {
    /// Logical K of the patch matrix: one flattened `kh × kw × in_c` window.
    pub fn patch_len(&self) -> usize {
        self.kh * self.kw * self.in_c
    }

    /// Stored image row width (features per sample actually resident).
    pub fn image_features(&self) -> usize {
        self.in_h * self.in_w * self.in_c
    }

    /// Logical GEMM rows for a batch: one row per output pixel per sample.
    pub fn gemm_rows(&self, batch: usize) -> usize {
        batch * self.out_h * self.out_w
    }

    /// The equivalent plain read tiler over the *logical* patch matrix —
    /// `gather` produces exactly this tiler's stream without materializing
    /// the matrix.
    pub fn read_tiler(&self, batch: usize) -> Tiler2d {
        Tiler2d::new(self.gemm_rows(batch), self.patch_len(), self.tile_m, self.tile_k)
    }

    /// One element of the logical patch matrix: row `m` (global GEMM row,
    /// sample-major), column `k` (window position × channel). Out-of-image
    /// taps (padding) read as zero.
    pub fn element(&self, image: &[i32], m: usize, k: usize) -> i32 {
        if k >= self.patch_len() {
            return 0;
        }
        let b = m / (self.out_h * self.out_w);
        let pix = m % (self.out_h * self.out_w);
        let oy = pix / self.out_w;
        let ox = pix % self.out_w;
        let ky = k / (self.kw * self.in_c);
        let kx = (k % (self.kw * self.in_c)) / self.in_c;
        let c = k % self.in_c;
        let iy = (oy * self.stride_h + ky) as isize - self.pad_top as isize;
        let ix = (ox * self.stride_w + kx) as isize - self.pad_left as isize;
        if iy < 0 || iy >= self.in_h as isize || ix < 0 || ix >= self.in_w as isize {
            return 0;
        }
        let addr = ((b * self.in_h + iy as usize) * self.in_w + ix as usize) * self.in_c + c;
        image[addr]
    }

    /// Materialize the logical patch (im2col) matrix row-major
    /// (`gemm_rows × patch_len`). Reference/test helper only — the compiled
    /// data path never builds this.
    pub fn im2col(&self, batch: usize, image: &[i32]) -> Vec<i32> {
        debug_assert_eq!(image.len(), batch * self.image_features());
        let rows = self.gemm_rows(batch);
        let cols = self.patch_len();
        let mut out = Vec::with_capacity(rows * cols);
        for m in 0..rows {
            for k in 0..cols {
                out.push(self.element(image, m, k));
            }
        }
        out
    }

    /// Stream the patch matrix in the consumer's `{tile_m, tile_k}` block
    /// order straight from the image — bit-identical to
    /// `self.read_tiler(batch).tile(self.im2col(batch, image))` but with the
    /// image buffer as the only operand in memory.
    pub fn gather(&self, batch: usize, image: &[i32]) -> Vec<i32> {
        debug_assert_eq!(image.len(), batch * self.image_features());
        let t = self.read_tiler(batch);
        let rows = t.rows;
        let mut out = Vec::with_capacity(t.stream_len());
        for br in 0..t.row_blocks() {
            for bc in 0..t.col_blocks() {
                for r in 0..t.tile_rows {
                    let m = br * t.tile_rows + r;
                    for c in 0..t.tile_cols {
                        let k = bc * t.tile_cols + c;
                        if m >= rows {
                            out.push(0);
                        } else {
                            out.push(self.element(image, m, k));
                        }
                    }
                }
            }
        }
        out
    }
}

/// A re-tiling between two layouts through a memory tile: producer writes in
/// `write` tile order, consumer reads in `read` tile order. Models the
/// independent write/read tilers of one memory-tile buffer (paper §III-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Retiler {
    pub write: Tiler2d,
    pub read: Tiler2d,
}

impl Retiler {
    /// Pass a producer-tiled stream through the buffer and out in consumer
    /// tile order. The logical matrix shape must agree.
    pub fn retile(&self, producer_stream: &[i32]) -> Vec<i32> {
        debug_assert_eq!(self.write.rows, self.read.rows);
        debug_assert_eq!(self.write.cols, self.read.cols);
        let linear = self.write.untile(producer_stream);
        self.read.tile(&linear)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn address_generator_contiguous() {
        let ag = AddressGenerator::new(0, vec![DimStep { stride: 1, wrap: 6 }]);
        assert_eq!(ag.addresses(), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(ag.len(), 6);
    }

    #[test]
    fn address_generator_strided_2d() {
        // 2 rows of 3, column-major read of a row-major 2x3 buffer.
        let ag = AddressGenerator::new(
            0,
            vec![DimStep { stride: 1, wrap: 3 }, DimStep { stride: 3, wrap: 2 }],
        );
        assert_eq!(ag.addresses(), vec![0, 3, 1, 4, 2, 5]);
    }

    #[test]
    fn address_generator_zero_pads_oob() {
        let ag = AddressGenerator::new(4, vec![DimStep { stride: 1, wrap: 4 }]);
        let buf = vec![1, 2, 3, 4, 5, 6];
        assert_eq!(ag.gather(&buf), vec![5, 6, 0, 0]);
    }

    #[test]
    fn tiler_roundtrip_exact() {
        let t = Tiler2d::new(4, 6, 2, 3);
        let m: Vec<i32> = (0..24).collect();
        let stream = t.tile(&m);
        assert_eq!(stream.len(), 24);
        assert_eq!(t.untile(&stream), m);
        // First tile is the top-left 2x3 block.
        assert_eq!(&stream[..6], &[0, 1, 2, 6, 7, 8]);
    }

    #[test]
    fn tiler_zero_pads() {
        // 3x5 matrix in 2x4 tiles -> padded to 4x8.
        let t = Tiler2d::new(3, 5, 2, 4);
        let m: Vec<i32> = (1..=15).collect();
        let stream = t.tile(&m);
        assert_eq!(stream.len(), 4 * 8);
        // Round-trip drops the padding.
        assert_eq!(t.untile(&stream), m);
        // Padding positions are zero: element (row 3, col 0) is OOB.
        let padded_rows = 4;
        let padded_cols = 8;
        assert_eq!(t.padded(), (padded_rows, padded_cols));
        // Tile (1,0) covers rows 2..4; its second row is all zeros.
        let tile10_start = (1 * t.col_blocks() + 0) * 8;
        assert_eq!(&stream[tile10_start + 4..tile10_start + 8], &[0, 0, 0, 0]);
    }

    #[test]
    fn tiler_matches_address_generator_when_divisible() {
        let t = Tiler2d::new(4, 8, 2, 4);
        let m: Vec<i32> = (0..32).collect();
        let ag = t.to_address_generator().unwrap();
        assert_eq!(ag.gather(&m), t.tile(&m));
    }

    #[test]
    fn address_generator_unavailable_when_padding_needed() {
        assert!(Tiler2d::new(3, 5, 2, 4).to_address_generator().is_none());
    }

    #[test]
    fn retile_between_layouts() {
        // Producer writes 2x2 tiles, consumer reads 1x4 tiles (layer_i
        // {M_i,N_i} -> layer_{i+1} {M_{i+1},K_{i+1}} re-tiling).
        let w = Tiler2d::new(4, 4, 2, 2);
        let r = Tiler2d::new(4, 4, 1, 4);
        let m: Vec<i32> = (0..16).collect();
        let produced = w.tile(&m);
        let retiled = Retiler { write: w, read: r }.retile(&produced);
        assert_eq!(retiled, r.tile(&m));
        // 1x4 tiles of a 4x4 row-major matrix are just its rows.
        assert_eq!(retiled, m);
    }

    #[test]
    fn offset_tilers_compose_a_concat_image() {
        // Two branches (3 + 5 features) landing in an 8-wide consumer
        // buffer read in 2x4 tiles: the composed image equals the plain
        // row-major concatenation, whatever the tile walk order.
        let batch = 5;
        let a: Vec<i32> = (0..batch as i32 * 3).collect();
        let b: Vec<i32> = (100..100 + batch as i32 * 5).collect();
        let ta = OffsetTiler::new(0, 8, 2, 4);
        let tb = OffsetTiler::new(3, 8, 2, 4);
        let mut image = vec![0i32; batch * 8];
        ta.scatter(batch, 3, &a, &mut image);
        tb.scatter(batch, 5, &b, &mut image);
        for r in 0..batch {
            assert_eq!(&image[r * 8..r * 8 + 3], &a[r * 3..(r + 1) * 3]);
            assert_eq!(&image[r * 8 + 3..(r + 1) * 8], &b[r * 5..(r + 1) * 5]);
        }
        // gather() inverts scatter() over each band.
        assert_eq!(ta.gather(batch, 3, &image), a);
        assert_eq!(tb.gather(batch, 5, &image), b);
    }

    #[test]
    fn offset_tiler_band_narrower_than_one_tile() {
        // A 2-feature band strictly inside one 8-column tile block.
        let t = OffsetTiler::new(3, 16, 4, 8);
        let branch = vec![7i32; 3 * 2];
        let mut image = vec![0i32; 3 * 16];
        t.scatter(3, 2, &branch, &mut image);
        for r in 0..3 {
            for c in 0..16 {
                let want = if (3..5).contains(&c) { 7 } else { 0 };
                assert_eq!(image[r * 16 + c], want, "row {r} col {c}");
            }
        }
    }

    fn small_conv_tiler() -> ConvPatchTiler {
        // 4x4x2 image, 3x3 kernel, stride 1, 'same' padding (pad 1) -> 4x4 out.
        ConvPatchTiler {
            in_h: 4,
            in_w: 4,
            in_c: 2,
            kh: 3,
            kw: 3,
            stride_h: 1,
            stride_w: 1,
            pad_top: 1,
            pad_left: 1,
            out_h: 4,
            out_w: 4,
            tile_m: 4,
            tile_k: 8,
            staged: false,
        }
    }

    #[test]
    fn conv_patch_gather_matches_materialized_im2col() {
        let t = small_conv_tiler();
        let batch = 3;
        let image: Vec<i32> = (0..(batch * t.image_features()) as i32).collect();
        let im2col = t.im2col(batch, &image);
        assert_eq!(im2col.len(), t.gemm_rows(batch) * t.patch_len());
        // The streamed walk is bit-identical to tiling the materialized matrix.
        assert_eq!(t.gather(batch, &image), t.read_tiler(batch).tile(&im2col));
    }

    #[test]
    fn conv_patch_same_padding_zeros() {
        let t = small_conv_tiler();
        let image: Vec<i32> = (1..=t.image_features() as i32).collect();
        // Row 0 = output pixel (0,0): taps with ky=0 or kx=0 fall off the
        // top/left edge and must read zero.
        for k in 0..t.patch_len() {
            let ky = k / (t.kw * t.in_c);
            let kx = (k % (t.kw * t.in_c)) / t.in_c;
            let v = t.element(&image, 0, k);
            if ky == 0 || kx == 0 {
                assert_eq!(v, 0, "padding tap k={k} must be zero");
            } else {
                // Interior tap: image pixel (ky-1, kx-1), channel k%2.
                let addr = ((ky - 1) * t.in_w + (kx - 1)) * t.in_c + k % t.in_c;
                assert_eq!(v, image[addr], "tap k={k}");
            }
        }
        // K columns beyond patch_len (K padding) are zero.
        assert_eq!(t.element(&image, 0, t.patch_len()), 0);
    }

    #[test]
    fn conv_patch_valid_stride_window() {
        // 5x5x1 image, 3x3 kernel, stride 2, 'valid' -> 2x2 out, no padding.
        let t = ConvPatchTiler {
            in_h: 5,
            in_w: 5,
            in_c: 1,
            kh: 3,
            kw: 3,
            stride_h: 2,
            stride_w: 2,
            pad_top: 0,
            pad_left: 0,
            out_h: 2,
            out_w: 2,
            tile_m: 2,
            tile_k: 4,
            staged: false,
        };
        let image: Vec<i32> = (0..25).collect();
        // Output pixel (1,1) -> window origin (2,2): rows 2..5, cols 2..5.
        let m = 1 * t.out_w + 1;
        let want: Vec<i32> =
            vec![12, 13, 14, 17, 18, 19, 22, 23, 24];
        let got: Vec<i32> = (0..t.patch_len()).map(|k| t.element(&image, m, k)).collect();
        assert_eq!(got, want);
        // No padding taps anywhere for 'valid'.
        let im2col = t.im2col(1, &image);
        assert!(im2col.iter().all(|&v| (0..25).contains(&v)));
    }

    #[test]
    fn conv_patch_1x1_is_identity() {
        // A 1x1 stride-1 conv's patch matrix IS the flattened image.
        let t = ConvPatchTiler {
            in_h: 3,
            in_w: 2,
            in_c: 4,
            kh: 1,
            kw: 1,
            stride_h: 1,
            stride_w: 1,
            pad_top: 0,
            pad_left: 0,
            out_h: 3,
            out_w: 2,
            tile_m: 2,
            tile_k: 4,
            staged: false,
        };
        let batch = 2;
        let image: Vec<i32> = (0..(batch * t.image_features()) as i32).collect();
        assert_eq!(t.im2col(batch, &image), image);
    }

    #[test]
    fn scatter_gather_inverse() {
        let ag = AddressGenerator::new(
            0,
            vec![DimStep { stride: 4, wrap: 3 }, DimStep { stride: 1, wrap: 4 }],
        );
        let data: Vec<i32> = (100..112).collect();
        let mut buf = vec![0i32; 12];
        ag.scatter(&mut buf, &data);
        assert_eq!(ag.gather(&buf), data);
    }
}
