//! The AIE-ML simulator substrate: DMA tilers, bit-exact functional
//! execution, the VLIW/cycle model, and the steady-state pipeline engine.
//!
//! The paper evaluates on AMD's cycle-accurate `aiesim`; this module is the
//! substitution (see DESIGN.md): `functional` is bit-exact by construction,
//! `vliw`+`cycles` are calibrated against the paper's published single-tile
//! numbers, and `engine` derives multi-tile/multi-layer behaviour from the
//! device model.

pub mod cycles;
pub mod dma;
pub mod engine;
pub mod functional;
pub mod interconnect;
pub mod vliw;

pub use cycles::{
    batch_cycles, kernel_cycles, sustained_gops, CycleBreakdown, CycleModel, KernelWorkload,
};
pub use dma::{AddressGenerator, DimStep, Retiler, Tiler2d};
pub use engine::{analyze, replicated_tops, EngineModel, PerfReport};
pub use functional::{
    dequantize_output, execute, execute_all, execute_layer, execute_merge, quantize_input,
    reference_dense, Activation,
};
