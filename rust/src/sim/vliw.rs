//! VLIW issue-slot model of the AIE-ML core.
//!
//! The AIE-ML core issues a 7-way very long instruction word: in one cycle
//! it can schedule one vector multiply-accumulate (VMAC), two vector loads
//! (VLDA, VLDB — one per load unit), one vector store (VST), a scalar ALU
//! op, and move operations (paper §III-A "Optimized VLIW Execution").
//! This module derives the steady-state initiation interval (II) of the
//! blocked linear-kernel loop from per-iteration slot demands, and models
//! the software-pipeline prologue/epilogue depth.

use crate::arch::{AieGeneration, MmulTiling};

/// Per-cycle issue capacity of the core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IssueSlots {
    pub vmac: usize,
    pub vld: usize,
    pub vst: usize,
    pub scalar: usize,
}

impl IssueSlots {
    /// AIE-ML / AIE-MLv2 7-way VLIW: 1 VMAC + 2 VLD + 1 VST + scalar + moves.
    pub fn aie_ml() -> IssueSlots {
        IssueSlots { vmac: 1, vld: 2, vst: 1, scalar: 1 }
    }
}

/// Slot demand of one steady-state loop iteration.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SlotDemand {
    pub vmac: usize,
    pub vld: usize,
    pub vst: usize,
    pub scalar: usize,
}

/// Steady-state initiation interval: cycles per loop iteration given the
/// slot demand — the maximum over resource classes of demand/capacity.
pub fn initiation_interval(demand: &SlotDemand, slots: &IssueSlots) -> usize {
    let per = |d: usize, c: usize| if c == 0 { usize::MAX } else { d.div_ceil(c) };
    per(demand.vmac, slots.vmac)
        .max(per(demand.vld, slots.vld))
        .max(per(demand.vst, slots.vst))
        .max(per(demand.scalar, slots.scalar))
        .max(1)
}

/// Slot demand of one iteration of the 2×2-blocked `aie::mmul` inner loop:
/// 4 tile-multiplies (two A tiles × two W tiles) per iteration, each tile
/// multiply costing `vmac_cycles_per_tile` VMAC issues; 2 A-tile loads and
/// 2 W-tile loads (each `load_cycles` wide-vector loads); one scalar
/// address update. Stores happen only in the K-loop epilogue and are
/// overlapped, so they don't appear in the steady-state demand.
pub fn blocked_loop_demand(tiling: &MmulTiling, generation: AieGeneration, load_port_bytes: usize) -> SlotDemand {
    let a_bytes = tiling.m * tiling.k * tiling.pair.act.bytes();
    let w_bytes = tiling.k * tiling.n * tiling.pair.wgt.bytes();
    let a_loads = a_bytes.div_ceil(load_port_bytes);
    let w_loads = w_bytes.div_ceil(load_port_bytes);
    SlotDemand {
        vmac: 4 * tiling.vmac_cycles_per_tile(generation),
        vld: 2 * a_loads + 2 * w_loads,
        vst: 0,
        scalar: 1,
    }
}

/// Steady-state cycles per *tile multiply* of the blocked kernel.
pub fn blocked_cycles_per_tile(
    tiling: &MmulTiling,
    generation: AieGeneration,
    load_port_bytes: usize,
) -> f64 {
    let demand = blocked_loop_demand(tiling, generation, load_port_bytes);
    initiation_interval(&demand, &IssueSlots::aie_ml()) as f64 / 4.0
}

/// Software-pipeline depth: cycles to fill/drain the loop pipeline once per
/// kernel invocation (loads → MAC → SRS → store stages).
pub const PIPELINE_DEPTH: usize = 8;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{native_tilings, PrecisionPair};

    #[test]
    fn native_tilings_reach_ii_four_for_four_tiles() {
        // Every Table-I native tiling sustains 4 tile-multiplies in 4 cycles
        // (1 VMAC/cycle) under the 2x2 scheme: the VLIW has enough load slots.
        for t in native_tilings() {
            let d = blocked_loop_demand(&t, AieGeneration::AieMl, 32);
            let ii = initiation_interval(&d, &IssueSlots::aie_ml());
            assert_eq!(
                ii,
                4 * t.vmac_cycles_per_tile(AieGeneration::AieMl),
                "tiling {t}: VMAC should bound the loop, not loads"
            );
        }
    }

    #[test]
    fn i8_tiling_is_exactly_one_tile_per_cycle() {
        let t = crate::arch::default_tiling(PrecisionPair::I8I8).unwrap();
        assert!((blocked_cycles_per_tile(&t, AieGeneration::AieMl, 32) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn load_bound_when_ports_halved() {
        // With hypothetical 16-byte ports the i8 <4,8,8> tiling becomes
        // load-bound: W tile is 64 B = 4 loads, so 2A+2W = 12 loads / 2 ports
        // = 6 cycles > 4 VMAC cycles.
        let t = crate::arch::default_tiling(PrecisionPair::I8I8).unwrap();
        let d = blocked_loop_demand(&t, AieGeneration::AieMl, 16);
        assert_eq!(initiation_interval(&d, &IssueSlots::aie_ml()), 6);
    }

    #[test]
    fn ii_never_zero() {
        let d = SlotDemand::default();
        assert_eq!(initiation_interval(&d, &IssueSlots::aie_ml()), 1);
    }
}
