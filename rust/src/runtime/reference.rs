//! Hermetic reference oracle: an independent, pure-Rust execution of the
//! *logical* model, used to gate the packed firmware path bit-exactly.
//!
//! The paper's toolflow validates firmware against the quantized hls4ml
//! model. Our default (network-free, PJRT-free) equivalent executes the
//! exporter JSON directly through [`reference_dense`] — unpacked row-major
//! weights, wide accumulation, the same quantize → SRS → saturate → ReLU
//! chain — sharing **no** code with the packed per-tile path the firmware
//! simulator runs. Any divergence between the two implementations trips the
//! `oracle_bitexact` gate on a fresh checkout, without artifacts.
//!
//! With `--features pjrt` the AOT-compiled JAX/XLA artifact provides a third,
//! fully external implementation (see [`super::pjrt`]).

use crate::arch::{Dtype, PrecisionPair};
use crate::frontend::JsonModel;
use crate::ir::{derive_shift, QuantSpec};
use crate::sim::functional::{reference_dense, Activation};
use anyhow::{ensure, Context, Result};
use std::path::Path;

use super::oracle::OracleBackend;

/// One dense layer in logical (unpacked) form.
struct RefLayer {
    name: String,
    in_features: usize,
    out_features: usize,
    /// Row-major `[out_features][in_features]`, exactly as exported.
    weights: Vec<i32>,
    bias: Option<Vec<i64>>,
    input: QuantSpec,
    output: QuantSpec,
    acc_dtype: Dtype,
    shift: u32,
    relu: bool,
}

/// The reference model: a chain of [`RefLayer`]s built straight from the
/// exporter JSON (no pass pipeline involved).
pub struct ReferenceOracle {
    name: String,
    layers: Vec<RefLayer>,
}

impl ReferenceOracle {
    /// Build from a parsed model JSON. Quantization attributes are derived
    /// the same way the Quantization pass derives them (accumulator dtype
    /// from the precision pair, SRS shift from the binary points) — but on
    /// the logical tensors, independent of tiling/packing/placement.
    pub fn from_model(json: &JsonModel) -> Result<ReferenceOracle> {
        json.validate().context("reference oracle: invalid model")?;
        let mut layers = Vec::with_capacity(json.layers.len());
        for l in &json.layers {
            let input = l.quant.input.to_spec(&l.name)?;
            let weight = l.quant.weight.to_spec(&l.name)?;
            let output = l.quant.output.to_spec(&l.name)?;
            let pair = PrecisionPair::new(input.dtype, weight.dtype);
            layers.push(RefLayer {
                name: l.name.clone(),
                in_features: l.in_features,
                out_features: l.out_features,
                weights: l.weights.clone(),
                bias: if l.use_bias { Some(l.bias.clone()) } else { None },
                input,
                output,
                acc_dtype: pair.acc_dtype(),
                shift: derive_shift(input.frac_bits, weight.frac_bits, output.frac_bits),
                relu: l.relu,
            });
        }
        ensure!(!layers.is_empty(), "reference oracle: model has no layers");
        Ok(ReferenceOracle { name: json.name.clone(), layers })
    }

    /// Build from a model JSON file.
    pub fn from_file(path: impl AsRef<Path>) -> Result<ReferenceOracle> {
        let path = path.as_ref();
        let json = JsonModel::from_file(path)
            .with_context(|| format!("reference oracle: loading {}", path.display()))?;
        Self::from_model(&json)
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn input_features(&self) -> usize {
        self.layers[0].in_features
    }

    pub fn output_features(&self) -> usize {
        self.layers.last().unwrap().out_features
    }

    /// Execute the whole chain on an integer batch.
    pub fn execute(&self, input: &Activation) -> Result<Activation> {
        ensure!(
            input.features == self.input_features(),
            "reference oracle: input features {} != model {}",
            input.features,
            self.input_features()
        );
        let (lo, hi) = self.layers[0].input.dtype.range();
        ensure!(
            input.data.iter().all(|&x| (x as i64) >= lo && (x as i64) <= hi),
            "reference oracle: input values outside {} range",
            self.layers[0].input.dtype
        );
        let mut act = input.clone();
        for l in &self.layers {
            ensure!(
                act.features == l.in_features,
                "reference oracle: layer '{}' expects {} features, got {}",
                l.name,
                l.in_features,
                act.features
            );
            act = reference_dense(
                &act,
                &l.weights,
                l.bias.as_deref(),
                l.out_features,
                l.shift,
                l.output.dtype,
                l.acc_dtype,
                l.relu,
            );
        }
        Ok(act)
    }
}

impl OracleBackend for ReferenceOracle {
    fn describe(&self) -> String {
        format!("reference({})", self.name)
    }

    fn execute_oracle(&mut self, input: &Activation) -> Result<Vec<i32>> {
        Ok(self.execute(input)?.data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::JsonLayer;

    fn two_layer() -> JsonModel {
        JsonModel::new(
            "ref",
            vec![
                JsonLayer::dense(
                    "fc1",
                    3,
                    2,
                    true,
                    true,
                    "int8",
                    "int8",
                    1,
                    vec![1, -2, 3, -4, 5, -6],
                    vec![10, -10],
                ),
                JsonLayer::dense("fc2", 2, 2, false, false, "int8", "int8", 0, vec![1, 0, 0, 1], vec![]),
            ],
        )
    }

    #[test]
    fn executes_hand_checked_chain() {
        let oracle = ReferenceOracle::from_model(&two_layer()).unwrap();
        assert_eq!(oracle.input_features(), 3);
        assert_eq!(oracle.output_features(), 2);
        // fc1 (shift = 1+1-1 = 1, relu): row [10, 20, 30] ->
        //   o0 = 10-40+90+10 = 70  -> srs 35
        //   o1 = -40+100-180-10 = -130 -> srs -65 -> relu 0
        // fc2 is identity with shift 0.
        let x = Activation::new(1, 3, vec![10, 20, 30]).unwrap();
        let y = oracle.execute(&x).unwrap();
        assert_eq!(y.data, vec![35, 0]);
    }

    #[test]
    fn input_range_checked() {
        let oracle = ReferenceOracle::from_model(&two_layer()).unwrap();
        let x = Activation::new(1, 3, vec![300, 0, 0]).unwrap();
        assert!(oracle.execute(&x).is_err());
        let bad = Activation::new(1, 2, vec![1, 2]).unwrap();
        assert!(oracle.execute(&bad).is_err());
    }

    #[test]
    fn mixed_precision_acc_dtype() {
        let mut m = two_layer();
        // i16 activations x i8 weights -> 32-bit accumulator.
        m.layers[0].quant.input.dtype = "int16".into();
        m.layers[0].quant.output.dtype = "int16".into();
        m.layers[1].quant.input.dtype = "int16".into();
        let oracle = ReferenceOracle::from_model(&m).unwrap();
        assert_eq!(oracle.layers[0].acc_dtype, Dtype::I32);
    }
}
