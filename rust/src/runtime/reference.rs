//! Hermetic reference oracle: an independent, pure-Rust execution of the
//! *logical* model, used to gate the packed firmware path bit-exactly.
//!
//! The paper's toolflow validates firmware against the quantized hls4ml
//! model. Our default (network-free, PJRT-free) equivalent executes the
//! exporter JSON directly through [`reference_dense`] — unpacked row-major
//! weights, wide accumulation, the same quantize → SRS → saturate → ReLU
//! chain — sharing **no** code with the packed per-tile path the firmware
//! simulator runs. The oracle executes the model as a **DAG**: layers name
//! their producers (`inputs`, defaulting to the previous layer), residual
//! `add` merges sum in wrapping i32 and saturate, `concat` merges splice
//! features — mirroring the IR semantics without touching the pass
//! pipeline. Any divergence between the two implementations trips the
//! `oracle_bitexact` gate on a fresh checkout, without artifacts.
//!
//! With `--features pjrt` the AOT-compiled JAX/XLA artifact provides a third,
//! fully external implementation (see [`super::pjrt`]).

use crate::arch::{Dtype, PrecisionPair};
use crate::frontend::JsonModel;
use crate::ir::{derive_shift, srs, srs_i32, Conv2DAttrs, Pool2DAttrs, QuantSpec};
use crate::sim::functional::{reference_dense, Activation};
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;

use super::oracle::OracleBackend;

/// Where a reference node reads an operand from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RefSource {
    /// The network input batch.
    Input,
    /// The output of an earlier node (index into `ReferenceOracle::nodes`).
    Node(usize),
}

/// A dense layer in logical (unpacked) form.
struct RefDense {
    in_features: usize,
    /// Row-major `[out_features][in_features]`, exactly as exported.
    weights: Vec<i32>,
    bias: Option<Vec<i64>>,
    acc_dtype: Dtype,
    shift: u32,
    relu: bool,
}

/// A Conv2D layer in logical form, executed as a naive direct NHWC
/// convolution — deliberately *not* the implicit-GEMM patch walk the
/// firmware path uses, so the two implementations stay independent.
struct RefConv {
    attrs: Conv2DAttrs,
    /// HWIO-flattened `[out_c][kh*kw*in_c]`, exactly as exported.
    weights: Vec<i32>,
    bias: Option<Vec<i64>>,
    acc_dtype: Dtype,
    shift: u32,
    relu: bool,
}

enum RefOp {
    Dense(RefDense),
    /// Naive direct 2D convolution (no im2col, no tilers).
    Conv2D(RefConv),
    /// Windowed max over present (in-bounds) taps.
    MaxPool2D(Pool2DAttrs),
    /// Windowed mean over present taps, round-half-toward-+inf, saturate.
    AvgPool2D(Pool2DAttrs),
    /// Per-sample 2D transpose of a `[rows, cols]` row-major tensor.
    Transpose { rows: usize, cols: usize },
    /// Residual add: wrapping i32 sum, SRS(0) saturating store.
    Add,
    /// Feature concatenation in input order.
    Concat,
}

/// One node of the reference DAG.
struct RefNode {
    name: String,
    op: RefOp,
    inputs: Vec<RefSource>,
    out_features: usize,
    output: QuantSpec,
}

/// The reference model: a DAG of [`RefNode`]s built straight from the
/// exporter JSON (no pass pipeline involved).
pub struct ReferenceOracle {
    name: String,
    nodes: Vec<RefNode>,
    input_features: usize,
    input_spec: QuantSpec,
    /// The unconsumed nodes — the network outputs, in layer order. The
    /// first entry is the primary output (single-sink models have one).
    output_nodes: Vec<usize>,
}

impl ReferenceOracle {
    /// Build from a parsed model JSON. Quantization attributes are derived
    /// the same way the Quantization pass derives them (accumulator dtype
    /// from the precision pair, SRS shift from the binary points) — but on
    /// the logical tensors, independent of tiling/packing/placement.
    pub fn from_model(json: &JsonModel) -> Result<ReferenceOracle> {
        json.validate().context("reference oracle: invalid model")?;
        let mut nodes: Vec<RefNode> = Vec::with_capacity(json.layers.len());
        let mut by_name: std::collections::HashMap<&str, usize> = std::collections::HashMap::new();
        let input_spec = json.layers[0].quant.input.to_spec(&json.layers[0].name)?;
        for (i, l) in json.layers.iter().enumerate() {
            // Resolve producers: explicit names, or the previous layer.
            let inputs: Vec<RefSource> = if l.inputs.is_empty() {
                if i == 0 {
                    vec![RefSource::Input]
                } else {
                    vec![RefSource::Node(i - 1)]
                }
            } else {
                l.inputs
                    .iter()
                    .map(|src| {
                        if src == "input" {
                            Ok(RefSource::Input)
                        } else {
                            by_name.get(src.as_str()).copied().map(RefSource::Node).with_context(
                                || format!("reference oracle: layer '{}' reads unknown '{src}'", l.name),
                            )
                        }
                    })
                    .collect::<Result<_>>()?
            };
            let node = match l.ty.as_str() {
                "dense" => {
                    let input = l.quant.input.to_spec(&l.name)?;
                    let weight = l.quant.weight.to_spec(&l.name)?;
                    let output = l.quant.output.to_spec(&l.name)?;
                    let pair = PrecisionPair::new(input.dtype, weight.dtype);
                    RefNode {
                        name: l.name.clone(),
                        op: RefOp::Dense(RefDense {
                            in_features: l.in_features,
                            weights: l.weights.clone(),
                            bias: if l.use_bias { Some(l.bias.clone()) } else { None },
                            acc_dtype: pair.acc_dtype(),
                            shift: derive_shift(input.frac_bits, weight.frac_bits, output.frac_bits),
                            relu: l.relu,
                        }),
                        inputs,
                        out_features: l.out_features,
                        output,
                    }
                }
                "conv2d" => {
                    let input = l.quant.input.to_spec(&l.name)?;
                    let weight = l.quant.weight.to_spec(&l.name)?;
                    let output = l.quant.output.to_spec(&l.name)?;
                    let pair = PrecisionPair::new(input.dtype, weight.dtype);
                    RefNode {
                        name: l.name.clone(),
                        op: RefOp::Conv2D(RefConv {
                            attrs: l.conv_attrs()?,
                            weights: l.weights.clone(),
                            bias: if l.use_bias { Some(l.bias.clone()) } else { None },
                            acc_dtype: pair.acc_dtype(),
                            shift: derive_shift(input.frac_bits, weight.frac_bits, output.frac_bits),
                            relu: l.relu,
                        }),
                        inputs,
                        out_features: l.out_features,
                        output,
                    }
                }
                "add" | "concat" | "maxpool2d" | "avgpool2d" | "transpose" => {
                    // The merge's store spec comes from its producers (the
                    // raw network input contributes the model input spec).
                    let mut spec: Option<QuantSpec> = None;
                    for src in &inputs {
                        let s = match src {
                            RefSource::Input => input_spec,
                            RefSource::Node(j) => nodes[*j].output,
                        };
                        match spec {
                            None => spec = Some(s),
                            Some(prev) if prev == s => {}
                            Some(prev) => bail!(
                                "reference oracle: merge '{}' input quantization disagrees \
                                 ({} frac {} vs {} frac {})",
                                l.name,
                                prev.dtype,
                                prev.frac_bits,
                                s.dtype,
                                s.frac_bits
                            ),
                        }
                    }
                    let output = spec.context("reference oracle: merge has no inputs")?;
                    let op = match l.ty.as_str() {
                        "add" => RefOp::Add,
                        "concat" => RefOp::Concat,
                        "maxpool2d" => RefOp::MaxPool2D(l.pool_attrs()?),
                        "avgpool2d" => RefOp::AvgPool2D(l.pool_attrs()?),
                        _ => {
                            let c = l.conv_attrs()?;
                            RefOp::Transpose { rows: c.in_h, cols: c.in_w }
                        }
                    };
                    RefNode { name: l.name.clone(), op, inputs, out_features: l.out_features, output }
                }
                other => bail!("reference oracle: unsupported layer type '{other}'"),
            };
            nodes.push(node);
            by_name.insert(json.layers[i].name.as_str(), i);
        }
        // The network outputs are the unconsumed nodes, in layer order —
        // the same per-sink ordering the compiled firmware's output drains
        // use, so multi-output comparisons line up sink by sink.
        let mut consumed = vec![false; nodes.len()];
        for n in &nodes {
            for src in &n.inputs {
                if let RefSource::Node(j) = src {
                    consumed[*j] = true;
                }
            }
        }
        let sinks: Vec<usize> = (0..nodes.len()).filter(|&i| !consumed[i]).collect();
        ensure!(!sinks.is_empty(), "reference oracle: model has no output sink");
        Ok(ReferenceOracle {
            name: json.name.clone(),
            input_features: json.layers[0].in_features,
            input_spec,
            output_nodes: sinks,
            nodes,
        })
    }

    /// Build from a model JSON file.
    pub fn from_file(path: impl AsRef<Path>) -> Result<ReferenceOracle> {
        let path = path.as_ref();
        let json = JsonModel::from_file(path)
            .with_context(|| format!("reference oracle: loading {}", path.display()))?;
        Self::from_model(&json)
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn input_features(&self) -> usize {
        self.input_features
    }

    /// Feature count of the primary (first) network output.
    pub fn output_features(&self) -> usize {
        self.nodes[self.output_nodes[0]].out_features
    }

    /// Number of network outputs (sinks).
    pub fn output_count(&self) -> usize {
        self.output_nodes.len()
    }

    /// Names of every network output, in output order.
    pub fn output_names(&self) -> Vec<&str> {
        self.output_nodes.iter().map(|&i| self.nodes[i].name.as_str()).collect()
    }

    /// Execute the whole DAG on an integer batch and return the primary
    /// (first) output; use [`ReferenceOracle::execute_all`] for every sink.
    pub fn execute(&self, input: &Activation) -> Result<Activation> {
        Ok(self.execute_all(input)?.swap_remove(0))
    }

    /// Execute the whole DAG and return every network output, one per
    /// sink, in output order.
    pub fn execute_all(&self, input: &Activation) -> Result<Vec<Activation>> {
        ensure!(
            input.features == self.input_features(),
            "reference oracle: input features {} != model {}",
            input.features,
            self.input_features()
        );
        let (lo, hi) = self.input_spec.dtype.range();
        ensure!(
            input.data.iter().all(|&x| (x as i64) >= lo && (x as i64) <= hi),
            "reference oracle: input values outside {} range",
            self.input_spec.dtype
        );
        let mut outs: Vec<Option<Activation>> = (0..self.nodes.len()).map(|_| None).collect();
        for (i, n) in self.nodes.iter().enumerate() {
            let mut ins: Vec<&Activation> = Vec::with_capacity(n.inputs.len());
            for src in &n.inputs {
                ins.push(match src {
                    RefSource::Input => input,
                    RefSource::Node(j) => outs
                        .get(*j)
                        .and_then(|o| o.as_ref())
                        .context("reference oracle: node order not topological")?,
                });
            }
            let out = match &n.op {
                RefOp::Dense(d) => {
                    let a = ins[0];
                    ensure!(
                        a.features == d.in_features,
                        "reference oracle: layer '{}' expects {} features, got {}",
                        n.name,
                        d.in_features,
                        a.features
                    );
                    reference_dense(
                        a,
                        &d.weights,
                        d.bias.as_deref(),
                        n.out_features,
                        d.shift,
                        n.output.dtype,
                        d.acc_dtype,
                        d.relu,
                    )
                }
                RefOp::Conv2D(c) => {
                    let a = ins[0];
                    let at = &c.attrs;
                    ensure!(
                        a.features == at.in_features(),
                        "reference oracle: conv '{}' expects {} features, got {}",
                        n.name,
                        at.in_features(),
                        a.features
                    );
                    ensure!(
                        n.out_features == at.out_features(),
                        "reference oracle: conv '{}' output shape mismatch",
                        n.name
                    );
                    let (oh, ow) = (at.out_h(), at.out_w());
                    let (pt, pl) = (at.pad_top() as isize, at.pad_left() as isize);
                    let mut data = vec![0i32; a.batch * n.out_features];
                    for b in 0..a.batch {
                        let img = a.row(b);
                        for oy in 0..oh {
                            for ox in 0..ow {
                                for oc in 0..at.out_c {
                                    let w = &c.weights
                                        [oc * at.patch_len()..(oc + 1) * at.patch_len()];
                                    let mut acc: i64 = 0;
                                    for ky in 0..at.kh {
                                        let iy = (oy * at.stride_h + ky) as isize - pt;
                                        if iy < 0 || iy >= at.in_h as isize {
                                            continue; // zero-padded tap
                                        }
                                        for kx in 0..at.kw {
                                            let ix = (ox * at.stride_w + kx) as isize - pl;
                                            if ix < 0 || ix >= at.in_w as isize {
                                                continue;
                                            }
                                            let px = (iy as usize * at.in_w + ix as usize)
                                                * at.in_c;
                                            for ic in 0..at.in_c {
                                                acc += img[px + ic] as i64
                                                    * w[(ky * at.kw + kx) * at.in_c + ic] as i64;
                                            }
                                        }
                                    }
                                    if let Some(bias) = &c.bias {
                                        acc += bias[oc];
                                    }
                                    // Same store semantics as reference_dense:
                                    // 32-bit accumulators wrap, i64 stays exact.
                                    let mut y = if c.acc_dtype != Dtype::I64 {
                                        srs_i32(acc as i32, c.shift, n.output.dtype) as i64
                                    } else {
                                        srs(acc, c.shift, n.output.dtype)
                                    };
                                    if c.relu {
                                        y = y.max(0);
                                    }
                                    data[b * n.out_features + (oy * ow + ox) * at.out_c + oc] =
                                        y as i32;
                                }
                            }
                        }
                    }
                    Activation { batch: a.batch, features: n.out_features, data }
                }
                RefOp::MaxPool2D(p) | RefOp::AvgPool2D(p) => {
                    let is_max = matches!(&n.op, RefOp::MaxPool2D(_));
                    let a = ins[0];
                    ensure!(
                        a.features == p.in_features() && n.out_features == p.out_features(),
                        "reference oracle: pool '{}' shape mismatch",
                        n.name
                    );
                    let (oh, ow) = (p.out_h(), p.out_w());
                    let (pt, pl) = (p.pad_top() as isize, p.pad_left() as isize);
                    let mut data = vec![0i32; a.batch * n.out_features];
                    for b in 0..a.batch {
                        let img = a.row(b);
                        for oy in 0..oh {
                            for ox in 0..ow {
                                for ch in 0..p.c {
                                    let mut mx = i32::MIN;
                                    let mut sum: i64 = 0;
                                    let mut count: i64 = 0;
                                    for ky in 0..p.kh {
                                        let iy = (oy * p.stride_h + ky) as isize - pt;
                                        if iy < 0 || iy >= p.in_h as isize {
                                            continue; // OOB taps are excluded
                                        }
                                        for kx in 0..p.kw {
                                            let ix = (ox * p.stride_w + kx) as isize - pl;
                                            if ix < 0 || ix >= p.in_w as isize {
                                                continue;
                                            }
                                            let v = img
                                                [(iy as usize * p.in_w + ix as usize) * p.c + ch];
                                            mx = mx.max(v);
                                            sum += v as i64;
                                            count += 1;
                                        }
                                    }
                                    ensure!(
                                        count > 0,
                                        "reference oracle: pool '{}' empty window",
                                        n.name
                                    );
                                    // Avg: round half toward +inf (SRS flavor),
                                    // then a saturating store.
                                    let y = if is_max {
                                        mx
                                    } else {
                                        (sum + count / 2).div_euclid(count) as i32
                                    };
                                    data[b * n.out_features + (oy * ow + ox) * p.c + ch] =
                                        srs_i32(y, 0, n.output.dtype);
                                }
                            }
                        }
                    }
                    Activation { batch: a.batch, features: n.out_features, data }
                }
                RefOp::Transpose { rows, cols } => {
                    let a = ins[0];
                    ensure!(
                        a.features == rows * cols && n.out_features == rows * cols,
                        "reference oracle: transpose '{}' shape mismatch",
                        n.name
                    );
                    let (rows, cols) = (*rows, *cols);
                    let mut data = vec![0i32; a.batch * n.out_features];
                    for b in 0..a.batch {
                        let src = a.row(b);
                        let dst = &mut data[b * n.out_features..(b + 1) * n.out_features];
                        for r in 0..rows {
                            for col in 0..cols {
                                dst[col * rows + r] = src[r * cols + col];
                            }
                        }
                    }
                    Activation { batch: a.batch, features: n.out_features, data }
                }
                RefOp::Add => {
                    let batch = ins[0].batch;
                    for a in &ins {
                        ensure!(
                            a.features == n.out_features && a.batch == batch,
                            "reference oracle: merge '{}' input shape mismatch",
                            n.name
                        );
                    }
                    let mut data = vec![0i32; batch * n.out_features];
                    for a in &ins {
                        for (acc, v) in data.iter_mut().zip(&a.data) {
                            *acc = acc.wrapping_add(*v);
                        }
                    }
                    for v in &mut data {
                        *v = srs_i32(*v, 0, n.output.dtype);
                    }
                    Activation { batch, features: n.out_features, data }
                }
                RefOp::Concat => {
                    let batch = ins[0].batch;
                    let total: usize = ins.iter().map(|a| a.features).sum();
                    ensure!(
                        total == n.out_features && ins.iter().all(|a| a.batch == batch),
                        "reference oracle: merge '{}' input shape mismatch",
                        n.name
                    );
                    let mut data = vec![0i32; batch * n.out_features];
                    let mut off = 0usize;
                    for a in &ins {
                        for b in 0..batch {
                            data[b * n.out_features + off..b * n.out_features + off + a.features]
                                .copy_from_slice(a.row(b));
                        }
                        off += a.features;
                    }
                    Activation { batch, features: n.out_features, data }
                }
            };
            drop(ins);
            outs[i] = Some(out);
        }
        self.output_nodes
            .iter()
            .map(|&o| {
                outs.get_mut(o)
                    .and_then(Option::take)
                    .context("reference oracle: output node missing")
            })
            .collect()
    }
}

impl OracleBackend for ReferenceOracle {
    fn describe(&self) -> String {
        format!("reference({})", self.name)
    }

    fn execute_oracle(&mut self, input: &Activation) -> Result<Vec<i32>> {
        Ok(self.execute(input)?.data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::JsonLayer;

    fn two_layer() -> JsonModel {
        JsonModel::new(
            "ref",
            vec![
                JsonLayer::dense(
                    "fc1",
                    3,
                    2,
                    true,
                    true,
                    "int8",
                    "int8",
                    1,
                    vec![1, -2, 3, -4, 5, -6],
                    vec![10, -10],
                ),
                JsonLayer::dense("fc2", 2, 2, false, false, "int8", "int8", 0, vec![1, 0, 0, 1], vec![]),
            ],
        )
    }

    #[test]
    fn executes_hand_checked_chain() {
        let oracle = ReferenceOracle::from_model(&two_layer()).unwrap();
        assert_eq!(oracle.input_features(), 3);
        assert_eq!(oracle.output_features(), 2);
        // fc1 (shift = 1+1-1 = 1, relu): row [10, 20, 30] ->
        //   o0 = 10-40+90+10 = 70  -> srs 35
        //   o1 = -40+100-180-10 = -130 -> srs -65 -> relu 0
        // fc2 is identity with shift 0.
        let x = Activation::new(1, 3, vec![10, 20, 30]).unwrap();
        let y = oracle.execute(&x).unwrap();
        assert_eq!(y.data, vec![35, 0]);
    }

    #[test]
    fn input_range_checked() {
        let oracle = ReferenceOracle::from_model(&two_layer()).unwrap();
        let x = Activation::new(1, 3, vec![300, 0, 0]).unwrap();
        assert!(oracle.execute(&x).is_err());
        let bad = Activation::new(1, 2, vec![1, 2]).unwrap();
        assert!(oracle.execute(&bad).is_err());
    }

    #[test]
    fn mixed_precision_acc_dtype() {
        let mut m = two_layer();
        // i16 activations x i8 weights -> 32-bit accumulator.
        m.layers[0].quant.input.dtype = "int16".into();
        m.layers[0].quant.output.dtype = "int16".into();
        m.layers[1].quant.input.dtype = "int16".into();
        let oracle = ReferenceOracle::from_model(&m).unwrap();
        match &oracle.nodes[0].op {
            RefOp::Dense(d) => assert_eq!(d.acc_dtype, Dtype::I32),
            _ => panic!("fc1 is dense"),
        }
    }

    #[test]
    fn executes_hand_checked_residual() {
        // Identity fc (shift 0), then add(input, fc): y = sat(x + x) = 2x,
        // saturating at the int8 rails.
        let m = JsonModel::new(
            "res",
            vec![
                JsonLayer::dense("fc", 2, 2, false, false, "int8", "int8", 0, vec![1, 0, 0, 1], vec![]),
                JsonLayer::residual_add("res", 2, "int8", 0, &["input", "fc"]),
            ],
        );
        let oracle = ReferenceOracle::from_model(&m).unwrap();
        assert_eq!(oracle.output_features(), 2);
        let x = Activation::new(1, 2, vec![30, 100]).unwrap();
        let y = oracle.execute(&x).unwrap();
        assert_eq!(y.data, vec![60, 127]); // 200 saturates to 127
    }

    #[test]
    fn executes_hand_checked_concat() {
        let m = JsonModel::new(
            "cat",
            vec![
                JsonLayer::dense("a", 2, 1, false, false, "int8", "int8", 0, vec![1, 0], vec![]),
                JsonLayer::dense("b", 2, 1, false, false, "int8", "int8", 0, vec![0, 1], vec![])
                    .with_inputs(&["input"]),
                JsonLayer::concat("cat", 2, "int8", 0, &["a", "b"]),
            ],
        );
        let oracle = ReferenceOracle::from_model(&m).unwrap();
        let x = Activation::new(2, 2, vec![5, -7, 9, 11]).unwrap();
        let y = oracle.execute(&x).unwrap();
        assert_eq!(y.data, vec![5, -7, 9, 11]);
    }

    #[test]
    fn executes_hand_checked_conv() {
        use crate::frontend::JsonConv;
        // 2x2x1 image, 2x2 valid conv, one output channel, bias 5, shift 0:
        // y = 1*1 + 2*2 + 3*3 + 4*4 + 5 = 35.
        let conv = JsonConv {
            in_h: 2,
            in_w: 2,
            in_c: 1,
            out_c: 1,
            kh: 2,
            kw: 2,
            stride_h: 1,
            stride_w: 1,
            padding: "valid".into(),
        };
        let m = JsonModel::new(
            "conv",
            vec![JsonLayer::conv2d("c", conv, true, false, "int8", "int8", 0, vec![1, 2, 3, 4], vec![5])],
        );
        let oracle = ReferenceOracle::from_model(&m).unwrap();
        assert_eq!(oracle.input_features(), 4);
        assert_eq!(oracle.output_features(), 1);
        let x = Activation::new(1, 4, vec![1, 2, 3, 4]).unwrap();
        assert_eq!(oracle.execute(&x).unwrap().data, vec![35]);
    }

    #[test]
    fn executes_hand_checked_pool_and_transpose() {
        use crate::frontend::JsonConv;
        // Identity 1x1 conv feeds a full-image pool: max([1,2,3,4]) = 4,
        // avg = (10 + 2) / 4 = 3 (round half toward +inf).
        let id = JsonConv {
            in_h: 2,
            in_w: 2,
            in_c: 1,
            out_c: 1,
            kh: 1,
            kw: 1,
            stride_h: 1,
            stride_w: 1,
            padding: "valid".into(),
        };
        let window = JsonConv { out_c: 0, kh: 2, kw: 2, ..id.clone() };
        for (ty, want) in [("maxpool2d", 4), ("avgpool2d", 3)] {
            let m = JsonModel::new(
                "pool",
                vec![
                    JsonLayer::conv2d("c", id.clone(), false, false, "int8", "int8", 0, vec![1], vec![]),
                    JsonLayer::pool2d("p", ty, window.clone(), "int8", 0),
                ],
            );
            let oracle = ReferenceOracle::from_model(&m).unwrap();
            let x = Activation::new(1, 4, vec![1, 2, 3, 4]).unwrap();
            assert_eq!(oracle.execute(&x).unwrap().data, vec![want], "{ty}");
        }
        // Transpose [2,3] -> [3,2]: row-major [1..6] -> [1,4,2,5,3,6].
        let id23 = JsonConv { in_h: 2, in_w: 3, ..id };
        let m = JsonModel::new(
            "tr",
            vec![
                JsonLayer::conv2d("c", id23, false, false, "int8", "int8", 0, vec![1], vec![]),
                JsonLayer::transpose("t", 2, 3, "int8", 0),
            ],
        );
        let oracle = ReferenceOracle::from_model(&m).unwrap();
        let x = Activation::new(1, 6, vec![1, 2, 3, 4, 5, 6]).unwrap();
        assert_eq!(oracle.execute(&x).unwrap().data, vec![1, 4, 2, 5, 3, 6]);
    }

    #[test]
    fn multi_sink_returns_every_output() {
        // Two unconsumed projections of the input: execute_all yields both
        // sinks in layer order; execute returns the primary (first).
        let m = JsonModel::new(
            "two",
            vec![
                JsonLayer::dense("a", 2, 1, false, false, "int8", "int8", 0, vec![1, 0], vec![]),
                JsonLayer::dense("b", 2, 1, false, false, "int8", "int8", 0, vec![0, 1], vec![])
                    .with_inputs(&["input"]),
            ],
        );
        let oracle = ReferenceOracle::from_model(&m).unwrap();
        assert_eq!(oracle.output_count(), 2);
        assert_eq!(oracle.output_names(), vec!["a", "b"]);
        let x = Activation::new(1, 2, vec![7, -3]).unwrap();
        let all = oracle.execute_all(&x).unwrap();
        assert_eq!(all[0].data, vec![7]);
        assert_eq!(all[1].data, vec![-3]);
        assert_eq!(oracle.execute(&x).unwrap().data, vec![7]);
    }
}
