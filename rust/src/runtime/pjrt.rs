//! PJRT runtime: load and execute AOT-compiled XLA artifacts from Rust.
//! Compiled only with `--features pjrt`.
//!
//! `python/compile/aot.py` lowers the quantized JAX model (whose hot loop is
//! the Pallas blocked-linear kernel) to **HLO text** once at build time;
//! this module loads that text via the `xla` crate, compiles it on the PJRT
//! CPU client and executes it with integer tensors. It serves as the
//! independent functional oracle — the role the paper's x86 simulation mode
//! plays against the AIE firmware — and never sits on the request path.
//!
//! Interchange is HLO *text*, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! In hermetic builds the `xla` dependency resolves to the in-repo stub
//! crate (`rust/xla_stub`), which type-checks identically but refuses to
//! create a client at runtime; swap the path dependency for a real xla-rs
//! checkout to execute artifacts.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A PJRT CPU client with a cache of compiled executables keyed by path.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Create the CPU client (the only backend in this environment).
    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached per path).
    pub fn load(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref().to_path_buf();
        if !self.cache.contains_key(&path) {
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            self.cache.insert(path, exe);
        }
        Ok(())
    }

    /// Execute an artifact on i32 input buffers of the given shapes.
    ///
    /// The aot.py convention: all inputs are i32 tensors (converted to the
    /// quantized dtype inside the graph), the output is a 1-tuple of an i32
    /// tensor (widened back), lowered with `return_tuple=True`.
    pub fn execute_i32(
        &mut self,
        path: impl AsRef<Path>,
        inputs: &[(&[i32], &[usize])],
    ) -> Result<Vec<i32>> {
        let exe_path = path.as_ref().to_path_buf();
        self.load(&exe_path)?;
        let exe = &self.cache[&exe_path];
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                let lit = xla::Literal::vec1(data);
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).context("reshaping input literal")
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .context("executing artifact")?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple result")?;
        out.to_vec::<i32>().context("reading i32 output")
    }
}

impl std::fmt::Debug for PjrtRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtRuntime")
            .field("platform", &self.client.platform_name())
            .field("cached", &self.cache.len())
            .finish()
    }
}
