//! Runtime oracles: independent model executions that gate the compiled
//! firmware bit-exactly, plus the paper's `predict()` interface.
//!
//! Two backends implement [`oracle::OracleBackend`]:
//!
//! * [`ReferenceOracle`] — hermetic pure-Rust execution of the logical
//!   (unpacked) model straight from the exporter JSON. Always compiled, no
//!   artifacts or external toolchains needed; this is what the tier-1
//!   `oracle_bitexact` tests run on a fresh checkout.
//! * [`PjrtRuntime`] / [`oracle::PjrtOracle`] (`--features pjrt`) — the
//!   AOT-lowered JAX model (built by `python/compile/aot.py`, hot loop in
//!   the Pallas blocked-linear kernel) executed through the PJRT CPU
//!   client. In hermetic builds the `xla` dependency resolves to the
//!   in-repo stub crate; see `rust/xla_stub`.

pub mod oracle;
pub mod predict;
pub mod reference;

#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use oracle::{OracleBackend, OracleReport};
pub use predict::{Mode, Predictor};
pub use reference::ReferenceOracle;

#[cfg(feature = "pjrt")]
pub use pjrt::PjrtRuntime;
