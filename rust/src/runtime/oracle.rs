//! Bit-exactness oracle: firmware simulator vs an independent backend.
//!
//! The paper's toolflow guarantees outputs "bit-exact with respect to the
//! quantized hls4ml model"; our equivalent gate compares the Rust firmware
//! simulator against an [`OracleBackend`]:
//!
//! * [`crate::runtime::ReferenceOracle`] — hermetic, pure-Rust execution of
//!   the logical model (always available; what `cargo test` runs on a fresh
//!   checkout).
//! * [`PjrtOracle`] (`--features pjrt`) — the AOT-lowered JAX model executed
//!   through the PJRT CPU client (itself pytest-checked against the Pallas
//!   kernel and the pure-jnp reference).
//!
//! A model passes when every output element matches exactly.

use crate::codegen::firmware::Firmware;
use crate::sim::functional::{execute, Activation};
use anyhow::{ensure, Context, Result};

/// An independent implementation of the model that the firmware simulator
/// is compared against element-by-element.
pub trait OracleBackend {
    /// Human-readable backend identity for reports and error messages.
    fn describe(&self) -> String;
    /// Run `input` (`[batch, f_in]` widened ints, row-major) and return the
    /// flat `[batch, f_out]` output.
    fn execute_oracle(&mut self, input: &Activation) -> Result<Vec<i32>>;
}

/// Result of one oracle comparison.
#[derive(Debug, Clone)]
pub struct OracleReport {
    pub backend: String,
    pub batch: usize,
    pub features_out: usize,
    pub elements: usize,
    pub mismatches: usize,
    /// First few mismatch positions (index, firmware, oracle) for debugging.
    pub first_mismatches: Vec<(usize, i32, i32)>,
}

impl OracleReport {
    pub fn bit_exact(&self) -> bool {
        self.mismatches == 0
    }
}

/// Run `input` through both the firmware simulator and the backend and
/// compare bit-exactly.
pub fn compare(
    backend: &mut dyn OracleBackend,
    fw: &Firmware,
    input: &Activation,
) -> Result<OracleReport> {
    ensure!(input.batch == fw.batch, "firmware is specialized to batch {}", fw.batch);
    let fw_out = execute(fw, input).context("firmware simulation")?;
    let oracle_out = backend
        .execute_oracle(input)
        .with_context(|| format!("oracle execution ({})", backend.describe()))?;
    ensure!(
        oracle_out.len() == fw_out.data.len(),
        "oracle {} produced {} elements, firmware {}",
        backend.describe(),
        oracle_out.len(),
        fw_out.data.len()
    );
    let mut mismatches = 0usize;
    let mut first = Vec::new();
    for (i, (&a, &b)) in fw_out.data.iter().zip(&oracle_out).enumerate() {
        if a != b {
            mismatches += 1;
            if first.len() < 8 {
                first.push((i, a, b));
            }
        }
    }
    Ok(OracleReport {
        backend: backend.describe(),
        batch: input.batch,
        features_out: fw_out.features,
        elements: fw_out.data.len(),
        mismatches,
        first_mismatches: first,
    })
}

/// PJRT-backed oracle over an AOT-compiled HLO artifact.
///
/// Artifact convention (see `python/compile/aot.py`): a single i32 input of
/// shape `[batch, f_in]`, weights baked as constants from the same exporter
/// JSON the Rust compiler consumed, i32 output `[batch, f_out]`.
#[cfg(feature = "pjrt")]
pub struct PjrtOracle {
    runtime: super::pjrt::PjrtRuntime,
    artifact: std::path::PathBuf,
}

#[cfg(feature = "pjrt")]
impl PjrtOracle {
    pub fn new(artifact: impl Into<std::path::PathBuf>) -> Result<PjrtOracle> {
        Ok(PjrtOracle { runtime: super::pjrt::PjrtRuntime::cpu()?, artifact: artifact.into() })
    }

    pub fn platform(&self) -> String {
        self.runtime.platform()
    }
}

#[cfg(feature = "pjrt")]
impl OracleBackend for PjrtOracle {
    fn describe(&self) -> String {
        format!("pjrt({})", self.artifact.display())
    }

    fn execute_oracle(&mut self, input: &Activation) -> Result<Vec<i32>> {
        self.runtime
            .execute_i32(&self.artifact, &[(&input.data, &[input.batch, input.features])])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::CompileConfig;
    use crate::harness::models::{mlp_spec, synth_model};
    use crate::passes::compile;
    use crate::runtime::ReferenceOracle;
    use crate::util::Pcg32;

    fn compiled(name: &str, dims: &[usize], batch: usize) -> (Firmware, ReferenceOracle) {
        let json = synth_model(name, &mlp_spec(dims, crate::arch::Dtype::I8), 6);
        let mut cfg = CompileConfig::default();
        cfg.batch = batch;
        cfg.tiles_per_layer = Some(4);
        let fw = compile(&json, cfg).unwrap().firmware.unwrap();
        let oracle = ReferenceOracle::from_model(&json).unwrap();
        (fw, oracle)
    }

    fn random_input(fw: &Firmware, seed: u64) -> Activation {
        let (lo, hi) = fw.input_quant.dtype.range();
        let mut rng = Pcg32::seed_from_u64(seed);
        Activation::new(
            fw.batch,
            fw.input_features(),
            (0..fw.batch * fw.input_features()).map(|_| rng.gen_i32_in(lo, hi)).collect(),
        )
        .unwrap()
    }

    #[test]
    fn firmware_matches_reference_backend() {
        let (fw, mut oracle) = compiled("oracle_unit", &[48, 32, 8], 6);
        let x = random_input(&fw, 3);
        let report = compare(&mut oracle, &fw, &x).unwrap();
        assert!(report.bit_exact(), "{report:?}");
        assert_eq!(report.elements, 6 * 8);
        assert!(report.backend.contains("reference"));
    }

    #[test]
    fn corruption_is_detected() {
        let (mut fw, mut oracle) = compiled("oracle_corrupt", &[32, 16], 4);
        // Poison the tail tile's bias after compilation and feed zeros: the
        // firmware output saturates to the rail while the oracle stays in
        // the small-bias band, so the comparator must flag every row
        // (guards against a vacuously-green comparison).
        for k in &mut fw.layers[0].kernels {
            if k.is_tail && k.cas_row == 0 {
                k.bias[0] += 100_000_000;
            }
        }
        let x = Activation::zeros(fw.batch, fw.input_features());
        let report = compare(&mut oracle, &fw, &x).unwrap();
        assert!(!report.bit_exact(), "corrupted bias must be detected");
        assert!(!report.first_mismatches.is_empty());
    }

    #[test]
    fn wrong_batch_rejected() {
        let (fw, mut oracle) = compiled("oracle_batch", &[16, 8], 4);
        let x = Activation::zeros(3, 16);
        assert!(compare(&mut oracle, &fw, &x).is_err());
    }
}
