//! Bit-exactness oracle: firmware simulator vs PJRT-executed JAX model.
//!
//! The paper's toolflow guarantees outputs "bit-exact with respect to the
//! quantized hls4ml model"; our equivalent gate compares the Rust firmware
//! simulator against the AOT-lowered JAX model (which itself is pytest-
//! checked against the Pallas kernel and the pure-jnp reference). A model
//! passes when every output element matches exactly.

use crate::codegen::firmware::Firmware;
use crate::sim::functional::{execute, Activation};
use anyhow::{ensure, Context, Result};
use std::path::Path;

use super::PjrtRuntime;

/// Result of one oracle comparison.
#[derive(Debug, Clone)]
pub struct OracleReport {
    pub batch: usize,
    pub features_out: usize,
    pub elements: usize,
    pub mismatches: usize,
    /// First few mismatch positions (index, firmware, oracle) for debugging.
    pub first_mismatches: Vec<(usize, i32, i32)>,
}

impl OracleReport {
    pub fn bit_exact(&self) -> bool {
        self.mismatches == 0
    }
}

/// Run `input` through both the firmware simulator and the HLO artifact and
/// compare bit-exactly.
///
/// Artifact convention (see `python/compile/aot.py`): a single i32 input of
/// shape `[batch, f_in]`, weights baked as constants from the same exporter
/// JSON the Rust compiler consumed, i32 output `[batch, f_out]`.
pub fn compare(
    runtime: &mut PjrtRuntime,
    artifact: impl AsRef<Path>,
    fw: &Firmware,
    input: &Activation,
) -> Result<OracleReport> {
    ensure!(input.batch == fw.batch, "artifact is specialized to batch {}", fw.batch);
    let fw_out = execute(fw, input).context("firmware simulation")?;
    let oracle_out = runtime
        .execute_i32(artifact, &[(&input.data, &[input.batch, input.features])])
        .context("PJRT oracle execution")?;
    ensure!(
        oracle_out.len() == fw_out.data.len(),
        "oracle produced {} elements, firmware {}",
        oracle_out.len(),
        fw_out.data.len()
    );
    let mut mismatches = 0usize;
    let mut first = Vec::new();
    for (i, (&a, &b)) in fw_out.data.iter().zip(&oracle_out).enumerate() {
        if a != b {
            mismatches += 1;
            if first.len() < 8 {
                first.push((i, a, b));
            }
        }
    }
    Ok(OracleReport {
        batch: input.batch,
        features_out: fw_out.features,
        elements: fw_out.data.len(),
        mismatches,
        first_mismatches: first,
    })
}
