//! The paper's `predict()` interface (§IV-B): one entry point, two
//! execution modes — fast functional **x86** simulation (here: the
//! AOT-lowered JAX model through PJRT) and the **aie** mode (here: the
//! bit-exact firmware simulator, which is also what reports hardware-level
//! statistics through the cycle model). Optional float I/O quantizes inputs
//! and dequantizes outputs at the boundary, like the generated AIE project.

use crate::codegen::firmware::Firmware;
use crate::sim::engine::{analyze, EngineModel, PerfReport};
use crate::sim::functional::{dequantize_output, execute, quantize_input, Activation};
use anyhow::{ensure, Context, Result};
use std::path::PathBuf;

use super::PjrtRuntime;

/// Execution mode for [`Predictor::predict`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Fast functional validation through the PJRT-compiled JAX model.
    X86,
    /// The firmware simulator (cycle model available via [`Predictor::profile`]).
    Aie,
}

/// A compiled model plus (optionally) its AOT artifact.
pub struct Predictor {
    fw: Firmware,
    artifact: Option<PathBuf>,
    runtime: Option<PjrtRuntime>,
}

impl Predictor {
    pub fn new(fw: Firmware, artifact: Option<PathBuf>) -> Predictor {
        Predictor { fw, artifact, runtime: None }
    }

    pub fn firmware(&self) -> &Firmware {
        &self.fw
    }

    /// Integer predict: `[batch, f_in]` widened ints in, widened ints out.
    pub fn predict(&mut self, x: &Activation, mode: Mode) -> Result<Activation> {
        ensure!(x.batch == self.fw.batch, "predictor is specialized to batch {}", self.fw.batch);
        match mode {
            Mode::Aie => execute(&self.fw, x),
            Mode::X86 => {
                let artifact = self
                    .artifact
                    .clone()
                    .context("x86 mode needs an AOT artifact (run `make artifacts`)")?;
                if self.runtime.is_none() {
                    self.runtime = Some(PjrtRuntime::cpu()?);
                }
                let rt = self.runtime.as_mut().unwrap();
                let out = rt.execute_i32(&artifact, &[(&x.data, &[x.batch, x.features])])?;
                Activation::new(x.batch, self.fw.output_features(), out)
            }
        }
    }

    /// Float predict: quantize at the input, dequantize at the output
    /// (the paper's optional NumPy float I/O).
    pub fn predict_f64(&mut self, x: &[f64], mode: Mode) -> Result<Vec<f64>> {
        let qx = quantize_input(&self.fw, x, self.fw.batch)?;
        let y = self.predict(&qx, mode)?;
        Ok(dequantize_output(&self.fw, &y))
    }

    /// Hardware-level statistics from the cycle model (the aie-mode
    /// profiling report of §IV-B: throughput, tile utilization, latency).
    pub fn profile(&self) -> PerfReport {
        analyze(&self.fw, &EngineModel::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dtype;
    use crate::harness::models::compile_mlp;
    use crate::util::Pcg32;

    fn predictor() -> Predictor {
        let m = compile_mlp("pred", &[32, 16, 8], Dtype::I8, 4, Some((1, 2))).unwrap();
        Predictor::new(m.firmware.unwrap(), None)
    }

    #[test]
    fn aie_mode_runs_without_artifact() {
        let mut p = predictor();
        let mut rng = Pcg32::seed_from_u64(1);
        let x = Activation::new(4, 32, (0..128).map(|_| rng.gen_i32_in(-128, 127)).collect())
            .unwrap();
        let y = p.predict(&x, Mode::Aie).unwrap();
        assert_eq!((y.batch, y.features), (4, 8));
    }

    #[test]
    fn x86_mode_requires_artifact() {
        let mut p = predictor();
        let x = Activation::zeros(4, 32);
        let err = p.predict(&x, Mode::X86).unwrap_err().to_string();
        assert!(err.contains("artifact"), "{err}");
    }

    #[test]
    fn float_io_roundtrip() {
        let mut p = predictor();
        let x: Vec<f64> = (0..4 * 32).map(|i| (i as f64 - 64.0) / 128.0).collect();
        let y = p.predict_f64(&x, Mode::Aie).unwrap();
        assert_eq!(y.len(), 4 * 8);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn profile_reports() {
        let p = predictor();
        let rep = p.profile();
        assert!(rep.throughput_tops > 0.0);
        assert_eq!(rep.layers.len(), 2);
    }

    #[test]
    fn wrong_batch_rejected() {
        let mut p = predictor();
        let x = Activation::zeros(3, 32);
        assert!(p.predict(&x, Mode::Aie).is_err());
    }
}
