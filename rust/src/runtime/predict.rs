//! The paper's `predict()` interface (§IV-B): one entry point, two
//! execution modes — fast functional **x86** validation and the **aie**
//! mode (the bit-exact firmware simulator, which also reports hardware-level
//! statistics through the cycle model).
//!
//! The x86 half is backend-pluggable: the hermetic default executes the
//! logical model through [`ReferenceOracle`]; with `--features pjrt` an
//! AOT-lowered JAX artifact runs through the PJRT CPU client instead.
//! Optional float I/O quantizes inputs and dequantizes outputs at the
//! boundary, like the generated AIE project.

use crate::codegen::firmware::Firmware;
use crate::sim::engine::{analyze, EngineModel, PerfReport};
use crate::sim::functional::{dequantize_output, execute, quantize_input, Activation};
use anyhow::{bail, ensure, Result};
use std::path::PathBuf;

use super::reference::ReferenceOracle;

/// Execution mode for [`Predictor::predict`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Fast functional validation through an independent x86 backend
    /// (reference oracle, or the PJRT-compiled JAX model with `pjrt`).
    X86,
    /// The firmware simulator (cycle model available via [`Predictor::profile`]).
    Aie,
}

enum X86Backend {
    /// Hermetic pure-Rust execution of the logical model.
    Reference(ReferenceOracle),
    /// AOT artifact through the PJRT CPU client (lazily created).
    #[cfg(feature = "pjrt")]
    Pjrt { artifact: PathBuf, runtime: Option<super::pjrt::PjrtRuntime> },
}

/// A compiled model plus (optionally) an independent x86 backend.
pub struct Predictor {
    fw: Firmware,
    backend: Option<X86Backend>,
    /// The artifact path as given, kept for diagnostics in builds where the
    /// PJRT backend is compiled out.
    artifact: Option<PathBuf>,
}

impl Predictor {
    /// Predictor over an optional AOT artifact. The artifact is executed
    /// through PJRT and therefore needs `--features pjrt`; in default builds
    /// x86 mode requires [`Predictor::with_reference`] instead.
    pub fn new(fw: Firmware, artifact: Option<PathBuf>) -> Predictor {
        #[cfg(feature = "pjrt")]
        let backend = artifact
            .clone()
            .map(|artifact| X86Backend::Pjrt { artifact, runtime: None });
        #[cfg(not(feature = "pjrt"))]
        let backend = None;
        Predictor { fw, backend, artifact }
    }

    /// Predictor whose x86 mode runs the hermetic reference oracle.
    pub fn with_reference(fw: Firmware, oracle: ReferenceOracle) -> Predictor {
        Predictor { fw, backend: Some(X86Backend::Reference(oracle)), artifact: None }
    }

    pub fn firmware(&self) -> &Firmware {
        &self.fw
    }

    /// Integer predict: `[batch, f_in]` widened ints in, widened ints out.
    pub fn predict(&mut self, x: &Activation, mode: Mode) -> Result<Activation> {
        ensure!(x.batch == self.fw.batch, "predictor is specialized to batch {}", self.fw.batch);
        match mode {
            Mode::Aie => execute(&self.fw, x),
            Mode::X86 => {
                let out = match self.backend.as_mut() {
                    Some(X86Backend::Reference(oracle)) => oracle.execute(x)?.data,
                    #[cfg(feature = "pjrt")]
                    Some(X86Backend::Pjrt { artifact, runtime }) => {
                        if runtime.is_none() {
                            *runtime = Some(super::pjrt::PjrtRuntime::cpu()?);
                        }
                        runtime
                            .as_mut()
                            .unwrap()
                            .execute_i32(&*artifact, &[(&x.data, &[x.batch, x.features])])?
                    }
                    None => bail!(
                        "x86 mode needs an AOT artifact executed through PJRT \
                         (build with --features pjrt and run `make artifacts`) or a \
                         hermetic reference oracle (Predictor::with_reference); \
                         artifact given: {:?}",
                        self.artifact
                    ),
                };
                Activation::new(x.batch, self.fw.output_features(), out)
            }
        }
    }

    /// Float predict: quantize at the input, dequantize at the output
    /// (the paper's optional NumPy float I/O).
    pub fn predict_f64(&mut self, x: &[f64], mode: Mode) -> Result<Vec<f64>> {
        let qx = quantize_input(&self.fw, x, self.fw.batch)?;
        let y = self.predict(&qx, mode)?;
        Ok(dequantize_output(&self.fw, &y))
    }

    /// Hardware-level statistics from the cycle model (the aie-mode
    /// profiling report of §IV-B: throughput, tile utilization, latency).
    pub fn profile(&self) -> PerfReport {
        analyze(&self.fw, &EngineModel::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dtype;
    use crate::frontend::CompileConfig;
    use crate::harness::models::{compile_mlp, mlp_spec, synth_model};
    use crate::passes::compile;
    use crate::util::Pcg32;

    fn predictor() -> Predictor {
        let m = compile_mlp("pred", &[32, 16, 8], Dtype::I8, 4, Some((1, 2))).unwrap();
        Predictor::new(m.firmware.unwrap(), None)
    }

    fn reference_predictor(name: &str) -> Predictor {
        let json = synth_model(name, &mlp_spec(&[32, 16, 8], Dtype::I8), 6);
        let mut cfg = CompileConfig::default();
        cfg.batch = 4;
        cfg.tiles_per_layer = Some(2);
        let fw = compile(&json, cfg).unwrap().firmware.unwrap();
        let oracle = ReferenceOracle::from_model(&json).unwrap();
        Predictor::with_reference(fw, oracle)
    }

    #[test]
    fn aie_mode_runs_without_artifact() {
        let mut p = predictor();
        let mut rng = Pcg32::seed_from_u64(1);
        let x = Activation::new(4, 32, (0..128).map(|_| rng.gen_i32_in(-128, 127)).collect())
            .unwrap();
        let y = p.predict(&x, Mode::Aie).unwrap();
        assert_eq!((y.batch, y.features), (4, 8));
    }

    #[test]
    fn x86_mode_requires_artifact() {
        let mut p = predictor();
        let x = Activation::zeros(4, 32);
        let err = p.predict(&x, Mode::X86).unwrap_err().to_string();
        assert!(err.contains("artifact"), "{err}");
    }

    #[test]
    fn x86_reference_mode_matches_aie() {
        let mut p = reference_predictor("pred_ref");
        let mut rng = Pcg32::seed_from_u64(2);
        let x = Activation::new(4, 32, (0..128).map(|_| rng.gen_i32_in(-128, 127)).collect())
            .unwrap();
        let aie = p.predict(&x, Mode::Aie).unwrap();
        let x86 = p.predict(&x, Mode::X86).unwrap();
        assert_eq!(aie.data, x86.data);
        // Float I/O agrees under both modes as well.
        let xf: Vec<f64> = (0..4 * 32).map(|i| (i % 97) as f64 / 97.0 - 0.5).collect();
        let yf_aie = p.predict_f64(&xf, Mode::Aie).unwrap();
        let yf_x86 = p.predict_f64(&xf, Mode::X86).unwrap();
        assert_eq!(yf_aie, yf_x86);
    }

    #[test]
    fn float_io_roundtrip() {
        let mut p = predictor();
        let x: Vec<f64> = (0..4 * 32).map(|i| (i as f64 - 64.0) / 128.0).collect();
        let y = p.predict_f64(&x, Mode::Aie).unwrap();
        assert_eq!(y.len(), 4 * 8);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn profile_reports() {
        let p = predictor();
        let rep = p.profile();
        assert!(rep.throughput_tops > 0.0);
        assert_eq!(rep.layers.len(), 2);
    }

    #[test]
    fn wrong_batch_rejected() {
        let mut p = predictor();
        let x = Activation::zeros(3, 32);
        assert!(p.predict(&x, Mode::Aie).is_err());
    }
}
