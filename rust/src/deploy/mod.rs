//! SLO-driven deployment: turn a compiled model into a concrete,
//! executable serving fleet.
//!
//! The compile pipeline answers "how fast is one copy of this model on one
//! array"; this subsystem answers the production question on top of it —
//! *how many copies, cut how, batched how, on which arrays, to serve a
//! target load within a latency budget*. It has three parts:
//!
//! * [`planner`] — the capacity planner. Given a model, a [`Fleet`]
//!   description (array count per device generation) and an [`Slo`]
//!   (target samples/s + latency budget), it searches deployment
//!   candidates — partition count K (via [`crate::partition`]),
//!   replication factor R, firmware batch, queue depth — scoring each with
//!   the calibrated [`crate::sim::engine`] /
//!   [`crate::partition::analyze_pipeline`] models and the *placed* tile
//!   footprint ([`crate::codegen::firmware::PlacementFootprint`], not the
//!   old tile-count approximation), and returns ranked
//!   [`DeploymentPlan`]s or an [`Infeasibility`] diagnosis.
//! * [`fleet`] — the executor. [`FleetServer`] runs a plan: R replicas of
//!   [`crate::coordinator::Server`] / [`crate::coordinator::PipelineServer`]
//!   behind the router's least-loaded dispatch policy
//!   ([`crate::coordinator::least_loaded`]), with per-replica metrics,
//!   drain-and-replace hot reload (the paper's RTP-reload story lifted to
//!   fleet scope) and replica-by-replica bit-exactness verification
//!   against [`crate::runtime::ReferenceOracle`].
//! * [`autoscale`] — the feedback loop. [`Autoscaler`] differences live
//!   serving snapshots into SLO-burn windows (arrival rate, shed ratio,
//!   queue depth, p99-over-budget) and decides when to grow or shrink R,
//!   reusing the planner's costed per-replica rate as its capacity prior
//!   and the fleet/continuous servers' `scale_to` drain machinery to act.
//!
//! An R = 1 / K = 1 plan degenerates to the plain single-array
//! [`crate::coordinator::Server`] — same firmware bytes, same metrics
//! shape — so the fleet layer adds no cost until replication is asked for.

pub mod autoscale;
pub mod fleet;
pub mod planner;

pub use autoscale::{Autoscaler, AutoscalerConfig, ReplanContext, ScaleDecision, SloBurn};
pub use fleet::{FleetClient, FleetMetricsReport, FleetServer, ReplicaMetrics};
pub use planner::{plan, plan_with, DeploymentPlan, PlannerOptions};

use crate::arch::Device;
use anyhow::{ensure, Result};

/// The service-level objective a deployment must meet.
///
/// * `target_sps` — sustained samples/second the fleet must absorb.
/// * `latency_budget_us` — bound on the planner's per-request latency
///   model: batch assembly at the target arrival rate, plus one
///   head-of-line batch interval, plus the empty-pipeline fill latency.
#[derive(Debug, Clone, Copy)]
pub struct Slo {
    pub target_sps: f64,
    pub latency_budget_us: f64,
}

impl Slo {
    pub fn new(target_sps: f64, latency_budget_us: f64) -> Slo {
        Slo { target_sps, latency_budget_us }
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.target_sps.is_finite() && self.target_sps > 0.0,
            "SLO target must be a positive samples/s rate, got {}",
            self.target_sps
        );
        ensure!(
            self.latency_budget_us.is_finite() && self.latency_budget_us > 0.0,
            "SLO latency budget must be positive µs, got {}",
            self.latency_budget_us
        );
        Ok(())
    }
}

/// A pool of identical arrays of one device generation.
#[derive(Debug, Clone)]
pub struct FleetGroup {
    /// Device name resolvable by [`Device::by_name`] ("vek280", "vek385").
    pub device: String,
    /// Arrays of that device available to the deployment.
    pub arrays: usize,
}

/// The hardware the planner may deploy onto: one or more device groups
/// (the per-generation AIE-ML / AIE-MLv2 mix).
#[derive(Debug, Clone)]
pub struct Fleet {
    pub groups: Vec<FleetGroup>,
}

impl Fleet {
    /// A fleet of `arrays` identical `device` arrays.
    pub fn homogeneous(device: &str, arrays: usize) -> Fleet {
        Fleet { groups: vec![FleetGroup { device: device.to_string(), arrays }] }
    }

    pub fn total_arrays(&self) -> usize {
        self.groups.iter().map(|g| g.arrays).sum()
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(!self.groups.is_empty(), "fleet has no device groups");
        for g in &self.groups {
            ensure!(
                Device::by_name(&g.device).is_some(),
                "fleet names unknown device '{}'",
                g.device
            );
            ensure!(g.arrays >= 1, "fleet group '{}' has no arrays", g.device);
        }
        Ok(())
    }
}

/// What the planner concluded.
#[derive(Debug, Clone)]
pub enum PlanOutcome {
    /// Ranked plans, best first. Never empty.
    Feasible(Vec<DeploymentPlan>),
    /// No candidate met the SLO; the diagnosis says how close the best
    /// ones came and why each candidate fell short.
    Infeasible(Infeasibility),
}

impl PlanOutcome {
    /// The top-ranked plan, if any candidate met the SLO.
    pub fn best(&self) -> Option<&DeploymentPlan> {
        match self {
            PlanOutcome::Feasible(plans) => plans.first(),
            PlanOutcome::Infeasible(_) => None,
        }
    }
}

/// Why no deployment met the SLO, with the closest the search came on
/// each axis — enough to tell a throughput-bound miss ("buy more arrays
/// or relax target_sps") from a latency-bound one ("no configuration
/// fills, queues and drains a batch inside the budget").
#[derive(Debug, Clone)]
pub struct Infeasibility {
    pub target_sps: f64,
    pub latency_budget_us: f64,
    /// Best sustained samples/s any candidate reaches within the fleet's
    /// array budget (0 when nothing compiled).
    pub best_sps: f64,
    /// Lowest modeled per-request latency among candidates whose
    /// throughput fits the fleet (0 when none does) — so a latency-bound
    /// diagnosis always quotes a latency that genuinely misses the budget.
    pub best_latency_us: f64,
    /// Candidates that compiled and were scored.
    pub candidates: usize,
    /// One line per rejected candidate: compile failure or the SLO axis
    /// it missed.
    pub reasons: Vec<String>,
}

impl Infeasibility {
    /// Which axis binds: true when even the best candidate's throughput
    /// falls short of the target (add arrays / relax target); false when
    /// throughput is reachable but latency is not.
    pub fn throughput_bound(&self) -> bool {
        self.best_sps < self.target_sps
    }
}

impl std::fmt::Display for Infeasibility {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "no deployment meets SLO {:.0} samples/s within {:.1} µs ({} candidate(s) scored)",
            self.target_sps, self.latency_budget_us, self.candidates
        )?;
        if self.candidates == 0 {
            writeln!(f, "  nothing compiled for this fleet:")?;
        } else if self.throughput_bound() {
            writeln!(
                f,
                "  throughput-bound: best achievable {:.0} samples/s ({:.1}% of target) — \
                 add arrays, allow more partitions, or relax the target",
                self.best_sps,
                100.0 * self.best_sps / self.target_sps
            )?;
        } else {
            writeln!(
                f,
                "  latency-bound: throughput is reachable but the best modeled latency is \
                 {:.1} µs against a {:.1} µs budget — shrink the batch or relax the budget",
                self.best_latency_us, self.latency_budget_us
            )?;
        }
        for r in &self.reasons {
            writeln!(f, "  - {r}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slo_and_fleet_validation() {
        assert!(Slo::new(1e6, 100.0).validate().is_ok());
        assert!(Slo::new(0.0, 100.0).validate().is_err());
        assert!(Slo::new(1e6, -1.0).validate().is_err());
        assert!(Slo::new(f64::NAN, 100.0).validate().is_err());
        assert!(Fleet::homogeneous("vek280", 4).validate().is_ok());
        assert!(Fleet::homogeneous("h100", 4).validate().is_err());
        assert!(Fleet::homogeneous("vek280", 0).validate().is_err());
        assert!(Fleet { groups: vec![] }.validate().is_err());
        let mixed = Fleet {
            groups: vec![
                FleetGroup { device: "vek280".into(), arrays: 2 },
                FleetGroup { device: "vek385".into(), arrays: 3 },
            ],
        };
        assert!(mixed.validate().is_ok());
        assert_eq!(mixed.total_arrays(), 5);
    }

    #[test]
    fn infeasibility_diagnosis_names_the_binding_axis() {
        let mut d = Infeasibility {
            target_sps: 1e6,
            latency_budget_us: 50.0,
            best_sps: 2e5,
            best_latency_us: 40.0,
            candidates: 3,
            reasons: vec!["vek280/K=1/batch=16: needs R=5, capacity 2".into()],
        };
        assert!(d.throughput_bound());
        let text = d.to_string();
        assert!(text.contains("throughput-bound"), "{text}");
        assert!(text.contains("needs R=5"), "{text}");
        d.best_sps = 2e6;
        d.best_latency_us = 80.0;
        assert!(!d.throughput_bound());
        assert!(d.to_string().contains("latency-bound"));
    }
}
