//! Replicated fleet serving: execute a [`DeploymentPlan`].
//!
//! [`FleetServer`] runs R identical replicas of the compiled pipeline —
//! each a plain [`Server`] (K = 1) or a [`PipelineServer`] (K > 1) — and
//! dispatches every request to the replica with the fewest in-flight
//! requests (the router's [`LeastLoaded`] policy, ties rotating
//! round-robin). Dispatch is work-conserving by construction: a request
//! only lands on a busy replica when every replica is at least as busy.
//!
//! Operations the single-server coordinator cannot offer:
//!
//! * **aggregated metrics** — per-replica dispatch counts and
//!   [`MetricsReport`]s plus a fleet-level merge
//!   ([`MetricsReport::merged`]);
//! * **drain-and-replace hot reload** — [`FleetServer::reload`] swaps in
//!   new firmware (the paper's RTP story: new coefficients, same graph)
//!   one replica at a time, so the fleet keeps serving throughout;
//! * **replica-by-replica bit-exactness** —
//!   [`FleetServer::verify_bit_exact`] probes every replica directly
//!   against [`ReferenceOracle::execute_all`], so a corrupted replica
//!   cannot hide behind its healthy peers.

use super::planner::DeploymentPlan;
use crate::coordinator::{LeastLoaded, MetricsReport, PipelineServer, Server};
use crate::partition::PartitionedFirmware;
use crate::runtime::ReferenceOracle;
use crate::sim::functional::Activation;
use crate::util::Pcg32;
use anyhow::{bail, ensure, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

/// One replica's serving backend: the degenerate K = 1 pipeline runs the
/// plain single-array server (same firmware bytes, same metrics shape);
/// deeper pipelines run the multi-array stage-thread server.
enum ReplicaBackend {
    Single(Server),
    Pipelined(PipelineServer),
}

impl ReplicaBackend {
    fn spawn(
        pfw: &Arc<PartitionedFirmware>,
        max_wait: Duration,
        queue_depth: usize,
    ) -> ReplicaBackend {
        if pfw.k() == 1 {
            let fw = Arc::new(pfw.partitions[0].clone());
            ReplicaBackend::Single(Server::spawn(fw, max_wait, queue_depth))
        } else {
            ReplicaBackend::Pipelined(PipelineServer::spawn(pfw.clone(), max_wait, queue_depth))
        }
    }

    fn client(&self) -> ReplicaClient {
        match self {
            ReplicaBackend::Single(s) => ReplicaClient::Single(s.client.clone()),
            ReplicaBackend::Pipelined(p) => ReplicaClient::Pipelined(p.client.clone()),
        }
    }

    fn input_features(&self) -> usize {
        match self {
            ReplicaBackend::Single(s) => s.firmware().input_features(),
            ReplicaBackend::Pipelined(p) => p.firmware().input_features(),
        }
    }

    fn metrics(&self) -> MetricsReport {
        match self {
            ReplicaBackend::Single(s) => s.metrics(),
            ReplicaBackend::Pipelined(p) => p.metrics(),
        }
    }

    fn shutdown(self) -> MetricsReport {
        match self {
            ReplicaBackend::Single(s) => s.shutdown(),
            ReplicaBackend::Pipelined(p) => p.shutdown(),
        }
    }
}

/// A cloned handle into one replica's request queue.
enum ReplicaClient {
    Single(crate::coordinator::Client),
    Pipelined(crate::coordinator::PipelineClient),
}

impl ReplicaClient {
    fn infer_multi(&self, features: Vec<i32>) -> Result<Vec<Vec<i32>>> {
        match self {
            ReplicaClient::Single(c) => c.infer_multi(features),
            ReplicaClient::Pipelined(c) => c.infer_multi(features),
        }
    }
}

/// One live replica slot.
struct ReplicaSlot {
    backend: ReplicaBackend,
    inflight: Arc<AtomicUsize>,
    /// Dispatch attempts routed here, including ones lost to a reload race.
    attempts: Arc<AtomicU64>,
    /// Requests this slot actually answered.
    dispatched: Arc<AtomicU64>,
}

impl ReplicaSlot {
    fn new(backend: ReplicaBackend) -> ReplicaSlot {
        ReplicaSlot {
            backend,
            inflight: Arc::new(AtomicUsize::new(0)),
            attempts: Arc::new(AtomicU64::new(0)),
            dispatched: Arc::new(AtomicU64::new(0)),
        }
    }
}

/// State shared between the fleet and its client handles.
struct FleetInner {
    slots: RwLock<Vec<ReplicaSlot>>,
    current: RwLock<Arc<PartitionedFirmware>>,
    policy: LeastLoaded,
}

/// A client handle to the fleet (cheap to clone; thread-safe). Each call
/// picks the least-loaded replica at dispatch time, so concurrent clients
/// spread across the fleet automatically.
#[derive(Clone)]
pub struct FleetClient {
    inner: Arc<FleetInner>,
}

impl FleetClient {
    /// Submit one sample and wait for the primary (first) model output.
    pub fn infer(&self, features: Vec<i32>) -> Result<Vec<i32>> {
        let mut outs = self.infer_multi(features)?;
        Ok(outs.swap_remove(0))
    }

    /// Submit one sample and wait for every model output, in sink order.
    ///
    /// A replica picked here can retire between the pick and the send (a
    /// concurrent [`FleetServer::reload`] drains what that replica already
    /// queued, then stops accepting); the only error a replica client can
    /// return is that stopped-replica condition — execution itself never
    /// surfaces as `Err` — so the request is transparently re-dispatched
    /// to a live replica instead of the swap leaking to the caller.
    pub fn infer_multi(&self, features: Vec<i32>) -> Result<Vec<Vec<i32>>> {
        const DISPATCH_RETRIES: usize = 4;
        let mut last_err = None;
        // Slot indices that already failed this request: a stopped replica
        // has 0 in-flight, so without masking the least-loaded pick would
        // deterministically re-select it on every retry.
        let mut failed: Vec<usize> = Vec::new();
        for _ in 0..DISPATCH_RETRIES {
            // Pick under the read lock, then release it before the blocking
            // inference wait (a hot reload may swap the slots meanwhile;
            // our cloned client keeps the old replica alive through its
            // drain).
            let (pick, client, inflight, served) = {
                let slots = self.inner.slots.read().unwrap();
                ensure!(!slots.is_empty(), "fleet is shut down");
                let expect = slots[0].backend.input_features();
                ensure!(
                    features.len() == expect,
                    "fleet expects {expect} features, got {}",
                    features.len()
                );
                let loads: Vec<usize> = slots
                    .iter()
                    .enumerate()
                    .map(|(i, s)| {
                        if failed.contains(&i) {
                            usize::MAX
                        } else {
                            s.inflight.load(Ordering::Relaxed)
                        }
                    })
                    .collect();
                let pick = self.inner.policy.pick(&loads).expect("non-empty fleet");
                if loads[pick] == usize::MAX {
                    // Every replica already failed this request.
                    break;
                }
                let slot = &slots[pick];
                slot.inflight.fetch_add(1, Ordering::Relaxed);
                // Attempts count at pick time; completions only after the
                // replica answers — a retried request must not inflate the
                // served-traffic view (`dispatched` used to count both,
                // so reload-race retries showed dispatched > served).
                slot.attempts.fetch_add(1, Ordering::Relaxed);
                (pick, slot.backend.client(), slot.inflight.clone(), slot.dispatched.clone())
            };
            let out = client.infer_multi(features.clone());
            inflight.fetch_sub(1, Ordering::Relaxed);
            match out {
                Ok(v) => {
                    served.fetch_add(1, Ordering::Relaxed);
                    return Ok(v);
                }
                Err(e) => {
                    last_err = Some(e);
                    failed.push(pick);
                }
            }
        }
        Err(last_err.expect("retry loop ran").context("no live replica answered"))
    }
}

/// One replica's slice of the fleet metrics.
#[derive(Debug, Clone)]
pub struct ReplicaMetrics {
    /// Slot index.
    pub replica: usize,
    /// Dispatch attempts routed to this slot, including attempts that
    /// failed against a retiring replica and were re-dispatched.
    pub attempts: u64,
    /// Requests this slot completed (`attempts - dispatched` is the
    /// reload-race retry count; always 0 outside a reload window).
    pub dispatched: u64,
    /// The replica server's own report.
    pub report: MetricsReport,
}

/// Fleet metrics: per-replica detail plus the merged fleet-level view.
#[derive(Debug, Clone)]
pub struct FleetMetricsReport {
    pub replicas: Vec<ReplicaMetrics>,
    pub merged: MetricsReport,
}

/// The running fleet.
pub struct FleetServer {
    inner: Arc<FleetInner>,
    max_wait: Duration,
    queue_depth: usize,
}

impl FleetServer {
    /// Spawn `replicas` servers for one compiled pipeline. `queue_depth`
    /// is the per-replica request-channel bound (in requests).
    pub fn spawn(
        pfw: Arc<PartitionedFirmware>,
        replicas: usize,
        max_wait: Duration,
        queue_depth: usize,
    ) -> Result<FleetServer> {
        ensure!(replicas >= 1, "fleet needs at least one replica");
        pfw.check_invariants()?;
        let slots: Vec<ReplicaSlot> = (0..replicas)
            .map(|_| ReplicaSlot::new(ReplicaBackend::spawn(&pfw, max_wait, queue_depth)))
            .collect();
        Ok(FleetServer {
            inner: Arc::new(FleetInner {
                slots: RwLock::new(slots),
                current: RwLock::new(pfw),
                policy: LeastLoaded::new(),
            }),
            max_wait,
            queue_depth,
        })
    }

    /// Execute a planner [`DeploymentPlan`]: R replicas at the plan's
    /// batching deadline, channel depth sized from the plan's queue depth
    /// (in batches) times its firmware batch.
    pub fn launch(plan: &DeploymentPlan) -> Result<FleetServer> {
        let max_wait = Duration::from_secs_f64(plan.max_wait_us.max(1.0) / 1e6);
        let depth = (plan.queue_depth * plan.batch).max(16);
        FleetServer::spawn(plan.firmware.clone(), plan.r, max_wait, depth)
    }

    /// A dispatch handle (cheap to clone; thread-safe).
    pub fn client(&self) -> FleetClient {
        FleetClient { inner: self.inner.clone() }
    }

    /// The firmware generation currently being rolled out / served.
    pub fn firmware(&self) -> Arc<PartitionedFirmware> {
        self.inner.current.read().unwrap().clone()
    }

    /// Live replica count.
    pub fn replicas(&self) -> usize {
        self.inner.slots.read().unwrap().len()
    }

    /// Point-in-time metrics: per-replica dispatch counts and reports,
    /// plus the merged fleet view.
    pub fn metrics(&self) -> FleetMetricsReport {
        let slots = self.inner.slots.read().unwrap();
        let replicas: Vec<ReplicaMetrics> = slots
            .iter()
            .enumerate()
            .map(|(i, s)| ReplicaMetrics {
                replica: i,
                attempts: s.attempts.load(Ordering::Relaxed),
                dispatched: s.dispatched.load(Ordering::Relaxed),
                report: s.backend.metrics(),
            })
            .collect();
        let merged =
            MetricsReport::merged(&replicas.iter().map(|r| r.report.clone()).collect::<Vec<_>>());
        FleetMetricsReport { replicas, merged }
    }

    /// Drain-and-replace hot reload: swap every replica to `new` firmware
    /// one slot at a time — the paper's RTP reload (new coefficients
    /// without a rebuild) at fleet scope. The new firmware must keep the
    /// serving contract (input width, batch, output shapes); each old
    /// replica drains fully (in-flight requests are answered with the old
    /// weights) while its peers keep serving. Returns the final metrics of
    /// every retired replica.
    pub fn reload(&self, new: Arc<PartitionedFirmware>) -> Result<Vec<MetricsReport>> {
        new.check_invariants()?;
        {
            let cur = self.inner.current.read().unwrap();
            ensure!(
                new.input_features() == cur.input_features(),
                "reload changes input width {} -> {}",
                cur.input_features(),
                new.input_features()
            );
            ensure!(
                new.batch() == cur.batch(),
                "reload changes firmware batch {} -> {}",
                cur.batch(),
                new.batch()
            );
            ensure!(
                new.outputs.len() == cur.outputs.len(),
                "reload changes output count {} -> {}",
                cur.outputs.len(),
                new.outputs.len()
            );
            for i in 0..new.outputs.len() {
                ensure!(
                    new.output_features_of(i) == cur.output_features_of(i),
                    "reload changes output {i} width {} -> {}",
                    cur.output_features_of(i),
                    new.output_features_of(i)
                );
            }
        }
        let count = self.replicas();
        let mut retired = Vec::with_capacity(count);
        for i in 0..count {
            let fresh =
                ReplicaSlot::new(ReplicaBackend::spawn(&new, self.max_wait, self.queue_depth));
            let old = {
                let mut slots = self.inner.slots.write().unwrap();
                if i >= slots.len() {
                    bail!("fleet shrank during reload");
                }
                std::mem::replace(&mut slots[i], fresh)
            };
            // Outside the lock: the rest of the fleet serves while this
            // replica drains.
            retired.push(old.backend.shutdown());
        }
        *self.inner.current.write().unwrap() = new;
        Ok(retired)
    }

    /// Grow or shrink the live replica count to `r` (≥ 1) using the same
    /// slot machinery as [`FleetServer::reload`]: growth pushes fresh
    /// replicas of the current firmware generation; shrinkage retires the
    /// highest slots one at a time, each draining *outside* the slots lock
    /// so the remaining replicas keep serving throughout (in-flight
    /// requests on a retiring replica are answered, and a request racing
    /// the retirement re-dispatches like a reload race). Returns the final
    /// metrics of every retired replica.
    pub fn scale_to(&self, r: usize) -> Result<Vec<MetricsReport>> {
        ensure!(r >= 1, "fleet needs at least one replica");
        let fw = self.firmware();
        let mut retired = Vec::new();
        loop {
            let shrink = {
                let mut slots = self.inner.slots.write().unwrap();
                ensure!(!slots.is_empty(), "fleet is shut down");
                if slots.len() < r {
                    let fresh = ReplicaSlot::new(ReplicaBackend::spawn(
                        &fw,
                        self.max_wait,
                        self.queue_depth,
                    ));
                    slots.push(fresh);
                    None
                } else if slots.len() > r {
                    Some(slots.pop().expect("len > r >= 1"))
                } else {
                    break;
                }
            };
            if let Some(old) = shrink {
                retired.push(old.backend.shutdown());
            }
        }
        Ok(retired)
    }

    /// Verify every replica bit-exactly against the reference oracle:
    /// `samples` random single-sample probes are sent *directly* to each
    /// replica (bypassing dispatch, so no replica can hide) and every
    /// output is compared element-wise to [`ReferenceOracle::execute_all`].
    pub fn verify_bit_exact(
        &self,
        oracle: &ReferenceOracle,
        samples: usize,
        seed: u64,
    ) -> Result<()> {
        let (clients, features, range) = {
            let slots = self.inner.slots.read().unwrap();
            ensure!(!slots.is_empty(), "fleet is shut down");
            let cur = self.inner.current.read().unwrap();
            let range = cur.partitions[0].input_quant.dtype.range();
            (
                slots.iter().map(|s| s.backend.client()).collect::<Vec<_>>(),
                cur.input_features(),
                range,
            )
        };
        ensure!(
            oracle.input_features() == features,
            "oracle expects {} input features, fleet serves {features}",
            oracle.input_features()
        );
        for (i, client) in clients.iter().enumerate() {
            let mut rng = Pcg32::seed_from_u64(seed.wrapping_add(i as u64));
            for s in 0..samples {
                let x: Vec<i32> =
                    (0..features).map(|_| rng.gen_i32_in(range.0, range.1)).collect();
                let got = client.infer_multi(x.clone())?;
                let want = oracle.execute_all(&Activation::new(1, features, x)?)?;
                ensure!(
                    got.len() == want.len(),
                    "replica {i}: {} outputs vs oracle's {}",
                    got.len(),
                    want.len()
                );
                for (o, (g, w)) in got.iter().zip(&want).enumerate() {
                    ensure!(
                        g == &w.data,
                        "replica {i} diverges from the reference oracle on probe {s}, output {o}"
                    );
                }
            }
        }
        Ok(())
    }

    /// Stop accepting requests, drain every replica and return the final
    /// fleet metrics.
    pub fn shutdown(self) -> FleetMetricsReport {
        let drained: Vec<ReplicaSlot> = {
            let mut slots = self.inner.slots.write().unwrap();
            slots.drain(..).collect()
        };
        let replicas: Vec<ReplicaMetrics> = drained
            .into_iter()
            .enumerate()
            .map(|(i, s)| ReplicaMetrics {
                replica: i,
                attempts: s.attempts.load(Ordering::Relaxed),
                dispatched: s.dispatched.load(Ordering::Relaxed),
                report: s.backend.shutdown(),
            })
            .collect();
        let merged =
            MetricsReport::merged(&replicas.iter().map(|r| r.report.clone()).collect::<Vec<_>>());
        FleetMetricsReport { replicas, merged }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dtype;
    use crate::frontend::CompileConfig;
    use crate::harness::models::{mlp_spec, synth_model};
    use crate::partition::{compile_partitioned, PartitionOptions};

    fn pipeline(name: &str, k: usize, batch: usize) -> Arc<PartitionedFirmware> {
        let json = synth_model(name, &mlp_spec(&[24, 16, 8], Dtype::I8), 6);
        let mut cfg = CompileConfig::default();
        cfg.batch = batch;
        cfg.tiles_per_layer = Some(1);
        let opts = PartitionOptions { partitions: Some(k), max_partitions: k };
        Arc::new(compile_partitioned(&json, cfg, &opts).unwrap().firmware)
    }

    fn oracle(name: &str) -> ReferenceOracle {
        let json = synth_model(name, &mlp_spec(&[24, 16, 8], Dtype::I8), 6);
        ReferenceOracle::from_model(&json).unwrap()
    }

    #[test]
    fn single_replica_fleet_serves_and_degenerates_to_server_metrics() {
        let pfw = pipeline("fleet_one", 1, 2);
        let fleet =
            FleetServer::spawn(pfw.clone(), 1, Duration::from_millis(2), 16).unwrap();
        let out = fleet.client().infer(vec![1; 24]).unwrap();
        assert_eq!(out.len(), 8);
        let m = fleet.shutdown();
        assert_eq!(m.replicas.len(), 1);
        assert_eq!(m.replicas[0].dispatched, 1);
        assert_eq!(m.merged.requests, 1);
        // K=1 replica runs the plain Server: no pipeline stage rows.
        assert!(m.replicas[0].report.stages.is_empty());
    }

    #[test]
    fn replicas_agree_with_each_other_and_the_oracle() {
        for k in [1usize, 2] {
            let pfw = pipeline("fleet_agree", k, 2);
            let fleet =
                FleetServer::spawn(pfw, 3, Duration::from_millis(1), 32).unwrap();
            fleet.verify_bit_exact(&oracle("fleet_agree"), 3, 0xF00D).unwrap();
            // Identical input through dispatch: same answer every time,
            // whichever replica serves it.
            let c = fleet.client();
            let golden = c.infer(vec![2; 24]).unwrap();
            for _ in 0..5 {
                assert_eq!(c.infer(vec![2; 24]).unwrap(), golden);
            }
            // Round-robin tie-breaking spread the probes: every replica saw
            // traffic (3 direct probes each + 6 dispatched).
            let m = fleet.shutdown();
            assert_eq!(m.replicas.len(), 3);
            for r in &m.replicas {
                assert!(r.report.requests >= 3, "replica {} starved", r.replica);
            }
            assert_eq!(m.merged.requests, 3 * 3 + 6);
        }
    }

    #[test]
    fn dispatch_is_work_conserving_under_concurrency() {
        let pfw = pipeline("fleet_wc", 1, 2);
        let fleet = FleetServer::spawn(pfw, 2, Duration::from_millis(1), 64).unwrap();
        std::thread::scope(|scope| {
            for t in 0..4 {
                let c = fleet.client();
                scope.spawn(move || {
                    for i in 0..8 {
                        let out = c.infer(vec![(t + i) % 7; 24]).unwrap();
                        assert_eq!(out.len(), 8);
                    }
                });
            }
        });
        let m = fleet.shutdown();
        let total: u64 = m.replicas.iter().map(|r| r.dispatched).sum();
        assert_eq!(total, 32);
        // Least-loaded + rotating ties: neither replica starves while the
        // other queues 32 requests.
        for r in &m.replicas {
            assert!(
                r.dispatched >= 4,
                "replica {} got {} of 32 requests",
                r.replica,
                r.dispatched
            );
        }
        assert_eq!(m.merged.requests, 32);
    }

    #[test]
    fn hot_reload_swaps_weights_without_dropping_service() {
        // v1 and v2 share topology but not weights (name seeds the PCG
        // weight stream).
        let v1 = pipeline("fleet_v1", 1, 2);
        let v2 = pipeline("fleet_v2", 1, 2);
        let fleet = FleetServer::spawn(v1, 2, Duration::from_millis(2), 16).unwrap();
        let c = fleet.client();
        let before = c.infer(vec![3; 24]).unwrap();
        let retired = fleet.reload(v2).unwrap();
        assert_eq!(retired.len(), 2);
        assert_eq!(retired.iter().map(|r| r.requests).sum::<usize>(), 1);
        assert_eq!(fleet.replicas(), 2, "fleet keeps its replica count across reload");
        let after = c.infer(vec![3; 24]).unwrap();
        assert_ne!(before, after, "new weights must change outputs");
        // The new generation is what verify checks against.
        fleet.verify_bit_exact(&oracle("fleet_v2"), 2, 7).unwrap();
        fleet.shutdown();
    }

    #[test]
    fn dispatch_counters_separate_attempts_from_completions() {
        // Regression for retry-inflated `dispatched`: under reload churn a
        // request that races a retiring replica is retried elsewhere, and
        // only the replica that *answered* may count it as served.
        let v1 = pipeline("fleet_cnt_v1", 1, 2);
        let v2 = pipeline("fleet_cnt_v2", 1, 2);
        let fleet = FleetServer::spawn(v1, 2, Duration::from_millis(1), 32).unwrap();
        let requests = 48u64;
        std::thread::scope(|scope| {
            for t in 0..4u64 {
                let c = fleet.client();
                scope.spawn(move || {
                    for i in 0..requests / 4 {
                        c.infer(vec![((t + i) % 7) as i32; 24]).unwrap();
                    }
                });
            }
            // Two reloads while traffic flows, to provoke retry races.
            let f = &fleet;
            let v2 = v2.clone();
            scope.spawn(move || {
                f.reload(v2.clone()).unwrap();
                f.reload(v2).unwrap();
            });
        });
        let m = fleet.shutdown();
        let attempts: u64 = m.replicas.iter().map(|r| r.attempts).sum();
        let dispatched: u64 = m.replicas.iter().map(|r| r.dispatched).sum();
        // Completions on the final slots plus requests the retired
        // generations answered account for every submitted request; the
        // live-slot completion count alone can never exceed it.
        assert!(attempts >= dispatched, "attempts {attempts} < completions {dispatched}");
        assert!(dispatched <= requests);
        for r in &m.replicas {
            assert!(
                r.attempts >= r.dispatched,
                "replica {}: attempts {} < completions {}",
                r.replica,
                r.attempts,
                r.dispatched
            );
        }
    }

    #[test]
    fn scale_to_grows_and_shrinks_without_dropping_service() {
        let fleet =
            FleetServer::spawn(pipeline("fleet_scale", 1, 2), 1, Duration::from_millis(1), 32)
                .unwrap();
        let c = fleet.client();
        let golden = c.infer(vec![4; 24]).unwrap();
        // Grow under traffic.
        std::thread::scope(|scope| {
            for _ in 0..3 {
                let c = fleet.client();
                let golden = &golden;
                scope.spawn(move || {
                    for _ in 0..6 {
                        assert_eq!(&c.infer(vec![4; 24]).unwrap(), golden);
                    }
                });
            }
            let retired = fleet.scale_to(3).unwrap();
            assert!(retired.is_empty(), "growth retires nobody");
        });
        assert_eq!(fleet.replicas(), 3);
        // Shrink back; the retired replicas' final metrics come back.
        let retired = fleet.scale_to(1).unwrap();
        assert_eq!(retired.len(), 2);
        assert_eq!(fleet.replicas(), 1);
        // Still serving, same weights.
        assert_eq!(c.infer(vec![4; 24]).unwrap(), golden);
        assert!(fleet.scale_to(0).is_err());
        let m = fleet.shutdown();
        assert_eq!(m.replicas.len(), 1);
        // Every request was answered exactly once somewhere: live-slot
        // completions + retired-replica requests == all submissions.
        let live_served: usize = m.merged.requests;
        let retired_served: usize = retired.iter().map(|r| r.requests).sum();
        assert_eq!(live_served + retired_served, 1 + 18 + 1);
    }

    #[test]
    fn reload_rejects_contract_changes() {
        let fleet =
            FleetServer::spawn(pipeline("fleet_c1", 1, 2), 1, Duration::from_millis(2), 8)
                .unwrap();
        // Different input width: 32 != 24.
        let other = {
            let json = synth_model("fleet_c2", &mlp_spec(&[32, 8], Dtype::I8), 6);
            let mut cfg = CompileConfig::default();
            cfg.batch = 2;
            cfg.tiles_per_layer = Some(1);
            let opts = PartitionOptions { partitions: Some(1), max_partitions: 1 };
            Arc::new(compile_partitioned(&json, cfg, &opts).unwrap().firmware)
        };
        assert!(fleet.reload(other).is_err());
        // Same topology, different batch.
        let rebatched = pipeline("fleet_c1", 1, 4);
        assert!(fleet.reload(rebatched).is_err());
        fleet.shutdown();
    }

    #[test]
    fn shutdown_then_dispatch_errors_cleanly() {
        let fleet =
            FleetServer::spawn(pipeline("fleet_dn", 1, 2), 1, Duration::from_millis(1), 8)
                .unwrap();
        let c = fleet.client();
        fleet.shutdown();
        assert!(c.infer(vec![0; 24]).is_err());
    }
}
