//! SLO-burn autoscaling: grow or shrink the replication factor R from
//! live serving signals.
//!
//! The planner ([`super::planner`]) answers the *static* question — how
//! many replicas for a target rate — from costed candidates. The
//! autoscaler answers it *continuously*: it differences consecutive
//! [`ServingSnapshot`]s (cumulative [`crate::coordinator::MetricsReport`]
//! + [`AdmissionReport`] counters) into observation windows, distills each
//! window into [`SloBurn`] signals, and emits [`ScaleDecision`]s that the
//! caller applies through `ContinuousServer::scale_to` /
//! `FleetServer::scale_to` — the same drain-and-replace machinery hot
//! reload uses, so scale transitions never drop admitted requests.
//!
//! Target selection is demand-driven and burn-boosted:
//!
//! * **demand** — the window's arrival rate divided by per-replica
//!   capacity. Capacity prefers the *live* estimate (firmware batch over
//!   the observed EWMA batch service time, which tracks host contention);
//!   before any batch has completed it falls back to the plan's costed
//!   [`DeploymentPlan::per_replica_sps`].
//! * **burn boost** — when the window shed requests, the queue is running
//!   deep, or the served p99 is burning the budget while arrivals outpace
//!   service, the target is raised to at least `current + 1` regardless
//!   of demand: the SLO is already bleeding, capacity math comes second.
//! * **scale-down hysteresis** — shrinking requires a clean window (no
//!   sheds, shallow queue, p99 comfortably inside the budget), and every
//!   transition starts a cooldown so the fleet does not flap.

use super::planner::{plan_with, DeploymentPlan, PlannerOptions};
use super::{Fleet, PlanOutcome, Slo};
use crate::cache::{CacheStats, FirmwareCache};
use crate::coordinator::{AdmissionReport, ServingSnapshot};
use crate::frontend::{CompileConfig, JsonModel};
use anyhow::Result;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Autoscaler knobs.
#[derive(Debug, Clone)]
pub struct AutoscalerConfig {
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// p99/budget ratio at or above which the fleet is burning its SLO
    /// (scale-up pressure, gated on arrivals outpacing service so a
    /// one-off historical tail cannot ratchet R upward forever).
    pub burn_up: f64,
    /// p99/budget ratio the window must stay below before scale-down.
    pub burn_down: f64,
    /// Window shed fraction at or above which the fleet scales up.
    pub shed_up: f64,
    /// Queue depth as a fraction of capacity at or above which the fleet
    /// scales up (backlog pressure before sheds even start).
    pub queue_up: f64,
    /// Queue fraction that must not be exceeded for scale-down.
    pub queue_down: f64,
    /// Multiplier on the demand-derived replica count (capacity margin).
    pub headroom: f64,
    /// Minimum time between scale transitions.
    pub cooldown: Duration,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            min_replicas: 1,
            max_replicas: 64,
            burn_up: 1.0,
            burn_down: 0.7,
            shed_up: 0.01,
            queue_up: 0.5,
            queue_down: 0.1,
            headroom: 1.0,
            cooldown: Duration::from_millis(50),
        }
    }
}

/// One observation window distilled into SLO-burn signals.
#[derive(Debug, Clone, Copy)]
pub struct SloBurn {
    /// Submitted requests per second in the window (offered load).
    pub arrival_sps: f64,
    /// Served requests per second in the window.
    pub served_sps: f64,
    /// Cumulative served p99 over the latency budget.
    pub p99_ratio: f64,
    /// Window shed fraction (shed / submitted).
    pub shed_ratio: f64,
    /// Instantaneous queue depth over queue capacity.
    pub queue_ratio: f64,
    /// Live per-replica capacity estimate, samples/s (plan fallback when
    /// no batch has completed yet).
    pub per_replica_sps: f64,
}

/// What the autoscaler wants done with the replica count.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScaleDecision {
    Hold,
    Up { from: usize, to: usize, reason: String },
    Down { from: usize, to: usize, reason: String },
}

impl ScaleDecision {
    /// The replica count to apply, if any change is wanted.
    pub fn target(&self) -> Option<usize> {
        match self {
            ScaleDecision::Hold => None,
            ScaleDecision::Up { to, .. } | ScaleDecision::Down { to, .. } => Some(*to),
        }
    }
}

/// Everything needed to re-run the capacity planner under live traffic,
/// with the content-addressed firmware cache that makes doing so cheap:
/// the first plan pays the candidate compiles, every re-plan at a new
/// observed rate is almost entirely cache hits (only the rate math and
/// the ranking change).
pub struct ReplanContext {
    json: JsonModel,
    base: CompileConfig,
    fleet: Fleet,
    opts: PlannerOptions,
    cache: Arc<FirmwareCache>,
}

impl ReplanContext {
    pub fn new(
        json: JsonModel,
        base: CompileConfig,
        fleet: Fleet,
        opts: PlannerOptions,
    ) -> ReplanContext {
        ReplanContext { json, base, fleet, opts, cache: Arc::new(FirmwareCache::new()) }
    }

    /// Compile/hit counters of the shared cache across every plan so far.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// The shared firmware cache itself — `Arc` so a serving snapshot
    /// source (e.g. `ContinuousServer::attach_cache`) can surface the
    /// same counters the re-planner drives.
    pub fn cache(&self) -> &Arc<FirmwareCache> {
        &self.cache
    }
}

/// The autoscaler. Owns the previous-window baselines; one instance per
/// served deployment.
pub struct Autoscaler {
    /// Per-replica capacity used before any live estimate exists,
    /// samples/s (the planner's costed rate, or a caller-measured one).
    fallback_sps: f64,
    /// The R the planner predicted, when built from a plan.
    plan_r: Option<usize>,
    budget_us: f64,
    cfg: AutoscalerConfig,
    /// Present when the autoscaler may re-run the planner
    /// ([`Autoscaler::with_replanning`]).
    replan: Option<ReplanContext>,
    /// Latest measured-vs-predicted drift correction from the serving
    /// snapshots (1.0 until any drift report arrives). Applied to the
    /// model-derived capacity fallback: if measured latencies run N× the
    /// model, the modeled per-replica rate is N× optimistic.
    drift_correction: f64,
    prev_admission: AdmissionReport,
    prev_requests: usize,
    prev_at: Option<Instant>,
    last_scale_at: Option<Instant>,
}

impl Autoscaler {
    /// Build on a planner candidate: the plan supplies the costed
    /// per-replica fallback rate and documents the R the planner predicted
    /// for its SLO ([`Autoscaler::plan_r`]).
    pub fn from_plan(plan: &DeploymentPlan, budget_us: f64, cfg: AutoscalerConfig) -> Autoscaler {
        Autoscaler::build(plan.per_replica_sps(), Some(plan.r), budget_us, cfg)
    }

    /// Build from a directly measured (or assumed) per-replica rate — the
    /// CLI path, where no planner run happened.
    pub fn from_rate(per_replica_sps: f64, budget_us: f64, cfg: AutoscalerConfig) -> Autoscaler {
        Autoscaler::build(per_replica_sps, None, budget_us, cfg)
    }

    fn build(
        fallback_sps: f64,
        plan_r: Option<usize>,
        budget_us: f64,
        cfg: AutoscalerConfig,
    ) -> Autoscaler {
        Autoscaler {
            fallback_sps,
            plan_r,
            budget_us,
            cfg,
            replan: None,
            drift_correction: 1.0,
            prev_admission: AdmissionReport::default(),
            prev_requests: 0,
            prev_at: None,
            last_scale_at: None,
        }
    }

    /// Arm the autoscaler with a [`ReplanContext`]: [`Autoscaler::replan`]
    /// may then re-run the full capacity planner at a freshly observed
    /// rate. The context's firmware cache persists across re-plans, so
    /// only the *first* plan pays candidate compiles.
    pub fn with_replanning(mut self, ctx: ReplanContext) -> Autoscaler {
        self.replan = Some(ctx);
        self
    }

    /// The replication factor the planner predicted, when known.
    pub fn plan_r(&self) -> Option<usize> {
        self.plan_r
    }

    /// The drift correction currently applied to the model-derived
    /// capacity fallback (1.0 = model trusted as calibrated).
    pub fn drift_correction(&self) -> f64 {
        self.drift_correction
    }

    /// Cache counters of the re-planning context, when armed.
    pub fn replan_cache_stats(&self) -> Option<CacheStats> {
        self.replan.as_ref().map(|c| c.cache_stats())
    }

    /// Re-run the capacity planner at `target_sps` (e.g. the last
    /// window's observed arrival rate) against the armed
    /// [`ReplanContext`]. On a feasible outcome the best plan's costed
    /// per-replica rate and predicted R replace the autoscaler's
    /// fallbacks, and the plan is returned so the caller can swap
    /// firmware/batching if the winning candidate changed. Returns
    /// `Ok(None)` when no context is armed or the target is infeasible
    /// (the current deployment keeps serving either way). Every compile
    /// behind this is memoized: re-planning under live traffic costs
    /// cache lookups, not pass-pipeline runs.
    pub fn replan(&mut self, target_sps: f64) -> Result<Option<DeploymentPlan>> {
        let Some(ctx) = self.replan.as_ref() else { return Ok(None) };
        if !(target_sps.is_finite() && target_sps > 0.0) {
            return Ok(None);
        }
        let slo = Slo::new(target_sps, self.budget_us);
        let _span = crate::obs::tracer()
            .span("deploy", "replan")
            .with_arg("target_sps", target_sps);
        let outcome = plan_with(&ctx.json, &ctx.base, &ctx.fleet, &slo, &ctx.opts, &ctx.cache)?;
        match outcome {
            PlanOutcome::Feasible(plans) => {
                let best = plans.into_iter().next().expect("feasible outcome has a plan");
                self.fallback_sps = best.per_replica_sps();
                self.plan_r = Some(best.r);
                Ok(Some(best))
            }
            PlanOutcome::Infeasible(_) => Ok(None),
        }
    }

    /// Ingest one snapshot, closing the current observation window.
    /// Returns `Hold` until two observations exist (no window yet).
    pub fn observe(&mut self, now: Instant, snap: &ServingSnapshot) -> ScaleDecision {
        // Fold the serving path's measured-vs-predicted drift into the
        // capacity fallback before sizing the window: a model that proves
        // N× optimistic deflates the modeled per-replica rate by N.
        if let Some(d) = &snap.drift {
            if d.has_samples() && d.correction > 0.0 {
                self.drift_correction = d.correction;
            }
        }
        let window = snap.admission.delta(&self.prev_admission);
        let served = snap.metrics.requests.saturating_sub(self.prev_requests);
        let elapsed = self.prev_at.map(|t| now.saturating_duration_since(t).as_secs_f64());
        self.prev_admission = snap.admission;
        self.prev_requests = snap.metrics.requests;
        self.prev_at = Some(now);
        let Some(elapsed) = elapsed else { return ScaleDecision::Hold };
        if elapsed <= 0.0 {
            return ScaleDecision::Hold;
        }
        let burn = SloBurn {
            arrival_sps: window.submitted as f64 / elapsed,
            served_sps: served as f64 / elapsed,
            p99_ratio: if self.budget_us > 0.0 {
                snap.metrics.p99_latency_us / self.budget_us
            } else {
                0.0
            },
            shed_ratio: window.shed_ratio(),
            queue_ratio: if snap.queue_capacity > 0 {
                snap.queued as f64 / snap.queue_capacity as f64
            } else {
                0.0
            },
            per_replica_sps: if snap.batch_us > 0.0 {
                snap.batch as f64 * 1e6 / snap.batch_us
            } else {
                // No live estimate yet: the model's costed rate, deflated
                // by the observed drift (the live EWMA branch needs no
                // correction — it already *is* a measurement).
                self.fallback_sps / self.drift_correction.max(f64::MIN_POSITIVE)
            },
        };
        self.decide(now, &burn, snap.replicas)
    }

    /// Pure decision logic (separated for testability; `now` only gates
    /// the cooldown).
    pub fn decide(&mut self, now: Instant, burn: &SloBurn, current_r: usize) -> ScaleDecision {
        if let Some(t) = self.last_scale_at {
            if now.saturating_duration_since(t) < self.cfg.cooldown {
                return ScaleDecision::Hold;
            }
        }
        let demand = if burn.per_replica_sps > 0.0 {
            let want = burn.arrival_sps * self.cfg.headroom / burn.per_replica_sps;
            (want.ceil() as usize).max(1)
        } else {
            current_r
        };
        let burning = burn.shed_ratio >= self.cfg.shed_up
            || burn.queue_ratio >= self.cfg.queue_up
            || (burn.p99_ratio >= self.cfg.burn_up && burn.arrival_sps > burn.served_sps);
        let mut target = demand;
        if burning {
            target = target.max(current_r + 1);
        }
        let target = target.clamp(self.cfg.min_replicas, self.cfg.max_replicas.max(1));
        let decision = if target > current_r {
            self.last_scale_at = Some(now);
            ScaleDecision::Up {
                from: current_r,
                to: target,
                reason: format!(
                    "demand {demand} replica(s) at {:.0} samples/s offered \
                     ({:.0}/replica); p99 burn {:.2}, shed {:.1}%, queue {:.0}%",
                    burn.arrival_sps,
                    burn.per_replica_sps,
                    burn.p99_ratio,
                    100.0 * burn.shed_ratio,
                    100.0 * burn.queue_ratio
                ),
            }
        } else if target < current_r
            && burn.shed_ratio == 0.0
            && burn.queue_ratio <= self.cfg.queue_down
            && burn.p99_ratio <= self.cfg.burn_down
        {
            self.last_scale_at = Some(now);
            ScaleDecision::Down {
                from: current_r,
                to: target,
                reason: format!(
                    "demand {demand} replica(s) at {:.0} samples/s offered; clean window \
                     (no sheds, queue {:.0}%, p99 burn {:.2})",
                    burn.arrival_sps,
                    100.0 * burn.queue_ratio,
                    burn.p99_ratio
                ),
            }
        } else {
            ScaleDecision::Hold
        };
        // Every decision becomes a trace instant carrying the window
        // signals that triggered it — the "why did it scale at t=3.2s"
        // answer lives in the trace, not in a log line to correlate.
        let tr = crate::obs::tracer();
        if tr.is_enabled() {
            tr.instant(
                "autoscale",
                match &decision {
                    ScaleDecision::Hold => "autoscale_hold",
                    ScaleDecision::Up { .. } => "autoscale_up",
                    ScaleDecision::Down { .. } => "autoscale_down",
                },
            )
            .with_arg("current_r", current_r)
            .with_arg("target", decision.target().unwrap_or(current_r))
            .with_arg("demand", demand)
            .with_arg("arrival_sps", burn.arrival_sps)
            .with_arg("served_sps", burn.served_sps)
            .with_arg("p99_ratio", burn.p99_ratio)
            .with_arg("shed_ratio", burn.shed_ratio)
            .with_arg("queue_ratio", burn.queue_ratio)
            .with_arg("per_replica_sps", burn.per_replica_sps);
        }
        decision
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dtype;
    use crate::deploy::{plan, Fleet, PlannerOptions, Slo};
    use crate::frontend::CompileConfig;
    use crate::harness::models::{mlp_spec, synth_model};

    fn test_plan() -> DeploymentPlan {
        let json = synth_model("autoscale_plan", &mlp_spec(&[32, 16, 8], Dtype::I8), 6);
        let mut cfg = CompileConfig::default();
        cfg.batch = 8;
        cfg.tiles_per_layer = Some(2);
        let out = plan(
            &json,
            &cfg,
            &Fleet::homogeneous("vek280", 4),
            &Slo::new(1e5, 100_000.0),
            &PlannerOptions::default(),
        )
        .unwrap();
        out.best().expect("test SLO must be plannable").clone()
    }

    fn burn(arrival: f64, per_replica: f64) -> SloBurn {
        SloBurn {
            arrival_sps: arrival,
            served_sps: arrival,
            p99_ratio: 0.2,
            shed_ratio: 0.0,
            queue_ratio: 0.0,
            per_replica_sps: per_replica,
        }
    }

    #[test]
    fn replanning_reuses_the_firmware_cache() {
        let json = synth_model("autoscale_replan", &mlp_spec(&[32, 16, 8], Dtype::I8), 6);
        let mut cfg = CompileConfig::default();
        cfg.batch = 8;
        cfg.tiles_per_layer = Some(2);
        let plan0 = test_plan();
        let one = plan0.per_replica_sps();
        let mut a = Autoscaler::from_plan(&plan0, 100_000.0, AutoscalerConfig::default())
            .with_replanning(ReplanContext::new(
                json,
                cfg,
                Fleet::homogeneous("vek280", 4),
                PlannerOptions::default(),
            ));
        // First re-plan pays the candidate compiles…
        let p1 = a.replan(one * 0.5).unwrap().expect("0.5x rate must be plannable");
        let cold = a.replan_cache_stats().unwrap();
        assert!(cold.misses > 0);
        // …every later re-plan (new observed rate) is pure cache hits.
        let p2 = a.replan(one * 2.5).unwrap().expect("2.5x rate must be plannable");
        let warm = a.replan_cache_stats().unwrap();
        assert_eq!(warm.misses, cold.misses, "re-plan recompiled firmware");
        assert!(warm.hits > cold.hits);
        // The new plan's sizing lands in the autoscaler's fallbacks.
        assert!(p2.r >= p1.r);
        assert_eq!(a.plan_r(), Some(p2.r));
        // Degenerate targets and unarmed autoscalers are no-ops.
        assert!(a.replan(f64::NAN).unwrap().is_none());
        let mut bare = Autoscaler::from_rate(1000.0, 1000.0, AutoscalerConfig::default());
        assert!(bare.replan(1000.0).unwrap().is_none());
    }

    #[test]
    fn demand_tracks_arrival_rate_and_cooldown_gates_flapping() {
        let mut a = Autoscaler::from_plan(
            &test_plan(),
            1000.0,
            AutoscalerConfig { cooldown: Duration::from_millis(200), ..Default::default() },
        );
        let t0 = Instant::now();
        // 2.5 replicas' worth of offered load at 1k/replica -> R=3.
        match a.decide(t0, &burn(2500.0, 1000.0), 1) {
            ScaleDecision::Up { from: 1, to: 3, .. } => {}
            d => panic!("expected Up to 3, got {d:?}"),
        }
        // Inside the cooldown nothing moves, even under pressure.
        assert_eq!(a.decide(t0 + Duration::from_millis(10), &burn(9000.0, 1000.0), 3),
            ScaleDecision::Hold);
        // After the cooldown a clean low-demand window shrinks the fleet.
        match a.decide(t0 + Duration::from_millis(300), &burn(800.0, 1000.0), 3) {
            ScaleDecision::Down { from: 3, to: 1, .. } => {}
            d => panic!("expected Down to 1, got {d:?}"),
        }
    }

    #[test]
    fn burn_signals_boost_past_demand() {
        let mut a = Autoscaler::from_plan(
            &test_plan(),
            1000.0,
            AutoscalerConfig { cooldown: Duration::ZERO, ..Default::default() },
        );
        let t = Instant::now();
        // Demand says 1 replica, but the window shed traffic: up anyway.
        let mut b = burn(500.0, 1000.0);
        b.shed_ratio = 0.05;
        match a.decide(t, &b, 2) {
            ScaleDecision::Up { from: 2, to: 3, .. } => {}
            d => panic!("expected shed-driven Up, got {d:?}"),
        }
        // Deep queue alone is enough.
        let mut b = burn(500.0, 1000.0);
        b.queue_ratio = 0.8;
        assert!(matches!(a.decide(t, &b, 2), ScaleDecision::Up { to: 3, .. }));
        // A historical p99 spike with arrivals <= service must NOT ratchet
        // R upward (the cumulative-p99 trap).
        let mut b = burn(500.0, 1000.0);
        b.p99_ratio = 2.0;
        assert_eq!(a.decide(t, &b, 2), ScaleDecision::Hold);
        // …but p99 burn while arrivals outpace service does.
        let mut b = burn(1500.0, 1000.0);
        b.p99_ratio = 2.0;
        b.served_sps = 900.0;
        assert!(matches!(a.decide(t, &b, 2), ScaleDecision::Up { to: 3, .. }));
    }

    #[test]
    fn drift_correction_deflates_model_capacity_fallback() {
        use crate::coordinator::MetricsReport;
        use crate::obs::attrib::DriftReport;
        let mut a = Autoscaler::from_rate(
            1000.0,
            1_000_000.0,
            AutoscalerConfig { cooldown: Duration::ZERO, ..Default::default() },
        );
        let snap = |submitted: u64, served: usize, drift: Option<DriftReport>| {
            let mut m = MetricsReport::empty();
            m.requests = served;
            ServingSnapshot {
                metrics: m,
                admission: AdmissionReport {
                    submitted,
                    admitted: submitted,
                    ..Default::default()
                },
                queued: 0,
                queue_capacity: 64,
                replicas: 1,
                batch: 8,
                batch_us: 0.0, // no live estimate: the model fallback decides
                cache: None,
                drift,
            }
        };
        let t0 = Instant::now();
        // First observation only opens the window.
        assert_eq!(a.observe(t0, &snap(0, 0, None)), ScaleDecision::Hold);
        // 2000 offered/s against a modeled 1000/s/replica: demand 2.
        let d1 = a.observe(t0 + Duration::from_secs(1), &snap(2000, 2000, None));
        assert!(matches!(d1, ScaleDecision::Up { from: 1, to: 2, .. }), "got {d1:?}");
        assert_eq!(a.drift_correction(), 1.0);
        // Same offered rate, but serving measured 4x the model's latency:
        // corrected capacity 250/s, so the same window demands 8 replicas.
        let drift = DriftReport {
            stages: Vec::new(),
            overall_ratio: 4.0,
            correction: 4.0,
            total_samples: 32,
        };
        let d2 = a.observe(t0 + Duration::from_secs(2), &snap(4000, 4000, Some(drift)));
        assert_eq!(a.drift_correction(), 4.0);
        assert!(matches!(d2, ScaleDecision::Up { from: 1, to: 8, .. }), "got {d2:?}");
    }

    #[test]
    fn dirty_windows_block_scale_down_and_bounds_clamp() {
        let mut a = Autoscaler::from_plan(
            &test_plan(),
            1000.0,
            AutoscalerConfig {
                cooldown: Duration::ZERO,
                max_replicas: 4,
                min_replicas: 2,
                ..Default::default()
            },
        );
        let t = Instant::now();
        // Sheds in the window: no shrink even at low demand.
        let mut b = burn(100.0, 1000.0);
        b.shed_ratio = 0.02;
        // (also not an up: current 4 == max)
        assert_eq!(a.decide(t, &b, 4), ScaleDecision::Hold);
        // Clean window shrinks, but only to min_replicas.
        match a.decide(t, &burn(100.0, 1000.0), 4) {
            ScaleDecision::Down { from: 4, to: 2, .. } => {}
            d => panic!("expected Down to min 2, got {d:?}"),
        }
        // Demand beyond max clamps to max.
        match a.decide(t, &burn(100_000.0, 1000.0), 2) {
            ScaleDecision::Up { from: 2, to: 4, .. } => {}
            d => panic!("expected Up clamped to 4, got {d:?}"),
        }
    }
}
