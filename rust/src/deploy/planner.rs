//! The capacity planner: search deployment candidates against an SLO.
//!
//! A candidate is a (device group, firmware batch, partition count K)
//! triple, compiled through [`crate::partition::compile_partitioned_with`]
//! against a content-addressed [`crate::cache::FirmwareCache`], so every
//! score rests on real firmware — the Eq. 2 placement, the mem-tile
//! plans, the calibrated cycle model — not on peak-TOPS arithmetic —
//! while fleet groups sharing a device, the cut DP's slice compiles, and
//! any re-plan of the same model dedupe to one compile each. From
//! each candidate's [`analyze_pipeline`] report the planner derives:
//!
//! * **per-replica rate** — `batch / interval` (one batch per steady-state
//!   interval);
//! * **replication** — the smallest R whose fleet rate covers the SLO
//!   target;
//! * **array cost** — for K = 1, replicas pack onto arrays by the *placed*
//!   footprint ([`crate::codegen::firmware::Firmware::placement_footprint`]): copies stamp the
//!   block's bounding box and share per-column memory tiles. For K > 1
//!   each replica owns K whole arrays (a partition exists precisely
//!   because it needs most of one);
//! * **latency** — batch assembly at the target arrival rate (capped by
//!   the batcher deadline) + one head-of-line interval + the
//!   empty-pipeline fill latency. The remaining budget headroom is turned
//!   into the queue depth the servers may run at.
//!
//! Feasible plans are ranked cheapest-first (fewest arrays, then lowest
//! latency, then most throughput headroom); when nothing is feasible the
//! planner reports *why* per candidate ([`Infeasibility`]).

use super::{Fleet, Infeasibility, PlanOutcome, Slo};
use crate::cache::FirmwareCache;
use crate::frontend::{CompileConfig, JsonModel};
use crate::partition::{
    analyze_pipeline, compile_partitioned_with, PartitionOptions, PartitionedFirmware,
};
use crate::sim::engine::EngineModel;
use anyhow::Result;
use std::sync::Arc;

/// Planner search-space knobs.
#[derive(Debug, Clone)]
pub struct PlannerOptions {
    /// Candidate firmware batch sizes; empty means "use the config's".
    pub batches: Vec<usize>,
    /// Largest partition count K tried per (device, batch).
    pub max_partitions: usize,
    /// Largest replication factor R a plan may ask for.
    pub max_replicas: usize,
    /// Cap on the queue depth (batches) a plan recommends.
    pub queue_depth_cap: usize,
    /// Batcher deadline: the longest a request waits for its batch to
    /// fill, µs. Bounds the assembly term of the latency model.
    pub max_wait_us: f64,
    /// Cost model used for scoring.
    pub engine: EngineModel,
}

impl Default for PlannerOptions {
    fn default() -> Self {
        PlannerOptions {
            batches: Vec::new(),
            max_partitions: 2,
            max_replicas: 64,
            queue_depth_cap: 32,
            max_wait_us: 200.0,
            engine: EngineModel::default(),
        }
    }
}

/// One ranked, executable deployment: everything
/// [`crate::deploy::FleetServer::launch`] needs, plus the predictions the
/// SLO was checked against.
#[derive(Debug, Clone)]
pub struct DeploymentPlan {
    pub model_name: String,
    /// Device name of the fleet group this plan deploys onto.
    pub device: String,
    /// Pipeline partitions per replica (arrays per replica for K > 1).
    pub k: usize,
    /// Replicas of the compiled pipeline.
    pub r: usize,
    /// Firmware batch every replica is specialized to.
    pub batch: usize,
    /// Recommended per-replica queue depth, in batches.
    pub queue_depth: usize,
    /// Batcher deadline the latency model assumed, µs.
    pub max_wait_us: f64,
    /// Steady-state per-replica batch interval, µs.
    pub interval_us: f64,
    /// Empty-pipeline fill latency, µs.
    pub service_latency_us: f64,
    /// The SLO-checked bound: assembly + head-of-line interval + fill, µs.
    pub slo_latency_us: f64,
    /// Fleet throughput at R replicas, samples/s.
    pub predicted_sps: f64,
    /// Replicas one array holds (footprint packing; 1 for K > 1 plans).
    pub replicas_per_array: usize,
    /// Arrays the whole deployment occupies.
    pub arrays_used: usize,
    /// Compute tiles one replica uses (summed over its partitions).
    pub tiles_per_replica: usize,
    /// The compiled pipeline every replica executes.
    pub firmware: Arc<PartitionedFirmware>,
}

impl DeploymentPlan {
    /// Throughput headroom over the target (≥ 1.0 for feasible plans).
    pub fn headroom(&self, slo: &Slo) -> f64 {
        self.predicted_sps / slo.target_sps
    }

    /// Does the plan meet `slo` under the planner's models?
    pub fn meets(&self, slo: &Slo) -> bool {
        self.predicted_sps >= slo.target_sps && self.slo_latency_us <= slo.latency_budget_us
    }

    /// Modeled sustained rate of one replica, samples/s.
    pub fn per_replica_sps(&self) -> f64 {
        self.batch as f64 * 1e6 / self.interval_us
    }

    /// Replicas needed for an arrival rate of `sps`, from this plan's
    /// costed per-replica candidate — the autoscaler's demand target.
    /// Clamped to `[1, cap]`.
    pub fn replicas_for_rate(&self, sps: f64, cap: usize) -> usize {
        let per = self.per_replica_sps();
        if !per.is_finite() || per <= 0.0 || !sps.is_finite() || sps <= 0.0 {
            return 1;
        }
        ((sps / per).ceil() as usize).clamp(1, cap.max(1))
    }
}

/// Arrays a deployment of `r` replicas occupies.
fn arrays_for(r: usize, k: usize, replicas_per_array: usize) -> usize {
    if k == 1 {
        r.div_ceil(replicas_per_array.max(1))
    } else {
        r * k
    }
}

/// Search deployment plans for `json` on `fleet` meeting `slo`.
///
/// `base` supplies everything the SLO search does not sweep (per-layer
/// overrides, tiles-per-layer, placement weights); its `device` and
/// `batch` are overridden per candidate. Candidates that fail to compile
/// are recorded, not fatal — a model that only fits at K = 2 simply loses
/// its K = 1 candidates.
pub fn plan(
    json: &JsonModel,
    base: &CompileConfig,
    fleet: &Fleet,
    slo: &Slo,
    opts: &PlannerOptions,
) -> Result<PlanOutcome> {
    plan_with(json, base, fleet, slo, opts, &FirmwareCache::new())
}

/// [`plan`] against a caller-owned firmware cache. The sweep's compiles —
/// the cut DP's candidate slices, every (device group × batch × K)
/// candidate, and candidates that *fail* to compile — are memoized by
/// content, so fleet groups sharing a device dedupe to one compile each
/// and a re-plan of the same model (autoscaler, SLO revision, warm bench)
/// is almost entirely cache hits.
pub fn plan_with(
    json: &JsonModel,
    base: &CompileConfig,
    fleet: &Fleet,
    slo: &Slo,
    opts: &PlannerOptions,
    cache: &FirmwareCache,
) -> Result<PlanOutcome> {
    slo.validate()?;
    fleet.validate()?;
    let tr = crate::obs::tracer();
    let mut sweep_span = tr
        .span("deploy", "plan_sweep")
        .with_arg("model", json.name.clone())
        .with_arg("target_sps", slo.target_sps)
        .with_arg("latency_budget_us", slo.latency_budget_us);
    let batches: Vec<usize> =
        if opts.batches.is_empty() { vec![base.batch] } else { opts.batches.clone() };
    let mut plans: Vec<DeploymentPlan> = Vec::new();
    let mut reasons: Vec<String> = Vec::new();
    let mut candidates = 0usize;
    let mut best_sps = 0.0f64;
    let mut best_latency = f64::INFINITY;

    for group in &fleet.groups {
        for &batch in &batches {
            for k in 1..=opts.max_partitions.max(1) {
                let tag = format!("{}/K={k}/batch={batch}", group.device);
                let mut cand_span = tr.span("deploy", "candidate").with_arg("tag", tag.clone());
                let mut cfg = base.clone();
                cfg.device = group.device.clone();
                cfg.batch = batch;
                let popts = PartitionOptions { partitions: Some(k), max_partitions: k };
                let pm = match compile_partitioned_with(json, cfg, &popts, cache) {
                    Ok(pm) => pm,
                    Err(e) => {
                        cand_span.arg("outcome", "compile_error");
                        reasons.push(format!("{tag}: does not compile ({e:#})"));
                        continue;
                    }
                };
                candidates += 1;
                let pfw = Arc::new(pm.firmware);
                let rep = analyze_pipeline(&pfw, &opts.engine);
                if rep.interval_us <= 0.0 || !rep.interval_us.is_finite() {
                    cand_span.arg("outcome", "degenerate_interval");
                    reasons.push(format!("{tag}: degenerate zero interval"));
                    continue;
                }
                let per_replica_sps = batch as f64 * 1e6 / rep.interval_us;
                let device = &pfw.partitions[0].device;
                let replicas_per_array = if pfw.k() == 1 {
                    pfw.partitions[0].placement_footprint().replicas_on(device)
                } else {
                    1
                };
                // Largest R the group's arrays (and the option cap) allow.
                let r_capacity = if pfw.k() == 1 {
                    group.arrays * replicas_per_array
                } else {
                    group.arrays / pfw.k()
                };
                let r_max = r_capacity.min(opts.max_replicas);
                best_sps = best_sps.max(per_replica_sps * r_max as f64);
                // Smallest R whose fleet rate covers the target.
                let r_needed = ((slo.target_sps / per_replica_sps).ceil() as usize).max(1);
                // Latency at that replication: each replica sees 1/R of the
                // arrival stream, so its batch assembles R× slower — the
                // batcher deadline caps the wait (partial flushes).
                let assemble_us = ((batch.saturating_sub(1)) as f64 * r_needed as f64 * 1e6
                    / slo.target_sps)
                    .min(opts.max_wait_us);
                let slo_latency_us = assemble_us + rep.interval_us + rep.latency_us;
                if r_needed > r_max {
                    cand_span.arg("outcome", "capacity_bound");
                    reasons.push(format!(
                        "{tag}: needs R={r_needed} for {:.0} samples/s, capacity is R={r_max} \
                         ({} arrays x {replicas_per_array} replica(s)/array)",
                        slo.target_sps, group.arrays
                    ));
                    continue;
                }
                // Tracked only for candidates whose throughput fits the
                // fleet, so an infeasible outcome's "latency-bound"
                // diagnosis always quotes a latency that genuinely misses
                // the budget (a capacity-rejected candidate's latency
                // would be unreachable anyway).
                best_latency = best_latency.min(slo_latency_us);
                if slo_latency_us > slo.latency_budget_us {
                    cand_span.arg("outcome", "latency_bound");
                    reasons.push(format!(
                        "{tag}: modeled latency {slo_latency_us:.1} µs exceeds the \
                         {:.1} µs budget",
                        slo.latency_budget_us
                    ));
                    continue;
                }
                // Budget headroom becomes queue depth: how many whole
                // batch intervals of backlog still fit inside the budget.
                let spare = slo.latency_budget_us - slo_latency_us;
                let queue_depth =
                    (1 + (spare / rep.interval_us) as usize).min(opts.queue_depth_cap.max(1));
                cand_span.arg("outcome", "feasible");
                cand_span.arg("per_replica_sps", per_replica_sps);
                cand_span.arg("r", r_needed);
                plans.push(DeploymentPlan {
                    model_name: json.name.clone(),
                    device: group.device.clone(),
                    k: pfw.k(),
                    r: r_needed,
                    batch,
                    queue_depth,
                    max_wait_us: opts.max_wait_us,
                    interval_us: rep.interval_us,
                    service_latency_us: rep.latency_us,
                    slo_latency_us,
                    predicted_sps: per_replica_sps * r_needed as f64,
                    replicas_per_array,
                    arrays_used: arrays_for(r_needed, pfw.k(), replicas_per_array),
                    tiles_per_replica: pfw.tiles_used(),
                    firmware: pfw,
                });
            }
        }
    }

    sweep_span.arg("compiled_candidates", candidates);
    sweep_span.arg("feasible_plans", plans.len());
    if plans.is_empty() {
        return Ok(PlanOutcome::Infeasible(Infeasibility {
            target_sps: slo.target_sps,
            latency_budget_us: slo.latency_budget_us,
            best_sps,
            best_latency_us: if best_latency.is_finite() { best_latency } else { 0.0 },
            candidates,
            reasons,
        }));
    }
    // Cheapest hardware first; latency, then throughput headroom break ties.
    plans.sort_by(|a, b| {
        a.arrays_used
            .cmp(&b.arrays_used)
            .then(a.slo_latency_us.partial_cmp(&b.slo_latency_us).unwrap())
            .then(b.predicted_sps.partial_cmp(&a.predicted_sps).unwrap())
    });
    plans.truncate(8);
    Ok(PlanOutcome::Feasible(plans))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dtype;
    use crate::deploy::{Fleet, FleetGroup, PlanOutcome, Slo};
    use crate::frontend::{CompileConfig, JsonModel};
    use crate::harness::models::{mlp_spec, synth_model};
    use crate::passes::compile;
    use crate::sim::engine::EngineModel;

    fn small_model() -> JsonModel {
        synth_model("plan_small", &mlp_spec(&[32, 16, 8], Dtype::I8), 6)
    }

    fn base_cfg(batch: usize) -> CompileConfig {
        let mut c = CompileConfig::default();
        c.batch = batch;
        c.tiles_per_layer = Some(2);
        c
    }

    /// Per-replica rate of the K=1 compile, for calibrating test SLOs.
    fn one_replica_sps(json: &JsonModel, cfg: &CompileConfig) -> f64 {
        let fw = compile(json, cfg.clone()).unwrap().firmware.unwrap();
        let rep = crate::sim::engine::analyze(&fw, &EngineModel::default());
        cfg.batch as f64 * 1e6 / rep.interval_us
    }

    #[test]
    fn easy_slo_degenerates_to_one_replica_one_array() {
        let json = small_model();
        let cfg = base_cfg(8);
        let one = one_replica_sps(&json, &cfg);
        let slo = Slo::new(one * 0.5, 100_000.0);
        let fleet = Fleet::homogeneous("vek280", 4);
        let out = plan(&json, &cfg, &fleet, &slo, &PlannerOptions::default()).unwrap();
        let best = out.best().expect("an easy SLO must be feasible");
        assert_eq!(best.r, 1);
        assert_eq!(best.k, 1);
        assert_eq!(best.arrays_used, 1);
        assert!(best.meets(&slo));
        assert!(best.headroom(&slo) >= 1.0);
        // The degenerate plan's firmware is byte-identical to the plain
        // single-array compile — the fleet layer adds nothing at R=1/K=1.
        let plain = compile(&json, cfg.clone()).unwrap().firmware.unwrap();
        assert_eq!(
            best.firmware.partitions[0].to_json().unwrap(),
            plain.to_json().unwrap(),
            "R=1/K=1 plan must carry the plain compile's firmware bytes"
        );
    }

    #[test]
    fn heavy_target_scales_replicas_until_capacity_binds() {
        let json = small_model();
        let cfg = base_cfg(8);
        let one = one_replica_sps(&json, &cfg);
        // 2.5 replicas' worth of traffic -> R = 3.
        let slo = Slo::new(one * 2.5, 100_000.0);
        let fleet = Fleet::homogeneous("vek280", 4);
        let out = plan(&json, &cfg, &fleet, &slo, &PlannerOptions::default()).unwrap();
        let best = out.best().expect("fleet has room for 3 replicas");
        assert_eq!(best.r, 3);
        assert!(best.predicted_sps >= slo.target_sps);
        // Footprint packing: this tiny model packs many replicas per
        // array, so 3 replicas still fit one array.
        assert!(best.replicas_per_array >= 3, "rpa {}", best.replicas_per_array);
        assert_eq!(best.arrays_used, 1);

        // Beyond fleet capacity: infeasible with a throughput diagnosis.
        let rpa = best.replicas_per_array;
        let impossible = Slo::new(one * (4.0 * rpa as f64 + 1.0), 100_000.0);
        let out = plan(&json, &cfg, &fleet, &impossible, &PlannerOptions::default()).unwrap();
        match out {
            PlanOutcome::Infeasible(d) => {
                assert!(d.throughput_bound(), "{d}");
                assert!(d.best_sps > 0.0);
                assert!(d.reasons.iter().any(|r| r.contains("capacity")), "{:?}", d.reasons);
            }
            PlanOutcome::Feasible(p) => {
                panic!("impossible target planned as feasible: {:?}", p[0].r)
            }
        }
    }

    #[test]
    fn latency_bound_slo_is_diagnosed_as_such() {
        let json = small_model();
        let cfg = base_cfg(8);
        let one = one_replica_sps(&json, &cfg);
        // Trivial throughput, absurd latency budget (sub-cycle).
        let slo = Slo::new(one * 0.1, 1e-6);
        let fleet = Fleet::homogeneous("vek280", 4);
        let out = plan(&json, &cfg, &fleet, &slo, &PlannerOptions::default()).unwrap();
        match out {
            PlanOutcome::Infeasible(d) => {
                assert!(!d.throughput_bound(), "{d}");
                assert!(d.best_latency_us > slo.latency_budget_us);
                assert!(d.to_string().contains("latency-bound"));
            }
            PlanOutcome::Feasible(_) => panic!("sub-cycle latency budget planned as feasible"),
        }
    }

    #[test]
    fn batch_sweep_surfaces_every_feasible_batch_candidate() {
        let json = small_model();
        let cfg = base_cfg(8);
        let one = one_replica_sps(&json, &cfg);
        let fleet = Fleet::homogeneous("vek280", 4);
        let mut opts = PlannerOptions::default();
        opts.batches = vec![2, 32];
        // Loose budget: both batches feasible; ranked list carries both.
        let out = plan(&json, &cfg, &fleet, &Slo::new(one * 0.2, 100_000.0), &opts).unwrap();
        let PlanOutcome::Feasible(plans) = out else { panic!("loose SLO infeasible") };
        let batches: Vec<usize> = plans.iter().map(|p| p.batch).collect();
        assert!(batches.contains(&2) && batches.contains(&32), "{batches:?}");
        // Every surviving plan meets the SLO it was planned for.
        for p in &plans {
            assert!(p.meets(&Slo::new(one * 0.2, 100_000.0)));
            assert!(p.queue_depth >= 1);
        }
    }

    #[test]
    fn replicas_for_rate_follows_the_costed_candidate() {
        let json = small_model();
        let cfg = base_cfg(8);
        let one = one_replica_sps(&json, &cfg);
        let slo = Slo::new(one * 0.5, 100_000.0);
        let fleet = Fleet::homogeneous("vek280", 4);
        let out = plan(&json, &cfg, &fleet, &slo, &PlannerOptions::default()).unwrap();
        let best = out.best().unwrap().clone();
        assert!((best.per_replica_sps() - one).abs() / one < 0.2);
        let per = best.per_replica_sps();
        assert_eq!(best.replicas_for_rate(per * 0.3, 64), 1);
        assert_eq!(best.replicas_for_rate(per * 2.5, 64), 3);
        // The cap binds; degenerate rates fall back to 1.
        assert_eq!(best.replicas_for_rate(per * 100.0, 8), 8);
        assert_eq!(best.replicas_for_rate(0.0, 8), 1);
        assert_eq!(best.replicas_for_rate(f64::NAN, 8), 1);
    }

    #[test]
    fn duplicate_device_groups_and_replans_share_compiles() {
        // The double-compile fix: a fleet with two groups on the same
        // device must compile each (batch, K) candidate exactly once, and
        // a re-plan against the same cache must add zero compiles.
        let json = small_model();
        let cfg = base_cfg(8);
        let one = one_replica_sps(&json, &cfg);
        let slo = Slo::new(one * 0.5, 100_000.0);
        let opts = PlannerOptions::default();

        let single = Fleet::homogeneous("vek280", 2);
        let cache_single = FirmwareCache::new();
        plan_with(&json, &cfg, &single, &slo, &opts, &cache_single).unwrap();
        let baseline = cache_single.stats().misses;
        assert!(baseline > 0);

        let double = Fleet {
            groups: vec![
                FleetGroup { device: "vek280".into(), arrays: 2 },
                FleetGroup { device: "vek280".into(), arrays: 2 },
            ],
        };
        let cache = FirmwareCache::new();
        let out = plan_with(&json, &cfg, &double, &slo, &opts, &cache).unwrap();
        assert!(out.best().is_some());
        let first = cache.stats();
        assert_eq!(first.misses, baseline, "second identical group recompiled");
        assert!(first.hits > 0);

        let out2 = plan_with(&json, &cfg, &double, &slo, &opts, &cache).unwrap();
        assert!(out2.best().is_some());
        let second = cache.stats();
        assert_eq!(second.misses, first.misses, "re-plan recompiled");
        assert!(second.hits > first.hits);
    }

    #[test]
    fn unknown_device_rejected_and_mixed_fleets_searched() {
        let json = small_model();
        let cfg = base_cfg(8);
        let one = one_replica_sps(&json, &cfg);
        let slo = Slo::new(one * 0.5, 100_000.0);
        assert!(plan(&json, &cfg, &Fleet::homogeneous("h100", 2), &slo, &PlannerOptions::default())
            .is_err());
        let mixed = Fleet {
            groups: vec![
                FleetGroup { device: "vek280".into(), arrays: 1 },
                FleetGroup { device: "vek385".into(), arrays: 1 },
            ],
        };
        let out = plan(&json, &cfg, &mixed, &slo, &PlannerOptions::default()).unwrap();
        let PlanOutcome::Feasible(plans) = out else { panic!("mixed fleet infeasible") };
        let devices: std::collections::BTreeSet<&str> =
            plans.iter().map(|p| p.device.as_str()).collect();
        assert!(devices.contains("vek280") && devices.contains("vek385"), "{devices:?}");
    }
}
