//! Self-contained JSON: value tree, recursive-descent parser, writer.
//!
//! The offline build environment has no serde/serde_json, so JSON — the
//! interchange format between the Python exporter and the Rust frontend —
//! is one of the substrates we build ourselves. The parser accepts the full
//! JSON grammar (RFC 8259); integers are kept exact in an `Int` variant
//! (weight payloads must not round-trip through f64).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use thiserror::Error;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

#[derive(Debug, Error)]
pub enum JsonError {
    #[error("unexpected end of input at byte {0}")]
    Eof(usize),
    #[error("unexpected character '{0}' at byte {1}")]
    Unexpected(char, usize),
    #[error("invalid number at byte {0}")]
    BadNumber(usize),
    #[error("invalid \\u escape at byte {0}")]
    BadEscape(usize),
    #[error("trailing garbage at byte {0}")]
    Trailing(usize),
    #[error("type error: expected {expected}, found {found}")]
    Type { expected: &'static str, found: &'static str },
    #[error("missing field '{0}'")]
    Missing(String),
}

impl Value {
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Int(_) => "int",
            Value::Float(_) => "float",
            Value::Str(_) => "string",
            Value::Array(_) => "array",
            Value::Object(_) => "object",
        }
    }

    pub fn parse(text: &str) -> Result<Value, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(JsonError::Trailing(p.pos));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Value::Bool(b) => Ok(*b),
            v => Err(JsonError::Type { expected: "bool", found: v.type_name() }),
        }
    }

    pub fn as_i64(&self) -> Result<i64, JsonError> {
        match self {
            Value::Int(i) => Ok(*i),
            Value::Float(f) if f.fract() == 0.0 && f.abs() < 9.0e15 => Ok(*f as i64),
            v => Err(JsonError::Type { expected: "int", found: v.type_name() }),
        }
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        let i = self.as_i64()?;
        usize::try_from(i).map_err(|_| JsonError::Type { expected: "usize", found: "negative int" })
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Value::Int(i) => Ok(*i as f64),
            Value::Float(f) => Ok(*f),
            v => Err(JsonError::Type { expected: "number", found: v.type_name() }),
        }
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Value::Str(s) => Ok(s),
            v => Err(JsonError::Type { expected: "string", found: v.type_name() }),
        }
    }

    pub fn as_array(&self) -> Result<&[Value], JsonError> {
        match self {
            Value::Array(a) => Ok(a),
            v => Err(JsonError::Type { expected: "array", found: v.type_name() }),
        }
    }

    pub fn as_object(&self) -> Result<&BTreeMap<String, Value>, JsonError> {
        match self {
            Value::Object(o) => Ok(o),
            v => Err(JsonError::Type { expected: "object", found: v.type_name() }),
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(o) => o.get(key),
            _ => None,
        }
    }

    /// Required object field.
    pub fn field(&self, key: &str) -> Result<&Value, JsonError> {
        self.get(key).ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    // ---- writer ----------------------------------------------------------
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        let nl = |out: &mut String, level: usize| {
            if let Some(w) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(w * level));
            }
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Value::Float(f) => {
                if f.is_finite() {
                    let _ = write!(out, "{f}");
                    // `{}` for f64 omits ".0" for integral values; keep JSON
                    // numbers unambiguous is not required, but keep as-is.
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if indent.is_some() {
                            out.push(' ');
                        }
                    }
                    v.write(out, None, level); // arrays stay on one line
                }
                out.push(']');
            }
            Value::Object(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                if !o.is_empty() {
                    nl(out, level);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- From conversions for ergonomic construction --------------------------
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<i32> for Value {
    fn from(v: i32) -> Self {
        Value::Int(v as i64)
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int(v)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Int(v as i64)
    }
}
impl From<u32> for Value {
    fn from(v: u32) -> Self {
        Value::Int(v as i64)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Float(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Array(v.into_iter().map(Into::into).collect())
    }
}
impl<T: Into<Value> + Clone> From<&[T]> for Value {
    fn from(v: &[T]) -> Self {
        Value::Array(v.iter().cloned().map(Into::into).collect())
    }
}

/// Build an object from (key, value) pairs.
pub fn obj<const N: usize>(pairs: [(&str, Value); N]) -> Value {
    Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

// ---- parser ----------------------------------------------------------------
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8, JsonError> {
        self.bytes.get(self.pos).copied().ok_or(JsonError::Eof(self.pos))
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        let c = self.peek()?;
        if c != b {
            return Err(JsonError::Unexpected(c as char, self.pos));
        }
        self.pos += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(JsonError::Unexpected(self.peek()? as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek()? {
            b'n' => self.lit("null", Value::Null),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(JsonError::Unexpected(c as char, self.pos)),
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Value::Array(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Value::Array(out));
                }
                c => return Err(JsonError::Unexpected(c as char, self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Value::Object(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            out.insert(key, self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Value::Object(out));
                }
                c => return Err(JsonError::Unexpected(c as char, self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pair?
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    s.push(
                                        char::from_u32(combined)
                                            .ok_or(JsonError::BadEscape(self.pos))?,
                                    );
                                } else {
                                    return Err(JsonError::BadEscape(self.pos));
                                }
                            } else {
                                s.push(
                                    char::from_u32(cp).ok_or(JsonError::BadEscape(self.pos))?,
                                );
                            }
                        }
                        _ => return Err(JsonError::BadEscape(self.pos)),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Re-decode UTF-8 multibyte from the raw slice.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(JsonError::Eof(self.pos));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| JsonError::BadEscape(start))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(JsonError::Eof(self.pos));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| JsonError::BadEscape(self.pos))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| JsonError::BadEscape(self.pos))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_digit() {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.bytes.get(self.pos) == Some(&b'.') {
            is_float = true;
            self.pos += 1;
            while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_digit() {
                self.pos += 1;
            }
        }
        if matches!(self.bytes.get(self.pos), Some(b'e') | Some(b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.bytes.get(self.pos), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_digit() {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| JsonError::BadNumber(start))?;
        if is_float {
            text.parse::<f64>().map(Value::Float).map_err(|_| JsonError::BadNumber(start))
        } else {
            // Fall back to float on i64 overflow.
            text.parse::<i64>()
                .map(Value::Int)
                .or_else(|_| text.parse::<f64>().map(Value::Float))
                .map_err(|_| JsonError::BadNumber(start))
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-42").unwrap(), Value::Int(-42));
        assert_eq!(Value::parse("3.5").unwrap(), Value::Float(3.5));
        assert_eq!(Value::parse("1e3").unwrap(), Value::Float(1000.0));
        assert_eq!(Value::parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.field("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.field("c").unwrap().as_str().unwrap(), "x");
        assert!(!v.field("a").unwrap().as_array().unwrap()[2]
            .field("b")
            .unwrap()
            .as_bool()
            .unwrap());
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Value::parse(r#""a\n\t\"\\ é 😀 é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀 é");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"m","n":-5,"x":2.5,"arr":[1,2,3],"nested":{"ok":true},"s":"q\"uote"}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
        let v3 = Value::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn errors() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("01x").is_err());
        assert!(Value::parse("\"unterminated").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(matches!(
            Value::parse("\"s\"").unwrap().as_i64(),
            Err(JsonError::Type { .. })
        ));
    }

    #[test]
    fn big_int_exact() {
        // i64 weights must not round through f64.
        let v = Value::parse("9007199254740993").unwrap(); // 2^53 + 1
        assert_eq!(v.as_i64().unwrap(), 9007199254740993);
    }

    #[test]
    fn large_array_parse() {
        let text = format!("[{}]", (0..10000).map(|i| i.to_string()).collect::<Vec<_>>().join(","));
        let v = Value::parse(&text).unwrap();
        assert_eq!(v.as_array().unwrap().len(), 10000);
        assert_eq!(v.as_array().unwrap()[9999].as_i64().unwrap(), 9999);
    }

    #[test]
    fn builder() {
        let v = obj([("a", Value::from(1)), ("b", Value::from(vec![1, 2]))]);
        assert_eq!(v.field("a").unwrap().as_i64().unwrap(), 1);
        assert_eq!(v.to_string_compact(), r#"{"a":1,"b":[1, 2]}"#.replace(", ", ",").as_str());
    }
}
