//! Micro property-testing harness (the environment has no proptest crate).
//!
//! `check` runs a property over N deterministic random cases and, on
//! failure, greedily shrinks the failing case via the strategy's `shrink`
//! before panicking with the minimal reproduction. Strategies are plain
//! functions from a PRNG to a value plus an optional shrinker.

use super::rng::Pcg32;

/// A value generator with an optional shrinker.
pub struct Strategy<T> {
    pub gen: Box<dyn Fn(&mut Pcg32) -> T>,
    pub shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Strategy<T> {
    pub fn new(gen: impl Fn(&mut Pcg32) -> T + 'static) -> Strategy<T> {
        Strategy { gen: Box::new(gen), shrink: Box::new(|_| Vec::new()) }
    }

    pub fn with_shrink(mut self, shrink: impl Fn(&T) -> Vec<T> + 'static) -> Strategy<T> {
        self.shrink = Box::new(shrink);
        self
    }
}

/// Ranged usize strategy with halving shrink toward `lo`.
pub fn usize_in(lo: usize, hi: usize) -> Strategy<usize> {
    Strategy::new(move |r| r.gen_range_usize(lo, hi)).with_shrink(move |&v| {
        let mut out = Vec::new();
        if v > lo {
            out.push(lo);
            let mid = lo + (v - lo) / 2;
            if mid != lo && mid != v {
                out.push(mid);
            }
            if v - 1 != mid {
                out.push(v - 1);
            }
        }
        out
    })
}

/// Ranged i64 strategy shrinking toward 0 (or the closest bound).
pub fn i64_in(lo: i64, hi: i64) -> Strategy<i64> {
    Strategy::new(move |r| r.gen_range_i64(lo, hi)).with_shrink(move |&v| {
        let target = 0i64.clamp(lo, hi);
        let mut out = Vec::new();
        if v != target {
            out.push(target);
            let mid = target + (v - target) / 2;
            if mid != target && mid != v {
                out.push(mid);
            }
        }
        out
    })
}

/// Vec strategy: length in [min_len, max_len], elements from `elem`.
pub fn vec_of<T: Clone + 'static>(
    elem: Strategy<T>,
    min_len: usize,
    max_len: usize,
) -> Strategy<Vec<T>> {
    let elem = std::rc::Rc::new(elem);
    let e1 = elem.clone();
    Strategy::new(move |r| {
        let n = r.gen_range_usize(min_len, max_len);
        (0..n).map(|_| (e1.gen)(r)).collect()
    })
    .with_shrink(move |v: &Vec<T>| {
        let mut out = Vec::new();
        // Shrink length first.
        if v.len() > min_len {
            out.push(v[..min_len].to_vec());
            out.push(v[..v.len() - 1].to_vec());
            if v.len() / 2 >= min_len {
                out.push(v[..v.len() / 2].to_vec());
            }
        }
        // Then shrink one element at a time (first few positions).
        for i in 0..v.len().min(4) {
            for s in (elem.shrink)(&v[i]) {
                let mut w = v.clone();
                w[i] = s;
                out.push(w);
            }
        }
        out
    })
}

/// Run `prop` over `cases` deterministic random inputs; shrink + panic on
/// the first failure. `name` seeds the generator so distinct properties get
/// distinct streams but each run is reproducible.
pub fn check<T: Clone + std::fmt::Debug + 'static>(
    name: &str,
    cases: usize,
    strat: &Strategy<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Pcg32::seed_from_u64(super::rng::fnv1a(name));
    for case in 0..cases {
        let input = (strat.gen)(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 200 {
                improved = false;
                rounds += 1;
                for cand in (strat.shrink)(&best) {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property '{name}' failed (case {case}/{cases}):\n  minimal input: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("add_commutes", 200, &vec_of(i64_in(-100, 100), 0, 8), |v| {
            let s1: i64 = v.iter().sum();
            let s2: i64 = v.iter().rev().sum();
            if s1 == s2 {
                Ok(())
            } else {
                Err("sum not commutative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always_fails' failed")]
    fn failing_property_panics() {
        check("always_fails", 10, &usize_in(0, 100), |_| Err("nope".into()));
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // Property: all values < 50. Failing inputs shrink toward 50.
        let result = std::panic::catch_unwind(|| {
            check("lt_50", 100, &usize_in(0, 1000), |&v| {
                if v < 50 {
                    Ok(())
                } else {
                    Err(format!("{v} >= 50"))
                }
            });
        });
        let err = *result.unwrap_err().downcast::<String>().unwrap();
        // The shrinker halves toward 0, so the reported minimum should be
        // well below the original random failure (usually exactly 50..99).
        let min: usize = err
            .split("minimal input: ")
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(min < 200, "shrunk to {min}");
    }
}
