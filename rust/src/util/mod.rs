//! In-repo substrates for the offline build environment: JSON, PRNG,
//! a scratch-dir helper and a micro property-testing harness.

pub mod bench;
pub mod json;
pub mod proptest;
pub mod rng;

pub use json::Value;
pub use rng::{fnv1a, Pcg32};

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

static SCRATCH_COUNTER: AtomicU64 = AtomicU64::new(0);

/// A unique scratch directory under the system temp dir, removed on drop
/// (tempfile replacement for tests).
pub struct ScratchDir {
    path: PathBuf,
}

impl ScratchDir {
    pub fn new(tag: &str) -> std::io::Result<ScratchDir> {
        let n = SCRATCH_COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "aie4ml-{tag}-{}-{}",
            std::process::id(),
            n
        ));
        std::fs::create_dir_all(&path)?;
        Ok(ScratchDir { path })
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for ScratchDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_dir_lifecycle() {
        let p;
        {
            let d = ScratchDir::new("t").unwrap();
            p = d.path().to_path_buf();
            std::fs::write(p.join("x"), b"hi").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn scratch_dirs_unique() {
        let a = ScratchDir::new("u").unwrap();
        let b = ScratchDir::new("u").unwrap();
        assert_ne!(a.path(), b.path());
    }
}
