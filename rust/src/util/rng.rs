//! Deterministic PRNG (PCG32) — the offline environment has no `rand`.
//!
//! Used everywhere reproducibility matters: synthetic weights, test inputs,
//! workload generators. The exporter on the Python side uses numpy's
//! default_rng with seeds derived from the same FNV-1a name hash, so both
//! sides can generate *independent but documented* payloads; bit-identical
//! payload sharing goes through the model JSON, never through parallel
//! generation.

/// PCG-XSH-RR 32-bit generator (O'Neill 2014), seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn seed_from_u64(seed: u64) -> Pcg32 {
        // SplitMix64 to spread the seed over state+stream.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let state = next();
        let inc = next() | 1;
        let mut rng = Pcg32 { state, inc };
        rng.next_u32(); // warm up
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[lo, hi]` inclusive (unbiased via rejection).
    pub fn gen_range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "empty range");
        let span = (hi - lo) as u64 + 1;
        if span == 0 {
            // full u64 range
            return self.next_u64() as i64;
        }
        // Fast path: spans that fit u32 need only one PCG step (this is the
        // synthetic-weight-generation hot loop).
        if span <= u32::MAX as u64 {
            let span32 = span as u32;
            let zone = u32::MAX - (u32::MAX % span32);
            loop {
                let v = self.next_u32();
                if v < zone {
                    return lo + (v % span32) as i64;
                }
            }
        }
        // Lemire-style rejection.
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + (v % span) as i64;
            }
        }
    }

    pub fn gen_range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.gen_range_i64(lo as i64, hi as i64) as usize
    }

    pub fn gen_i32_in(&mut self, lo: i64, hi: i64) -> i32 {
        self.gen_range_i64(lo, hi) as i32
    }

    /// Uniform in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Random boolean with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range_usize(0, i);
            xs.swap(i, j);
        }
    }
}

/// FNV-1a 64-bit hash — stable seed derivation from names (mirrored by the
/// Python exporter).
pub fn fnv1a(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seed_from_u64(42);
        let mut b = Pcg32::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Pcg32::seed_from_u64(7);
        for _ in 0..10_000 {
            let v = r.gen_range_i64(-128, 127);
            assert!((-128..=127).contains(&v));
        }
        // Degenerate range.
        assert_eq!(r.gen_range_i64(5, 5), 5);
    }

    #[test]
    fn range_covers_extremes() {
        let mut r = Pcg32::seed_from_u64(1);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..20_000 {
            match r.gen_range_i64(0, 7) {
                0 => seen_lo = true,
                7 => seen_hi = true,
                _ => {}
            }
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn roughly_uniform() {
        let mut r = Pcg32::seed_from_u64(99);
        let mut counts = [0usize; 8];
        let n = 80_000;
        for _ in 0..n {
            counts[r.gen_range_usize(0, 7)] += 1;
        }
        for c in counts {
            let expected = n / 8;
            assert!((c as f64 - expected as f64).abs() < expected as f64 * 0.1);
        }
    }

    #[test]
    fn fnv_stable() {
        assert_eq!(fnv1a("mlp7"), fnv1a("mlp7"));
        assert_ne!(fnv1a("a"), fnv1a("b"));
        // Known FNV-1a vector.
        assert_eq!(fnv1a(""), 0xcbf29ce484222325);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::seed_from_u64(3);
        for _ in 0..1000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seed_from_u64(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
