//! Micro benchmark harness (the offline environment has no criterion).
//!
//! Each `benches/*.rs` binary regenerates one paper table/figure and times
//! the regeneration. `run` does warmup + N timed iterations and prints
//! mean / min / max wall-clock, which is what `cargo bench` surfaces.

use std::time::{Duration, Instant};

/// Timing statistics over the measured iterations.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: usize,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:>10.3?}  min {:>10.3?}  max {:>10.3?}  ({} iters)",
            self.mean, self.min, self.max, self.iters
        )
    }
}

/// Time `f` over `iters` iterations after one warmup call. The closure's
/// output is returned from the *last* iteration so benches can print the
/// regenerated table exactly once.
pub fn run<T>(name: &str, iters: usize, mut f: impl FnMut() -> T) -> (T, BenchStats) {
    let mut result = f(); // warmup
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        result = f();
        times.push(t0.elapsed());
    }
    let total: Duration = times.iter().sum();
    let stats = BenchStats {
        iters,
        mean: total / iters as u32,
        min: times.iter().min().copied().unwrap_or_default(),
        max: times.iter().max().copied().unwrap_or_default(),
    };
    println!("bench {name:<28} {stats}");
    (result, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_and_returns() {
        let mut calls = 0;
        let (out, stats) = run("noop", 5, || {
            calls += 1;
            calls
        });
        assert_eq!(out, 6); // warmup + 5 iters
        assert_eq!(stats.iters, 5);
        assert!(stats.min <= stats.mean && stats.mean <= stats.max);
    }
}
