//! Micro benchmark harness (the offline environment has no criterion).
//!
//! Each `benches/*.rs` binary regenerates one paper table/figure and times
//! the regeneration. `run` does warmup + N timed iterations and prints
//! mean / median / min / max wall-clock, which is what `cargo bench`
//! surfaces. Benches additionally emit a structured [`BenchRecord`]
//! (`BENCH_<name>.json`) so the [`crate::obs::baseline`] regression
//! sentinel can compare runs against a committed baseline.

use crate::util::json::{obj, Value};
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Environment variable naming the directory `BenchRecord::write` emits
/// into (defaults to the current directory).
pub const BENCH_OUT_ENV: &str = "AIE4ML_BENCH_OUT";

/// Timing statistics over the measured iterations.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: usize,
    pub mean: Duration,
    /// Median of the measured iterations — the noise-tolerant central
    /// value the regression sentinel records.
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:>10.3?}  min {:>10.3?}  max {:>10.3?}  ({} iters)",
            self.mean, self.min, self.max, self.iters
        )
    }
}

/// Time `f` over `iters` iterations after one warmup call. The closure's
/// output is returned from the *last* iteration so benches can print the
/// regenerated table exactly once.
pub fn run<T>(name: &str, iters: usize, mut f: impl FnMut() -> T) -> (T, BenchStats) {
    let mut result = f(); // warmup
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        result = f();
        times.push(t0.elapsed());
    }
    let total: Duration = times.iter().sum();
    times.sort();
    let median = if times.is_empty() {
        Duration::default()
    } else if times.len() % 2 == 1 {
        times[times.len() / 2]
    } else {
        (times[times.len() / 2 - 1] + times[times.len() / 2]) / 2
    };
    let stats = BenchStats {
        iters,
        mean: total / (iters.max(1)) as u32,
        median,
        min: times.first().copied().unwrap_or_default(),
        max: times.last().copied().unwrap_or_default(),
    };
    println!("bench {name:<28} {stats}");
    (result, stats)
}

/// One named metric inside a [`BenchRecord`].
#[derive(Debug, Clone)]
pub struct BenchMetric {
    pub name: String,
    pub value: f64,
    pub unit: String,
}

/// Structured output of one bench binary, serialized as
/// `BENCH_<name>.json` for the regression sentinel (`aie4ml bench-check`).
///
/// Schema (version 1):
/// ```json
/// {"schema": 1, "bench": "obs_overhead", "smoke": true,
///  "metrics": [{"name": "disabled_pct", "value": 0.2, "unit": "pct"}]}
/// ```
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub name: String,
    pub smoke: bool,
    pub metrics: Vec<BenchMetric>,
}

impl BenchRecord {
    pub fn new(name: &str, smoke: bool) -> BenchRecord {
        BenchRecord { name: name.to_string(), smoke, metrics: Vec::new() }
    }

    /// Append one metric (last write wins is *not* applied — duplicates
    /// are kept verbatim; the sentinel reads the first occurrence).
    pub fn metric(&mut self, name: &str, value: f64, unit: &str) -> &mut Self {
        self.metrics.push(BenchMetric {
            name: name.to_string(),
            value,
            unit: unit.to_string(),
        });
        self
    }

    /// Record a [`BenchStats`] as `<prefix>_median_us` / `<prefix>_mean_us`.
    pub fn stats(&mut self, prefix: &str, stats: &BenchStats) -> &mut Self {
        self.metric(&format!("{prefix}_median_us"), stats.median.as_secs_f64() * 1e6, "us");
        self.metric(&format!("{prefix}_mean_us"), stats.mean.as_secs_f64() * 1e6, "us")
    }

    pub fn to_json(&self) -> Value {
        let metrics: Vec<Value> = self
            .metrics
            .iter()
            .map(|m| {
                obj([
                    ("name", m.name.as_str().into()),
                    ("value", Value::Float(m.value)),
                    ("unit", m.unit.as_str().into()),
                ])
            })
            .collect();
        obj([
            ("schema", Value::Int(1)),
            ("bench", self.name.as_str().into()),
            ("smoke", Value::Bool(self.smoke)),
            ("metrics", Value::Array(metrics)),
        ])
    }

    /// Parse a `BENCH_<name>.json` document.
    pub fn from_json(v: &Value) -> anyhow::Result<BenchRecord> {
        let name = v.field("bench")?.as_str()?.to_string();
        let smoke = v.field("smoke")?.as_bool()?;
        let mut metrics = Vec::new();
        for m in v.field("metrics")?.as_array()? {
            metrics.push(BenchMetric {
                name: m.field("name")?.as_str()?.to_string(),
                value: m.field("value")?.as_f64()?,
                unit: m.get("unit").and_then(|u| u.as_str().ok()).unwrap_or("").to_string(),
            });
        }
        Ok(BenchRecord { name, smoke, metrics })
    }

    pub fn get(&self, metric: &str) -> Option<f64> {
        self.metrics.iter().find(|m| m.name == metric).map(|m| m.value)
    }

    /// Write `BENCH_<name>.json` into `dir`.
    pub fn write_to(&self, dir: &std::path::Path) -> std::io::Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json().to_string_pretty())?;
        Ok(path)
    }

    /// Write into `$AIE4ML_BENCH_OUT` (or the current directory) and
    /// print the destination; errors are reported, not fatal — a bench
    /// must never fail because a record directory is missing.
    pub fn write(&self) {
        let dir = std::env::var(BENCH_OUT_ENV).unwrap_or_else(|_| ".".to_string());
        match self.write_to(std::path::Path::new(&dir)) {
            Ok(path) => println!("bench record -> {}", path.display()),
            Err(e) => eprintln!("warning: could not write bench record for {}: {e}", self.name),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_and_returns() {
        let mut calls = 0;
        let (out, stats) = run("noop", 5, || {
            calls += 1;
            calls
        });
        assert_eq!(out, 6); // warmup + 5 iters
        assert_eq!(stats.iters, 5);
        assert!(stats.min <= stats.mean && stats.mean <= stats.max);
        assert!(stats.min <= stats.median && stats.median <= stats.max);
    }

    #[test]
    fn record_round_trips() {
        let mut r = BenchRecord::new("demo", true);
        r.metric("speedup", 5.5, "x").metric("cold_us", 1234.0, "us");
        let v = Value::parse(&r.to_json().to_string_compact()).unwrap();
        let back = BenchRecord::from_json(&v).unwrap();
        assert_eq!(back.name, "demo");
        assert!(back.smoke);
        assert_eq!(back.get("speedup"), Some(5.5));
        assert_eq!(back.get("cold_us"), Some(1234.0));
        assert_eq!(back.get("missing"), None);
    }

    #[test]
    fn record_writes_file() {
        let dir = std::env::temp_dir().join("aie4ml_bench_record_test");
        let mut r = BenchRecord::new("unit_demo", false);
        r.metric("v", 1.0, "");
        let path = r.write_to(&dir).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = Value::parse(&text).unwrap();
        assert_eq!(v.field("bench").unwrap().as_str().unwrap(), "unit_demo");
        std::fs::remove_dir_all(&dir).ok();
    }
}
