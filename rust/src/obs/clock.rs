//! Injected monotonic clocks for the tracer: no wall-clock reads in hot
//! paths, deterministic timestamps in tests.
//!
//! Every tracer timestamp is microseconds since an arbitrary per-clock
//! origin (Chrome trace-event `ts` semantics). Production uses
//! [`MonotonicClock`] — `Instant`-based, origin at construction, so traces
//! from one process share one timeline. Tests use [`ManualClock`] and
//! advance time explicitly: span durations and orderings become exact
//! constants instead of scheduler noise.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// A monotonic microsecond clock. Implementations must be cheap — the
/// tracer reads the clock twice per recorded span.
pub trait Clock: Send + Sync + 'static {
    /// Microseconds since this clock's origin. Must never decrease.
    fn now_us(&self) -> u64;
}

/// Production clock: microseconds since construction, from
/// [`std::time::Instant`].
#[derive(Debug)]
pub struct MonotonicClock {
    origin: Instant,
}

impl MonotonicClock {
    pub fn new() -> MonotonicClock {
        MonotonicClock { origin: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        MonotonicClock::new()
    }
}

impl Clock for MonotonicClock {
    fn now_us(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }
}

/// Test clock: time moves only when the test says so.
#[derive(Debug, Default)]
pub struct ManualClock {
    t: AtomicU64,
}

impl ManualClock {
    pub fn new() -> ManualClock {
        ManualClock::default()
    }

    /// Starting at `t` microseconds.
    pub fn at(t: u64) -> ManualClock {
        ManualClock { t: AtomicU64::new(t) }
    }

    /// Advance by `dt` microseconds, returning the new now.
    pub fn advance(&self, dt: u64) -> u64 {
        self.t.fetch_add(dt, Ordering::SeqCst) + dt
    }

    /// Jump to an absolute time (must not move backwards in tests that
    /// care about monotonicity; the clock itself does not enforce it).
    pub fn set(&self, t: u64) {
        self.t.store(t, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now_us(&self) -> u64 {
        self.t.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_is_deterministic() {
        let c = ManualClock::at(100);
        assert_eq!(c.now_us(), 100);
        assert_eq!(c.advance(50), 150);
        assert_eq!(c.now_us(), 150);
        c.set(1000);
        assert_eq!(c.now_us(), 1000);
    }

    #[test]
    fn monotonic_clock_never_decreases() {
        let c = MonotonicClock::new();
        let a = c.now_us();
        let b = c.now_us();
        assert!(b >= a);
    }
}
