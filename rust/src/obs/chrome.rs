//! Chrome trace-event JSON export (loads in Perfetto / `chrome://tracing`).
//!
//! Emits the object form — `{"traceEvents": [...]}` — with:
//!
//! - one `M` (metadata) `thread_name` event per named track, so workers,
//!   pipeline stages, and logical lanes ("queue", "autoscaler") get
//!   labelled rows in the UI;
//! - one `X` (complete) event per span, `ts`/`dur` in microseconds on the
//!   tracer clock's timeline;
//! - one `i` (instant, thread scope) event per instant record —
//!   autoscaler decisions, cache hits, shed events.
//!
//! Span parent links ride in `args.span_id` / `args.parent_id`; Perfetto
//! reconstructs nesting from `ts`/`dur` containment per track, which the
//! tracer's per-thread LIFO guard discipline guarantees.

use super::tracer::{ArgValue, EventKind, SpanRecord, TraceBatch};
use crate::util::json::{obj, Value};
use anyhow::{Context, Result};
use std::borrow::Cow;
use std::collections::BTreeMap;

/// One process id for the whole trace; tracks map to Chrome `tid`s.
const PID: i64 = 1;

fn arg_value(v: &ArgValue) -> Value {
    match v {
        ArgValue::U64(u) => {
            if *u <= i64::MAX as u64 {
                Value::Int(*u as i64)
            } else {
                Value::Float(*u as f64)
            }
        }
        ArgValue::F64(f) => Value::Float(*f),
        ArgValue::Bool(b) => Value::Bool(*b),
        ArgValue::Str(s) => Value::Str(s.clone()),
    }
}

/// Render a drained batch as Chrome trace-event JSON.
pub fn to_chrome_json(batch: &TraceBatch) -> String {
    let mut events: Vec<Value> = Vec::with_capacity(batch.records.len() + batch.track_names.len());

    for (track, label) in &batch.track_names {
        events.push(obj([
            ("ph", "M".into()),
            ("name", "thread_name".into()),
            ("pid", Value::Int(PID)),
            ("tid", Value::Int(*track as i64)),
            ("args", obj([("name", label.as_str().into())])),
        ]));
    }

    for rec in &batch.records {
        let mut args: BTreeMap<String, Value> = rec
            .args
            .iter()
            .map(|(k, v)| (k.to_string(), arg_value(v)))
            .collect();
        args.insert("span_id".to_string(), Value::Int(rec.id as i64));
        if let Some(p) = rec.parent {
            args.insert("parent_id".to_string(), Value::Int(p as i64));
        }
        let mut ev: BTreeMap<String, Value> = BTreeMap::new();
        ev.insert("name".to_string(), rec.name.as_ref().into());
        ev.insert("cat".to_string(), rec.cat.into());
        ev.insert("pid".to_string(), Value::Int(PID));
        ev.insert("tid".to_string(), Value::Int(rec.track as i64));
        ev.insert("ts".to_string(), Value::Int(rec.start_us as i64));
        ev.insert("args".to_string(), Value::Object(args));
        match rec.kind {
            EventKind::Span => {
                ev.insert("ph".to_string(), "X".into());
                ev.insert("dur".to_string(), Value::Int(rec.dur_us as i64));
            }
            EventKind::Instant => {
                ev.insert("ph".to_string(), "i".into());
                ev.insert("s".to_string(), "t".into());
            }
        }
        events.push(Value::Object(ev));
    }

    let mut root: BTreeMap<String, Value> = BTreeMap::new();
    root.insert("traceEvents".to_string(), Value::Array(events));
    root.insert("displayTimeUnit".to_string(), "ms".into());
    if batch.dropped > 0 {
        // Surface ring overflow in the file itself, not just stderr.
        root.insert("aie4ml_dropped_records".to_string(), Value::Int(batch.dropped as i64));
    }
    Value::Object(root).to_string_compact()
}

/// Intern a parsed category as `&'static str` (the [`SpanRecord`] field
/// type). Leaks one allocation per *unique* category string — bounded by
/// the handful of subsystem names a trace contains, paid only on the
/// offline `analyze` import path.
fn intern_cat(s: &str, cache: &mut BTreeMap<String, &'static str>) -> &'static str {
    if let Some(&v) = cache.get(s) {
        return v;
    }
    let leaked: &'static str = Box::leak(s.to_string().into_boxed_str());
    cache.insert(s.to_string(), leaked);
    leaked
}

/// Parse a Chrome trace-event JSON document (as produced by
/// [`to_chrome_json`]) back into a [`TraceBatch`] so the attribution
/// analyses can run on exported traces (`aie4ml analyze --trace`).
///
/// Span ids and parent links ride in `args.span_id` / `args.parent_id`;
/// events without a `span_id` (foreign traces) get synthetic ids above
/// `1 << 62`. Structured span arguments are not reconstructed (their key
/// type is `&'static str`) — ids, timing, tracks, names, and categories
/// all survive the round trip, which is everything the critical-path and
/// rollup analyses consume.
pub fn from_chrome_json(text: &str) -> Result<TraceBatch> {
    let v = Value::parse(text).context("parsing Chrome trace JSON")?;
    let events = v.field("traceEvents").context("missing traceEvents")?.as_array()?;
    let mut cats: BTreeMap<String, &'static str> = BTreeMap::new();
    let mut records = Vec::new();
    let mut track_names = Vec::new();
    let mut synthetic_id: u64 = 1 << 62;
    for ev in events {
        let ph = ev.field("ph")?.as_str()?;
        let track = ev.get("tid").and_then(|t| t.as_i64().ok()).unwrap_or(0).max(0) as u32;
        match ph {
            "M" => {
                if ev.get("name").and_then(|n| n.as_str().ok()) == Some("thread_name") {
                    if let Some(label) =
                        ev.get("args").and_then(|a| a.get("name")).and_then(|n| n.as_str().ok())
                    {
                        track_names.push((track, label.to_string()));
                    }
                }
            }
            "X" | "i" => {
                let args = ev.get("args");
                let id = args
                    .and_then(|a| a.get("span_id"))
                    .and_then(|x| x.as_i64().ok())
                    .map(|x| x.max(0) as u64)
                    .unwrap_or_else(|| {
                        synthetic_id += 1;
                        synthetic_id
                    });
                let parent = args
                    .and_then(|a| a.get("parent_id"))
                    .and_then(|x| x.as_i64().ok())
                    .map(|x| x.max(0) as u64);
                let cat = ev.get("cat").and_then(|c| c.as_str().ok()).unwrap_or("");
                let name = ev.get("name").and_then(|n| n.as_str().ok()).unwrap_or("").to_string();
                let start_us =
                    ev.get("ts").and_then(|t| t.as_i64().ok()).unwrap_or(0).max(0) as u64;
                let dur_us = if ph == "X" {
                    ev.get("dur").and_then(|d| d.as_i64().ok()).unwrap_or(0).max(0) as u64
                } else {
                    0
                };
                records.push(SpanRecord {
                    id,
                    parent,
                    track,
                    cat: intern_cat(cat, &mut cats),
                    name: Cow::Owned(name),
                    kind: if ph == "X" { EventKind::Span } else { EventKind::Instant },
                    start_us,
                    dur_us,
                    args: Vec::new(),
                });
            }
            // Foreign traces may contain other phases (B/E, counters) —
            // skip them rather than fail the import.
            _ => {}
        }
    }
    records.sort_by_key(|r| (r.start_us, r.id));
    let dropped = v
        .get("aie4ml_dropped_records")
        .and_then(|d| d.as_i64().ok())
        .unwrap_or(0)
        .max(0) as u64;
    Ok(TraceBatch { records, dropped, track_names })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::clock::ManualClock;
    use crate::obs::tracer::Tracer;

    #[test]
    fn export_parses_and_keeps_invariants() {
        let clock = ManualClock::new();
        let t = Tracer::with_clock(Box::new(clock));
        t.enable();
        t.set_track_name("test-main");
        {
            let _s = t.span("serve", "request").with_arg("id", 7u64);
            t.instant("serve", "admit").with_arg("ok", true);
        }
        let json = to_chrome_json(&t.drain());
        let v = Value::parse(&json).expect("chrome JSON must parse");
        let events = v.field("traceEvents").unwrap().as_array().unwrap();
        assert!(events.len() >= 3); // thread_name + span + instant
        let mut saw_x = false;
        let mut saw_i = false;
        for ev in events {
            let ph = ev.field("ph").unwrap().as_str().unwrap();
            match ph {
                "X" => {
                    saw_x = true;
                    assert!(ev.field("ts").unwrap().as_i64().unwrap() >= 0);
                    assert!(ev.field("dur").unwrap().as_i64().unwrap() >= 0);
                }
                "i" => {
                    saw_i = true;
                    assert_eq!(ev.field("s").unwrap().as_str().unwrap(), "t");
                }
                "M" => {
                    assert_eq!(ev.field("name").unwrap().as_str().unwrap(), "thread_name");
                }
                other => panic!("unexpected phase {other}"),
            }
        }
        assert!(saw_x && saw_i);
    }

    #[test]
    fn round_trips_through_parse() {
        use crate::obs::clock::Clock;
        use std::sync::Arc;
        let clock = Arc::new(ManualClock::new());
        struct Shared(Arc<ManualClock>);
        impl Clock for Shared {
            fn now_us(&self) -> u64 {
                self.0.now_us()
            }
        }
        let t = Tracer::with_clock(Box::new(Shared(clock.clone())));
        t.enable();
        t.set_track_name("rt-main");
        {
            let _root = t.span("serve", "request");
            clock.advance(10);
            {
                let _child = t.span("serve", "stage");
                clock.advance(25);
            }
            clock.advance(5);
        }
        let batch = t.drain();
        let json = to_chrome_json(&batch);
        let back = from_chrome_json(&json).expect("round trip parses");
        assert_eq!(back.dropped, 0);
        assert_eq!(back.track_names.len(), batch.track_names.len());
        let spans: Vec<_> =
            back.records.iter().filter(|r| r.kind == EventKind::Span).collect();
        assert_eq!(spans.len(), 2);
        let root = spans.iter().find(|r| r.name == "request").unwrap();
        let child = spans.iter().find(|r| r.name == "stage").unwrap();
        assert_eq!(root.dur_us, 40);
        assert_eq!(child.dur_us, 25);
        assert_eq!(child.parent, Some(root.id));
        assert_eq!(root.cat, "serve");
        // The attribution layer runs unchanged on the re-imported batch.
        let cp = crate::obs::attrib::critical_path(&back, None).unwrap();
        assert_eq!(cp.total_us(), 40);
        let step_sum: u64 = cp.steps.iter().map(|s| s.dur_us()).sum();
        assert_eq!(step_sum, 40);
    }
}
