//! Chrome trace-event JSON export (loads in Perfetto / `chrome://tracing`).
//!
//! Emits the object form — `{"traceEvents": [...]}` — with:
//!
//! - one `M` (metadata) `thread_name` event per named track, so workers,
//!   pipeline stages, and logical lanes ("queue", "autoscaler") get
//!   labelled rows in the UI;
//! - one `X` (complete) event per span, `ts`/`dur` in microseconds on the
//!   tracer clock's timeline;
//! - one `i` (instant, thread scope) event per instant record —
//!   autoscaler decisions, cache hits, shed events.
//!
//! Span parent links ride in `args.span_id` / `args.parent_id`; Perfetto
//! reconstructs nesting from `ts`/`dur` containment per track, which the
//! tracer's per-thread LIFO guard discipline guarantees.

use super::tracer::{ArgValue, EventKind, TraceBatch};
use crate::util::json::{obj, Value};
use std::collections::BTreeMap;

/// One process id for the whole trace; tracks map to Chrome `tid`s.
const PID: i64 = 1;

fn arg_value(v: &ArgValue) -> Value {
    match v {
        ArgValue::U64(u) => {
            if *u <= i64::MAX as u64 {
                Value::Int(*u as i64)
            } else {
                Value::Float(*u as f64)
            }
        }
        ArgValue::F64(f) => Value::Float(*f),
        ArgValue::Bool(b) => Value::Bool(*b),
        ArgValue::Str(s) => Value::Str(s.clone()),
    }
}

/// Render a drained batch as Chrome trace-event JSON.
pub fn to_chrome_json(batch: &TraceBatch) -> String {
    let mut events: Vec<Value> = Vec::with_capacity(batch.records.len() + batch.track_names.len());

    for (track, label) in &batch.track_names {
        events.push(obj([
            ("ph", "M".into()),
            ("name", "thread_name".into()),
            ("pid", Value::Int(PID)),
            ("tid", Value::Int(*track as i64)),
            ("args", obj([("name", label.as_str().into())])),
        ]));
    }

    for rec in &batch.records {
        let mut args: BTreeMap<String, Value> = rec
            .args
            .iter()
            .map(|(k, v)| (k.to_string(), arg_value(v)))
            .collect();
        args.insert("span_id".to_string(), Value::Int(rec.id as i64));
        if let Some(p) = rec.parent {
            args.insert("parent_id".to_string(), Value::Int(p as i64));
        }
        let mut ev: BTreeMap<String, Value> = BTreeMap::new();
        ev.insert("name".to_string(), rec.name.as_ref().into());
        ev.insert("cat".to_string(), rec.cat.into());
        ev.insert("pid".to_string(), Value::Int(PID));
        ev.insert("tid".to_string(), Value::Int(rec.track as i64));
        ev.insert("ts".to_string(), Value::Int(rec.start_us as i64));
        ev.insert("args".to_string(), Value::Object(args));
        match rec.kind {
            EventKind::Span => {
                ev.insert("ph".to_string(), "X".into());
                ev.insert("dur".to_string(), Value::Int(rec.dur_us as i64));
            }
            EventKind::Instant => {
                ev.insert("ph".to_string(), "i".into());
                ev.insert("s".to_string(), "t".into());
            }
        }
        events.push(Value::Object(ev));
    }

    let mut root: BTreeMap<String, Value> = BTreeMap::new();
    root.insert("traceEvents".to_string(), Value::Array(events));
    root.insert("displayTimeUnit".to_string(), "ms".into());
    if batch.dropped > 0 {
        // Surface ring overflow in the file itself, not just stderr.
        root.insert("aie4ml_dropped_records".to_string(), Value::Int(batch.dropped as i64));
    }
    Value::Object(root).to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::clock::ManualClock;
    use crate::obs::tracer::Tracer;

    #[test]
    fn export_parses_and_keeps_invariants() {
        let clock = ManualClock::new();
        let t = Tracer::with_clock(Box::new(clock));
        t.enable();
        t.set_track_name("test-main");
        {
            let _s = t.span("serve", "request").with_arg("id", 7u64);
            t.instant("serve", "admit").with_arg("ok", true);
        }
        let json = to_chrome_json(&t.drain());
        let v = Value::parse(&json).expect("chrome JSON must parse");
        let events = v.field("traceEvents").unwrap().as_array().unwrap();
        assert!(events.len() >= 3); // thread_name + span + instant
        let mut saw_x = false;
        let mut saw_i = false;
        for ev in events {
            let ph = ev.field("ph").unwrap().as_str().unwrap();
            match ph {
                "X" => {
                    saw_x = true;
                    assert!(ev.field("ts").unwrap().as_i64().unwrap() >= 0);
                    assert!(ev.field("dur").unwrap().as_i64().unwrap() >= 0);
                }
                "i" => {
                    saw_i = true;
                    assert_eq!(ev.field("s").unwrap().as_str().unwrap(), "t");
                }
                "M" => {
                    assert_eq!(ev.field("name").unwrap().as_str().unwrap(), "thread_name");
                }
                other => panic!("unexpected phase {other}"),
            }
        }
        assert!(saw_x && saw_i);
    }
}
