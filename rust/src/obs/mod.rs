//! Observability spine: spans, mergeable latency histograms, and
//! Perfetto/Prometheus export.
//!
//! The stack's telemetry used to be a patchwork of bespoke structs; this
//! module gives every layer one shared vocabulary:
//!
//! * [`tracer()`] — the process-global span tracer. Instrumentation lives
//!   in the 7-pass compile pipeline (`passes::compile`), the partition
//!   cut DP (per-candidate compile, cache hit/miss), the deploy planner's
//!   candidate sweep, autoscaler decisions (window signals as arguments),
//!   and the full serving request lifecycle (submit → admit/shed →
//!   queue → batch-form → dispatch → per-partition stage → complete).
//!   Disabled it costs one relaxed atomic load per site; enable it with
//!   `serve --trace-out <path>` or `compile --profile`.
//! * [`LatencyHistogram`] — fixed-size log-bucketed distribution whose
//!   merge is element-wise and therefore *exact*: fleet percentiles in
//!   `coordinator::metrics::MetricsReport::merged` are computed on the
//!   pooled distribution, bit-identical to per-replica-then-merge.
//! * [`chrome::to_chrome_json`] — Chrome trace-event JSON (open the file
//!   in <https://ui.perfetto.dev>); one track per worker / pipeline
//!   stage / logical lane.
//! * [`prom::to_prometheus`] — Prometheus text exposition of a serving
//!   snapshot, with conservation counters that reconcile exactly against
//!   `AdmissionReport::delta` windows.
//!
//! Clocks are injected ([`Clock`]): production uses a monotonic
//! `Instant`-based clock, tests a [`ManualClock`] — so span timings in
//! tests are exact constants, not scheduler noise.
//!
//! On top of the spine sits the attribution layer:
//!
//! * [`attrib::critical`] — self-time rollups + exact critical paths
//!   over drained (or re-imported) span trees, `aie4ml analyze --trace`;
//! * [`attrib::tiles`] — per-tile busy/peak accounting, Fig. 4-style
//!   scaling efficiency, array heatmaps, DMA-byte/hop totals;
//! * [`attrib::drift`] — measured-vs-predicted latency drift from the
//!   serving path, fed back into autoscaler capacity estimates;
//! * [`baseline`] — the bench regression sentinel over `BENCH_*.json`
//!   records (`aie4ml bench-check`, `make bench-check`).

pub mod attrib;
pub mod baseline;
pub mod chrome;
pub mod clock;
pub mod hist;
pub mod prom;
pub mod tracer;

pub use attrib::{CriticalPath, DriftDetector, DriftReport, TileUtilReport};
pub use chrome::{from_chrome_json, to_chrome_json};
pub use clock::{Clock, ManualClock, MonotonicClock};
pub use hist::LatencyHistogram;
pub use prom::{parse_prometheus, to_prometheus};
pub use tracer::{tracer, ArgValue, EventKind, Span, SpanRecord, TraceBatch, Tracer, TracerStats};
