//! Performance attribution on top of the observability spine.
//!
//! PR 8's tracer records *what happened*; this module explains *where the
//! time went* and *whether the model still matches reality*:
//!
//! * [`critical`] — self-time rollups and exact critical-path extraction
//!   over drained span trees (`aie4ml analyze --trace`).
//! * [`tiles`] — per-tile busy/peak accounting, the Fig. 4-style
//!   scaling-efficiency-vs-single-kernel number, array heatmaps, and
//!   per-stage DMA-byte/hop totals (`compile --profile`).
//! * [`drift`] — windowed measured-vs-predicted latency ratios from the
//!   serving path, exported in `ServingSnapshot`/Prometheus and fed back
//!   into the autoscaler's capacity fallback.

pub mod critical;
pub mod drift;
pub mod tiles;

pub use critical::{
    critical_path, critical_path_under, rollup, root_names, CriticalPath, NameRollup, PathStep,
};
pub use drift::{DriftDetector, DriftReport, StageDrift};
pub use tiles::{tile_utilization, StageUtil, TileUtilReport};
