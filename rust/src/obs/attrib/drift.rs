//! Model-drift detection: calibrated cycle-model predictions vs measured
//! serving latencies.
//!
//! The cycle model predicts a per-stage device time (`analyze_pipeline`
//! interval / per-partition intervals); serving measures what a batch
//! actually took. [`DriftDetector`] keeps a bounded window of measured
//! samples per stage and reports the ratio
//!
//! ```text
//!   drift = windowed mean measured latency / predicted latency
//! ```
//!
//! so `1.0` means the model is calibrated, `>1` means the model is
//! optimistic (hardware/host slower than predicted), `<1` pessimistic.
//! The overall ratio weights stages by predicted time (Σ measured /
//! Σ predicted over stages with samples), and a clamped correction
//! factor feeds the autoscaler's model-derived capacity fallback so
//! replica decisions track reality rather than a stale calibration.

use std::collections::VecDeque;

/// Default number of measured samples retained per stage.
pub const DEFAULT_WINDOW: usize = 64;

/// Correction clamp: a wildly mis-scaled model still only skews capacity
/// estimates by this factor either way.
const CORRECTION_CLAMP: f64 = 32.0;

/// Windowed measured-vs-predicted ratio for one pipeline stage.
#[derive(Debug, Clone)]
pub struct StageDrift {
    /// Stage index (partition index; 0 for a single-stage server).
    pub stage: usize,
    /// Cycle-model predicted per-batch latency in microseconds.
    pub predicted_us: f64,
    /// Windowed mean of measured per-batch latencies in microseconds.
    pub measured_us: f64,
    /// Samples currently in the window.
    pub samples: usize,
    /// `measured_us / predicted_us` (0 when no samples yet).
    pub ratio: f64,
}

/// Snapshot of every stage plus the aggregate, as carried in
/// [`crate::coordinator::ServingSnapshot`] and exported to Prometheus.
#[derive(Debug, Clone)]
pub struct DriftReport {
    pub stages: Vec<StageDrift>,
    /// Σ windowed-mean measured / Σ predicted over stages with samples.
    pub overall_ratio: f64,
    /// Clamped `overall_ratio` suitable as a capacity correction factor
    /// (1.0 until any samples arrive).
    pub correction: f64,
    pub total_samples: usize,
}

impl DriftReport {
    /// True once at least one measured sample informed the report.
    pub fn has_samples(&self) -> bool {
        self.total_samples > 0
    }
}

struct StageWindow {
    predicted_us: f64,
    window: VecDeque<f64>,
    capacity: usize,
}

impl StageWindow {
    fn mean(&self) -> f64 {
        if self.window.is_empty() {
            0.0
        } else {
            self.window.iter().sum::<f64>() / self.window.len() as f64
        }
    }
}

/// Accumulates measured per-stage latencies against fixed predictions.
/// Callers lock it around `observe`; `report` is cheap.
pub struct DriftDetector {
    stages: Vec<StageWindow>,
}

impl DriftDetector {
    /// One window per stage, with the model's predicted per-batch
    /// latency (µs) for each.
    pub fn new(predicted_us: &[f64]) -> DriftDetector {
        DriftDetector::with_window(predicted_us, DEFAULT_WINDOW)
    }

    pub fn with_window(predicted_us: &[f64], window: usize) -> DriftDetector {
        DriftDetector {
            stages: predicted_us
                .iter()
                .map(|&p| StageWindow {
                    predicted_us: p,
                    window: VecDeque::with_capacity(window.max(1)),
                    capacity: window.max(1),
                })
                .collect(),
        }
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Record one measured per-batch latency (µs) for `stage`. Out-of-
    /// range stages and non-finite samples are ignored (a serving loop
    /// must never panic on telemetry).
    pub fn observe(&mut self, stage: usize, measured_us: f64) {
        let Some(s) = self.stages.get_mut(stage) else { return };
        if !measured_us.is_finite() || measured_us < 0.0 {
            return;
        }
        if s.window.len() == s.capacity {
            s.window.pop_front();
        }
        s.window.push_back(measured_us);
    }

    pub fn report(&self) -> DriftReport {
        let mut stages = Vec::with_capacity(self.stages.len());
        let mut pred_sum = 0.0;
        let mut meas_sum = 0.0;
        let mut total_samples = 0;
        for (i, s) in self.stages.iter().enumerate() {
            let measured = s.mean();
            let samples = s.window.len();
            let ratio = if samples > 0 && s.predicted_us > 0.0 {
                measured / s.predicted_us
            } else {
                0.0
            };
            if samples > 0 && s.predicted_us > 0.0 {
                pred_sum += s.predicted_us;
                meas_sum += measured;
                total_samples += samples;
            }
            stages.push(StageDrift {
                stage: i,
                predicted_us: s.predicted_us,
                measured_us: measured,
                samples,
                ratio,
            });
        }
        let overall_ratio = if pred_sum > 0.0 { meas_sum / pred_sum } else { 0.0 };
        let correction = if total_samples > 0 && overall_ratio > 0.0 {
            overall_ratio.clamp(1.0 / CORRECTION_CLAMP, CORRECTION_CLAMP)
        } else {
            1.0
        };
        DriftReport { stages, overall_ratio, correction, total_samples }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_to_fixed_multiple() {
        let mut d = DriftDetector::with_window(&[100.0, 50.0], 8);
        for _ in 0..20 {
            d.observe(0, 300.0);
            d.observe(1, 150.0);
        }
        let r = d.report();
        assert!((r.stages[0].ratio - 3.0).abs() < 1e-9);
        assert!((r.stages[1].ratio - 3.0).abs() < 1e-9);
        assert!((r.overall_ratio - 3.0).abs() < 1e-9);
        assert!((r.correction - 3.0).abs() < 1e-9);
        // Window is bounded.
        assert_eq!(r.stages[0].samples, 8);
    }

    #[test]
    fn empty_is_neutral() {
        let d = DriftDetector::new(&[100.0]);
        let r = d.report();
        assert!(!r.has_samples());
        assert_eq!(r.correction, 1.0);
        assert_eq!(r.overall_ratio, 0.0);
    }

    #[test]
    fn ignores_bad_samples_and_clamps() {
        let mut d = DriftDetector::new(&[1.0]);
        d.observe(0, f64::NAN);
        d.observe(0, -5.0);
        d.observe(5, 10.0); // out of range
        assert!(!d.report().has_samples());
        d.observe(0, 1.0e9);
        let r = d.report();
        assert!((r.correction - 32.0).abs() < 1e-9);
    }
}
