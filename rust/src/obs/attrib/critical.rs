//! Critical-path extraction and self-time rollups over drained span trees.
//!
//! Both analyses consume a [`TraceBatch`] (live from [`Tracer::drain`] or
//! re-parsed from a Chrome export via [`crate::obs::chrome::from_chrome_json`]):
//!
//! * [`rollup`] — per-name aggregation: span count, total (inclusive)
//!   time, *self* time (inclusive minus direct children), max duration.
//!   For a well-nested single-root trace the self times partition the
//!   root's wall time exactly.
//! * [`critical_path`] — the end-to-end critical path under one root
//!   span: a backward walk from the root's end that always descends into
//!   the child ending latest, attributing every uncovered gap to the
//!   enclosing span. By construction the step durations sum to the root's
//!   wall time *exactly*, even when children overlap across tracks
//!   (concurrent workers under one request span).

use crate::obs::tracer::{EventKind, SpanRecord, TraceBatch};

/// Paranoia bound on parent-chain depth so a malformed trace (cycle in
/// the parent links) cannot recurse forever.
const MAX_DEPTH: usize = 4096;

/// One segment of the critical path: self time of `name` on `[start_us,
/// end_us)`. `depth` is the nesting depth under the root (root = 0).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PathStep {
    pub name: String,
    pub cat: String,
    pub track: u32,
    pub depth: usize,
    pub start_us: u64,
    pub end_us: u64,
}

impl PathStep {
    pub fn dur_us(&self) -> u64 {
        self.end_us.saturating_sub(self.start_us)
    }
}

/// The critical path under one root span, in chronological order.
#[derive(Debug, Clone)]
pub struct CriticalPath {
    pub root_name: String,
    pub root_start_us: u64,
    pub root_end_us: u64,
    pub steps: Vec<PathStep>,
}

impl CriticalPath {
    /// Root wall time; equals the sum of the step durations.
    pub fn total_us(&self) -> u64 {
        self.root_end_us.saturating_sub(self.root_start_us)
    }

    /// Render as an indented text table (one line per step).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "critical path: {} [{} .. {}] total {} us\n",
            self.root_name, self.root_start_us, self.root_end_us, self.total_us()
        ));
        for s in &self.steps {
            out.push_str(&format!(
                "  {:>8} us  [{:>8} .. {:>8}]  {}{}\n",
                s.dur_us(),
                s.start_us,
                s.end_us,
                "  ".repeat(s.depth),
                s.name
            ));
        }
        out
    }
}

/// Per-name aggregation over every span in a batch.
#[derive(Debug, Clone)]
pub struct NameRollup {
    pub name: String,
    pub cat: String,
    pub count: usize,
    /// Σ inclusive duration.
    pub total_us: u64,
    /// Σ (inclusive − direct children), clamped at zero per span so
    /// cross-track overlap cannot drive it negative.
    pub self_us: u64,
    pub max_us: u64,
}

struct Tree<'a> {
    spans: Vec<&'a SpanRecord>,
    /// Children indices per span index, sorted by (end_us, start_us).
    children: Vec<Vec<usize>>,
    roots: Vec<usize>,
}

fn build_tree(batch: &TraceBatch) -> Tree<'_> {
    let spans: Vec<&SpanRecord> =
        batch.records.iter().filter(|r| r.kind == EventKind::Span).collect();
    let index: std::collections::HashMap<u64, usize> =
        spans.iter().enumerate().map(|(i, r)| (r.id, i)).collect();
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); spans.len()];
    let mut roots = Vec::new();
    for (i, r) in spans.iter().enumerate() {
        match r.parent.and_then(|p| index.get(&p).copied()) {
            // A self-parented record would otherwise loop forever below.
            Some(p) if p != i => children[p].push(i),
            _ => roots.push(i),
        }
    }
    for c in &mut children {
        c.sort_by_key(|&i| (spans[i].end_us(), spans[i].start_us));
    }
    Tree { spans, children, roots }
}

/// Per-name rollups, sorted by self time descending.
pub fn rollup(batch: &TraceBatch) -> Vec<NameRollup> {
    let tree = build_tree(batch);
    let mut by_name: std::collections::BTreeMap<(String, String), NameRollup> =
        std::collections::BTreeMap::new();
    for (i, r) in tree.spans.iter().enumerate() {
        let child_us: u64 = tree.children[i].iter().map(|&c| tree.spans[c].dur_us).sum();
        let e = by_name
            .entry((r.name.to_string(), r.cat.to_string()))
            .or_insert_with(|| NameRollup {
                name: r.name.to_string(),
                cat: r.cat.to_string(),
                count: 0,
                total_us: 0,
                self_us: 0,
                max_us: 0,
            });
        e.count += 1;
        e.total_us += r.dur_us;
        e.self_us += r.dur_us.saturating_sub(child_us);
        e.max_us = e.max_us.max(r.dur_us);
    }
    let mut rows: Vec<NameRollup> = by_name.into_values().collect();
    rows.sort_by(|a, b| b.self_us.cmp(&a.self_us).then_with(|| a.name.cmp(&b.name)));
    rows
}

/// Backward walk from `t` under span `idx`; emits self segments into
/// `steps` (reverse-chronological) and returns the time it reached
/// (the span's clamped start).
fn walk(tree: &Tree<'_>, idx: usize, t: u64, depth: usize, steps: &mut Vec<PathStep>) -> u64 {
    let span = tree.spans[idx];
    let lo = span.start_us.min(t);
    let mut t = t.min(span.end_us()).max(lo);
    if depth >= MAX_DEPTH {
        push_self(span, depth, lo, t, steps);
        return lo;
    }
    loop {
        // Child ending latest within (lo, t]; ties broken toward the
        // later-starting child so the walk always makes progress.
        let next = tree.children[idx]
            .iter()
            .copied()
            .filter(|&c| {
                let e = tree.spans[c].end_us();
                e <= t && e > lo
            })
            .max_by_key(|&c| (tree.spans[c].end_us(), tree.spans[c].start_us));
        match next {
            None => {
                push_self(span, depth, lo, t, steps);
                return lo;
            }
            Some(c) => {
                let child_end = tree.spans[c].end_us();
                push_self(span, depth, child_end, t, steps);
                let reached = walk(tree, c, child_end, depth + 1, steps);
                if reached <= lo {
                    return lo;
                }
                t = reached;
            }
        }
    }
}

fn push_self(span: &SpanRecord, depth: usize, start: u64, end: u64, steps: &mut Vec<PathStep>) {
    if end > start {
        steps.push(PathStep {
            name: span.name.to_string(),
            cat: span.cat.to_string(),
            track: span.track,
            depth,
            start_us: start,
            end_us: end,
        });
    }
}

/// Critical path under the given root span record.
pub fn critical_path_under(batch: &TraceBatch, root_id: u64) -> Option<CriticalPath> {
    let tree = build_tree(batch);
    let idx = tree.spans.iter().position(|r| r.id == root_id)?;
    let root = tree.spans[idx];
    let mut steps = Vec::new();
    walk(&tree, idx, root.end_us(), 0, &mut steps);
    steps.reverse();
    Some(CriticalPath {
        root_name: root.name.to_string(),
        root_start_us: root.start_us,
        root_end_us: root.end_us(),
        steps,
    })
}

/// Critical path under the longest root span, optionally restricted to
/// roots with a given name (e.g. `"request"`).
pub fn critical_path(batch: &TraceBatch, root_name: Option<&str>) -> Option<CriticalPath> {
    let tree = build_tree(batch);
    let root = tree
        .roots
        .iter()
        .copied()
        .filter(|&i| root_name.is_none_or(|n| tree.spans[i].name == n))
        .max_by_key(|&i| (tree.spans[i].dur_us, tree.spans[i].id))?;
    critical_path_under(batch, tree.spans[root].id)
}

/// Root span names present in a batch with counts, longest-first — what
/// `analyze` offers when the requested root is absent.
pub fn root_names(batch: &TraceBatch) -> Vec<(String, usize, u64)> {
    let tree = build_tree(batch);
    let mut by_name: std::collections::BTreeMap<String, (usize, u64)> =
        std::collections::BTreeMap::new();
    for &i in &tree.roots {
        let e = by_name.entry(tree.spans[i].name.to_string()).or_insert((0, 0));
        e.0 += 1;
        e.1 = e.1.max(tree.spans[i].dur_us);
    }
    let mut rows: Vec<(String, usize, u64)> =
        by_name.into_iter().map(|(n, (c, d))| (n, c, d)).collect();
    rows.sort_by(|a, b| b.2.cmp(&a.2).then_with(|| a.0.cmp(&b.0)));
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::borrow::Cow;

    fn span(
        id: u64,
        parent: Option<u64>,
        track: u32,
        name: &str,
        start: u64,
        end: u64,
    ) -> SpanRecord {
        SpanRecord {
            id,
            parent,
            track,
            cat: "test",
            name: Cow::Owned(name.to_string()),
            kind: EventKind::Span,
            start_us: start,
            dur_us: end - start,
            args: Vec::new(),
        }
    }

    fn batch(records: Vec<SpanRecord>) -> TraceBatch {
        TraceBatch { records, dropped: 0, track_names: Vec::new() }
    }

    #[test]
    fn overlapping_concurrent_children_path_is_exact() {
        // root[0,100]; A[0,40] on track 1, B[10,90] on track 2 overlap;
        // B1[20,60] nests in B. Expected: root(0-10), B(10-20),
        // B1(20-60), B(60-90), root(90-100).
        let b = batch(vec![
            span(1, None, 0, "root", 0, 100),
            span(2, Some(1), 1, "A", 0, 40),
            span(3, Some(1), 2, "B", 10, 90),
            span(4, Some(3), 2, "B1", 20, 60),
        ]);
        let cp = critical_path(&b, None).unwrap();
        let got: Vec<(String, u64, u64)> =
            cp.steps.iter().map(|s| (s.name.clone(), s.start_us, s.end_us)).collect();
        assert_eq!(
            got,
            vec![
                ("root".to_string(), 0, 10),
                ("B".to_string(), 10, 20),
                ("B1".to_string(), 20, 60),
                ("B".to_string(), 60, 90),
                ("root".to_string(), 90, 100),
            ]
        );
        let sum: u64 = cp.steps.iter().map(|s| s.dur_us()).sum();
        assert_eq!(sum, cp.total_us());
    }

    #[test]
    fn nested_self_times_partition_root() {
        // root[0,100] > A[10,40] > A1[20,30]; root > B[50,90].
        let b = batch(vec![
            span(1, None, 0, "root", 0, 100),
            span(2, Some(1), 0, "A", 10, 40),
            span(3, Some(2), 0, "A1", 20, 30),
            span(4, Some(1), 0, "B", 50, 90),
        ]);
        let rows = rollup(&b);
        let self_of = |n: &str| rows.iter().find(|r| r.name == n).unwrap().self_us;
        assert_eq!(self_of("root"), 30);
        assert_eq!(self_of("A"), 20);
        assert_eq!(self_of("A1"), 10);
        assert_eq!(self_of("B"), 40);
        let total_self: u64 = rows.iter().map(|r| r.self_us).sum();
        assert_eq!(total_self, 100);
    }

    #[test]
    fn self_parent_and_missing_parent_do_not_loop() {
        let b =
            batch(vec![span(7, Some(7), 0, "loop", 0, 10), span(8, Some(99), 0, "orphan", 0, 5)]);
        let cp = critical_path(&b, None).unwrap();
        assert_eq!(cp.root_name, "loop");
        assert_eq!(cp.total_us(), 10);
    }
}
