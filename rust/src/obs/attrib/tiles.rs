//! Per-tile efficiency accounting: busy cycles vs architectural peak.
//!
//! [`tile_utilization`] turns compiled firmware + the calibrated cycle
//! model into an attribution report:
//!
//! * per-layer **busy fraction** — cascade head/tail kernel cycles per
//!   batch over the steady-state interval (what fraction of the pipeline
//!   slot each tile spends computing);
//! * per-layer **peak fraction** — useful MACs over the architectural
//!   peak (`macs_per_cycle` × interval) for the layer's precision pair,
//!   i.e. distance from the Table I ceiling;
//! * a whole-model **scaling efficiency** mirroring the paper's Fig. 4
//!   layer-scaling metric: achieved throughput over `tiles ×` the
//!   single-kernel baseline running the same per-tile slice
//!   back-to-back (98.6 % is the paper's i16×i8 peak);
//! * a per-array **utilization heatmap** (rows × placeable columns,
//!   busy fraction per placed tile) as a text grid and JSON;
//! * per-stage **DMA bytes** and the routed **interconnect hops** — the
//!   substrate the energy-planning roadmap item needs.

use crate::arch::{macs_per_cycle, Device};
use crate::codegen::firmware::{Firmware, MergeOp, StageRef};
use crate::passes::resolve::batch_chunk;
use crate::sim::cycles::{batch_cycles, KernelWorkload};
use crate::sim::engine::{analyze, EngineModel};
use crate::util::json::{obj, Value};

/// Per-stage utilization row (dense layers carry tile numbers; merge
/// stages are pure DMA and report zero tiles).
#[derive(Debug, Clone)]
pub struct StageUtil {
    pub name: String,
    pub tiles: usize,
    /// Kernel cycles per batch on a cascade head/mid tile.
    pub head_busy_cycles: f64,
    /// Kernel cycles per batch on a cascade tail tile (the slowest).
    pub tail_busy_cycles: f64,
    /// `tail_busy_cycles / interval` — time-busy share of the pipeline slot.
    pub busy_fraction: f64,
    /// Useful MACs over architectural peak MACs within one interval.
    pub peak_fraction: f64,
    /// Fig. 4-style per-layer scaling efficiency vs the single-kernel
    /// baseline (1.0 = perfect linear scaling).
    pub scaling_efficiency: f64,
    /// Total bytes the stage DMAs in / out per batch.
    pub dma_in_bytes: f64,
    pub dma_out_bytes: f64,
}

/// Whole-model tile-efficiency report.
#[derive(Debug, Clone)]
pub struct TileUtilReport {
    pub model_name: String,
    pub device_name: String,
    pub batch: usize,
    /// Heatmap geometry: device rows × placeable columns.
    pub rows: usize,
    pub cols: usize,
    pub interval_cycles: f64,
    pub throughput_tops: f64,
    pub tiles_used: usize,
    pub tiles_total: usize,
    pub stages: Vec<StageUtil>,
    /// Whole-model Fig. 4-style efficiency vs the single-kernel baseline.
    pub scaling_efficiency: f64,
    /// `tiles_used / tiles_total` (the paper's 296/304 = 97.4 %).
    pub array_utilization: f64,
    /// Busy fraction per placed tile, `grid[row][col]`; 0.0 = idle.
    pub grid: Vec<Vec<f64>>,
    /// Total routed stream-switch hops ([`crate::sim::interconnect`]).
    pub total_hops: usize,
}

impl TileUtilReport {
    /// Mean busy fraction over *used* tiles.
    pub fn mean_busy_fraction(&self) -> f64 {
        let (mut sum, mut n) = (0.0, 0usize);
        for row in &self.grid {
            for &v in row {
                if v > 0.0 {
                    sum += v;
                    n += 1;
                }
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Text heatmap, north row first; each placed tile prints its busy
    /// decile 0-9, idle tiles print '·'.
    pub fn render_heatmap(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "array heatmap {}x{} (busy decile per tile, '.' idle), {} / {} tiles used\n",
            self.rows, self.cols, self.tiles_used, self.tiles_total
        ));
        for r in (0..self.rows).rev() {
            out.push_str(&format!("  row {r:>2} |"));
            for c in 0..self.cols {
                let v = self.grid[r][c];
                if v > 0.0 {
                    let d = ((v * 10.0) as usize).min(9);
                    out.push_str(&d.to_string());
                } else {
                    out.push('.');
                }
            }
            out.push_str("|\n");
        }
        out
    }

    /// Per-stage table for `compile --profile`.
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<16} {:>5} {:>10} {:>8} {:>8} {:>8} {:>12} {:>12}\n",
            "stage", "tiles", "busy_cyc", "busy", "peak", "scale", "dma_in_B", "dma_out_B"
        ));
        for s in &self.stages {
            out.push_str(&format!(
                "{:<16} {:>5} {:>10.0} {:>7.1}% {:>7.1}% {:>7.1}% {:>12.0} {:>12.0}\n",
                s.name,
                s.tiles,
                s.tail_busy_cycles,
                s.busy_fraction * 100.0,
                s.peak_fraction * 100.0,
                s.scaling_efficiency * 100.0,
                s.dma_in_bytes,
                s.dma_out_bytes
            ));
        }
        out.push_str(&format!(
            "scaling efficiency vs single-kernel baseline: {:.1}%  (array utilization {:.1}%, {} hops)\n",
            self.scaling_efficiency * 100.0,
            self.array_utilization * 100.0,
            self.total_hops
        ));
        out
    }

    pub fn to_json(&self) -> Value {
        let stages: Vec<Value> = self
            .stages
            .iter()
            .map(|s| {
                obj([
                    ("name", s.name.as_str().into()),
                    ("tiles", Value::Int(s.tiles as i64)),
                    ("head_busy_cycles", Value::Float(s.head_busy_cycles)),
                    ("tail_busy_cycles", Value::Float(s.tail_busy_cycles)),
                    ("busy_fraction", Value::Float(s.busy_fraction)),
                    ("peak_fraction", Value::Float(s.peak_fraction)),
                    ("scaling_efficiency", Value::Float(s.scaling_efficiency)),
                    ("dma_in_bytes", Value::Float(s.dma_in_bytes)),
                    ("dma_out_bytes", Value::Float(s.dma_out_bytes)),
                ])
            })
            .collect();
        let grid: Vec<Value> = self
            .grid
            .iter()
            .map(|row| Value::Array(row.iter().map(|&v| Value::Float(v)).collect()))
            .collect();
        obj([
            ("model", self.model_name.as_str().into()),
            ("device", self.device_name.as_str().into()),
            ("batch", Value::Int(self.batch as i64)),
            ("rows", Value::Int(self.rows as i64)),
            ("cols", Value::Int(self.cols as i64)),
            ("interval_cycles", Value::Float(self.interval_cycles)),
            ("throughput_tops", Value::Float(self.throughput_tops)),
            ("tiles_used", Value::Int(self.tiles_used as i64)),
            ("tiles_total", Value::Int(self.tiles_total as i64)),
            ("scaling_efficiency", Value::Float(self.scaling_efficiency)),
            ("array_utilization", Value::Float(self.array_utilization)),
            ("total_hops", Value::Int(self.total_hops as i64)),
            ("stages", Value::Array(stages)),
            ("grid", Value::Array(grid)),
        ])
    }
}

/// Build the tile-efficiency report for one compiled firmware.
pub fn tile_utilization(fw: &Firmware, model: &EngineModel) -> TileUtilReport {
    let device: &Device = &fw.device;
    let batch = fw.batch;
    let report = analyze(fw, model);
    let interval = report.interval_cycles.max(1.0);
    let rows = device.rows;
    let cols = device.placeable_cols();
    let mut grid = vec![vec![0.0f64; cols]; rows];

    // Fig. 4 aggregation: achieved rate over the ideal `tiles × single
    // kernel` rate, ops-weighted across dense layers —
    //   eff = (Σ_l w_l / interval) / (Σ_l w_l / tail_l)
    // which degenerates to tail/interval for a single layer, exactly the
    // per-layer scaling-efficiency definition.
    let mut w_over_interval = 0.0;
    let mut w_over_tail = 0.0;

    let mut stages = Vec::with_capacity(fw.stages.len());
    for s in &fw.stages {
        match s.op {
            StageRef::Layer(li) => {
                let layer = &fw.layers[li];
                let geo = layer.cascade;
                let q = layer.quant;
                // A lowered conv runs `batch × m_scale` GEMM rows per batch.
                let rows = layer.gemm_rows(batch);
                let (chunk, _) =
                    batch_chunk(device, &layer.tiling, &q, geo.f_in_slice, geo.f_out_slice, rows)
                        .expect("emission validated local memory");
                let tail = KernelWorkload {
                    batch: chunk,
                    f_in_slice: geo.f_in_slice,
                    f_out_slice: geo.f_out_slice,
                    tiling: layer.tiling,
                    use_bias: layer.use_bias,
                    relu: layer.relu,
                    is_tail: true,
                };
                let head = KernelWorkload { is_tail: false, ..tail };
                let tail_busy = batch_cycles(
                    rows,
                    chunk,
                    &tail,
                    &model.kernel,
                    device.generation,
                    device.load_port_bytes,
                );
                let head_busy = batch_cycles(
                    rows,
                    chunk,
                    &head,
                    &model.kernel,
                    device.generation,
                    device.load_port_bytes,
                );
                let busy_fraction = (tail_busy / interval).min(1.0);
                let mpc = macs_per_cycle(device.generation, layer.tiling.pair).unwrap_or(0) as f64;
                // Padded per-tile GEMM slice — the work the kernel actually
                // streams, used to busy-weight the scaling aggregate.
                let slice_macs = (rows * geo.f_in_slice * geo.f_out_slice) as f64;
                // Peak fraction counts the layer's *true* MACs — for a
                // lowered conv that is OH·OW·KH·KW·C_in·C_out per sample,
                // never the padded GEMM shape's inflated figure.
                let true_macs =
                    (batch * layer.macs_per_sample()) as f64 / layer.tiles().max(1) as f64;
                let peak_fraction =
                    if mpc > 0.0 { (true_macs / (mpc * interval)).min(1.0) } else { 0.0 };
                let scaling_efficiency =
                    if tail_busy > 0.0 { (tail_busy / interval).min(1.0) } else { 0.0 };
                if tail_busy > 0.0 {
                    let w = (layer.tiles() as f64) * slice_macs;
                    w_over_interval += w / interval;
                    w_over_tail += w / tail_busy;
                }
                // Every cascade column streams its own input slice (for a
                // conv: the patch walk's rows×K traffic); each cascade-row
                // tail stores its output slice.
                let dma_in_bytes =
                    (rows * geo.f_in_slice * q.input.dtype.bytes() * geo.cas_len) as f64;
                let dma_out_bytes = (rows * layer.out_features * q.output.dtype.bytes()) as f64;
                // Paint the placement rect: tails sit on the east column of
                // each cascade row (the cascade flows west→east).
                let rect = layer.placement;
                for dy in 0..rect.height {
                    for dx in 0..rect.width {
                        let (r, c) = (rect.row + dy, rect.col + dx);
                        if r < rows && c < cols {
                            let busy =
                                if dx + 1 == rect.width { tail_busy } else { head_busy };
                            grid[r][c] = (busy / interval).min(1.0).max(grid[r][c]);
                        }
                    }
                }
                stages.push(StageUtil {
                    name: layer.name.clone(),
                    tiles: layer.tiles(),
                    head_busy_cycles: head_busy,
                    tail_busy_cycles: tail_busy,
                    busy_fraction,
                    peak_fraction,
                    scaling_efficiency,
                    dma_in_bytes,
                    dma_out_bytes,
                });
            }
            StageRef::Merge(mi) => {
                let m = &fw.merges[mi];
                let (dma_in_bytes, dma_out_bytes) = if m.plan.offset_tiled() {
                    (0.0, 0.0)
                } else {
                    let bytes = m.quant.dtype.bytes();
                    let out = (batch * m.features * bytes) as f64;
                    let inb = match m.op {
                        MergeOp::Add => out * m.plan.write_tilers.len() as f64,
                        MergeOp::Concat => out,
                        // Pooling lands the image then re-reads the window
                        // walk's taps; transpose lands and re-reads once.
                        MergeOp::MaxPool2D(p) | MergeOp::AvgPool2D(p) => {
                            let image = (batch * p.in_features() * bytes) as f64;
                            let walk =
                                (batch * p.out_h() * p.out_w() * p.kh * p.kw * p.c * bytes) as f64;
                            image + walk
                        }
                        MergeOp::Transpose { .. } => out * 2.0,
                    };
                    (inb, out)
                };
                stages.push(StageUtil {
                    name: m.name.clone(),
                    tiles: 0,
                    head_busy_cycles: 0.0,
                    tail_busy_cycles: 0.0,
                    busy_fraction: 0.0,
                    peak_fraction: 0.0,
                    scaling_efficiency: 0.0,
                    dma_in_bytes,
                    dma_out_bytes,
                });
            }
        }
    }

    let scaling_efficiency = if w_over_tail > 0.0 { w_over_interval / w_over_tail } else { 0.0 };
    let tiles_total = device.placeable_tiles();
    let total_hops = crate::sim::interconnect::route_firmware(fw)
        .map(|p| p.total_hops)
        .unwrap_or(0);
    TileUtilReport {
        model_name: fw.model_name.clone(),
        device_name: device.name.clone(),
        batch,
        rows,
        cols,
        interval_cycles: report.interval_cycles,
        throughput_tops: report.throughput_tops,
        tiles_used: fw.tiles_used(),
        tiles_total,
        stages,
        scaling_efficiency,
        array_utilization: fw.tiles_used() as f64 / tiles_total.max(1) as f64,
        grid,
        total_hops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{CompileConfig, JsonLayer, JsonModel, LayerConfig};
    use crate::passes::compile;

    fn fw(dims: &[usize], batch: usize, cascade: (usize, usize)) -> Firmware {
        let layers: Vec<JsonLayer> = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| {
                JsonLayer::dense(
                    &format!("fc{}", i + 1),
                    w[0],
                    w[1],
                    true,
                    true,
                    "int8",
                    "int8",
                    6,
                    vec![1; w[0] * w[1]],
                    vec![0i64; w[1]],
                )
            })
            .collect();
        let jm = JsonModel::new("util", layers);
        let mut cfg = CompileConfig::default();
        cfg.batch = batch;
        for i in 0..dims.len() - 1 {
            cfg.layers.insert(
                format!("fc{}", i + 1),
                LayerConfig { cascade: Some(cascade), ..Default::default() },
            );
        }
        compile(&jm, cfg).unwrap().firmware.unwrap()
    }

    #[test]
    fn fractions_are_sane_and_grid_matches_tiles() {
        let f = fw(&[256, 256], 64, (4, 4));
        let r = tile_utilization(&f, &EngineModel::default());
        assert_eq!(r.stages.len(), 1);
        let s = &r.stages[0];
        assert!(s.busy_fraction > 0.0 && s.busy_fraction <= 1.0);
        assert!(s.peak_fraction > 0.0 && s.peak_fraction <= 1.0);
        assert!(r.scaling_efficiency > 0.0 && r.scaling_efficiency <= 1.0);
        // The compute-bound single layer is its own bottleneck: the tail
        // busy time is the interval, so scaling efficiency is high.
        assert!(r.scaling_efficiency > 0.5, "eff {}", r.scaling_efficiency);
        let painted: usize =
            r.grid.iter().map(|row| row.iter().filter(|&&v| v > 0.0).count()).sum();
        assert_eq!(painted, f.tiles_used());
        assert_eq!(r.tiles_used, 16);
        // JSON renders and re-parses.
        let v = Value::parse(&r.to_json().to_string_compact()).unwrap();
        assert_eq!(v.field("tiles_used").unwrap().as_i64().unwrap(), 16);
        assert!(!r.render_heatmap().is_empty());
        assert!(!r.render_table().is_empty());
    }

    #[test]
    fn single_layer_efficiency_equals_tail_over_interval() {
        let f = fw(&[512, 512], 128, (4, 4));
        let r = tile_utilization(&f, &EngineModel::default());
        let s = &r.stages[0];
        let expect = (s.tail_busy_cycles / r.interval_cycles).min(1.0);
        assert!((r.scaling_efficiency - expect).abs() < 1e-9);
    }
}
