//! Bench regression sentinel: compare `BENCH_*.json` records against a
//! committed baseline with noise-tolerant thresholds.
//!
//! Every bench binary emits a [`BenchRecord`]; `aie4ml bench-check`
//! (`make bench-check`) loads the records plus `benches/BASELINE.json`
//! and evaluates each baseline entry:
//!
//! * `max` / `min` — absolute bounds (machine-independent budgets such
//!   as the obs-overhead percentages, cache speedups, modeled cycle
//!   counts);
//! * `baseline` + `rel_budget` — relative bound `value ≤ baseline ×
//!   (1 + rel_budget)` for lower-is-better metrics (wall-clock medians),
//!   tolerant to host noise;
//! * `enforce` — entries that gate even in report-only mode (the CI PR
//!   job); non-enforced entries are informational there and gate only a
//!   full `bench-check`.
//!
//! A missing record or metric for an *enforced* entry is a failure in
//! every mode: silently dropping a bench is itself a regression.
//!
//! Baseline schema (version 1):
//! ```json
//! {"schema": 1, "entries": [
//!   {"bench": "obs_overhead", "metric": "disabled_pct", "max": 1.0,
//!    "enforce": true},
//!   {"bench": "compile_throughput", "metric": "warm_us",
//!    "baseline": 1200.0, "rel_budget": 2.0}
//! ]}
//! ```
//!
//! Updating the baseline: run `make bench-check`, inspect the report,
//! copy the new steady value into `benches/BASELINE.json` in the same
//! change that justifies it.

use crate::util::bench::BenchRecord;
use crate::util::json::Value;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// One budgeted metric in the committed baseline.
#[derive(Debug, Clone)]
pub struct BaselineEntry {
    pub bench: String,
    pub metric: String,
    /// Reference value for relative comparison (lower is better).
    pub baseline: Option<f64>,
    /// Allowed relative regression over `baseline` (e.g. `2.0` = 3×).
    pub rel_budget: Option<f64>,
    /// Absolute upper bound.
    pub max: Option<f64>,
    /// Absolute lower bound (for higher-is-better metrics).
    pub min: Option<f64>,
    /// Gate even in report-only mode.
    pub enforce: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FindingStatus {
    Pass,
    Fail,
    /// The record or metric was not produced by the run.
    Missing,
}

/// Outcome of one baseline entry against the loaded records.
#[derive(Debug, Clone)]
pub struct Finding {
    pub bench: String,
    pub metric: String,
    pub value: Option<f64>,
    /// Human-readable bound, e.g. `<= 1` or `<= 3600 (1200 +200%)`.
    pub limit: String,
    pub status: FindingStatus,
    pub enforce: bool,
}

/// Full sentinel outcome.
#[derive(Debug, Clone)]
pub struct SentinelReport {
    pub findings: Vec<Finding>,
    /// Bench records that were loaded (name, smoke flag).
    pub records: Vec<(String, bool)>,
}

impl SentinelReport {
    /// Entries that gate a report-only (PR) run.
    pub fn gating_failures(&self) -> Vec<&Finding> {
        self.findings
            .iter()
            .filter(|f| f.enforce && f.status != FindingStatus::Pass)
            .collect()
    }

    /// Entries that gate a full run.
    pub fn all_failures(&self) -> Vec<&Finding> {
        self.findings.iter().filter(|f| f.status != FindingStatus::Pass).collect()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "bench sentinel: {} records, {} budgeted metrics\n",
            self.records.len(),
            self.findings.len()
        ));
        for f in &self.findings {
            let status = match f.status {
                FindingStatus::Pass => "PASS",
                FindingStatus::Fail => "FAIL",
                FindingStatus::Missing => "MISSING",
            };
            let value = match f.value {
                Some(v) => format!("{v:.4}"),
                None => "-".to_string(),
            };
            out.push_str(&format!(
                "  {status:<8} {:<24} {:<28} value {:>12}  budget {}{}\n",
                f.bench,
                f.metric,
                value,
                f.limit,
                if f.enforce { "  [enforced]" } else { "" }
            ));
        }
        out
    }
}

fn opt_f64(v: &Value, key: &str) -> Result<Option<f64>> {
    match v.get(key) {
        Some(x) => Ok(Some(x.as_f64()?)),
        None => Ok(None),
    }
}

/// Parse `BASELINE.json`.
pub fn parse_baseline(text: &str) -> Result<Vec<BaselineEntry>> {
    let v = Value::parse(text).context("parsing baseline JSON")?;
    let schema = v.field("schema")?.as_i64()?;
    if schema != 1 {
        bail!("unsupported baseline schema {schema}");
    }
    let mut entries = Vec::new();
    for e in v.field("entries")?.as_array()? {
        let entry = BaselineEntry {
            bench: e.field("bench")?.as_str()?.to_string(),
            metric: e.field("metric")?.as_str()?.to_string(),
            baseline: opt_f64(e, "baseline")?,
            rel_budget: opt_f64(e, "rel_budget")?,
            max: opt_f64(e, "max")?,
            min: opt_f64(e, "min")?,
            enforce: match e.get("enforce") {
                Some(b) => b.as_bool()?,
                None => false,
            },
        };
        if entry.baseline.is_none() && entry.max.is_none() && entry.min.is_none() {
            bail!(
                "baseline entry {}/{} has no bound (need baseline+rel_budget, max, or min)",
                entry.bench,
                entry.metric
            );
        }
        entries.push(entry);
    }
    Ok(entries)
}

pub fn load_baseline(path: &Path) -> Result<Vec<BaselineEntry>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading baseline {}", path.display()))?;
    parse_baseline(&text)
}

/// Load every `BENCH_*.json` in `dir` (non-recursive).
pub fn load_records(dir: &Path) -> Result<Vec<BenchRecord>> {
    let mut records = Vec::new();
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("reading bench record dir {}", dir.display()))?;
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().to_string();
        if !(name.starts_with("BENCH_") && name.ends_with(".json")) {
            continue;
        }
        let text = std::fs::read_to_string(entry.path())
            .with_context(|| format!("reading {}", entry.path().display()))?;
        let v = Value::parse(&text).with_context(|| format!("parsing {name}"))?;
        records.push(
            BenchRecord::from_json(&v).with_context(|| format!("decoding record {name}"))?,
        );
    }
    records.sort_by(|a, b| a.name.cmp(&b.name));
    Ok(records)
}

/// Evaluate the baseline against the records.
pub fn check(entries: &[BaselineEntry], records: &[BenchRecord]) -> SentinelReport {
    let mut findings = Vec::with_capacity(entries.len());
    for e in entries {
        let value = records.iter().find(|r| r.name == e.bench).and_then(|r| r.get(&e.metric));
        let mut limits = Vec::new();
        if let Some(max) = e.max {
            limits.push(format!("<= {max}"));
        }
        if let Some(min) = e.min {
            limits.push(format!(">= {min}"));
        }
        if let (Some(base), Some(rel)) = (e.baseline, e.rel_budget) {
            limits.push(format!("<= {:.4} ({base} +{:.0}%)", base * (1.0 + rel), rel * 100.0));
        }
        let status = match value {
            None => FindingStatus::Missing,
            Some(v) if !v.is_finite() => FindingStatus::Fail,
            Some(v) => {
                let mut ok = true;
                if let Some(max) = e.max {
                    ok &= v <= max;
                }
                if let Some(min) = e.min {
                    ok &= v >= min;
                }
                if let (Some(base), Some(rel)) = (e.baseline, e.rel_budget) {
                    ok &= v <= base * (1.0 + rel);
                }
                if ok {
                    FindingStatus::Pass
                } else {
                    FindingStatus::Fail
                }
            }
        };
        findings.push(Finding {
            bench: e.bench.clone(),
            metric: e.metric.clone(),
            value,
            limit: limits.join(" and "),
            status,
            enforce: e.enforce,
        });
    }
    SentinelReport {
        findings,
        records: records.iter().map(|r| (r.name.clone(), r.smoke)).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(name: &str, metric: &str, value: f64) -> BenchRecord {
        let mut r = BenchRecord::new(name, true);
        r.metric(metric, value, "");
        r
    }

    #[test]
    fn absolute_and_relative_bounds() {
        let baseline = r#"{"schema": 1, "entries": [
            {"bench": "a", "metric": "pct", "max": 1.0, "enforce": true},
            {"bench": "b", "metric": "speedup", "min": 5.0},
            {"bench": "c", "metric": "wall_us", "baseline": 100.0, "rel_budget": 1.0}
        ]}"#;
        let entries = parse_baseline(baseline).unwrap();
        let records = vec![
            record("a", "pct", 0.5),
            record("b", "speedup", 7.0),
            record("c", "wall_us", 150.0),
        ];
        let report = check(&entries, &records);
        assert!(report.all_failures().is_empty(), "{}", report.render());

        let bad = vec![
            record("a", "pct", 2.0),
            record("b", "speedup", 3.0),
            record("c", "wall_us", 250.0),
        ];
        let report = check(&entries, &bad);
        assert_eq!(report.all_failures().len(), 3);
        // Only the enforced entry gates report-only mode.
        assert_eq!(report.gating_failures().len(), 1);
        assert_eq!(report.gating_failures()[0].bench, "a");
    }

    #[test]
    fn missing_enforced_metric_gates() {
        let entries = parse_baseline(
            r#"{"schema": 1, "entries": [
                {"bench": "gone", "metric": "pct", "max": 1.0, "enforce": true}
            ]}"#,
        )
        .unwrap();
        let report = check(&entries, &[]);
        assert_eq!(report.findings[0].status, FindingStatus::Missing);
        assert_eq!(report.gating_failures().len(), 1);
    }

    #[test]
    fn entry_without_bound_is_rejected() {
        let res = parse_baseline(
            r#"{"schema": 1, "entries": [{"bench": "x", "metric": "y"}]}"#,
        );
        assert!(res.is_err());
    }

    #[test]
    fn round_trip_through_directory() {
        let dir = std::env::temp_dir().join("aie4ml_sentinel_test");
        std::fs::remove_dir_all(&dir).ok();
        record("demo", "pct", 0.25).write_to(&dir).unwrap();
        let records = load_records(&dir).unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].get("pct"), Some(0.25));
        std::fs::remove_dir_all(&dir).ok();
    }
}
