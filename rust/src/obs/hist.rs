//! Mergeable log-bucketed latency histograms.
//!
//! Replaces the sorted-`Vec<f64>` percentile samples in
//! `coordinator::metrics`. The design goals, in order:
//!
//! 1. **Merge is exact.** A histogram is a fixed vector of bucket counts
//!    plus exact `count/sum/min/max`; merging is element-wise addition.
//!    Any quantile computed from a merged histogram is therefore
//!    *bit-identical* to the quantile computed from one histogram fed all
//!    the samples — there is no per-replica information loss for the
//!    merge to approximate. This is what fixes `MetricsReport::merged`
//!    tail semantics: fleet p99 is the p99 of the pooled distribution,
//!    not the worst replica's.
//! 2. **Bounded memory.** [`NUM_BUCKETS`] fixed `u64` slots (~2 KiB per
//!    histogram) regardless of how many samples land — sustained serving
//!    load cannot grow it.
//! 3. **Known resolution.** Buckets grow by γ = 2^(1/8) (8 sub-buckets
//!    per octave, ≈ 9.05% relative width), so any quantile is within
//!    ±4.5% of the exact sample quantile; `min`/`max`/`sum`/`count` are
//!    exact, and quantile results are clamped into `[min, max]`.

/// Lowest bucket upper bound, µs. Everything at or below lands in
/// bucket 0.
const BASE_US: f64 = 0.1;

/// Sub-buckets per octave: γ = 2^(1/8) ≈ 1.0905.
const SUB_BUCKETS: f64 = 8.0;

/// Bucket 254's upper bound is BASE·2^(255/8) ≈ 4.5×10^8 µs (~7.5 min);
/// bucket 255 is the overflow bucket (+Inf).
pub const NUM_BUCKETS: usize = 256;

/// A fixed-size log-bucketed histogram of microsecond latencies.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    count: u64,
    sum_us: f64,
    min_us: f64,
    max_us: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

/// Upper bound of bucket `i` in µs (`+Inf` for the last bucket).
pub fn bucket_upper_us(i: usize) -> f64 {
    if i + 1 >= NUM_BUCKETS {
        f64::INFINITY
    } else {
        BASE_US * ((i + 1) as f64 / SUB_BUCKETS).exp2()
    }
}

fn bucket_lower_us(i: usize) -> f64 {
    if i == 0 {
        0.0
    } else {
        BASE_US * (i as f64 / SUB_BUCKETS).exp2()
    }
}

/// Bucket index for a value: the smallest `i` with `v <= upper(i)`.
fn bucket_index(v_us: f64) -> usize {
    if v_us <= BASE_US {
        return 0;
    }
    let f = SUB_BUCKETS * (v_us / BASE_US).log2();
    let i = (f.ceil() as i64 - 1).max(0) as usize;
    i.min(NUM_BUCKETS - 1)
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            counts: vec![0; NUM_BUCKETS],
            count: 0,
            sum_us: 0.0,
            min_us: f64::INFINITY,
            max_us: 0.0,
        }
    }

    /// Record one latency sample (µs). Negative/NaN samples are clamped
    /// to 0 (they land in bucket 0 and drag `min` to 0, which is the
    /// least-surprising rendering of a corrupt sample).
    pub fn record_us(&mut self, v_us: f64) {
        let v = if v_us.is_finite() && v_us > 0.0 { v_us } else { 0.0 };
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum_us += v;
        self.min_us = self.min_us.min(v);
        self.max_us = self.max_us.max(v);
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    /// Element-wise merge. `merge(a, b)` then `quantile` is bit-identical
    /// to recording all of `a`'s and `b`'s samples into one histogram.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += *b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of all recorded samples, µs.
    pub fn sum_us(&self) -> f64 {
        self.sum_us
    }

    /// Exact mean, µs (0 when empty).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us / self.count as f64
        }
    }

    /// Exact minimum recorded sample, µs (0 when empty).
    pub fn min_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min_us
        }
    }

    /// Exact maximum recorded sample, µs (0 when empty).
    pub fn max_us(&self) -> f64 {
        self.max_us
    }

    /// Quantile estimate, µs: the value at rank `ceil(q·count)` with
    /// linear interpolation inside the containing bucket, clamped into
    /// `[min, max]`. `q` outside [0,1] is clamped. Returns 0 when empty.
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if cum + c >= target {
                let lower = bucket_lower_us(i);
                let upper = if bucket_upper_us(i).is_finite() {
                    bucket_upper_us(i)
                } else {
                    // Overflow bucket: max is exact, use it as the cap.
                    self.max_us
                };
                let frac = (target - cum) as f64 / c as f64;
                let v = lower + frac * (upper - lower);
                return v.clamp(self.min_us, self.max_us);
            }
            cum += c;
        }
        self.max_us
    }

    /// Cumulative non-empty buckets for Prometheus exposition:
    /// `(upper_bound_us, cumulative_count)` at each non-empty bucket, in
    /// ascending order. The implicit `+Inf` bucket equals [`Self::count`].
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            cum += c;
            out.push((bucket_upper_us(i), cum));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds_bracket_the_value() {
        for &v in &[0.05, 0.1, 0.11, 1.0, 7.3, 100.0, 5e4, 1e7] {
            let i = bucket_index(v);
            assert!(v <= bucket_upper_us(i) * (1.0 + 1e-12), "v={v} i={i}");
            if i > 0 {
                assert!(v > bucket_lower_us(i) * (1.0 - 1e-9), "v={v} i={i}");
            }
        }
    }

    #[test]
    fn quantiles_within_bucket_resolution() {
        let mut h = LatencyHistogram::new();
        for v in 1..=1000u64 {
            h.record_us(v as f64);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.min_us(), 1.0);
        assert_eq!(h.max_us(), 1000.0);
        // γ = 2^(1/8): any quantile is within ±4.6% of exact.
        let p50 = h.quantile_us(0.50);
        let p99 = h.quantile_us(0.99);
        assert!((p50 - 500.0).abs() / 500.0 < 0.05, "p50={p50}");
        assert!((p99 - 990.0).abs() / 990.0 < 0.05, "p99={p99}");
        assert!(h.quantile_us(1.0) <= h.max_us() + 1e-9);
        assert!(h.quantile_us(0.0) >= h.min_us() - 1e-9);
    }

    #[test]
    fn merged_quantiles_are_bit_identical_to_pooled() {
        // Two very asymmetric replicas.
        let fast: Vec<f64> = (1..=900).map(|v| v as f64).collect();
        let slow: Vec<f64> = (1..=100).map(|v| 5000.0 + 13.0 * v as f64).collect();

        let mut pooled = LatencyHistogram::new();
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for &v in &fast {
            pooled.record_us(v);
            a.record_us(v);
        }
        for &v in &slow {
            pooled.record_us(v);
            b.record_us(v);
        }
        let mut merged = a.clone();
        merged.merge(&b);

        // Bit-identical, not approximately equal: element-wise counts and
        // exact moments make the merged struct indistinguishable from the
        // pooled one.
        assert_eq!(merged, pooled);
        for &q in &[0.0, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
            assert_eq!(merged.quantile_us(q).to_bits(), pooled.quantile_us(q).to_bits());
        }
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = LatencyHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile_us(0.99), 0.0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.min_us(), 0.0);
        assert_eq!(h.max_us(), 0.0);
        assert!(h.cumulative_buckets().is_empty());
    }

    #[test]
    fn single_sample_quantiles_are_exact() {
        let mut h = LatencyHistogram::new();
        h.record_us(42.0);
        // Clamping to [min, max] makes every quantile exact for n=1.
        assert_eq!(h.quantile_us(0.5), 42.0);
        assert_eq!(h.quantile_us(0.99), 42.0);
    }

    #[test]
    fn overflow_bucket_uses_exact_max() {
        let mut h = LatencyHistogram::new();
        h.record_us(1e12); // far beyond the last finite bound
        h.record_us(1e12);
        assert_eq!(h.quantile_us(0.99), 1e12);
        let cum = h.cumulative_buckets();
        assert_eq!(cum, vec![(f64::INFINITY, 2)]);
    }

    #[test]
    fn cumulative_buckets_reach_total_count() {
        let mut h = LatencyHistogram::new();
        for v in [1.0, 10.0, 100.0, 1000.0] {
            h.record_us(v);
        }
        let cum = h.cumulative_buckets();
        assert_eq!(cum.last().unwrap().1, h.count());
        // Ascending in both bound and count.
        for w in cum.windows(2) {
            assert!(w[0].0 < w[1].0 && w[0].1 < w[1].1);
        }
    }

    #[test]
    fn corrupt_samples_clamp_to_zero() {
        let mut h = LatencyHistogram::new();
        h.record_us(f64::NAN);
        h.record_us(-5.0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.min_us(), 0.0);
        assert_eq!(h.max_us(), 0.0);
        assert_eq!(h.sum_us(), 0.0);
    }
}
