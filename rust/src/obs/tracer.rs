//! Span tracer: structured request/compile lifecycle recording with
//! injected clocks and lock-light sharded ring buffers.
//!
//! Design constraints, in order:
//!
//! 1. **Near-zero cost when disabled.** Every instrumentation point pays
//!    one relaxed atomic load and returns an inert guard. No clock read,
//!    no allocation, no lock. The serving hot path is instrumented
//!    unconditionally and gated here ([`benches/obs_overhead.rs`] pins
//!    the budget).
//! 2. **Lock-light when enabled.** Finished spans land in one of
//!    [`SHARDS`] ring buffers selected by the recording thread's track id,
//!    so each worker thread almost always has a shard to itself; the only
//!    cross-thread contention is the drain. Rings are bounded: sustained
//!    load overwrites the oldest records and counts the drops instead of
//!    growing without bound.
//! 3. **Deterministic in tests.** Timestamps come from an injected
//!    [`Clock`]; a [`ManualClock`](super::clock::ManualClock) makes span
//!    durations exact constants.
//!
//! Spans are recorded *complete* (start + duration) when their guard
//! drops — there is no unmatched-begin failure mode, and parent links are
//! maintained per-thread: a span opened while another span of the same
//! tracer is open on the same thread becomes its child. Cross-thread
//! phases (e.g. a request's queue wait, submitted on a client thread and
//! claimed on a worker) are recorded explicitly via
//! [`Tracer::record_span`] onto a logical track.

use super::clock::{Clock, MonotonicClock};
use std::borrow::Cow;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Ring-buffer shards. Track ids map onto shards round-robin, so up to
/// this many recording threads write without contending.
const SHARDS: usize = 16;

/// Default per-shard ring capacity (records). 16 shards × 16 Ki records
/// bounds tracer memory at a few tens of MiB worst case.
const DEFAULT_SHARD_CAPACITY: usize = 16 * 1024;

/// One span argument value.
#[derive(Debug, Clone, PartialEq)]
pub enum ArgValue {
    U64(u64),
    F64(f64),
    Bool(bool),
    Str(String),
}

impl From<u64> for ArgValue {
    fn from(v: u64) -> ArgValue {
        ArgValue::U64(v)
    }
}
impl From<usize> for ArgValue {
    fn from(v: usize) -> ArgValue {
        ArgValue::U64(v as u64)
    }
}
impl From<f64> for ArgValue {
    fn from(v: f64) -> ArgValue {
        ArgValue::F64(v)
    }
}
impl From<bool> for ArgValue {
    fn from(v: bool) -> ArgValue {
        ArgValue::Bool(v)
    }
}
impl From<String> for ArgValue {
    fn from(v: String) -> ArgValue {
        ArgValue::Str(v)
    }
}
impl From<&str> for ArgValue {
    fn from(v: &str) -> ArgValue {
        ArgValue::Str(v.to_string())
    }
}

/// What kind of trace event a record is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A complete span: `start_us` + `dur_us` (Chrome phase `X`).
    Span,
    /// A point event: `dur_us` == 0 (Chrome phase `i`).
    Instant,
}

/// One finished trace event.
#[derive(Debug, Clone)]
pub struct SpanRecord {
    /// Process-unique span id (monotone in allocation order).
    pub id: u64,
    /// Enclosing span on the same thread (same tracer), if any.
    pub parent: Option<u64>,
    /// Track (≈ thread or logical lane) the event belongs to.
    pub track: u32,
    /// Category (subsystem): "compile", "serve", "deploy", …
    pub cat: &'static str,
    pub name: Cow<'static, str>,
    pub kind: EventKind,
    /// Microseconds since the tracer clock's origin.
    pub start_us: u64,
    pub dur_us: u64,
    pub args: Vec<(&'static str, ArgValue)>,
}

impl SpanRecord {
    pub fn end_us(&self) -> u64 {
        self.start_us + self.dur_us
    }

    /// Argument lookup by key.
    pub fn arg(&self, key: &str) -> Option<&ArgValue> {
        self.args.iter().find(|(k, _)| *k == key).map(|(_, v)| v)
    }
}

/// Everything one [`Tracer::drain`] returns: the finished records (all
/// shards, unordered across shards), the number of records the bounded
/// rings overwrote, and the track-name registry for export.
#[derive(Debug, Clone, Default)]
pub struct TraceBatch {
    pub records: Vec<SpanRecord>,
    pub dropped: u64,
    /// `(track id, label)` pairs for every named track.
    pub track_names: Vec<(u32, String)>,
}

struct Shard {
    ring: VecDeque<SpanRecord>,
}

/// Ring-buffer health without draining: whether tracing is on, how many
/// records the bounded rings overwrote, and each shard's current
/// occupancy against its capacity. Exported as Prometheus gauges so a
/// scrape-only consumer can see trace loss.
#[derive(Debug, Clone)]
pub struct TracerStats {
    pub enabled: bool,
    pub dropped: u64,
    pub shard_occupancy: Vec<usize>,
    pub shard_capacity: usize,
}

impl TracerStats {
    /// Records currently buffered across all shards.
    pub fn total_occupancy(&self) -> usize {
        self.shard_occupancy.iter().sum()
    }
}

/// The tracer. One process-global instance backs all built-in
/// instrumentation ([`tracer()`]); tests construct private instances with
/// manual clocks.
pub struct Tracer {
    /// Distinguishes tracers in the thread-local span stack / track cache
    /// (a thread may interleave spans of the global and a test tracer).
    identity: u64,
    enabled: AtomicBool,
    clock: Box<dyn Clock>,
    shards: Vec<Mutex<Shard>>,
    shard_capacity: usize,
    next_span: AtomicU64,
    next_track: AtomicU32,
    dropped: AtomicU64,
    track_names: Mutex<Vec<(u32, String)>>,
}

thread_local! {
    /// Open spans on this thread: (tracer identity, span id).
    static SPAN_STACK: RefCell<Vec<(u64, u64)>> = const { RefCell::new(Vec::new()) };
    /// This thread's track per tracer: (tracer identity, track id).
    static TRACK: RefCell<Vec<(u64, u32)>> = const { RefCell::new(Vec::new()) };
}

static TRACER_IDS: AtomicU64 = AtomicU64::new(1);
static GLOBAL: OnceLock<Tracer> = OnceLock::new();

/// The process-global tracer every built-in instrumentation point records
/// into. Disabled until something ([`Tracer::enable`], the `serve
/// --trace-out` / `compile --profile` CLI paths, a test) turns it on.
pub fn tracer() -> &'static Tracer {
    GLOBAL.get_or_init(Tracer::new)
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::new()
    }
}

impl Tracer {
    /// A disabled tracer on the production monotonic clock.
    pub fn new() -> Tracer {
        Tracer::with_clock(Box::new(MonotonicClock::new()))
    }

    /// A disabled tracer on an injected clock (tests pass a
    /// [`ManualClock`](super::clock::ManualClock)).
    pub fn with_clock(clock: Box<dyn Clock>) -> Tracer {
        Tracer::with_clock_and_capacity(clock, DEFAULT_SHARD_CAPACITY)
    }

    /// Full control, for tests that exercise the bounded-ring drop path.
    pub fn with_clock_and_capacity(clock: Box<dyn Clock>, shard_capacity: usize) -> Tracer {
        Tracer {
            identity: TRACER_IDS.fetch_add(1, Ordering::Relaxed),
            enabled: AtomicBool::new(false),
            clock,
            shards: (0..SHARDS)
                .map(|_| Mutex::new(Shard { ring: VecDeque::new() }))
                .collect(),
            shard_capacity: shard_capacity.max(1),
            next_span: AtomicU64::new(1),
            next_track: AtomicU32::new(1),
            dropped: AtomicU64::new(0),
            track_names: Mutex::new(Vec::new()),
        }
    }

    pub fn enable(&self) {
        self.enabled.store(true, Ordering::SeqCst);
    }

    pub fn disable(&self) {
        self.enabled.store(false, Ordering::SeqCst);
    }

    /// The one check every instrumentation point starts with. Callers may
    /// also use it to gate argument computation that is itself expensive.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Clock read, in the tracer's timeline. Only meaningful for
    /// [`Tracer::record_span`] bookkeeping; returns 0 when disabled so
    /// hot paths never pay the clock while tracing is off.
    #[inline]
    pub fn now_us(&self) -> u64 {
        if self.is_enabled() {
            self.clock.now_us()
        } else {
            0
        }
    }

    /// Open a span. Returns an inert guard (no clock read, no allocation)
    /// when disabled. The span records when the guard drops; spans opened
    /// while it is live on the same thread become its children.
    #[inline]
    pub fn span(&self, cat: &'static str, name: impl Into<Cow<'static, str>>) -> Span<'_> {
        if !self.is_enabled() {
            return Span { tracer: None, rec: None };
        }
        self.live_event(cat, name.into(), EventKind::Span)
    }

    /// Record a point event (Chrome `i` phase) when its guard drops —
    /// argument attachment works exactly like spans.
    #[inline]
    pub fn instant(&self, cat: &'static str, name: impl Into<Cow<'static, str>>) -> Span<'_> {
        if !self.is_enabled() {
            return Span { tracer: None, rec: None };
        }
        self.live_event(cat, name.into(), EventKind::Instant)
    }

    fn live_event(&self, cat: &'static str, name: Cow<'static, str>, kind: EventKind) -> Span<'_> {
        let id = self.next_span.fetch_add(1, Ordering::Relaxed);
        let track = self.current_track();
        let parent = SPAN_STACK.with(|s| {
            let mut s = s.borrow_mut();
            let parent = s.iter().rev().find(|(t, _)| *t == self.identity).map(|(_, id)| *id);
            if kind == EventKind::Span {
                s.push((self.identity, id));
            }
            parent
        });
        Span {
            tracer: Some(self),
            rec: Some(SpanRecord {
                id,
                parent,
                track,
                cat,
                name,
                kind,
                start_us: self.clock.now_us(),
                dur_us: 0,
                args: Vec::new(),
            }),
        }
    }

    /// Record a complete span with explicit endpoints — for phases whose
    /// start and end are observed on different threads (a request's queue
    /// wait). No parent link, lands on `track` (use
    /// [`Tracer::logical_track`] or [`Tracer::current_track`]).
    pub fn record_span(
        &self,
        cat: &'static str,
        name: impl Into<Cow<'static, str>>,
        track: u32,
        start_us: u64,
        end_us: u64,
        args: Vec<(&'static str, ArgValue)>,
    ) {
        if !self.is_enabled() {
            return;
        }
        self.push(SpanRecord {
            id: self.next_span.fetch_add(1, Ordering::Relaxed),
            parent: None,
            track,
            cat,
            name: name.into(),
            kind: EventKind::Span,
            start_us,
            dur_us: end_us.saturating_sub(start_us),
            args,
        });
    }

    /// The calling thread's track id under this tracer, assigned on first
    /// use.
    pub fn current_track(&self) -> u32 {
        TRACK.with(|t| {
            let mut t = t.borrow_mut();
            if let Some((_, id)) = t.iter().find(|(tid, _)| *tid == self.identity) {
                return *id;
            }
            let id = self.next_track.fetch_add(1, Ordering::Relaxed);
            t.push((self.identity, id));
            id
        })
    }

    /// Name the calling thread's track ("worker-0", "autoscaler", …);
    /// exported as Chrome thread-name metadata.
    pub fn set_track_name(&self, label: impl Into<String>) {
        let track = self.current_track();
        self.name_track(track, label);
    }

    /// Allocate a fresh logical track (not bound to any thread) — e.g.
    /// one "queue" lane per server for cross-thread queue-wait spans.
    pub fn logical_track(&self, label: impl Into<String>) -> u32 {
        let id = self.next_track.fetch_add(1, Ordering::Relaxed);
        self.name_track(id, label);
        id
    }

    fn name_track(&self, track: u32, label: impl Into<String>) {
        let label = label.into();
        let mut names = self.track_names.lock().unwrap();
        match names.iter_mut().find(|(t, _)| *t == track) {
            Some((_, l)) => *l = label,
            None => names.push((track, label)),
        }
    }

    fn push(&self, rec: SpanRecord) {
        let shard = &self.shards[rec.track as usize % self.shards.len()];
        let mut s = shard.lock().unwrap();
        if s.ring.len() >= self.shard_capacity {
            s.ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        s.ring.push_back(rec);
    }

    /// Take every finished record (the rings empty; drop counts and track
    /// names are reported but not reset). Records are sorted by start
    /// time, ties by id.
    pub fn drain(&self) -> TraceBatch {
        let mut records = Vec::new();
        for shard in &self.shards {
            records.extend(shard.lock().unwrap().ring.drain(..));
        }
        records.sort_by_key(|r| (r.start_us, r.id));
        TraceBatch {
            records,
            dropped: self.dropped.load(Ordering::Relaxed),
            track_names: self.track_names.lock().unwrap().clone(),
        }
    }

    /// Non-draining ring health snapshot for scrape-only consumers
    /// ([`crate::obs::prom::tracer_gauges`]): before this, drop counts
    /// only surfaced in the Chrome export's root field.
    pub fn stats(&self) -> TracerStats {
        TracerStats {
            enabled: self.is_enabled(),
            dropped: self.dropped.load(Ordering::Relaxed),
            shard_occupancy: self
                .shards
                .iter()
                .map(|s| s.lock().unwrap().ring.len())
                .collect(),
            shard_capacity: self.shard_capacity,
        }
    }
}

/// A live (or inert) span guard. Records on drop. `with_arg` attaches
/// structured arguments; on an inert guard it is free.
pub struct Span<'a> {
    tracer: Option<&'a Tracer>,
    rec: Option<SpanRecord>,
}

impl Span<'_> {
    /// Whether this guard will record (tracing was enabled at open).
    #[inline]
    pub fn is_live(&self) -> bool {
        self.rec.is_some()
    }

    /// Attach an argument (builder style).
    #[inline]
    pub fn with_arg(mut self, key: &'static str, value: impl Into<ArgValue>) -> Self {
        if let Some(rec) = self.rec.as_mut() {
            rec.args.push((key, value.into()));
        }
        self
    }

    /// Attach an argument through a live borrow (for args only known
    /// mid-span).
    #[inline]
    pub fn arg(&mut self, key: &'static str, value: impl Into<ArgValue>) {
        if let Some(rec) = self.rec.as_mut() {
            rec.args.push((key, value.into()));
        }
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        let (Some(tracer), Some(mut rec)) = (self.tracer, self.rec.take()) else {
            return;
        };
        if rec.kind == EventKind::Span {
            rec.dur_us = tracer.clock.now_us().saturating_sub(rec.start_us);
            SPAN_STACK.with(|s| {
                let mut s = s.borrow_mut();
                // Guards drop LIFO per thread, so our entry is the topmost
                // for this tracer; search from the end for robustness.
                if let Some(pos) =
                    s.iter().rposition(|(t, id)| *t == tracer.identity && *id == rec.id)
                {
                    s.remove(pos);
                }
            });
        }
        tracer.push(rec);
    }
}

#[cfg(test)]
mod tests {
    use super::super::clock::ManualClock;
    use super::*;
    use std::sync::Arc;

    fn manual_tracer() -> (Tracer, Arc<ManualClock>) {
        let clock = Arc::new(ManualClock::new());
        struct Shared(Arc<ManualClock>);
        impl Clock for Shared {
            fn now_us(&self) -> u64 {
                self.0.now_us()
            }
        }
        let t = Tracer::with_clock(Box::new(Shared(clock.clone())));
        (t, clock)
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let (t, _) = manual_tracer();
        {
            let _s = t.span("test", "outer").with_arg("k", 1u64);
            let _i = t.instant("test", "point");
        }
        t.record_span("test", "manual", 7, 0, 10, vec![]);
        assert!(t.drain().records.is_empty());
    }

    #[test]
    fn spans_nest_and_time_deterministically() {
        let (t, clock) = manual_tracer();
        t.enable();
        {
            let _outer = t.span("test", "outer");
            clock.advance(10);
            {
                let _inner = t.span("test", "inner").with_arg("depth", 2u64);
                clock.advance(5);
            }
            clock.advance(1);
        }
        let batch = t.drain();
        assert_eq!(batch.records.len(), 2);
        let outer = batch.records.iter().find(|r| r.name == "outer").unwrap();
        let inner = batch.records.iter().find(|r| r.name == "inner").unwrap();
        assert_eq!((outer.start_us, outer.dur_us), (0, 16));
        assert_eq!((inner.start_us, inner.dur_us), (10, 5));
        assert_eq!(inner.parent, Some(outer.id));
        assert_eq!(outer.parent, None);
        assert_eq!(inner.arg("depth"), Some(&ArgValue::U64(2)));
        // Contained: parent interval covers the child.
        assert!(outer.start_us <= inner.start_us && inner.end_us() <= outer.end_us());
    }

    #[test]
    fn instants_record_zero_duration_and_keep_parents() {
        let (t, clock) = manual_tracer();
        t.enable();
        {
            let _outer = t.span("test", "outer");
            clock.advance(3);
            t.instant("test", "decision").with_arg("to", 4u64);
        }
        let batch = t.drain();
        let i = batch.records.iter().find(|r| r.kind == EventKind::Instant).unwrap();
        assert_eq!((i.start_us, i.dur_us), (3, 0));
        assert!(i.parent.is_some());
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let (t, _) = manual_tracer();
        t.enable();
        let t = Tracer::with_clock_and_capacity(Box::new(ManualClock::new()), 4);
        t.enable();
        for i in 0..10u64 {
            t.record_span("test", "s", 0, i, i + 1, vec![]);
        }
        let batch = t.drain();
        // Track 0 hashes to one shard with capacity 4: the 6 oldest fell out.
        assert_eq!(batch.records.len(), 4);
        assert_eq!(batch.dropped, 6);
        // The *newest* records survived.
        assert_eq!(batch.records.last().unwrap().start_us, 9);
    }

    #[test]
    fn stats_report_occupancy_without_draining() {
        let t = Tracer::with_clock_and_capacity(Box::new(ManualClock::new()), 4);
        t.enable();
        for i in 0..6u64 {
            t.record_span("test", "s", 0, i, i + 1, vec![]);
        }
        let stats = t.stats();
        assert!(stats.enabled);
        assert_eq!(stats.shard_capacity, 4);
        assert_eq!(stats.total_occupancy(), 4);
        assert_eq!(stats.dropped, 2);
        // Stats did not drain: the records are still there.
        assert_eq!(t.drain().records.len(), 4);
        assert_eq!(t.stats().total_occupancy(), 0);
    }

    #[test]
    fn two_tracers_on_one_thread_do_not_cross_parents() {
        let (a, _) = manual_tracer();
        let (b, _) = manual_tracer();
        a.enable();
        b.enable();
        {
            let _pa = a.span("test", "a_parent");
            let _sb = b.span("test", "b_root");
        }
        let bb = b.drain();
        assert_eq!(bb.records.len(), 1);
        // b's span must not adopt a's open span as parent.
        assert_eq!(bb.records[0].parent, None);
        assert_eq!(a.drain().records.len(), 1);
    }

    #[test]
    fn tracks_are_per_thread_and_nameable() {
        let (t, _) = manual_tracer();
        t.enable();
        t.set_track_name("main");
        let main_track = t.current_track();
        let t_ref = &t;
        let worker_track = std::thread::scope(|s| {
            s.spawn(|| {
                t_ref.set_track_name("worker");
                let _s = t_ref.span("test", "work");
                t_ref.current_track()
            })
            .join()
            .unwrap()
        });
        assert_ne!(main_track, worker_track);
        let batch = t.drain();
        assert_eq!(batch.records[0].track, worker_track);
        let names: std::collections::HashMap<u32, String> =
            batch.track_names.into_iter().collect();
        assert_eq!(names[&main_track], "main");
        assert_eq!(names[&worker_track], "worker");
    }
}
