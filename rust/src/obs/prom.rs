//! Prometheus text-exposition export of a [`ServingSnapshot`].
//!
//! One call renders everything an operator scrapes: the admission funnel
//! as conservation counters (`submitted == admitted + shed_* +
//! rejected_*`, per reason), the request-latency histogram straight from
//! the mergeable [`LatencyHistogram`] buckets (cumulative `_bucket{le=}`
//! semantics, exact `_sum`/`_count`), queue/replica gauges, per-stage
//! pipeline health, and firmware-cache counters when a cache is attached.
//!
//! Counters are cumulative, so two scrapes difference into a window
//! exactly like [`AdmissionReport::delta`] — pinned by the conservation
//! property test in `tests/obs_trace.rs`.
//!
//! [`AdmissionReport::delta`]: crate::coordinator::AdmissionReport::delta

use crate::coordinator::ServingSnapshot;
use std::collections::BTreeMap;
use std::fmt::Write as _;

fn counter(out: &mut String, name: &str, help: &str, series: &[(&str, f64)]) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    for (labels, v) in series {
        let _ = writeln!(out, "{name}{labels} {v}");
    }
}

fn gauge(out: &mut String, name: &str, help: &str, series: &[(&str, f64)]) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    for (labels, v) in series {
        let _ = writeln!(out, "{name}{labels} {v}");
    }
}

/// Render one snapshot as Prometheus text exposition (version 0.0.4).
pub fn to_prometheus(snap: &ServingSnapshot) -> String {
    let mut out = String::new();
    let a = &snap.admission;
    counter(
        &mut out,
        "aie4ml_requests_submitted_total",
        "Requests offered to admission control.",
        &[("", a.submitted as f64)],
    );
    counter(
        &mut out,
        "aie4ml_requests_admitted_total",
        "Requests admitted into the serving queue.",
        &[("", a.admitted as f64)],
    );
    counter(
        &mut out,
        "aie4ml_requests_shed_total",
        "Well-formed requests shed at admission, by reason.",
        &[
            ("{reason=\"queue_full\"}", a.shed_queue_full as f64),
            ("{reason=\"deadline_risk\"}", a.shed_deadline as f64),
        ],
    );
    counter(
        &mut out,
        "aie4ml_requests_rejected_total",
        "Requests rejected for non-load reasons, by reason.",
        &[
            ("{reason=\"malformed\"}", a.rejected_malformed as f64),
            ("{reason=\"stopped\"}", a.rejected_stopped as f64),
        ],
    );

    let m = &snap.metrics;
    counter(
        &mut out,
        "aie4ml_requests_served_total",
        "Requests whose batch completed.",
        &[("", m.requests as f64)],
    );
    counter(
        &mut out,
        "aie4ml_batches_executed_total",
        "Firmware batches executed.",
        &[("", m.batches as f64)],
    );
    counter(
        &mut out,
        "aie4ml_device_busy_microseconds_total",
        "Modeled device-busy time across executed batches.",
        &[("", m.device_busy_us)],
    );

    gauge(
        &mut out,
        "aie4ml_batch_occupancy_mean",
        "Mean real rows per executed batch.",
        &[("", m.mean_batch_occupancy)],
    );
    gauge(
        &mut out,
        "aie4ml_queue_depth",
        "Requests admitted but not yet claimed by a worker.",
        &[("", snap.queued as f64)],
    );
    gauge(
        &mut out,
        "aie4ml_queue_capacity",
        "Admission queue bound.",
        &[("", snap.queue_capacity as f64)],
    );
    gauge(
        &mut out,
        "aie4ml_replicas",
        "Effective worker count.",
        &[("", snap.replicas as f64)],
    );
    gauge(
        &mut out,
        "aie4ml_batch_size",
        "Firmware batch each worker executes.",
        &[("", snap.batch as f64)],
    );
    gauge(
        &mut out,
        "aie4ml_batch_service_time_microseconds",
        "EWMA wall-clock batch service time.",
        &[("", snap.batch_us)],
    );

    // Request latency histogram — cumulative buckets straight from the
    // log-bucketed histogram, plus exact sum/count.
    let name = "aie4ml_request_latency_microseconds";
    let _ = writeln!(out, "# HELP {name} End-to-end request latency (submit to reply).");
    let _ = writeln!(out, "# TYPE {name} histogram");
    for (le, cum) in m.latency.cumulative_buckets() {
        if le.is_finite() {
            let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {cum}");
        }
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", m.latency.count());
    let _ = writeln!(out, "{name}_sum {}", m.latency.sum_us());
    let _ = writeln!(out, "{name}_count {}", m.latency.count());

    if !m.stages.is_empty() {
        let labels: Vec<String> = m
            .stages
            .iter()
            .map(|s| format!("{{partition=\"{}\"}}", s.partition))
            .collect();
        let busy: Vec<(&str, f64)> = labels
            .iter()
            .zip(&m.stages)
            .map(|(l, s)| (l.as_str(), s.busy_fraction))
            .collect();
        let depth: Vec<(&str, f64)> = labels
            .iter()
            .zip(&m.stages)
            .map(|(l, s)| (l.as_str(), s.mean_queue_depth))
            .collect();
        let depth_max: Vec<(&str, f64)> = labels
            .iter()
            .zip(&m.stages)
            .map(|(l, s)| (l.as_str(), s.max_queue_depth as f64))
            .collect();
        let batches: Vec<(&str, f64)> = labels
            .iter()
            .zip(&m.stages)
            .map(|(l, s)| (l.as_str(), s.batches as f64))
            .collect();
        gauge(
            &mut out,
            "aie4ml_stage_busy_fraction",
            "Fraction of wall time each pipeline stage spends executing.",
            &busy,
        );
        gauge(
            &mut out,
            "aie4ml_stage_queue_depth_mean",
            "Mean input-queue depth per pipeline stage at dequeue time.",
            &depth,
        );
        gauge(
            &mut out,
            "aie4ml_stage_queue_depth_max",
            "Peak input-queue depth per pipeline stage.",
            &depth_max,
        );
        counter(
            &mut out,
            "aie4ml_stage_batches_total",
            "Batches each pipeline stage processed.",
            &batches,
        );
    }

    if let Some(d) = &snap.drift {
        let labels: Vec<String> =
            d.stages.iter().map(|s| format!("{{partition=\"{}\"}}", s.stage)).collect();
        let ratios: Vec<(&str, f64)> =
            labels.iter().zip(&d.stages).map(|(l, s)| (l.as_str(), s.ratio)).collect();
        gauge(
            &mut out,
            "aie4ml_stage_drift_ratio",
            "Windowed measured/predicted latency ratio per stage (1 = calibrated model).",
            &ratios,
        );
        gauge(
            &mut out,
            "aie4ml_model_drift_ratio",
            "Overall measured/predicted latency ratio across stages with samples.",
            &[("", d.overall_ratio)],
        );
        gauge(
            &mut out,
            "aie4ml_model_drift_correction",
            "Clamped drift correction applied to model-derived capacity estimates.",
            &[("", d.correction)],
        );
    }

    if let Some(c) = &snap.cache {
        counter(
            &mut out,
            "aie4ml_fw_cache_requests_total",
            "Firmware-cache compile requests, by outcome.",
            &[
                ("{outcome=\"hit\"}", c.hits as f64),
                ("{outcome=\"miss\"}", c.misses as f64),
            ],
        );
        gauge(
            &mut out,
            "aie4ml_fw_cache_entries",
            "Cached compile outcomes resident.",
            &[("", c.entries as f64)],
        );
        gauge(
            &mut out,
            "aie4ml_fw_cache_negative_entries",
            "Cached compile failures resident.",
            &[("", c.negative_entries as f64)],
        );
    }
    out
}

/// Render tracer ring-buffer health as Prometheus gauges — appended to a
/// snapshot exposition by the CLI's `--metrics-out` path so a
/// scrape-only consumer sees trace loss (ring overwrites) and shard
/// pressure without draining the rings.
pub fn tracer_gauges(stats: &crate::obs::tracer::TracerStats) -> String {
    let mut out = String::new();
    gauge(
        &mut out,
        "aie4ml_tracer_enabled",
        "Whether span tracing is currently enabled (1/0).",
        &[("", if stats.enabled { 1.0 } else { 0.0 })],
    );
    counter(
        &mut out,
        "aie4ml_tracer_dropped_records_total",
        "Span records overwritten by the bounded rings before drain.",
        &[("", stats.dropped as f64)],
    );
    gauge(
        &mut out,
        "aie4ml_tracer_shard_capacity",
        "Per-shard ring capacity in records.",
        &[("", stats.shard_capacity as f64)],
    );
    let labels: Vec<String> = (0..stats.shard_occupancy.len())
        .map(|i| format!("{{shard=\"{i}\"}}"))
        .collect();
    let occupancy: Vec<(&str, f64)> = labels
        .iter()
        .zip(&stats.shard_occupancy)
        .map(|(l, &n)| (l.as_str(), n as f64))
        .collect();
    gauge(
        &mut out,
        "aie4ml_tracer_shard_occupancy",
        "Records currently buffered per ring shard.",
        &occupancy,
    );
    out
}

/// Render a tile-utilization report as Prometheus gauges — the
/// `compile --profile --metrics-out` path, so per-tile efficiency lands
/// on the same scrape surface as the serving metrics.
pub fn tile_gauges(rep: &crate::obs::attrib::TileUtilReport) -> String {
    let mut out = String::new();
    gauge(
        &mut out,
        "aie4ml_array_utilization",
        "Placed tiles over placeable tiles.",
        &[("", rep.array_utilization)],
    );
    gauge(
        &mut out,
        "aie4ml_scaling_efficiency",
        "Achieved throughput over the tiles x single-kernel baseline (Fig. 4 metric).",
        &[("", rep.scaling_efficiency)],
    );
    gauge(
        &mut out,
        "aie4ml_tiles_used",
        "Compute tiles the firmware occupies.",
        &[("", rep.tiles_used as f64)],
    );
    gauge(
        &mut out,
        "aie4ml_interconnect_hops",
        "Total routed stream-switch hops.",
        &[("", rep.total_hops as f64)],
    );
    let labels: Vec<String> = rep
        .stages
        .iter()
        .map(|s| format!("{{stage=\"{}\"}}", s.name))
        .collect();
    let busy: Vec<(&str, f64)> = labels
        .iter()
        .zip(&rep.stages)
        .map(|(l, s)| (l.as_str(), s.busy_fraction))
        .collect();
    let peak: Vec<(&str, f64)> = labels
        .iter()
        .zip(&rep.stages)
        .map(|(l, s)| (l.as_str(), s.peak_fraction))
        .collect();
    let dma: Vec<(String, f64)> = rep
        .stages
        .iter()
        .flat_map(|s| {
            [
                (format!("{{stage=\"{}\",dir=\"in\"}}", s.name), s.dma_in_bytes),
                (format!("{{stage=\"{}\",dir=\"out\"}}", s.name), s.dma_out_bytes),
            ]
        })
        .collect();
    let dma_refs: Vec<(&str, f64)> = dma.iter().map(|(l, v)| (l.as_str(), *v)).collect();
    gauge(
        &mut out,
        "aie4ml_tile_busy_fraction",
        "Per-stage tail-tile busy fraction of the steady-state interval.",
        &busy,
    );
    gauge(
        &mut out,
        "aie4ml_tile_peak_fraction",
        "Per-stage useful MACs over architectural peak within one interval.",
        &peak,
    );
    gauge(
        &mut out,
        "aie4ml_stage_dma_bytes",
        "Per-stage DMA bytes per batch, by direction.",
        &dma_refs,
    );
    out
}

/// Parse a text exposition back into `full-series-name -> value` (keys
/// keep their label set, e.g. `aie4ml_requests_shed_total{reason="queue_full"}`).
/// Used by the validation tests and the CLI's own post-write check.
pub fn parse_prometheus(text: &str) -> Result<BTreeMap<String, f64>, String> {
    let mut out = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (series, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {}: no value separator: {line:?}", i + 1))?;
        let v: f64 = value
            .parse()
            .map_err(|_| format!("line {}: bad value {value:?}", i + 1))?;
        if out.insert(series.to_string(), v).is_some() {
            return Err(format!("line {}: duplicate series {series:?}", i + 1));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MetricsReport;

    fn snapshot() -> ServingSnapshot {
        ServingSnapshot {
            metrics: MetricsReport::empty(),
            admission: Default::default(),
            queued: 3,
            queue_capacity: 64,
            replicas: 2,
            batch: 8,
            batch_us: 123.5,
            cache: Some(crate::cache::CacheStats {
                hits: 10,
                misses: 2,
                entries: 2,
                negative_entries: 1,
            }),
            drift: None,
        }
    }

    #[test]
    fn exposition_round_trips_through_the_parser() {
        let text = to_prometheus(&snapshot());
        let parsed = parse_prometheus(&text).unwrap();
        assert_eq!(parsed["aie4ml_queue_depth"], 3.0);
        assert_eq!(parsed["aie4ml_replicas"], 2.0);
        assert_eq!(parsed["aie4ml_batch_service_time_microseconds"], 123.5);
        assert_eq!(parsed["aie4ml_fw_cache_requests_total{outcome=\"hit\"}"], 10.0);
        assert_eq!(parsed["aie4ml_fw_cache_negative_entries"], 1.0);
        // Empty histogram still exposes the +Inf bucket and exact counts.
        assert_eq!(parsed["aie4ml_request_latency_microseconds_bucket{le=\"+Inf\"}"], 0.0);
        assert_eq!(parsed["aie4ml_request_latency_microseconds_count"], 0.0);
    }

    #[test]
    fn conservation_holds_in_the_exposition() {
        let mut snap = snapshot();
        snap.admission.submitted = 10;
        snap.admission.admitted = 6;
        snap.admission.shed_queue_full = 2;
        snap.admission.shed_deadline = 1;
        snap.admission.rejected_malformed = 1;
        let parsed = parse_prometheus(&to_prometheus(&snap)).unwrap();
        let sum = parsed["aie4ml_requests_admitted_total"]
            + parsed["aie4ml_requests_shed_total{reason=\"queue_full\"}"]
            + parsed["aie4ml_requests_shed_total{reason=\"deadline_risk\"}"]
            + parsed["aie4ml_requests_rejected_total{reason=\"malformed\"}"]
            + parsed["aie4ml_requests_rejected_total{reason=\"stopped\"}"];
        assert_eq!(parsed["aie4ml_requests_submitted_total"], sum);
    }

    #[test]
    fn drift_gauges_render_when_present() {
        use crate::obs::attrib::DriftDetector;
        let mut snap = snapshot();
        let mut d = DriftDetector::new(&[100.0]);
        d.observe(0, 250.0);
        snap.drift = Some(d.report());
        let parsed = parse_prometheus(&to_prometheus(&snap)).unwrap();
        assert_eq!(parsed["aie4ml_stage_drift_ratio{partition=\"0\"}"], 2.5);
        assert_eq!(parsed["aie4ml_model_drift_ratio"], 2.5);
        assert_eq!(parsed["aie4ml_model_drift_correction"], 2.5);
        // Absent drift renders no drift series (no empty families).
        let bare = to_prometheus(&snapshot());
        assert!(!bare.contains("aie4ml_model_drift_ratio"));
    }

    #[test]
    fn tile_gauges_render_and_parse() {
        use crate::obs::attrib::{StageUtil, TileUtilReport};
        let rep = TileUtilReport {
            model_name: "m".into(),
            device_name: "vek280".into(),
            batch: 8,
            rows: 2,
            cols: 2,
            interval_cycles: 100.0,
            throughput_tops: 1.0,
            tiles_used: 3,
            tiles_total: 4,
            stages: vec![StageUtil {
                name: "fc1".into(),
                tiles: 3,
                head_busy_cycles: 80.0,
                tail_busy_cycles: 90.0,
                busy_fraction: 0.9,
                peak_fraction: 0.5,
                scaling_efficiency: 0.9,
                dma_in_bytes: 1024.0,
                dma_out_bytes: 256.0,
            }],
            scaling_efficiency: 0.9,
            array_utilization: 0.75,
            grid: vec![vec![0.9, 0.9], vec![0.9, 0.0]],
            total_hops: 12,
        };
        let parsed = parse_prometheus(&tile_gauges(&rep)).unwrap();
        assert_eq!(parsed["aie4ml_array_utilization"], 0.75);
        assert_eq!(parsed["aie4ml_scaling_efficiency"], 0.9);
        assert_eq!(parsed["aie4ml_tile_busy_fraction{stage=\"fc1\"}"], 0.9);
        assert_eq!(parsed["aie4ml_stage_dma_bytes{stage=\"fc1\",dir=\"in\"}"], 1024.0);
        assert_eq!(parsed["aie4ml_interconnect_hops"], 12.0);
    }

    #[test]
    fn tracer_gauges_render_and_parse() {
        let stats = crate::obs::tracer::TracerStats {
            enabled: true,
            dropped: 7,
            shard_occupancy: vec![3, 0, 5],
            shard_capacity: 16,
        };
        let parsed = parse_prometheus(&tracer_gauges(&stats)).unwrap();
        assert_eq!(parsed["aie4ml_tracer_enabled"], 1.0);
        assert_eq!(parsed["aie4ml_tracer_dropped_records_total"], 7.0);
        assert_eq!(parsed["aie4ml_tracer_shard_capacity"], 16.0);
        assert_eq!(parsed["aie4ml_tracer_shard_occupancy{shard=\"0\"}"], 3.0);
        assert_eq!(parsed["aie4ml_tracer_shard_occupancy{shard=\"2\"}"], 5.0);
    }
}
