//! Prometheus text-exposition export of a [`ServingSnapshot`].
//!
//! One call renders everything an operator scrapes: the admission funnel
//! as conservation counters (`submitted == admitted + shed_* +
//! rejected_*`, per reason), the request-latency histogram straight from
//! the mergeable [`LatencyHistogram`] buckets (cumulative `_bucket{le=}`
//! semantics, exact `_sum`/`_count`), queue/replica gauges, per-stage
//! pipeline health, and firmware-cache counters when a cache is attached.
//!
//! Counters are cumulative, so two scrapes difference into a window
//! exactly like [`AdmissionReport::delta`] — pinned by the conservation
//! property test in `tests/obs_trace.rs`.
//!
//! [`AdmissionReport::delta`]: crate::coordinator::AdmissionReport::delta

use crate::coordinator::ServingSnapshot;
use std::collections::BTreeMap;
use std::fmt::Write as _;

fn counter(out: &mut String, name: &str, help: &str, series: &[(&str, f64)]) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    for (labels, v) in series {
        let _ = writeln!(out, "{name}{labels} {v}");
    }
}

fn gauge(out: &mut String, name: &str, help: &str, series: &[(&str, f64)]) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    for (labels, v) in series {
        let _ = writeln!(out, "{name}{labels} {v}");
    }
}

/// Render one snapshot as Prometheus text exposition (version 0.0.4).
pub fn to_prometheus(snap: &ServingSnapshot) -> String {
    let mut out = String::new();
    let a = &snap.admission;
    counter(
        &mut out,
        "aie4ml_requests_submitted_total",
        "Requests offered to admission control.",
        &[("", a.submitted as f64)],
    );
    counter(
        &mut out,
        "aie4ml_requests_admitted_total",
        "Requests admitted into the serving queue.",
        &[("", a.admitted as f64)],
    );
    counter(
        &mut out,
        "aie4ml_requests_shed_total",
        "Well-formed requests shed at admission, by reason.",
        &[
            ("{reason=\"queue_full\"}", a.shed_queue_full as f64),
            ("{reason=\"deadline_risk\"}", a.shed_deadline as f64),
        ],
    );
    counter(
        &mut out,
        "aie4ml_requests_rejected_total",
        "Requests rejected for non-load reasons, by reason.",
        &[
            ("{reason=\"malformed\"}", a.rejected_malformed as f64),
            ("{reason=\"stopped\"}", a.rejected_stopped as f64),
        ],
    );

    let m = &snap.metrics;
    counter(
        &mut out,
        "aie4ml_requests_served_total",
        "Requests whose batch completed.",
        &[("", m.requests as f64)],
    );
    counter(
        &mut out,
        "aie4ml_batches_executed_total",
        "Firmware batches executed.",
        &[("", m.batches as f64)],
    );
    counter(
        &mut out,
        "aie4ml_device_busy_microseconds_total",
        "Modeled device-busy time across executed batches.",
        &[("", m.device_busy_us)],
    );

    gauge(
        &mut out,
        "aie4ml_batch_occupancy_mean",
        "Mean real rows per executed batch.",
        &[("", m.mean_batch_occupancy)],
    );
    gauge(
        &mut out,
        "aie4ml_queue_depth",
        "Requests admitted but not yet claimed by a worker.",
        &[("", snap.queued as f64)],
    );
    gauge(
        &mut out,
        "aie4ml_queue_capacity",
        "Admission queue bound.",
        &[("", snap.queue_capacity as f64)],
    );
    gauge(
        &mut out,
        "aie4ml_replicas",
        "Effective worker count.",
        &[("", snap.replicas as f64)],
    );
    gauge(
        &mut out,
        "aie4ml_batch_size",
        "Firmware batch each worker executes.",
        &[("", snap.batch as f64)],
    );
    gauge(
        &mut out,
        "aie4ml_batch_service_time_microseconds",
        "EWMA wall-clock batch service time.",
        &[("", snap.batch_us)],
    );

    // Request latency histogram — cumulative buckets straight from the
    // log-bucketed histogram, plus exact sum/count.
    let name = "aie4ml_request_latency_microseconds";
    let _ = writeln!(out, "# HELP {name} End-to-end request latency (submit to reply).");
    let _ = writeln!(out, "# TYPE {name} histogram");
    for (le, cum) in m.latency.cumulative_buckets() {
        if le.is_finite() {
            let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {cum}");
        }
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", m.latency.count());
    let _ = writeln!(out, "{name}_sum {}", m.latency.sum_us());
    let _ = writeln!(out, "{name}_count {}", m.latency.count());

    if !m.stages.is_empty() {
        let labels: Vec<String> = m
            .stages
            .iter()
            .map(|s| format!("{{partition=\"{}\"}}", s.partition))
            .collect();
        let busy: Vec<(&str, f64)> = labels
            .iter()
            .zip(&m.stages)
            .map(|(l, s)| (l.as_str(), s.busy_fraction))
            .collect();
        let depth: Vec<(&str, f64)> = labels
            .iter()
            .zip(&m.stages)
            .map(|(l, s)| (l.as_str(), s.mean_queue_depth))
            .collect();
        gauge(
            &mut out,
            "aie4ml_stage_busy_fraction",
            "Fraction of wall time each pipeline stage spends executing.",
            &busy,
        );
        gauge(
            &mut out,
            "aie4ml_stage_queue_depth_mean",
            "Mean input-queue depth per pipeline stage at dequeue time.",
            &depth,
        );
    }

    if let Some(c) = &snap.cache {
        counter(
            &mut out,
            "aie4ml_fw_cache_requests_total",
            "Firmware-cache compile requests, by outcome.",
            &[
                ("{outcome=\"hit\"}", c.hits as f64),
                ("{outcome=\"miss\"}", c.misses as f64),
            ],
        );
        gauge(
            &mut out,
            "aie4ml_fw_cache_entries",
            "Cached compile outcomes resident.",
            &[("", c.entries as f64)],
        );
        gauge(
            &mut out,
            "aie4ml_fw_cache_negative_entries",
            "Cached compile failures resident.",
            &[("", c.negative_entries as f64)],
        );
    }
    out
}

/// Parse a text exposition back into `full-series-name -> value` (keys
/// keep their label set, e.g. `aie4ml_requests_shed_total{reason="queue_full"}`).
/// Used by the validation tests and the CLI's own post-write check.
pub fn parse_prometheus(text: &str) -> Result<BTreeMap<String, f64>, String> {
    let mut out = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (series, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {}: no value separator: {line:?}", i + 1))?;
        let v: f64 = value
            .parse()
            .map_err(|_| format!("line {}: bad value {value:?}", i + 1))?;
        if out.insert(series.to_string(), v).is_some() {
            return Err(format!("line {}: duplicate series {series:?}", i + 1));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MetricsReport;

    fn snapshot() -> ServingSnapshot {
        ServingSnapshot {
            metrics: MetricsReport::empty(),
            admission: Default::default(),
            queued: 3,
            queue_capacity: 64,
            replicas: 2,
            batch: 8,
            batch_us: 123.5,
            cache: Some(crate::cache::CacheStats {
                hits: 10,
                misses: 2,
                entries: 2,
                negative_entries: 1,
            }),
        }
    }

    #[test]
    fn exposition_round_trips_through_the_parser() {
        let text = to_prometheus(&snapshot());
        let parsed = parse_prometheus(&text).unwrap();
        assert_eq!(parsed["aie4ml_queue_depth"], 3.0);
        assert_eq!(parsed["aie4ml_replicas"], 2.0);
        assert_eq!(parsed["aie4ml_batch_service_time_microseconds"], 123.5);
        assert_eq!(parsed["aie4ml_fw_cache_requests_total{outcome=\"hit\"}"], 10.0);
        assert_eq!(parsed["aie4ml_fw_cache_negative_entries"], 1.0);
        // Empty histogram still exposes the +Inf bucket and exact counts.
        assert_eq!(parsed["aie4ml_request_latency_microseconds_bucket{le=\"+Inf\"}"], 0.0);
        assert_eq!(parsed["aie4ml_request_latency_microseconds_count"], 0.0);
    }

    #[test]
    fn conservation_holds_in_the_exposition() {
        let mut snap = snapshot();
        snap.admission.submitted = 10;
        snap.admission.admitted = 6;
        snap.admission.shed_queue_full = 2;
        snap.admission.shed_deadline = 1;
        snap.admission.rejected_malformed = 1;
        let parsed = parse_prometheus(&to_prometheus(&snap)).unwrap();
        let sum = parsed["aie4ml_requests_admitted_total"]
            + parsed["aie4ml_requests_shed_total{reason=\"queue_full\"}"]
            + parsed["aie4ml_requests_shed_total{reason=\"deadline_risk\"}"]
            + parsed["aie4ml_requests_rejected_total{reason=\"malformed\"}"]
            + parsed["aie4ml_requests_rejected_total{reason=\"stopped\"}"];
        assert_eq!(parsed["aie4ml_requests_submitted_total"], sum);
    }
}
