//! The fully-resolved firmware description — the compiler's output.
//!
//! On real hardware this corresponds to the emitted Vitis project (kernel
//! C++, graph hpp, mem-tile buffer descriptors); here it is additionally the
//! exact configuration the cycle-approximate simulator executes. Everything
//! is concrete: per-tile packed weight streams, per-edge mem-tile tiler
//! programs, placement coordinates.

use crate::arch::{Device, Dtype, MmulTiling};
use crate::ir::{CascadeGeometry, DenseQuant, NodeId, PlacementRect, Pool2DAttrs, QuantSpec};
use crate::sim::dma::{ConvPatchTiler, OffsetTiler, Tiler2d};

/// One compute-tile kernel instance.
#[derive(Debug, Clone)]
pub struct KernelInst {
    /// Physical coordinates on the array.
    pub col: usize,
    pub row: usize,
    /// Logical position within the layer: (cascade row index, position along
    /// the cascade, 0 = west-most).
    pub cas_row: usize,
    pub cas_pos: usize,
    /// Packed weight stream for this tile: the `f_in_slice × f_out_slice`
    /// transposed weight slice in ⟨K,N⟩ tile-major order (RTP-loaded once,
    /// resident in local memory).
    pub weights: Vec<i32>,
    /// Bias slice (accumulator scale); only the cascade *tail* tile applies
    /// bias+SRS+activation. Empty elsewhere.
    pub bias: Vec<i64>,
    /// Is this the cascade tail (east-most tile of its row)?
    pub is_tail: bool,
    /// Local-memory bytes used by weights + double-buffered I/O.
    pub local_mem_bytes: usize,
}

/// The mem-tile program for one inter-layer edge.
#[derive(Debug, Clone)]
pub struct MemTilePlan {
    /// Column of the memory tile used (south edge of the consumer's input
    /// column after placement).
    pub mem_col: usize,
    /// Producer-side write tiler (layer_i writes {M_i, N_i} tiles).
    pub write_tiler: Tiler2d,
    /// Consumer-side read tiler (layer_{i+1} reads {M_{i+1}, K_{i+1}} tiles).
    pub read_tiler: Tiler2d,
    /// Implicit-GEMM patch walk (`Conv2D` consumers only): the buffer holds
    /// the NHWC *image* and the read DMA synthesizes the im2col stream from
    /// it coordinate-by-coordinate — `read_tiler` then describes the
    /// *logical* patch-matrix read the walk realizes, and `buffer_bytes` is
    /// image-sized (the zero-materialized-im2col invariant). `None` for
    /// every non-conv consumer; serialization skips it, so pre-conv
    /// firmware.json is byte-identical.
    pub patch: Option<ConvPatchTiler>,
    /// Buffer bytes (whole logical activation, single buffer).
    pub buffer_bytes: usize,
    /// Ping-pong double buffering enabled.
    pub ping_pong: bool,
    /// Element dtype stored in the buffer.
    pub dtype: Dtype,
    /// Memory-tile columns the buffer is sharded over (one shard per
    /// cascade column; each column's memory tile holds only its slice).
    pub columns: usize,
}

impl MemTilePlan {
    pub fn total_bytes(&self) -> usize {
        if self.ping_pong {
            self.buffer_bytes * 2
        } else {
            self.buffer_bytes
        }
    }

    /// Bytes resident in a single memory tile (its shard, ×2 if ping-pong).
    pub fn per_column_bytes(&self) -> usize {
        let shard = self.buffer_bytes.div_ceil(self.columns.max(1));
        if self.ping_pong {
            shard * 2
        } else {
            shard
        }
    }
}

/// The mem-tile program of a merge node: a multi-input buffer. Every
/// producer lands its tiles through its own write tiler (paper §III-C
/// generalized from one writer to N); consumers read the merged activation
/// row-major through their own input plans.
#[derive(Debug, Clone)]
pub struct MergePlan {
    /// Column of the memory tile holding the merged buffer.
    pub mem_col: usize,
    /// One producer-side write tiler per input edge, in input order.
    pub write_tilers: Vec<Tiler2d>,
    /// **Offset tilers** (`Concat` only): when non-empty, every producer
    /// writes its feature band directly into each dense consumer's {M, K}
    /// read-tile buffer — this plan then describes no buffer of its own
    /// (the merge's bytes live in the consumers' input plans) and the
    /// staged row-major copy is gone. The layout is consumer-major: one
    /// group of `inputs.len()` tilers per consumer, in consumer order, so
    /// `len == n_inputs × n_consumers` and group `c` is
    /// `offset_tilers[c*n_inputs..(c+1)*n_inputs]`. Empty means the legacy
    /// staged path: producers land in this buffer through `write_tilers`
    /// and consumers re-read it row-major.
    pub offset_tilers: Vec<OffsetTiler>,
    /// Merged activation width.
    pub features: usize,
    /// Buffer bytes (whole merged activation, single buffer).
    pub buffer_bytes: usize,
    /// Ping-pong double buffering enabled.
    pub ping_pong: bool,
    /// Element quantization of the merged buffer (all inputs must agree).
    pub quant: QuantSpec,
    /// Memory-tile columns the buffer spans (merge buffers are not sharded).
    pub columns: usize,
}

impl MergePlan {
    /// Bytes resident in a single memory tile (×2 if ping-pong).
    pub fn per_column_bytes(&self) -> usize {
        let shard = self.buffer_bytes.div_ceil(self.columns.max(1));
        if self.ping_pong {
            shard * 2
        } else {
            shard
        }
    }

    /// Whether the producers write straight into the consumer's read-tile
    /// buffer (no staged merge buffer of its own).
    pub fn offset_tiled(&self) -> bool {
        !self.offset_tilers.is_empty()
    }
}

/// One fully-resolved layer.
#[derive(Debug, Clone)]
pub struct FirmwareLayer {
    pub name: String,
    pub node_id: NodeId,
    /// GEMM K: `in_features` for Dense, `KH·KW·C_in` (one patch) for Conv2D.
    pub in_features: usize,
    /// GEMM N: `out_features` for Dense, `C_out` for Conv2D.
    pub out_features: usize,
    /// GEMM rows per sample: 1 for Dense, `OH·OW` for a lowered Conv2D —
    /// the layer processes `batch × m_scale` rows and its output tensor is
    /// `m_scale × out_features` wide per sample.
    pub m_scale: usize,
    pub use_bias: bool,
    pub relu: bool,
    pub quant: DenseQuant,
    pub tiling: MmulTiling,
    pub cascade: CascadeGeometry,
    pub placement: PlacementRect,
    /// `cascade.cas_num × cascade.cas_len` kernels, row-major by cascade row.
    pub kernels: Vec<KernelInst>,
    /// Mem-tile program feeding this layer's input.
    pub input_plan: MemTilePlan,
}

impl FirmwareLayer {
    pub fn kernel(&self, cas_row: usize, cas_pos: usize) -> &KernelInst {
        &self.kernels[cas_row * self.cascade.cas_len + cas_pos]
    }
    pub fn tiles(&self) -> usize {
        self.kernels.len()
    }
    /// True MACs per sample — for a lowered conv this is
    /// `OH·OW · KH·KW·C_in · C_out`, not the padded GEMM shape.
    pub fn macs_per_sample(&self) -> usize {
        self.in_features * self.out_features * self.m_scale
    }
    /// Output tensor width per sample (what downstream stages consume).
    pub fn out_width(&self) -> usize {
        self.out_features * self.m_scale
    }
    /// GEMM row count for a batch.
    pub fn gemm_rows(&self, batch: usize) -> usize {
        batch * self.m_scale
    }
}

/// A memory-tile stage operator in compiled firmware: the multi-input
/// merges plus the single-input windowed ops (pooling, transpose) that
/// execute on memory tiles without occupying compute tiles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergeOp {
    /// Residual elementwise add: i32 wrapping sum, SRS(shift 0) store
    /// (pure saturation — all operands share one binary point).
    Add,
    /// Feature concatenation in input order.
    Concat,
    /// Windowed max over an NHWC image (out-of-bounds taps excluded).
    MaxPool2D(Pool2DAttrs),
    /// Windowed mean over an NHWC image: sum over present taps, divide by
    /// the present count with round-half-toward-+inf, saturating store.
    AvgPool2D(Pool2DAttrs),
    /// Per-sample 2D transpose: `[rows, cols]` row-major → `[cols, rows]`.
    Transpose { rows: usize, cols: usize },
}

impl MergeOp {
    /// How many producers this stage takes: merges fan in two or more,
    /// windowed ops exactly one.
    pub fn arity_range(&self) -> (usize, usize) {
        match self {
            MergeOp::Add | MergeOp::Concat => (2, usize::MAX),
            _ => (1, 1),
        }
    }
    /// Expected input width per producer, when fixed by the op (pools and
    /// transpose; Add fixes it to `features`, Concat constrains the sum).
    pub fn fixed_in_width(&self) -> Option<usize> {
        match self {
            MergeOp::MaxPool2D(p) | MergeOp::AvgPool2D(p) => Some(p.in_features()),
            MergeOp::Transpose { rows, cols } => Some(rows * cols),
            _ => None,
        }
    }
}

/// One fully-resolved memory-tile stage (merge / pool / transpose).
#[derive(Debug, Clone)]
pub struct MergeStage {
    pub name: String,
    pub node_id: NodeId,
    pub op: MergeOp,
    /// Output width of the merged activation.
    pub features: usize,
    /// Quantization of the merged buffer (inputs and output agree).
    pub quant: QuantSpec,
    /// The multi-input mem-tile buffer realizing the merge.
    pub plan: MergePlan,
}

/// Where a stage reads its input from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageSource {
    /// The network input buffer.
    Input,
    /// The output of an earlier stage (index into [`Firmware::stages`]).
    Stage(usize),
}

/// What a stage executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageRef {
    /// Index into [`Firmware::layers`].
    Layer(usize),
    /// Index into [`Firmware::merges`].
    Merge(usize),
}

/// One node of the firmware stage DAG.
#[derive(Debug, Clone)]
pub struct FirmwareStage {
    pub op: StageRef,
    /// Producers feeding this stage, in input order. Dense stages have
    /// exactly one; merge stages have two or more.
    pub inputs: Vec<StageSource>,
}

/// One network output: a sink stage drained to the host through its own
/// mem-tile buffer. Multi-sink graphs carry one entry per sink, in
/// frontend layer order; the first entry is the *primary* output mirrored
/// by [`Firmware::output_stage`] / [`Firmware::output_plan`].
#[derive(Debug, Clone)]
pub struct FirmwareOutput {
    /// Name of the producing stage (the sink layer/merge's name).
    pub name: String,
    /// Index into [`Firmware::stages`] of the producing stage.
    pub stage: usize,
    /// Mem-tile program draining this output.
    pub plan: MemTilePlan,
    /// Offset tiler landing this drain directly in a downstream consumer's
    /// {M, K} read layout — set by the partitioner on the drain feeding a
    /// [`crate::partition::PartitionLink`], so the crossing activation
    /// never stages row-major on the downstream array. `None` (the
    /// emission default) is the legacy row-major drain; serialization
    /// skips it, so single-array firmware.json is unchanged.
    pub write_tiler: Option<OffsetTiler>,
}

/// The rectangular array region a placed firmware actually occupies, plus
/// its worst-case memory-tile residency — the unit of replication.
///
/// Replicating a compiled block (paper §V-B) means stamping the same
/// relative placement elsewhere on the array, so the copy needs the full
/// bounding box of the original — including any tiles the placer left idle
/// inside it — not just `tiles_used()`. Copies stacked vertically in the
/// same columns additionally share those columns' memory tiles, so the
/// per-column buffer residency bounds how many rows-worth of copies one
/// column stack can hold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlacementFootprint {
    /// Column span of the bounding box (compute rects and mem-tile shards).
    pub cols: usize,
    /// Row span of the bounding box.
    pub rows: usize,
    /// Worst per-column memory-tile residency in bytes (every buffer shard
    /// landing in one column summed, ping-pong included).
    pub mem_bytes_per_col: usize,
}

impl PlacementFootprint {
    /// Tiles inside the bounding box (≥ `Firmware::tiles_used()`).
    pub fn tiles(&self) -> usize {
        self.cols * self.rows
    }

    /// How many non-overlapping copies of this footprint fit on `device`:
    /// horizontal stampings across the placeable columns times vertical
    /// stampings, the latter limited by both the row count and the
    /// memory-tile capacity the stacked copies share per column. Always at
    /// least 1 (the firmware itself is placed).
    pub fn replicas_on(&self, device: &Device) -> usize {
        let horizontal = device.placeable_cols() / self.cols.max(1);
        let by_rows = device.rows / self.rows.max(1);
        let by_mem = if self.mem_bytes_per_col == 0 {
            by_rows
        } else {
            device.mem_tile_bytes / self.mem_bytes_per_col
        };
        (horizontal * by_rows.min(by_mem)).max(1)
    }
}

/// The complete firmware package for one model.
///
/// Execution structure is a **stage DAG**, not a layer chain: `stages`
/// lists every compute stage (dense layers and merge nodes) in topological
/// order, each naming its producers, so fan-out and residual fan-in
/// topologies execute the same way chains do (a chain is the degenerate
/// DAG where every stage has one input and one consumer). `layers` and
/// `merges` are the stage pools the DAG indexes into.
#[derive(Debug, Clone)]
pub struct Firmware {
    pub model_name: String,
    pub device: Device,
    /// Dense stages in topological order.
    pub layers: Vec<FirmwareLayer>,
    /// Merge stages (residual Add / Concat) in topological order.
    pub merges: Vec<MergeStage>,
    /// The stage DAG in topological order: a stage's inputs always
    /// reference lower stage indices (or the network input).
    pub stages: Vec<FirmwareStage>,
    /// Index into `stages` of the stage producing the *primary* network
    /// output — always `outputs[0].stage` (kept as a field so single-output
    /// callers and serialization stay unchanged).
    pub output_stage: usize,
    /// Network input width.
    pub in_features: usize,
    /// Quantization of the network input buffer.
    pub input_quant: QuantSpec,
    /// Mem-tile program draining the primary output stage — always a copy
    /// of `outputs[0].plan`.
    pub output_plan: MemTilePlan,
    /// Every network output, one per graph sink, in frontend layer order.
    /// Single-sink firmware has exactly one entry (the primary output).
    pub outputs: Vec<FirmwareOutput>,
    /// Steady-state batch size the pipeline is configured for.
    pub batch: usize,
}

impl Firmware {
    /// Compute tiles used across all layers.
    pub fn tiles_used(&self) -> usize {
        self.layers.iter().map(|l| l.tiles()).sum()
    }

    /// Total MACs per sample.
    pub fn macs_per_sample(&self) -> usize {
        self.layers.iter().map(|l| l.macs_per_sample()).sum()
    }

    /// The placed bounding box + per-column memory-tile residency — what a
    /// replica of this firmware actually costs on the array (see
    /// [`PlacementFootprint`]). Spans cover the compute rects *and* every
    /// mem-tile shard column (input plans, merge buffers, output drains);
    /// residency sums all shards landing in the worst column.
    pub fn placement_footprint(&self) -> PlacementFootprint {
        let mut col_lo = usize::MAX;
        let mut col_hi = 0usize;
        let mut row_lo = usize::MAX;
        let mut row_hi = 0usize;
        // Every mem-tile shard: (west-most column, columns spanned, bytes
        // per column).
        let mut shards: Vec<(usize, usize, usize)> = Vec::new();
        for l in &self.layers {
            col_lo = col_lo.min(l.placement.col);
            col_hi = col_hi.max(l.placement.col + l.placement.width - 1);
            row_lo = row_lo.min(l.placement.row);
            row_hi = row_hi.max(l.placement.row + l.placement.height);
            shards.push((
                l.input_plan.mem_col,
                l.input_plan.columns,
                l.input_plan.per_column_bytes(),
            ));
        }
        for m in &self.merges {
            // Offset-tiled merges own no buffer: their bytes live in the
            // consumer's input plan, already counted above.
            if !m.plan.offset_tiled() {
                shards.push((m.plan.mem_col, m.plan.columns, m.plan.per_column_bytes()));
            }
        }
        for o in &self.outputs {
            shards.push((o.plan.mem_col, o.plan.columns, o.plan.per_column_bytes()));
        }
        if col_lo == usize::MAX {
            // No layers (cannot happen for emitted firmware) — empty box.
            return PlacementFootprint { cols: 0, rows: 0, mem_bytes_per_col: 0 };
        }
        let mut per_col = std::collections::BTreeMap::<usize, usize>::new();
        for (mem_col, columns, bytes) in shards {
            let n = columns.max(1);
            col_lo = col_lo.min(mem_col);
            col_hi = col_hi.max(mem_col + n - 1);
            for c in mem_col..mem_col + n {
                *per_col.entry(c).or_insert(0) += bytes;
            }
        }
        PlacementFootprint {
            cols: col_hi - col_lo + 1,
            rows: row_hi - row_lo.min(row_hi),
            mem_bytes_per_col: per_col.values().copied().max().unwrap_or(0),
        }
    }

    /// Total ops per sample (2 per MAC).
    pub fn ops_per_sample(&self) -> usize {
        2 * self.macs_per_sample()
    }

    /// Network input/output feature counts.
    pub fn input_features(&self) -> usize {
        self.in_features
    }
    pub fn output_features(&self) -> usize {
        self.stages
            .get(self.output_stage)
            .map(|s| self.stage_out_features_of(s))
            .unwrap_or(0)
    }

    /// Quantization of the primary network output (the output stage's
    /// store spec).
    pub fn output_quant(&self) -> QuantSpec {
        self.stage_quant(self.output_stage)
    }

    /// Store spec of stage `i`.
    pub fn stage_quant(&self, i: usize) -> QuantSpec {
        match self.stages[i].op {
            StageRef::Layer(li) => self.layers[li].quant.output,
            StageRef::Merge(mi) => self.merges[mi].quant,
        }
    }

    /// Feature count of network output `i` (index into [`Firmware::outputs`]).
    pub fn output_features_of(&self, i: usize) -> usize {
        self.stage_out_features(self.outputs[i].stage)
    }

    /// Names of every network output, in output order.
    pub fn output_names(&self) -> Vec<&str> {
        self.outputs.iter().map(|o| o.name.as_str()).collect()
    }

    /// Feature count produced by stage `i`.
    pub fn stage_out_features(&self, i: usize) -> usize {
        self.stage_out_features_of(&self.stages[i])
    }

    fn stage_out_features_of(&self, s: &FirmwareStage) -> usize {
        match s.op {
            // Full output tensor width: a lowered conv produces
            // `m_scale × out_features` per sample.
            StageRef::Layer(li) => self.layers[li].out_width(),
            StageRef::Merge(mi) => self.merges[mi].features,
        }
    }

    /// Display name of stage `i`.
    pub fn stage_name(&self, i: usize) -> &str {
        match self.stages[i].op {
            StageRef::Layer(li) => &self.layers[li].name,
            StageRef::Merge(mi) => &self.merges[mi].name,
        }
    }

    /// Stages consuming stage `i`'s output, in stage order (empty for the
    /// output stage).
    pub fn stage_consumers(&self, i: usize) -> Vec<usize> {
        self.stages
            .iter()
            .enumerate()
            .filter(|(_, s)| s.inputs.contains(&StageSource::Stage(i)))
            .map(|(j, _)| j)
            .collect()
    }

    /// The same firmware with every offset tiler stripped — the legacy
    /// **staged** data path (row-major merge buffers, row-major drains).
    /// Bit-exactness is unaffected (the tilers only change data layout);
    /// benches and tests use this for staged-vs-offset comparisons of the
    /// performance and routing models.
    pub fn staged_variant(&self) -> Firmware {
        let mut fw = self.clone();
        for m in &mut fw.merges {
            m.plan.offset_tilers.clear();
        }
        for o in &mut fw.outputs {
            o.write_tiler = None;
        }
        fw
    }

    /// The same firmware with every conv patch walk flipped to the
    /// **staged-im2col** baseline: the input buffer additionally holds the
    /// materialized `M × K` patch matrix and the cycle model charges the
    /// staging copy's DMA traffic. Functional results are identical — only
    /// modeled residency/cycles change. `benches/conv_lowering.rs` baseline.
    pub fn staged_im2col_variant(&self) -> Firmware {
        let mut fw = self.clone();
        let batch = self.batch;
        for l in &mut fw.layers {
            if let Some(p) = &mut l.input_plan.patch {
                p.staged = true;
                let rows = batch * p.out_h * p.out_w;
                l.input_plan.buffer_bytes += rows * p.patch_len() * l.input_plan.dtype.bytes();
            }
        }
        fw
    }

    /// Sanity invariants the emission pass guarantees; exercised by tests
    /// and by `aie4ml compile --verify`.
    pub fn check_invariants(&self) -> anyhow::Result<()> {
        use anyhow::ensure;
        let cols = self.device.cols - self.device.reserved_cols;
        let rows = self.device.rows;
        // Placements legal + non-overlapping.
        for (i, a) in self.layers.iter().enumerate() {
            ensure!(
                a.placement.fits(cols, rows),
                "layer {} placement out of bounds: {:?}",
                a.name,
                a.placement
            );
            for b in &self.layers[i + 1..] {
                ensure!(
                    !a.placement.overlaps(&b.placement),
                    "layers {} and {} overlap",
                    a.name,
                    b.name
                );
            }
        }
        for l in &self.layers {
            // Kernel grid complete and coordinates inside the rect.
            ensure!(
                l.kernels.len() == l.cascade.tiles(),
                "layer {}: {} kernels for {} cascade tiles",
                l.name,
                l.kernels.len(),
                l.cascade.tiles()
            );
            for k in &l.kernels {
                ensure!(
                    k.col >= l.placement.col
                        && k.col < l.placement.col + l.placement.width
                        && k.row >= l.placement.row
                        && k.row < l.placement.row + l.placement.height,
                    "layer {}: kernel at ({},{}) outside rect {:?}",
                    l.name,
                    k.col,
                    k.row,
                    l.placement
                );
                // Tail tiles carry bias (when used); heads/mids don't.
                if k.is_tail {
                    ensure!(
                        !l.use_bias || k.bias.len() == l.cascade.f_out_slice,
                        "layer {}: tail bias length",
                        l.name
                    );
                } else {
                    ensure!(k.bias.is_empty(), "layer {}: non-tail tile has bias", l.name);
                }
                // Local memory budget.
                ensure!(
                    k.local_mem_bytes <= self.device.local_mem_bytes,
                    "layer {}: tile ({},{}) uses {} B local memory (limit {})",
                    l.name,
                    k.col,
                    k.row,
                    k.local_mem_bytes,
                    self.device.local_mem_bytes
                );
            }
            // Mem-tile buffer shard fits one memory tile.
            ensure!(
                l.input_plan.per_column_bytes() <= self.device.mem_tile_bytes,
                "layer {}: input mem-tile shard {} B exceeds {} B",
                l.name,
                l.input_plan.per_column_bytes(),
                self.device.mem_tile_bytes
            );
            // Conv layers carry a patch walk agreeing with the GEMM shape;
            // unless modeling the staged-im2col baseline, the input buffer
            // holds only the image (the zero-materialized-im2col invariant).
            match &l.input_plan.patch {
                Some(p) => {
                    ensure!(
                        p.patch_len() == l.in_features && p.out_h * p.out_w == l.m_scale,
                        "layer {}: patch walk ({} K, {} rows/sample) disagrees with \
                         GEMM shape ({} K, {} rows/sample)",
                        l.name,
                        p.patch_len(),
                        p.out_h * p.out_w,
                        l.in_features,
                        l.m_scale
                    );
                    if !p.staged {
                        let image_bytes =
                            self.batch * p.image_features() * l.input_plan.dtype.bytes();
                        ensure!(
                            l.input_plan.buffer_bytes == image_bytes,
                            "layer {}: conv input buffer {} B != image {} B \
                             (materialized im2col?)",
                            l.name,
                            l.input_plan.buffer_bytes,
                            image_bytes
                        );
                    }
                }
                None => {
                    ensure!(
                        l.m_scale == 1,
                        "layer {}: m_scale {} without a patch-walk read plan",
                        l.name,
                        l.m_scale
                    );
                }
            }
        }
        ensure!(
            self.tiles_used() <= self.device.placeable_tiles(),
            "firmware uses {} tiles, device has {}",
            self.tiles_used(),
            self.device.placeable_tiles()
        );
        // Stage DAG: complete, topological, well-typed.
        ensure!(
            self.stages.len() == self.layers.len() + self.merges.len(),
            "stage DAG has {} stages for {} layers + {} merges",
            self.stages.len(),
            self.layers.len(),
            self.merges.len()
        );
        ensure!(self.output_stage < self.stages.len(), "output stage out of range");
        // Per-sink outputs: non-empty, primary mirrors outputs[0], every
        // entry names a distinct in-range stage nothing else consumes.
        ensure!(!self.outputs.is_empty(), "firmware has no network outputs");
        ensure!(
            self.outputs[0].stage == self.output_stage,
            "primary output stage {} != outputs[0].stage {}",
            self.output_stage,
            self.outputs[0].stage
        );
        for (i, o) in self.outputs.iter().enumerate() {
            ensure!(o.stage < self.stages.len(), "output '{}' stage out of range", o.name);
            for other in &self.outputs[i + 1..] {
                ensure!(
                    other.stage != o.stage,
                    "outputs '{}' and '{}' drain the same stage",
                    o.name,
                    other.name
                );
            }
            ensure!(
                o.plan.per_column_bytes() <= self.device.mem_tile_bytes,
                "output '{}': drain buffer {} B exceeds {} B",
                o.name,
                o.plan.per_column_bytes(),
                self.device.mem_tile_bytes
            );
        }
        for (i, s) in self.stages.iter().enumerate() {
            for src in &s.inputs {
                if let StageSource::Stage(j) = src {
                    ensure!(*j < i, "stage {i} consumes stage {j}: DAG not topological");
                }
            }
            match s.op {
                StageRef::Layer(li) => {
                    ensure!(li < self.layers.len(), "stage {i}: layer index {li} out of range");
                    ensure!(
                        s.inputs.len() == 1,
                        "dense stage '{}' has {} inputs",
                        self.layers[li].name,
                        s.inputs.len()
                    );
                }
                StageRef::Merge(mi) => {
                    ensure!(mi < self.merges.len(), "stage {i}: merge index {mi} out of range");
                    let m = &self.merges[mi];
                    let (lo, hi) = m.op.arity_range();
                    ensure!(
                        s.inputs.len() >= lo
                            && s.inputs.len() <= hi
                            && s.inputs.len() == m.plan.write_tilers.len(),
                        "merge '{}': {} inputs vs {} write tilers",
                        m.name,
                        s.inputs.len(),
                        m.plan.write_tilers.len()
                    );
                    let widths: Vec<usize> = s
                        .inputs
                        .iter()
                        .map(|src| match src {
                            StageSource::Input => self.in_features,
                            StageSource::Stage(j) => self.stage_out_features(*j),
                        })
                        .collect();
                    match m.op {
                        MergeOp::Add => {
                            ensure!(
                                widths.iter().all(|&w| w == m.features),
                                "merge '{}': add input widths {:?} != {}",
                                m.name,
                                widths,
                                m.features
                            );
                        }
                        MergeOp::Concat => {
                            let sum: usize = widths.iter().sum();
                            ensure!(
                                sum == m.features,
                                "merge '{}': concat widths {:?} sum to {} != {}",
                                m.name,
                                widths,
                                sum,
                                m.features
                            );
                        }
                        MergeOp::MaxPool2D(p) | MergeOp::AvgPool2D(p) => {
                            ensure!(
                                widths == [p.in_features()] && m.features == p.out_features(),
                                "stage '{}': pool widths {:?} -> {} inconsistent with window",
                                m.name,
                                widths,
                                m.features
                            );
                        }
                        MergeOp::Transpose { rows, cols } => {
                            ensure!(
                                widths == [rows * cols] && m.features == rows * cols,
                                "stage '{}': transpose widths {:?} != {}x{}",
                                m.name,
                                widths,
                                rows,
                                cols
                            );
                        }
                    }
                    if m.plan.offset_tiled() {
                        // Offset tilers: Concat only, consumer-major groups
                        // of one tiler per input, each group's bands tiling
                        // the merged width exactly in input order, one
                        // group per dense consumer stage.
                        ensure!(
                            m.op == MergeOp::Concat,
                            "merge '{}': offset tilers on a non-concat merge",
                            m.name
                        );
                        ensure!(
                            m.plan.offset_tilers.len() % s.inputs.len() == 0,
                            "merge '{}': {} offset tilers not a multiple of {} inputs",
                            m.name,
                            m.plan.offset_tilers.len(),
                            s.inputs.len()
                        );
                        let consumers = self.stage_consumers(i);
                        let groups = m.plan.offset_tilers.len() / s.inputs.len();
                        ensure!(
                            groups == consumers.len(),
                            "merge '{}': {} offset-tiler groups for {} consumers",
                            m.name,
                            groups,
                            consumers.len()
                        );
                        for &c in &consumers {
                            ensure!(
                                matches!(self.stages[c].op, StageRef::Layer(_)),
                                "merge '{}': offset-tiled consumer stage {c} is not dense",
                                m.name
                            );
                        }
                        for group in m.plan.offset_tilers.chunks(s.inputs.len()) {
                            let mut off = 0usize;
                            for (t, &w) in group.iter().zip(&widths) {
                                ensure!(
                                    t.offset == off && t.stride == m.features,
                                    "merge '{}': offset tiler band ({}, {}) misplaced \
                                     (expected offset {off}, stride {})",
                                    m.name,
                                    t.offset,
                                    t.stride,
                                    m.features
                                );
                                off += w;
                            }
                            ensure!(
                                off == m.features,
                                "merge '{}': offset bands cover {} of {} features",
                                m.name,
                                off,
                                m.features
                            );
                        }
                    } else {
                        // Staged merges own the buffer: its shard must fit
                        // one memory tile (offset-tiled merges have no
                        // buffer — the consumer's input plan is checked).
                        ensure!(
                            m.plan.per_column_bytes() <= self.device.mem_tile_bytes,
                            "merge '{}': buffer {} B exceeds {} B",
                            m.name,
                            m.plan.per_column_bytes(),
                            self.device.mem_tile_bytes
                        );
                    }
                }
            }
        }
        Ok(())
    }

    /// Serialize a structural summary to pretty JSON (weights elided — they
    /// live in the packed binary blobs next to the project).
    pub fn to_json(&self) -> anyhow::Result<String> {
        use crate::util::json::{obj, Value};
        let layers: Vec<Value> = self
            .layers
            .iter()
            .map(|l| {
                let mut v = obj([
                    ("name", Value::from(l.name.as_str())),
                    ("in_features", Value::from(l.in_features)),
                    ("out_features", Value::from(l.out_features)),
                    ("use_bias", Value::from(l.use_bias)),
                    ("relu", Value::from(l.relu)),
                    ("dtype", Value::from(l.quant.input.dtype.to_string())),
                    ("acc_dtype", Value::from(l.quant.acc_dtype.to_string())),
                    ("shift", Value::from(l.quant.shift)),
                    (
                        "tiling",
                        Value::from(vec![l.tiling.m, l.tiling.k, l.tiling.n]),
                    ),
                    (
                        "cascade",
                        obj([
                            ("cas_len", Value::from(l.cascade.cas_len)),
                            ("cas_num", Value::from(l.cascade.cas_num)),
                            ("f_in_slice", Value::from(l.cascade.f_in_slice)),
                            ("f_out_slice", Value::from(l.cascade.f_out_slice)),
                        ]),
                    ),
                    (
                        "placement",
                        Value::from(vec![
                            l.placement.col,
                            l.placement.row,
                            l.placement.width,
                            l.placement.height,
                        ]),
                    ),
                    ("mem_col", Value::from(l.input_plan.mem_col)),
                    ("mem_bytes_per_column", Value::from(l.input_plan.per_column_bytes())),
                ]);
                // Lowered convs describe their implicit-GEMM patch walk;
                // dense layers keep the exact legacy shape (no keys), so
                // pre-conv firmware.json is byte-identical.
                if let Some(p) = &l.input_plan.patch {
                    if let Value::Object(fields) = &mut v {
                        fields.insert("m_scale".to_string(), Value::from(l.m_scale));
                        fields.insert(
                            "patch".to_string(),
                            obj([
                                ("image", Value::from(vec![p.in_h, p.in_w, p.in_c])),
                                ("kernel", Value::from(vec![p.kh, p.kw])),
                                ("stride", Value::from(vec![p.stride_h, p.stride_w])),
                                ("pad", Value::from(vec![p.pad_top, p.pad_left])),
                                ("out", Value::from(vec![p.out_h, p.out_w])),
                                ("tile", Value::from(vec![p.tile_m, p.tile_k])),
                                ("staged", Value::from(p.staged)),
                            ]),
                        );
                    }
                }
                v
            })
            .collect();
        let mut top = obj([
            ("model", Value::from(self.model_name.as_str())),
            ("device", Value::from(self.device.name.as_str())),
            ("batch", Value::from(self.batch)),
            ("tiles_used", Value::from(self.tiles_used())),
            ("macs_per_sample", Value::from(self.macs_per_sample())),
            ("layers", Value::Array(layers)),
        ]);
        // DAG models additionally describe their merges and stage wiring;
        // chain firmware keeps the exact pre-DAG JSON shape.
        if !self.merges.is_empty() {
            let merges: Vec<Value> = self
                .merges
                .iter()
                .map(|m| {
                    let mut v = obj([
                        ("name", Value::from(m.name.as_str())),
                        (
                            "op",
                            Value::from(match m.op {
                                MergeOp::Add => "add",
                                MergeOp::Concat => "concat",
                                MergeOp::MaxPool2D(_) => "maxpool2d",
                                MergeOp::AvgPool2D(_) => "avgpool2d",
                                MergeOp::Transpose { .. } => "transpose",
                            }),
                        ),
                        ("features", Value::from(m.features)),
                        ("dtype", Value::from(m.quant.dtype.to_string())),
                        ("mem_col", Value::from(m.plan.mem_col)),
                        // An offset-tiled merge owns no buffer: its bytes
                        // live in the consumer's input plan (reporting the
                        // staged size here would double-count the column).
                        (
                            "mem_bytes",
                            Value::from(if m.plan.offset_tiled() {
                                0
                            } else {
                                m.plan.per_column_bytes()
                            }),
                        ),
                    ]);
                    // Offset-tiled concats describe their direct-landing
                    // descriptors; staged merges keep the exact legacy
                    // shape (no key), so pre-offset firmware.json is
                    // byte-identical.
                    if m.plan.offset_tiled() {
                        if let Value::Object(fields) = &mut v {
                            fields.insert(
                                "write_tilers".to_string(),
                                Value::Array(
                                    m.plan
                                        .offset_tilers
                                        .iter()
                                        .map(|t| {
                                            Value::from(vec![
                                                t.offset, t.stride, t.tile_m, t.tile_k,
                                            ])
                                        })
                                        .collect(),
                                ),
                            );
                        }
                    }
                    v
                })
                .collect();
            let stages: Vec<Value> = self
                .stages
                .iter()
                .map(|s| {
                    let op = match s.op {
                        StageRef::Layer(i) => format!("dense:{i}"),
                        StageRef::Merge(i) => format!("merge:{i}"),
                    };
                    let inputs: Vec<Value> = s
                        .inputs
                        .iter()
                        .map(|src| match src {
                            StageSource::Input => Value::from("input"),
                            StageSource::Stage(j) => Value::from(*j),
                        })
                        .collect();
                    obj([("op", Value::from(op)), ("inputs", Value::Array(inputs))])
                })
                .collect();
            if let Value::Object(fields) = &mut top {
                fields.insert("merges".to_string(), Value::Array(merges));
                fields.insert("stages".to_string(), Value::Array(stages));
                fields.insert("output_stage".to_string(), Value::from(self.output_stage));
            }
        }
        // Multi-sink firmware names every output drain; single-output
        // firmware keeps the exact pre-multi-sink JSON shape.
        if self.outputs.len() > 1 {
            let outs: Vec<Value> = self
                .outputs
                .iter()
                .map(|o| {
                    let mut v = obj([
                        ("name", Value::from(o.name.as_str())),
                        ("stage", Value::from(o.stage)),
                        ("features", Value::from(self.stage_out_features(o.stage))),
                        ("mem_col", Value::from(o.plan.mem_col)),
                    ]);
                    // Only drains re-targeted by the partitioner carry a
                    // landing descriptor; plain drains keep the legacy
                    // shape byte-for-byte.
                    if let (Value::Object(fields), Some(t)) = (&mut v, &o.write_tiler) {
                        fields.insert(
                            "write_tiler".to_string(),
                            Value::from(vec![t.offset, t.stride, t.tile_m, t.tile_k]),
                        );
                    }
                    v
                })
                .collect();
            if let Value::Object(fields) = &mut top {
                fields.insert("outputs".to_string(), Value::Array(outs));
            }
        }
        Ok(top.to_string_pretty())
    }
}
