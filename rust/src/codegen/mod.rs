//! Code generation: the resolved firmware package and project rendering.

pub mod firmware;
pub mod render;

pub use firmware::{
    Firmware, FirmwareLayer, FirmwareOutput, FirmwareStage, KernelInst, MemTilePlan, MergeOp,
    MergePlan, MergeStage, PlacementFootprint, StageRef, StageSource,
};
pub use render::{render_floorplan, render_graph, render_kernel, write_project};
