//! Steady-state performance model of a partitioned, multi-array pipeline.
//!
//! Each partition occupies its own AIE-ML array and the arrays form a
//! K-stage macro-pipeline connected by inter-array links (on real silicon:
//! the NoC / PL stream between packages; modeled as a mem-tile-rate DMA
//! transfer plus one descriptor setup, since the link ingests from and
//! lands into memory-tile buffers on both sides). Double buffering on the
//! link buffers means batches overlap across arrays, so:
//!
//! * **interval** — the slowest pipeline stage: the worst per-partition
//!   steady-state interval, or the slowest link transfer if the wire is
//!   the bottleneck;
//! * **latency** — the sum of every partition's fill latency plus every
//!   link hop (a batch must traverse all K arrays before its first output
//!   appears).
//!
//! A one-partition pipeline degenerates to [`crate::sim::engine::analyze`]
//! exactly — same interval, same latency.

use super::{PartitionLink, PartitionedFirmware};
use crate::codegen::firmware::{Firmware, StageRef, StageSource};
use crate::sim::engine::{analyze, EngineModel};

/// Per-partition summary row.
#[derive(Debug, Clone)]
pub struct PartitionPerf {
    pub name: String,
    /// Dense stages in this partition.
    pub layers: usize,
    pub tiles: usize,
    /// Steady-state interval of this partition alone (cycles/batch).
    pub interval_cycles: f64,
    /// Fill latency of this partition alone (cycles).
    pub latency_cycles: f64,
}

/// Per-link summary row: one inter-array transfer between consecutive
/// partitions.
#[derive(Debug, Clone)]
pub struct LinkPerf {
    /// Upstream partition index (the link feeds partition `from + 1`).
    pub from: usize,
    /// Link activation features per sample.
    pub features: usize,
    /// Bytes moved per batch.
    pub bytes: usize,
    /// Transfer cycles charged to latency (and interval, if the wire is
    /// the pipeline bottleneck).
    pub cycles: f64,
    /// True when the landing image needs a downstream re-tile pass (no
    /// offset tiler on the wire).
    pub staged: bool,
    /// Switch traversals the landing pays on the downstream array.
    pub landing_hops: usize,
}

/// Whole-pipeline performance report.
#[derive(Debug, Clone)]
pub struct PipelinePerfReport {
    pub model_name: String,
    /// Pipeline depth (number of arrays).
    pub k: usize,
    pub batch: usize,
    /// Tiles summed over every array.
    pub tiles_used: usize,
    /// Steady-state cycles between consecutive batch outputs.
    pub interval_cycles: f64,
    /// End-to-end cycles for one batch through the empty pipeline.
    pub latency_cycles: f64,
    pub interval_us: f64,
    pub latency_us: f64,
    /// Steady-state per-sample output interval, µs.
    pub interval_per_sample_us: f64,
    /// Sustained throughput over the whole deployment, TOPS.
    pub throughput_tops: f64,
    /// Total link-hop cycles charged to latency.
    pub link_cycles: f64,
    pub partitions: Vec<PartitionPerf>,
    /// Per-link rows (`k - 1` entries, in pipeline order).
    pub links: Vec<LinkPerf>,
}

impl PipelinePerfReport {
    /// The partition bounding the steady-state interval.
    pub fn bottleneck_partition(&self) -> Option<&PartitionPerf> {
        self.partitions
            .iter()
            .max_by(|a, b| a.interval_cycles.partial_cmp(&b.interval_cycles).unwrap())
    }
}

/// Cycles for one inter-partition link transfer of `bytes`.
///
/// An offset-tiled link ([`PartitionLink::write_tiler`]) is a single wire
/// transfer: the upstream drain already holds the activation in the
/// downstream consumer's {M, K} read layout, so it lands directly in the
/// input buffer. A staged (row-major) link pays one more buffer pass at
/// memory-tile rate on the downstream side — the landing image must be
/// re-tiled into the consumer's read layout before the first read can
/// broadcast up the cascade columns. That staging copy was previously
/// unmodeled; the tiled path costs exactly what the old formula charged.
fn link_transfer_cycles(
    link: &PartitionLink,
    bytes: usize,
    port_bytes: usize,
    model: &EngineModel,
) -> f64 {
    let wire = bytes as f64 / port_bytes.max(1) as f64 + model.dma_setup as f64;
    if link.write_tiler.is_some() {
        wire
    } else {
        wire + bytes as f64 / port_bytes.max(1) as f64 + model.dma_setup as f64
    }
}

/// Landing hops of one link on its downstream array: switch traversals
/// along the memory-tile row from the shim entry (column 0) into the
/// downstream input buffer. An **offset-tiled** link streams its {M, K}
/// blocks in a single pass from the entry out to the farthest shard
/// column of the consumer's read buffer. A **staged** link lands its
/// row-major image at the entry column (a local store, no row hops) and
/// then pays a buffer-to-buffer re-tile pass into every shard column —
/// charged from the image's location, exactly how
/// [`crate::sim::interconnect::route_firmware`] charges a staged merge's
/// forwarding, so the staged-vs-offset comparison measures only the extra
/// pass the offset tiler eliminates.
fn link_landing_hops(link: &PartitionLink, down: &Firmware) -> usize {
    // The input buffer(s): every stage reading the network input.
    let mut hops = 0usize;
    for s in &down.stages {
        if !s.inputs.contains(&StageSource::Input) {
            continue;
        }
        let (mem_col, columns) = match s.op {
            StageRef::Layer(li) => {
                let p = &down.layers[li].input_plan;
                (p.mem_col, p.columns.max(1))
            }
            StageRef::Merge(mi) => (down.merges[mi].plan.mem_col, 1),
        };
        if link.write_tiler.is_some() {
            // Direct landing: one pass to the farthest shard column.
            hops += mem_col + columns - 1;
        } else {
            // Staged: re-tile the entry-column image into each shard.
            hops += (0..columns).map(|shard| mem_col + shard).sum::<usize>();
        }
    }
    hops
}

/// Total interconnect hops of a pipeline: every partition's static routes
/// ([`crate::sim::interconnect::route_firmware`]) plus each link's landing
/// hops on its downstream array — the number the offset tilers shrink.
pub fn pipeline_total_hops(pfw: &PartitionedFirmware) -> usize {
    let mut total = 0usize;
    for fw in &pfw.partitions {
        total += crate::sim::interconnect::route_firmware(fw)
            .expect("partitioned firmware drains every sink (check_invariants)")
            .total_hops;
    }
    for (i, link) in pfw.links.iter().enumerate() {
        total += link_landing_hops(link, &pfw.partitions[i + 1]);
    }
    total
}

/// Analyze a partitioned pipeline under the engine's cost model.
pub fn analyze_pipeline(pfw: &PartitionedFirmware, model: &EngineModel) -> PipelinePerfReport {
    let batch = pfw.batch();
    let mut partitions = Vec::with_capacity(pfw.partitions.len());
    let mut interval = 0.0f64;
    let mut latency = 0.0f64;
    for fw in &pfw.partitions {
        let rep = analyze(fw, model);
        interval = interval.max(rep.interval_cycles);
        latency += rep.latency_cycles;
        partitions.push(PartitionPerf {
            name: fw.model_name.clone(),
            layers: fw.layers.len(),
            tiles: fw.tiles_used(),
            interval_cycles: rep.interval_cycles,
            latency_cycles: rep.latency_cycles,
        });
    }
    let mut link_cycles = 0.0f64;
    let mut links = Vec::with_capacity(pfw.links.len());
    for (i, link) in pfw.links.iter().enumerate() {
        let device = &pfw.partitions[i].device;
        let bytes = batch * link.features * link.quant.dtype.bytes();
        let hop = link_transfer_cycles(link, bytes, device.mem_tile_port_bytes, model);
        // A link is a pipeline stage of its own: it bounds the interval
        // when the wire is slower than every array, and every hop adds to
        // the fill latency.
        interval = interval.max(hop);
        link_cycles += hop;
        links.push(LinkPerf {
            from: i,
            features: link.features,
            bytes,
            cycles: hop,
            staged: link.write_tiler.is_none(),
            landing_hops: link_landing_hops(link, &pfw.partitions[i + 1]),
        });
    }
    latency += link_cycles;
    let freq_hz = pfw.partitions[0].device.freq_ghz * 1e9;
    let interval_us = interval / freq_hz * 1e6;
    let latency_us = latency / freq_hz * 1e6;
    let ops = pfw.partitions.iter().map(|p| p.ops_per_sample()).sum::<usize>() as f64
        * batch as f64;
    let throughput_tops =
        if interval > 0.0 { ops / (interval / freq_hz) / 1e12 } else { 0.0 };
    PipelinePerfReport {
        model_name: pfw.model_name.clone(),
        k: pfw.k(),
        batch,
        tiles_used: pfw.tiles_used(),
        interval_cycles: interval,
        latency_cycles: latency,
        interval_us,
        latency_us,
        interval_per_sample_us: interval_us / batch as f64,
        throughput_tops,
        link_cycles,
        partitions,
        links,
    }
}

/// One step of the modeled critical path: a partition's fill latency or a
/// link transfer, in pipeline order.
#[derive(Debug, Clone)]
pub struct ModelPathStep {
    pub name: String,
    pub is_link: bool,
    pub cycles: f64,
    pub us: f64,
    /// True when this step's own steady-state interval bounds the whole
    /// pipeline (the bottleneck stage).
    pub bottleneck: bool,
}

/// The stage-DAG critical path of one batch through the empty pipeline —
/// the model-level sibling of the trace-level
/// [`crate::obs::attrib::CriticalPath`]. The pipeline is a linear chain,
/// so the fill path *is* every partition plus every link; what the
/// breakdown adds is per-step cycles/µs and which step bounds the
/// steady-state interval.
#[derive(Debug, Clone)]
pub struct ModelCriticalPath {
    pub steps: Vec<ModelPathStep>,
    pub total_cycles: f64,
    pub total_us: f64,
    /// Steady-state interval, for the closing summary line.
    pub interval_cycles: f64,
}

impl ModelCriticalPath {
    /// Text rendering for `partition --explain`.
    pub fn render(&self) -> String {
        let mut out = String::from("Critical path (batch fill through the empty pipeline):\n");
        for s in &self.steps {
            let mark = if s.bottleneck { "  <- interval bottleneck" } else { "" };
            out.push_str(&format!(
                "  {:<44} {:>12.0} cyc {:>10.2} us{}\n",
                s.name, s.cycles, s.us, mark
            ));
        }
        out.push_str(&format!(
            "  {:<44} {:>12.0} cyc {:>10.2} us\n",
            "total fill latency", self.total_cycles, self.total_us
        ));
        out
    }
}

/// Build the modeled critical path of a partitioned pipeline.
pub fn model_critical_path(pfw: &PartitionedFirmware, model: &EngineModel) -> ModelCriticalPath {
    let rep = analyze_pipeline(pfw, model);
    let freq_hz = pfw.partitions[0].device.freq_ghz * 1e9;
    let to_us = |c: f64| c / freq_hz * 1e6;
    let mut steps = Vec::with_capacity(rep.partitions.len() + rep.links.len());
    for (i, p) in rep.partitions.iter().enumerate() {
        steps.push(ModelPathStep {
            name: format!("array {i}: {} ({} layers, {} tiles)", p.name, p.layers, p.tiles),
            is_link: false,
            cycles: p.latency_cycles,
            us: to_us(p.latency_cycles),
            bottleneck: p.interval_cycles == rep.interval_cycles,
        });
        if let Some(l) = rep.links.get(i) {
            let kind = if l.staged { "staged" } else { "offset-tiled" };
            steps.push(ModelPathStep {
                name: format!("link {i}->{}: {} B {kind}", i + 1, l.bytes),
                is_link: true,
                cycles: l.cycles,
                us: to_us(l.cycles),
                bottleneck: l.cycles == rep.interval_cycles,
            });
        }
    }
    ModelCriticalPath {
        steps,
        total_cycles: rep.latency_cycles,
        total_us: rep.latency_us,
        interval_cycles: rep.interval_cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::CompileConfig;
    use crate::harness::models::{mlp_spec, synth_model};
    use crate::partition::{compile_partitioned, PartitionOptions};

    fn cfg(batch: usize) -> CompileConfig {
        let mut c = CompileConfig::default();
        c.batch = batch;
        c
    }

    #[test]
    fn k1_report_matches_engine_analyze() {
        let json = synth_model("pipe_k1", &mlp_spec(&[128, 128, 64], crate::arch::Dtype::I8), 6);
        let mut c = cfg(16);
        c.tiles_per_layer = Some(4);
        let pm = compile_partitioned(&json, c.clone(), &PartitionOptions::default()).unwrap();
        assert_eq!(pm.firmware.k(), 1);
        let pipe = analyze_pipeline(&pm.firmware, &EngineModel::default());
        let plain = analyze(&pm.firmware.partitions[0], &EngineModel::default());
        assert_eq!(pipe.interval_cycles, plain.interval_cycles);
        assert_eq!(pipe.latency_cycles, plain.latency_cycles);
        assert_eq!(pipe.link_cycles, 0.0);
    }

    #[test]
    fn deeper_pipelines_trade_latency_for_interval() {
        // Re-balancing layers over more arrays gives every layer more
        // tiles, so the bottleneck stage (interval) shrinks while the fill
        // path (latency) picks up link hops. Wide layers + a real batch
        // keep the arrays compute-bound, so the inter-array link is not
        // the pipeline bottleneck at K = 2.
        let json = synth_model("pipe_scale", &mlp_spec(&[512; 8], crate::arch::Dtype::I8), 6);
        let k1 = compile_partitioned(
            &json,
            cfg(64),
            &PartitionOptions { partitions: Some(1), ..Default::default() },
        )
        .unwrap();
        let k2 = compile_partitioned(
            &json,
            cfg(64),
            &PartitionOptions { partitions: Some(2), ..Default::default() },
        )
        .unwrap();
        let r1 = analyze_pipeline(&k1.firmware, &EngineModel::default());
        let r2 = analyze_pipeline(&k2.firmware, &EngineModel::default());
        assert_eq!(r2.k, 2);
        assert!(r2.link_cycles > 0.0);
        assert!(
            r2.interval_cycles <= r1.interval_cycles,
            "K=2 interval {} vs K=1 {}",
            r2.interval_cycles,
            r1.interval_cycles
        );
        assert!(r2.throughput_tops >= r1.throughput_tops);
        // Per-partition rows cover every array.
        assert_eq!(r2.partitions.len(), 2);
        assert!(r2.bottleneck_partition().is_some());
        // Per-link rows: one per wire, cycles summing to link_cycles.
        assert_eq!(r2.links.len(), 1);
        assert_eq!(r2.links[0].from, 0);
        assert!(r2.links[0].bytes > 0);
        assert!((r2.links.iter().map(|l| l.cycles).sum::<f64>() - r2.link_cycles).abs() < 1e-9);
    }

    #[test]
    fn model_critical_path_partitions_the_fill_latency() {
        let json = synth_model("pipe_cp", &mlp_spec(&[256; 6], crate::arch::Dtype::I8), 6);
        let pm = compile_partitioned(
            &json,
            cfg(32),
            &PartitionOptions { partitions: Some(2), ..Default::default() },
        )
        .unwrap();
        let cp = model_critical_path(&pm.firmware, &EngineModel::default());
        // Two arrays plus the one wire between them, in pipeline order.
        assert_eq!(cp.steps.len(), 3);
        assert!(!cp.steps[0].is_link && cp.steps[1].is_link && !cp.steps[2].is_link);
        // The steps partition the fill latency exactly.
        let sum: f64 = cp.steps.iter().map(|s| s.cycles).sum();
        assert!((sum - cp.total_cycles).abs() < 1e-6, "steps {} vs total {}", sum, cp.total_cycles);
        let rep = analyze_pipeline(&pm.firmware, &EngineModel::default());
        assert_eq!(cp.total_cycles, rep.latency_cycles);
        assert_eq!(cp.interval_cycles, rep.interval_cycles);
        // Exactly the interval-bounding step(s) are marked.
        assert!(cp.steps.iter().any(|s| s.bottleneck));
        let text = cp.render();
        assert!(text.contains("total fill latency"));
        assert!(text.contains("interval bottleneck"));
    }
}
