//! Multi-array model partitioner: shard a DAG model into pipelined
//! partitions when it exceeds one AIE-ML array (or when the user asks for
//! a fixed pipeline depth for throughput).
//!
//! One VEK280 tops out at 296 placeable tiles and ~19 MiB of memory-tile
//! SRAM; production models and throughput targets outgrow both. This
//! module slices the model's layer DAG at *single-tensor* synchronization
//! points ([`cut::cut_candidates`]), balances the slices with a
//! compile-in-the-loop bottleneck DP ([`cut::choose_cuts`]) scored by each
//! slice's *modeled interval* (candidate slices are compiled through the
//! real pipeline, memoized in the content-addressed
//! [`crate::cache::FirmwareCache`]), and compiles each chosen slice
//! through the full 7-pass pipeline — so tiling, mem-tile planning and
//! the Eq. 2 branch-and-bound placement are re-optimized *per array*. Cut edges turn
//! interior nodes into partition outputs (drained through the multi-sink
//! output machinery via `CompileConfig::extra_outputs`), and each cut
//! becomes a typed [`PartitionLink`]: the upstream firmware names which of
//! its output drains feeds the downstream array's input, with width and
//! quantization carried along.
//!
//! Execution semantics are unchanged: [`execute_partitioned`] runs the
//! arrays back-to-back and is bit-exact with the unpartitioned model (the
//! link hop is a pure row-major store/load). Steady-state behaviour is a
//! K-stage pipeline — interval = slowest partition (or link), latency =
//! sum of partition fills plus link hops — modeled by
//! [`pipeline::analyze_pipeline`] and driven for real by
//! [`crate::coordinator::PipelineServer`].

pub mod cut;
pub mod pipeline;

use crate::cache::FirmwareCache;
use crate::codegen::firmware::{Firmware, StageRef, StageSource};
use crate::frontend::{CompileConfig, JsonModel};
use crate::ir::QuantSpec;
use crate::passes::Model;
use crate::sim::dma::OffsetTiler;
use crate::sim::functional::{execute_all, Activation};
use anyhow::{bail, ensure, Context, Result};

pub use cut::{
    choose_cuts, choose_cuts_by_macs, choose_cuts_explained, cut_candidates, CutCandidate, CutPlan,
};
pub use pipeline::{
    analyze_pipeline, model_critical_path, pipeline_total_hops, LinkPerf, ModelCriticalPath,
    ModelPathStep, PartitionPerf, PipelinePerfReport,
};

/// How to partition.
#[derive(Debug, Clone)]
pub struct PartitionOptions {
    /// Explicit partition count, or `None` to search for the smallest K
    /// whose partitions all compile on one array each.
    pub partitions: Option<usize>,
    /// Largest K the auto search tries.
    pub max_partitions: usize,
}

impl Default for PartitionOptions {
    fn default() -> Self {
        PartitionOptions { partitions: None, max_partitions: 8 }
    }
}

/// A typed inter-partition edge: which output drain of partition `i`
/// feeds partition `i + 1`'s network input.
#[derive(Debug, Clone)]
pub struct PartitionLink {
    /// Index into the upstream partition's `Firmware::outputs`.
    pub from_output: usize,
    /// Name of the crossing tensor (the producing layer).
    pub tensor: String,
    /// Activation width crossing the link.
    pub features: usize,
    /// Quantization of the crossing activation.
    pub quant: QuantSpec,
    /// Offset tiler landing the crossing activation directly in the
    /// downstream array's {M, K} read-tile input buffer (mirrored onto the
    /// upstream drain's [`crate::codegen::firmware::FirmwareOutput`]).
    /// `None` when the downstream input fans out to several readers — the
    /// link then lands row-major and stages, as before.
    pub write_tiler: Option<OffsetTiler>,
}

/// One final model output, located in whichever partition produced it.
#[derive(Debug, Clone)]
pub struct PipelineOutput {
    /// Partition index holding the producing sink.
    pub partition: usize,
    /// Index into that partition's `Firmware::outputs`.
    pub output: usize,
    /// Sink layer name.
    pub name: String,
}

/// The compiled multi-array artifact: one [`Firmware`] per partition plus
/// the typed links wiring them into a linear pipeline. `links[i]` connects
/// partition `i` to `i + 1`; `outputs` lists the original model's sinks in
/// frontend layer order, each resolved to the partition that produces it.
#[derive(Debug, Clone)]
pub struct PartitionedFirmware {
    pub model_name: String,
    pub partitions: Vec<Firmware>,
    pub links: Vec<PartitionLink>,
    pub outputs: Vec<PipelineOutput>,
}

impl PartitionedFirmware {
    /// Wrap a plain single-array compile as the degenerate K = 1 pipeline:
    /// no links, and every firmware output surfaces as a final pipeline
    /// output in drain order. The firmware bytes are untouched — this is
    /// exactly what `compile_partitioned` produces for a model that fits
    /// one array.
    pub fn from_single(fw: Firmware) -> PartitionedFirmware {
        let outputs = fw
            .outputs
            .iter()
            .enumerate()
            .map(|(i, o)| PipelineOutput { partition: 0, output: i, name: o.name.clone() })
            .collect();
        PartitionedFirmware {
            model_name: fw.model_name.clone(),
            partitions: vec![fw],
            links: Vec::new(),
            outputs,
        }
    }

    /// Pipeline depth (number of arrays).
    pub fn k(&self) -> usize {
        self.partitions.len()
    }

    /// Compute tiles used summed over every array.
    pub fn tiles_used(&self) -> usize {
        self.partitions.iter().map(|p| p.tiles_used()).sum()
    }

    /// Total MACs per sample across the pipeline.
    pub fn macs_per_sample(&self) -> usize {
        self.partitions.iter().map(|p| p.macs_per_sample()).sum()
    }

    /// Steady-state batch every partition is specialized to.
    pub fn batch(&self) -> usize {
        self.partitions[0].batch
    }

    /// Network input width (partition 0's input).
    pub fn input_features(&self) -> usize {
        self.partitions[0].input_features()
    }

    /// Feature count of final output `i` (index into `outputs`).
    pub fn output_features_of(&self, i: usize) -> usize {
        let o = &self.outputs[i];
        self.partitions[o.partition].output_features_of(o.output)
    }

    /// The same pipeline with every offset tiler stripped — the legacy
    /// **staged** data path (row-major link landings and merge buffers),
    /// bit-exact with the tiled pipeline; benches and tests use it for
    /// staged-vs-offset comparisons of the performance/routing models.
    pub fn staged_variant(&self) -> PartitionedFirmware {
        let mut p = self.clone();
        p.partitions = p.partitions.iter().map(Firmware::staged_variant).collect();
        for l in &mut p.links {
            l.write_tiler = None;
        }
        p
    }

    /// Sanity invariants over the assembled pipeline.
    pub fn check_invariants(&self) -> Result<()> {
        ensure!(!self.partitions.is_empty(), "pipeline has no partitions");
        ensure!(
            self.links.len() + 1 == self.partitions.len(),
            "{} links for {} partitions",
            self.links.len(),
            self.partitions.len()
        );
        ensure!(!self.outputs.is_empty(), "pipeline has no final outputs");
        let batch = self.batch();
        for (i, fw) in self.partitions.iter().enumerate() {
            fw.check_invariants()?;
            ensure!(fw.batch == batch, "partition {i} batch {} != {batch}", fw.batch);
        }
        for (i, link) in self.links.iter().enumerate() {
            let up = &self.partitions[i];
            let down = &self.partitions[i + 1];
            ensure!(
                link.from_output < up.outputs.len(),
                "link {i}: output index {} out of range",
                link.from_output
            );
            ensure!(
                up.output_features_of(link.from_output) == down.input_features(),
                "link {i} ('{}'): {} features into a {}-feature input",
                link.tensor,
                up.output_features_of(link.from_output),
                down.input_features()
            );
            ensure!(
                link.quant.dtype == down.input_quant.dtype,
                "link {i} ('{}'): dtype {} into {} input",
                link.tensor,
                link.quant.dtype,
                down.input_quant.dtype
            );
            if let Some(t) = &link.write_tiler {
                ensure!(
                    t.offset == 0 && t.stride == down.input_features(),
                    "link {i} ('{}'): landing tiler band ({}, {}) does not cover the \
                     downstream {}-feature input",
                    link.tensor,
                    t.offset,
                    t.stride,
                    down.input_features()
                );
                ensure!(
                    up.outputs[link.from_output].write_tiler.as_ref() == Some(t),
                    "link {i} ('{}'): upstream drain tiler diverged from the link tiler",
                    link.tensor
                );
            }
        }
        for o in &self.outputs {
            ensure!(o.partition < self.partitions.len(), "output '{}' partition oob", o.name);
            ensure!(
                o.output < self.partitions[o.partition].outputs.len(),
                "output '{}' index oob",
                o.name
            );
        }
        Ok(())
    }
}

/// Result of a partitioned compile: the assembled pipeline firmware plus
/// the per-partition [`Model`]s (placement reports etc. intact).
pub struct PartitionedModel {
    pub firmware: PartitionedFirmware,
    pub models: Vec<Model>,
    /// The chosen cut positions (`after` layer indices) in the original model.
    pub cuts: Vec<usize>,
}

/// One sub-model produced by [`split_model`].
struct SubModel {
    model: JsonModel,
    /// Crossing tensor this partition must drain for the next one.
    link_tensor: Option<String>,
}

/// Build the contiguous sub-model covering `layers[lo..=hi]` under `name`,
/// with `incoming` (the tensor crossing the upstream cut, if any) renamed
/// to `"input"`. Layer payloads, quantizers and per-layer names are
/// preserved, so per-layer config overrides keep applying.
///
/// Shared by [`split_model`] and the cut DP ([`cut::choose_cuts`]): both
/// must produce *identical* slice content, so the DP's candidate compiles
/// are content-addressed cache hits when the chosen partitioning compiles
/// for real.
pub(crate) fn slice_submodel(
    json: &JsonModel,
    incoming: Option<&str>,
    lo: usize,
    hi: usize,
    name: &str,
) -> Result<JsonModel> {
    let index_of = |name: &str| json.layers.iter().position(|l| l.name == name);
    let mut layers = Vec::with_capacity(hi - lo + 1);
    for g in lo..=hi {
        let mut l = json.layers[g].clone();
        if !l.inputs.is_empty() {
            for src in &mut l.inputs {
                if Some(src.as_str()) == incoming {
                    *src = "input".to_string();
                } else if src != "input" {
                    let p = index_of(src)
                        .with_context(|| format!("layer '{}' reads unknown '{src}'", l.name))?;
                    ensure!(
                        (lo..g).contains(&p),
                        "cut after layer {} severs edge '{}' -> '{}' (not the link tensor)",
                        lo.saturating_sub(1),
                        src,
                        l.name
                    );
                } else {
                    ensure!(
                        incoming.is_none(),
                        "layer '{}' reads the raw network input across a cut",
                        l.name
                    );
                }
            }
        }
        layers.push(l);
    }
    let mut model = JsonModel::new(name, layers);
    model.device = json.device.clone();
    Ok(model)
}

/// The per-slice compile config: keep any user-requested extra drains that
/// live in this slice (a drain can only land in the partition that owns
/// the layer), and add the link tensor on top. Shared by [`try_k`] and the
/// cut DP for the same cache-identity reason as [`slice_submodel`].
pub(crate) fn slice_config(
    cfg: &CompileConfig,
    model: &JsonModel,
    link_tensor: Option<&str>,
) -> CompileConfig {
    let mut sub = cfg.clone();
    sub.extra_outputs.retain(|name| model.layers.iter().any(|l| &l.name == name));
    if let Some(t) = link_tensor {
        if !sub.extra_outputs.iter().any(|x| x == t) {
            sub.extra_outputs.push(t.to_string());
        }
    }
    sub
}

/// Slice `json` at the chosen cut positions into K sub-models. Each cut's
/// crossing tensor becomes the upstream sub-model's extra output and the
/// downstream sub-model's network input (references renamed to
/// `"input"`).
fn split_model(
    json: &JsonModel,
    candidates: &[CutCandidate],
    cuts: &[usize],
) -> Result<Vec<SubModel>> {
    let tensor_of = |after: usize| -> Result<&str> {
        candidates
            .iter()
            .find(|c| c.after == after)
            .map(|c| c.tensor.as_str())
            .with_context(|| format!("cut after layer {after} is not a legal cut point"))
    };
    let index_of = |name: &str| json.layers.iter().position(|l| l.name == name);
    let mut subs = Vec::with_capacity(cuts.len() + 1);
    let mut lo = 0usize;
    for i in 0..=cuts.len() {
        let hi = if i < cuts.len() { cuts[i] } else { json.layers.len() - 1 };
        ensure!(lo <= hi, "cut positions must be strictly increasing");
        // The tensor entering this partition (renamed to "input" inside).
        let incoming: Option<&str> = if i == 0 { None } else { Some(tensor_of(cuts[i - 1])?) };
        // K = 1 keeps the original model name (it *is* the original model);
        // real slices are suffixed with their pipeline position.
        let sub_name =
            if cuts.is_empty() { json.name.clone() } else { format!("{}.p{i}", json.name) };
        let model = slice_submodel(json, incoming, lo, hi, &sub_name)?;
        let link_tensor = if i < cuts.len() {
            let t = tensor_of(cuts[i])?;
            let p = index_of(t).context("link tensor names no layer")?;
            ensure!(
                (lo..=hi).contains(&p),
                "link tensor '{t}' is not produced inside partition {i} \
                 (an intermediate partition produces nothing the pipeline consumes)"
            );
            Some(t.to_string())
        } else {
            None
        };
        subs.push(SubModel { model, link_tensor });
        lo = hi + 1;
    }
    Ok(subs)
}

/// The offset tiler landing an inter-partition link directly in `down`'s
/// {M, K} read-tile input buffer: available when exactly one dense layer
/// reads the downstream network input (its tiling defines the read blocks).
/// Several readers — a merge reading the raw input, or a conv layer (whose
/// patch walk needs the row-major image, not GEMM tiles) — keep the legacy
/// row-major landing (`None`).
pub(crate) fn link_landing_tiler(down: &Firmware) -> Option<OffsetTiler> {
    let mut fed: Option<usize> = None;
    for s in &down.stages {
        if s.inputs.contains(&StageSource::Input) {
            match s.op {
                StageRef::Layer(li) if fed.is_none() => fed = Some(li),
                _ => return None,
            }
        }
    }
    let l = &down.layers[fed?];
    if l.input_plan.patch.is_some() {
        return None;
    }
    Some(OffsetTiler::new(0, down.in_features, l.tiling.m, l.tiling.k))
}

/// Compile one partitioning attempt at a fixed K.
fn try_k(
    json: &JsonModel,
    cfg: &CompileConfig,
    candidates: &[CutCandidate],
    k: usize,
    cache: &FirmwareCache,
) -> Result<PartitionedModel> {
    let cuts = choose_cuts(json, cfg, candidates, k, cache)?;
    compile_partitioned_at(json, cfg, candidates, &cuts, cache)
}

/// Compile `json` at an explicit set of cut positions (each must be a
/// legal [`CutCandidate`] boundary). This is the assembly half of
/// [`compile_partitioned`] without the cut search — benches and tests use
/// it to compare cut policies (e.g. interval-balanced vs MAC-balanced) on
/// identical machinery, and the cut DP's slice compiles make the chosen
/// partitioning's compiles here cache hits.
pub fn compile_partitioned_at(
    json: &JsonModel,
    cfg: &CompileConfig,
    candidates: &[CutCandidate],
    cuts: &[usize],
    cache: &FirmwareCache,
) -> Result<PartitionedModel> {
    let subs = split_model(json, candidates, cuts)?;
    let mut models = Vec::with_capacity(subs.len());
    for (i, sub) in subs.iter().enumerate() {
        let sub_cfg = slice_config(cfg, &sub.model, sub.link_tensor.as_deref());
        let model = cache
            .compile(&sub.model, sub_cfg)
            .with_context(|| format!("partition {i} ('{}')", sub.model.name))?;
        models.push(model);
    }
    let mut partitions: Vec<Firmware> = models
        .iter()
        .map(|m| m.firmware.clone().context("partition compiled without firmware"))
        .collect::<Result<_>>()?;
    // Typed links: resolve each crossing tensor to its drain index.
    let mut links = Vec::with_capacity(subs.len().saturating_sub(1));
    for (i, sub) in subs.iter().enumerate().take(subs.len() - 1) {
        let tensor = sub.link_tensor.as_ref().context("non-final partition without a link")?;
        let fw = &partitions[i];
        let from_output = fw
            .outputs
            .iter()
            .position(|o| &o.name == tensor)
            .with_context(|| format!("partition {i} does not drain link tensor '{tensor}'"))?;
        links.push(PartitionLink {
            from_output,
            tensor: tensor.clone(),
            features: fw.output_features_of(from_output),
            quant: fw.stage_quant(fw.outputs[from_output].stage),
            write_tiler: None,
        });
    }
    // Offset-tile the links: each crossing activation lands straight in
    // the downstream array's {M, K} read-tile input buffer (when a single
    // dense layer reads it), so the link never stages row-major. The same
    // tiler is stamped onto the upstream drain — both the pipeline's copy
    // and the per-partition `Model`'s firmware, so serializing either view
    // carries the landing descriptor.
    for (i, link) in links.iter_mut().enumerate() {
        if let Some(t) = link_landing_tiler(&partitions[i + 1]) {
            link.write_tiler = Some(t);
            partitions[i].outputs[link.from_output].write_tiler = Some(t);
            if let Some(fw) = models[i].firmware.as_mut() {
                fw.outputs[link.from_output].write_tiler = Some(t);
            }
        }
    }
    // Final model outputs: the original sinks, wherever they landed.
    let mut outputs = Vec::new();
    for name in json.sink_names() {
        let mut found = None;
        for (pi, fw) in partitions.iter().enumerate() {
            if let Some(oi) = fw.outputs.iter().position(|o| o.name == name) {
                found = Some(PipelineOutput { partition: pi, output: oi, name: name.clone() });
                break;
            }
        }
        outputs.push(found.with_context(|| format!("model output '{name}' drained nowhere"))?);
    }
    let firmware = PartitionedFirmware {
        model_name: json.name.clone(),
        partitions,
        links,
        outputs,
    };
    firmware.check_invariants()?;
    Ok(PartitionedModel { firmware, models, cuts: cuts.to_vec() })
}

/// Compile `json` into a pipelined multi-array deployment.
///
/// With `opts.partitions = Some(k)` the model is cut into exactly `k`
/// partitions (error if impossible). In auto mode the smallest K whose
/// partitions *all* compile within one array each is chosen — K = 1 is the
/// plain single-array compile, so models that fit produce a degenerate
/// one-partition pipeline with identical firmware.
pub fn compile_partitioned(
    json: &JsonModel,
    cfg: CompileConfig,
    opts: &PartitionOptions,
) -> Result<PartitionedModel> {
    compile_partitioned_with(json, cfg, opts, &FirmwareCache::new())
}

/// [`compile_partitioned`] against a caller-owned firmware cache: the cut
/// DP's slice compiles, the auto-K search's repeated slices and any later
/// re-plan of the same model all hit the cache instead of re-running the
/// pass pipeline. The deploy planner and autoscaler thread one cache
/// through their whole candidate sweep.
pub fn compile_partitioned_with(
    json: &JsonModel,
    cfg: CompileConfig,
    opts: &PartitionOptions,
    cache: &FirmwareCache,
) -> Result<PartitionedModel> {
    json.validate()?;
    let candidates = cut_candidates(json);
    let ks: Vec<usize> = match opts.partitions {
        Some(0) => bail!("partition count must be positive"),
        Some(k) => vec![k],
        None => (1..=opts.max_partitions.max(1)).collect(),
    };
    let mut last_err: Option<anyhow::Error> = None;
    for k in ks {
        match try_k(json, &cfg, &candidates, k, cache) {
            Ok(pm) => return Ok(pm),
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err
        .unwrap_or_else(|| anyhow::anyhow!("no partition count attempted"))
        .context(format!(
            "model '{}' does not fit {} (tried up to {} partitions)",
            json.name,
            cfg.device,
            opts.max_partitions.max(1)
        )))
}

/// Execute the pipeline end to end on one batch and return the final model
/// outputs (sink order). Bit-exact with the unpartitioned model: the link
/// hop is a row-major store/load of an already-quantized activation.
pub fn execute_partitioned(
    pfw: &PartitionedFirmware,
    input: &Activation,
) -> Result<Vec<Activation>> {
    let mut finals: Vec<Option<Activation>> = vec![None; pfw.outputs.len()];
    let mut carry: Option<Activation> = None;
    for (i, fw) in pfw.partitions.iter().enumerate() {
        let _stage = crate::obs::tracer()
            .span("serve", "stage")
            .with_arg("partition", i)
            .with_arg("tiles", fw.stages.len());
        let x = carry.as_ref().unwrap_or(input);
        let mut outs = execute_all(fw, x)?;
        for (slot, o) in pfw.outputs.iter().enumerate() {
            if o.partition == i {
                finals[slot] = Some(outs[o.output].clone());
            }
        }
        if i + 1 < pfw.partitions.len() {
            carry = Some(outs.swap_remove(pfw.links[i].from_output));
        }
    }
    finals
        .into_iter()
        .enumerate()
        .map(|(i, o)| o.with_context(|| format!("output '{}' never produced", pfw.outputs[i].name)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::models::{diamond_mlp_model, mlp_spec, residual_mlp_model, synth_model};
    use crate::passes::compile;
    use crate::runtime::ReferenceOracle;
    use crate::util::Pcg32;

    fn cfg(batch: usize, tiles: usize) -> CompileConfig {
        let mut c = CompileConfig::default();
        c.batch = batch;
        c.tiles_per_layer = Some(tiles);
        c
    }

    fn random_input(features: usize, batch: usize, seed: u64) -> Activation {
        let mut rng = Pcg32::seed_from_u64(seed);
        Activation::new(
            batch,
            features,
            (0..batch * features).map(|_| rng.gen_i32_in(-128, 127)).collect(),
        )
        .unwrap()
    }

    #[test]
    fn k1_wraps_the_plain_compile() {
        let json = synth_model("part_k1", &mlp_spec(&[64, 48, 16], crate::arch::Dtype::I8), 6);
        let pm = compile_partitioned(&json, cfg(4, 2), &PartitionOptions::default()).unwrap();
        assert_eq!(pm.firmware.k(), 1);
        assert!(pm.cuts.is_empty());
        assert!(pm.firmware.links.is_empty());
        // Degenerate pipeline executes exactly like the plain firmware.
        let plain = compile(&json, cfg(4, 2)).unwrap().firmware.unwrap();
        let x = random_input(64, 4, 1);
        let got = execute_partitioned(&pm.firmware, &x).unwrap();
        let want = crate::sim::functional::execute(&plain, &x).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].data, want.data);
    }

    #[test]
    fn explicit_k2_chain_is_bit_exact() {
        let json = synth_model("part_k2", &mlp_spec(&[96, 64, 48, 32], crate::arch::Dtype::I8), 6);
        let opts = PartitionOptions { partitions: Some(2), ..Default::default() };
        let pm = compile_partitioned(&json, cfg(6, 2), &opts).unwrap();
        assert_eq!(pm.firmware.k(), 2);
        assert_eq!(pm.firmware.links.len(), 1);
        let x = random_input(96, 6, 7);
        let got = execute_partitioned(&pm.firmware, &x).unwrap();
        let oracle = ReferenceOracle::from_model(&json).unwrap();
        let want = oracle.execute(&x).unwrap();
        assert_eq!(got[0].data, want.data);
        // The link is typed: width and dtype of the crossing tensor.
        let link = &pm.firmware.links[0];
        assert_eq!(link.features, pm.firmware.partitions[1].input_features());
        assert_eq!(link.quant.dtype, pm.firmware.partitions[1].input_quant.dtype);
    }

    #[test]
    fn residual_dag_partitions_after_the_merge() {
        let json = residual_mlp_model("part_res", 64, 96, 16, 6);
        let opts = PartitionOptions { partitions: Some(2), ..Default::default() };
        let pm = compile_partitioned(&json, cfg(4, 2), &opts).unwrap();
        assert_eq!(pm.cuts, vec![2]); // the only legal cut: after the merge
        assert_eq!(pm.firmware.links[0].tensor, "res");
        let x = random_input(64, 4, 3);
        let got = execute_partitioned(&pm.firmware, &x).unwrap();
        let want = ReferenceOracle::from_model(&json).unwrap().execute(&x).unwrap();
        assert_eq!(got[0].data, want.data);
    }

    #[test]
    fn diamond_k3_is_bit_exact() {
        let json = diamond_mlp_model("part_dia", 48, 48, 8, 6);
        let opts = PartitionOptions { partitions: Some(3), ..Default::default() };
        let pm = compile_partitioned(&json, cfg(4, 2), &opts).unwrap();
        assert_eq!(pm.firmware.k(), 3);
        let x = random_input(48, 4, 9);
        let got = execute_partitioned(&pm.firmware, &x).unwrap();
        let want = ReferenceOracle::from_model(&json).unwrap().execute(&x).unwrap();
        assert_eq!(got[0].data, want.data);
    }

    #[test]
    fn stranded_multi_sink_head_drains_from_its_partition() {
        // trunk -> {head_a, head_b}; cut after head_a strands it upstream:
        // the final outputs still surface in model sink order, head_a from
        // partition 0 and head_b from partition 1, and `trunk` is drained
        // as an *interior* extra output feeding the link.
        use crate::frontend::JsonLayer;
        let mut r = Pcg32::seed_from_u64(0xFA7);
        let mut dense = |name: &str, fin: usize, fout: usize| {
            let w: Vec<i32> = (0..fin * fout).map(|_| r.gen_i32_in(-128, 127)).collect();
            JsonLayer::dense(name, fin, fout, false, false, "int8", "int8", 6, w, vec![])
        };
        // head_b is by far the heaviest layer, so the balanced 2-way cut
        // lands *after* head_a — stranding it upstream and forcing `trunk`
        // (consumed by head_a inside partition 0) to drain as an interior
        // extra output feeding the link.
        let json = JsonModel::new(
            "strand",
            vec![
                dense("trunk", 16, 16),
                dense("head_a", 16, 16).with_inputs(&["trunk"]),
                dense("head_b", 16, 256).with_inputs(&["trunk"]),
            ],
        );
        let candidates = cut_candidates(&json);
        assert_eq!(candidates.len(), 2);
        let subs = split_model(&json, &candidates, &[1]).unwrap();
        assert_eq!(subs[0].link_tensor.as_deref(), Some("trunk"));
        let opts = PartitionOptions { partitions: Some(2), ..Default::default() };
        let pm = compile_partitioned(&json, cfg(4, 1), &opts).unwrap();
        assert_eq!(pm.cuts, vec![1]);
        // Partition 0 drains the interior trunk (the link) plus head_a.
        assert_eq!(pm.firmware.partitions[0].output_names(), vec!["trunk", "head_a"]);
        assert_eq!(pm.firmware.links[0].tensor, "trunk");
        let names: Vec<&str> = pm.firmware.outputs.iter().map(|o| o.name.as_str()).collect();
        assert_eq!(names, vec!["head_a", "head_b"]);
        let x = random_input(16, 4, 5);
        let got = execute_partitioned(&pm.firmware, &x).unwrap();
        let want = ReferenceOracle::from_model(&json).unwrap().execute_all(&x).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].data, want[0].data);
        assert_eq!(got[1].data, want[1].data);
    }

    #[test]
    fn from_single_wraps_plain_firmware_unchanged() {
        let json = synth_model("part_wrap", &mlp_spec(&[48, 32, 8], crate::arch::Dtype::I8), 6);
        let plain = compile(&json, cfg(4, 2)).unwrap().firmware.unwrap();
        let pfw = PartitionedFirmware::from_single(plain.clone());
        pfw.check_invariants().unwrap();
        assert_eq!(pfw.k(), 1);
        assert!(pfw.links.is_empty());
        assert_eq!(pfw.outputs.len(), plain.outputs.len());
        let x = random_input(48, 4, 11);
        let got = execute_partitioned(&pfw, &x).unwrap();
        let want = crate::sim::functional::execute(&plain, &x).unwrap();
        assert_eq!(got[0].data, want.data);
    }

    #[test]
    fn impossible_k_rejected() {
        let json = synth_model("part_bad", &mlp_spec(&[32, 16], crate::arch::Dtype::I8), 6);
        let opts = PartitionOptions { partitions: Some(3), ..Default::default() };
        assert!(compile_partitioned(&json, cfg(2, 1), &opts).is_err());
    }
}
