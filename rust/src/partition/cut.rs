//! Cut search: where a model's layer DAG may be sliced into pipeline
//! partitions, and which cuts balance the pipeline best.
//!
//! Layers in the exporter JSON are listed in a valid topological order
//! (every `inputs` entry names an *earlier* layer), so a partition is a
//! contiguous run of layers and a cut is a position between two layers.
//! A position qualifies as a [`CutCandidate`] only when exactly **one**
//! tensor is live across it — the single value produced at or before the
//! cut that any later layer still reads. That tensor becomes the typed
//! inter-partition link: the upstream partition drains it through an
//! output buffer (multi-sink emission), the downstream partition ingests
//! it as its network input. Residual skips therefore cut *after* their
//! merge, never inside the skip window, and a diamond cuts before its
//! fan-out or after its fan-in — exactly the synchronization points where
//! an array-to-array hop is physically a single stream.
//!
//! [`choose_cuts`] picks `k − 1` candidates minimizing the heaviest
//! partition (MACs as the stage-time proxy), the pipeline analog of the
//! Eq. 2 objective: steady-state interval is governed by the slowest
//! partition, so the bottleneck weight is what the search must flatten.
//! Each partition is then compiled with the full pass pipeline, so the
//! Eq. 2 placement objective is re-optimized per partition.

use crate::frontend::JsonModel;
use anyhow::{bail, Result};
use std::collections::BTreeSet;

/// One legal cut position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CutCandidate {
    /// The cut sits after `layers[after]` (0-based layer index).
    pub after: usize,
    /// Name of the single tensor crossing the cut (the link tensor).
    pub tensor: String,
}

/// Enumerate every legal cut position. A position `after` qualifies when:
/// exactly one layer-produced tensor is consumed across it, the raw
/// network input is not read beyond it, and the first downstream layer is
/// dense (it becomes the downstream partition's input-consuming layer).
/// Liveness is computed over [`JsonModel::effective_inputs`] — the same
/// wiring rule `to_graph` connects, so a legal cut here is a legal cut in
/// the compiled graph.
pub fn cut_candidates(json: &JsonModel) -> Vec<CutCandidate> {
    let inputs = json.effective_inputs();
    let n = json.layers.len();
    let index_of = |name: &str| json.layers.iter().position(|l| l.name == name);
    let mut out = Vec::new();
    for after in 0..n.saturating_sub(1) {
        // Tensors produced at or before the cut but read after it.
        let mut crossing: BTreeSet<&str> = BTreeSet::new();
        let mut input_crosses = false;
        for consumer in after + 1..n {
            for src in &inputs[consumer] {
                if src == "input" {
                    input_crosses = true;
                } else if index_of(src).map(|p| p <= after).unwrap_or(false) {
                    crossing.insert(src.as_str());
                }
            }
        }
        if input_crosses || crossing.len() != 1 {
            continue;
        }
        if json.layers[after + 1].ty != "dense" {
            continue; // the downstream partition's first layer must be dense
        }
        out.push(CutCandidate {
            after,
            tensor: (*crossing.iter().next().unwrap()).to_string(),
        });
    }
    out
}

/// MACs per layer (merge layers are free), the per-partition weight the
/// balance objective sums.
fn layer_macs(json: &JsonModel) -> Vec<u64> {
    json.layers
        .iter()
        .map(|l| {
            if l.ty == "dense" {
                (l.in_features * l.out_features) as u64
            } else {
                0
            }
        })
        .collect()
}

/// Choose `k - 1` cut positions (a subset of `candidates`) minimizing the
/// heaviest partition's MAC weight — the pipeline bottleneck. Returns the
/// chosen `after` indices in ascending order. Classic contiguous-partition
/// DP over the candidate boundaries (tiny inputs; exactness is free).
pub fn choose_cuts(json: &JsonModel, candidates: &[CutCandidate], k: usize) -> Result<Vec<usize>> {
    let n = json.layers.len();
    if k == 0 {
        bail!("cannot partition into zero partitions");
    }
    if k == 1 {
        return Ok(Vec::new());
    }
    if candidates.len() < k - 1 {
        bail!(
            "model '{}' has {} legal cut points; {} partitions need {}",
            json.name,
            candidates.len(),
            k,
            k - 1
        );
    }
    let macs = layer_macs(json);
    let prefix: Vec<u64> = std::iter::once(0)
        .chain(macs.iter().scan(0u64, |acc, &m| {
            *acc += m;
            Some(*acc)
        }))
        .collect();
    // Segment weight between boundary positions (exclusive layer ranges):
    // boundaries are "after layer b" cut points plus the virtual ends
    // before layer 0 and after layer n-1.
    let bounds: Vec<usize> = std::iter::once(0)
        .chain(candidates.iter().map(|c| c.after + 1))
        .chain(std::iter::once(n))
        .collect();
    let seg = |a: usize, b: usize| prefix[bounds[b]] - prefix[bounds[a]];
    let m = bounds.len() - 1; // number of atomic segments
    // dp[j][i]: minimal bottleneck splitting segments 0..i into j parts.
    let mut dp = vec![vec![u64::MAX; m + 1]; k + 1];
    let mut back = vec![vec![0usize; m + 1]; k + 1];
    for i in 1..=m {
        dp[1][i] = seg(0, i);
    }
    for j in 2..=k {
        for i in j..=m {
            for split in j - 1..i {
                if dp[j - 1][split] == u64::MAX {
                    continue;
                }
                let cost = dp[j - 1][split].max(seg(split, i));
                if cost < dp[j][i] {
                    dp[j][i] = cost;
                    back[j][i] = split;
                }
            }
        }
    }
    if dp[k][m] == u64::MAX {
        bail!("model '{}' cannot be split into {k} partitions", json.name);
    }
    // Recover the chosen boundary indices, then map back to `after` values.
    let mut cuts = Vec::with_capacity(k - 1);
    let mut i = m;
    for j in (2..=k).rev() {
        let split = back[j][i];
        cuts.push(bounds[split] - 1); // boundary before segment `split` = after layer
        i = split;
    }
    cuts.reverse();
    Ok(cuts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::JsonLayer;

    fn dense(name: &str, fin: usize, fout: usize) -> JsonLayer {
        JsonLayer::dense(name, fin, fout, false, false, "int8", "int8", 0, vec![0; fin * fout], vec![])
    }

    fn chain(dims: &[usize]) -> JsonModel {
        let layers = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| dense(&format!("fc{}", i + 1), w[0], w[1]))
            .collect();
        JsonModel::new("chain", layers)
    }

    #[test]
    fn every_chain_boundary_is_a_candidate() {
        let m = chain(&[8, 8, 8, 8]);
        let c = cut_candidates(&m);
        assert_eq!(c.len(), 2);
        assert_eq!(c[0], CutCandidate { after: 0, tensor: "fc1".into() });
        assert_eq!(c[1], CutCandidate { after: 1, tensor: "fc2".into() });
    }

    #[test]
    fn residual_skip_window_is_uncuttable() {
        // input -> fc1 -> fc2, add(input, fc2), head: the raw input stays
        // live until the merge, so the only legal cut is after the merge.
        let m = JsonModel::new(
            "res",
            vec![
                dense("fc1", 8, 16),
                dense("fc2", 16, 8),
                JsonLayer::residual_add("res", 8, "int8", 0, &["input", "fc2"]),
                dense("head", 8, 4).with_inputs(&["res"]),
            ],
        );
        let c = cut_candidates(&m);
        assert_eq!(c, vec![CutCandidate { after: 2, tensor: "res".into() }]);
    }

    #[test]
    fn diamond_cuts_at_fanout_and_fanin() {
        let m = JsonModel::new(
            "dia",
            vec![
                dense("stem", 8, 8),
                dense("a", 8, 8).with_inputs(&["stem"]),
                dense("b", 8, 8).with_inputs(&["stem"]),
                JsonLayer::residual_add("merge", 8, "int8", 0, &["a", "b"]),
                dense("head", 8, 4).with_inputs(&["merge"]),
            ],
        );
        let c = cut_candidates(&m);
        let afters: Vec<usize> = c.iter().map(|c| c.after).collect();
        // After the stem (only `stem` crosses) and after the merge; inside
        // the branch window two tensors are live, so no cut exists there.
        assert_eq!(afters, vec![0, 3]);
    }

    #[test]
    fn multi_sink_cuts_keep_stranded_heads_as_upstream_outputs() {
        // head_a is unconsumed (a network sink). Cutting after it is legal
        // because only `trunk` crosses — head_a simply becomes an output of
        // the upstream partition (multi-sink drains make that expressible).
        let m = JsonModel::new(
            "heads",
            vec![
                dense("trunk", 8, 8),
                dense("head_a", 8, 4).with_inputs(&["trunk"]),
                dense("head_b", 8, 2).with_inputs(&["trunk"]),
            ],
        );
        let c = cut_candidates(&m);
        assert_eq!(
            c,
            vec![
                CutCandidate { after: 0, tensor: "trunk".into() },
                CutCandidate { after: 1, tensor: "trunk".into() },
            ]
        );
        assert_eq!(m.sink_names(), vec!["head_a", "head_b"]);
    }

    #[test]
    fn dp_balances_bottleneck() {
        // Weights 64, 64, 64, 192 (by MACs): the balanced 2-way split puts
        // the heavy tail alone.
        let m = chain(&[8, 8, 8, 8, 24]);
        let c = cut_candidates(&m);
        let cuts = choose_cuts(&m, &c, 2).unwrap();
        assert_eq!(cuts, vec![2]); // {fc1,fc2,fc3} | {fc4}
        let three = choose_cuts(&m, &c, 3).unwrap();
        assert_eq!(three.len(), 2);
        assert!(three[0] < three[1]);
    }

    #[test]
    fn too_many_partitions_rejected() {
        let m = chain(&[8, 8, 8]);
        let c = cut_candidates(&m);
        assert!(choose_cuts(&m, &c, 4).is_err());
        assert!(choose_cuts(&m, &c, 2).is_ok());
        assert!(choose_cuts(&m, &c, 1).unwrap().is_empty());
    }
}
