//! Cut search: where a model's layer DAG may be sliced into pipeline
//! partitions, and which cuts balance the pipeline best.
//!
//! Layers in the exporter JSON are listed in a valid topological order
//! (every `inputs` entry names an *earlier* layer), so a partition is a
//! contiguous run of layers and a cut is a position between two layers.
//! A position qualifies as a [`CutCandidate`] only when exactly **one**
//! tensor is live across it — the single value produced at or before the
//! cut that any later layer still reads. That tensor becomes the typed
//! inter-partition link: the upstream partition drains it through an
//! output buffer (multi-sink emission), the downstream partition ingests
//! it as its network input. Residual skips therefore cut *after* their
//! merge, never inside the skip window, and a diamond cuts before its
//! fan-out or after its fan-in — exactly the synchronization points where
//! an array-to-array hop is physically a single stream.
//!
//! [`choose_cuts`] is **compile-in-the-loop**: every candidate slice is
//! compiled through the real 7-pass pipeline (memoized in the
//! content-addressed [`FirmwareCache`], cold compiles fanned out across a
//! bounded thread pool) and scored by its *modeled steady-state interval*
//! plus the cost of the link feeding it — the same numbers
//! [`super::analyze_pipeline`] reports for the assembled pipeline. A
//! bottleneck DP then picks the `k − 1` cuts minimizing the slowest
//! pipeline stage. MAC balancing ([`choose_cuts_by_macs`], the previous
//! policy) survives as the tie-breaker and the fallback when no slice set
//! compiles: raw MACs mistrack DMA-bound and merge-heavy models whose
//! true bottleneck is data movement, which the compiled interval sees.
//!
//! The DP builds its slices with exactly the machinery `split_model` uses
//! ([`super::slice_submodel`] / [`super::slice_config`]), so when the
//! chosen partitioning is compiled for real, every per-partition compile
//! is a cache hit — scoring is not paid twice.

use crate::arch::{Device, Dtype};
use crate::cache::FirmwareCache;
use crate::frontend::{CompileConfig, JsonModel};
use crate::sim::engine::{analyze, EngineModel};
use anyhow::{bail, Context, Result};
use std::collections::BTreeSet;

/// One legal cut position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CutCandidate {
    /// The cut sits after `layers[after]` (0-based layer index).
    pub after: usize,
    /// Name of the single tensor crossing the cut (the link tensor).
    pub tensor: String,
}

/// Enumerate every legal cut position. A position `after` qualifies when:
/// exactly one layer-produced tensor is consumed across it, the raw
/// network input is not read beyond it, and the first downstream layer is
/// dense or conv2d (it becomes the downstream partition's input-consuming
/// layer).
/// Liveness is computed over [`JsonModel::effective_inputs`] — the same
/// wiring rule `to_graph` connects, so a legal cut here is a legal cut in
/// the compiled graph.
pub fn cut_candidates(json: &JsonModel) -> Vec<CutCandidate> {
    let inputs = json.effective_inputs();
    let n = json.layers.len();
    let index_of = |name: &str| json.layers.iter().position(|l| l.name == name);
    let mut out = Vec::new();
    for after in 0..n.saturating_sub(1) {
        // Tensors produced at or before the cut but read after it.
        let mut crossing: BTreeSet<&str> = BTreeSet::new();
        let mut input_crosses = false;
        for consumer in after + 1..n {
            for src in &inputs[consumer] {
                if src == "input" {
                    input_crosses = true;
                } else if index_of(src).map(|p| p <= after).unwrap_or(false) {
                    crossing.insert(src.as_str());
                }
            }
        }
        if input_crosses || crossing.len() != 1 {
            continue;
        }
        if !matches!(json.layers[after + 1].ty.as_str(), "dense" | "conv2d") {
            // The downstream partition's first layer must consume the link
            // as its network input: dense or conv2d.
            continue;
        }
        out.push(CutCandidate {
            after,
            tensor: (*crossing.iter().next().unwrap()).to_string(),
        });
    }
    out
}

/// MACs per layer (merge/pool/transpose layers are free), the
/// per-partition weight the MAC balance objective sums. Conv layers count
/// their *true* MACs (`OH·OW·KH·KW·C_in·C_out`), not the padded GEMM.
fn layer_macs(json: &JsonModel) -> Vec<u64> {
    json.layers
        .iter()
        .map(|l| match l.ty.as_str() {
            "dense" => (l.in_features * l.out_features) as u64,
            "conv2d" => l.conv_attrs().map(|c| c.macs() as u64).unwrap_or(0),
            _ => 0,
        })
        .collect()
}

/// The `bounds` array over cut candidates: boundary `i` sits before layer
/// `bounds[i]`, with the virtual ends before layer 0 and after the last
/// layer. Segment `(a, b)` spans `layers[bounds[a]..bounds[b]]`.
fn boundary_positions(json: &JsonModel, candidates: &[CutCandidate]) -> Vec<usize> {
    std::iter::once(0)
        .chain(candidates.iter().map(|c| c.after + 1))
        .chain(std::iter::once(json.layers.len()))
        .collect()
}

/// Shared preconditions of both cut policies.
fn check_arity(json: &JsonModel, candidates: &[CutCandidate], k: usize) -> Result<()> {
    if k == 0 {
        bail!("cannot partition into zero partitions");
    }
    if k > 1 && candidates.len() < k - 1 {
        bail!(
            "model '{}' has {} legal cut points; {} partitions need {}",
            json.name,
            candidates.len(),
            k,
            k - 1
        );
    }
    Ok(())
}

/// Choose `k - 1` cut positions (a subset of `candidates`) minimizing the
/// heaviest partition's MAC weight. Returns the chosen `after` indices in
/// ascending order. Classic contiguous-partition DP over the candidate
/// boundaries (tiny inputs; exactness is free). This is the pre-compile
/// proxy policy: [`choose_cuts`] uses it as tie-breaker and fallback, and
/// benches compare against it to measure what interval balancing buys.
pub fn choose_cuts_by_macs(
    json: &JsonModel,
    candidates: &[CutCandidate],
    k: usize,
) -> Result<Vec<usize>> {
    check_arity(json, candidates, k)?;
    if k == 1 {
        return Ok(Vec::new());
    }
    let macs = layer_macs(json);
    let prefix: Vec<u64> = std::iter::once(0)
        .chain(macs.iter().scan(0u64, |acc, &m| {
            *acc += m;
            Some(*acc)
        }))
        .collect();
    let bounds = boundary_positions(json, candidates);
    let seg = |a: usize, b: usize| prefix[bounds[b]] - prefix[bounds[a]];
    let m = bounds.len() - 1; // number of atomic segments
    // dp[j][i]: minimal bottleneck splitting segments 0..i into j parts.
    let mut dp = vec![vec![u64::MAX; m + 1]; k + 1];
    let mut back = vec![vec![0usize; m + 1]; k + 1];
    for i in 1..=m {
        dp[1][i] = seg(0, i);
    }
    for j in 2..=k {
        for i in j..=m {
            for split in j - 1..i {
                if dp[j - 1][split] == u64::MAX {
                    continue;
                }
                let cost = dp[j - 1][split].max(seg(split, i));
                if cost < dp[j][i] {
                    dp[j][i] = cost;
                    back[j][i] = split;
                }
            }
        }
    }
    if dp[k][m] == u64::MAX {
        bail!("model '{}' cannot be split into {k} partitions", json.name);
    }
    // Recover the chosen boundary indices, then map back to `after` values.
    let mut cuts = Vec::with_capacity(k - 1);
    let mut i = m;
    for j in (2..=k).rev() {
        let split = back[j][i];
        cuts.push(bounds[split] - 1); // boundary before segment `split` = after layer
        i = split;
    }
    cuts.reverse();
    Ok(cuts)
}

/// The interval DP's verdict, with everything `partition --explain` shows.
#[derive(Debug, Clone)]
pub struct CutPlan {
    /// Chosen cut positions (`after` layer indices), ascending.
    pub cuts: Vec<usize>,
    /// Modeled bottleneck of the chosen pipeline, cycles/batch: the
    /// slowest of any partition's steady-state interval or link transfer.
    pub bottleneck_cycles: f64,
    /// Per-partition score (its interval max'd with its incoming link
    /// cost), one entry per partition in pipeline order.
    pub segment_cycles: Vec<f64>,
    /// What the MAC-balancing proxy would have chosen, for comparison.
    pub mac_cuts: Vec<usize>,
    /// True when no candidate slice set compiled and the MAC cuts were
    /// returned unchanged (`try_k` then surfaces the real compile error).
    pub used_macs_fallback: bool,
}

/// One scored segment: the modeled bottleneck contribution in cycles,
/// with the segment's MAC weight as lexicographic tie-breaker (equal
/// modeled intervals fall back to MAC balance, keeping the DP
/// deterministic where the cycle model cannot distinguish).
#[derive(Clone, Copy, PartialEq)]
struct Score {
    cycles: f64,
    macs: u64,
}

impl Score {
    fn better_than(self, other: Score) -> bool {
        self.cycles < other.cycles || (self.cycles == other.cycles && self.macs < other.macs)
    }

    fn bottleneck(self, other: Score) -> Score {
        Score { cycles: self.cycles.max(other.cycles), macs: self.macs.max(other.macs) }
    }
}

/// Is segment `(a, b)` of `m` usable as one part of a `k`-way contiguous
/// split? (Each part takes ≥ 1 segment; part 1 must start at 0 and part
/// `k` must end at `m`.) Pruning the slice grid to usable segments keeps
/// the common K = 2 case down to prefixes and suffixes.
fn segment_usable(a: usize, b: usize, m: usize, k: usize) -> bool {
    match (a == 0, b == m) {
        (true, true) => k == 1,
        (true, false) => m - b >= k - 1,
        (false, true) => a >= k - 1,
        (false, false) => k >= 3 && a + (m - b) >= k - 1,
    }
}

/// Compile-in-the-loop cut choice: pick the `k - 1` cuts minimizing the
/// modeled pipeline bottleneck (see [`choose_cuts_explained`]; this
/// returns just the cuts).
pub fn choose_cuts(
    json: &JsonModel,
    cfg: &CompileConfig,
    candidates: &[CutCandidate],
    k: usize,
    cache: &FirmwareCache,
) -> Result<Vec<usize>> {
    Ok(choose_cuts_explained(json, cfg, candidates, k, cache)?.cuts)
}

/// Compile-in-the-loop cut choice with the full [`CutPlan`] explanation.
///
/// Every usable candidate slice is compiled (through `cache`) and scored
/// `max(slice interval, incoming link cycles)` — the slice's contribution
/// to [`super::analyze_pipeline`]'s pipeline interval, computed with the
/// same formulas (link cost knows whether the slice's input landing is
/// offset-tiled or staged, from the compiled firmware). A min-max DP over
/// the scored segments is therefore *exact* for the modeled objective:
/// the chosen cuts' assembled pipeline interval equals the DP bottleneck,
/// and no other cut set models faster. Slices that fail to compile score
/// infinite; if no finite k-way split exists the MAC cuts are returned so
/// the caller's real compile surfaces the underlying error.
pub fn choose_cuts_explained(
    json: &JsonModel,
    cfg: &CompileConfig,
    candidates: &[CutCandidate],
    k: usize,
    cache: &FirmwareCache,
) -> Result<CutPlan> {
    let mut search_span = crate::obs::tracer()
        .span("partition", "cut_search")
        .with_arg("model", json.name.clone())
        .with_arg("k", k)
        .with_arg("candidates", candidates.len());
    let mac_cuts = choose_cuts_by_macs(json, candidates, k)?;
    if k == 1 {
        return Ok(CutPlan {
            cuts: Vec::new(),
            bottleneck_cycles: 0.0,
            segment_cycles: Vec::new(),
            mac_cuts,
            used_macs_fallback: false,
        });
    }
    let engine = EngineModel::default();
    let device = Device::by_name(&cfg.device)
        .with_context(|| format!("unknown device '{}'", cfg.device))?;
    let port = device.mem_tile_port_bytes.max(1);
    let bounds = boundary_positions(json, candidates);
    let m = bounds.len() - 1;
    let macs = layer_macs(json);
    let mac_prefix: Vec<u64> = std::iter::once(0)
        .chain(macs.iter().scan(0u64, |acc, &w| {
            *acc += w;
            Some(*acc)
        }))
        .collect();
    let seg_macs = |a: usize, b: usize| mac_prefix[bounds[b]] - mac_prefix[bounds[a]];
    // Wire cycles of the link crossing boundary `s` (1..m): one DMA pass
    // of the crossing activation at memory-tile port rate. Matches
    // `pipeline::link_transfer_cycles` — a staged landing pays it twice.
    let wire_at = |s: usize| -> f64 {
        let c = &candidates[s - 1];
        let bytes = json
            .layers
            .iter()
            .find(|l| l.name == c.tensor)
            .map(|l| {
                let db = Dtype::parse(&l.quant.output.dtype).map(|d| d.bytes()).unwrap_or(1);
                cfg.batch * l.out_features * db
            })
            .unwrap_or(0);
        bytes as f64 / port as f64 + engine.dma_setup as f64
    };
    // The usable slice grid, compiled in one batch (cold slices across the
    // cache's thread pool). Slice content mirrors `split_model` exactly so
    // the winning cuts' real compiles are cache hits.
    let mut grid: Vec<(usize, usize)> = Vec::new();
    let mut jobs: Vec<(JsonModel, CompileConfig)> = Vec::new();
    for a in 0..m {
        for b in a + 1..=m {
            if !segment_usable(a, b, m, k) {
                continue;
            }
            let incoming = if a > 0 { Some(candidates[a - 1].tensor.as_str()) } else { None };
            let link = if b < m { Some(candidates[b - 1].tensor.as_str()) } else { None };
            let name = format!("{}.s{a}x{b}", json.name);
            let Ok(model) = super::slice_submodel(json, incoming, bounds[a], bounds[b] - 1, &name)
            else {
                continue; // defensively skip: an illegal slice can never win
            };
            let sub_cfg = super::slice_config(cfg, &model, link);
            grid.push((a, b));
            jobs.push((model, sub_cfg));
        }
    }
    search_span.arg("slices", grid.len());
    let compiled = cache.compile_many(&jobs);
    // Score every compiled segment: its own steady-state interval, max'd
    // with the cost of the link feeding it (which depends on whether this
    // slice's compiled input landing is offset-tiled or staged).
    let mut score = vec![vec![None::<Score>; m + 1]; m];
    for ((a, b), outcome) in grid.iter().zip(&compiled) {
        let Ok(model) = outcome else { continue };
        let Some(fw) = model.firmware.as_ref() else { continue };
        let mut cycles = analyze(fw, &engine).interval_cycles;
        if *a > 0 {
            let wire = wire_at(*a);
            let link_cycles =
                if super::link_landing_tiler(fw).is_some() { wire } else { 2.0 * wire };
            cycles = cycles.max(link_cycles);
        }
        score[*a][*b] = Some(Score { cycles, macs: seg_macs(*a, *b) });
    }
    // Min-max DP over scored segments, with backpointers.
    let mut dp = vec![vec![None::<Score>; m + 1]; k + 1];
    let mut back = vec![vec![0usize; m + 1]; k + 1];
    for i in 1..=m {
        dp[1][i] = score[0][i];
    }
    for j in 2..=k {
        for i in j..=m {
            for split in j - 1..i {
                let (Some(prev), Some(seg)) = (dp[j - 1][split], score[split][i]) else {
                    continue;
                };
                let cost = prev.bottleneck(seg);
                if dp[j][i].map(|cur| cost.better_than(cur)).unwrap_or(true) {
                    dp[j][i] = Some(cost);
                    back[j][i] = split;
                }
            }
        }
    }
    let Some(best) = dp[k][m] else {
        // No candidate slice set compiles at this K. Hand back the MAC
        // cuts: the caller's real compile then reports *why* (the actual
        // per-partition compile error), instead of a bare "no cuts".
        search_span.arg("used_macs_fallback", true);
        return Ok(CutPlan {
            cuts: mac_cuts.clone(),
            bottleneck_cycles: f64::INFINITY,
            segment_cycles: Vec::new(),
            mac_cuts,
            used_macs_fallback: true,
        });
    };
    // Recover boundaries and per-part scores, last part first.
    let mut cuts = Vec::with_capacity(k - 1);
    let mut segment_cycles = Vec::with_capacity(k);
    let mut i = m;
    for j in (2..=k).rev() {
        let split = back[j][i];
        segment_cycles.push(score[split][i].expect("chosen segment was scored").cycles);
        cuts.push(bounds[split] - 1);
        i = split;
    }
    segment_cycles.push(score[0][i].expect("first segment was scored").cycles);
    cuts.reverse();
    segment_cycles.reverse();
    search_span.arg("bottleneck_cycles", best.cycles);
    Ok(CutPlan {
        cuts,
        bottleneck_cycles: best.cycles,
        segment_cycles,
        mac_cuts,
        used_macs_fallback: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::JsonLayer;

    fn dense(name: &str, fin: usize, fout: usize) -> JsonLayer {
        JsonLayer::dense(name, fin, fout, false, false, "int8", "int8", 0, vec![0; fin * fout], vec![])
    }

    fn chain(dims: &[usize]) -> JsonModel {
        let layers = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| dense(&format!("fc{}", i + 1), w[0], w[1]))
            .collect();
        JsonModel::new("chain", layers)
    }

    fn cfg() -> CompileConfig {
        let mut c = CompileConfig::default();
        c.batch = 4;
        c
    }

    #[test]
    fn every_chain_boundary_is_a_candidate() {
        let m = chain(&[8, 8, 8, 8]);
        let c = cut_candidates(&m);
        assert_eq!(c.len(), 2);
        assert_eq!(c[0], CutCandidate { after: 0, tensor: "fc1".into() });
        assert_eq!(c[1], CutCandidate { after: 1, tensor: "fc2".into() });
    }

    #[test]
    fn residual_skip_window_is_uncuttable() {
        // input -> fc1 -> fc2, add(input, fc2), head: the raw input stays
        // live until the merge, so the only legal cut is after the merge.
        let m = JsonModel::new(
            "res",
            vec![
                dense("fc1", 8, 16),
                dense("fc2", 16, 8),
                JsonLayer::residual_add("res", 8, "int8", 0, &["input", "fc2"]),
                dense("head", 8, 4).with_inputs(&["res"]),
            ],
        );
        let c = cut_candidates(&m);
        assert_eq!(c, vec![CutCandidate { after: 2, tensor: "res".into() }]);
    }

    #[test]
    fn diamond_cuts_at_fanout_and_fanin() {
        let m = JsonModel::new(
            "dia",
            vec![
                dense("stem", 8, 8),
                dense("a", 8, 8).with_inputs(&["stem"]),
                dense("b", 8, 8).with_inputs(&["stem"]),
                JsonLayer::residual_add("merge", 8, "int8", 0, &["a", "b"]),
                dense("head", 8, 4).with_inputs(&["merge"]),
            ],
        );
        let c = cut_candidates(&m);
        let afters: Vec<usize> = c.iter().map(|c| c.after).collect();
        // After the stem (only `stem` crosses) and after the merge; inside
        // the branch window two tensors are live, so no cut exists there.
        assert_eq!(afters, vec![0, 3]);
    }

    #[test]
    fn multi_sink_cuts_keep_stranded_heads_as_upstream_outputs() {
        // head_a is unconsumed (a network sink). Cutting after it is legal
        // because only `trunk` crosses — head_a simply becomes an output of
        // the upstream partition (multi-sink drains make that expressible).
        let m = JsonModel::new(
            "heads",
            vec![
                dense("trunk", 8, 8),
                dense("head_a", 8, 4).with_inputs(&["trunk"]),
                dense("head_b", 8, 2).with_inputs(&["trunk"]),
            ],
        );
        let c = cut_candidates(&m);
        assert_eq!(
            c,
            vec![
                CutCandidate { after: 0, tensor: "trunk".into() },
                CutCandidate { after: 1, tensor: "trunk".into() },
            ]
        );
        assert_eq!(m.sink_names(), vec!["head_a", "head_b"]);
    }

    #[test]
    fn mac_dp_balances_bottleneck() {
        // Weights 64, 64, 64, 192 (by MACs): the balanced 2-way split puts
        // the heavy tail alone.
        let m = chain(&[8, 8, 8, 8, 24]);
        let c = cut_candidates(&m);
        let cuts = choose_cuts_by_macs(&m, &c, 2).unwrap();
        assert_eq!(cuts, vec![2]); // {fc1,fc2,fc3} | {fc4}
        let three = choose_cuts_by_macs(&m, &c, 3).unwrap();
        assert_eq!(three.len(), 2);
        assert!(three[0] < three[1]);
    }

    #[test]
    fn interval_dp_matches_macs_on_a_heavy_tail_chain() {
        // Uniform tiny stages with one heavy tail: the compiled intervals
        // agree with the MAC proxy here (compute-bound chain), so both
        // policies isolate the tail — and the plan carries the comparison.
        let m = chain(&[8, 8, 8, 8, 24]);
        let c = cut_candidates(&m);
        let cache = FirmwareCache::new();
        let plan = choose_cuts_explained(&m, &cfg(), &c, 2, &cache).unwrap();
        assert!(!plan.used_macs_fallback);
        assert_eq!(plan.cuts, vec![2]);
        assert_eq!(plan.mac_cuts, vec![2]);
        assert_eq!(plan.segment_cycles.len(), 2);
        assert!(plan.bottleneck_cycles.is_finite() && plan.bottleneck_cycles > 0.0);
        assert_eq!(
            plan.bottleneck_cycles,
            plan.segment_cycles.iter().cloned().fold(0.0, f64::max)
        );
    }

    #[test]
    fn interval_dp_slices_hit_cache_on_repeat() {
        let m = chain(&[16, 16, 16, 16]);
        let c = cut_candidates(&m);
        let cache = FirmwareCache::new();
        let first = choose_cuts(&m, &cfg(), &c, 2, &cache).unwrap();
        let cold = cache.stats();
        assert!(cold.misses > 0);
        let second = choose_cuts(&m, &cfg(), &c, 2, &cache).unwrap();
        let warm = cache.stats();
        assert_eq!(first, second);
        assert_eq!(warm.misses, cold.misses, "second search recompiled");
        assert!(warm.hits > cold.hits);
    }

    #[test]
    fn too_many_partitions_rejected() {
        let m = chain(&[8, 8, 8]);
        let c = cut_candidates(&m);
        let cache = FirmwareCache::new();
        assert!(choose_cuts(&m, &cfg(), &c, 4, &cache).is_err());
        assert!(choose_cuts_by_macs(&m, &c, 4).is_err());
        assert!(choose_cuts(&m, &cfg(), &c, 2, &cache).is_ok());
        assert!(choose_cuts(&m, &cfg(), &c, 1, &cache).unwrap().is_empty());
    }
}
