//! Device descriptions: the 2D AIE array geometry, local memories, memory
//! tiles, cascade chains and interface columns.
//!
//! The evaluation platform is the Versal VEK280 (AIE-ML generation): a
//! 38-column × 8-row array of 304 compute tiles with one row of memory tiles
//! along the array's south edge. The paper's layer-scaling study uses up to
//! 296 of 304 tiles (97.4%): one full column is held back for array
//! I/O / RTP plumbing, which we model as a reserved column.

use super::precision::AieGeneration;

/// Static description of one AIE device target.
#[derive(Debug, Clone)]
pub struct Device {
    pub name: String,
    pub generation: AieGeneration,
    /// Compute-array geometry.
    pub cols: usize,
    pub rows: usize,
    /// Columns reserved for shim/RTP plumbing (not placeable).
    pub reserved_cols: usize,
    /// Core clock in GHz.
    pub freq_ghz: f64,
    /// Data memory local to each AIE tile, bytes (AIE-ML: 64 KiB).
    pub local_mem_bytes: usize,
    /// Number of local-memory banks (parallel loads/stores need distinct banks).
    pub local_mem_banks: usize,
    /// Each load port width in bytes (256-bit = 32 B); two load ports + one store.
    pub load_port_bytes: usize,
    pub load_ports: usize,
    pub store_port_bytes: usize,
    /// Memory tiles: one per column along the south edge.
    pub mem_tiles: usize,
    /// Capacity of one memory tile in bytes (AIE-ML: 512 KiB).
    pub mem_tile_bytes: usize,
    /// Memory-tile DMA channel width in bytes per cycle (512-bit = 64 B).
    pub mem_tile_port_bytes: usize,
    /// Read/write DMA channels per memory tile.
    pub mem_tile_channels: usize,
    /// Cascade port width in bits (AIE-ML: 512).
    pub cascade_bits: usize,
    /// VLIW issue slots (AIE-ML: 7-way).
    pub vliw_slots: usize,
}

impl Device {
    /// Versal VEK280 — the paper's evaluation platform (AIE-ML).
    pub fn vek280() -> Device {
        Device {
            name: "VEK280".to_string(),
            generation: AieGeneration::AieMl,
            cols: 38,
            rows: 8,
            reserved_cols: 1,
            freq_ghz: 1.25,
            local_mem_bytes: 64 * 1024,
            local_mem_banks: 8,
            load_port_bytes: 32,
            load_ports: 2,
            store_port_bytes: 32,
            mem_tiles: 38,
            mem_tile_bytes: 512 * 1024,
            mem_tile_port_bytes: 64,
            mem_tile_channels: 6,
            cascade_bits: 512,
            vliw_slots: 7,
        }
    }

    /// Versal VEK385 — AIE-MLv2, functionally validated target.
    pub fn vek385() -> Device {
        Device {
            name: "VEK385".to_string(),
            generation: AieGeneration::AieMlV2,
            cols: 36,
            rows: 8,
            reserved_cols: 1,
            freq_ghz: 1.25,
            local_mem_bytes: 64 * 1024,
            local_mem_banks: 8,
            load_port_bytes: 64,
            load_ports: 2,
            store_port_bytes: 64,
            mem_tiles: 36,
            mem_tile_bytes: 512 * 1024,
            mem_tile_port_bytes: 64,
            mem_tile_channels: 6,
            cascade_bits: 512,
            vliw_slots: 7,
        }
    }

    /// First-generation AIE device (VCK190-class) — used only by the
    /// prior-framework baseline models in Table IV.
    pub fn vck190() -> Device {
        Device {
            name: "VCK190".to_string(),
            generation: AieGeneration::Aie,
            cols: 50,
            rows: 8,
            reserved_cols: 0,
            freq_ghz: 1.25,
            local_mem_bytes: 32 * 1024,
            local_mem_banks: 8,
            load_port_bytes: 32,
            load_ports: 2,
            store_port_bytes: 32,
            mem_tiles: 0, // no memory tiles on first-gen AIE
            mem_tile_bytes: 0,
            mem_tile_port_bytes: 0,
            mem_tile_channels: 0,
            cascade_bits: 384,
            vliw_slots: 7,
        }
    }

    /// Look a device up by name.
    pub fn by_name(name: &str) -> Option<Device> {
        match name.to_ascii_lowercase().as_str() {
            "vek280" | "aie-ml" | "aieml" => Some(Device::vek280()),
            "vek385" | "aie-mlv2" | "aiemlv2" => Some(Device::vek385()),
            "vck190" | "aie" => Some(Device::vck190()),
            _ => None,
        }
    }

    /// Total compute tiles on the device.
    pub fn total_tiles(&self) -> usize {
        self.cols * self.rows
    }

    /// Tiles available to the placer (reserved columns excluded).
    pub fn placeable_tiles(&self) -> usize {
        (self.cols - self.reserved_cols) * self.rows
    }

    /// Columns available to the placer.
    pub fn placeable_cols(&self) -> usize {
        self.cols - self.reserved_cols
    }

    /// Theoretical INT8 device peak in TOPS (all compute tiles).
    pub fn peak_int8_tops(&self) -> f64 {
        use super::precision::{macs_per_cycle, PrecisionPair};
        let w = macs_per_cycle(self.generation, PrecisionPair::I8I8).unwrap_or(0) as f64;
        2.0 * w * self.freq_ghz * self.total_tiles() as f64 / 1000.0
    }

    /// Load bandwidth of one tile in bytes/cycle.
    pub fn tile_load_bandwidth(&self) -> usize {
        self.load_ports * self.load_port_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vek280_geometry_matches_paper() {
        let d = Device::vek280();
        assert_eq!(d.total_tiles(), 304);
        // 296/304 tiles usable = 97.4% spatial utilization (paper Fig. 4).
        assert_eq!(d.placeable_tiles(), 296);
        let util = d.placeable_tiles() as f64 / d.total_tiles() as f64;
        assert!((util - 0.974).abs() < 0.001, "utilization {util}");
    }

    #[test]
    fn vek280_int8_peak_near_195_tops() {
        // 304 tiles x 256 MAC/cyc x 2 op x 1.25 GHz = 194.56 TOPS; the
        // paper's "160 TOPS = 82.2% of theoretical INT8 peak" implies a
        // peak of ~194.6 TOPS.
        let d = Device::vek280();
        let peak = d.peak_int8_tops();
        assert!((peak - 194.56).abs() < 0.01, "peak {peak}");
        assert!((160.0 / peak - 0.822).abs() < 0.005);
    }

    #[test]
    fn device_lookup() {
        assert_eq!(Device::by_name("vek280").unwrap().name, "VEK280");
        assert_eq!(Device::by_name("AIE-MLv2").unwrap().name, "VEK385");
        assert!(Device::by_name("h100").is_none());
    }

    #[test]
    fn memory_tile_capacity() {
        let d = Device::vek280();
        // One 512 KiB memory tile per column.
        assert_eq!(d.mem_tiles, d.cols);
        assert_eq!(d.mem_tile_bytes, 524288);
    }

    #[test]
    fn bandwidths() {
        let d = Device::vek280();
        assert_eq!(d.tile_load_bandwidth(), 64); // 2 x 256-bit
        assert_eq!(d.mem_tile_port_bytes, 64); // 512-bit DMA
    }
}
