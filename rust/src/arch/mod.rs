//! Architecture model of AMD Versal AI Engine devices.
//!
//! This module is the static substrate everything else builds on: integer
//! precisions and the MAC-density table (`precision`), `aie::mmul` tiling
//! shapes with their analytic ceilings (`mmul`), and whole-device
//! descriptions (`device`).

pub mod device;
pub mod mmul;
pub mod precision;

pub use device::Device;
pub use mmul::{
    default_tiling, default_tiling_for, native_tilings, native_tilings_v2, supported_tilings,
    table1_ceilings, tile_peak_gops, CeilingRow, MmulTiling,
};
pub use precision::{macs_per_cycle, AieGeneration, Dtype, PrecisionPair};
