//! `aie::mmul` tiling configurations and single-tile performance ceilings.
//!
//! The `aie::mmul` class template is parameterized by ⟨M,K,N⟩ and the operand
//! datatypes; *native* tilings map directly to one hardware intrinsic while
//! non-native tilings are emulated through multiple intrinsic calls with
//! extra data manipulation (paper §III-A). Table I of the paper lists the
//! native tilings this study uses and their theoretical ceilings, which this
//! module reproduces analytically.

use super::precision::{macs_per_cycle, AieGeneration, PrecisionPair};
use std::fmt;

/// An ⟨M,K,N⟩ `aie::mmul` tile shape for a precision pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MmulTiling {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub pair: PrecisionPair,
    /// Maps directly to a single hardware intrinsic.
    pub native: bool,
}

impl MmulTiling {
    pub const fn new(m: usize, k: usize, n: usize, pair: PrecisionPair, native: bool) -> Self {
        MmulTiling { m, k, n, pair, native }
    }

    /// MACs performed by one tile multiply.
    pub fn macs_per_tile(&self) -> usize {
        self.m * self.k * self.n
    }

    /// Bytes loaded per tile multiply (A tile + W tile).
    pub fn bytes_per_tile(&self) -> usize {
        self.m * self.k * self.pair.act.bytes() + self.k * self.n * self.pair.wgt.bytes()
    }

    /// Cycles the VMAC pipeline needs per tile multiply, given the
    /// generation's MAC density: `ceil(M·K·N / W(p_A,p_B))`.
    pub fn vmac_cycles_per_tile(&self, generation: AieGeneration) -> usize {
        let w = macs_per_cycle(generation, self.pair).unwrap_or(1) as usize;
        self.macs_per_tile().div_ceil(w)
    }

    /// Load-port cycles per tile multiply: two 256-bit (32 B) load ports,
    /// one dedicated to A and one to W (paper: VLDA / VLDB from each unit).
    /// The slower port bounds the load stage.
    pub fn load_cycles_per_tile(&self, load_port_bytes: usize) -> usize {
        let a_bytes = self.m * self.k * self.pair.act.bytes();
        let w_bytes = self.k * self.n * self.pair.wgt.bytes();
        let a_cyc = a_bytes.div_ceil(load_port_bytes);
        let w_cyc = w_bytes.div_ceil(load_port_bytes);
        a_cyc.max(w_cyc)
    }

    /// Effective steady-state cycles per tile multiply for a *single-tile
    /// schedule* (no accumulator blocking): the slowest of VMAC / VLDA /
    /// VLDB stages (paper: "per-tile efficiency is limited by the slowest
    /// stage among VLDA, VLDB, or VMAC").
    pub fn single_tile_cycles(&self, generation: AieGeneration, load_port_bytes: usize) -> usize {
        self.vmac_cycles_per_tile(generation)
            .max(self.load_cycles_per_tile(load_port_bytes))
    }

    /// Effective steady-state cycles per tile multiply under the 2×2
    /// accumulator scheme: each loaded A tile is reused across 2 W tiles and
    /// vice versa, so per-tile load traffic halves and the VMAC stage
    /// dominates for all native tilings.
    pub fn blocked_cycles(&self, generation: AieGeneration, load_port_bytes: usize) -> usize {
        let vmac = self.vmac_cycles_per_tile(generation);
        // With 2x2 blocking each load feeds two tile-multiplies.
        let a_bytes = self.m * self.k * self.pair.act.bytes();
        let w_bytes = self.k * self.n * self.pair.wgt.bytes();
        let load = (a_bytes.div_ceil(load_port_bytes)).max(w_bytes.div_ceil(load_port_bytes));
        vmac.max(load.div_ceil(2))
    }

    /// Peak sustained MAC/cycle for this tiling with the blocked schedule.
    pub fn peak_macs_per_cycle(&self, generation: AieGeneration, load_port_bytes: usize) -> f64 {
        self.macs_per_tile() as f64 / self.blocked_cycles(generation, load_port_bytes) as f64
    }
}

impl fmt::Display for MmulTiling {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "<{},{},{}> {}", self.m, self.k, self.n, self.pair)
    }
}

/// The representative native tilings selected in the paper (Table I).
pub fn native_tilings() -> Vec<MmulTiling> {
    vec![
        MmulTiling::new(4, 8, 8, PrecisionPair::I8I8, true),
        MmulTiling::new(4, 4, 8, PrecisionPair::I16I8, true),
        MmulTiling::new(4, 4, 4, PrecisionPair::I16I16, true),
    ]
}

/// The full set of tilings the tool supports (a superset of Table I;
/// non-native entries are emulated and modeled with an efficiency penalty).
pub fn supported_tilings() -> Vec<MmulTiling> {
    let mut v = native_tilings();
    v.extend(native_tilings_v2());
    v.extend([
        // Additional native shapes for AIE-ML per AMD's table.
        MmulTiling::new(2, 8, 8, PrecisionPair::I8I8, true),
        MmulTiling::new(4, 8, 4, PrecisionPair::I8I8, true),
        MmulTiling::new(8, 8, 4, PrecisionPair::I8I8, true),
        MmulTiling::new(2, 4, 8, PrecisionPair::I16I8, true),
        MmulTiling::new(4, 4, 4, PrecisionPair::I16I8, true),
        MmulTiling::new(2, 4, 4, PrecisionPair::I16I16, true),
        MmulTiling::new(4, 2, 4, PrecisionPair::I16I16, true),
        // Non-native examples (emulated via two intrinsic calls).
        MmulTiling::new(4, 16, 8, PrecisionPair::I8I8, false),
        MmulTiling::new(8, 4, 4, PrecisionPair::I16I16, false),
    ]);
    v
}

/// AIE-MLv2 native tilings: the wider MAC array (2x density) makes larger
/// ⟨M,K,N⟩ shapes single-intrinsic (paper §III: "using more blocks can
/// improve accumulator usage on AIE-MLv2 devices").
pub fn native_tilings_v2() -> Vec<MmulTiling> {
    vec![
        MmulTiling::new(8, 8, 8, PrecisionPair::I8I8, true),
        MmulTiling::new(8, 4, 8, PrecisionPair::I16I8, true),
        MmulTiling::new(4, 4, 8, PrecisionPair::I16I16, true),
    ]
}

/// Pick the paper's preferred native tiling for a precision pair.
pub fn default_tiling(pair: PrecisionPair) -> Option<MmulTiling> {
    native_tilings().into_iter().find(|t| t.pair == pair)
}

/// Generation-aware default tiling (AIE-MLv2 forward compatibility).
pub fn default_tiling_for(generation: AieGeneration, pair: PrecisionPair) -> Option<MmulTiling> {
    match generation {
        AieGeneration::AieMlV2 => native_tilings_v2().into_iter().find(|t| t.pair == pair),
        _ => default_tiling(pair),
    }
}

/// One row of Table I: theoretical single-tile ceiling for a tiling.
#[derive(Debug, Clone)]
pub struct CeilingRow {
    pub tiling: (usize, usize, usize),
    pub datatype: String,
    pub native: bool,
    pub mac_per_cycle: u32,
    pub gmac_s: f64,
    pub gop_s: f64,
}

/// Reproduce Table I for a given generation and clock.
pub fn table1_ceilings(generation: AieGeneration, freq_ghz: f64) -> Vec<CeilingRow> {
    native_tilings()
        .into_iter()
        .map(|t| {
            let w = macs_per_cycle(generation, t.pair).unwrap();
            let gmac = w as f64 * freq_ghz;
            CeilingRow {
                tiling: (t.m, t.k, t.n),
                datatype: t.pair.to_string(),
                native: t.native,
                mac_per_cycle: w,
                gmac_s: gmac,
                gop_s: 2.0 * gmac,
            }
        })
        .collect()
}

/// Peak GOP/s of one tile for a precision pair (2 ops per MAC).
pub fn tile_peak_gops(generation: AieGeneration, pair: PrecisionPair, freq_ghz: f64) -> f64 {
    2.0 * macs_per_cycle(generation, pair).unwrap_or(0) as f64 * freq_ghz
}

#[cfg(test)]
mod tests {
    use super::*;

    const LOAD_PORT_BYTES: usize = 32; // 256-bit

    #[test]
    fn table1_rows_match_paper() {
        let rows = table1_ceilings(AieGeneration::AieMl, 1.25);
        assert_eq!(rows.len(), 3);
        // <4,8,8> i8xi8: 256 MAC/cyc, 320 GMAC/s, 640 GOP/s
        assert_eq!(rows[0].tiling, (4, 8, 8));
        assert_eq!(rows[0].mac_per_cycle, 256);
        assert!((rows[0].gmac_s - 320.0).abs() < 1e-9);
        assert!((rows[0].gop_s - 640.0).abs() < 1e-9);
        // <4,4,8> i16xi8: 128, 160, 320
        assert_eq!(rows[1].mac_per_cycle, 128);
        assert!((rows[1].gop_s - 320.0).abs() < 1e-9);
        // <4,4,4> i16xi16: 64, 80, 160
        assert_eq!(rows[2].mac_per_cycle, 64);
        assert!((rows[2].gop_s - 160.0).abs() < 1e-9);
    }

    #[test]
    fn native_tilings_sustain_one_vmac_per_cycle_blocked() {
        // With the 2x2 accumulator scheme every native tiling from Table I
        // should reach 1 tile-multiply per cycle (VMAC-bound, not load-bound).
        for t in native_tilings() {
            assert_eq!(
                t.blocked_cycles(AieGeneration::AieMl, LOAD_PORT_BYTES),
                t.vmac_cycles_per_tile(AieGeneration::AieMl),
                "tiling {t} should be VMAC-bound under 2x2 blocking"
            );
        }
    }

    #[test]
    fn i8_tile_load_bound_without_blocking() {
        // <4,8,8> i8: A tile 32 B (1 cyc), W tile 64 B (2 cyc) -> load-bound
        // at 2 cycles/tile in a single-tile schedule; blocking recovers it.
        let t = MmulTiling::new(4, 8, 8, PrecisionPair::I8I8, true);
        assert_eq!(t.vmac_cycles_per_tile(AieGeneration::AieMl), 1);
        assert_eq!(t.load_cycles_per_tile(LOAD_PORT_BYTES), 2);
        assert_eq!(t.single_tile_cycles(AieGeneration::AieMl, LOAD_PORT_BYTES), 2);
        assert_eq!(t.blocked_cycles(AieGeneration::AieMl, LOAD_PORT_BYTES), 1);
    }

    #[test]
    fn gemv_memory_ceiling() {
        // Paper §III-A: two 256-bit load ports = 64 B/cycle, i.e. only
        // ~32 int8 MAC/cycle without reuse (GEMV regime).
        let bytes_per_cycle = 2 * LOAD_PORT_BYTES;
        let macs_no_reuse = bytes_per_cycle / 2; // one A byte + one W byte per MAC
        assert_eq!(macs_no_reuse, 32);
    }

    #[test]
    fn v2_tilings_single_cycle_on_v2() {
        // Each v2 native tiling is one VMAC on AIE-MLv2 (2x MAC density),
        // and stays load-feedable with the wider 512-bit v2 load ports.
        for t in native_tilings_v2() {
            assert_eq!(t.vmac_cycles_per_tile(AieGeneration::AieMlV2), 1, "{t}");
            assert_eq!(
                t.blocked_cycles(AieGeneration::AieMlV2, 64),
                1,
                "{t} must stay VMAC-bound with 64 B load ports"
            );
        }
    }

    #[test]
    fn generation_aware_defaults() {
        let ml = default_tiling_for(AieGeneration::AieMl, PrecisionPair::I8I8).unwrap();
        let v2 = default_tiling_for(AieGeneration::AieMlV2, PrecisionPair::I8I8).unwrap();
        assert_eq!((ml.m, ml.k, ml.n), (4, 8, 8));
        assert_eq!((v2.m, v2.k, v2.n), (8, 8, 8));
    }

    #[test]
    fn default_tilings_exist_for_all_pairs() {
        for pair in [PrecisionPair::I8I8, PrecisionPair::I16I8, PrecisionPair::I16I16] {
            let t = default_tiling(pair).unwrap();
            assert!(t.native);
            assert_eq!(t.pair, pair);
        }
    }

    #[test]
    fn macs_and_bytes_per_tile() {
        let t = MmulTiling::new(4, 8, 8, PrecisionPair::I8I8, true);
        assert_eq!(t.macs_per_tile(), 256);
        assert_eq!(t.bytes_per_tile(), 32 + 64);
        let t16 = MmulTiling::new(4, 4, 4, PrecisionPair::I16I16, true);
        assert_eq!(t16.bytes_per_tile(), 32 + 32);
    }
}
