//! Integer datatypes and the AIE-ML MAC-throughput table `W(p_A, p_B)`.
//!
//! The AIE-ML vector unit issues one vector multiply-accumulate (VMAC) per
//! cycle; the number of parallel MACs inside that VMAC depends on the operand
//! precision pair. Values follow AMD's published performance table for the
//! AIE-ML generation at 1.25 GHz (paper Table I / ref [20]).

use std::fmt;

/// Integer datatypes supported on the AIE-ML datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dtype {
    I8,
    I16,
    I32,
    I64,
}

impl Dtype {
    /// Width in bits.
    pub fn bits(self) -> u32 {
        match self {
            Dtype::I8 => 8,
            Dtype::I16 => 16,
            Dtype::I32 => 32,
            Dtype::I64 => 64,
        }
    }

    /// Width in bytes.
    pub fn bytes(self) -> usize {
        (self.bits() / 8) as usize
    }

    /// Inclusive representable range.
    pub fn range(self) -> (i64, i64) {
        match self {
            Dtype::I8 => (i8::MIN as i64, i8::MAX as i64),
            Dtype::I16 => (i16::MIN as i64, i16::MAX as i64),
            Dtype::I32 => (i32::MIN as i64, i32::MAX as i64),
            Dtype::I64 => (i64::MIN, i64::MAX),
        }
    }

    /// Saturate `v` into this dtype's range.
    pub fn saturate(self, v: i64) -> i64 {
        let (lo, hi) = self.range();
        v.clamp(lo, hi)
    }

    /// Parse from the exporter's string form ("int8", "i8", ...).
    pub fn parse(s: &str) -> Option<Dtype> {
        match s.to_ascii_lowercase().as_str() {
            "i8" | "int8" | "s8" => Some(Dtype::I8),
            "i16" | "int16" | "s16" => Some(Dtype::I16),
            "i32" | "int32" | "s32" => Some(Dtype::I32),
            "i64" | "int64" | "s64" => Some(Dtype::I64),
            _ => None,
        }
    }
}

impl fmt::Display for Dtype {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Dtype::I8 => "i8",
            Dtype::I16 => "i16",
            Dtype::I32 => "i32",
            Dtype::I64 => "i64",
        };
        write!(f, "{s}")
    }
}

/// An (activation, weight) precision pair, e.g. i16×i8.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PrecisionPair {
    pub act: Dtype,
    pub wgt: Dtype,
}

impl PrecisionPair {
    pub const fn new(act: Dtype, wgt: Dtype) -> Self {
        PrecisionPair { act, wgt }
    }

    pub const I8I8: PrecisionPair = PrecisionPair::new(Dtype::I8, Dtype::I8);
    pub const I16I8: PrecisionPair = PrecisionPair::new(Dtype::I16, Dtype::I8);
    pub const I16I16: PrecisionPair = PrecisionPair::new(Dtype::I16, Dtype::I16);

    /// Accumulator dtype used on AIE-ML for this pair (paper Table II notes):
    /// 32-bit accumulators for i8×i8 and i16×i8, 64-bit for i16×i16.
    pub fn acc_dtype(self) -> Dtype {
        match (self.act, self.wgt) {
            (Dtype::I16, Dtype::I16) => Dtype::I64,
            _ => Dtype::I32,
        }
    }
}

impl fmt::Display for PrecisionPair {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}", self.act, self.wgt)
    }
}

/// AIE generation. AIE-MLv2 doubles MAC density for the 8-bit path and
/// widens local memory, but shares the programming model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AieGeneration {
    /// First-generation AIE (Versal AI Core, e.g. VCK190) — for baselines.
    Aie,
    /// Second generation, ML-optimized (VEK280).
    AieMl,
    /// Third generation (VEK385).
    AieMlV2,
}

impl fmt::Display for AieGeneration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            AieGeneration::Aie => "AIE",
            AieGeneration::AieMl => "AIE-ML",
            AieGeneration::AieMlV2 => "AIE-MLv2",
        };
        write!(f, "{s}")
    }
}

/// `W(p_A, p_B)`: parallel MACs per cycle for a precision pair on a given
/// AIE generation. Returns `None` for unsupported pairs.
pub fn macs_per_cycle(generation: AieGeneration, p: PrecisionPair) -> Option<u32> {
    use Dtype::*;
    let base = match (p.act, p.wgt) {
        (I8, I8) => 256,
        (I16, I8) | (I8, I16) => 128,
        (I16, I16) => 64,
        _ => return None,
    };
    Some(match generation {
        // First-gen AIE had half the 8-bit MAC density of AIE-ML.
        AieGeneration::Aie => base / 2,
        AieGeneration::AieMl => base,
        // AIE-MLv2 doubles vector MAC density.
        AieGeneration::AieMlV2 => base * 2,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_ranges() {
        assert_eq!(Dtype::I8.range(), (-128, 127));
        assert_eq!(Dtype::I16.range(), (-32768, 32767));
        assert_eq!(Dtype::I8.saturate(300), 127);
        assert_eq!(Dtype::I8.saturate(-300), -128);
        assert_eq!(Dtype::I16.saturate(1234), 1234);
    }

    #[test]
    fn dtype_parse_roundtrip() {
        for d in [Dtype::I8, Dtype::I16, Dtype::I32, Dtype::I64] {
            assert_eq!(Dtype::parse(&d.to_string()), Some(d));
        }
        assert_eq!(Dtype::parse("int8"), Some(Dtype::I8));
        assert_eq!(Dtype::parse("float32"), None);
    }

    #[test]
    fn mac_table_matches_paper_table1() {
        // Paper Table I: W(8b,8b)=256, W(16b,8b)=128, W(16b,16b)=64 on AIE-ML.
        assert_eq!(
            macs_per_cycle(AieGeneration::AieMl, PrecisionPair::I8I8),
            Some(256)
        );
        assert_eq!(
            macs_per_cycle(AieGeneration::AieMl, PrecisionPair::I16I8),
            Some(128)
        );
        assert_eq!(
            macs_per_cycle(AieGeneration::AieMl, PrecisionPair::I16I16),
            Some(64)
        );
    }

    #[test]
    fn mlv2_doubles_density() {
        assert_eq!(
            macs_per_cycle(AieGeneration::AieMlV2, PrecisionPair::I8I8),
            Some(512)
        );
    }

    #[test]
    fn acc_dtypes_match_paper_footnotes() {
        assert_eq!(PrecisionPair::I8I8.acc_dtype(), Dtype::I32);
        assert_eq!(PrecisionPair::I16I8.acc_dtype(), Dtype::I32);
        assert_eq!(PrecisionPair::I16I16.acc_dtype(), Dtype::I64);
    }
}
