//! Prior AIE-framework baselines (paper Table IV).
//!
//! Direct measurement of MaxEVA/AutoMM/GAMA/CHARM/ARIES is impossible here
//! (different toolchains, first-gen hardware); the paper itself compares
//! against their *reported* sustained INT8 efficiency and architectural
//! features. We encode those published characteristics as data — plus an
//! analytical sanity model that recomputes each framework's efficiency from
//! its reported sustained TOPS and its device's INT8 peak — so the table is
//! regenerated rather than transcribed: AIE4ML's row comes from our
//! simulator's GEMM run, the baselines from their papers' numbers.

use crate::arch::{AieGeneration, Device};

/// Feature matrix + reported performance of one framework.
#[derive(Debug, Clone)]
pub struct FrameworkRow {
    pub name: &'static str,
    pub generation: AieGeneration,
    /// Reported sustained INT8 TOPS (midpoint when a range is published).
    pub sustained_tops: f64,
    /// Reported efficiency range (% of device INT8 peak), when published
    /// directly; otherwise derived from `sustained_tops`.
    pub reported_eff_pct: Option<(f64, f64)>,
    pub fused_bias_act: bool,
    pub weights_on_aie: bool,
    pub activations_on_aie: bool,
    pub multi_layer: bool,
    /// Multi-layer support relies on PL-side orchestration.
    pub multi_layer_via_pl: bool,
    pub auto_placement: bool,
    pub aies_used: (usize, usize),
}

impl FrameworkRow {
    /// Device INT8 peak the framework's numbers are normalized against.
    pub fn device(&self) -> Device {
        match self.generation {
            AieGeneration::Aie => Device::vck190(),
            AieGeneration::AieMl | AieGeneration::AieMlV2 => Device::vek280(),
        }
    }

    /// Efficiency as % of the device INT8 peak: the reported range when the
    /// source publishes one, else derived sustained/peak.
    pub fn efficiency_pct(&self) -> (f64, f64) {
        if let Some(r) = self.reported_eff_pct {
            return r;
        }
        let pct = 100.0 * self.sustained_tops / self.device().peak_int8_tops();
        (pct, pct)
    }

    pub fn utilization_pct(&self) -> f64 {
        100.0 * self.aies_used.0 as f64 / self.aies_used.1 as f64
    }
}

/// The prior-framework rows of Table IV (published numbers; references in
/// the paper: MaxEVA [13], AutoMM [15], GAMA [19], CHARM [16], ARIES [17]).
pub fn prior_frameworks() -> Vec<FrameworkRow> {
    vec![
        FrameworkRow {
            name: "AutoMM",
            generation: AieGeneration::Aie,
            sustained_tops: 3.5,
            reported_eff_pct: Some((27.5, 27.5)),
            fused_bias_act: false,
            weights_on_aie: false,
            activations_on_aie: false,
            multi_layer: true,
            multi_layer_via_pl: true,
            auto_placement: false,
            aies_used: (192, 400),
        },
        FrameworkRow {
            name: "MaxEVA",
            generation: AieGeneration::Aie,
            sustained_tops: 7.4,
            reported_eff_pct: Some((56.0, 60.0)),
            fused_bias_act: false,
            weights_on_aie: false,
            activations_on_aie: false,
            multi_layer: false,
            multi_layer_via_pl: false,
            auto_placement: false,
            aies_used: (400, 400),
        },
        FrameworkRow {
            name: "GAMA",
            generation: AieGeneration::AieMl,
            sustained_tops: 165.0,
            reported_eff_pct: Some((85.0, 85.0)),
            fused_bias_act: false,
            weights_on_aie: false,
            activations_on_aie: false,
            multi_layer: false,
            multi_layer_via_pl: false,
            auto_placement: false,
            aies_used: (288, 304),
        },
        FrameworkRow {
            name: "CHARM",
            generation: AieGeneration::Aie,
            sustained_tops: 3.9,
            reported_eff_pct: Some((31.0, 31.0)),
            fused_bias_act: false,
            weights_on_aie: false,
            activations_on_aie: false,
            multi_layer: true,
            multi_layer_via_pl: true,
            auto_placement: false,
            aies_used: (192, 400),
        },
        FrameworkRow {
            name: "ARIES",
            generation: AieGeneration::Aie,
            sustained_tops: 5.7,
            reported_eff_pct: Some((45.0, 45.0)),
            fused_bias_act: false,
            weights_on_aie: false,
            activations_on_aie: false,
            multi_layer: true,
            multi_layer_via_pl: true,
            auto_placement: true, // within user-defined core groups
            aies_used: (320, 400),
        },
    ]
}

/// The AIE4ML row, filled from a measured GEMM-at-full-array run.
pub fn aie4ml_row(measured_gemm_tops: f64, tiles_used: usize) -> FrameworkRow {
    let device = Device::vek280();
    let eff = 100.0 * measured_gemm_tops / device.peak_int8_tops();
    FrameworkRow {
        name: "AIE4ML",
        generation: AieGeneration::AieMl,
        sustained_tops: measured_gemm_tops,
        reported_eff_pct: Some((eff, eff)),
        fused_bias_act: true,
        weights_on_aie: true,
        activations_on_aie: true,
        multi_layer: true,
        multi_layer_via_pl: false,
        auto_placement: true,
        aies_used: (tiles_used, device.total_tiles()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prior_rows_match_paper_table4() {
        let rows = prior_frameworks();
        let by_name = |n: &str| rows.iter().find(|r| r.name == n).unwrap();
        assert_eq!(by_name("MaxEVA").efficiency_pct(), (56.0, 60.0));
        assert_eq!(by_name("GAMA").efficiency_pct(), (85.0, 85.0));
        assert!((by_name("AutoMM").utilization_pct() - 48.0).abs() < 0.1);
        assert!((by_name("GAMA").utilization_pct() - 94.7).abs() < 0.1);
        assert!(!by_name("GAMA").fused_bias_act);
        assert!(by_name("ARIES").auto_placement);
    }

    #[test]
    fn aie4ml_row_derives_efficiency() {
        // Paper: 160 TOPS sustained GEMM = 82.2% of INT8 peak, 296/304 tiles.
        let row = aie4ml_row(160.0, 296);
        let (lo, _) = row.efficiency_pct();
        assert!((lo - 82.2).abs() < 0.3, "eff {lo}");
        assert!((row.utilization_pct() - 97.4).abs() < 0.1);
        assert!(row.fused_bias_act && row.weights_on_aie && row.activations_on_aie);
    }

    #[test]
    fn only_aie4ml_is_fully_on_chip() {
        for r in prior_frameworks() {
            assert!(!(r.weights_on_aie && r.activations_on_aie), "{}", r.name);
        }
    }
}
