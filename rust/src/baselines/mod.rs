//! Analytical baselines for the paper's comparison tables.
//!
//! `frameworks` encodes the prior AIE-framework rows of Table IV;
//! `devices` the cross-architecture roofline models of Table V. In both
//! tables the AIE4ML row is produced by our simulator — only the
//! competitors are literature constants (documented per row).

pub mod devices;
pub mod frameworks;

pub use devices::{baseline_devices, DeviceRow};
pub use frameworks::{aie4ml_row, prior_frameworks, FrameworkRow};
