//! Cross-architecture device baselines (paper Table V).
//!
//! The paper benchmarks the 7-layer 512×512 INT8 MLP on a VU13P FPGA
//! (hls4ml), an NVIDIA RTX 3060 (TensorRT) and an Apple M4 ANE (Core ML).
//! We cannot run those devices here; per the substitution rule each is an
//! analytical roofline model — published INT8 peak × a sustained-efficiency
//! factor for this workload class, with the factors chosen so the model
//! reproduces the paper's *measured* throughputs and documented below.
//! The AIE4ML row comes from our simulator, not from a constant.


/// One cross-device comparison row.
#[derive(Debug, Clone)]
pub struct DeviceRow {
    pub device: &'static str,
    pub generation: &'static str,
    pub toolchain: &'static str,
    /// Theoretical INT8 peak, TOPS.
    pub peak_int8_tops: f64,
    /// Sustained-efficiency factor on batched dense INT8 MLP inference,
    /// derived from vendor-reported benchmarks of this workload class.
    pub sustained_efficiency: f64,
}

impl DeviceRow {
    /// Modeled sustained throughput on the 7-layer MLP workload.
    pub fn throughput_tops(&self) -> f64 {
        self.peak_int8_tops * self.sustained_efficiency
    }
}

/// Baseline devices of Table V.
///
/// Peaks: RTX 3060 ≈ 101 INT8 TOPS (dense, boost), VU13P ≈ 38 INT8 TOPS
/// (DSP-limited at 710 MHz), Apple M4 ANE = 38 TOPS (vendor figure).
/// Efficiency factors are the ratio measured/peak implied by the paper's
/// Table V numbers and are consistent with public TensorRT / hls4ml / Core
/// ML benchmarks of small dense MLPs, where launch overheads, memory-bound
/// GEMV phases and scheduling keep devices far from peak:
/// GPU 14.1/101 ≈ 0.14, FPGA 3.7/38 ≈ 0.10, ANE 10.5/38 ≈ 0.28.
pub fn baseline_devices() -> Vec<DeviceRow> {
    vec![
        DeviceRow {
            device: "VU13P FPGA",
            generation: "UltraScale+",
            toolchain: "hls4ml",
            peak_int8_tops: 38.0,
            sustained_efficiency: 0.0974,
        },
        DeviceRow {
            device: "Nvidia 3060 GPU",
            generation: "Ampere",
            toolchain: "TensorRT",
            peak_int8_tops: 101.0,
            sustained_efficiency: 0.1396,
        },
        DeviceRow {
            device: "Apple M4 ANE",
            generation: "2024",
            toolchain: "Core ML",
            peak_int8_tops: 38.0,
            sustained_efficiency: 0.2763,
        },
    ]
}

/// Paper-reported Table V throughputs, for the comparison harness.
pub fn paper_reported() -> Vec<(&'static str, f64)> {
    vec![
        ("Versal VEK280", 113.4),
        ("VU13P FPGA", 3.7),
        ("Nvidia 3060 GPU", 14.1),
        ("Apple M4 ANE", 10.5),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modeled_throughputs_match_paper_table5() {
        let rows = baseline_devices();
        let expect = [("VU13P FPGA", 3.7), ("Nvidia 3060 GPU", 14.1), ("Apple M4 ANE", 10.5)];
        for (name, tops) in expect {
            let row = rows.iter().find(|r| r.device == name).unwrap();
            assert!(
                (row.throughput_tops() - tops).abs() / tops < 0.02,
                "{name}: modeled {} vs paper {tops}",
                row.throughput_tops()
            );
        }
    }

    #[test]
    fn baselines_possess_lower_peaks_than_aie_ml() {
        // Paper: GPU/FPGA/ANE peaks are roughly 50%/19%/19% of AIE-ML's.
        let aie_peak = crate::arch::Device::vek280().peak_int8_tops();
        for r in baseline_devices() {
            assert!(r.peak_int8_tops < aie_peak * 0.55, "{}", r.device);
        }
    }
}
