//! Model JSON ingestion — the hls4ml-parser substitute.
//!
//! The paper reuses the hls4ml frontend to parse quantized Keras/PyTorch
//! models; our Python exporter (`python/compile/exporter.py`) plays the same
//! role and emits a neutral JSON description: layer list, shapes, power-of-two
//! quantizers, and the already-quantized integer weights. This module parses
//! that JSON (via the in-repo `util::json` parser) into the frontend graph
//! the Lowering pass consumes.

use crate::arch::Dtype;
use crate::ir::{Graph, OpKind, QuantSpec};
use crate::util::json::{JsonError, Value};
use std::path::Path;
use thiserror::Error;

#[derive(Debug, Error)]
pub enum FrontendError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("json error: {0}")]
    Json(#[from] JsonError),
    #[error("layer {layer}: unknown dtype '{dtype}'")]
    BadDtype { layer: String, dtype: String },
    #[error("layer {layer}: weights length {got}, expected {want} (= out_features x in_features)")]
    BadWeights { layer: String, got: usize, want: usize },
    #[error("layer {layer}: bias length {got}, expected {want}")]
    BadBias { layer: String, got: usize, want: usize },
    #[error("layer {layer}: unsupported layer type '{ty}'")]
    BadLayerType { layer: String, ty: String },
    #[error("model has no layers")]
    Empty,
}

/// JSON quantizer spec.
#[derive(Debug, Clone)]
pub struct JsonQuant {
    pub dtype: String,
    pub frac_bits: i32,
}

impl JsonQuant {
    pub fn new(dtype: &str, frac_bits: i32) -> JsonQuant {
        JsonQuant { dtype: dtype.to_string(), frac_bits }
    }

    pub fn to_spec(&self, layer: &str) -> Result<QuantSpec, FrontendError> {
        let dtype = Dtype::parse(&self.dtype).ok_or_else(|| FrontendError::BadDtype {
            layer: layer.to_string(),
            dtype: self.dtype.clone(),
        })?;
        Ok(QuantSpec::new(dtype, self.frac_bits))
    }

    fn from_json(v: &Value) -> Result<JsonQuant, FrontendError> {
        Ok(JsonQuant {
            dtype: v.field("dtype")?.as_str()?.to_string(),
            frac_bits: v.get("frac_bits").map(|x| x.as_i64()).transpose()? .unwrap_or(0) as i32,
        })
    }
}

/// Per-layer quantization block.
#[derive(Debug, Clone)]
pub struct JsonLayerQuant {
    pub input: JsonQuant,
    pub weight: JsonQuant,
    pub output: JsonQuant,
}

/// One layer entry.
#[derive(Debug, Clone)]
pub struct JsonLayer {
    pub name: String,
    pub ty: String,
    pub in_features: usize,
    pub out_features: usize,
    pub use_bias: bool,
    /// Separate ReLU after this layer (Lowering will fuse it).
    pub relu: bool,
    pub quant: JsonLayerQuant,
    /// Quantized integer weights, row-major [out_features][in_features].
    pub weights: Vec<i32>,
    /// Quantized integer bias at accumulator scale, length out_features.
    pub bias: Vec<i64>,
}

impl JsonLayer {
    /// Convenience constructor for a dense layer with uniform quantization —
    /// used pervasively by tests, benches and the synthetic-model builders.
    #[allow(clippy::too_many_arguments)]
    pub fn dense(
        name: &str,
        in_features: usize,
        out_features: usize,
        use_bias: bool,
        relu: bool,
        act_dtype: &str,
        wgt_dtype: &str,
        frac_bits: i32,
        weights: Vec<i32>,
        bias: Vec<i64>,
    ) -> JsonLayer {
        JsonLayer {
            name: name.to_string(),
            ty: "dense".to_string(),
            in_features,
            out_features,
            use_bias,
            relu,
            quant: JsonLayerQuant {
                input: JsonQuant::new(act_dtype, frac_bits),
                weight: JsonQuant::new(wgt_dtype, frac_bits),
                output: JsonQuant::new(act_dtype, frac_bits),
            },
            weights,
            bias,
        }
    }

    fn from_json(v: &Value) -> Result<JsonLayer, FrontendError> {
        let q = v.field("quant")?;
        let weights = match v.get("weights") {
            Some(arr) => {
                let arr = arr.as_array()?;
                let mut out = Vec::with_capacity(arr.len());
                for x in arr {
                    out.push(x.as_i64()? as i32);
                }
                out
            }
            None => Vec::new(),
        };
        let bias = match v.get("bias") {
            Some(arr) => {
                let arr = arr.as_array()?;
                let mut out = Vec::with_capacity(arr.len());
                for x in arr {
                    out.push(x.as_i64()?);
                }
                out
            }
            None => Vec::new(),
        };
        Ok(JsonLayer {
            name: v.field("name")?.as_str()?.to_string(),
            ty: v.field("type")?.as_str()?.to_string(),
            in_features: v.field("in_features")?.as_usize()?,
            out_features: v.field("out_features")?.as_usize()?,
            use_bias: v.get("use_bias").map(|x| x.as_bool()).transpose()?.unwrap_or(false),
            relu: v.get("relu").map(|x| x.as_bool()).transpose()?.unwrap_or(false),
            quant: JsonLayerQuant {
                input: JsonQuant::from_json(q.field("input")?)?,
                weight: JsonQuant::from_json(q.field("weight")?)?,
                output: JsonQuant::from_json(q.field("output")?)?,
            },
            weights,
            bias,
        })
    }
}

/// Top-level model description.
#[derive(Debug, Clone)]
pub struct JsonModel {
    pub name: String,
    pub device: Option<String>,
    pub layers: Vec<JsonLayer>,
}

impl JsonModel {
    pub fn new(name: &str, layers: Vec<JsonLayer>) -> JsonModel {
        JsonModel { name: name.to_string(), device: None, layers }
    }

    pub fn from_file(path: impl AsRef<Path>) -> Result<JsonModel, FrontendError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_str(&text)
    }

    pub fn from_str(text: &str) -> Result<JsonModel, FrontendError> {
        let v = Value::parse(text)?;
        let layers = v
            .field("layers")?
            .as_array()?
            .iter()
            .map(JsonLayer::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(JsonModel {
            name: v.field("name")?.as_str()?.to_string(),
            device: v.get("device").and_then(|d| d.as_str().ok()).map(str::to_string),
            layers,
        })
    }

    /// Serialize back to JSON (inverse of `from_str`; used to write model
    /// files and by round-trip tests).
    pub fn to_json_string(&self) -> String {
        use crate::util::json::obj;
        let layers: Vec<Value> = self
            .layers
            .iter()
            .map(|l| {
                let q = |j: &JsonQuant| {
                    obj([
                        ("dtype", Value::from(j.dtype.as_str())),
                        ("frac_bits", Value::from(j.frac_bits as i64)),
                    ])
                };
                obj([
                    ("name", Value::from(l.name.as_str())),
                    ("type", Value::from(l.ty.as_str())),
                    ("in_features", Value::from(l.in_features)),
                    ("out_features", Value::from(l.out_features)),
                    ("use_bias", Value::from(l.use_bias)),
                    ("relu", Value::from(l.relu)),
                    (
                        "quant",
                        obj([
                            ("input", q(&l.quant.input)),
                            ("weight", q(&l.quant.weight)),
                            ("output", q(&l.quant.output)),
                        ]),
                    ),
                    ("weights", Value::from(l.weights.clone())),
                    ("bias", Value::from(l.bias.clone())),
                ])
            })
            .collect();
        let mut fields = vec![
            ("name", Value::from(self.name.as_str())),
            ("layers", Value::Array(layers)),
        ];
        if let Some(d) = &self.device {
            fields.push(("device", Value::from(d.as_str())));
        }
        Value::Object(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
            .to_string_pretty()
    }

    /// Validate tensor sizes against declared shapes.
    pub fn validate(&self) -> Result<(), FrontendError> {
        if self.layers.is_empty() {
            return Err(FrontendError::Empty);
        }
        for l in &self.layers {
            if l.ty != "dense" {
                return Err(FrontendError::BadLayerType {
                    layer: l.name.clone(),
                    ty: l.ty.clone(),
                });
            }
            let want = l.in_features * l.out_features;
            if l.weights.len() != want {
                return Err(FrontendError::BadWeights {
                    layer: l.name.clone(),
                    got: l.weights.len(),
                    want,
                });
            }
            if l.use_bias && l.bias.len() != l.out_features {
                return Err(FrontendError::BadBias {
                    layer: l.name.clone(),
                    got: l.bias.len(),
                    want: l.out_features,
                });
            }
        }
        Ok(())
    }

    /// Build the frontend IR graph (ReLU still standalone; quantizers and
    /// weights attached to nodes; AIE attrs untouched).
    pub fn to_graph(&self) -> Result<Graph, FrontendError> {
        self.validate()?;
        let mut g = Graph::new();
        let input = g.add_node(
            "input",
            OpKind::Input { features: self.layers[0].in_features },
        );
        let mut prev = input;
        for l in &self.layers {
            let id = g.add_node(
                l.name.clone(),
                OpKind::Dense {
                    in_features: l.in_features,
                    out_features: l.out_features,
                    use_bias: l.use_bias,
                    fused_relu: false,
                },
            );
            {
                // Pre-populate quant attrs from the JSON; the Quantization
                // pass finalizes acc dtype and shift.
                let node = g.node_mut(id).unwrap();
                node.weights = l.weights.clone();
                node.bias = l.bias.clone();
                node.attrs.quant = Some(crate::ir::DenseQuant {
                    input: l.quant.input.to_spec(&l.name)?,
                    weight: l.quant.weight.to_spec(&l.name)?,
                    output: l.quant.output.to_spec(&l.name)?,
                    bias_dtype: Dtype::I32,
                    acc_dtype: Dtype::I32, // finalized by Quantization pass
                    shift: 0,              // finalized by Quantization pass
                });
            }
            g.connect(prev, id);
            prev = id;
            if l.relu {
                let r = g.add_node(format!("{}_relu", l.name), OpKind::ReLU);
                g.connect(prev, r);
                prev = r;
            }
        }
        let out = g.add_node("output", OpKind::Output);
        g.connect(prev, out);
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model() -> JsonModel {
        let mut m = JsonModel::new(
            "tiny",
            vec![JsonLayer::dense("fc1", 2, 2, true, true, "int8", "int8", 4, vec![1, 2, 3, 4], vec![10, -10])],
        );
        m.device = Some("vek280".into());
        m
    }

    #[test]
    fn parse_and_build() {
        // Round-trip through real JSON text, then build the graph.
        let text = tiny_model().to_json_string();
        let m = JsonModel::from_str(&text).unwrap();
        assert_eq!(m.device.as_deref(), Some("vek280"));
        let g = m.to_graph().unwrap();
        // input, fc1, fc1_relu, output
        assert_eq!(g.nodes.len(), 4);
        let dense = g.dense_order().unwrap();
        assert_eq!(dense.len(), 1);
        let n = g.node(dense[0]).unwrap();
        assert_eq!(n.weights, vec![1, 2, 3, 4]);
        assert_eq!(n.bias, vec![10, -10]);
        let q = n.attrs.quant.unwrap();
        assert_eq!(q.input.frac_bits, 4);
    }

    #[test]
    fn parse_from_raw_exporter_shape() {
        // The exact shape exporter.py writes.
        let text = r#"{
            "name": "raw", "device": "vek280",
            "layers": [{
                "name": "fc1", "type": "dense",
                "in_features": 2, "out_features": 1,
                "use_bias": true, "relu": false,
                "quant": {"input": {"dtype": "int8", "frac_bits": 6},
                          "weight": {"dtype": "int8", "frac_bits": 6},
                          "output": {"dtype": "int8", "frac_bits": 6}},
                "weights": [5, -3], "bias": [100]
            }]
        }"#;
        let m = JsonModel::from_str(text).unwrap();
        m.validate().unwrap();
        assert_eq!(m.layers[0].weights, vec![5, -3]);
        assert_eq!(m.layers[0].bias, vec![100]);
    }

    #[test]
    fn bad_weights_rejected() {
        let mut m = tiny_model();
        m.layers[0].weights.pop();
        assert!(matches!(m.validate(), Err(FrontendError::BadWeights { .. })));
    }

    #[test]
    fn bad_dtype_rejected() {
        let mut m = tiny_model();
        m.layers[0].quant.input.dtype = "fp8".into();
        assert!(m.to_graph().is_err());
    }

    #[test]
    fn bad_bias_rejected() {
        let mut m = tiny_model();
        m.layers[0].bias.push(0);
        assert!(matches!(m.validate(), Err(FrontendError::BadBias { .. })));
    }

    #[test]
    fn empty_rejected() {
        let m = JsonModel::new("x", vec![]);
        assert!(matches!(m.validate(), Err(FrontendError::Empty)));
    }

    #[test]
    fn json_roundtrip_preserves_payloads() {
        let m = tiny_model();
        let m2 = JsonModel::from_str(&m.to_json_string()).unwrap();
        assert_eq!(m2.layers[0].weights, m.layers[0].weights);
        assert_eq!(m2.layers[0].bias, m.layers[0].bias);
        assert_eq!(m2.layers[0].quant.weight.frac_bits, 4);
    }
}
