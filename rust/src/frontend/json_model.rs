//! Model JSON ingestion — the hls4ml-parser substitute.
//!
//! The paper reuses the hls4ml frontend to parse quantized Keras/PyTorch
//! models; our Python exporter (`python/compile/exporter.py`) plays the same
//! role and emits a neutral JSON description: layer list, shapes, power-of-two
//! quantizers, and the already-quantized integer weights. This module parses
//! that JSON (via the in-repo `util::json` parser) into the frontend graph
//! the Lowering pass consumes.

use crate::arch::Dtype;
use crate::ir::{Graph, OpKind, QuantSpec};
use crate::util::json::{JsonError, Value};
use std::path::Path;
use thiserror::Error;

#[derive(Debug, Error)]
pub enum FrontendError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("json error: {0}")]
    Json(#[from] JsonError),
    #[error("layer {layer}: unknown dtype '{dtype}'")]
    BadDtype { layer: String, dtype: String },
    #[error("layer {layer}: weights length {got}, expected {want} (= out_features x in_features)")]
    BadWeights { layer: String, got: usize, want: usize },
    #[error("layer {layer}: bias length {got}, expected {want}")]
    BadBias { layer: String, got: usize, want: usize },
    #[error(
        "layer {layer}: unknown layer kind '{ty}' (supported: dense, conv2d, maxpool2d, \
         avgpool2d, transpose, add, concat)"
    )]
    BadLayerType { layer: String, ty: String },
    #[error(
        "layer {layer}: a 'conv' window-geometry block is only valid on conv2d, maxpool2d, \
         avgpool2d and transpose layers, not on '{ty}'"
    )]
    ConvFieldOnNonConv { layer: String, ty: String },
    #[error("layer {layer}: layer kind '{ty}' requires a 'conv' window-geometry block")]
    MissingConvField { layer: String, ty: String },
    #[error("layer {layer}: {detail}")]
    BadTopology { layer: String, detail: String },
    #[error("model has no layers")]
    Empty,
}

/// JSON quantizer spec.
#[derive(Debug, Clone)]
pub struct JsonQuant {
    pub dtype: String,
    pub frac_bits: i32,
}

impl JsonQuant {
    pub fn new(dtype: &str, frac_bits: i32) -> JsonQuant {
        JsonQuant { dtype: dtype.to_string(), frac_bits }
    }

    pub fn to_spec(&self, layer: &str) -> Result<QuantSpec, FrontendError> {
        let dtype = Dtype::parse(&self.dtype).ok_or_else(|| FrontendError::BadDtype {
            layer: layer.to_string(),
            dtype: self.dtype.clone(),
        })?;
        Ok(QuantSpec::new(dtype, self.frac_bits))
    }

    fn from_json(v: &Value) -> Result<JsonQuant, FrontendError> {
        Ok(JsonQuant {
            dtype: v.field("dtype")?.as_str()?.to_string(),
            frac_bits: v.get("frac_bits").map(|x| x.as_i64()).transpose()? .unwrap_or(0) as i32,
        })
    }
}

/// Per-layer quantization block.
#[derive(Debug, Clone)]
pub struct JsonLayerQuant {
    pub input: JsonQuant,
    pub weight: JsonQuant,
    pub output: JsonQuant,
}

/// Window-geometry block for conv2d / pooling / transpose layers (the JSON
/// `"conv"` key). Conv layers use every field; pools ignore `out_c`
/// (channels are preserved); transpose reads `in_h`/`in_w` as its
/// `rows`/`cols` and ignores the window fields.
#[derive(Debug, Clone)]
pub struct JsonConv {
    pub in_h: usize,
    pub in_w: usize,
    pub in_c: usize,
    /// Conv output channels; 0 (absent) for pools and transpose.
    pub out_c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride_h: usize,
    pub stride_w: usize,
    /// `"same"` or `"valid"`.
    pub padding: String,
}

impl JsonConv {
    fn from_json(v: &Value) -> Result<JsonConv, FrontendError> {
        let u = |key: &str, default: usize| -> Result<usize, FrontendError> {
            Ok(v.get(key).map(|x| x.as_usize()).transpose()?.unwrap_or(default))
        };
        Ok(JsonConv {
            in_h: v.field("in_h")?.as_usize()?,
            in_w: v.field("in_w")?.as_usize()?,
            in_c: u("in_c", 1)?,
            out_c: u("out_c", 0)?,
            kh: u("kh", 1)?,
            kw: u("kw", 1)?,
            stride_h: u("stride_h", 1)?,
            stride_w: u("stride_w", 1)?,
            padding: v
                .get("padding")
                .map(|x| x.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_else(|| "valid".to_string()),
        })
    }

    fn to_json(&self) -> Value {
        use crate::util::json::obj;
        obj([
            ("in_h", Value::from(self.in_h)),
            ("in_w", Value::from(self.in_w)),
            ("in_c", Value::from(self.in_c)),
            ("out_c", Value::from(self.out_c)),
            ("kh", Value::from(self.kh)),
            ("kw", Value::from(self.kw)),
            ("stride_h", Value::from(self.stride_h)),
            ("stride_w", Value::from(self.stride_w)),
            ("padding", Value::from(self.padding.as_str())),
        ])
    }

    fn parse_padding(&self, layer: &str) -> Result<crate::ir::Padding, FrontendError> {
        crate::ir::Padding::parse(&self.padding).ok_or_else(|| FrontendError::BadTopology {
            layer: layer.to_string(),
            detail: format!("unknown padding '{}' (use 'same' or 'valid')", self.padding),
        })
    }
}

/// One layer entry.
///
/// `ty` is `"dense"`, `"conv2d"`, `"maxpool2d"`, `"avgpool2d"`,
/// `"transpose"`, `"add"` (residual merge) or `"concat"`. Windowed kinds
/// carry their NHWC geometry in the `conv` block. Layers wire
/// into a DAG through `inputs`: each entry names an earlier layer (its
/// post-activation output) or the literal `"input"` for the network input.
/// An empty `inputs` list means "the previous layer" — the chain default,
/// so exporter JSONs written before DAG support parse unchanged.
#[derive(Debug, Clone)]
pub struct JsonLayer {
    pub name: String,
    pub ty: String,
    pub in_features: usize,
    pub out_features: usize,
    pub use_bias: bool,
    /// Separate ReLU after this layer (Lowering will fuse it).
    pub relu: bool,
    pub quant: JsonLayerQuant,
    /// Quantized integer weights, row-major [out_features][in_features].
    pub weights: Vec<i32>,
    /// Quantized integer bias at accumulator scale, length out_features.
    pub bias: Vec<i64>,
    /// Producer layers feeding this one (empty = previous layer).
    pub inputs: Vec<String>,
    /// Window geometry — present exactly on conv2d/pool/transpose layers.
    pub conv: Option<JsonConv>,
}

impl JsonLayer {
    /// Convenience constructor for a dense layer with uniform quantization —
    /// used pervasively by tests, benches and the synthetic-model builders.
    #[allow(clippy::too_many_arguments)]
    pub fn dense(
        name: &str,
        in_features: usize,
        out_features: usize,
        use_bias: bool,
        relu: bool,
        act_dtype: &str,
        wgt_dtype: &str,
        frac_bits: i32,
        weights: Vec<i32>,
        bias: Vec<i64>,
    ) -> JsonLayer {
        JsonLayer {
            name: name.to_string(),
            ty: "dense".to_string(),
            in_features,
            out_features,
            use_bias,
            relu,
            quant: JsonLayerQuant {
                input: JsonQuant::new(act_dtype, frac_bits),
                weight: JsonQuant::new(wgt_dtype, frac_bits),
                output: JsonQuant::new(act_dtype, frac_bits),
            },
            weights,
            bias,
            inputs: Vec::new(),
            conv: None,
        }
    }

    /// Convenience constructor for a Conv2D layer (NHWC, HWIO-flattened
    /// weights `[out_c][kh*kw*in_c]`) with uniform quantization.
    #[allow(clippy::too_many_arguments)]
    pub fn conv2d(
        name: &str,
        conv: JsonConv,
        use_bias: bool,
        relu: bool,
        act_dtype: &str,
        wgt_dtype: &str,
        frac_bits: i32,
        weights: Vec<i32>,
        bias: Vec<i64>,
    ) -> JsonLayer {
        let in_features = conv.in_h * conv.in_w * conv.in_c;
        // Output dims mirror ir::Padding; validate() re-derives and checks.
        let out = |input: usize, kernel: usize, stride: usize| match conv.padding.as_str() {
            "same" => input.div_ceil(stride),
            _ => (input.saturating_sub(kernel)) / stride + 1,
        };
        let out_features =
            out(conv.in_h, conv.kh, conv.stride_h) * out(conv.in_w, conv.kw, conv.stride_w) * conv.out_c;
        JsonLayer {
            name: name.to_string(),
            ty: "conv2d".to_string(),
            in_features,
            out_features,
            use_bias,
            relu,
            quant: JsonLayerQuant {
                input: JsonQuant::new(act_dtype, frac_bits),
                weight: JsonQuant::new(wgt_dtype, frac_bits),
                output: JsonQuant::new(act_dtype, frac_bits),
            },
            weights,
            bias,
            inputs: Vec::new(),
            conv: Some(conv),
        }
    }

    /// Convenience constructor for a pooling layer (`ty` is `"maxpool2d"`
    /// or `"avgpool2d"`); channels are preserved, `conv.out_c` is ignored.
    pub fn pool2d(name: &str, ty: &str, conv: JsonConv, dtype: &str, frac_bits: i32) -> JsonLayer {
        let in_features = conv.in_h * conv.in_w * conv.in_c;
        let out = |input: usize, kernel: usize, stride: usize| match conv.padding.as_str() {
            "same" => input.div_ceil(stride),
            _ => (input.saturating_sub(kernel)) / stride + 1,
        };
        let out_features =
            out(conv.in_h, conv.kh, conv.stride_h) * out(conv.in_w, conv.kw, conv.stride_w) * conv.in_c;
        JsonLayer {
            name: name.to_string(),
            ty: ty.to_string(),
            in_features,
            out_features,
            use_bias: false,
            relu: false,
            quant: JsonLayerQuant {
                input: JsonQuant::new(dtype, frac_bits),
                weight: JsonQuant::new(dtype, frac_bits),
                output: JsonQuant::new(dtype, frac_bits),
            },
            weights: Vec::new(),
            bias: Vec::new(),
            inputs: Vec::new(),
            conv: Some(conv),
        }
    }

    /// Convenience constructor for a per-sample 2D transpose:
    /// `[rows, cols]` row-major → `[cols, rows]`.
    pub fn transpose(name: &str, rows: usize, cols: usize, dtype: &str, frac_bits: i32) -> JsonLayer {
        JsonLayer {
            name: name.to_string(),
            ty: "transpose".to_string(),
            in_features: rows * cols,
            out_features: rows * cols,
            use_bias: false,
            relu: false,
            quant: JsonLayerQuant {
                input: JsonQuant::new(dtype, frac_bits),
                weight: JsonQuant::new(dtype, frac_bits),
                output: JsonQuant::new(dtype, frac_bits),
            },
            weights: Vec::new(),
            bias: Vec::new(),
            inputs: Vec::new(),
            conv: Some(JsonConv {
                in_h: rows,
                in_w: cols,
                in_c: 1,
                out_c: 0,
                kh: 1,
                kw: 1,
                stride_h: 1,
                stride_w: 1,
                padding: "valid".to_string(),
            }),
        }
    }

    /// Rewire this layer to read from explicitly named producers (an earlier
    /// layer's name, or `"input"` for the network input).
    pub fn with_inputs(mut self, inputs: &[&str]) -> JsonLayer {
        self.inputs = inputs.iter().map(|s| s.to_string()).collect();
        self
    }

    fn merge(name: &str, ty: &str, features: usize, dtype: &str, frac_bits: i32, inputs: &[&str]) -> JsonLayer {
        JsonLayer {
            name: name.to_string(),
            ty: ty.to_string(),
            in_features: features,
            out_features: features,
            use_bias: false,
            relu: false,
            quant: JsonLayerQuant {
                input: JsonQuant::new(dtype, frac_bits),
                weight: JsonQuant::new(dtype, frac_bits),
                output: JsonQuant::new(dtype, frac_bits),
            },
            weights: Vec::new(),
            bias: Vec::new(),
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            conv: None,
        }
    }

    /// A residual fan-in: elementwise add of `inputs`, each `features` wide.
    pub fn residual_add(name: &str, features: usize, dtype: &str, frac_bits: i32, inputs: &[&str]) -> JsonLayer {
        Self::merge(name, "add", features, dtype, frac_bits, inputs)
    }

    /// A feature concatenation of `inputs`; `features` is the total width.
    pub fn concat(name: &str, features: usize, dtype: &str, frac_bits: i32, inputs: &[&str]) -> JsonLayer {
        Self::merge(name, "concat", features, dtype, frac_bits, inputs)
    }

    fn from_json(v: &Value) -> Result<JsonLayer, FrontendError> {
        let q = v.field("quant")?;
        let weights = match v.get("weights") {
            Some(arr) => {
                let arr = arr.as_array()?;
                let mut out = Vec::with_capacity(arr.len());
                for x in arr {
                    out.push(x.as_i64()? as i32);
                }
                out
            }
            None => Vec::new(),
        };
        let bias = match v.get("bias") {
            Some(arr) => {
                let arr = arr.as_array()?;
                let mut out = Vec::with_capacity(arr.len());
                for x in arr {
                    out.push(x.as_i64()?);
                }
                out
            }
            None => Vec::new(),
        };
        let inputs = match v.get("inputs") {
            Some(arr) => {
                let arr = arr.as_array()?;
                let mut out = Vec::with_capacity(arr.len());
                for x in arr {
                    out.push(x.as_str()?.to_string());
                }
                out
            }
            None => Vec::new(),
        };
        Ok(JsonLayer {
            name: v.field("name")?.as_str()?.to_string(),
            ty: v.field("type")?.as_str()?.to_string(),
            in_features: v.field("in_features")?.as_usize()?,
            out_features: v.field("out_features")?.as_usize()?,
            use_bias: v.get("use_bias").map(|x| x.as_bool()).transpose()?.unwrap_or(false),
            relu: v.get("relu").map(|x| x.as_bool()).transpose()?.unwrap_or(false),
            quant: JsonLayerQuant {
                input: JsonQuant::from_json(q.field("input")?)?,
                weight: JsonQuant::from_json(q.field("weight")?)?,
                output: JsonQuant::from_json(q.field("output")?)?,
            },
            weights,
            bias,
            inputs,
            conv: v.get("conv").map(JsonConv::from_json).transpose()?,
        })
    }

    /// IR conv attributes for a `conv2d` layer (geometry checked by
    /// [`JsonModel::validate`]).
    pub(crate) fn conv_attrs(&self) -> Result<crate::ir::Conv2DAttrs, FrontendError> {
        let c = self.conv.as_ref().ok_or_else(|| FrontendError::MissingConvField {
            layer: self.name.clone(),
            ty: self.ty.clone(),
        })?;
        Ok(crate::ir::Conv2DAttrs {
            in_h: c.in_h,
            in_w: c.in_w,
            in_c: c.in_c,
            out_c: c.out_c,
            kh: c.kh,
            kw: c.kw,
            stride_h: c.stride_h,
            stride_w: c.stride_w,
            padding: c.parse_padding(&self.name)?,
            use_bias: self.use_bias,
            fused_relu: false,
        })
    }

    /// IR pool attributes for a `maxpool2d`/`avgpool2d` layer.
    pub(crate) fn pool_attrs(&self) -> Result<crate::ir::Pool2DAttrs, FrontendError> {
        let c = self.conv.as_ref().ok_or_else(|| FrontendError::MissingConvField {
            layer: self.name.clone(),
            ty: self.ty.clone(),
        })?;
        Ok(crate::ir::Pool2DAttrs {
            in_h: c.in_h,
            in_w: c.in_w,
            c: c.in_c,
            kh: c.kh,
            kw: c.kw,
            stride_h: c.stride_h,
            stride_w: c.stride_w,
            padding: c.parse_padding(&self.name)?,
        })
    }
}

/// Top-level model description.
#[derive(Debug, Clone)]
pub struct JsonModel {
    pub name: String,
    pub device: Option<String>,
    pub layers: Vec<JsonLayer>,
}

impl JsonModel {
    pub fn new(name: &str, layers: Vec<JsonLayer>) -> JsonModel {
        JsonModel { name: name.to_string(), device: None, layers }
    }

    pub fn from_file(path: impl AsRef<Path>) -> Result<JsonModel, FrontendError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_str(&text)
    }

    pub fn from_str(text: &str) -> Result<JsonModel, FrontendError> {
        let v = Value::parse(text)?;
        let layers = v
            .field("layers")?
            .as_array()?
            .iter()
            .map(JsonLayer::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(JsonModel {
            name: v.field("name")?.as_str()?.to_string(),
            device: v.get("device").and_then(|d| d.as_str().ok()).map(str::to_string),
            layers,
        })
    }

    /// Serialize back to JSON (inverse of `from_str`; used to write model
    /// files and by round-trip tests).
    pub fn to_json_string(&self) -> String {
        use crate::util::json::obj;
        let layers: Vec<Value> = self
            .layers
            .iter()
            .map(|l| {
                let q = |j: &JsonQuant| {
                    obj([
                        ("dtype", Value::from(j.dtype.as_str())),
                        ("frac_bits", Value::from(j.frac_bits as i64)),
                    ])
                };
                let mut layer = obj([
                    ("name", Value::from(l.name.as_str())),
                    ("type", Value::from(l.ty.as_str())),
                    ("in_features", Value::from(l.in_features)),
                    ("out_features", Value::from(l.out_features)),
                    ("use_bias", Value::from(l.use_bias)),
                    ("relu", Value::from(l.relu)),
                    (
                        "quant",
                        obj([
                            ("input", q(&l.quant.input)),
                            ("weight", q(&l.quant.weight)),
                            ("output", q(&l.quant.output)),
                        ]),
                    ),
                    ("weights", Value::from(l.weights.clone())),
                    ("bias", Value::from(l.bias.clone())),
                ]);
                // Only DAG layers carry `inputs` — chain JSONs stay
                // byte-identical to what pre-DAG exporters wrote. The same
                // goes for the `conv` geometry block: only windowed layers
                // write it, so pre-conv model files round-trip unchanged.
                if let Value::Object(fields) = &mut layer {
                    if !l.inputs.is_empty() {
                        fields.insert("inputs".to_string(), Value::from(l.inputs.clone()));
                    }
                    if let Some(c) = &l.conv {
                        fields.insert("conv".to_string(), c.to_json());
                    }
                }
                layer
            })
            .collect();
        let mut fields = vec![
            ("name", Value::from(self.name.as_str())),
            ("layers", Value::Array(layers)),
        ];
        if let Some(d) = &self.device {
            fields.push(("device", Value::from(d.as_str())));
        }
        Value::Object(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
            .to_string_pretty()
    }

    /// Validate tensor sizes against declared shapes and the DAG wiring
    /// (merge arity, payload-free merges, unique layer names).
    pub fn validate(&self) -> Result<(), FrontendError> {
        if self.layers.is_empty() {
            return Err(FrontendError::Empty);
        }
        if self.layers[0].ty != "dense" && self.layers[0].ty != "conv2d" {
            return Err(FrontendError::BadTopology {
                layer: self.layers[0].name.clone(),
                detail: "the first layer must be dense or conv2d (it consumes the network input)"
                    .into(),
            });
        }
        let mut names = std::collections::HashSet::new();
        for l in &self.layers {
            if !names.insert(l.name.as_str()) || l.name == "input" {
                return Err(FrontendError::BadTopology {
                    layer: l.name.clone(),
                    detail: "layer names must be unique and must not shadow 'input'".into(),
                });
            }
            match l.ty.as_str() {
                "dense" => {
                    if l.conv.is_some() {
                        return Err(FrontendError::ConvFieldOnNonConv {
                            layer: l.name.clone(),
                            ty: l.ty.clone(),
                        });
                    }
                    if l.inputs.len() > 1 {
                        return Err(FrontendError::BadTopology {
                            layer: l.name.clone(),
                            detail: format!("dense layers take one input, found {}", l.inputs.len()),
                        });
                    }
                    let want = l.in_features * l.out_features;
                    if l.weights.len() != want {
                        return Err(FrontendError::BadWeights {
                            layer: l.name.clone(),
                            got: l.weights.len(),
                            want,
                        });
                    }
                    if l.use_bias && l.bias.len() != l.out_features {
                        return Err(FrontendError::BadBias {
                            layer: l.name.clone(),
                            got: l.bias.len(),
                            want: l.out_features,
                        });
                    }
                }
                "conv2d" => {
                    let c = l.conv_attrs()?;
                    if l.inputs.len() > 1 {
                        return Err(FrontendError::BadTopology {
                            layer: l.name.clone(),
                            detail: format!("conv2d layers take one input, found {}", l.inputs.len()),
                        });
                    }
                    if c.out_c == 0 {
                        return Err(FrontendError::BadTopology {
                            layer: l.name.clone(),
                            detail: "conv2d requires out_c > 0 in its 'conv' block".into(),
                        });
                    }
                    if l.in_features != c.in_features() || l.out_features != c.out_features() {
                        return Err(FrontendError::BadTopology {
                            layer: l.name.clone(),
                            detail: format!(
                                "declared features {}→{} disagree with the conv geometry \
                                 {}→{} (flattened NHWC)",
                                l.in_features,
                                l.out_features,
                                c.in_features(),
                                c.out_features()
                            ),
                        });
                    }
                    // HWIO-flattened weights: [out_c][kh*kw*in_c].
                    let want = c.out_c * c.patch_len();
                    if l.weights.len() != want {
                        return Err(FrontendError::BadWeights {
                            layer: l.name.clone(),
                            got: l.weights.len(),
                            want,
                        });
                    }
                    if l.use_bias && l.bias.len() != c.out_c {
                        return Err(FrontendError::BadBias {
                            layer: l.name.clone(),
                            got: l.bias.len(),
                            want: c.out_c,
                        });
                    }
                }
                "maxpool2d" | "avgpool2d" => {
                    let p = l.pool_attrs()?;
                    if l.inputs.len() > 1 {
                        return Err(FrontendError::BadTopology {
                            layer: l.name.clone(),
                            detail: format!("{} layers take one input, found {}", l.ty, l.inputs.len()),
                        });
                    }
                    if !l.weights.is_empty() || !l.bias.is_empty() || l.use_bias || l.relu {
                        return Err(FrontendError::BadTopology {
                            layer: l.name.clone(),
                            detail: "pooling layers carry no weights, bias or activation".into(),
                        });
                    }
                    if l.in_features != p.in_features() || l.out_features != p.out_features() {
                        return Err(FrontendError::BadTopology {
                            layer: l.name.clone(),
                            detail: format!(
                                "declared features {}→{} disagree with the pool geometry \
                                 {}→{} (flattened NHWC)",
                                l.in_features,
                                l.out_features,
                                p.in_features(),
                                p.out_features()
                            ),
                        });
                    }
                }
                "transpose" => {
                    let c = l.conv.as_ref().ok_or_else(|| FrontendError::MissingConvField {
                        layer: l.name.clone(),
                        ty: l.ty.clone(),
                    })?;
                    if l.inputs.len() > 1 {
                        return Err(FrontendError::BadTopology {
                            layer: l.name.clone(),
                            detail: format!("transpose layers take one input, found {}", l.inputs.len()),
                        });
                    }
                    if !l.weights.is_empty() || !l.bias.is_empty() || l.use_bias || l.relu {
                        return Err(FrontendError::BadTopology {
                            layer: l.name.clone(),
                            detail: "transpose layers carry no weights, bias or activation".into(),
                        });
                    }
                    let (rows, cols) = (c.in_h, c.in_w);
                    if l.in_features != rows * cols || l.out_features != rows * cols {
                        return Err(FrontendError::BadTopology {
                            layer: l.name.clone(),
                            detail: format!(
                                "transpose of a {rows}x{cols} matrix needs in/out features {} \
                                 (found {}→{})",
                                rows * cols,
                                l.in_features,
                                l.out_features
                            ),
                        });
                    }
                }
                "add" | "concat" => {
                    if l.conv.is_some() {
                        return Err(FrontendError::ConvFieldOnNonConv {
                            layer: l.name.clone(),
                            ty: l.ty.clone(),
                        });
                    }
                    if l.inputs.len() < 2 {
                        return Err(FrontendError::BadTopology {
                            layer: l.name.clone(),
                            detail: format!(
                                "{} merges need at least two inputs, found {}",
                                l.ty,
                                l.inputs.len()
                            ),
                        });
                    }
                    if !l.weights.is_empty() || !l.bias.is_empty() || l.use_bias || l.relu {
                        return Err(FrontendError::BadTopology {
                            layer: l.name.clone(),
                            detail: "merge layers carry no weights, bias or activation".into(),
                        });
                    }
                    if l.ty == "add" && l.in_features != l.out_features {
                        return Err(FrontendError::BadTopology {
                            layer: l.name.clone(),
                            detail: "add merges preserve width (in_features == out_features)".into(),
                        });
                    }
                    // The declared merge quantization must match every
                    // producer's store spec (the raw input's spec for
                    // "input" arms) — the buffer cannot reconcile binary
                    // points, and the backends derive the spec from the
                    // producers, so a mismatched declaration would be a
                    // silent lie otherwise.
                    for src in &l.inputs {
                        let produced = if src == "input" {
                            Some(&self.layers[0].quant.input)
                        } else {
                            self.layers
                                .iter()
                                .take_while(|p| p.name != l.name)
                                .find(|p| &p.name == src)
                                .map(|p| &p.quant.output)
                        };
                        // Unknown names are reported by to_graph with a
                        // better message, and unknown dtype spellings by
                        // to_spec; only check resolvable, parseable arms.
                        if let Some(produced) = produced {
                            let same_dtype = match (
                                Dtype::parse(&produced.dtype),
                                Dtype::parse(&l.quant.output.dtype),
                            ) {
                                (Some(a), Some(b)) => a == b,
                                _ => true,
                            };
                            if !same_dtype || produced.frac_bits != l.quant.output.frac_bits {
                                return Err(FrontendError::BadTopology {
                                    layer: l.name.clone(),
                                    detail: format!(
                                        "input '{src}' quantization disagrees with the merge \
                                         ({} frac {} vs declared {} frac {})",
                                        produced.dtype,
                                        produced.frac_bits,
                                        l.quant.output.dtype,
                                        l.quant.output.frac_bits
                                    ),
                                });
                            }
                        }
                    }
                }
                _ => {
                    return Err(FrontendError::BadLayerType {
                        layer: l.name.clone(),
                        ty: l.ty.clone(),
                    })
                }
            }
        }
        Ok(())
    }

    /// Effective producers of every layer with the chain default resolved:
    /// an empty `inputs` list means the previous layer (the literal
    /// `"input"` for layer 0). This is the single statement of the wiring
    /// rule — [`JsonModel::to_graph`] connects exactly these edges, and the
    /// partitioner's cut search computes liveness over the same lists.
    pub fn effective_inputs(&self) -> Vec<Vec<String>> {
        self.layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                if !l.inputs.is_empty() {
                    l.inputs.clone()
                } else if i == 0 {
                    vec!["input".to_string()]
                } else {
                    vec![self.layers[i - 1].name.clone()]
                }
            })
            .collect()
    }

    /// Names of the model's sinks (layers no other layer consumes), in
    /// layer order — the network outputs, matching the graph's
    /// [`crate::ir::Graph::output_producers`] for JSON-built graphs.
    pub fn sink_names(&self) -> Vec<String> {
        let inputs = self.effective_inputs();
        self.layers
            .iter()
            .filter(|l| !inputs.iter().any(|ins| ins.iter().any(|s| s == &l.name)))
            .map(|l| l.name.clone())
            .collect()
    }

    /// Build the frontend IR graph (ReLU still standalone; quantizers and
    /// weights attached to nodes; AIE attrs untouched).
    ///
    /// Layers wire by their [`JsonModel::effective_inputs`]: an empty
    /// `inputs` list chains onto the previous layer; explicit entries
    /// resolve to earlier layers' post-activation outputs (or `"input"`),
    /// so fan-out and fan-in topologies are expressible while chain JSONs
    /// build the same graph as before. The last layer is the network
    /// output.
    pub fn to_graph(&self) -> Result<Graph, FrontendError> {
        self.validate()?;
        let mut g = Graph::new();
        let input = g.add_node(
            "input",
            OpKind::Input { features: self.layers[0].in_features },
        );
        // Layer name -> the node carrying its output (the ReLU node when a
        // separate activation follows, so consumers see post-activation data).
        let mut handles: std::collections::HashMap<&str, usize> = std::collections::HashMap::new();
        let mut prev = input;
        let effective = self.effective_inputs();
        for (l, srcs) in self.layers.iter().zip(&effective) {
            let id = match l.ty.as_str() {
                "dense" => {
                    let id = g.add_node(
                        l.name.clone(),
                        OpKind::Dense {
                            in_features: l.in_features,
                            out_features: l.out_features,
                            use_bias: l.use_bias,
                            fused_relu: false,
                        },
                    );
                    // Pre-populate quant attrs from the JSON; the Quantization
                    // pass finalizes acc dtype and shift.
                    let node = g.node_mut(id).unwrap();
                    node.weights = l.weights.clone();
                    node.bias = l.bias.clone();
                    node.attrs.quant = Some(crate::ir::DenseQuant {
                        input: l.quant.input.to_spec(&l.name)?,
                        weight: l.quant.weight.to_spec(&l.name)?,
                        output: l.quant.output.to_spec(&l.name)?,
                        bias_dtype: Dtype::I32,
                        acc_dtype: Dtype::I32, // finalized by Quantization pass
                        shift: 0,              // finalized by Quantization pass
                    });
                    id
                }
                "conv2d" => {
                    let id = g.add_node(l.name.clone(), OpKind::Conv2D(l.conv_attrs()?));
                    let node = g.node_mut(id).unwrap();
                    node.weights = l.weights.clone();
                    node.bias = l.bias.clone();
                    node.attrs.quant = Some(crate::ir::DenseQuant {
                        input: l.quant.input.to_spec(&l.name)?,
                        weight: l.quant.weight.to_spec(&l.name)?,
                        output: l.quant.output.to_spec(&l.name)?,
                        bias_dtype: Dtype::I32,
                        acc_dtype: Dtype::I32, // finalized by Quantization pass
                        shift: 0,              // finalized by Quantization pass
                    });
                    id
                }
                "maxpool2d" => g.add_node(l.name.clone(), OpKind::MaxPool2D(l.pool_attrs()?)),
                "avgpool2d" => g.add_node(l.name.clone(), OpKind::AvgPool2D(l.pool_attrs()?)),
                "transpose" => {
                    let c = l.conv.as_ref().expect("validate() requires the conv block");
                    g.add_node(l.name.clone(), OpKind::Transpose { rows: c.in_h, cols: c.in_w })
                }
                "add" => g.add_node(l.name.clone(), OpKind::Add { features: l.out_features }),
                _ => g.add_node(l.name.clone(), OpKind::Concat { features: l.out_features }),
            };
            for src in srcs {
                let from = if src == "input" {
                    input
                } else {
                    *handles.get(src.as_str()).ok_or_else(|| FrontendError::BadTopology {
                        layer: l.name.clone(),
                        detail: format!(
                            "unknown input '{src}' (inputs must name an earlier layer or 'input')"
                        ),
                    })?
                };
                g.connect(from, id);
            }
            prev = id;
            if l.relu {
                let r = g.add_node(format!("{}_relu", l.name), OpKind::ReLU);
                g.connect(prev, r);
                prev = r;
            }
            handles.insert(l.name.as_str(), prev);
        }
        let out = g.add_node("output", OpKind::Output);
        g.connect(prev, out);
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model() -> JsonModel {
        let mut m = JsonModel::new(
            "tiny",
            vec![JsonLayer::dense("fc1", 2, 2, true, true, "int8", "int8", 4, vec![1, 2, 3, 4], vec![10, -10])],
        );
        m.device = Some("vek280".into());
        m
    }

    #[test]
    fn parse_and_build() {
        // Round-trip through real JSON text, then build the graph.
        let text = tiny_model().to_json_string();
        let m = JsonModel::from_str(&text).unwrap();
        assert_eq!(m.device.as_deref(), Some("vek280"));
        let g = m.to_graph().unwrap();
        // input, fc1, fc1_relu, output
        assert_eq!(g.nodes.len(), 4);
        let dense = g.dense_order().unwrap();
        assert_eq!(dense.len(), 1);
        let n = g.node(dense[0]).unwrap();
        assert_eq!(n.weights, vec![1, 2, 3, 4]);
        assert_eq!(n.bias, vec![10, -10]);
        let q = n.attrs.quant.unwrap();
        assert_eq!(q.input.frac_bits, 4);
    }

    #[test]
    fn parse_from_raw_exporter_shape() {
        // The exact shape exporter.py writes.
        let text = r#"{
            "name": "raw", "device": "vek280",
            "layers": [{
                "name": "fc1", "type": "dense",
                "in_features": 2, "out_features": 1,
                "use_bias": true, "relu": false,
                "quant": {"input": {"dtype": "int8", "frac_bits": 6},
                          "weight": {"dtype": "int8", "frac_bits": 6},
                          "output": {"dtype": "int8", "frac_bits": 6}},
                "weights": [5, -3], "bias": [100]
            }]
        }"#;
        let m = JsonModel::from_str(text).unwrap();
        m.validate().unwrap();
        assert_eq!(m.layers[0].weights, vec![5, -3]);
        assert_eq!(m.layers[0].bias, vec![100]);
    }

    #[test]
    fn bad_weights_rejected() {
        let mut m = tiny_model();
        m.layers[0].weights.pop();
        assert!(matches!(m.validate(), Err(FrontendError::BadWeights { .. })));
    }

    #[test]
    fn bad_dtype_rejected() {
        let mut m = tiny_model();
        m.layers[0].quant.input.dtype = "fp8".into();
        assert!(m.to_graph().is_err());
    }

    #[test]
    fn bad_bias_rejected() {
        let mut m = tiny_model();
        m.layers[0].bias.push(0);
        assert!(matches!(m.validate(), Err(FrontendError::BadBias { .. })));
    }

    #[test]
    fn empty_rejected() {
        let m = JsonModel::new("x", vec![]);
        assert!(matches!(m.validate(), Err(FrontendError::Empty)));
    }

    #[test]
    fn json_roundtrip_preserves_payloads() {
        let m = tiny_model();
        let m2 = JsonModel::from_str(&m.to_json_string()).unwrap();
        assert_eq!(m2.layers[0].weights, m.layers[0].weights);
        assert_eq!(m2.layers[0].bias, m.layers[0].bias);
        assert_eq!(m2.layers[0].quant.weight.frac_bits, 4);
    }

    fn residual_model() -> JsonModel {
        JsonModel::new(
            "res",
            vec![
                JsonLayer::dense("fc1", 4, 8, true, true, "int8", "int8", 4, vec![1; 32], vec![0; 8]),
                JsonLayer::dense("fc2", 8, 4, true, false, "int8", "int8", 4, vec![1; 32], vec![0; 4]),
                JsonLayer::residual_add("res", 4, "int8", 4, &["input", "fc2"]),
                JsonLayer::dense("head", 4, 2, false, false, "int8", "int8", 4, vec![1; 8], vec![])
                    .with_inputs(&["res"]),
            ],
        )
    }

    #[test]
    fn residual_json_builds_dag() {
        let m = residual_model();
        m.validate().unwrap();
        let g = m.to_graph().unwrap();
        // input, fc1, fc1_relu, fc2, res, head, output.
        assert_eq!(g.nodes.len(), 7);
        g.validate_shapes().unwrap();
        assert_eq!(g.input_features().unwrap(), 4);
        assert_eq!(g.output_features().unwrap(), 2);
        // The merge has two predecessors: the network input and fc2.
        let res = g.nodes.iter().find(|n| n.name == "res").unwrap().id;
        assert_eq!(g.predecessors(res).len(), 2);
        // Fan-out: input feeds fc1 and the merge.
        assert_eq!(g.successors(0).len(), 2);
    }

    #[test]
    fn dag_json_roundtrips_inputs() {
        let m = residual_model();
        let m2 = JsonModel::from_str(&m.to_json_string()).unwrap();
        assert_eq!(m2.layers[2].ty, "add");
        assert_eq!(m2.layers[2].inputs, vec!["input", "fc2"]);
        assert_eq!(m2.layers[3].inputs, vec!["res"]);
        m2.to_graph().unwrap();
        // Chain layers keep writing no `inputs` key at all.
        assert!(!tiny_model().to_json_string().contains("inputs"));
    }

    #[test]
    fn effective_inputs_and_sinks_resolve_chain_defaults() {
        // The single wiring rule shared by to_graph and the partitioner's
        // cut search: empty `inputs` means the previous layer.
        let m = residual_model();
        assert_eq!(
            m.effective_inputs(),
            vec![
                vec!["input".to_string()],
                vec!["fc1".to_string()],
                vec!["input".to_string(), "fc2".to_string()],
                vec!["res".to_string()],
            ]
        );
        assert_eq!(m.sink_names(), vec!["head"]);
        // Multi-sink: two unconsumed layers surface in layer order.
        let mut two = residual_model();
        two.layers.push(
            JsonLayer::dense("aux", 4, 3, false, false, "int8", "int8", 4, vec![1; 12], vec![])
                .with_inputs(&["res"]),
        );
        assert_eq!(two.sink_names(), vec!["head", "aux"]);
    }

    #[test]
    fn unknown_input_rejected() {
        let mut m = residual_model();
        m.layers[3].inputs = vec!["nonexistent".into()];
        assert!(matches!(m.to_graph(), Err(FrontendError::BadTopology { .. })));
    }

    #[test]
    fn merge_arity_and_payload_rejected() {
        let mut m = residual_model();
        m.layers[2].inputs = vec!["fc2".into()];
        assert!(matches!(m.validate(), Err(FrontendError::BadTopology { .. })));
        let mut m = residual_model();
        m.layers[2].weights = vec![1];
        assert!(matches!(m.validate(), Err(FrontendError::BadTopology { .. })));
    }

    #[test]
    fn duplicate_layer_name_rejected() {
        let mut m = residual_model();
        m.layers[1].name = "fc1".into();
        assert!(matches!(m.validate(), Err(FrontendError::BadTopology { .. })));
    }

    fn small_conv() -> JsonConv {
        JsonConv {
            in_h: 4,
            in_w: 4,
            in_c: 2,
            out_c: 3,
            kh: 3,
            kw: 3,
            stride_h: 1,
            stride_w: 1,
            padding: "same".to_string(),
        }
    }

    fn conv_model() -> JsonModel {
        // conv 4x4x2 -> 4x4x3 (same) -> maxpool 2x2/2 -> dense head.
        let conv = small_conv();
        let pool = JsonConv {
            in_c: 3,
            out_c: 0,
            kh: 2,
            kw: 2,
            stride_h: 2,
            stride_w: 2,
            padding: "valid".into(),
            ..conv.clone()
        };
        JsonModel::new(
            "cnn",
            vec![
                JsonLayer::conv2d("c1", conv, true, true, "int8", "int8", 4, vec![1; 3 * 18], vec![0; 3]),
                JsonLayer::pool2d("p1", "maxpool2d", pool, "int8", 4),
                JsonLayer::dense("head", 12, 5, false, false, "int8", "int8", 4, vec![1; 60], vec![]),
            ],
        )
    }

    #[test]
    fn conv_model_validates_builds_and_roundtrips() {
        let m = conv_model();
        m.validate().unwrap();
        assert_eq!(m.layers[0].in_features, 32);
        assert_eq!(m.layers[0].out_features, 48); // 4x4 'same' x 3 channels
        assert_eq!(m.layers[1].out_features, 12); // 2x2 x 3 channels
        let g = m.to_graph().unwrap();
        g.validate_shapes().unwrap();
        // input, c1, c1_relu, p1, head, output.
        assert_eq!(g.nodes.len(), 6);
        let m2 = JsonModel::from_str(&m.to_json_string()).unwrap();
        let c = m2.layers[0].conv.as_ref().unwrap();
        assert_eq!((c.kh, c.kw, c.out_c, c.padding.as_str()), (3, 3, 3, "same"));
        m2.to_graph().unwrap();
        // Dense-only models keep writing no `conv` key at all.
        assert!(!tiny_model().to_json_string().contains("\"conv\""));
    }

    #[test]
    fn unknown_layer_kind_names_layer_and_lists_supported() {
        let mut m = tiny_model();
        m.layers[0].ty = "conv3d".into();
        let err = m.validate().unwrap_err();
        assert!(matches!(&err, FrontendError::BadLayerType { layer, ty } if layer == "fc1" && ty == "conv3d"));
        let msg = err.to_string();
        assert!(msg.contains("fc1"), "{msg}");
        for kind in ["dense", "conv2d", "maxpool2d", "avgpool2d", "transpose", "add", "concat"] {
            assert!(msg.contains(kind), "missing '{kind}' in: {msg}");
        }
    }

    #[test]
    fn conv_field_on_non_conv_layer_rejected() {
        let mut m = tiny_model();
        m.layers[0].conv = Some(small_conv());
        let err = m.validate().unwrap_err();
        assert!(
            matches!(&err, FrontendError::ConvFieldOnNonConv { layer, ty } if layer == "fc1" && ty == "dense"),
            "{err}"
        );
        assert!(err.to_string().contains("fc1"));
        // Same on merges.
        let mut m = residual_model();
        m.layers[2].conv = Some(small_conv());
        assert!(matches!(m.validate(), Err(FrontendError::ConvFieldOnNonConv { .. })));
    }

    #[test]
    fn conv_layer_without_geometry_rejected() {
        let mut m = conv_model();
        m.layers[0].conv = None;
        assert!(matches!(
            m.validate(),
            Err(FrontendError::MissingConvField { layer, ty }) if layer == "c1" && ty == "conv2d"
        ));
        let mut m = conv_model();
        m.layers[1].conv = None;
        assert!(matches!(m.validate(), Err(FrontendError::MissingConvField { .. })));
    }

    #[test]
    fn conv_shape_and_payload_mismatches_rejected() {
        // Wrong weight count for the HWIO layout.
        let mut m = conv_model();
        m.layers[0].weights.pop();
        assert!(matches!(m.validate(), Err(FrontendError::BadWeights { want: 54, .. })));
        // Declared features disagree with the geometry.
        let mut m = conv_model();
        m.layers[0].out_features = 47;
        assert!(matches!(m.validate(), Err(FrontendError::BadTopology { .. })));
        // Bad padding spelling.
        let mut m = conv_model();
        m.layers[0].conv.as_mut().unwrap().padding = "full".into();
        assert!(matches!(m.validate(), Err(FrontendError::BadTopology { .. })));
        // Pool layers carry no payload.
        let mut m = conv_model();
        m.layers[1].relu = true;
        assert!(matches!(m.validate(), Err(FrontendError::BadTopology { .. })));
    }

    #[test]
    fn transpose_layer_parses_and_checks_shape() {
        let m = JsonModel::new(
            "tr",
            vec![
                JsonLayer::dense("fc", 6, 12, false, false, "int8", "int8", 0, vec![1; 72], vec![]),
                JsonLayer::transpose("t", 3, 4, "int8", 0),
                JsonLayer::dense("head", 12, 2, false, false, "int8", "int8", 0, vec![1; 24], vec![]),
            ],
        );
        m.validate().unwrap();
        let g = m.to_graph().unwrap();
        g.validate_shapes().unwrap();
        let mut bad = m.clone();
        bad.layers[1].in_features = 13;
        assert!(matches!(bad.validate(), Err(FrontendError::BadTopology { .. })));
    }

    #[test]
    fn concat_layer_parses() {
        let m = JsonModel::new(
            "cat",
            vec![
                JsonLayer::dense("a", 4, 4, false, false, "int8", "int8", 0, vec![1; 16], vec![]),
                JsonLayer::dense("b", 4, 2, false, false, "int8", "int8", 0, vec![1; 8], vec![])
                    .with_inputs(&["input"]),
                JsonLayer::concat("cat", 6, "int8", 0, &["a", "b"]),
            ],
        );
        let g = m.to_graph().unwrap();
        g.validate_shapes().unwrap();
        assert_eq!(g.output_features().unwrap(), 6);
    }
}
