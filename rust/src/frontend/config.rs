//! User configuration directives (the hls4ml config-interface analog).
//!
//! Inferred IR attributes can be overridden per layer — bitwidths, cascade
//! parameters, tiling shapes or placement coordinates — provided they are
//! valid for the target device; the Resolve and Placement passes honor these
//! as hard constraints (paper §IV-A).

use crate::ir::{CascadeGeometry, PlacementRect};
use crate::util::json::Value;
use std::collections::HashMap;
use std::path::Path;

/// Per-layer overrides.
#[derive(Debug, Clone, Default)]
pub struct LayerConfig {
    /// Explicit ⟨M,K,N⟩ tiling.
    pub tiling: Option<(usize, usize, usize)>,
    /// Explicit cascade geometry (cas_len, cas_num).
    pub cascade: Option<(usize, usize)>,
    /// Pinned placement anchor (col, row) — hard constraint for B&B.
    pub place_at: Option<(usize, usize)>,
}

/// Global compile configuration.
#[derive(Debug, Clone)]
pub struct CompileConfig {
    /// Target device name (default "vek280").
    pub device: String,
    /// Placement objective weights (Eq. 2): λ weighs vertical hops,
    /// µ biases toward lower rows.
    pub lambda: f64,
    pub mu: f64,
    /// Placement start coordinates for the first graph.
    pub start: (usize, usize),
    /// Target tiles per layer for the auto-parallelizer; `None` lets the
    /// Resolve pass balance the whole network across the array.
    pub tiles_per_layer: Option<usize>,
    /// Steady-state batch size used for performance reporting.
    pub batch: usize,
    /// Branch-and-bound node budget (safety valve for pathological graphs).
    pub bnb_max_nodes: usize,
    /// Layer names to drain to the host *in addition to* the graph's sinks.
    /// The multi-array partitioner uses this to turn an interior node into
    /// a partition output when a cut edge crosses it; plain compiles leave
    /// it empty.
    pub extra_outputs: Vec<String>,
    /// Per-layer overrides keyed by layer name.
    pub layers: HashMap<String, LayerConfig>,
}

impl Default for CompileConfig {
    fn default() -> Self {
        CompileConfig {
            device: "vek280".to_string(),
            lambda: 1.0,
            mu: 0.05,
            start: (0, 0),
            tiles_per_layer: None,
            batch: 128,
            bnb_max_nodes: 150_000,
            extra_outputs: Vec::new(),
            layers: HashMap::new(),
        }
    }
}

fn pair_usize(v: &Value) -> anyhow::Result<(usize, usize)> {
    let a = v.as_array()?;
    anyhow::ensure!(a.len() == 2, "expected a 2-element array");
    Ok((a[0].as_usize()?, a[1].as_usize()?))
}

impl CompileConfig {
    pub fn from_file(path: impl AsRef<Path>) -> anyhow::Result<CompileConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json_str(&text)
    }

    /// Parse a config JSON; all fields optional, defaults as in `Default`.
    pub fn from_json_str(text: &str) -> anyhow::Result<CompileConfig> {
        let v = Value::parse(text)?;
        let mut c = CompileConfig::default();
        if let Some(d) = v.get("device") {
            c.device = d.as_str()?.to_string();
        }
        if let Some(l) = v.get("lambda") {
            c.lambda = l.as_f64()?;
        }
        if let Some(m) = v.get("mu") {
            c.mu = m.as_f64()?;
        }
        if let Some(s) = v.get("start") {
            c.start = pair_usize(s)?;
        }
        if let Some(t) = v.get("tiles_per_layer") {
            if !matches!(t, Value::Null) {
                c.tiles_per_layer = Some(t.as_usize()?);
            }
        }
        if let Some(b) = v.get("batch") {
            c.batch = b.as_usize()?;
        }
        if let Some(n) = v.get("bnb_max_nodes") {
            c.bnb_max_nodes = n.as_usize()?;
        }
        if let Some(e) = v.get("extra_outputs") {
            c.extra_outputs = e
                .as_array()?
                .iter()
                .map(|x| x.as_str().map(str::to_string))
                .collect::<Result<_, _>>()?;
        }
        if let Some(layers) = v.get("layers") {
            for (name, lv) in layers.as_object()? {
                let mut lc = LayerConfig::default();
                if let Some(t) = lv.get("tiling") {
                    let a = t.as_array()?;
                    anyhow::ensure!(a.len() == 3, "tiling must be [M,K,N]");
                    lc.tiling = Some((a[0].as_usize()?, a[1].as_usize()?, a[2].as_usize()?));
                }
                if let Some(cas) = lv.get("cascade") {
                    lc.cascade = Some(pair_usize(cas)?);
                }
                if let Some(p) = lv.get("place_at") {
                    lc.place_at = Some(pair_usize(p)?);
                }
                c.layers.insert(name.clone(), lc);
            }
        }
        Ok(c)
    }

    /// Serialize to JSON (inverse of `from_json_str`).
    pub fn to_json_string(&self) -> String {
        let layers: std::collections::BTreeMap<String, Value> = self
            .layers
            .iter()
            .map(|(k, lc)| {
                let mut fields: Vec<(&str, Value)> = Vec::new();
                if let Some((m, kk, n)) = lc.tiling {
                    fields.push(("tiling", Value::from(vec![m, kk, n])));
                }
                if let Some((l, n)) = lc.cascade {
                    fields.push(("cascade", Value::from(vec![l, n])));
                }
                if let Some((c, r)) = lc.place_at {
                    fields.push(("place_at", Value::from(vec![c, r])));
                }
                (
                    k.clone(),
                    Value::Object(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect()),
                )
            })
            .collect();
        let mut fields = vec![
            ("device", Value::from(self.device.as_str())),
            ("lambda", Value::from(self.lambda)),
            ("mu", Value::from(self.mu)),
            ("start", Value::from(vec![self.start.0, self.start.1])),
            ("batch", Value::from(self.batch)),
            ("bnb_max_nodes", Value::from(self.bnb_max_nodes)),
            ("layers", Value::Object(layers)),
        ];
        if let Some(t) = self.tiles_per_layer {
            fields.push(("tiles_per_layer", Value::from(t)));
        }
        if !self.extra_outputs.is_empty() {
            fields.push(("extra_outputs", Value::from(self.extra_outputs.clone())));
        }
        Value::Object(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
            .to_string_pretty()
    }

    pub fn layer(&self, name: &str) -> LayerConfig {
        self.layers.get(name).cloned().unwrap_or_default()
    }

    /// Apply a pinned placement from config into a rect, given geometry.
    pub fn pinned_rect(&self, name: &str, geo: &CascadeGeometry) -> Option<PlacementRect> {
        self.layer(name).place_at.map(|(col, row)| PlacementRect {
            col,
            row,
            width: geo.cas_len,
            height: geo.cas_num,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_fig3() {
        let c = CompileConfig::default();
        assert_eq!(c.start, (0, 0));
        assert!((c.lambda - 1.0).abs() < 1e-12);
        assert!((c.mu - 0.05).abs() < 1e-12);
    }

    #[test]
    fn layer_override_roundtrip() {
        let mut c = CompileConfig::default();
        c.layers.insert(
            "fc1".into(),
            LayerConfig {
                tiling: Some((4, 8, 8)),
                cascade: Some((4, 4)),
                place_at: Some((2, 0)),
            },
        );
        let text = c.to_json_string();
        let c2 = CompileConfig::from_json_str(&text).unwrap();
        assert_eq!(c2.layer("fc1").cascade, Some((4, 4)));
        assert_eq!(c2.layer("fc1").tiling, Some((4, 8, 8)));
        assert_eq!(c2.layer("fc1").place_at, Some((2, 0)));
        assert_eq!(c2.layer("fc2").cascade, None);
    }

    #[test]
    fn partial_config_parses_with_defaults() {
        let c = CompileConfig::from_json_str(r#"{"batch": 64, "mu": 0.1}"#).unwrap();
        assert_eq!(c.batch, 64);
        assert!((c.mu - 0.1).abs() < 1e-12);
        assert_eq!(c.device, "vek280");
        assert!(c.tiles_per_layer.is_none());
    }

    #[test]
    fn pinned_rect_uses_geometry() {
        let mut c = CompileConfig::default();
        c.layers.insert("fc1".into(), LayerConfig { place_at: Some((3, 1)), ..Default::default() });
        let geo = CascadeGeometry { cas_len: 4, cas_num: 2, f_in_slice: 32, f_out_slice: 64 };
        let r = c.pinned_rect("fc1", &geo).unwrap();
        assert_eq!((r.col, r.row, r.width, r.height), (3, 1, 4, 2));
        assert!(c.pinned_rect("fc2", &geo).is_none());
    }

    #[test]
    fn extra_outputs_roundtrip() {
        let mut c = CompileConfig::default();
        c.extra_outputs = vec!["fc2".into()];
        let c2 = CompileConfig::from_json_str(&c.to_json_string()).unwrap();
        assert_eq!(c2.extra_outputs, vec!["fc2".to_string()]);
        assert!(CompileConfig::from_json_str("{}").unwrap().extra_outputs.is_empty());
    }

    #[test]
    fn bad_config_rejected() {
        assert!(CompileConfig::from_json_str("{").is_err());
        assert!(CompileConfig::from_json_str(r#"{"start": [1]}"#).is_err());
        assert!(
            CompileConfig::from_json_str(r#"{"layers": {"fc": {"tiling": [1,2]}}}"#).is_err()
        );
    }
}
