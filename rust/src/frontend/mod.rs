//! Frontend: model ingestion and user configuration.
//!
//! `json_model` parses the exporter's neutral JSON (the hls4ml-parser role);
//! `config` carries the user directives that override inferred attributes.

pub mod config;
pub mod json_model;

pub use config::{CompileConfig, LayerConfig};
pub use json_model::{FrontendError, JsonConv, JsonLayer, JsonModel, JsonQuant};
