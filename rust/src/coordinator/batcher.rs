//! Dynamic batcher: groups single-sample requests into device batches.
//!
//! Trigger-system style serving: requests arrive one event at a time and
//! must leave within a deadline, so the batcher flushes on whichever comes
//! first — a full batch or the batching deadline. The compiled firmware is
//! specialized to a fixed batch, so partial flushes are zero-padded up to
//! the firmware batch (padding rows are discarded on the way out; the
//! mem-tile zero-pad makes this free on hardware).

use super::admission::AdmissionError;
use crate::sim::functional::Activation;
use std::time::{Duration, Instant};

/// One queued request.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub features: Vec<i32>,
    pub enqueued: Instant,
}

/// A batch ready for execution.
#[derive(Debug)]
pub struct Batch {
    pub ids: Vec<u64>,
    pub activation: Activation,
    /// Per-request queueing delay at flush time.
    pub queue_delays: Vec<Duration>,
    /// Rows that are real requests (the rest is padding).
    pub occupancy: usize,
}

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Device batch (must equal the firmware's compiled batch).
    pub batch: usize,
    /// Max time the oldest request may wait before a partial flush.
    pub max_wait: Duration,
}

/// Accumulates requests and decides when to flush.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    features: usize,
    pending: Vec<Request>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy, features: usize) -> Batcher {
        Batcher { policy, features, pending: Vec::with_capacity(policy.batch) }
    }

    /// Queue one request. The feature width is a hard contract: a
    /// mis-sized request is rejected with a typed error instead of
    /// silently corrupting neighboring rows of the flushed batch (the old
    /// `debug_assert_eq!` vanished in release builds).
    pub fn push(&mut self, req: Request) -> Result<(), AdmissionError> {
        if req.features.len() != self.features {
            return Err(AdmissionError::FeatureMismatch {
                expected: self.features,
                got: req.features.len(),
            });
        }
        self.pending.push(req);
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Should we flush now?
    pub fn ready(&self, now: Instant) -> bool {
        if self.pending.len() >= self.policy.batch {
            return true;
        }
        self.pending
            .first()
            .map(|r| now.duration_since(r.enqueued) >= self.policy.max_wait)
            .unwrap_or(false)
    }

    /// Time until the deadline of the oldest pending request (for timers).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.pending.first().map(|r| {
            self.policy
                .max_wait
                .checked_sub(now.duration_since(r.enqueued))
                .unwrap_or(Duration::ZERO)
        })
    }

    /// Flush up to one device batch, zero-padding to the firmware batch.
    pub fn flush(&mut self, now: Instant) -> Option<Batch> {
        if self.pending.is_empty() {
            return None;
        }
        let take = self.pending.len().min(self.policy.batch);
        let reqs: Vec<Request> = self.pending.drain(..take).collect();
        let occupancy = reqs.len();
        let mut data = vec![0i32; self.policy.batch * self.features];
        let mut ids = Vec::with_capacity(occupancy);
        let mut delays = Vec::with_capacity(occupancy);
        for (i, r) in reqs.into_iter().enumerate() {
            data[i * self.features..(i + 1) * self.features].copy_from_slice(&r.features);
            ids.push(r.id);
            delays.push(now.duration_since(r.enqueued));
        }
        Some(Batch {
            ids,
            activation: Activation {
                batch: self.policy.batch,
                features: self.features,
                data,
            },
            queue_delays: delays,
            occupancy,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, features: usize, t: Instant) -> Request {
        Request { id, features: vec![id as i32 % 100; features], enqueued: t }
    }

    #[test]
    fn flush_on_full_batch() {
        let now = Instant::now();
        let mut b = Batcher::new(
            BatchPolicy { batch: 4, max_wait: Duration::from_secs(10) },
            8,
        );
        for i in 0..3 {
            b.push(req(i, 8, now)).unwrap();
        }
        assert!(!b.ready(now));
        b.push(req(3, 8, now)).unwrap();
        assert!(b.ready(now));
        let batch = b.flush(now).unwrap();
        assert_eq!(batch.occupancy, 4);
        assert_eq!(batch.ids, vec![0, 1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn flush_on_deadline_with_padding() {
        let start = Instant::now();
        let mut b = Batcher::new(
            BatchPolicy { batch: 8, max_wait: Duration::from_millis(1) },
            4,
        );
        b.push(req(42, 4, start)).unwrap();
        let later = start + Duration::from_millis(2);
        assert!(b.ready(later));
        let batch = b.flush(later).unwrap();
        assert_eq!(batch.occupancy, 1);
        assert_eq!(batch.activation.batch, 8);
        // Row 0 is the request, rows 1.. are zero padding.
        assert_eq!(batch.activation.row(0), &[42, 42, 42, 42]);
        assert!(batch.activation.row(3).iter().all(|&v| v == 0));
    }

    #[test]
    fn overfull_queue_flushes_in_order() {
        let now = Instant::now();
        let mut b = Batcher::new(
            BatchPolicy { batch: 2, max_wait: Duration::from_secs(1) },
            1,
        );
        for i in 0..5 {
            b.push(req(i, 1, now)).unwrap();
        }
        assert_eq!(b.flush(now).unwrap().ids, vec![0, 1]);
        assert_eq!(b.flush(now).unwrap().ids, vec![2, 3]);
        assert_eq!(b.flush(now).unwrap().ids, vec![4]);
        assert!(b.flush(now).is_none());
    }

    #[test]
    fn mis_sized_push_rejected_without_corrupting_neighbors() {
        let now = Instant::now();
        let mut b = Batcher::new(
            BatchPolicy { batch: 4, max_wait: Duration::from_secs(1) },
            4,
        );
        b.push(req(0, 4, now)).unwrap();
        // Wrong width: typed rejection, queue untouched.
        let err = b.push(req(1, 3, now)).unwrap_err();
        assert_eq!(err, AdmissionError::FeatureMismatch { expected: 4, got: 3 });
        assert_eq!(b.len(), 1);
        // A well-formed request still lands, and the flushed rows carry
        // exactly the admitted payloads.
        b.push(req(2, 4, now)).unwrap();
        let batch = b.flush(now).unwrap();
        assert_eq!(batch.ids, vec![0, 2]);
        assert_eq!(batch.activation.row(0), &[0, 0, 0, 0]);
        assert_eq!(batch.activation.row(1), &[2, 2, 2, 2]);
    }

    #[test]
    fn deadline_timer() {
        let start = Instant::now();
        let mut b = Batcher::new(
            BatchPolicy { batch: 8, max_wait: Duration::from_millis(100) },
            1,
        );
        assert!(b.next_deadline(start).is_none());
        b.push(req(0, 1, start)).unwrap();
        let d = b.next_deadline(start + Duration::from_millis(40)).unwrap();
        assert!(d <= Duration::from_millis(60));
    }
}
