//! Serving metrics: latency distributions, throughput, batch occupancy,
//! and per-partition pipeline-stage health (queue depth, busy fraction)
//! for multi-array deployments.
//!
//! Latencies accumulate into a mergeable log-bucketed
//! [`LatencyHistogram`] (bounded memory under sustained load) instead of
//! an unbounded sorted-sample vector. Reports *carry* the histogram, so
//! fleet-level [`MetricsReport::merged`] percentiles are computed from
//! the pooled distribution — exact by construction, not a worst-replica
//! or request-weighted approximation.

use crate::obs::LatencyHistogram;
use std::time::Duration;

/// Accumulator for one pipeline stage (one partition / array).
#[derive(Debug, Default, Clone)]
struct StageAccum {
    batches: usize,
    depth_sum: usize,
    max_depth: usize,
    busy_us: f64,
    span_us: f64,
}

/// Streaming metrics accumulator.
#[derive(Debug, Default)]
pub struct Metrics {
    latency: LatencyHistogram,
    batches: usize,
    requests: usize,
    padded_rows: usize,
    device_busy_us: f64,
    stages: Vec<StageAccum>,
}

/// Per-partition pipeline-stage snapshot: how deep its input queue runs
/// and what fraction of wall time the stage spends executing — the two
/// numbers that make pipeline imbalance observable (a stage with a rising
/// queue and ~1.0 busy fraction is the bottleneck array).
#[derive(Debug, Clone)]
pub struct StageMetricsReport {
    /// Partition (pipeline stage) index.
    pub partition: usize,
    /// Batches this stage executed.
    pub batches: usize,
    /// Deepest its input queue ever ran (jobs waiting at dequeue time).
    pub max_queue_depth: usize,
    /// Mean input-queue depth observed at dequeue time.
    pub mean_queue_depth: f64,
    /// Fraction of the stage's wall-clock span spent executing batches.
    pub busy_fraction: f64,
}

/// A point-in-time snapshot.
#[derive(Debug, Clone)]
pub struct MetricsReport {
    pub requests: usize,
    pub batches: usize,
    pub mean_batch_occupancy: f64,
    /// The full latency distribution this report was derived from.
    /// Carried so merges pool distributions instead of approximating from
    /// summary points; also feeds the Prometheus histogram exposition.
    pub latency: LatencyHistogram,
    pub p50_latency_us: f64,
    pub p99_latency_us: f64,
    pub max_latency_us: f64,
    pub device_busy_us: f64,
    /// Per-partition pipeline-stage metrics; empty for single-array servers.
    pub stages: Vec<StageMetricsReport>,
}

impl MetricsReport {
    /// An empty report (no traffic yet) — the identity of [`merged`].
    ///
    /// [`merged`]: MetricsReport::merged
    pub fn empty() -> MetricsReport {
        MetricsReport {
            requests: 0,
            batches: 0,
            mean_batch_occupancy: 0.0,
            latency: LatencyHistogram::new(),
            p50_latency_us: 0.0,
            p99_latency_us: 0.0,
            max_latency_us: 0.0,
            device_busy_us: 0.0,
            stages: Vec::new(),
        }
    }

    fn quantiles_from_hist(&mut self) {
        self.p50_latency_us = self.latency.quantile_us(0.50);
        self.p99_latency_us = self.latency.quantile_us(0.99);
        self.max_latency_us = self.latency.max_us();
    }

    /// Aggregate per-replica reports into one fleet-level view: requests,
    /// batches and device time sum; occupancy is batch-weighted.
    ///
    /// Latency percentiles are computed on the element-wise **merged
    /// histogram** — bit-identical to pooling every replica's samples
    /// into one histogram. This replaces two historical approximations
    /// that are now regression-pinned: a request-weighted p50 (biased
    /// whenever replicas are asymmetric) and a worst-replica p99, which
    /// over-estimated the fleet tail whenever the slow replica carried
    /// less than 1% of traffic (10 requests at 100 µs next to 990 at
    /// 10 µs pool to a ~10 µs p99, not 100 µs).
    ///
    /// Per-stage rows are dropped: stage indices are per-replica pipeline
    /// positions, not fleet-wide entities.
    pub fn merged(reports: &[MetricsReport]) -> MetricsReport {
        let mut out = MetricsReport::empty();
        let mut occupancy_weighted = 0.0;
        for r in reports {
            out.requests += r.requests;
            out.batches += r.batches;
            out.device_busy_us += r.device_busy_us;
            occupancy_weighted += r.mean_batch_occupancy * r.batches as f64;
            out.latency.merge(&r.latency);
        }
        if out.batches > 0 {
            out.mean_batch_occupancy = occupancy_weighted / out.batches as f64;
        }
        out.quantiles_from_hist();
        out
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_batch(&mut self, occupancy: usize, batch: usize, latencies: &[Duration], device_us: f64) {
        self.batches += 1;
        self.requests += occupancy;
        self.padded_rows += batch - occupancy;
        self.device_busy_us += device_us;
        for l in latencies {
            self.latency.record(*l);
        }
    }

    /// Record one batch through pipeline stage `partition`: the input-queue
    /// depth observed when the batch was dequeued, the stage's cumulative
    /// execution time, and its wall-clock span so far (the latter two
    /// overwrite — callers report running totals).
    pub fn record_stage_batch(
        &mut self,
        partition: usize,
        queue_depth: usize,
        busy_us: f64,
        span_us: f64,
    ) {
        if self.stages.len() <= partition {
            self.stages.resize(partition + 1, StageAccum::default());
        }
        let s = &mut self.stages[partition];
        s.batches += 1;
        s.depth_sum += queue_depth;
        s.max_depth = s.max_depth.max(queue_depth);
        s.busy_us = busy_us;
        s.span_us = span_us;
    }

    pub fn report(&self) -> MetricsReport {
        let mut out = MetricsReport {
            requests: self.requests,
            batches: self.batches,
            mean_batch_occupancy: if self.batches == 0 {
                0.0
            } else {
                self.requests as f64 / (self.requests + self.padded_rows).max(1) as f64
                    * (self.requests + self.padded_rows) as f64
                    / self.batches as f64
            },
            latency: self.latency.clone(),
            p50_latency_us: 0.0,
            p99_latency_us: 0.0,
            max_latency_us: 0.0,
            device_busy_us: self.device_busy_us,
            stages: self
                .stages
                .iter()
                .enumerate()
                .map(|(i, s)| StageMetricsReport {
                    partition: i,
                    batches: s.batches,
                    max_queue_depth: s.max_depth,
                    mean_queue_depth: if s.batches == 0 {
                        0.0
                    } else {
                        s.depth_sum as f64 / s.batches as f64
                    },
                    busy_fraction: if s.span_us > 0.0 {
                        (s.busy_us / s.span_us).clamp(0.0, 1.0)
                    } else {
                        0.0
                    },
                })
                .collect(),
        };
        out.quantiles_from_hist();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::new();
        let lat: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        m.record_batch(100, 128, &lat, 500.0);
        let r = m.report();
        assert_eq!(r.requests, 100);
        // Histogram buckets grow by 2^(1/8): quantiles are within ±5%.
        assert!((r.p50_latency_us - 50.0).abs() / 50.0 < 0.05, "p50 {}", r.p50_latency_us);
        assert!((r.p99_latency_us - 99.0).abs() / 99.0 < 0.05, "p99 {}", r.p99_latency_us);
        // Min/max/count/sum are exact.
        assert_eq!(r.max_latency_us, 100.0);
        assert_eq!(r.latency.count(), 100);
        assert!((r.latency.sum_us() - 5050.0).abs() < 1e-6);
        assert_eq!(r.device_busy_us, 500.0);
    }

    #[test]
    fn small_window_percentiles_stay_inside_the_samples() {
        // Histogram quantiles are clamped into [min, max]: a single
        // sample is every percentile exactly, and p99 never exceeds the
        // observed max in small windows.
        let mut m = Metrics::new();
        m.record_batch(1, 1, &[Duration::from_micros(7)], 0.0);
        let r = m.report();
        assert_eq!(r.p50_latency_us, 7.0);
        assert_eq!(r.p99_latency_us, 7.0);
        assert_eq!(r.max_latency_us, 7.0);

        let mut m = Metrics::new();
        let lat: Vec<Duration> = (1..=10).map(Duration::from_micros).collect();
        m.record_batch(10, 16, &lat, 0.0);
        let r = m.report();
        assert!(r.p99_latency_us <= r.max_latency_us);
        assert!(r.p50_latency_us >= 1.0 && r.p50_latency_us <= 10.0);
    }

    #[test]
    fn empty_report() {
        let r = Metrics::new().report();
        assert_eq!(r.requests, 0);
        assert_eq!(r.p99_latency_us, 0.0);
        assert!(r.stages.is_empty());
        assert!(r.latency.is_empty());
    }

    #[test]
    fn merged_reports_pool_distributions_exactly() {
        let mut a = Metrics::new();
        a.record_batch(4, 4, &[Duration::from_micros(10); 4], 100.0);
        let mut b = Metrics::new();
        b.record_batch(2, 4, &[Duration::from_micros(50); 2], 80.0);
        b.record_batch(4, 4, &[Duration::from_micros(20); 4], 80.0);
        let m = MetricsReport::merged(&[a.report(), b.report()]);
        assert_eq!(m.requests, 10);
        assert_eq!(m.batches, 3);
        assert!((m.device_busy_us - 260.0).abs() < 1e-9);
        assert_eq!(m.max_latency_us, 50.0);

        // The merged report is bit-identical to recording every sample
        // into one accumulator.
        let mut pooled = Metrics::new();
        pooled.record_batch(4, 4, &[Duration::from_micros(10); 4], 100.0);
        pooled.record_batch(2, 4, &[Duration::from_micros(50); 2], 80.0);
        pooled.record_batch(4, 4, &[Duration::from_micros(20); 4], 80.0);
        let p = pooled.report();
        assert_eq!(m.latency, p.latency);
        assert_eq!(m.p50_latency_us.to_bits(), p.p50_latency_us.to_bits());
        assert_eq!(m.p99_latency_us.to_bits(), p.p99_latency_us.to_bits());

        // Batch-weighted occupancy: (4*1 + 3*2) / 3 batches = 10/3.
        assert!((m.mean_batch_occupancy - 10.0 / 3.0).abs() < 1e-9);
        // Identity on the empty set.
        let e = MetricsReport::merged(&[]);
        assert_eq!(e.requests, 0);
        assert_eq!(e.p99_latency_us, 0.0);
    }

    #[test]
    fn merged_tail_is_pooled_not_worst_replica() {
        // Regression for the old worst-replica p99 merge rule. Replica
        // `fast` serves 990 requests at 10 µs; replica `slow` serves 10
        // at 100 µs — 1% of traffic. Pooled, the p99 sits at ~10 µs (99%
        // of requests finished in 10 µs); the old rule reported the slow
        // replica's 100 µs, a 10× over-estimate that would page an
        // operator for a fleet comfortably inside its SLO.
        let mut fast = Metrics::new();
        for _ in 0..99 {
            fast.record_batch(10, 10, &[Duration::from_micros(10); 10], 100.0);
        }
        let mut slow = Metrics::new();
        slow.record_batch(10, 10, &[Duration::from_micros(100); 10], 1000.0);
        let (fr, sr) = (fast.report(), slow.report());
        let worst_replica_p99 = fr.p99_latency_us.max(sr.p99_latency_us);
        assert_eq!(worst_replica_p99, 100.0, "old rule: worst replica dominates");

        let m = MetricsReport::merged(&[fr, sr]);
        assert_eq!(m.requests, 1000);
        assert!(
            (m.p50_latency_us - 10.0).abs() / 10.0 < 0.05,
            "pooled median ~10 µs, got {}",
            m.p50_latency_us
        );
        assert!(
            (m.p99_latency_us - 10.0).abs() / 10.0 < 0.05,
            "pooled p99 ~10 µs (990 of 1000 at rank 990), got {}",
            m.p99_latency_us
        );
        assert!(
            m.p99_latency_us < worst_replica_p99 / 5.0,
            "exact merged p99 must undercut the worst-replica over-estimate"
        );
        // The true maximum is still exact.
        assert_eq!(m.max_latency_us, 100.0);
    }

    #[test]
    fn merged_tail_stays_conservative_when_slow_traffic_is_over_one_percent() {
        // 100 fast requests at 10 µs + 10 slow at 100 µs: the slowest 9%
        // of pooled traffic took 100 µs, so pooled p99 must report it.
        let mut fast = Metrics::new();
        for _ in 0..10 {
            fast.record_batch(10, 10, &[Duration::from_micros(10); 10], 100.0);
        }
        let mut slow = Metrics::new();
        slow.record_batch(10, 10, &[Duration::from_micros(100); 10], 1000.0);
        let m = MetricsReport::merged(&[fast.report(), slow.report()]);
        assert!(
            (m.p99_latency_us - 100.0).abs() / 100.0 < 0.05,
            "pooled p99 ~100 µs, got {}",
            m.p99_latency_us
        );
    }

    #[test]
    fn stage_metrics_expose_queue_depth_and_busy_fraction() {
        let mut m = Metrics::new();
        // Stage 0: two batches at depths 1 and 3, busy 30 of 100 µs.
        m.record_stage_batch(0, 1, 10.0, 50.0);
        m.record_stage_batch(0, 3, 30.0, 100.0);
        // Stage 1: one batch, empty queue, busy 90 of 100 µs (bottleneck).
        m.record_stage_batch(1, 0, 90.0, 100.0);
        let r = m.report();
        assert_eq!(r.stages.len(), 2);
        assert_eq!(r.stages[0].partition, 0);
        assert_eq!(r.stages[0].batches, 2);
        assert_eq!(r.stages[0].max_queue_depth, 3);
        assert!((r.stages[0].mean_queue_depth - 2.0).abs() < 1e-12);
        assert!((r.stages[0].busy_fraction - 0.3).abs() < 1e-12);
        assert!((r.stages[1].busy_fraction - 0.9).abs() < 1e-12);
        // The busier stage is identifiable as the pipeline bottleneck.
        let bottleneck = r
            .stages
            .iter()
            .max_by(|a, b| a.busy_fraction.partial_cmp(&b.busy_fraction).unwrap())
            .unwrap();
        assert_eq!(bottleneck.partition, 1);
    }
}
