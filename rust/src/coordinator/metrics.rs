//! Serving metrics: latency percentiles, throughput, batch occupancy.

use std::time::Duration;

/// Streaming metrics accumulator.
#[derive(Debug, Default)]
pub struct Metrics {
    latencies_us: Vec<f64>,
    batches: usize,
    requests: usize,
    padded_rows: usize,
    device_busy_us: f64,
}

/// A point-in-time snapshot.
#[derive(Debug, Clone)]
pub struct MetricsReport {
    pub requests: usize,
    pub batches: usize,
    pub mean_batch_occupancy: f64,
    pub p50_latency_us: f64,
    pub p99_latency_us: f64,
    pub max_latency_us: f64,
    pub device_busy_us: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_batch(&mut self, occupancy: usize, batch: usize, latencies: &[Duration], device_us: f64) {
        self.batches += 1;
        self.requests += occupancy;
        self.padded_rows += batch - occupancy;
        self.device_busy_us += device_us;
        for l in latencies {
            self.latencies_us.push(l.as_secs_f64() * 1e6);
        }
    }

    pub fn report(&self) -> MetricsReport {
        let mut sorted = self.latencies_us.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            if sorted.is_empty() {
                return 0.0;
            }
            let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
            sorted[idx]
        };
        MetricsReport {
            requests: self.requests,
            batches: self.batches,
            mean_batch_occupancy: if self.batches == 0 {
                0.0
            } else {
                self.requests as f64 / (self.requests + self.padded_rows).max(1) as f64
                    * (self.requests + self.padded_rows) as f64
                    / self.batches as f64
            },
            p50_latency_us: pct(0.50),
            p99_latency_us: pct(0.99),
            max_latency_us: sorted.last().copied().unwrap_or(0.0),
            device_busy_us: self.device_busy_us,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::new();
        let lat: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        m.record_batch(100, 128, &lat, 500.0);
        let r = m.report();
        assert_eq!(r.requests, 100);
        assert!((r.p50_latency_us - 50.0).abs() <= 1.5);
        assert!((r.p99_latency_us - 99.0).abs() <= 1.5);
        assert_eq!(r.max_latency_us, 100.0);
        assert_eq!(r.device_busy_us, 500.0);
    }

    #[test]
    fn empty_report() {
        let r = Metrics::new().report();
        assert_eq!(r.requests, 0);
        assert_eq!(r.p99_latency_us, 0.0);
    }
}
