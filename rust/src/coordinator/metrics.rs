//! Serving metrics: latency percentiles, throughput, batch occupancy, and
//! per-partition pipeline-stage health (queue depth, busy fraction) for
//! multi-array deployments.

use std::time::Duration;

/// Accumulator for one pipeline stage (one partition / array).
#[derive(Debug, Default, Clone)]
struct StageAccum {
    batches: usize,
    depth_sum: usize,
    max_depth: usize,
    busy_us: f64,
    span_us: f64,
}

/// Streaming metrics accumulator.
#[derive(Debug, Default)]
pub struct Metrics {
    latencies_us: Vec<f64>,
    batches: usize,
    requests: usize,
    padded_rows: usize,
    device_busy_us: f64,
    stages: Vec<StageAccum>,
}

/// Per-partition pipeline-stage snapshot: how deep its input queue runs
/// and what fraction of wall time the stage spends executing — the two
/// numbers that make pipeline imbalance observable (a stage with a rising
/// queue and ~1.0 busy fraction is the bottleneck array).
#[derive(Debug, Clone)]
pub struct StageMetricsReport {
    /// Partition (pipeline stage) index.
    pub partition: usize,
    /// Batches this stage executed.
    pub batches: usize,
    /// Deepest its input queue ever ran (jobs waiting at dequeue time).
    pub max_queue_depth: usize,
    /// Mean input-queue depth observed at dequeue time.
    pub mean_queue_depth: f64,
    /// Fraction of the stage's wall-clock span spent executing batches.
    pub busy_fraction: f64,
}

/// A point-in-time snapshot.
#[derive(Debug, Clone)]
pub struct MetricsReport {
    pub requests: usize,
    pub batches: usize,
    pub mean_batch_occupancy: f64,
    pub p50_latency_us: f64,
    pub p99_latency_us: f64,
    pub max_latency_us: f64,
    pub device_busy_us: f64,
    /// Per-partition pipeline-stage metrics; empty for single-array servers.
    pub stages: Vec<StageMetricsReport>,
}

impl MetricsReport {
    /// An empty report (no traffic yet) — the identity of [`merged`].
    ///
    /// [`merged`]: MetricsReport::merged
    pub fn empty() -> MetricsReport {
        MetricsReport {
            requests: 0,
            batches: 0,
            mean_batch_occupancy: 0.0,
            p50_latency_us: 0.0,
            p99_latency_us: 0.0,
            max_latency_us: 0.0,
            device_busy_us: 0.0,
            stages: Vec::new(),
        }
    }

    /// Aggregate per-replica reports into one fleet-level view: requests,
    /// batches and device time sum; occupancy is batch-weighted.
    ///
    /// Latency semantics (exact fleet percentiles would need the pooled
    /// raw samples, which replicas do not ship):
    /// * **p50** is merged *request-weighted* — each replica's median
    ///   contributes proportionally to the requests it served. Taking the
    ///   worst replica (the old rule) badly overstated the fleet median
    ///   under skewed load: one replica serving a handful of slow requests
    ///   dominated the p50 of a fleet that answered thousands quickly.
    /// * **p99** stays the *worst replica's* p99 — a request-weighted mean
    ///   would understate the pooled tail whenever a slow replica serves a
    ///   small share of traffic (10 requests at 100 µs next to 100 at
    ///   10 µs pool to a 100 µs p99, not 18 µs), and an SLO check on the
    ///   tail must not pass on an average. The max is an upper bound of
    ///   the pooled p99 and exact when the slow replica carries ≥ 1% of
    ///   the traffic.
    /// * **max_latency_us** is a true maximum over replicas.
    ///
    /// Per-stage rows are dropped: stage indices are per-replica pipeline
    /// positions, not fleet-wide entities.
    pub fn merged(reports: &[MetricsReport]) -> MetricsReport {
        let mut out = MetricsReport::empty();
        let mut occupancy_weighted = 0.0;
        let mut p50_weighted = 0.0;
        for r in reports {
            out.requests += r.requests;
            out.batches += r.batches;
            out.device_busy_us += r.device_busy_us;
            occupancy_weighted += r.mean_batch_occupancy * r.batches as f64;
            p50_weighted += r.p50_latency_us * r.requests as f64;
            out.p99_latency_us = out.p99_latency_us.max(r.p99_latency_us);
            out.max_latency_us = out.max_latency_us.max(r.max_latency_us);
        }
        if out.batches > 0 {
            out.mean_batch_occupancy = occupancy_weighted / out.batches as f64;
        }
        if out.requests > 0 {
            out.p50_latency_us = p50_weighted / out.requests as f64;
        }
        out
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_batch(&mut self, occupancy: usize, batch: usize, latencies: &[Duration], device_us: f64) {
        self.batches += 1;
        self.requests += occupancy;
        self.padded_rows += batch - occupancy;
        self.device_busy_us += device_us;
        for l in latencies {
            self.latencies_us.push(l.as_secs_f64() * 1e6);
        }
    }

    /// Record one batch through pipeline stage `partition`: the input-queue
    /// depth observed when the batch was dequeued, the stage's cumulative
    /// execution time, and its wall-clock span so far (the latter two
    /// overwrite — callers report running totals).
    pub fn record_stage_batch(
        &mut self,
        partition: usize,
        queue_depth: usize,
        busy_us: f64,
        span_us: f64,
    ) {
        if self.stages.len() <= partition {
            self.stages.resize(partition + 1, StageAccum::default());
        }
        let s = &mut self.stages[partition];
        s.batches += 1;
        s.depth_sum += queue_depth;
        s.max_depth = s.max_depth.max(queue_depth);
        s.busy_us = busy_us;
        s.span_us = span_us;
    }

    pub fn report(&self) -> MetricsReport {
        let mut sorted = self.latencies_us.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Nearest-rank with linear interpolation between the straddling
        // samples. The old `((n-1)*p).round()` collapsed p99 onto the max
        // for any window under ~50 samples and biased p50 on even-length
        // windows (both pinned by `percentile_interpolation_small_windows`).
        let pct = |p: f64| -> f64 {
            if sorted.is_empty() {
                return 0.0;
            }
            let rank = (sorted.len() - 1) as f64 * p;
            let lo = rank.floor() as usize;
            let hi = rank.ceil() as usize;
            if lo == hi {
                sorted[lo]
            } else {
                sorted[lo] + (sorted[hi] - sorted[lo]) * (rank - lo as f64)
            }
        };
        MetricsReport {
            requests: self.requests,
            batches: self.batches,
            mean_batch_occupancy: if self.batches == 0 {
                0.0
            } else {
                self.requests as f64 / (self.requests + self.padded_rows).max(1) as f64
                    * (self.requests + self.padded_rows) as f64
                    / self.batches as f64
            },
            p50_latency_us: pct(0.50),
            p99_latency_us: pct(0.99),
            max_latency_us: sorted.last().copied().unwrap_or(0.0),
            device_busy_us: self.device_busy_us,
            stages: self
                .stages
                .iter()
                .enumerate()
                .map(|(i, s)| StageMetricsReport {
                    partition: i,
                    batches: s.batches,
                    max_queue_depth: s.max_depth,
                    mean_queue_depth: if s.batches == 0 {
                        0.0
                    } else {
                        s.depth_sum as f64 / s.batches as f64
                    },
                    busy_fraction: if s.span_us > 0.0 {
                        (s.busy_us / s.span_us).clamp(0.0, 1.0)
                    } else {
                        0.0
                    },
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::new();
        let lat: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        m.record_batch(100, 128, &lat, 500.0);
        let r = m.report();
        assert_eq!(r.requests, 100);
        assert!((r.p50_latency_us - 50.0).abs() <= 1.5);
        assert!((r.p99_latency_us - 99.0).abs() <= 1.5);
        assert_eq!(r.max_latency_us, 100.0);
        assert_eq!(r.device_busy_us, 500.0);
    }

    #[test]
    fn percentile_interpolation_small_windows() {
        // Regression for the `((n-1)*p).round()` index: with 10 samples it
        // returned sorted[9] for p99 — the max — hiding every sub-max tail
        // sample in small windows. Interpolated rank 8.91 sits just below.
        let mut m = Metrics::new();
        let lat: Vec<Duration> = (1..=10).map(Duration::from_micros).collect();
        m.record_batch(10, 16, &lat, 0.0);
        let r = m.report();
        assert!((r.p99_latency_us - 9.91).abs() < 1e-6, "p99 {}", r.p99_latency_us);
        assert!(
            r.p99_latency_us < r.max_latency_us,
            "p99 must not collapse onto the max in small windows"
        );
        // Even-length window: the median is the mean of the two middle
        // samples, not whichever one rounding lands on.
        let mut m = Metrics::new();
        let lat: Vec<Duration> = (1..=4).map(Duration::from_micros).collect();
        m.record_batch(4, 4, &lat, 0.0);
        let r = m.report();
        assert!((r.p50_latency_us - 2.5).abs() < 1e-6, "p50 {}", r.p50_latency_us);
        // A single sample is every percentile.
        let mut m = Metrics::new();
        m.record_batch(1, 1, &[Duration::from_micros(7)], 0.0);
        let r = m.report();
        assert!((r.p50_latency_us - 7.0).abs() < 1e-6);
        assert!((r.p99_latency_us - 7.0).abs() < 1e-6);
    }

    #[test]
    fn empty_report() {
        let r = Metrics::new().report();
        assert_eq!(r.requests, 0);
        assert_eq!(r.p99_latency_us, 0.0);
        assert!(r.stages.is_empty());
    }

    #[test]
    fn merged_reports_sum_and_weight_latency() {
        let mut a = Metrics::new();
        a.record_batch(4, 4, &[Duration::from_micros(10); 4], 100.0);
        let mut b = Metrics::new();
        b.record_batch(2, 4, &[Duration::from_micros(50); 2], 80.0);
        b.record_batch(4, 4, &[Duration::from_micros(20); 4], 80.0);
        let m = MetricsReport::merged(&[a.report(), b.report()]);
        assert_eq!(m.requests, 10);
        assert_eq!(m.batches, 3);
        assert!((m.device_busy_us - 260.0).abs() < 1e-9);
        // The tail (p99, max) is conservative; the median is
        // request-weighted.
        assert_eq!(m.max_latency_us, 50.0);
        let (pa, pb) = (a.report(), b.report());
        assert_eq!(m.p99_latency_us, pa.p99_latency_us.max(pb.p99_latency_us));
        let want_p50 = (pa.p50_latency_us * 4.0 + pb.p50_latency_us * 6.0) / 10.0;
        assert!((m.p50_latency_us - want_p50).abs() < 1e-9);
        // Batch-weighted occupancy: (4*1 + 3*2) / 3 batches = 10/3.
        assert!((m.mean_batch_occupancy - 10.0 / 3.0).abs() < 1e-9);
        // Identity on the empty set.
        let e = MetricsReport::merged(&[]);
        assert_eq!(e.requests, 0);
        assert_eq!(e.p99_latency_us, 0.0);
    }

    #[test]
    fn merged_percentiles_track_load_not_the_worst_replica() {
        // Regression for the worst-replica merge rule: replica `fast`
        // serves 100 requests at 10 µs, replica `slow` serves 10 at
        // 100 µs. The fleet *median* must sit near the traffic (~18 µs),
        // not jump to the slow replica's 100 µs — while the tail (p99,
        // max) must stay at 100 µs: pooled, the slowest ~9% of requests
        // all took 100 µs, so a request-weighted p99 of 18 µs would let a
        // 50 µs SLO check pass with >1% of traffic in violation.
        let mut fast = Metrics::new();
        for _ in 0..25 {
            fast.record_batch(4, 4, &[Duration::from_micros(10); 4], 40.0);
        }
        let mut slow = Metrics::new();
        for _ in 0..5 {
            slow.record_batch(2, 2, &[Duration::from_micros(100); 2], 200.0);
        }
        let m = MetricsReport::merged(&[fast.report(), slow.report()]);
        assert_eq!(m.requests, 110);
        let want = (10.0 * 100.0 + 100.0 * 10.0) / 110.0; // ≈ 18.18 µs
        assert!((m.p50_latency_us - want).abs() < 1e-9, "p50 {}", m.p50_latency_us);
        assert!(m.p50_latency_us < 100.0, "median must not be the worst replica");
        assert_eq!(m.p99_latency_us, 100.0, "tail percentile must stay conservative");
        assert_eq!(m.max_latency_us, 100.0);
    }

    #[test]
    fn stage_metrics_expose_queue_depth_and_busy_fraction() {
        let mut m = Metrics::new();
        // Stage 0: two batches at depths 1 and 3, busy 30 of 100 µs.
        m.record_stage_batch(0, 1, 10.0, 50.0);
        m.record_stage_batch(0, 3, 30.0, 100.0);
        // Stage 1: one batch, empty queue, busy 90 of 100 µs (bottleneck).
        m.record_stage_batch(1, 0, 90.0, 100.0);
        let r = m.report();
        assert_eq!(r.stages.len(), 2);
        assert_eq!(r.stages[0].partition, 0);
        assert_eq!(r.stages[0].batches, 2);
        assert_eq!(r.stages[0].max_queue_depth, 3);
        assert!((r.stages[0].mean_queue_depth - 2.0).abs() < 1e-12);
        assert!((r.stages[0].busy_fraction - 0.3).abs() < 1e-12);
        assert!((r.stages[1].busy_fraction - 0.9).abs() < 1e-12);
        // The busier stage is identifiable as the pipeline bottleneck.
        let bottleneck = r
            .stages
            .iter()
            .max_by(|a, b| a.busy_fraction.partial_cmp(&b.busy_fraction).unwrap())
            .unwrap();
        assert_eq!(bottleneck.partition, 1);
    }
}
