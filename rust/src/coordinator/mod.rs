//! L3 serving coordinator: the ultra-low-latency companion runtime.
//!
//! The paper positions AIE4ML for trigger-system-like environments where
//! events arrive continuously and must be classified within microseconds.
//! This module is that companion: an async request router and dynamic
//! batcher in front of the compiled firmware, with latency/throughput
//! metrics. Rust owns the event loop; the firmware package (and on real
//! hardware, the AIE array) does the math.

pub mod admission;
pub mod batcher;
pub mod continuous;
pub mod metrics;
pub mod pipeline;
pub mod router;
pub mod server;

pub use admission::{AdmissionConfig, AdmissionError, AdmissionReport, AdmissionStats};
pub use batcher::{Batch, BatchPolicy, Batcher, Request};
pub use continuous::{
    ContinuousClient, ContinuousPolicy, ContinuousServer, InferTicket, ServingSnapshot,
};
pub use metrics::{Metrics, MetricsReport, StageMetricsReport};
pub use pipeline::{PipelineClient, PipelineServer};
pub use router::{least_loaded, LeastLoaded, Router};
pub use server::{Client, InferHandle, Server};
