//! Continuous batch formation: a shared admission-controlled queue in
//! front of R replica workers.
//!
//! The deadline batcher ([`super::Server`]) binds every request to *one*
//! batcher flush: a replica sits idle until its own queue fills or its
//! deadline fires, and under an open-loop burst the backlog it accumulates
//! is drained one flush at a time. [`ContinuousServer`] inverts the
//! control flow — replicas *pull*: every worker that finishes a batch
//! immediately claims up to `batch` requests from the front of one shared
//! FIFO queue (zero-padding partial claims exactly like the batcher), so
//! each firmware slot refills the moment a replica frees up instead of
//! blocking on a per-replica flush cycle.
//!
//! Intake is non-blocking and admission-controlled
//! ([`super::admission`]): a submission either returns an [`InferTicket`]
//! or a typed [`AdmissionError`] immediately — the queue is bounded and a
//! request whose projected sojourn would bust the latency budget is shed
//! at the door rather than served late. The replica count is live:
//! [`ContinuousServer::scale_to`] grows by spawning workers onto the same
//! queue and shrinks by retiring them between batches, which is what the
//! deploy layer's autoscaler drives.

use super::admission::{admit, AdmissionConfig, AdmissionError, AdmissionReport, AdmissionStats};
use super::batcher::Request;
use super::metrics::{Metrics, MetricsReport};
use crate::cache::{CacheStats, FirmwareCache};
use crate::obs;
use crate::obs::attrib::{DriftDetector, DriftReport};
use crate::partition::{analyze_pipeline, execute_partitioned, PartitionedFirmware};
use crate::sim::engine::EngineModel;
use crate::sim::functional::Activation;
use anyhow::{ensure, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Replies carry one feature vector per model output (sink), in
/// [`PartitionedFirmware::outputs`] order.
type Reply = SyncSender<Vec<Vec<i32>>>;

/// How long an idle worker sleeps between queue polls. Wake-ups are
/// condvar-driven; this only bounds shutdown/retire latency if a notify
/// is missed.
const IDLE_POLL: Duration = Duration::from_millis(25);

/// Policy knobs for the continuous-batching server.
#[derive(Debug, Clone, Copy)]
pub struct ContinuousPolicy {
    /// Max time the oldest queued request may wait before a worker flushes
    /// a partial (zero-padded) batch.
    pub max_wait: Duration,
    /// Admission control: queue bound + latency-budget shedding.
    pub admission: AdmissionConfig,
    /// Keep a log of each executed batch's request ids (admission order).
    /// Test instrumentation — off in production policies.
    pub record_batches: bool,
}

impl Default for ContinuousPolicy {
    fn default() -> Self {
        ContinuousPolicy {
            max_wait: Duration::from_micros(200),
            admission: AdmissionConfig::default(),
            record_batches: false,
        }
    }
}

/// One admitted request waiting in the shared queue.
struct Pending {
    req: Request,
    reply: Reply,
    /// Tracer-timeline admission timestamp (µs); the claiming worker
    /// closes the queue-wait span with it. 0 while tracing is disabled.
    enqueued_us: u64,
}

/// Mutable queue state, guarded by one mutex (submissions and batch
/// claims both touch it, so the lock also serializes admission decisions
/// against queue depth).
struct QueueState {
    pending: VecDeque<Pending>,
    stopped: bool,
    /// Worker threads currently attached to the queue.
    live: usize,
    /// Workers asked to retire at their next batch boundary (≤ live - 1
    /// while running, so the queue always keeps one worker).
    retiring: usize,
    /// EWMA of wall-clock batch service time, µs; 0 until the first batch
    /// completes. Feeds the admission projection and the autoscaler's
    /// live per-replica capacity estimate.
    batch_us_ewma: f64,
}

struct Shared {
    pfw: Arc<PartitionedFirmware>,
    features: usize,
    batch: usize,
    policy: ContinuousPolicy,
    /// Simulated device time per batch, from the cycle model.
    device_us: f64,
    state: Mutex<QueueState>,
    work: Condvar,
    stats: AdmissionStats,
    metrics: Mutex<Metrics>,
    next_id: AtomicU64,
    batch_log: Mutex<Vec<Vec<u64>>>,
    /// Logical trace track the per-request queue-wait spans land on
    /// (their start and end are observed on different threads).
    queue_track: u32,
    /// Worker labels for trace tracks ("worker-0", "worker-1", …).
    worker_seq: AtomicU64,
    /// Firmware cache whose counters this server surfaces in snapshots
    /// (attached when an autoscaler re-plans against one).
    cache: Mutex<Option<Arc<FirmwareCache>>>,
    /// Measured-vs-predicted batch-latency drift (one stage: the whole
    /// pipeline executes inside each worker). Predicted time comes from
    /// the cycle model the server was spawned with.
    drift: Mutex<DriftDetector>,
}

/// A pending reply for one admitted request. Dropping the ticket abandons
/// the reply (the request still executes).
pub struct InferTicket {
    id: u64,
    rx: Receiver<Vec<Vec<i32>>>,
}

impl InferTicket {
    /// The queue-assigned request id (monotone in admission order).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the request's batch completes; one feature vector per
    /// model output.
    pub fn wait(self) -> Result<Vec<Vec<i32>>> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("continuous server dropped the reply (worker died)"))
    }
}

/// A client handle to the continuous-batching queue (cheap to clone;
/// thread-safe). Submission never blocks: it either admits and returns a
/// ticket or rejects with a typed error.
#[derive(Clone)]
pub struct ContinuousClient {
    shared: Arc<Shared>,
}

impl ContinuousClient {
    /// Submit one sample. Non-blocking: admission is decided immediately.
    pub fn submit(&self, features: Vec<i32>) -> Result<InferTicket, AdmissionError> {
        let tr = obs::tracer();
        let mut span = tr.span("serve", "submit");
        if features.len() != self.shared.features {
            let err = AdmissionError::FeatureMismatch {
                expected: self.shared.features,
                got: features.len(),
            };
            self.shared.stats.reject(&err);
            span.arg("outcome", "rejected_malformed");
            return Err(err);
        }
        let (tx, rx) = sync_channel(1);
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.stopped {
                let err = AdmissionError::Stopped;
                self.shared.stats.reject(&err);
                span.arg("outcome", "rejected_stopped");
                return Err(err);
            }
            let workers = st.live.saturating_sub(st.retiring).max(1);
            if let Err(err) = admit(
                &self.shared.policy.admission,
                st.pending.len(),
                self.shared.batch,
                workers,
                st.batch_us_ewma,
            ) {
                self.shared.stats.reject(&err);
                span.arg(
                    "outcome",
                    match &err {
                        AdmissionError::QueueFull { .. } => "shed_queue_full",
                        AdmissionError::DeadlineRisk { .. } => "shed_deadline",
                        _ => "rejected",
                    },
                );
                span.arg("queued", st.pending.len());
                return Err(err);
            }
            st.pending.push_back(Pending {
                req: Request { id, features, enqueued: Instant::now() },
                reply: tx,
                enqueued_us: tr.now_us(),
            });
            self.shared.stats.admit();
            span.arg("outcome", "admitted");
            span.arg("id", id);
            span.arg("queued", st.pending.len());
        }
        self.shared.work.notify_all();
        Ok(InferTicket { id, rx })
    }

    /// Submit and wait for every model output, in sink order.
    pub fn infer_multi(&self, features: Vec<i32>) -> Result<Vec<Vec<i32>>> {
        let ticket = self.submit(features)?;
        ticket.wait()
    }

    /// Submit and wait for the primary (first) model output.
    pub fn infer(&self, features: Vec<i32>) -> Result<Vec<i32>> {
        let mut outs = self.infer_multi(features)?;
        Ok(outs.swap_remove(0))
    }
}

/// Everything the autoscaler needs from one observation instant.
#[derive(Debug, Clone)]
pub struct ServingSnapshot {
    pub metrics: MetricsReport,
    pub admission: AdmissionReport,
    /// Requests queued (admitted, not yet claimed by a worker).
    pub queued: usize,
    /// The admission queue bound.
    pub queue_capacity: usize,
    /// Effective worker count (live minus pending retirements).
    pub replicas: usize,
    /// Firmware batch each worker executes.
    pub batch: usize,
    /// EWMA wall-clock batch service time, µs (0 before the first batch).
    pub batch_us: f64,
    /// Firmware-cache counters, when a cache is attached
    /// ([`ContinuousServer::attach_cache`]) — surfaces re-planning
    /// hit/miss/negative-entry behaviour next to the serving signals.
    pub cache: Option<CacheStats>,
    /// Measured-vs-predicted latency drift, once at least one batch has
    /// been measured (`None` before the first sample). The autoscaler
    /// folds [`DriftReport::correction`] into its model-derived capacity
    /// fallback.
    pub drift: Option<DriftReport>,
}

/// The running continuous-batching server.
pub struct ContinuousServer {
    shared: Arc<Shared>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl ContinuousServer {
    /// Spawn `replicas` worker threads pulling from one shared queue,
    /// predicting batch time with the default calibrated cycle model.
    pub fn spawn(
        pfw: Arc<PartitionedFirmware>,
        replicas: usize,
        policy: ContinuousPolicy,
    ) -> Result<ContinuousServer> {
        ContinuousServer::spawn_with_model(pfw, replicas, policy, &EngineModel::default())
    }

    /// Spawn with an explicit cycle model. The model sets the predicted
    /// per-batch device time the drift detector compares measured
    /// latencies against — tests inject a deliberately mis-scaled model
    /// to exercise the drift path.
    pub fn spawn_with_model(
        pfw: Arc<PartitionedFirmware>,
        replicas: usize,
        policy: ContinuousPolicy,
        model: &EngineModel,
    ) -> Result<ContinuousServer> {
        ensure!(replicas >= 1, "continuous server needs at least one replica worker");
        ensure!(policy.admission.queue_capacity >= 1, "queue capacity must be >= 1");
        pfw.check_invariants()?;
        let device_us = analyze_pipeline(&pfw, model).interval_us;
        let shared = Arc::new(Shared {
            features: pfw.input_features(),
            batch: pfw.batch(),
            pfw,
            policy,
            device_us,
            state: Mutex::new(QueueState {
                pending: VecDeque::new(),
                stopped: false,
                live: replicas,
                retiring: 0,
                batch_us_ewma: 0.0,
            }),
            work: Condvar::new(),
            stats: AdmissionStats::new(),
            metrics: Mutex::new(Metrics::new()),
            next_id: AtomicU64::new(0),
            batch_log: Mutex::new(Vec::new()),
            queue_track: obs::tracer().logical_track("queue"),
            worker_seq: AtomicU64::new(0),
            cache: Mutex::new(None),
            drift: Mutex::new(DriftDetector::new(&[device_us])),
        });
        let mut handles = Vec::with_capacity(replicas);
        for _ in 0..replicas {
            let s = shared.clone();
            handles.push(std::thread::spawn(move || worker_loop(&s)));
        }
        Ok(ContinuousServer { shared, handles: Mutex::new(handles) })
    }

    /// A submission handle (cheap to clone; thread-safe).
    pub fn client(&self) -> ContinuousClient {
        ContinuousClient { shared: self.shared.clone() }
    }

    /// The pipeline every worker executes.
    pub fn firmware(&self) -> &Arc<PartitionedFirmware> {
        &self.shared.pfw
    }

    /// Effective worker count (live minus pending retirements).
    pub fn replicas(&self) -> usize {
        let st = self.shared.state.lock().unwrap();
        st.live.saturating_sub(st.retiring)
    }

    /// Requests currently queued (admitted, not yet claimed).
    pub fn queued(&self) -> usize {
        self.shared.state.lock().unwrap().pending.len()
    }

    pub fn metrics(&self) -> MetricsReport {
        self.shared.metrics.lock().unwrap().report()
    }

    pub fn admission(&self) -> AdmissionReport {
        self.shared.stats.report()
    }

    /// Surface a firmware cache's counters in every later
    /// [`ContinuousServer::snapshot`] (typically the autoscaler's
    /// re-planning cache, so serve-loop summaries show hit/miss/negative
    /// counts next to the admission funnel).
    pub fn attach_cache(&self, cache: Arc<FirmwareCache>) {
        *self.shared.cache.lock().unwrap() = Some(cache);
    }

    /// One consistent observation for the autoscaler.
    pub fn snapshot(&self) -> ServingSnapshot {
        let (queued, replicas, batch_us) = {
            let st = self.shared.state.lock().unwrap();
            (st.pending.len(), st.live.saturating_sub(st.retiring), st.batch_us_ewma)
        };
        ServingSnapshot {
            metrics: self.metrics(),
            admission: self.shared.stats.report(),
            queued,
            queue_capacity: self.shared.policy.admission.queue_capacity,
            replicas,
            batch: self.shared.batch,
            batch_us,
            cache: self.shared.cache.lock().unwrap().as_ref().map(|c| c.stats()),
            drift: {
                let report = self.shared.drift.lock().unwrap().report();
                if report.has_samples() {
                    Some(report)
                } else {
                    None
                }
            },
        }
    }

    /// The per-batch request-id log (admission order within each executed
    /// batch). Empty unless the policy set `record_batches`.
    pub fn batch_log(&self) -> Vec<Vec<u64>> {
        self.shared.batch_log.lock().unwrap().clone()
    }

    /// Grow or shrink the effective worker count to `replicas` (≥ 1).
    /// Growth spawns workers onto the same queue immediately; shrinkage
    /// marks workers to retire at their next batch boundary, so in-flight
    /// and queued requests are never dropped by a scale-down.
    pub fn scale_to(&self, replicas: usize) -> Result<()> {
        ensure!(replicas >= 1, "continuous server needs at least one replica worker");
        let to_spawn = {
            let mut st = self.shared.state.lock().unwrap();
            ensure!(!st.stopped, "continuous server is shut down");
            let effective = st.live.saturating_sub(st.retiring);
            if replicas > effective {
                let mut grow = replicas - effective;
                // Cancel pending retirements before spawning new threads.
                let cancel = grow.min(st.retiring);
                st.retiring -= cancel;
                grow -= cancel;
                st.live += grow;
                grow
            } else {
                st.retiring += effective - replicas;
                self.shared.work.notify_all();
                0
            }
        };
        for _ in 0..to_spawn {
            let s = self.shared.clone();
            let h = std::thread::spawn(move || worker_loop(&s));
            self.handles.lock().unwrap().push(h);
        }
        Ok(())
    }

    /// Stop intake, drain the queue through the workers, join them all and
    /// return the final metrics and admission accounting.
    pub fn shutdown(self) -> (MetricsReport, AdmissionReport) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.stopped = true;
        }
        self.shared.work.notify_all();
        let handles: Vec<_> = self.handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        let report = self.shared.metrics.lock().unwrap().report();
        (report, self.shared.stats.report())
    }
}

/// One replica worker: claim up to one firmware batch from the queue
/// front (waiting for batch-full, the oldest request's deadline, or
/// shutdown), execute, reply per row, repeat — until retired or the
/// stopped queue runs dry.
fn worker_loop(shared: &Shared) {
    let batch = shared.batch;
    let tr = obs::tracer();
    tr.set_track_name(format!(
        "worker-{}",
        shared.worker_seq.fetch_add(1, Ordering::Relaxed)
    ));
    loop {
        let form_start_us = tr.now_us();
        let taken: Vec<Pending> = {
            let mut st = shared.state.lock().unwrap();
            loop {
                // Scale-down retires workers between batches; shutdown
                // drains first, so retirement yields to the stop flag.
                if st.retiring > 0 && !st.stopped {
                    st.retiring -= 1;
                    st.live -= 1;
                    return;
                }
                if st.stopped && st.pending.is_empty() {
                    st.live = st.live.saturating_sub(1);
                    return;
                }
                let n = st.pending.len();
                if n >= batch || (st.stopped && n > 0) {
                    break;
                }
                if n > 0 {
                    let age = st.pending.front().expect("n > 0").req.enqueued.elapsed();
                    if age >= shared.policy.max_wait {
                        break;
                    }
                    let (guard, _) = shared
                        .work
                        .wait_timeout(st, shared.policy.max_wait - age)
                        .expect("queue lock poisoned");
                    st = guard;
                } else {
                    let (guard, _) =
                        shared.work.wait_timeout(st, IDLE_POLL).expect("queue lock poisoned");
                    st = guard;
                }
            }
            let take = st.pending.len().min(batch);
            st.pending.drain(..take).collect()
        };
        let occupancy = taken.len();
        if tr.is_enabled() {
            let now = tr.now_us();
            // The wait for a claimable batch, on this worker's track.
            tr.record_span(
                "serve",
                "batch_form",
                tr.current_track(),
                form_start_us,
                now,
                vec![("occupancy", occupancy.into())],
            );
            // Each claimed request's queue residency, on the queue track.
            for p in &taken {
                tr.record_span(
                    "serve",
                    "queue_wait",
                    shared.queue_track,
                    p.enqueued_us,
                    now,
                    vec![("id", p.req.id.into())],
                );
            }
        }
        let exec_span = tr
            .span("serve", "batch_execute")
            .with_arg("occupancy", occupancy)
            .with_arg("batch", batch);
        let t0 = Instant::now();
        let mut data = vec![0i32; batch * shared.features];
        for (i, p) in taken.iter().enumerate() {
            data[i * shared.features..(i + 1) * shared.features]
                .copy_from_slice(&p.req.features);
        }
        let act = Activation::new(batch, shared.features, data)
            .expect("admission guarantees request shapes");
        let outs = execute_partitioned(&shared.pfw, &act).expect("pipeline execution failed");
        let exec_us = t0.elapsed().as_secs_f64() * 1e6;
        drop(exec_span);
        shared.drift.lock().unwrap().observe(0, exec_us);
        {
            let mut st = shared.state.lock().unwrap();
            st.batch_us_ewma = if st.batch_us_ewma == 0.0 {
                exec_us
            } else {
                0.7 * st.batch_us_ewma + 0.3 * exec_us
            };
        }
        if shared.policy.record_batches {
            shared
                .batch_log
                .lock()
                .unwrap()
                .push(taken.iter().map(|p| p.req.id).collect());
        }
        let dispatch_span = tr.span("serve", "dispatch").with_arg("occupancy", occupancy);
        let mut delays = Vec::with_capacity(occupancy);
        for (slot, p) in taken.into_iter().enumerate() {
            let _ = p.reply.send(outs.iter().map(|o| o.row(slot).to_vec()).collect());
            delays.push(p.req.enqueued.elapsed());
            if tr.is_enabled() {
                tr.instant("serve", "complete")
                    .with_arg("id", p.req.id)
                    .with_arg("latency_us", p.req.enqueued.elapsed().as_secs_f64() * 1e6);
            }
        }
        drop(dispatch_span);
        shared
            .metrics
            .lock()
            .unwrap()
            .record_batch(occupancy, batch, &delays, shared.device_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::CompileConfig;
    use crate::harness::models::{mlp_spec, synth_model};
    use crate::partition::{compile_partitioned, PartitionOptions};

    fn pipeline(name: &str, k: usize, batch: usize) -> Arc<PartitionedFirmware> {
        let json = synth_model(name, &mlp_spec(&[24, 16, 8], crate::arch::Dtype::I8), 6);
        let mut cfg = CompileConfig::default();
        cfg.batch = batch;
        cfg.tiles_per_layer = Some(1);
        let opts = PartitionOptions { partitions: Some(k), max_partitions: k };
        Arc::new(compile_partitioned(&json, cfg, &opts).unwrap().firmware)
    }

    #[test]
    fn serves_and_accounts_admissions() {
        let server = ContinuousServer::spawn(
            pipeline("cont_basic", 1, 4),
            2,
            ContinuousPolicy { max_wait: Duration::from_millis(2), ..Default::default() },
        )
        .unwrap();
        let c = server.client();
        let golden = c.infer(vec![3; 24]).unwrap();
        assert_eq!(golden.len(), 8);
        for _ in 0..7 {
            assert_eq!(c.infer(vec![3; 24]).unwrap(), golden);
        }
        let (m, a) = server.shutdown();
        assert_eq!(m.requests, 8);
        assert_eq!(a.submitted, 8);
        assert_eq!(a.admitted, 8);
        assert_eq!(a.shed(), 0);
    }

    #[test]
    fn scale_transitions_keep_one_worker_and_update_counts() {
        let server = ContinuousServer::spawn(
            pipeline("cont_scale", 1, 2),
            1,
            ContinuousPolicy { max_wait: Duration::from_millis(1), ..Default::default() },
        )
        .unwrap();
        assert_eq!(server.replicas(), 1);
        server.scale_to(3).unwrap();
        assert_eq!(server.replicas(), 3);
        // Shrink marks retirements immediately; the effective count drops
        // even before the threads reach their next batch boundary.
        server.scale_to(1).unwrap();
        assert_eq!(server.replicas(), 1);
        assert!(server.scale_to(0).is_err());
        let c = server.client();
        assert_eq!(c.infer(vec![1; 24]).unwrap().len(), 8);
        let (m, _) = server.shutdown();
        assert_eq!(m.requests, 1);
    }

    #[test]
    fn snapshot_reports_drift_after_batches() {
        let server = ContinuousServer::spawn_with_model(
            pipeline("cont_drift", 1, 2),
            1,
            ContinuousPolicy { max_wait: Duration::from_millis(1), ..Default::default() },
            &EngineModel::default(),
        )
        .unwrap();
        // No drift before the first measured batch.
        assert!(server.snapshot().drift.is_none());
        let c = server.client();
        c.infer(vec![1; 24]).unwrap();
        let snap = server.snapshot();
        let d = snap.drift.expect("drift present after first batch");
        assert_eq!(d.stages.len(), 1);
        assert!(d.total_samples >= 1);
        // Host wall-clock vs modeled device time: any positive ratio is
        // valid, but it must be a real measurement.
        assert!(d.overall_ratio > 0.0);
        assert!(d.correction > 0.0);
        server.shutdown();
    }

    #[test]
    fn mis_sized_and_post_shutdown_submissions_get_typed_errors() {
        let server = ContinuousServer::spawn(
            pipeline("cont_typed", 2, 2),
            1,
            ContinuousPolicy::default(),
        )
        .unwrap();
        let c = server.client();
        match c.submit(vec![0; 7]) {
            Err(AdmissionError::FeatureMismatch { expected: 24, got: 7 }) => {}
            other => panic!("expected FeatureMismatch, got {:?}", other.map(|t| t.id())),
        }
        server.shutdown();
        assert!(matches!(c.submit(vec![0; 24]), Err(AdmissionError::Stopped)));
    }
}
