//! Admission control for the async serving path: bounded-queue
//! backpressure with typed shed errors.
//!
//! The synchronous servers apply backpressure by blocking the sender on a
//! full channel — fine for closed-loop clients, fatal for an open-loop
//! trigger stream where events keep arriving whether or not the fleet can
//! absorb them. The continuous-batching path instead *decides* at submit
//! time: a request is admitted only if the queue has room **and** its
//! projected sojourn time fits the latency budget; otherwise it is shed
//! immediately with a typed [`AdmissionError`], so the caller (or the
//! upstream trigger) can degrade deliberately instead of watching tail
//! latency grow without bound.
//!
//! Every decision is counted in [`AdmissionStats`]; the deploy layer's
//! autoscaler consumes windowed deltas of the resulting
//! [`AdmissionReport`] as its SLO-burn signal.

use std::sync::atomic::{AtomicU64, Ordering};
use thiserror::Error;

/// Why a request was not admitted. `QueueFull` and `DeadlineRisk` are
/// *sheds* (a well-formed request the server chose not to serve);
/// `FeatureMismatch` is a malformed request; `Stopped` is a server
/// lifecycle error.
#[derive(Debug, Error, Clone, PartialEq)]
pub enum AdmissionError {
    #[error("queue full: {depth} queued requests at capacity {capacity} — request shed")]
    QueueFull { depth: usize, capacity: usize },
    #[error(
        "projected queue delay {projected_us:.1} µs busts the {budget_us:.1} µs latency \
         budget — request shed"
    )]
    DeadlineRisk { projected_us: f64, budget_us: f64 },
    #[error("request carries {got} features, model expects {expected}")]
    FeatureMismatch { expected: usize, got: usize },
    #[error("server stopped")]
    Stopped,
}

/// Admission knobs for the continuous-batching queue.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Hard bound on queued (not yet executing) requests; submissions
    /// beyond it are shed with [`AdmissionError::QueueFull`].
    pub queue_capacity: usize,
    /// Latency budget in µs: once the projected queue delay plus service
    /// time would bust it, requests are shed with
    /// [`AdmissionError::DeadlineRisk`]. `None` disables delay shedding
    /// (the queue bound still applies).
    pub latency_budget_us: Option<f64>,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { queue_capacity: 1024, latency_budget_us: None }
    }
}

/// Projected sojourn time, in µs, of a request admitted at queue position
/// `depth`: the batches already queued ahead of it drain across `workers`
/// replicas at the observed per-batch service time, then its own batch
/// executes. Deliberately simple — an M/D/c delay bound, not a simulator —
/// because it only has to be right about *order of magnitude* to keep the
/// tail inside the budget.
pub fn projected_latency_us(depth: usize, batch: usize, workers: usize, batch_us: f64) -> f64 {
    let batches_ahead = (depth / batch.max(1)) as f64;
    batches_ahead * batch_us / workers.max(1) as f64 + batch_us
}

/// The admission decision for one well-formed request, given queue state.
/// `observed_batch_us` is the serving loop's EWMA of wall-clock batch
/// service time; until the first batch completes (0.0) delay shedding is
/// skipped because there is nothing credible to project from.
pub fn admit(
    cfg: &AdmissionConfig,
    depth: usize,
    batch: usize,
    workers: usize,
    observed_batch_us: f64,
) -> Result<(), AdmissionError> {
    if depth >= cfg.queue_capacity {
        return Err(AdmissionError::QueueFull { depth, capacity: cfg.queue_capacity });
    }
    if let Some(budget_us) = cfg.latency_budget_us {
        if observed_batch_us > 0.0 {
            let projected_us = projected_latency_us(depth, batch, workers, observed_batch_us);
            if projected_us > budget_us {
                return Err(AdmissionError::DeadlineRisk { projected_us, budget_us });
            }
        }
    }
    Ok(())
}

/// Atomic counters for every admission decision a server makes.
#[derive(Debug, Default)]
pub struct AdmissionStats {
    submitted: AtomicU64,
    admitted: AtomicU64,
    shed_queue_full: AtomicU64,
    shed_deadline: AtomicU64,
    rejected_malformed: AtomicU64,
    rejected_stopped: AtomicU64,
}

impl AdmissionStats {
    pub fn new() -> AdmissionStats {
        AdmissionStats::default()
    }

    /// Count one admitted request.
    pub fn admit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.admitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one rejected request under the matching counter.
    pub fn reject(&self, err: &AdmissionError) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        match err {
            AdmissionError::QueueFull { .. } => {
                self.shed_queue_full.fetch_add(1, Ordering::Relaxed);
            }
            AdmissionError::DeadlineRisk { .. } => {
                self.shed_deadline.fetch_add(1, Ordering::Relaxed);
            }
            AdmissionError::FeatureMismatch { .. } => {
                self.rejected_malformed.fetch_add(1, Ordering::Relaxed);
            }
            AdmissionError::Stopped => {
                // Counted under its own reason: without this, `submitted`
                // drifts ahead of the per-reason sum and the conservation
                // identity submitted == admitted + shed + rejected breaks
                // whenever a request races server shutdown.
                self.rejected_stopped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    pub fn report(&self) -> AdmissionReport {
        AdmissionReport {
            submitted: self.submitted.load(Ordering::Relaxed),
            admitted: self.admitted.load(Ordering::Relaxed),
            shed_queue_full: self.shed_queue_full.load(Ordering::Relaxed),
            shed_deadline: self.shed_deadline.load(Ordering::Relaxed),
            rejected_malformed: self.rejected_malformed.load(Ordering::Relaxed),
            rejected_stopped: self.rejected_stopped.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time snapshot of [`AdmissionStats`]. Counters are
/// cumulative; [`AdmissionReport::delta`] turns two snapshots into a
/// window.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct AdmissionReport {
    pub submitted: u64,
    pub admitted: u64,
    pub shed_queue_full: u64,
    pub shed_deadline: u64,
    pub rejected_malformed: u64,
    pub rejected_stopped: u64,
}

impl AdmissionReport {
    /// Well-formed requests the server chose not to serve.
    pub fn shed(&self) -> u64 {
        self.shed_queue_full + self.shed_deadline
    }

    /// Requests refused for non-load reasons (malformed or server
    /// stopped).
    pub fn rejected(&self) -> u64 {
        self.rejected_malformed + self.rejected_stopped
    }

    /// The conservation identity every snapshot must satisfy: each
    /// submitted request landed in exactly one outcome bucket.
    pub fn is_conserved(&self) -> bool {
        self.submitted == self.admitted + self.shed() + self.rejected()
    }

    /// Shed fraction of everything submitted (0.0 when idle).
    pub fn shed_ratio(&self) -> f64 {
        if self.submitted == 0 {
            0.0
        } else {
            self.shed() as f64 / self.submitted as f64
        }
    }

    /// The window between an `earlier` snapshot and this one.
    pub fn delta(&self, earlier: &AdmissionReport) -> AdmissionReport {
        AdmissionReport {
            submitted: self.submitted.saturating_sub(earlier.submitted),
            admitted: self.admitted.saturating_sub(earlier.admitted),
            shed_queue_full: self.shed_queue_full.saturating_sub(earlier.shed_queue_full),
            shed_deadline: self.shed_deadline.saturating_sub(earlier.shed_deadline),
            rejected_malformed: self
                .rejected_malformed
                .saturating_sub(earlier.rejected_malformed),
            rejected_stopped: self.rejected_stopped.saturating_sub(earlier.rejected_stopped),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_bound_is_hard() {
        let cfg = AdmissionConfig { queue_capacity: 4, latency_budget_us: None };
        assert!(admit(&cfg, 3, 8, 1, 0.0).is_ok());
        match admit(&cfg, 4, 8, 1, 0.0) {
            Err(AdmissionError::QueueFull { depth: 4, capacity: 4 }) => {}
            other => panic!("expected QueueFull, got {other:?}"),
        }
    }

    #[test]
    fn deadline_shedding_projects_queue_drain() {
        let cfg = AdmissionConfig { queue_capacity: 1024, latency_budget_us: Some(1000.0) };
        // Empty queue: one batch time (400 µs) fits the 1000 µs budget.
        assert!(admit(&cfg, 0, 8, 1, 400.0).is_ok());
        // 2 full batches ahead on 1 worker: 2*400 + 400 busts it.
        match admit(&cfg, 16, 8, 1, 400.0) {
            Err(AdmissionError::DeadlineRisk { projected_us, budget_us }) => {
                assert!((projected_us - 1200.0).abs() < 1e-9);
                assert!((budget_us - 1000.0).abs() < 1e-9);
            }
            other => panic!("expected DeadlineRisk, got {other:?}"),
        }
        // Same backlog across 4 workers drains in parallel: admitted.
        assert!(admit(&cfg, 16, 8, 4, 400.0).is_ok());
        // No observation yet: delay shedding stands down, queue bound holds.
        assert!(admit(&cfg, 512, 8, 1, 0.0).is_ok());
    }

    #[test]
    fn stats_partition_by_outcome() {
        let stats = AdmissionStats::new();
        stats.admit();
        stats.admit();
        stats.reject(&AdmissionError::QueueFull { depth: 1, capacity: 1 });
        stats.reject(&AdmissionError::DeadlineRisk { projected_us: 2.0, budget_us: 1.0 });
        stats.reject(&AdmissionError::FeatureMismatch { expected: 8, got: 7 });
        stats.reject(&AdmissionError::Stopped);
        let r = stats.report();
        assert_eq!(r.submitted, 6);
        assert_eq!(r.admitted, 2);
        assert_eq!(r.shed_queue_full, 1);
        assert_eq!(r.shed_deadline, 1);
        assert_eq!(r.rejected_malformed, 1);
        assert_eq!(r.rejected_stopped, 1);
        assert_eq!(r.shed(), 2);
        assert_eq!(r.rejected(), 2);
        // Conservation holds even with Stopped rejections in the mix
        // (regression: Stopped used to bump `submitted` with no reason
        // counter, leaving the identity short by one per occurrence).
        assert!(r.is_conserved());
        assert!((r.shed_ratio() - 2.0 / 6.0).abs() < 1e-12);
        // Windows difference cleanly.
        stats.admit();
        let w = stats.report().delta(&r);
        assert_eq!(w.submitted, 1);
        assert_eq!(w.admitted, 1);
        assert_eq!(w.shed(), 0);
        assert_eq!(w.rejected(), 0);
        assert!(w.is_conserved());
        assert_eq!(w.shed_ratio(), 0.0);
    }
}
