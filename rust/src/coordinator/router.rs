//! Multi-model router: one serving endpoint in front of many compiled
//! firmware instances (the vLLM-router shape, scaled to the trigger world).
//!
//! A trigger farm runs several classifiers concurrently (e.g. jet tagging,
//! muon ID, anomaly scoring) on the same host; the router owns one
//! [`Server`] per model, routes requests by model name, and aggregates
//! metrics. Registration is dynamic: models can be added while serving
//! (the paper's RTP-reload story — new coefficients without rebuilds —
//! corresponds to re-registering a model under the same name).

use super::metrics::MetricsReport;
use super::server::Server;
use crate::codegen::firmware::Firmware;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, RwLock};
use std::time::Duration;

/// Routing table entry.
struct Entry {
    server: Server,
    features: usize,
}

/// The router. Cheap to share (`Arc<Router>`); all methods take `&self`.
pub struct Router {
    table: RwLock<HashMap<String, Entry>>,
    max_wait: Duration,
    queue_depth: usize,
}

impl Router {
    pub fn new(max_wait: Duration, queue_depth: usize) -> Router {
        Router { table: RwLock::new(HashMap::new()), max_wait, queue_depth }
    }

    /// Register (or replace) a model. Replacing drains the old server.
    pub fn register(&self, name: &str, fw: Arc<Firmware>) -> Result<()> {
        let features = fw.input_features();
        let server = Server::spawn(fw, self.max_wait, self.queue_depth);
        let old = self
            .table
            .write()
            .unwrap()
            .insert(name.to_string(), Entry { server, features });
        if let Some(e) = old {
            e.server.shutdown();
        }
        Ok(())
    }

    /// Deregister a model, draining its server; returns its final metrics.
    pub fn deregister(&self, name: &str) -> Result<MetricsReport> {
        let entry = self
            .table
            .write()
            .unwrap()
            .remove(name)
            .with_context(|| format!("model '{name}' not registered"))?;
        Ok(entry.server.shutdown())
    }

    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<String> = self.table.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Route one request to `model`. Blocks until the batch it lands in
    /// completes (same contract as [`super::Client::infer`]).
    pub fn infer(&self, model: &str, features: Vec<i32>) -> Result<Vec<i32>> {
        // Clone the client under the read lock, then release it before the
        // (potentially long) inference wait.
        let client = {
            let table = self.table.read().unwrap();
            let Some(entry) = table.get(model) else {
                bail!("model '{model}' not registered (have: {:?})", {
                    let mut v: Vec<&String> = table.keys().collect();
                    v.sort();
                    v
                })
            };
            if features.len() != entry.features {
                bail!(
                    "model '{model}' expects {} features, got {}",
                    entry.features,
                    features.len()
                );
            }
            entry.server.client.clone()
        };
        client.infer(features)
    }

    /// Per-model metrics snapshot.
    pub fn metrics(&self) -> HashMap<String, MetricsReport> {
        self.table
            .read()
            .unwrap()
            .iter()
            .map(|(k, e)| (k.clone(), e.server.metrics()))
            .collect()
    }

    /// Drain every server.
    pub fn shutdown(self) -> HashMap<String, MetricsReport> {
        self.table
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|(k, e)| (k, e.server.shutdown()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dtype;
    use crate::harness::models::compile_mlp;

    fn fw(name: &str, dims: &[usize], batch: usize) -> Arc<Firmware> {
        Arc::new(
            compile_mlp(name, dims, Dtype::I8, batch, Some((1, 2)))
                .unwrap()
                .firmware
                .unwrap(),
        )
    }

    #[test]
    fn routes_by_model_name() {
        let router = Router::new(Duration::from_millis(2), 64);
        router.register("jets", fw("jets", &[16, 8, 4], 4)).unwrap();
        router.register("muons", fw("muons", &[24, 8, 2], 4)).unwrap();
        assert_eq!(router.models(), vec!["jets", "muons"]);
        let a = router.infer("jets", vec![1; 16]).unwrap();
        let b = router.infer("muons", vec![1; 24]).unwrap();
        assert_eq!(a.len(), 4);
        assert_eq!(b.len(), 2);
        let m = router.shutdown();
        assert_eq!(m["jets"].requests, 1);
        assert_eq!(m["muons"].requests, 1);
    }

    #[test]
    fn unknown_model_and_bad_shape_rejected() {
        let router = Router::new(Duration::from_millis(2), 8);
        router.register("only", fw("only", &[8, 4], 2)).unwrap();
        assert!(router.infer("nope", vec![0; 8]).is_err());
        assert!(router.infer("only", vec![0; 7]).is_err());
        router.shutdown();
    }

    #[test]
    fn reregister_replaces_model() {
        let router = Router::new(Duration::from_millis(2), 8);
        router.register("m", fw("v1", &[8, 4], 2)).unwrap();
        let y1 = router.infer("m", vec![5; 8]).unwrap();
        // New coefficients under the same name (different seed -> weights).
        router.register("m", fw("v2", &[8, 4], 2)).unwrap();
        let y2 = router.infer("m", vec![5; 8]).unwrap();
        assert_eq!(y1.len(), y2.len());
        assert_ne!(y1, y2, "new weights must change outputs");
        router.shutdown();
    }

    #[test]
    fn concurrent_multi_model_traffic() {
        let router = Router::new(Duration::from_millis(5), 256);
        router.register("a", fw("ma", &[8, 4], 4)).unwrap();
        router.register("b", fw("mb", &[8, 4], 4)).unwrap();
        std::thread::scope(|scope| {
            for t in 0..6 {
                let r = &router;
                scope.spawn(move || {
                    let model = if t % 2 == 0 { "a" } else { "b" };
                    for i in 0..20 {
                        let out = r.infer(model, vec![(i % 5) as i32; 8]).unwrap();
                        assert_eq!(out.len(), 4);
                    }
                });
            }
        });
        // Metrics are recorded after replies are delivered, so only the
        // post-drain (shutdown) report is exact.
        let metrics = router.shutdown();
        assert_eq!(metrics["a"].requests + metrics["b"].requests, 120);
    }
}
