//! Multi-model router: one serving endpoint in front of many compiled
//! firmware instances (the vLLM-router shape, scaled to the trigger world).
//!
//! A trigger farm runs several classifiers concurrently (e.g. jet tagging,
//! muon ID, anomaly scoring) on the same host; the router owns one entry
//! per model name, routes requests by name, and aggregates metrics. Each
//! entry holds one **or more** [`Server`] replicas behind least-loaded
//! dispatch ([`least_loaded`]): a request lands on the replica with the
//! fewest in-flight requests, ties rotating round-robin, so no replica
//! sits idle while another queues. Registration is dynamic: models can be
//! added while serving (the paper's RTP-reload story — new coefficients
//! without rebuilds — corresponds to re-registering a model under the same
//! name). The replicated-fleet deployment layer
//! ([`crate::deploy::FleetServer`]) builds on the same dispatch policy.

use super::admission::AdmissionError;
use super::metrics::MetricsReport;
use super::server::Server;
use crate::codegen::firmware::Firmware;
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Duration;

/// Pick the least-loaded replica: the index with the smallest in-flight
/// count. `rotate` breaks ties fairly — among equally loaded replicas the
/// `rotate % ties`-th one is chosen, so an idle fleet still spreads
/// requests round-robin instead of hammering replica 0.
pub fn least_loaded(inflight: &[usize], rotate: usize) -> Option<usize> {
    let min = *inflight.iter().min()?;
    let ties: Vec<usize> = inflight
        .iter()
        .enumerate()
        .filter(|(_, &load)| load == min)
        .map(|(i, _)| i)
        .collect();
    Some(ties[rotate % ties.len()])
}

/// The dispatch policy's mutable state: [`least_loaded`] selection plus
/// the rotation counter that keeps tie-breaking fair across calls. One
/// instance per replica set — [`Router`] entries and
/// [`crate::deploy::FleetServer`] share this exact state machine.
#[derive(Debug, Default)]
pub struct LeastLoaded {
    rotate: AtomicUsize,
}

impl LeastLoaded {
    pub fn new() -> LeastLoaded {
        LeastLoaded::default()
    }

    /// Pick the replica for one dispatch, advancing the tie rotation.
    pub fn pick(&self, loads: &[usize]) -> Option<usize> {
        least_loaded(loads, self.rotate.fetch_add(1, Ordering::Relaxed))
    }
}

/// One server replica plus its in-flight request counter.
struct Replica {
    server: Server,
    inflight: Arc<AtomicUsize>,
}

/// Routing table entry: R ≥ 1 replicas of one model.
struct Entry {
    replicas: Vec<Replica>,
    features: usize,
    policy: LeastLoaded,
}

/// The router. Cheap to share (`Arc<Router>`); all methods take `&self`.
pub struct Router {
    table: RwLock<HashMap<String, Entry>>,
    max_wait: Duration,
    queue_depth: usize,
}

impl Router {
    pub fn new(max_wait: Duration, queue_depth: usize) -> Router {
        Router { table: RwLock::new(HashMap::new()), max_wait, queue_depth }
    }

    /// Register (or replace) a model with a single replica.
    pub fn register(&self, name: &str, fw: Arc<Firmware>) -> Result<()> {
        self.register_replicated(name, fw, 1)
    }

    /// Register (or replace) a model served by `replicas` identical
    /// servers behind least-loaded dispatch. Replacing drains every old
    /// replica after the new entry is installed.
    pub fn register_replicated(
        &self,
        name: &str,
        fw: Arc<Firmware>,
        replicas: usize,
    ) -> Result<()> {
        ensure!(replicas >= 1, "model '{name}': replica count must be >= 1");
        let features = fw.input_features();
        let entry = Entry {
            replicas: (0..replicas)
                .map(|_| Replica {
                    server: Server::spawn(fw.clone(), self.max_wait, self.queue_depth),
                    inflight: Arc::new(AtomicUsize::new(0)),
                })
                .collect(),
            features,
            policy: LeastLoaded::new(),
        };
        let old = self.table.write().unwrap().insert(name.to_string(), entry);
        if let Some(e) = old {
            for r in e.replicas {
                r.server.shutdown();
            }
        }
        Ok(())
    }

    /// Deregister a model, draining its replicas; returns the merged final
    /// metrics across all of them.
    pub fn deregister(&self, name: &str) -> Result<MetricsReport> {
        let entry = self
            .table
            .write()
            .unwrap()
            .remove(name)
            .with_context(|| format!("model '{name}' not registered"))?;
        let reports: Vec<MetricsReport> =
            entry.replicas.into_iter().map(|r| r.server.shutdown()).collect();
        Ok(MetricsReport::merged(&reports))
    }

    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<String> = self.table.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Route one request to `model`, landing it on the least-loaded
    /// replica. Blocks until the batch it lands in completes (same
    /// contract as [`super::Client::infer`]).
    pub fn infer(&self, model: &str, features: Vec<i32>) -> Result<Vec<i32>> {
        // Pick a replica and clone its client under the read lock, then
        // release the lock before the (potentially long) inference wait.
        let (client, inflight) = {
            let table = self.table.read().unwrap();
            let Some(entry) = table.get(model) else {
                bail!("model '{model}' not registered (have: {:?})", {
                    let mut v: Vec<&String> = table.keys().collect();
                    v.sort();
                    v
                })
            };
            if features.len() != entry.features {
                return Err(AdmissionError::FeatureMismatch {
                    expected: entry.features,
                    got: features.len(),
                })
                .with_context(|| format!("model '{model}' rejected the request"));
            }
            let loads: Vec<usize> =
                entry.replicas.iter().map(|r| r.inflight.load(Ordering::Relaxed)).collect();
            let pick = entry.policy.pick(&loads).expect("entry has at least one replica");
            let replica = &entry.replicas[pick];
            replica.inflight.fetch_add(1, Ordering::Relaxed);
            (replica.server.client.clone(), replica.inflight.clone())
        };
        let out = client.infer(features);
        inflight.fetch_sub(1, Ordering::Relaxed);
        out
    }

    /// Per-model metrics snapshot (replicas merged).
    pub fn metrics(&self) -> HashMap<String, MetricsReport> {
        self.table
            .read()
            .unwrap()
            .iter()
            .map(|(k, e)| {
                let reports: Vec<MetricsReport> =
                    e.replicas.iter().map(|r| r.server.metrics()).collect();
                (k.clone(), MetricsReport::merged(&reports))
            })
            .collect()
    }

    /// Drain every server.
    pub fn shutdown(self) -> HashMap<String, MetricsReport> {
        self.table
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|(k, e)| {
                let reports: Vec<MetricsReport> =
                    e.replicas.into_iter().map(|r| r.server.shutdown()).collect();
                (k, MetricsReport::merged(&reports))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dtype;
    use crate::harness::models::compile_mlp;

    fn fw(name: &str, dims: &[usize], batch: usize) -> Arc<Firmware> {
        Arc::new(
            compile_mlp(name, dims, Dtype::I8, batch, Some((1, 2)))
                .unwrap()
                .firmware
                .unwrap(),
        )
    }

    #[test]
    fn least_loaded_picks_minimum_and_rotates_ties() {
        assert_eq!(least_loaded(&[], 0), None);
        assert_eq!(least_loaded(&[2, 0, 1], 0), Some(1));
        assert_eq!(least_loaded(&[2, 0, 1], 7), Some(1));
        // All idle: rotation spreads across every replica.
        assert_eq!(least_loaded(&[0, 0, 0], 0), Some(0));
        assert_eq!(least_loaded(&[0, 0, 0], 1), Some(1));
        assert_eq!(least_loaded(&[0, 0, 0], 5), Some(2));
        // Two-way tie among replicas 0 and 2.
        assert_eq!(least_loaded(&[1, 3, 1], 1), Some(2));
        // Work conservation: an idle replica always beats a queued one.
        assert_eq!(least_loaded(&[4, 1, 0, 1], 3), Some(2));
    }

    #[test]
    fn routes_by_model_name() {
        let router = Router::new(Duration::from_millis(2), 64);
        router.register("jets", fw("jets", &[16, 8, 4], 4)).unwrap();
        router.register("muons", fw("muons", &[24, 8, 2], 4)).unwrap();
        assert_eq!(router.models(), vec!["jets", "muons"]);
        let a = router.infer("jets", vec![1; 16]).unwrap();
        let b = router.infer("muons", vec![1; 24]).unwrap();
        assert_eq!(a.len(), 4);
        assert_eq!(b.len(), 2);
        let m = router.shutdown();
        assert_eq!(m["jets"].requests, 1);
        assert_eq!(m["muons"].requests, 1);
    }

    #[test]
    fn unknown_model_and_bad_shape_rejected() {
        let router = Router::new(Duration::from_millis(2), 8);
        router.register("only", fw("only", &[8, 4], 2)).unwrap();
        assert!(router.infer("nope", vec![0; 8]).is_err());
        assert!(router.infer("only", vec![0; 7]).is_err());
        assert!(router.register_replicated("only", fw("only", &[8, 4], 2), 0).is_err());
        router.shutdown();
    }

    #[test]
    fn reregister_replaces_model() {
        let router = Router::new(Duration::from_millis(2), 8);
        router.register("m", fw("v1", &[8, 4], 2)).unwrap();
        let y1 = router.infer("m", vec![5; 8]).unwrap();
        // New coefficients under the same name (different seed -> weights).
        router.register("m", fw("v2", &[8, 4], 2)).unwrap();
        let y2 = router.infer("m", vec![5; 8]).unwrap();
        assert_eq!(y1.len(), y2.len());
        assert_ne!(y1, y2, "new weights must change outputs");
        router.shutdown();
    }

    #[test]
    fn replicated_entry_spreads_requests_and_answers_consistently() {
        let router = Router::new(Duration::from_millis(1), 64);
        router.register_replicated("rep", fw("rep", &[8, 4], 2), 3).unwrap();
        // Identical inputs must produce identical outputs whichever replica
        // (and batch slot) serves them.
        let golden = router.infer("rep", vec![3; 8]).unwrap();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let r = &router;
                let golden = &golden;
                scope.spawn(move || {
                    for _ in 0..6 {
                        assert_eq!(&r.infer("rep", vec![3; 8]).unwrap(), golden);
                    }
                });
            }
        });
        let m = router.shutdown();
        // Replica metrics merge into one per-model report.
        assert_eq!(m["rep"].requests, 25);
        assert!(m["rep"].batches >= 13, "batch 2 => at least ceil(25/2) batches");
    }

    #[test]
    fn concurrent_multi_model_traffic() {
        let router = Router::new(Duration::from_millis(5), 256);
        router.register("a", fw("ma", &[8, 4], 4)).unwrap();
        router.register("b", fw("mb", &[8, 4], 4)).unwrap();
        std::thread::scope(|scope| {
            for t in 0..6 {
                let r = &router;
                scope.spawn(move || {
                    let model = if t % 2 == 0 { "a" } else { "b" };
                    for i in 0..20 {
                        let out = r.infer(model, vec![(i % 5) as i32; 8]).unwrap();
                        assert_eq!(out.len(), 4);
                    }
                });
            }
        });
        // Metrics are recorded after replies are delivered, so only the
        // post-drain (shutdown) report is exact.
        let metrics = router.shutdown();
        assert_eq!(metrics["a"].requests + metrics["b"].requests, 120);
    }
}
