//! Pipelined multi-array serving: one thread per partition, batches
//! overlapping across arrays.
//!
//! [`PipelineServer`] is the multi-array sibling of [`super::Server`]: a
//! front batcher drains the request queue exactly like the single-array
//! loop, but instead of executing the whole model in place it hands each
//! flushed batch to a chain of *stage threads* — one per partition, i.e.
//! one per simulated array. Stage `i` executes its partition's firmware,
//! keeps any final model outputs the batch produced there, and forwards
//! the link activation to stage `i + 1`, so while array 1 computes batch
//! `t`, array 0 is already computing batch `t + 1` — the steady-state
//! interval is governed by the slowest partition, exactly as
//! [`crate::partition::analyze_pipeline`] models it.
//!
//! Each stage records per-partition metrics — input-queue depth at
//! dequeue time and the fraction of wall-clock time spent executing — so
//! pipeline imbalance is observable in the final [`MetricsReport`]
//! (`stages[i].busy_fraction` ≈ 1 marks the bottleneck array).

use super::admission::{AdmissionError, AdmissionReport};
use super::batcher::{BatchPolicy, Batcher, Request};
use super::continuous::ServingSnapshot;
use super::metrics::{Metrics, MetricsReport};
use crate::obs::attrib::DriftDetector;
use crate::partition::{analyze_pipeline, PartitionedFirmware};
use crate::sim::engine::EngineModel;
use crate::sim::functional::{execute_all, Activation};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Replies carry one feature vector per final model output (sink), in
/// [`PartitionedFirmware::outputs`] order.
type Reply = SyncSender<Vec<Vec<i32>>>;

enum Msg {
    Req(Request, Reply),
    Shutdown,
}

/// One batch traversing the pipeline.
struct StageJob {
    ids: Vec<u64>,
    occupancy: usize,
    replies: Vec<(u64, Reply)>,
    queue_delays: Vec<Duration>,
    flushed_at: Instant,
    /// Input activation for the next stage (the link tensor).
    act: Activation,
    /// Final model outputs produced by earlier stages:
    /// `(index into outputs, activation)`.
    finals: Vec<(usize, Activation)>,
}

/// A client handle to the pipeline (cheap to clone; thread-safe).
#[derive(Clone)]
pub struct PipelineClient {
    tx: SyncSender<Msg>,
    next_id: Arc<AtomicU64>,
    features: usize,
}

impl PipelineClient {
    /// Submit one sample and wait for the primary (first) model output.
    pub fn infer(&self, features: Vec<i32>) -> Result<Vec<i32>> {
        let mut outs = self.infer_multi(features)?;
        Ok(outs.swap_remove(0))
    }

    /// Submit one sample and wait for every model output, in sink order.
    /// Mis-sized requests are rejected with the typed admission error.
    pub fn infer_multi(&self, features: Vec<i32>) -> Result<Vec<Vec<i32>>> {
        if features.len() != self.features {
            return Err(AdmissionError::FeatureMismatch {
                expected: self.features,
                got: features.len(),
            }
            .into());
        }
        let (tx, rx) = sync_channel(1);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Msg::Req(Request { id, features, enqueued: Instant::now() }, tx))
            .map_err(|_| anyhow::anyhow!("pipeline server stopped"))?;
        Ok(rx.recv()?)
    }
}

/// The running multi-array pipeline server.
pub struct PipelineServer {
    pub client: PipelineClient,
    pfw: Arc<PartitionedFirmware>,
    metrics: Arc<Mutex<Metrics>>,
    drift: Arc<Mutex<DriftDetector>>,
    depths: Vec<Arc<AtomicUsize>>,
    device_us: f64,
    queue_capacity: usize,
    front: std::thread::JoinHandle<()>,
    stages: Vec<std::thread::JoinHandle<()>>,
}

impl PipelineServer {
    /// Spawn the front batcher plus one stage thread per partition,
    /// predicting per-stage batch time with the default calibrated model.
    pub fn spawn(
        pfw: Arc<PartitionedFirmware>,
        max_wait: Duration,
        queue_depth: usize,
    ) -> PipelineServer {
        PipelineServer::spawn_with_model(pfw, max_wait, queue_depth, &EngineModel::default())
    }

    /// Spawn with an explicit cycle model. The model sets the predicted
    /// per-partition batch times the drift detector compares measured
    /// stage latencies against.
    pub fn spawn_with_model(
        pfw: Arc<PartitionedFirmware>,
        max_wait: Duration,
        queue_depth: usize,
        model: &EngineModel,
    ) -> PipelineServer {
        let k = pfw.k();
        let policy = BatchPolicy { batch: pfw.batch(), max_wait };
        let features = pfw.input_features();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        // Simulated device time per batch for the whole pipeline, plus the
        // per-partition predictions the drift detector measures against.
        let pipe = analyze_pipeline(&pfw, model);
        let device_us = pipe.interval_us;
        let freq_hz = pfw.partitions[0].device.freq_ghz * 1e9;
        let predicted_us: Vec<f64> = pipe
            .partitions
            .iter()
            .map(|p| p.interval_cycles / freq_hz * 1e6)
            .collect();
        let drift = Arc::new(Mutex::new(DriftDetector::new(&predicted_us)));

        // Stage channels: front -> stage 0 -> ... -> stage k-1. Each has a
        // shared depth counter so stages can report queue pressure.
        let mut txs: Vec<SyncSender<StageJob>> = Vec::with_capacity(k);
        let mut rxs: Vec<Receiver<StageJob>> = Vec::with_capacity(k);
        let depths: Vec<Arc<AtomicUsize>> =
            (0..k).map(|_| Arc::new(AtomicUsize::new(0))).collect();
        for _ in 0..k {
            let (tx, rx) = sync_channel(queue_depth.max(1));
            txs.push(tx);
            rxs.push(rx);
        }

        // Stage threads, last to first so each can own its forward sender.
        let mut stages = Vec::with_capacity(k);
        let mut forward: Option<SyncSender<StageJob>> = None;
        let mut forward_depth: Option<Arc<AtomicUsize>> = None;
        for i in (0..k).rev() {
            let rx = rxs.pop().expect("stage receiver");
            let next_tx = forward.take();
            let next_depth = forward_depth.take();
            let my_depth = depths[i].clone();
            let pfw = pfw.clone();
            let metrics = metrics.clone();
            let drift = drift.clone();
            let handle = std::thread::spawn(move || {
                stage_loop(i, &pfw, rx, next_tx, next_depth, my_depth, metrics, drift, device_us)
            });
            stages.push(handle);
            forward = Some(txs[i].clone());
            forward_depth = Some(depths[i].clone());
        }
        stages.reverse();
        let stage0_tx = forward.expect("stage 0 sender");
        let stage0_depth = forward_depth.expect("stage 0 depth");

        let (tx, rx): (SyncSender<Msg>, Receiver<Msg>) = sync_channel(queue_depth.max(1));
        let front = std::thread::spawn(move || {
            let mut batcher = Batcher::new(policy, features);
            let mut waiters: Vec<(u64, Reply)> = Vec::new();
            let flush =
                |batcher: &mut Batcher, waiters: &mut Vec<(u64, Reply)>| {
                    let Some(batch) = batcher.flush(Instant::now()) else { return };
                    let mut replies = Vec::with_capacity(batch.ids.len());
                    for id in &batch.ids {
                        if let Some(pos) = waiters.iter().position(|(wid, _)| wid == id) {
                            replies.push(waiters.swap_remove(pos));
                        }
                    }
                    let job = StageJob {
                        ids: batch.ids,
                        occupancy: batch.occupancy,
                        replies,
                        queue_delays: batch.queue_delays,
                        flushed_at: Instant::now(),
                        act: batch.activation,
                        finals: Vec::new(),
                    };
                    stage0_depth.fetch_add(1, Ordering::Relaxed);
                    if stage0_tx.send(job).is_err() {
                        stage0_depth.fetch_sub(1, Ordering::Relaxed);
                    }
                };
            loop {
                let timeout = batcher
                    .next_deadline(Instant::now())
                    .unwrap_or(Duration::from_secs(3600));
                match rx.recv_timeout(timeout) {
                    Ok(Msg::Req(req, reply)) => {
                        let id = req.id;
                        match batcher.push(req) {
                            // Defense in depth behind the client-side
                            // check: dropping the reply surfaces the
                            // rejection to the waiting caller.
                            Ok(()) => waiters.push((id, reply)),
                            Err(_) => drop(reply),
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Ok(Msg::Shutdown) | Err(RecvTimeoutError::Disconnected) => {
                        while !batcher.is_empty() {
                            flush(&mut batcher, &mut waiters);
                        }
                        return; // dropping stage0_tx unwinds the stages
                    }
                }
                while batcher.ready(Instant::now()) {
                    flush(&mut batcher, &mut waiters);
                }
            }
        });

        PipelineServer {
            client: PipelineClient { tx, next_id: Arc::new(AtomicU64::new(0)), features },
            pfw,
            metrics,
            drift,
            depths,
            device_us,
            queue_capacity: queue_depth.max(1),
            front,
            stages,
        }
    }

    /// The partitioned firmware this pipeline executes.
    pub fn firmware(&self) -> &Arc<PartitionedFirmware> {
        &self.pfw
    }

    pub fn metrics(&self) -> MetricsReport {
        self.metrics.lock().unwrap().report()
    }

    /// One consistent observation of the pipeline: per-stage metrics and
    /// measured-vs-predicted drift in the same [`ServingSnapshot`] shape
    /// the continuous server exposes, so the Prometheus exporter and the
    /// autoscaler consume both server kinds uniformly. The pipeline has no
    /// admission gate of its own, so the admission report is empty and
    /// `replicas` is the single pipeline instance.
    pub fn snapshot(&self) -> ServingSnapshot {
        let queued = self.depths.iter().map(|d| d.load(Ordering::Relaxed)).sum();
        let report = self.drift.lock().unwrap().report();
        ServingSnapshot {
            metrics: self.metrics(),
            admission: AdmissionReport::default(),
            queued,
            queue_capacity: self.queue_capacity,
            replicas: 1,
            batch: self.pfw.batch(),
            batch_us: self.device_us,
            cache: None,
            drift: if report.has_samples() { Some(report) } else { None },
        }
    }

    /// Stop accepting requests, drain in-flight batches through every
    /// stage, and join all threads.
    pub fn shutdown(self) -> MetricsReport {
        let _ = self.client.tx.send(Msg::Shutdown);
        drop(self.client);
        let _ = self.front.join();
        for h in self.stages {
            let _ = h.join();
        }
        let report = self.metrics.lock().unwrap().report();
        report
    }
}

/// One stage thread: execute this partition's firmware on each incoming
/// batch, collect final outputs, forward the link activation (or reply at
/// the pipeline tail).
#[allow(clippy::too_many_arguments)]
fn stage_loop(
    i: usize,
    pfw: &PartitionedFirmware,
    rx: Receiver<StageJob>,
    next_tx: Option<SyncSender<StageJob>>,
    next_depth: Option<Arc<AtomicUsize>>,
    my_depth: Arc<AtomicUsize>,
    metrics: Arc<Mutex<Metrics>>,
    drift: Arc<Mutex<DriftDetector>>,
    device_us: f64,
) {
    let fw = &pfw.partitions[i];
    let tr = crate::obs::tracer();
    tr.set_track_name(format!("stage-{i}"));
    let started = Instant::now();
    let mut busy = Duration::ZERO;
    while let Ok(mut job) = rx.recv() {
        let depth = my_depth.fetch_sub(1, Ordering::Relaxed).saturating_sub(1);
        let t0 = Instant::now();
        let mut outs = {
            let _span = tr
                .span("serve", "stage")
                .with_arg("partition", i)
                .with_arg("occupancy", job.occupancy)
                .with_arg("queue_depth", depth);
            execute_all(fw, &job.act).expect("partition execution failed")
        };
        let exec = t0.elapsed();
        busy += exec;
        drift.lock().unwrap().observe(i, exec.as_secs_f64() * 1e6);
        for (slot, o) in pfw.outputs.iter().enumerate() {
            if o.partition == i {
                job.finals.push((slot, outs[o.output].clone()));
            }
        }
        metrics.lock().unwrap().record_stage_batch(
            i,
            depth,
            busy.as_secs_f64() * 1e6,
            started.elapsed().as_secs_f64() * 1e6,
        );
        match (&next_tx, &next_depth) {
            (Some(tx), Some(depth_ctr)) => {
                job.act = outs.swap_remove(pfw.links[i].from_output);
                depth_ctr.fetch_add(1, Ordering::Relaxed);
                if tx.send(job).is_err() {
                    depth_ctr.fetch_sub(1, Ordering::Relaxed);
                }
            }
            _ => {
                // Pipeline tail: assemble per-output rows and reply.
                job.finals.sort_by_key(|(slot, _)| *slot);
                let exec = job.flushed_at.elapsed();
                for (id, reply) in &job.replies {
                    let Some(slot) = job.ids.iter().position(|jid| jid == id) else { continue };
                    let out: Vec<Vec<i32>> = job
                        .finals
                        .iter()
                        .map(|(_, act)| act.row(slot).to_vec())
                        .collect();
                    let _ = reply.send(out);
                }
                let delays: Vec<Duration> =
                    job.queue_delays.iter().map(|d| *d + exec).collect();
                metrics.lock().unwrap().record_batch(
                    job.occupancy,
                    pfw.batch(),
                    &delays,
                    device_us,
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::CompileConfig;
    use crate::harness::models::{mlp_spec, synth_model};
    use crate::partition::{compile_partitioned, execute_partitioned, PartitionOptions};
    use crate::util::Pcg32;

    fn pipeline(k: usize) -> Arc<PartitionedFirmware> {
        let json = synth_model("pipe_srv", &mlp_spec(&[32, 24, 16, 8], crate::arch::Dtype::I8), 6);
        let mut cfg = CompileConfig::default();
        cfg.batch = 4;
        cfg.tiles_per_layer = Some(1);
        let opts = PartitionOptions { partitions: Some(k), ..Default::default() };
        Arc::new(compile_partitioned(&json, cfg, &opts).unwrap().firmware)
    }

    #[test]
    fn pipelined_responses_match_direct_execution() {
        let pfw = pipeline(2);
        let server = PipelineServer::spawn(pfw.clone(), Duration::from_millis(2), 32);
        let mut rng = Pcg32::seed_from_u64(3);
        let x: Vec<i32> = (0..32).map(|_| rng.gen_i32_in(-128, 127)).collect();
        let got = server.client.infer(x.clone()).unwrap();
        let mut data = vec![0i32; 4 * 32];
        data[..32].copy_from_slice(&x);
        let direct =
            execute_partitioned(&pfw, &Activation::new(4, 32, data).unwrap()).unwrap();
        assert_eq!(got, direct[0].row(0));
        let m = server.shutdown();
        assert_eq!(m.requests, 1);
    }

    #[test]
    fn batches_overlap_and_metrics_cover_every_stage() {
        let pfw = pipeline(3);
        let server = PipelineServer::spawn(pfw.clone(), Duration::from_millis(1), 64);
        let mut handles = Vec::new();
        for i in 0..24 {
            let c = server.client.clone();
            handles.push(std::thread::spawn(move || c.infer(vec![i % 5; 32]).unwrap()));
        }
        let outs: Vec<Vec<i32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Identical inputs give identical outputs regardless of batch slot.
        assert_eq!(outs[0], outs[5]);
        assert_eq!(outs[1], outs[6]);
        let m = server.shutdown();
        assert_eq!(m.requests, 24);
        assert!(m.batches >= 6); // batch 4, 24 requests
        // Per-partition stage metrics: one row per array, sane values.
        assert_eq!(m.stages.len(), 3);
        for s in &m.stages {
            assert_eq!(s.batches, m.batches);
            assert!((0.0..=1.0).contains(&s.busy_fraction));
        }
    }

    #[test]
    fn snapshot_exposes_stage_drift() {
        let pfw = pipeline(2);
        let server = PipelineServer::spawn(pfw, Duration::from_millis(1), 16);
        // No drift before any batch reaches a stage.
        assert!(server.snapshot().drift.is_none());
        for i in 0..8 {
            server.client.infer(vec![i; 32]).unwrap();
        }
        let snap = server.snapshot();
        assert_eq!(snap.replicas, 1);
        assert_eq!(snap.batch, 4);
        assert!(snap.batch_us > 0.0);
        let d = snap.drift.expect("drift present after batches");
        assert_eq!(d.stages.len(), 2);
        for s in &d.stages {
            assert!(s.samples >= 1, "stage {} never observed", s.stage);
            assert!(s.predicted_us > 0.0);
            assert!(s.ratio > 0.0);
        }
        assert!(d.correction > 0.0);
        let m = server.shutdown();
        assert_eq!(m.stages.len(), 2);
    }

    #[test]
    fn shutdown_drains_in_flight_batches() {
        let pfw = pipeline(2);
        let server = PipelineServer::spawn(pfw, Duration::from_secs(10), 16);
        let c = server.client.clone();
        let h = std::thread::spawn(move || c.infer(vec![1; 32]).unwrap());
        std::thread::sleep(Duration::from_millis(50));
        let m = server.shutdown();
        let out = h.join().unwrap();
        assert_eq!(out.len(), 8);
        assert_eq!(m.requests, 1);
    }
}
