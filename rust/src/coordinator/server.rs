//! The serving loop: request intake → dynamic batching → firmware
//! execution → response fan-out.
//!
//! The coordinator owns the event loop and process topology: a dedicated
//! batcher thread drains an mpsc request queue, flushes on batch-full or
//! deadline, executes the batch on the firmware simulator (the simulated
//! device is CPU-bound, so a thread — not an async reactor — is the honest
//! execution model in this offline environment), accounts simulated device
//! time from the cycle model, and answers each request over its own reply
//! channel. Python is never involved: the firmware package is
//! self-contained.

use super::admission::AdmissionError;
use super::batcher::{BatchPolicy, Batcher, Request};
use super::metrics::{Metrics, MetricsReport};
use crate::codegen::firmware::Firmware;
use crate::sim::engine::{analyze, EngineModel};
use crate::sim::functional::execute_all;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Replies carry one feature vector per network output (sink), in
/// [`Firmware::outputs`] order; single-sink models reply with one entry.
type Reply = SyncSender<Vec<Vec<i32>>>;

enum Msg {
    Req(Request, Reply),
    Shutdown,
}

/// A pending reply for one enqueued request.
pub struct InferHandle {
    rx: Receiver<Vec<Vec<i32>>>,
}

impl InferHandle {
    /// Block until the request's batch completes; one feature vector per
    /// network output.
    pub fn wait(self) -> Result<Vec<Vec<i32>>> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("server dropped the request"))
    }
}

/// A client handle to the serving loop (cheap to clone; thread-safe).
#[derive(Clone)]
pub struct Client {
    tx: SyncSender<Msg>,
    next_id: Arc<AtomicU64>,
    features: usize,
}

impl Client {
    /// Submit one sample and wait for the *primary* output feature vector
    /// (the first network output; the only one for single-sink models).
    pub fn infer(&self, features: Vec<i32>) -> Result<Vec<i32>> {
        let mut outs = self.infer_multi(features)?;
        Ok(outs.swap_remove(0))
    }

    /// Submit one sample and wait for **every** network output, one
    /// feature vector per sink in firmware output order.
    pub fn infer_multi(&self, features: Vec<i32>) -> Result<Vec<Vec<i32>>> {
        self.submit(features)?.wait()
    }

    /// Enqueue one sample without waiting for its batch: the returned
    /// handle collects the reply later, so one open-loop driver thread can
    /// keep many requests in flight. Blocks only if the request channel is
    /// at its configured depth (classic sender backpressure); mis-sized
    /// requests are rejected here with the typed admission error.
    pub fn submit(&self, features: Vec<i32>) -> Result<InferHandle> {
        if features.len() != self.features {
            return Err(AdmissionError::FeatureMismatch {
                expected: self.features,
                got: features.len(),
            }
            .into());
        }
        let (tx, rx) = sync_channel(1);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Msg::Req(Request { id, features, enqueued: Instant::now() }, tx))
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        Ok(InferHandle { rx })
    }
}

/// The running server.
pub struct Server {
    pub client: Client,
    fw: Arc<Firmware>,
    metrics: Arc<Mutex<Metrics>>,
    handle: std::thread::JoinHandle<()>,
}

impl Server {
    /// Spawn the serving loop for a compiled firmware.
    pub fn spawn(fw: Arc<Firmware>, max_wait: Duration, queue_depth: usize) -> Server {
        let policy = BatchPolicy { batch: fw.batch, max_wait };
        let features = fw.input_features();
        let (tx, rx): (SyncSender<Msg>, Receiver<Msg>) = sync_channel(queue_depth);
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let metrics_task = metrics.clone();
        // Simulated device time per batch, from the cycle model (constant
        // for a fixed firmware).
        let device_us_per_batch = analyze(&fw, &EngineModel::default()).interval_us;

        let fw_task = fw.clone();
        let handle = std::thread::spawn(move || {
            let fw = fw_task;
            let mut batcher = Batcher::new(policy, features);
            let mut waiters: Vec<(u64, Reply)> = Vec::new();
            loop {
                // Wait for work or the oldest request's deadline.
                let timeout = batcher
                    .next_deadline(Instant::now())
                    .unwrap_or(Duration::from_secs(3600));
                match rx.recv_timeout(timeout) {
                    Ok(Msg::Req(req, reply)) => {
                        let id = req.id;
                        match batcher.push(req) {
                            // Defense in depth behind the client-side
                            // check: dropping the reply channel surfaces
                            // the rejection to the waiting caller.
                            Ok(()) => waiters.push((id, reply)),
                            Err(_) => drop(reply),
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Ok(Msg::Shutdown) | Err(RecvTimeoutError::Disconnected) => {
                        // Drain remaining work, then stop.
                        while !batcher.is_empty() {
                            run_batch(&fw, &mut batcher, &mut waiters, &metrics_task, device_us_per_batch);
                        }
                        return;
                    }
                }
                while batcher.ready(Instant::now()) {
                    run_batch(&fw, &mut batcher, &mut waiters, &metrics_task, device_us_per_batch);
                }
            }
        });

        Server {
            client: Client { tx, next_id: Arc::new(AtomicU64::new(0)), features },
            fw,
            metrics,
            handle,
        }
    }

    /// The firmware this server executes.
    pub fn firmware(&self) -> &Arc<Firmware> {
        &self.fw
    }

    pub fn metrics(&self) -> MetricsReport {
        self.metrics.lock().unwrap().report()
    }

    /// Stop accepting requests, drain pending batches and join the loop.
    pub fn shutdown(self) -> MetricsReport {
        let _ = self.client.tx.send(Msg::Shutdown);
        drop(self.client);
        let _ = self.handle.join();
        let report = self.metrics.lock().unwrap().report();
        report
    }
}

fn run_batch(
    fw: &Arc<Firmware>,
    batcher: &mut Batcher,
    waiters: &mut Vec<(u64, Reply)>,
    metrics: &Arc<Mutex<Metrics>>,
    device_us: f64,
) {
    let Some(batch) = batcher.flush(Instant::now()) else { return };
    let started = Instant::now();
    let outs = {
        let _span = crate::obs::tracer()
            .span("serve", "batch_execute")
            .with_arg("occupancy", batch.occupancy)
            .with_arg("batch", fw.batch);
        execute_all(fw, &batch.activation).expect("firmware execution failed")
    };
    let exec_time = started.elapsed();
    let mut delays = Vec::with_capacity(batch.occupancy);
    for (slot, id) in batch.ids.iter().enumerate() {
        if let Some(pos) = waiters.iter().position(|(wid, _)| wid == id) {
            let (_, reply) = waiters.swap_remove(pos);
            let _ = reply.send(outs.iter().map(|o| o.row(slot).to_vec()).collect());
        }
        delays.push(batch.queue_delays[slot] + exec_time);
    }
    metrics
        .lock()
        .unwrap()
        .record_batch(batch.occupancy, outs[0].batch, &delays, device_us);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{CompileConfig, JsonLayer, JsonModel};
    use crate::passes::compile;

    fn small_fw(batch: usize) -> Arc<Firmware> {
        let weights: Vec<i32> = (0..32 * 16).map(|i| (i % 5) - 2).collect();
        let jm = JsonModel::new(
            "srv",
            vec![JsonLayer::dense("fc1", 32, 16, true, false, "int8", "int8", 0, weights, vec![1i64; 16])],
        );
        let mut cfg = CompileConfig::default();
        cfg.batch = batch;
        cfg.tiles_per_layer = Some(2);
        Arc::new(compile(&jm, cfg).unwrap().firmware.unwrap())
    }

    #[test]
    fn serves_single_request_via_deadline() {
        let fw = small_fw(8);
        let server = Server::spawn(fw.clone(), Duration::from_millis(5), 64);
        let out = server.client.infer(vec![1; 32]).unwrap();
        assert_eq!(out.len(), 16);
        let m = server.metrics();
        assert_eq!(m.requests, 1);
        server.shutdown();
    }

    #[test]
    fn full_batches_answer_everyone_consistently() {
        let fw = small_fw(4);
        let server = Server::spawn(fw.clone(), Duration::from_millis(50), 64);
        let mut handles = Vec::new();
        for i in 0..8 {
            let c = server.client.clone();
            handles.push(std::thread::spawn(move || c.infer(vec![i % 3; 32]).unwrap()));
        }
        let outs: Vec<Vec<i32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Same input => same output regardless of batch slot.
        assert_eq!(outs[0], outs[3]);
        assert_eq!(outs[1], outs[4]);
        let m = server.shutdown();
        assert_eq!(m.requests, 8);
        assert!(m.batches >= 2);
    }

    #[test]
    fn responses_match_direct_execution() {
        let fw = small_fw(2);
        let server = Server::spawn(fw.clone(), Duration::from_millis(2), 8);
        let x = vec![3i32; 32];
        let via_server = server.client.infer(x.clone()).unwrap();
        let mut data = vec![0i32; 2 * 32];
        data[..32].copy_from_slice(&x);
        let direct = crate::sim::functional::execute(
            &fw,
            &crate::sim::functional::Activation::new(2, 32, data).unwrap(),
        )
        .unwrap();
        assert_eq!(via_server, direct.row(0));
        server.shutdown();
    }

    #[test]
    fn multi_sink_model_replies_per_output() {
        // Two heads off one trunk: infer_multi returns one vector per sink
        // (in layer order); infer returns the primary head only.
        let jm = JsonModel::new(
            "srv_heads",
            vec![
                JsonLayer::dense("trunk", 16, 16, false, false, "int8", "int8", 0, vec![1; 256], vec![]),
                JsonLayer::dense("head_a", 16, 8, false, false, "int8", "int8", 0, vec![1; 128], vec![])
                    .with_inputs(&["trunk"]),
                JsonLayer::dense("head_b", 16, 2, false, false, "int8", "int8", 0, vec![-1; 32], vec![])
                    .with_inputs(&["trunk"]),
            ],
        );
        let mut cfg = CompileConfig::default();
        cfg.batch = 2;
        cfg.tiles_per_layer = Some(1);
        let fw = Arc::new(compile(&jm, cfg).unwrap().firmware.unwrap());
        assert_eq!(fw.outputs.len(), 2);
        let server = Server::spawn(fw.clone(), Duration::from_millis(2), 8);
        let outs = server.client.infer_multi(vec![1; 16]).unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].len(), 8);
        assert_eq!(outs[1].len(), 2);
        let primary = server.client.infer(vec![1; 16]).unwrap();
        assert_eq!(primary, outs[0]);
        server.shutdown();
    }

    #[test]
    fn submit_overlaps_requests_and_rejects_mis_sized_ones() {
        let fw = small_fw(4);
        let server = Server::spawn(fw.clone(), Duration::from_millis(2), 64);
        // Typed rejection at the client edge, before the queue.
        let err = server.client.submit(vec![1; 31]).unwrap_err();
        let typed = err.downcast_ref::<AdmissionError>().expect("typed admission error");
        assert_eq!(*typed, AdmissionError::FeatureMismatch { expected: 32, got: 31 });
        // One driver thread keeps several requests in flight.
        let handles: Vec<InferHandle> =
            (0..6).map(|i| server.client.submit(vec![i % 3; 32]).unwrap()).collect();
        let outs: Vec<Vec<Vec<i32>>> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
        assert_eq!(outs[0], outs[3]);
        assert_eq!(outs[1], outs[4]);
        let m = server.shutdown();
        assert_eq!(m.requests, 6);
    }

    #[test]
    fn shutdown_drains_pending() {
        let fw = small_fw(64); // large batch: deadline flush only
        let server = Server::spawn(fw.clone(), Duration::from_secs(10), 64);
        let c = server.client.clone();
        let h = std::thread::spawn(move || c.infer(vec![2; 32]).unwrap());
        // Give the request time to enqueue, then shut down; the drain path
        // must still answer it.
        std::thread::sleep(Duration::from_millis(50));
        let m = server.shutdown();
        let out = h.join().unwrap();
        assert_eq!(out.len(), 16);
        assert_eq!(m.requests, 1);
    }
}
